//! Quickstart: train the small CNN with AdaQAT on synthetic CIFAR-10.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```
//!
//! Demonstrates the whole public API in ~40 lines: open the runtime,
//! configure an experiment, run it, read the result. Takes ~1 minute on
//! a laptop-class CPU.

use adaqat::config::ExperimentConfig;
use adaqat::coordinator::{default_runtime, Experiment};

fn main() -> anyhow::Result<()> {
    adaqat::util::logger::init();

    // 1. Open the AOT artifacts (built once by `make artifacts`).
    let runtime = default_runtime()?;
    let model = runtime.load_model("smallcnn")?;

    // 2. Describe the experiment. Everything has a sane default; we
    //    shrink sizes so the quickstart finishes fast and raise the
    //    bit-width learning rates so the adaptation is visible within
    //    three epochs (the paper's 1e-3/5e-4 are tuned for 150+ epochs).
    let mut cfg = ExperimentConfig::default_for("smallcnn");
    cfg.epochs = 3;
    cfg.train_size = 2048;
    cfg.test_size = 512;
    cfg.lambda = 0.15; // hardware-vs-accuracy balance (paper eq. (2))
    cfg.eta_w = 0.02;
    cfg.eta_a = 0.01;

    // 3. Run: Rust drives the compiled HLO train/probe/eval graphs; the
    //    AdaQAT controller adapts N_w / N_a between steps.
    let exp = Experiment::new(&model, cfg)?;
    let result = exp.run()?;

    // 4. Inspect.
    let (k_w, k_a) = result.final_bits;
    println!("\n=== quickstart result ===");
    println!("learned bit-widths  W/A = {k_w}/{k_a}");
    println!("test top-1          {:.1}%", result.test_top1 * 100.0);
    println!("weight compression  {:.1}x vs fp32", result.wcr);
    println!("BitOPs              {:.3} Gb", result.bitops_g);
    println!(
        "steps               {} ({:.0} ms/step)",
        result.steps,
        result.step_seconds * 1e3
    );
    for e in &result.epochs {
        println!(
            "  epoch {}: train acc {:.3} | test acc {:.3} | bits {}/{}",
            e.epoch, e.train_acc, e.test_acc, e.k_w, e.k_a
        );
    }
    Ok(())
}

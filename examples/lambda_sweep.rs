//! λ sweep (the Table III experiment at example scale): how the
//! hardware-loss balance trades compression against accuracy.
//!
//! Runs AdaQAT from scratch at λ ∈ {0.2, 0.15, 0.1} on the small CNN
//! (fast) and prints the learned (W, A, top-1) triple per λ — the paper's
//! qualitative claim is that larger λ compresses harder and scores lower.
//!
//! ```bash
//! cargo run --release --example lambda_sweep
//! cargo run --release --example lambda_sweep -- --model resnet20 --epochs 4
//! ```

use adaqat::config::ExperimentConfig;
use adaqat::coordinator::{default_runtime, Experiment};
use adaqat::metrics::Table;
use adaqat::util::cli::Args;

fn main() -> anyhow::Result<()> {
    adaqat::util::logger::init();
    let args = Args::from_env().map_err(|e| anyhow::anyhow!(e))?;
    let model_key = args.get_str("model", "smallcnn");

    let runtime = default_runtime()?;
    let model = runtime.load_model(&model_key)?;

    let mut table = Table::new(&["lambda", "W", "A", "top-1 (%)", "WCR", "BitOPs (Gb)"]);
    for lambda in [0.2, 0.15, 0.1] {
        let mut cfg = ExperimentConfig::default_for(&model_key);
        cfg.epochs = 3;
        cfg.train_size = 2048;
        cfg.test_size = 512;
        cfg.eta_w = 0.02;
        cfg.eta_a = 0.01;
        cfg.apply_args(&args).map_err(|e| anyhow::anyhow!(e))?;
        cfg.lambda = lambda;
        let result = Experiment::new(&model, cfg)?.run()?;
        let (k_w, k_a) = result.final_bits;
        table.row(vec![
            format!("{lambda}"),
            k_w.to_string(),
            k_a.to_string(),
            format!("{:.1}", result.test_top1 * 100.0),
            format!("{:.1}x", result.wcr),
            format!("{:.3}", result.bitops_g),
        ]);
    }

    println!("\n=== λ sweep ({model_key}) — cf. paper Table III ===");
    print!("{}", table.render());
    println!("expected shape: larger λ ⇒ fewer bits and (weakly) lower top-1.");
    Ok(())
}

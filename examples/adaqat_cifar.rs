//! End-to-end driver: AdaQAT on ResNet-20 / synthetic CIFAR-10 — the
//! run recorded in EXPERIMENTS.md (§End-to-end).
//!
//! Trains for a few hundred steps through the full three-layer stack
//! (Rust coordinator → compiled HLO with Pallas quantizer kernels),
//! logging the loss curve, the bit-width trajectory, and the final
//! accuracy/compression numbers. Outputs land in `runs/adaqat_cifar/`
//! (trace.csv, epochs.csv, final.ckpt).
//!
//! ```bash
//! cargo run --release --example adaqat_cifar            # default ~5 min
//! cargo run --release --example adaqat_cifar -- --epochs 8 --train_size 8192
//! ```

use adaqat::config::ExperimentConfig;
use adaqat::coordinator::{default_runtime, Experiment};
use adaqat::metrics::ascii_plot;
use adaqat::util::cli::Args;

fn main() -> anyhow::Result<()> {
    adaqat::util::logger::init();
    let args = Args::from_env().map_err(|e| anyhow::anyhow!(e))?;

    let runtime = default_runtime()?;
    let model = runtime.load_model("resnet20")?;

    let mut cfg = ExperimentConfig::default_for("resnet20");
    cfg.epochs = 4;
    cfg.train_size = 4096; // 32 steps/epoch at batch 128
    cfg.test_size = 1024;
    cfg.lambda = 0.15;
    // CPU-scale schedule: the paper runs 300 epochs with η_w = 1e-3; at
    // a few hundred steps we scale the bit-width LRs up accordingly so
    // the adaptation and oscillation dynamics are observable (Fig. 1).
    cfg.eta_w = 0.03;
    cfg.eta_a = 0.015;
    cfg.out_dir = Some("runs/adaqat_cifar".into());
    cfg.apply_args(&args).map_err(|e| anyhow::anyhow!(e))?;

    let exp = Experiment::new(&model, cfg)?;
    let result = exp.run()?;

    println!("\n=== AdaQAT / ResNet-20 / synthetic CIFAR-10 ===");
    println!(
        "{:<6} {:>10} {:>10} {:>10} {:>9} {:>6}",
        "epoch", "train_loss", "train_acc", "test_loss", "test_acc", "W/A"
    );
    for e in &result.epochs {
        println!(
            "{:<6} {:>10.4} {:>10.3} {:>10.4} {:>9.3} {:>6}",
            e.epoch,
            e.train_loss,
            e.train_acc,
            e.test_loss,
            e.test_acc,
            format!("{}/{}", e.k_w, e.k_a)
        );
    }

    // loss curve + bit-width staircase over probe steps
    let loss: Vec<f64> = result.trace.iter().map(|t| t.train_loss).collect();
    let nw: Vec<f64> = result.trace.iter().map(|t| t.n_w).collect();
    let na: Vec<f64> = result.trace.iter().map(|t| t.n_a).collect();
    if !loss.is_empty() {
        println!("\ntrain loss over steps:");
        print!("{}", ascii_plot(&[("loss", &loss)], 72, 10));
        println!("\nfractional bit-widths over steps:");
        print!("{}", ascii_plot(&[("N_w", &nw), ("N_a", &na)], 72, 10));
    }

    let (k_w, k_a) = result.final_bits;
    println!(
        "\nfinal:  W/A {k_w}/{k_a}  top-1 {:.2}%  WCR {:.1}x  BitOPs {:.2} Gb",
        result.test_top1 * 100.0,
        result.wcr,
        result.bitops_g
    );
    println!(
        "wall {:.1}s, {} steps, {:.0} ms/step",
        result.wall_seconds,
        result.steps,
        result.step_seconds * 1e3
    );
    println!("artifacts in runs/adaqat_cifar/ (trace.csv, epochs.csv, final.ckpt)");
    Ok(())
}

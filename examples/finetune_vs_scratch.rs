//! Fine-tuning vs training from scratch — the flexibility claim of the
//! paper's abstract ("works well in both training from scratch and
//! fine-tuning scenarios", cf. the two "Ours" blocks of Table I).
//!
//! 1. pretrains an fp32 model (cached under runs/pretrained/),
//! 2. runs AdaQAT fine-tuning from that checkpoint,
//! 3. runs AdaQAT from scratch with the same budget,
//! and prints both results side by side.
//!
//! ```bash
//! cargo run --release --example finetune_vs_scratch
//! cargo run --release --example finetune_vs_scratch -- --model resnet20
//! ```

use std::path::Path;

use adaqat::config::{ExperimentConfig, Scenario};
use adaqat::coordinator::{default_runtime, ensure_fp32_pretrain, Experiment};
use adaqat::metrics::Table;
use adaqat::util::cli::Args;

fn main() -> anyhow::Result<()> {
    adaqat::util::logger::init();
    let args = Args::from_env().map_err(|e| anyhow::anyhow!(e))?;
    let model_key = args.get_str("model", "smallcnn");

    let runtime = default_runtime()?;
    let model = runtime.load_model(&model_key)?;

    let mut base = ExperimentConfig::default_for(&model_key);
    base.epochs = 3;
    base.train_size = 2048;
    base.test_size = 512;
    base.eta_w = 0.02;
    base.eta_a = 0.01;
    base.apply_args(&args).map_err(|e| anyhow::anyhow!(e))?;

    // fp32 pretrain (the "pretrained full-precision model" of §IV)
    let ck = ensure_fp32_pretrain(&model, &base, base.epochs, Path::new("runs/pretrained"))?;

    let mut table = Table::new(&["scenario", "W/A", "top-1 (%)", "WCR", "BitOPs (Gb)"]);
    for (label, scenario) in [
        ("fine-tuning", Scenario::Finetune { checkpoint: ck.clone() }),
        ("from scratch", Scenario::Scratch),
    ] {
        let mut cfg = base.clone();
        cfg.scenario = scenario;
        // the paper fine-tunes with a 10x smaller LR (§IV-A)
        if label == "fine-tuning" {
            cfg.lr = 0.01;
        }
        let result = Experiment::new(&model, cfg)?.run()?;
        let (k_w, k_a) = result.final_bits;
        table.row(vec![
            label.to_string(),
            format!("{k_w}/{k_a}"),
            format!("{:.1}", result.test_top1 * 100.0),
            format!("{:.1}x", result.wcr),
            format!("{:.3}", result.bitops_g),
        ]);
    }

    println!("\n=== AdaQAT fine-tuning vs from scratch ({model_key}) ===");
    print!("{}", table.render());
    println!("expected shape: both land within a fraction of a point of each");
    println!("other (paper Table I: 92.2 vs 92.1 at 3/4).");
    Ok(())
}

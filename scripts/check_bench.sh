#!/usr/bin/env bash
# Bench-regression gate (ISSUE 4): compare the freshly emitted
# BENCH_*.json files at the repo root against the committed baselines in
# bench_baselines/, failing when a throughput metric regresses by more
# than 25%.
#
# Absolute ms/step numbers do not travel between machines, so the gate
# compares *ratio* metrics only — dimensionless speedups that measure
# the kernels against a same-run baseline executed on the same box:
#
#   BENCH_kernels.json       speedup_vs_legacy   per (mode, k_w, batch)
#   BENCH_kernels.json       speedup_vs_i8       per (mode, k_w, batch)
#                            (mode "bitserial": the §14 popcount GEMM
#                             vs the dense path at k_w = k_a = k —
#                             floors fall as k grows because popcount
#                             work is ∝ k_w·k_a while dense work is
#                             flat; the dense side is vectorized as of
#                             §16, so floors sit below parity past the
#                             BITSERIAL_MAX_PRODUCT crossover)
#   BENCH_kernels.json       speedup_vs_scalar   per (mode, k_w, batch)
#                            (mode "dense": §16 SIMD dot kernels vs the
#                             same plan forced portable in-process)
#   BENCH_kernels.json       speedup_vs_perrow   per (mode, k_w, batch)
#                            (mode "bslice": §16 whole-batch bit-plane
#                             slicing vs per-row runs of the same plan)
#   BENCH_conv_native.json   speedup_vs_direct   per (k_w, batch)
#   BENCH_conv_native.json   speedup_vs_f32      per (k_w, batch)
#                            (the §18 resnet rows: integer residual
#                             serving vs the same QuantConvNet with raw
#                             f32 payloads and no activation quant)
#   BENCH_train_native.json  steps_per_sec / fp32 steps_per_sec
#                                                per quantized config
#   BENCH_obs.json           overhead_ratio      instrumented / plain
#                            serve throughput — an *absolute* floor
#                            (0.95 = 5% budget), no tolerance applied
#   BENCH_serve.json         overload_score      per load point — a 0/1
#                            pass score from the §19 overload scenario
#                            (4x offered load: rejections carry finite
#                            retry_after_ms, accounting conserves every
#                            submit, admitted p99 stays bounded); the
#                            1.0 baseline with the 0.75x tolerance
#                            means only a clean 1.0 passes
#
# The committed baselines are deliberately conservative floors (they
# sit below the acceptance numbers in DESIGN.md §11/§13); to ratchet
# them up, copy a fresh BENCH_*.json from a healthy run into
# bench_baselines/ — the files share one format.
#
# Usage: scripts/check_bench.sh   (from the repo root or anywhere)
set -euo pipefail
cd "$(dirname "$0")/.." || exit 1

PY=python3
command -v "$PY" >/dev/null 2>&1 || PY=python

"$PY" - <<'EOF'
import json, os, sys

TOLERANCE = 0.75  # fresh must be >= 25% of the way below baseline

def ratio_metric(doc, metric, key_fields):
    """(key -> ratio) straight from a per-row ratio field. Rows lacking
    the metric are skipped *before* keying, so row families that share
    key fields but carry disjoint metrics (e.g. the smallcnn
    speedup_vs_direct rows and the resnet speedup_vs_f32 rows in
    BENCH_conv_native.json) cannot clobber each other."""
    out = {}
    for row in doc.get("results", []):
        if metric not in row:
            continue
        # "mode" defaults to "quant" so pre-bitserial files still key
        key = tuple(row.get(f, "quant") if f == "mode" else row.get(f)
                    for f in key_fields)
        out[key] = row[metric]
    return out

def train_relative(doc):
    """steps_per_sec of each quantized config relative to the same
    run's fp32 row — machine-independent."""
    rows = {r["config"]: r for r in doc.get("results", [])}
    fp32 = rows.get("fp32", {}).get("steps_per_sec")
    if not fp32:
        return {}
    return {(c,): r["steps_per_sec"] / fp32
            for c, r in rows.items() if c != "fp32"}

CHECKS = [
    ("BENCH_kernels.json",      "speedup_vs_legacy",
     lambda d: ratio_metric(d, "speedup_vs_legacy", ("mode", "k_w", "batch"))),
    ("BENCH_kernels.json",      "speedup_vs_i8",
     lambda d: ratio_metric(d, "speedup_vs_i8", ("mode", "k_w", "batch"))),
    ("BENCH_kernels.json",      "speedup_vs_scalar",
     lambda d: ratio_metric(d, "speedup_vs_scalar", ("mode", "k_w", "batch"))),
    ("BENCH_kernels.json",      "speedup_vs_perrow",
     lambda d: ratio_metric(d, "speedup_vs_perrow", ("mode", "k_w", "batch"))),
    ("BENCH_conv_native.json",  "speedup_vs_direct",
     lambda d: ratio_metric(d, "speedup_vs_direct", ("k_w", "batch"))),
    ("BENCH_conv_native.json",  "speedup_vs_f32",
     lambda d: ratio_metric(d, "speedup_vs_f32", ("k_w", "batch"))),
    ("BENCH_train_native.json", "steps_per_sec vs fp32",
     train_relative),
    ("BENCH_serve.json",        "overload_score",
     lambda d: ratio_metric(d, "overload_score", ("load",))),
]

failures = []
for fname, label, extract in CHECKS:
    base_path = os.path.join("bench_baselines", fname)
    if not os.path.exists(base_path):
        failures.append(f"{fname}: missing baseline {base_path}")
        continue
    if not os.path.exists(fname):
        failures.append(f"{fname}: bench output missing — run scripts/verify.sh first")
        continue
    with open(base_path) as f:
        baseline = extract(json.load(f))
    with open(fname) as f:
        fresh = extract(json.load(f))
    if not baseline:
        failures.append(f"{base_path}: no comparable rows — baseline malformed?")
        continue
    print(f"== {fname} ({label}; fail below {TOLERANCE:.2f}x baseline) ==")
    for key, want in sorted(baseline.items(), key=str):
        got = fresh.get(key)
        tag = "/".join(str(k) for k in key)
        if got is None:
            failures.append(f"{fname} {tag}: row missing from fresh output")
            print(f"  {tag:>12}: baseline {want:6.2f}  fresh MISSING")
            continue
        ok = got >= want * TOLERANCE
        print(f"  {tag:>12}: baseline {want:6.2f}  fresh {got:6.2f}  "
              f"{'ok' if ok else 'REGRESSION'}")
        if not ok:
            failures.append(
                f"{fname} {tag}: {label} {got:.2f} < {TOLERANCE:.2f} x "
                f"baseline {want:.2f}")

# --- observability overhead gate (DESIGN.md §15) -----------------------
# Unlike the throughput ratchets above, this is an *absolute floor*: the
# committed baseline overhead_ratio (0.95 = at most 5% overhead) is the
# budget itself, so no TOLERANCE multiplier is applied — both sides are
# same-run, same-box ratios and travel between machines as-is.
OBS = "BENCH_obs.json"
obs_base_path = os.path.join("bench_baselines", OBS)
if not os.path.exists(obs_base_path):
    failures.append(f"{OBS}: missing baseline {obs_base_path}")
elif not os.path.exists(OBS):
    failures.append(f"{OBS}: bench output missing — run scripts/verify.sh first")
else:
    with open(obs_base_path) as f:
        obs_floor = {r["metric"]: r["overhead_ratio"]
                     for r in json.load(f).get("results", [])
                     if "overhead_ratio" in r}
    with open(OBS) as f:
        obs_fresh = {r["metric"]: r["overhead_ratio"]
                     for r in json.load(f).get("results", [])
                     if "overhead_ratio" in r}
    if not obs_floor:
        failures.append(f"{obs_base_path}: no overhead_ratio rows — baseline malformed?")
    print(f"== {OBS} (overhead_ratio; absolute floor, no tolerance) ==")
    for metric, floor in sorted(obs_floor.items()):
        got = obs_fresh.get(metric)
        if got is None:
            failures.append(f"{OBS} {metric}: row missing from fresh output")
            print(f"  {metric:>14}: floor {floor:6.2f}  fresh MISSING")
            continue
        ok = got >= floor
        print(f"  {metric:>14}: floor {floor:6.2f}  fresh {got:6.2f}  "
              f"{'ok' if ok else 'OVER BUDGET'}")
        if not ok:
            failures.append(
                f"{OBS} {metric}: overhead_ratio {got:.3f} < floor {floor:.2f} "
                f"(instrumentation overhead exceeds the 5% budget)")

if failures:
    print("\nbench-regression gate FAILED:", file=sys.stderr)
    for f in failures:
        print(f"  - {f}", file=sys.stderr)
    sys.exit(1)
print("\nbench-regression gate: OK")
EOF

#!/usr/bin/env bash
# Tier-1 verification (ROADMAP.md) + bench smoke.
#
#   scripts/verify.sh           # build, unit+integration tests, bench smoke
#
# Works offline: integration tests and the paper benches skip themselves
# when AOT artifacts are absent (DESIGN.md §3); the serve bench runs
# fully on the pure-Rust reference backend, so the serving subsystem is
# exercised end-to-end either way.
set -euo pipefail
cd "$(dirname "$0")/../rust"

echo "== tier-1: cargo build --release =="
cargo build --release

echo "== tier-1: cargo test -q =="
cargo test -q

echo "== bench smoke: cargo test -q --benches =="
# harness = false benches run as plain binaries; each either completes a
# smoke-scale run or prints why it skipped
cargo test -q --benches

echo "== kernels bench: emit BENCH_kernels.json =="
# f32-vs-quantized GEMM sweep (k x batch) on the demo MLP; the JSON at
# the repo root is the perf trajectory later PRs must not regress
cargo bench --bench kernels -- --iters 3 --out ../BENCH_kernels.json
test -s ../BENCH_kernels.json

echo "== native training bench: emit BENCH_train_native.json =="
# steps/sec of the pure-Rust train step at k in {2,4,8} vs fp32
# (DESIGN.md §12); runs fully offline, like the kernels sweep
cargo bench --bench train_native -- --steps 20 --out ../BENCH_train_native.json
test -s ../BENCH_train_native.json

echo "verify: OK"

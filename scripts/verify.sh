#!/usr/bin/env bash
# Tier-1 verification (ROADMAP.md) + bench smoke + bench emission.
#
#   scripts/verify.sh           # build, unit+integration tests, bench
#                               # smoke, BENCH_*.json emission
#
# Works offline: integration tests and the paper benches skip themselves
# when AOT artifacts are absent (DESIGN.md §3); the serve bench and the
# native training/conv benches run fully on the pure-Rust backends, so
# the serving and training subsystems are exercised end-to-end either
# way.
#
# CI gates layered on top of this script (.github/workflows/ci.yml):
#   lint        cargo fmt --check + cargo clippy --all-targets -D warnings
#               (style-lint allowances live in rust/Cargo.toml [lints])
#               + shellcheck over scripts/*.sh
#   verify      this script
#   analysis    scripts/analyze.sh — Miri / ThreadSanitizer /
#               AddressSanitizer matrix over the unsafe core
#               (DESIGN.md §17)
#   e2e         release-mode tests/train_native.rs + tests/conv_native.rs
#               (the offline train→export→serve closures, MLP and conv)
#   bench gate  scripts/check_bench.sh — the BENCH_*.json ratio metrics
#               emitted below vs the committed bench_baselines/*.json,
#               failing on a >25% throughput regression
set -euo pipefail
cd "$(dirname "$0")/../rust" || exit 1

echo "== tier-1: cargo build --release =="
cargo build --release

echo "== unsafe policy audit (DESIGN.md §17) =="
# source-side enforcement of the unsafe contract: every unsafe site
# carries a SAFETY justification, unsafe Send/Sync impls carry AUDIT
# tags, Ordering::Relaxed stays inside the allow-listed counter modules
# (rust/unsafe_audit.conf); reuses the release build from the step above
cargo run --release --bin unsafe_audit -- --report ../UNSAFE_AUDIT.json
test -s ../UNSAFE_AUDIT.json

echo "== tier-1: cargo test -q =="
cargo test -q

echo "== chaos suite: cargo test --features failpoints =="
# deterministic fault injection (DESIGN.md §19): the failpoints feature
# compiles the injection sites in, and the chaos module in
# tests/concurrency.rs stalls the batcher, panics workers, and resets
# connections while asserting the accounting identity
#   answered + shed + overloaded + deadline_expired == submitted
# holds exactly. The feature is additive, so the whole test suite runs
# with it on.
cargo test -q --features failpoints --test concurrency

echo "== kernel tests under ADAQAT_FORCE_PORTABLE=1 =="
# the same kernel suite with the SIMD dispatch forced onto the portable
# scalar paths (DESIGN.md §16) — proves the fallback stays bit-identical
# on the very hardware where the vector paths normally win
ADAQAT_FORCE_PORTABLE=1 cargo test -q kernels::

echo "== bench smoke: cargo test -q --benches =="
# harness = false benches run as plain binaries; each either completes a
# smoke-scale run or prints why it skipped
cargo test -q --benches

echo "== kernels bench: emit BENCH_kernels.json =="
# f32-vs-quantized GEMM sweep (k x batch) on the demo MLP; the JSON at
# the repo root is the perf trajectory later PRs must not regress
cargo bench --bench kernels -- --iters 3 --out ../BENCH_kernels.json
test -s ../BENCH_kernels.json

echo "== native training bench: emit BENCH_train_native.json =="
# steps/sec of the pure-Rust train step at k in {2,4,8} vs fp32
# (DESIGN.md §12); runs fully offline, like the kernels sweep
cargo bench --bench train_native -- --steps 20 --out ../BENCH_train_native.json
test -s ../BENCH_train_native.json

echo "== native conv bench: emit BENCH_conv_native.json =="
# integer im2col conv vs direct f32 convolution on the native smallcnn
# (DESIGN.md §13); the speedup_vs_direct ratios feed the CI bench gate
# (scripts/check_bench.sh — run there as its own step so a perf
# regression is its own red X, not a failure buried inside this script;
# run it by hand after this script for the same check locally)
cargo bench --bench conv_native -- --iters 3 --out ../BENCH_conv_native.json
test -s ../BENCH_conv_native.json

echo "== obs bench: emit BENCH_obs.json =="
# serve throughput with the metrics samplers on vs off (DESIGN.md §15);
# the overhead_ratio feeds the CI bench gate, which holds it >= 0.95
# (instrumentation may cost at most 5% of uninstrumented throughput)
cargo bench --bench obs -- --iters 3 --out ../BENCH_obs.json
test -s ../BENCH_obs.json

echo "== serve bench: emit BENCH_serve.json =="
# the §19 overload scenario: 4x offered load against a small queue with
# admission control armed; the 0/1 overload_score (finite retry-after
# hints, exact accounting, bounded admitted p99) feeds the CI bench gate
cargo bench --bench serve -- --out ../BENCH_serve.json
test -s ../BENCH_serve.json

echo "verify: OK"

#!/usr/bin/env bash
# Unsafe-core analysis matrix (DESIGN.md §17).
#
#   scripts/analyze.sh                        # run what the host can
#   ADAQAT_ANALYZE_STRICT=1 scripts/analyze.sh  # skips become failures
#
# Four stages, each proving a different class of invariant:
#
#   1. unsafe_audit   source-side policy: SAFETY/AUDIT comments present,
#                     Ordering::Relaxed confined to the allow-list
#                     (rust/unsafe_audit.conf). Needs only the stable
#                     toolchain; also runs inside scripts/verify.sh.
#   2. Miri           UB interpreter over the portable kernel / pack /
#                     quant / SplitMut suites. The SIMD paths are cfg'd
#                     out under Miri (ISA detection pins Portable), so
#                     what runs is exactly the portable arithmetic plus
#                     the raw-pointer carve logic the SIMD paths share.
#   3. TSan           ThreadSanitizer over tests/concurrency.rs — the
#                     jittered worker-pool / queue / trace-ring /
#                     registry stress suite, plus (via the failpoints
#                     feature) the §19 chaos schedules: batcher stalls,
#                     worker panics, connection resets.
#   4. ASan           AddressSanitizer over the SplitMut and scratch-
#                     arena unit suites — the raw-pointer carve paths
#                     and the poisoned-mutex recovery path.
#
# Stages 2–4 need a rustup nightly toolchain (Miri additionally the
# `miri` component, the sanitizers the `rust-src` component for
# -Zbuild-std). Hosts without them skip those stages with a note; the
# CI `analysis` job (.github/workflows/ci.yml) installs all three and
# exports ADAQAT_ANALYZE_STRICT=1 so a silent skip can never turn the
# matrix green.
set -euo pipefail
cd "$(dirname "$0")/../rust" || exit 1

STRICT="${ADAQAT_ANALYZE_STRICT:-0}"

skip() {
  # $1 = stage name, $2 = reason
  if [ "$STRICT" = "1" ]; then
    echo "analyze: FAIL (strict mode): $1 skipped — $2" >&2
    exit 1
  fi
  echo "analyze: skip $1 — $2"
}

have_nightly() {
  command -v rustup >/dev/null 2>&1 &&
    rustup run nightly rustc --version >/dev/null 2>&1
}

nightly_component() {
  # component rows read e.g. "miri-x86_64-unknown-linux-gnu (installed)"
  rustup component list --toolchain nightly 2>/dev/null |
    grep -q "^$1.*(installed)"
}

echo "== analysis 1/4: unsafe policy audit =="
cargo run --release --bin unsafe_audit -- --report ../UNSAFE_AUDIT.json
test -s ../UNSAFE_AUDIT.json

echo "== analysis 2/4: Miri (portable kernel/pack/quant/SplitMut) =="
if have_nightly && nightly_component miri; then
  # --skip pool: the worker-pool tests park persistent threads on a
  # condvar; Miri treats threads still live at process exit as an
  # error, and the pool's schedule space is TSan's job (stage 3).
  # ADAQAT_FORCE_PORTABLE is forwarded so the forced-portable dispatch
  # pairs exercise the same env contract under the interpreter.
  MIRIFLAGS="-Zmiri-env-forward=ADAQAT_FORCE_PORTABLE" \
    ADAQAT_FORCE_PORTABLE=1 \
    cargo +nightly miri test --lib -- --skip pool \
    kernels::pack kernels::activ quant:: splitmut_
else
  skip "Miri" "rustup nightly with the miri component is not installed"
fi

HOST_TARGET=""
if have_nightly; then
  HOST_TARGET="$(rustup run nightly rustc -vV | sed -n 's/^host: //p')"
fi

echo "== analysis 3/4: ThreadSanitizer (tests/concurrency.rs) =="
if have_nightly && nightly_component rust-src; then
  # explicit --target keeps RUSTFLAGS off host build scripts; a
  # dedicated target dir keeps sanitized artifacts from thrashing the
  # regular build cache
  # --features failpoints compiles the §19 chaos module in, so the
  # batcher-stall / worker-panic / connection-reset schedules run under
  # the race detector too, not just in tier-1
  RUSTFLAGS="-Zsanitizer=thread" \
    CARGO_TARGET_DIR=target/tsan \
    cargo +nightly test -Zbuild-std --target "$HOST_TARGET" \
    --features failpoints --test concurrency
else
  skip "TSan" "rustup nightly with the rust-src component is not installed"
fi

echo "== analysis 4/4: AddressSanitizer (SplitMut + scratch suites) =="
if have_nightly && nightly_component rust-src; then
  # detect_leaks=0: the worker pool parks persistent threads that are
  # deliberately alive at process exit; LeakSanitizer flags their
  # stacks, and leak detection is not what this stage is for (the
  # carve/recovery paths are the memory-error surface under test)
  RUSTFLAGS="-Zsanitizer=address" \
    CARGO_TARGET_DIR=target/asan \
    ASAN_OPTIONS="detect_leaks=0" \
    cargo +nightly test -Zbuild-std --target "$HOST_TARGET" \
    --lib -- splitmut scratch
else
  skip "ASan" "rustup nightly with the rust-src component is not installed"
fi

echo "analyze: OK"

#!/usr/bin/env bash
# Docs-drift gate (ISSUE 9, satellite d): every `--flag` token that
# docs/HANDBOOK.md mentions — in fenced command blocks or prose — must
# exist in one of the CLI flag tables in rust/src/main.rs (the
# `const *_FLAGS: &[&str]` consts that the argument parser validates
# against). A renamed or removed flag therefore fails CI instead of
# silently rotting the operator walkthrough.
#
# The companion rustdoc gate (`RUSTDOCFLAGS="-D warnings" cargo doc`)
# lives in .github/workflows/ci.yml next to the call site of this
# script; this half covers the handbook, that half covers doc comments.
#
# Usage: scripts/check_docs.sh   (from the repo root or anywhere)
set -euo pipefail
cd "$(dirname "$0")/.." || exit 1

PY=python3
command -v "$PY" >/dev/null 2>&1 || PY=python

"$PY" - <<'EOF'
import re
import sys

SRC = "rust/src/main.rs"
DOC = "docs/HANDBOOK.md"

with open(SRC) as f:
    src = f.read()

# The flag universe: every quoted name inside a `*_FLAGS: &[&str]`
# const. The parser rejects anything outside these tables, so they are
# the ground truth the handbook must agree with.
valid = set()
tables = re.findall(r"_FLAGS: &\[&str\] =\s*&\[(.*?)\];", src, re.S)
for body in tables:
    valid.update(re.findall(r'"([a-z][a-z0-9_]*)"', body))
if not tables or not valid:
    sys.exit(f"check_docs: no *_FLAGS tables found in {SRC} — "
             "did the CLI parser move?")

with open(DOC) as f:
    text = f.read()
# Join backslash-continued shell lines so multi-line commands read as
# one, drop lines invoking cargo (whose --release/--test flags are not
# ours to validate), then collect every `--flag` token. The lookbehind
# keeps `---` table rules and mid-word dashes out.
text = text.replace("\\\n", " ")
lines = [ln for ln in text.splitlines() if "cargo " not in ln]
used = set(re.findall(r"(?<![-\w])--([a-z][a-z0-9_]*)", "\n".join(lines)))
if not used:
    sys.exit(f"check_docs: no --flag tokens found in {DOC} — "
             "extraction broken or handbook gutted?")

unknown = sorted(used - valid)
if unknown:
    print(f"docs gate FAILED: {DOC} references flags {SRC} does not "
          "define:", file=sys.stderr)
    for flag in unknown:
        print(f"  --{flag}", file=sys.stderr)
    print("(fix the handbook, or add the flag to the *_FLAGS table "
          "it belongs to)", file=sys.stderr)
    sys.exit(1)

print(f"docs gate: OK — {len(used)} distinct flags in {DOC}, "
      f"all present in {SRC} ({len(valid)} defined)")
EOF

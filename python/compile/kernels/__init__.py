"""Layer-1 Pallas kernels for AdaQAT.

All kernels are authored TPU-idiomatically but lowered with
``interpret=True`` so they run on the CPU PJRT plugin (real-TPU lowering
emits Mosaic custom-calls the CPU client cannot execute). Correctness of
every kernel is pinned against the pure-jnp oracle in ``ref.py`` by
``python/tests/test_kernels.py``.
"""

from .dorefa import dorefa_quant, dorefa_quant_blocked
from .pact import pact_quant, pact_quant_blocked
from .matmul import matmul as pallas_matmul
from .matmul import matmul_ad as pallas_matmul_ad

__all__ = [
    "dorefa_quant",
    "dorefa_quant_blocked",
    "pact_quant",
    "pact_quant_blocked",
    "pallas_matmul",
    "pallas_matmul_ad",
]

"""Tiled Pallas matmul targeting the MXU systolic array.

The paper's networks spend their FLOPs in conv/fc layers; on TPU those map
to MXU matmuls. This kernel is the GEMM primitive behind the classifier
head and the optional im2col conv path (``layers.conv2d_im2col``).

Tiling: grid over (M/bm, N/bn) output tiles; the full K ("contraction")
dimension is resident per tile — for AdaQAT's shapes K ≤ C·kh·kw ≤ 4608,
so an (bm, K) + (K, bn) + (bm, bn) working set stays well under the
16 MiB VMEM budget (e.g. bm=bn=128, K=4608: 4.7 MiB). Accumulation is
f32 (``preferred_element_type``), the MXU-native accumulate.

Inputs whose dims don't divide the tile are padded by the wrapper and the
result is sliced back — mirroring how XLA pads to MXU lanes.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(x_ref, y_ref, o_ref):
    o_ref[...] = jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("bm", "bn"))
def matmul(x, y, bm: int = 128, bn: int = 128):
    """``x @ y`` via a (M/bm, N/bn)-tiled Pallas kernel.

    Args:
      x: (M, K) float32.
      y: (K, N) float32.
      bm, bn: output tile sizes (MXU-shaped: multiples of 128 on TPU).
    Returns:
      (M, N) float32 product.
    """
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, f"contraction mismatch {x.shape} @ {y.shape}"
    bm = min(bm, max(m, 1))
    bn = min(bn, max(n, 1))
    mp = (m + bm - 1) // bm * bm
    np_ = (n + bn - 1) // bn * bn
    xp = jnp.pad(x, ((0, mp - m), (0, 0))) if mp != m else x
    yp = jnp.pad(y, ((0, 0), (0, np_ - n))) if np_ != n else y
    out = pl.pallas_call(
        _matmul_kernel,
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        grid=(mp // bm, np_ // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        interpret=True,
    )(xp.astype(jnp.float32), yp.astype(jnp.float32))
    if mp != m or np_ != n:
        out = out[:m, :n]
    return out


# Reverse-mode autodiff cannot see through pallas_call; the VJP of a
# matmul is two more matmuls, so the backward pass reuses the same kernel
# (the MXU runs fwd and bwd GEMMs alike). Tile sizes are non-diff static
# arguments so callers can tune them per site (see layers._conv2d_im2col).
@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def matmul_ad(x, y, bm: int = 128, bn: int = 128):
    """Differentiable ``x @ y`` backed by the tiled Pallas kernel."""
    return matmul(x, y, bm, bn)


def _matmul_ad_fwd(x, y, bm, bn):
    return matmul(x, y, bm, bn), (x, y)


def _matmul_ad_bwd(bm, bn, res, g):
    x, y = res
    return matmul(g, y.T, bm, bn), matmul(x.T, g, bm, bn)


matmul_ad.defvjp(_matmul_ad_fwd, _matmul_ad_bwd)

"""Pure-jnp correctness oracles for every Layer-1 Pallas kernel.

These are the ground truth the kernels are tested against
(``python/tests/test_kernels.py``) — straight transcriptions of the
paper's equations with no Pallas machinery.
"""

import jax.numpy as jnp


def quantize_ref(x, s):
    """Eq. (1): q(x) = round(x*s)/s for x in [0, 1], s = 2^k - 1."""
    return jnp.round(x * s) / s


def dorefa_ref(w, s):
    """DoReFa weight fake-quant: tanh-normalize to [0,1], quantize, expand."""
    t = jnp.tanh(w)
    m = jnp.maximum(jnp.max(jnp.abs(t)), 1e-12)
    x = t / (2.0 * m) + 0.5
    return 2.0 * quantize_ref(x, s) - 1.0


def pact_ref(x, alpha, s):
    """PACT activation quant: clip to [0, alpha], quantize with s/alpha."""
    y = jnp.clip(x, 0.0, alpha)
    scale = s / alpha
    return jnp.round(y * scale) / scale


def matmul_ref(x, y):
    return jnp.dot(
        x.astype(jnp.float32),
        y.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )

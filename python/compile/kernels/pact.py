"""PACT activation quantization Pallas kernel (paper §III-A).

PACT (Choi et al. 2018) replaces ReLU with a learnable clip:

    y     = clip(x, 0, alpha)
    scale = s / alpha                      # s = 2^k - 1 (runtime scalar)
    y_q   = round(y * scale) / scale       # in [0, alpha]

``alpha`` is a trained parameter (one per quantized activation site);
``s`` is the runtime bit-width scale fed by the Rust coordinator. Both
arrive as (1,)-shaped operands so the kernel body stays elementwise.

Same two lowering variants as the DoReFa kernel (see dorefa.py).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pact_kernel(x_ref, a_ref, s_ref, o_ref):
    alpha = a_ref[0]
    y = jnp.clip(x_ref[...], 0.0, alpha)
    scale = s_ref[0] / alpha
    o_ref[...] = jnp.round(y * scale) / scale


def pact_quant(x, alpha, s):
    """Clip-and-quantize activations at runtime scale ``s = 2^k - 1``.

    Args:
      x: float32 activation tensor, any shape.
      alpha: float32 scalar, the learned clipping level (alpha > 0).
      s: float32 scalar, the quantization scale.
    """
    alpha = jnp.asarray(alpha, jnp.float32).reshape(1)
    s = jnp.asarray(s, jnp.float32).reshape(1)
    return pl.pallas_call(
        _pact_kernel,
        out_shape=jax.ShapeDtypeStruct(x.shape, jnp.float32),
        interpret=True,
    )(x.astype(jnp.float32), alpha, s)


def pact_quant_blocked(x, alpha, s, block_rows: int = 8):
    """Blocked variant, 1-D grid over the leading (batch) axis."""
    assert x.ndim >= 1 and x.shape[0] % block_rows == 0
    alpha = jnp.asarray(alpha, jnp.float32).reshape(1)
    s = jnp.asarray(s, jnp.float32).reshape(1)
    grid = (x.shape[0] // block_rows,)
    block = (block_rows,) + x.shape[1:]
    zeros_tail = (0,) * (x.ndim - 1)
    return pl.pallas_call(
        _pact_kernel,
        out_shape=jax.ShapeDtypeStruct(x.shape, jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec(block, lambda i: (i,) + zeros_tail),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec(block, lambda i: (i,) + zeros_tail),
        interpret=True,
    )(x.astype(jnp.float32), alpha, s)

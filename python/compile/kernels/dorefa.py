"""DoReFa weight fake-quantization Pallas kernel (paper §III-A, eq. (1)).

Pipeline (DoReFa-Net, Zhou et al. 2016, as adopted by AdaQAT):

    t   = tanh(w)
    x   = t / (2 * max|t|) + 1/2          # in [0, 1]
    q   = round(x * s) / s                # s = 2^k - 1  (runtime scalar!)
    w_q = 2 * q - 1                       # in [-1, 1]

The global ``max|tanh(w)|`` reduction is computed *outside* the kernel (a
cheap XLA reduce) and fed in as a (1,)-shaped operand, so the kernel body
itself is purely elementwise — the shape that vectorizes on the TPU VPU.

``s`` is a runtime scalar: the Rust coordinator realizes the AdaQAT
discretization ceil/floor(N_w) by feeding ``s = 2^k - 1`` for different
integer ``k`` into the *same* compiled executable (see DESIGN.md §6).

Two lowering variants:
  * ``dorefa_quant``          — grid=() whole-array block. Used in the
    production artifacts: the lowered HLO is one fused elementwise chain.
  * ``dorefa_quant_blocked``  — 1-D grid over the leading axis with a
    VMEM-sized BlockSpec. This is the shape that streams HBM→VMEM on a
    real TPU; kept lowerable + tested for structural parity.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _dorefa_kernel(w_ref, m_ref, s_ref, o_ref):
    """Elementwise DoReFa body. m = max|tanh(w)| (global), s = 2^k - 1."""
    t = jnp.tanh(w_ref[...])
    x = t / (2.0 * m_ref[0]) + 0.5
    q = jnp.round(x * s_ref[0]) / s_ref[0]
    o_ref[...] = 2.0 * q - 1.0


def dorefa_quant(w, s):
    """Quantize a weight tensor with DoReFa at runtime scale ``s = 2^k - 1``.

    Args:
      w: float32 weight tensor, any shape.
      s: float32 scalar (or ()-shaped array), the quantization scale.
    Returns:
      Fake-quantized tensor of the same shape, values in [-1, 1].
    """
    m = jnp.max(jnp.abs(jnp.tanh(w))).reshape(1)
    m = jnp.maximum(m, 1e-12)  # all-zero tensors must not divide by zero
    s = jnp.asarray(s, jnp.float32).reshape(1)
    return pl.pallas_call(
        _dorefa_kernel,
        out_shape=jax.ShapeDtypeStruct(w.shape, jnp.float32),
        interpret=True,
    )(w.astype(jnp.float32), m, s)


def dorefa_quant_blocked(w, s, block_rows: int = 8):
    """Blocked variant: 1-D grid over the leading axis.

    On TPU each grid step streams a ``(block_rows, *w.shape[1:])`` tile
    HBM→VMEM; ``block_rows`` is chosen so a tile is ≤ ~4 MiB of VMEM.
    Requires ``w.shape[0] % block_rows == 0`` (callers pad; the production
    path uses the whole-array variant).
    """
    assert w.ndim >= 1 and w.shape[0] % block_rows == 0
    m = jnp.max(jnp.abs(jnp.tanh(w))).reshape(1)
    m = jnp.maximum(m, 1e-12)
    s = jnp.asarray(s, jnp.float32).reshape(1)
    grid = (w.shape[0] // block_rows,)
    block = (block_rows,) + w.shape[1:]
    zeros_tail = (0,) * (w.ndim - 1)
    return pl.pallas_call(
        _dorefa_kernel,
        out_shape=jax.ShapeDtypeStruct(w.shape, jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec(block, lambda i: (i,) + zeros_tail),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec(block, lambda i: (i,) + zeros_tail),
        interpret=True,
    )(w.astype(jnp.float32), m, s)

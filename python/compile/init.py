"""Python-side parameter initialization — used by the pytest suite only.

The *runtime* initialization lives in Rust (``rust/src/tensor/init.rs``,
seeded xorshift + Box-Muller) so Python stays off the request path; this
module mirrors the same init *specs* (Kaiming-normal over fan-in, zeros,
ones, const) for build-time testing.
"""

import jax
import jax.numpy as jnp

from .models import Model


def init_params(model: Model, key):
    params = {}
    for p in model.spec.params:
        if p.init.startswith("kaiming:"):
            fan_in = int(p.init.split(":")[1])
            key, sub = jax.random.split(key)
            std = (2.0 / fan_in) ** 0.5
            params[p.name] = std * jax.random.normal(sub, p.shape, jnp.float32)
        elif p.init == "zeros":
            params[p.name] = jnp.zeros(p.shape, jnp.float32)
        elif p.init == "ones":
            params[p.name] = jnp.ones(p.shape, jnp.float32)
        elif p.init.startswith("const:"):
            v = float(p.init.split(":")[1])
            params[p.name] = jnp.full(p.shape, v, jnp.float32)
        else:
            raise ValueError(f"unknown init {p.init}")
    return params


def init_bn(model: Model):
    return {b.name: (jnp.zeros(b.shape, jnp.float32) if b.init == "zeros"
                     else jnp.ones(b.shape, jnp.float32))
            for b in model.spec.bn}


def flatten_params(model: Model, params):
    return [params[p.name] for p in model.spec.params]


def flatten_bn(model: Model, bn):
    return [bn[b.name] for b in model.spec.bn]

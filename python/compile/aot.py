"""AOT lowering: JAX step functions → HLO **text** + manifest.json.

This is the single build-time entry point (``make artifacts``); after it
runs, Python is never needed again — the Rust coordinator loads the HLO
text via ``HloModuleProto::from_text_file`` and executes it on the PJRT
CPU client.

Interchange is HLO *text*, not a serialized ``HloModuleProto``: jax ≥ 0.5
emits protos with 64-bit instruction ids which the crate's xla_extension
0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser reassigns ids
and round-trips cleanly (see /opt/xla-example/README.md).

Artifacts per model ``m``:

    {m}_train.hlo.txt     quantized train step  (runtime s_w/s_a scalars)
    {m}_loss.hlo.txt      quantized forward probe (batch-stat BN)
    {m}_eval.hlo.txt      quantized eval (running-stat BN)
    {m}_infer.hlo.txt     quantized serving forward: class ids, no labels
    {m}_fp_train.hlo.txt  fp32 baseline train step (pretraining / Table I)
    {m}_fp_eval.hlo.txt   fp32 baseline eval

plus a ``smallcnn_pallas_*`` variant that routes *convolutions* through
the Layer-1 Pallas matmul (im2col), proving the all-Pallas path composes
end-to-end on the PJRT runtime.

The manifest records the flat tensor layout (the ordering contract with
``rust/src/runtime/manifest.rs``), init specs so Rust can initialize
parameters itself, and per-layer geometry for the BitOPs/WCR cost model.
"""

import argparse
import json
import os
import sys

import jax

from jax._src.lib import xla_client as xc

from .models import MODELS
from .steps import (make_train_step, make_forward_step, make_infer_step,
                    example_args, infer_args)

# Batch sizes are baked into the artifacts (PJRT shapes are static).
# Chosen for CPU-PJRT throughput; the paper's 256 is a V100 setting.
BATCH = {"smallcnn": 64, "resnet20": 128, "resnet18": 32}


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_model(model, batch: int, *, pallas_conv: bool = False):
    """Lower the step graphs for one model; returns {suffix: hlo}."""
    out = {}
    train_args = example_args(model, batch, with_opt=True, with_lr=True)
    fwd_args = example_args(model, batch, with_opt=False, with_lr=False)

    def lower(fn, args):
        # keep_unused=True: the manifest promises a fixed argument list;
        # without it jax prunes args a given graph doesn't read (e.g. BN
        # running stats in the batch-stat loss probe, s_w/s_a in fp32
        # graphs) and the Rust runtime's buffer count no longer matches.
        return to_hlo_text(jax.jit(fn, keep_unused=True).lower(*args))

    out["train"] = lower(
        make_train_step(model, quant=True, pallas_conv=pallas_conv),
        train_args)
    out["loss"] = lower(
        make_forward_step(model, quant=True, train_bn=True,
                          pallas_conv=pallas_conv), fwd_args)
    out["eval"] = lower(
        make_forward_step(model, quant=True, train_bn=False,
                          pallas_conv=pallas_conv), fwd_args)
    out["infer"] = lower(
        make_infer_step(model, quant=True, pallas_conv=pallas_conv),
        infer_args(model, batch))
    if not pallas_conv:
        out["fp_train"] = lower(
            make_train_step(model, quant=False), train_args)
        out["fp_eval"] = lower(
            make_forward_step(model, quant=False, train_bn=False), fwd_args)
    return out


def model_manifest(model, batch: int, artifacts: dict) -> dict:
    return {
        "batch": batch,
        "input_hw": list(model.input_hw),
        "in_channels": model.in_channels,
        "num_classes": model.num_classes,
        "params": [
            {"name": p.name, "shape": list(p.shape), "init": p.init,
             "role": p.role}
            for p in model.spec.params
        ],
        "bn": [
            {"name": b.name, "shape": list(b.shape), "init": b.init}
            for b in model.spec.bn
        ],
        "geoms": [
            {"name": g.name, "kind": g.kind,
             "weight_count": g.weight_count, "macs": g.macs,
             "fixed8": g.fixed8}
            for g in model.spec.geoms
        ],
        "artifacts": artifacts,
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--models", nargs="*",
                    default=["smallcnn", "resnet20", "resnet18"])
    ap.add_argument("--pallas-conv-models", nargs="*", default=["smallcnn"])
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    manifest = {"version": 1, "models": {}}

    def emit(key, model, batch, pallas_conv):
        hlos = lower_model(model, batch, pallas_conv=pallas_conv)
        arts = {}
        for suffix, text in hlos.items():
            fname = f"{key}_{suffix}.hlo.txt"
            with open(os.path.join(args.out, fname), "w") as f:
                f.write(text)
            arts[suffix] = fname
            print(f"  wrote {fname} ({len(text)//1024} KiB)", file=sys.stderr)
        manifest["models"][key] = model_manifest(model, batch, arts)

    for name in args.models:
        model = MODELS[name]()
        print(f"[aot] lowering {name} (batch {BATCH[name]})", file=sys.stderr)
        emit(name, model, BATCH[name], pallas_conv=False)
    for name in args.pallas_conv_models:
        model = MODELS[name]()
        key = f"{name}_pallas"
        print(f"[aot] lowering {key} (batch {BATCH[name]})", file=sys.stderr)
        emit(key, model, BATCH[name], pallas_conv=True)

    mpath = os.path.join(args.out, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"[aot] wrote {mpath}", file=sys.stderr)


if __name__ == "__main__":
    main()

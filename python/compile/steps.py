"""Layer-2 step functions: fused train / loss-probe / eval graphs.

Each step is a *flat-positional* function (so the lowered HLO has a fixed
parameter list the Rust runtime can bind via the manifest):

  train:  (P params..., P momenta..., B bn..., x, y, lr, s_w, s_a)
          -> (P params'..., P momenta'..., B bn'..., loss, correct)
  loss:   (P params..., B bn..., x, y, s_w, s_a) -> (loss, correct)
          [forward-only, batch-stat BN — the finite-difference probe of
           paper §III-C re-runs this with neighbor scales on the SAME batch]
  eval:   same signature as loss, but running-stat BN (inference mode).
  infer:  (P params..., B bn..., x, s_w, s_a) -> (preds,)
          [serving graph: predicted class ids as f32, running-stat BN,
           no labels — consumed by the Rust serve subsystem, DESIGN.md §7]

The optimizer (SGD, momentum 0.9, weight decay 1e-4 on conv/fc weights —
paper §IV-A) is fused into the train graph so one PJRT execution performs
the whole training step; nothing round-trips to the host but the batch,
the scalar knobs, and the (loss, correct) metrics.
"""

import functools
from typing import List

import jax
import jax.numpy as jnp

from . import layers as L
from .models import Model

MOMENTUM = 0.9
WEIGHT_DECAY = 1e-4


def _cross_entropy(logits, labels):
    logp = jax.nn.log_softmax(logits)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=1)[:, 0]
    return jnp.mean(nll)


def _correct(logits, labels):
    return jnp.sum((jnp.argmax(logits, axis=1) == labels).astype(jnp.float32))


def _unflatten(names: List[str], flat):
    return dict(zip(names, flat))


def make_train_step(model: Model, *, quant: bool, pallas_conv: bool = False):
    """Build the fused train step. ``quant=False`` → fp32 baseline graph."""
    pnames = [p.name for p in model.spec.params]
    bnames = [b.name for b in model.spec.bn]
    decayed = {p.name: p.decayed for p in model.spec.params}
    np_, nb = len(pnames), len(bnames)

    def step(*flat):
        params = _unflatten(pnames, flat[:np_])
        mom = _unflatten(pnames, flat[np_:2 * np_])
        bn = _unflatten(bnames, flat[2 * np_:2 * np_ + nb])
        x, y, lr, s_w, s_a = flat[2 * np_ + nb:]

        def loss_fn(p):
            ctx = L.Ctx(p, bn, s_w, s_a, train=True, quant=quant,
                        pallas_conv=pallas_conv)
            logits = model.forward(ctx, x)
            loss = _cross_entropy(logits, y)
            return loss, (ctx.new_bn, _correct(logits, y))

        (loss, (new_bn, correct)), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params)

        outs = []
        new_mom = {}
        for n in pnames:
            g = grads[n]
            if decayed[n]:
                g = g + WEIGHT_DECAY * params[n]
            m = MOMENTUM * mom[n] + g
            new_mom[n] = m
            outs.append(params[n] - lr * m)
        outs.extend(new_mom[n] for n in pnames)
        outs.extend(new_bn[n] for n in bnames)
        outs.append(loss)
        outs.append(correct)
        return tuple(outs)

    return step


def make_forward_step(model: Model, *, quant: bool, train_bn: bool,
                      pallas_conv: bool = False):
    """Loss-probe (``train_bn=True``) or eval (``train_bn=False``) graph."""
    pnames = [p.name for p in model.spec.params]
    bnames = [b.name for b in model.spec.bn]
    np_, nb = len(pnames), len(bnames)

    def step(*flat):
        params = _unflatten(pnames, flat[:np_])
        bn = _unflatten(bnames, flat[np_:np_ + nb])
        x, y, s_w, s_a = flat[np_ + nb:]
        ctx = L.Ctx(params, bn, s_w, s_a, train=train_bn, quant=quant,
                    pallas_conv=pallas_conv)
        logits = model.forward(ctx, x)
        return (_cross_entropy(logits, y), _correct(logits, y))

    return step


def make_infer_step(model: Model, *, quant: bool, pallas_conv: bool = False):
    """Serving graph: per-sample predicted classes (as f32 so every
    artifact stays single-dtype on the output side), inference-mode BN,
    no labels."""
    pnames = [p.name for p in model.spec.params]
    bnames = [b.name for b in model.spec.bn]
    np_, nb = len(pnames), len(bnames)

    def step(*flat):
        params = _unflatten(pnames, flat[:np_])
        bn = _unflatten(bnames, flat[np_:np_ + nb])
        x, s_w, s_a = flat[np_ + nb:]
        ctx = L.Ctx(params, bn, s_w, s_a, train=False, quant=quant,
                    pallas_conv=pallas_conv)
        logits = model.forward(ctx, x)
        return (jnp.argmax(logits, axis=1).astype(jnp.float32),)

    return step


def infer_args(model: Model, batch: int):
    """ShapeDtypeStructs matching the infer step's flat signature."""
    f32 = jnp.float32
    sds = jax.ShapeDtypeStruct
    args = [sds(p.shape, f32) for p in model.spec.params]
    args += [sds(b.shape, f32) for b in model.spec.bn]
    h, w = model.input_hw
    args.append(sds((batch, h, w, model.in_channels), f32))
    args.append(sds((), f32))  # s_w
    args.append(sds((), f32))  # s_a
    return args


def example_args(model: Model, batch: int, *, with_opt: bool,
                 with_lr: bool):
    """ShapeDtypeStructs matching a step's flat signature (for lowering)."""
    f32 = jnp.float32
    sds = jax.ShapeDtypeStruct
    args = [sds(p.shape, f32) for p in model.spec.params]
    if with_opt:
        args += [sds(p.shape, f32) for p in model.spec.params]
    args += [sds(b.shape, f32) for b in model.spec.bn]
    h, w = model.input_hw
    args.append(sds((batch, h, w, model.in_channels), f32))
    args.append(sds((batch,), jnp.int32))
    if with_lr:
        args.append(sds((), f32))
    args.append(sds((), f32))  # s_w
    args.append(sds((), f32))  # s_a
    return args

"""STE quantizer wrappers (paper §III-A backward rules).

Forward passes call the Layer-1 Pallas kernels; backward passes implement
the straight-through estimators:

  * DoReFa weights:  round() is identity in the backward pass, the tanh
    reparameterization *is* differentiated (max|tanh| treated constant):
        dL/dw = dL/dw_q · (1 - tanh(w)^2) / max|tanh(w)|
  * PACT activations:
        dL/dx     = dL/dy_q · 1[0 ≤ x ≤ alpha]
        dL/dalpha = Σ dL/dy_q · 1[x > alpha]
    (the quantization rounding is again straight-through).

The runtime scale ``s = 2^k - 1`` receives no gradient — in AdaQAT the
bit-widths are optimized by the Rust coordinator's finite-difference rule
(paper §III-C), not by backprop.
"""

import jax
import jax.numpy as jnp

from .kernels import dorefa_quant, pact_quant


# --------------------------------------------------------------------------
# DoReFa weight quantizer
# --------------------------------------------------------------------------

@jax.custom_vjp
def weight_quant(w, s):
    """Fake-quantize weights with DoReFa at runtime scale s = 2^k - 1."""
    return dorefa_quant(w, s)


def _weight_quant_fwd(w, s):
    return dorefa_quant(w, s), w


def _weight_quant_bwd(w, g):
    t = jnp.tanh(w)
    m = jnp.maximum(jnp.max(jnp.abs(t)), 1e-12)
    # d/dw [ 2*(tanh(w)/(2m) + 1/2) - 1 ] = (1 - tanh^2 w)/m, round ~ id.
    return (g * (1.0 - t * t) / m, None)


weight_quant.defvjp(_weight_quant_fwd, _weight_quant_bwd)


# --------------------------------------------------------------------------
# PACT activation quantizer
# --------------------------------------------------------------------------

@jax.custom_vjp
def act_quant(x, alpha, s):
    """Clip-and-quantize activations (PACT) at runtime scale s = 2^k - 1."""
    return pact_quant(x, alpha, s)


def _act_quant_fwd(x, alpha, s):
    return pact_quant(x, alpha, s), (x, alpha)


def _act_quant_bwd(res, g):
    x, alpha = res
    in_range = jnp.logical_and(x >= 0.0, x <= alpha)
    gx = jnp.where(in_range, g, 0.0)
    galpha = jnp.sum(jnp.where(x > alpha, g, 0.0))
    # alpha is stored as a (1,)-shaped parameter; match its shape/dtype.
    galpha = jnp.reshape(galpha.astype(jnp.float32), jnp.shape(alpha))
    return (gx, galpha, None)


act_quant.defvjp(_act_quant_fwd, _act_quant_bwd)


def bitwidth_scale(k):
    """s = 2^k - 1 for integer bit-width k (host-side helper, mirrored in
    rust/src/quant/mod.rs — keep the two in sync)."""
    return float(2.0 ** k - 1.0)


# Feeding this scale emulates "activations not quantized" (the `/32` rows
# of Table I): 2^24 is the largest power of two for which round(x*s)/s is
# exact in f32 arithmetic, so quantization becomes the identity.
S_IDENTITY = float(2.0 ** 24)

"""Layer-2 model zoo: ResNet-20 (CIFAR-10), ResNet-18 (ImageNet-lite),
SmallCNN (quickstart).

Each model is a ``Model`` with (a) an ordered spec list — the manifest
contract with Rust — and (b) a pure ``forward(ctx, x) -> logits``.

Per the paper (§IV-A): first and last layers are pinned to 8 bits; every
other conv weight quantizes at the runtime scale ``s_w`` and every
activation at ``s_a``.
"""

import dataclasses
from typing import List, Tuple

import jax.numpy as jnp

from . import layers as L


@dataclasses.dataclass
class Model:
    name: str
    input_hw: Tuple[int, int]
    in_channels: int
    num_classes: int
    spec: L.SpecBuilder
    stages: List[Tuple[int, int, int]]  # (width, blocks, stride) per stage
    stem_width: int

    # ---------------------------------------------------------------- fwd
    def forward(self, ctx: L.Ctx, x):
        """x: (N, H, W, C) float32 → logits (N, num_classes)."""
        h = L.conv2d(ctx, "stem", x, stride=1, fixed8=True)
        h = L.batchnorm(ctx, "stem.bn", h)
        h = L.activation(ctx, "stem.act", h)
        cin = self.stem_width
        for si, (width, blocks, stride) in enumerate(self.stages):
            for bi in range(blocks):
                h = self._block(ctx, f"s{si}.b{bi}", h, cin, width,
                                stride if bi == 0 else 1)
                cin = width
        h = L.global_avg_pool(h)
        return L.dense(ctx, "fc", h, fixed8=True)

    def _block(self, ctx, name, x, cin, cout, stride):
        """Basic residual block (two 3x3 convs, projection shortcut when
        the shape changes)."""
        h = L.conv2d(ctx, f"{name}.conv1", x, stride=stride)
        h = L.batchnorm(ctx, f"{name}.bn1", h)
        h = L.activation(ctx, f"{name}.act1", h)
        h = L.conv2d(ctx, f"{name}.conv2", h, stride=1)
        h = L.batchnorm(ctx, f"{name}.bn2", h)
        if stride != 1 or cin != cout:
            sc = L.conv2d(ctx, f"{name}.down", x, stride=stride)
            sc = L.batchnorm(ctx, f"{name}.down.bn", sc)
        else:
            sc = x
        return L.activation(ctx, f"{name}.act2", h + sc)


def _build(name, input_hw, in_channels, num_classes, stem_width, stages):
    """Register every ParamSpec/BnSpec/LayerGeom in forward-pass order."""
    b = L.SpecBuilder()
    h, w = input_hw
    b.conv("stem", 3, 3, in_channels, stem_width, (h, w), fixed8=True)
    b.batchnorm("stem.bn", stem_width)
    b.act("stem.act")
    cin = stem_width
    for si, (width, blocks, stride) in enumerate(stages):
        for bi in range(blocks):
            st = stride if bi == 0 else 1
            if st > 1:
                h = (h + st - 1) // st
                w = (w + st - 1) // st
            n = f"s{si}.b{bi}"
            b.conv(f"{n}.conv1", 3, 3, cin, width, (h, w))
            b.batchnorm(f"{n}.bn1", width)
            b.act(f"{n}.act1")
            b.conv(f"{n}.conv2", 3, 3, width, width, (h, w))
            b.batchnorm(f"{n}.bn2", width)
            if st != 1 or cin != width:
                b.conv(f"{n}.down", 1, 1, cin, width, (h, w))
                b.batchnorm(f"{n}.down.bn", width)
            b.act(f"{n}.act2")
            cin = width
    b.dense("fc", cin, num_classes, fixed8=True)
    return Model(name, input_hw, in_channels, num_classes, b, stages,
                 stem_width)


def resnet20(num_classes: int = 10) -> Model:
    """He et al.'s CIFAR ResNet-20: 3 stages of 3 basic blocks, 16/32/64."""
    return _build("resnet20", (32, 32), 3, num_classes, 16,
                  [(16, 3, 1), (32, 3, 2), (64, 3, 2)])


def resnet18(num_classes: int = 100) -> Model:
    """ResNet-18 adapted to 32x32 inputs (3x3 stem, no maxpool) for the
    synthetic ImageNet-lite substitution (DESIGN.md §4)."""
    return _build("resnet18", (32, 32), 3, num_classes, 64,
                  [(64, 2, 1), (128, 2, 2), (256, 2, 2), (512, 2, 2)])


def smallcnn(num_classes: int = 10) -> Model:
    """Tiny 3-stage CNN for the quickstart example and fast tests."""
    return _build("smallcnn", (32, 32), 3, num_classes, 8,
                  [(8, 1, 1), (16, 1, 2), (32, 1, 2)])


MODELS = {
    "resnet20": resnet20,
    "resnet18": resnet18,
    "smallcnn": smallcnn,
}

"""Layer-2 layer library: quantized conv / BN / residual primitives.

Everything is functional: parameters live in a flat ``dict[str, Array]``
keyed by dotted names, and each model carries an ordered spec list (built
at model-definition time) that fixes the flattening order shared with the
Rust side through ``artifacts/manifest.json``.

Quantization policy (paper §IV-A):
  * conv/fc weights  → DoReFa at runtime scale ``s_w`` (first & last layer
    pinned to 8 bits, i.e. scale 255),
  * activations      → PACT at runtime scale ``s_a`` with a learned
    ``alpha`` per quantization site,
  * BN parameters and ``alpha`` are never quantized.

``Ctx.quant=False`` gives the fp32 baseline graph (plain ReLU, raw
weights) used for the Table I baseline row and fine-tuning pretrains; the
parameter set is identical so fp32 checkpoints load directly into the
quantized graph.
"""

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from .quantizers import weight_quant, act_quant
from .kernels import pallas_matmul_ad as pallas_matmul

FIXED8_SCALE = 255.0  # 2^8 - 1: first/last layers are pinned to 8 bits.


@dataclasses.dataclass
class ParamSpec:
    """One trainable tensor: its manifest identity."""
    name: str
    shape: Tuple[int, ...]
    init: str   # "kaiming:<fan_in>" | "zeros" | "ones" | "const:<v>"
    role: str   # "conv_w" | "fc_w" | "fc_b" | "bn_scale" | "bn_bias" | "alpha"

    @property
    def decayed(self) -> bool:
        """Weight decay applies to conv/fc weights only (not BN, not alpha)."""
        return self.role in ("conv_w", "fc_w")


@dataclasses.dataclass
class BnSpec:
    """One BN running-statistic tensor (mean or var)."""
    name: str
    shape: Tuple[int, ...]
    init: str  # "zeros" for means, "ones" for vars


@dataclasses.dataclass
class LayerGeom:
    """Geometry needed by the Rust cost model (BitOPs eq. of §III-B, WCR)."""
    name: str
    kind: str          # "conv" | "fc"
    weight_count: int  # |f| — cardinality of the filter
    macs: int          # kh*kw*cin*cout*out_h*out_w (fc: in*out)
    fixed8: bool       # first/last layer rule


class Ctx:
    """Per-forward context: params, BN state, runtime scales, mode flags."""

    def __init__(self, params: Dict[str, jnp.ndarray],
                 bn_state: Dict[str, jnp.ndarray],
                 s_w, s_a, *, train: bool, quant: bool = True,
                 pallas_conv: bool = False, bn_momentum: float = 0.8):
        self.params = params
        self.bn_state = bn_state
        self.s_w = s_w
        self.s_a = s_a
        self.train = train
        self.quant = quant
        self.pallas_conv = pallas_conv
        self.bn_momentum = bn_momentum
        self.new_bn: Dict[str, jnp.ndarray] = {}


# --------------------------------------------------------------------------
# Primitives
# --------------------------------------------------------------------------

def _quantized_weight(ctx: Ctx, w, fixed8: bool):
    if not ctx.quant:
        return w
    scale = FIXED8_SCALE if fixed8 else ctx.s_w
    return weight_quant(w, scale)


def conv2d(ctx: Ctx, name: str, x, stride: int = 1, fixed8: bool = False):
    """3x3/1x1 'SAME' conv, NHWC, weights HWIO, DoReFa-quantized."""
    w = _quantized_weight(ctx, ctx.params[f"{name}.w"], fixed8)
    if ctx.pallas_conv:
        return _conv2d_im2col(x, w, stride)
    return lax.conv_general_dilated(
        x, w, window_strides=(stride, stride), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def _conv2d_im2col(x, w, stride: int):
    """Conv as im2col + the Layer-1 Pallas matmul (the MXU mapping of the
    paper's conv hot-spot — see DESIGN.md §8). Used by the ``*_pallas``
    artifact variants; numerically equal to lax.conv (tested)."""
    kh, kw, cin, cout = w.shape
    n, h, win, _ = x.shape
    patches = lax.conv_general_dilated_patches(
        x, filter_shape=(kh, kw), window_strides=(stride, stride),
        padding="SAME", dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )  # (N, OH, OW, cin*kh*kw), feature order: cin-major, then kh, kw
    oh, ow = patches.shape[1], patches.shape[2]
    cols = patches.reshape(n * oh * ow, cin * kh * kw)
    # Patches order features as (cin, kh, kw); weights are (kh, kw, cin, co).
    wmat = jnp.transpose(w, (2, 0, 1, 3)).reshape(cin * kh * kw, cout)
    # Perf (EXPERIMENTS.md §Perf, L1 iteration): M = N·OH·OW is huge for
    # conv, so use tall bm tiles — fewer grid steps amortize the
    # interpret-mode loop (and on TPU keep the MXU pipeline fed); VMEM per
    # tile stays ≤ (512·K + K·128 + 512·128)·4B ≈ 1.5 MiB at K=576.
    out = pallas_matmul(cols, wmat, bm=512, bn=128)
    return out.reshape(n, oh, ow, cout)


def batchnorm(ctx: Ctx, name: str, x, eps: float = 1e-5):
    """BN over NHW with running stats threaded through ``ctx``.

    Train: normalize with batch stats, emit updated running stats into
    ``ctx.new_bn``. Eval: normalize with running stats (and re-emit them
    unchanged so the output signature is mode-independent).
    """
    scale = ctx.params[f"{name}.scale"]
    bias = ctx.params[f"{name}.bias"]
    r_mean = ctx.bn_state[f"{name}.mean"]
    r_var = ctx.bn_state[f"{name}.var"]
    if ctx.train:
        mean = jnp.mean(x, axis=(0, 1, 2))
        var = jnp.var(x, axis=(0, 1, 2))
        m = ctx.bn_momentum
        ctx.new_bn[f"{name}.mean"] = m * r_mean + (1.0 - m) * mean
        ctx.new_bn[f"{name}.var"] = m * r_var + (1.0 - m) * var
    else:
        mean, var = r_mean, r_var
        ctx.new_bn[f"{name}.mean"] = r_mean
        ctx.new_bn[f"{name}.var"] = r_var
    inv = lax.rsqrt(var + eps)
    return (x - mean) * (inv * scale) + bias


def activation(ctx: Ctx, name: str, x):
    """PACT quantized activation (quant mode) or plain ReLU (fp32 mode)."""
    if not ctx.quant:
        return jax.nn.relu(x)
    alpha = ctx.params[f"{name}.alpha"]
    return act_quant(x, alpha, ctx.s_a)


def global_avg_pool(x):
    return jnp.mean(x, axis=(1, 2))


def dense(ctx: Ctx, name: str, x, fixed8: bool = True):
    """Classifier head: Pallas-matmul dense layer, 8-bit pinned weights."""
    w = _quantized_weight(ctx, ctx.params[f"{name}.w"], fixed8)
    b = ctx.params[f"{name}.b"]
    return pallas_matmul(x, w) + b


# --------------------------------------------------------------------------
# Spec builder
# --------------------------------------------------------------------------

class SpecBuilder:
    """Accumulates ParamSpec/BnSpec/LayerGeom in deterministic build order.

    The order of ``self.params`` is the flattening contract with Rust.
    """

    def __init__(self):
        self.params: List[ParamSpec] = []
        self.bn: List[BnSpec] = []
        self.geoms: List[LayerGeom] = []

    def conv(self, name: str, kh: int, kw: int, cin: int, cout: int,
             out_hw: Tuple[int, int], fixed8: bool = False):
        fan_in = kh * kw * cin
        self.params.append(ParamSpec(f"{name}.w", (kh, kw, cin, cout),
                                     f"kaiming:{fan_in}", "conv_w"))
        self.geoms.append(LayerGeom(
            name, "conv", kh * kw * cin * cout,
            kh * kw * cin * cout * out_hw[0] * out_hw[1], fixed8))

    def batchnorm(self, name: str, c: int):
        self.params.append(ParamSpec(f"{name}.scale", (c,), "ones", "bn_scale"))
        self.params.append(ParamSpec(f"{name}.bias", (c,), "zeros", "bn_bias"))
        self.bn.append(BnSpec(f"{name}.mean", (c,), "zeros"))
        self.bn.append(BnSpec(f"{name}.var", (c,), "ones"))

    def act(self, name: str, alpha_init: float = 10.0):
        self.params.append(ParamSpec(f"{name}.alpha", (1,),
                                     f"const:{alpha_init}", "alpha"))

    def dense(self, name: str, cin: int, cout: int, fixed8: bool = True):
        self.params.append(ParamSpec(f"{name}.w", (cin, cout),
                                     f"kaiming:{cin}", "fc_w"))
        self.params.append(ParamSpec(f"{name}.b", (cout,), "zeros", "fc_b"))
        self.geoms.append(LayerGeom(name, "fc", cin * cout, cin * cout, fixed8))

"""Layer-2 model zoo: shapes, spec/manifest consistency, conv-path parity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import layers as L
from compile.init import init_params, init_bn
from compile.models import MODELS, resnet20, resnet18, smallcnn
from compile.quantizers import bitwidth_scale

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def rng():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("name", list(MODELS))
def test_forward_shapes(name, rng):
    m = MODELS[name]()
    p, bn = init_params(m, rng), init_bn(m)
    x = jax.random.normal(rng, (4, *m.input_hw, m.in_channels))
    ctx = L.Ctx(p, bn, bitwidth_scale(4), bitwidth_scale(4), train=True)
    logits = m.forward(ctx, x)
    assert logits.shape == (4, m.num_classes)
    # train-mode BN must emit one update per running stat
    assert set(ctx.new_bn) == {b.name for b in m.spec.bn}


@pytest.mark.parametrize("name", list(MODELS))
def test_spec_names_unique_and_used(name, rng):
    m = MODELS[name]()
    names = [p.name for p in m.spec.params]
    assert len(names) == len(set(names)), "duplicate param names"
    bn_names = [b.name for b in m.spec.bn]
    assert len(bn_names) == len(set(bn_names))


def test_resnet20_param_count():
    """He et al. report ~0.27M parameters for CIFAR ResNet-20."""
    m = resnet20()
    total = sum(int(np.prod(p.shape)) for p in m.spec.params
                if p.role in ("conv_w", "fc_w", "fc_b"))
    assert 0.25e6 < total < 0.32e6, total


def test_resnet18_param_count():
    """~11.2M conv/fc parameters for ResNet-18 (fc head differs: 100 cls)."""
    m = resnet18()
    total = sum(int(np.prod(p.shape)) for p in m.spec.params
                if p.role in ("conv_w", "fc_w", "fc_b"))
    assert 10.5e6 < total < 12.0e6, total


def test_first_last_layer_fixed8():
    m = resnet20()
    geoms = {g.name: g for g in m.spec.geoms}
    assert geoms["stem"].fixed8
    assert geoms["fc"].fixed8
    inner = [g for g in m.spec.geoms if g.name not in ("stem", "fc")]
    assert inner and all(not g.fixed8 for g in inner)


def test_macs_positive_and_scaled():
    """Stride-2 stages see their spatial MACs shrink accordingly."""
    m = resnet20()
    geoms = {g.name: g for g in m.spec.geoms}
    # s1.b0.conv1: 16->32 at 16x16; s0.b0.conv1: 16->16 at 32x32
    assert geoms["s0.b0.conv1"].macs == 3 * 3 * 16 * 16 * 32 * 32
    assert geoms["s1.b0.conv1"].macs == 3 * 3 * 16 * 32 * 16 * 16
    assert all(g.macs > 0 for g in m.spec.geoms)


def test_pallas_conv_matches_lax_conv(rng):
    """The im2col + Pallas-matmul conv path equals lax.conv numerically."""
    m = smallcnn()
    p, bn = init_params(m, rng), init_bn(m)
    x = jax.random.normal(rng, (2, 32, 32, 3))
    sw, sa = bitwidth_scale(4), bitwidth_scale(4)
    ctx_a = L.Ctx(p, bn, sw, sa, train=False, pallas_conv=False)
    ctx_b = L.Ctx(p, bn, sw, sa, train=False, pallas_conv=True)
    la = m.forward(ctx_a, x)
    lb = m.forward(ctx_b, x)
    np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                               rtol=1e-4, atol=1e-4)


def test_eval_mode_uses_running_stats(rng):
    """Eval BN must depend on bn_state, not the batch."""
    m = smallcnn()
    p, bn = init_params(m, rng), init_bn(m)
    x1 = jax.random.normal(rng, (4, 32, 32, 3))
    x2 = x1 * 5.0 + 1.0
    sw = sa = bitwidth_scale(8)
    out1 = m.forward(L.Ctx(p, bn, sw, sa, train=False), x1[:1])
    out2 = m.forward(L.Ctx(p, bn, sw, sa, train=False),
                     jnp.concatenate([x1[:1], x2[1:]], 0))[:1]
    np.testing.assert_allclose(np.asarray(out1[0]), np.asarray(out2[0]),
                               rtol=1e-4, atol=1e-5)


def test_fp32_mode_ignores_scales(rng):
    """quant=False graphs must not read s_w/s_a at all."""
    m = smallcnn()
    p, bn = init_params(m, rng), init_bn(m)
    x = jax.random.normal(rng, (2, 32, 32, 3))
    o1 = m.forward(L.Ctx(p, bn, 3.0, 3.0, train=False, quant=False), x)
    o2 = m.forward(L.Ctx(p, bn, 255.0, 255.0, train=False, quant=False), x)
    np.testing.assert_array_equal(np.asarray(o1), np.asarray(o2))

"""Fused step graphs: loss decreases, probe semantics, bit-width response.

These tests exercise the exact functions that get AOT-lowered into the
artifacts, so green here means the HLO the Rust side runs is sane.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.init import init_params, init_bn, flatten_params, flatten_bn
from compile.models import smallcnn
from compile.steps import (make_train_step, make_forward_step,
                           make_infer_step, example_args, infer_args)
from compile.quantizers import bitwidth_scale, S_IDENTITY

jax.config.update("jax_platform_name", "cpu")

B = 32


@pytest.fixture(scope="module")
def setup():
    m = smallcnn()
    key = jax.random.PRNGKey(0)
    p = init_params(m, key)
    bn = init_bn(m)
    mom = {k: jnp.zeros_like(v) for k, v in p.items()}
    x = jax.random.normal(key, (B, 32, 32, 3))
    y = jax.random.randint(jax.random.fold_in(key, 1), (B,), 0, 10)
    return m, p, mom, bn, x, y


def flat_train(m, p, mom, bn, x, y, lr, kw, ka):
    return (flatten_params(m, p) + flatten_params(m, mom) + flatten_bn(m, bn)
            + [x, y, jnp.float32(lr), jnp.float32(bitwidth_scale(kw)),
               jnp.float32(bitwidth_scale(ka))])


def test_train_step_decreases_loss(setup):
    m, p, mom, bn, x, y = setup
    step = jax.jit(make_train_step(m, quant=True))
    np_, nb = len(m.spec.params), len(m.spec.bn)
    flat = flat_train(m, p, mom, bn, x, y, 0.1, 4, 4)
    losses = []
    for _ in range(15):
        out = step(*flat)
        flat = list(out[:2 * np_ + nb]) + flat[2 * np_ + nb:]
        losses.append(float(out[-2]))
    assert losses[-1] < losses[0] * 0.7, losses


def test_fp_train_step_decreases_loss(setup):
    m, p, mom, bn, x, y = setup
    step = jax.jit(make_train_step(m, quant=False))
    np_, nb = len(m.spec.params), len(m.spec.bn)
    flat = flat_train(m, p, mom, bn, x, y, 0.1, 8, 8)
    losses = []
    for _ in range(15):
        out = step(*flat)
        flat = list(out[:2 * np_ + nb]) + flat[2 * np_ + nb:]
        losses.append(float(out[-2]))
    assert losses[-1] < losses[0] * 0.7, losses


def test_step_output_arity_matches_manifest_convention(setup):
    m, p, mom, bn, x, y = setup
    step = make_train_step(m, quant=True)
    out = step(*flat_train(m, p, mom, bn, x, y, 0.1, 4, 4))
    np_, nb = len(m.spec.params), len(m.spec.bn)
    assert len(out) == 2 * np_ + nb + 2  # params', mom', bn', loss, correct
    fwd = make_forward_step(m, quant=True, train_bn=True)
    pr = fwd(*(flatten_params(m, p) + flatten_bn(m, bn)
               + [x, y, jnp.float32(15.0), jnp.float32(15.0)]))
    assert len(pr) == 2


def test_probe_loss_worsens_at_one_bit(setup):
    """The finite-difference signal: fewer bits ⇒ (much) higher loss on a
    partially trained net — the mechanism AdaQAT's gradient relies on."""
    m, p, mom, bn, x, y = setup
    step = jax.jit(make_train_step(m, quant=True))
    np_, nb = len(m.spec.params), len(m.spec.bn)
    flat = flat_train(m, p, mom, bn, x, y, 0.1, 8, 8)
    for _ in range(30):
        out = step(*flat)
        flat = list(out[:2 * np_ + nb]) + flat[2 * np_ + nb:]
    probe = jax.jit(make_forward_step(m, quant=True, train_bn=True))
    base = flat[:np_] + flat[2 * np_:2 * np_ + nb] + [x, y]

    def loss_at(kw, ka):
        return float(probe(*base, jnp.float32(bitwidth_scale(kw)),
                           jnp.float32(bitwidth_scale(ka)))[0])

    l_8 = loss_at(8, 8)
    l_1 = loss_at(1, 8)
    assert l_1 > l_8, (l_1, l_8)


def test_identity_scale_equals_high_bits(setup):
    """S_IDENTITY (the `/32` rows) ≈ 24-bit quantization ≈ no quantization."""
    m, p, mom, bn, x, y = setup
    probe = jax.jit(make_forward_step(m, quant=True, train_bn=True))
    base = (flatten_params(m, p) + flatten_bn(m, bn) + [x, y])
    l_id = float(probe(*base, jnp.float32(S_IDENTITY),
                       jnp.float32(S_IDENTITY))[0])
    l_16 = float(probe(*base, jnp.float32(bitwidth_scale(16)),
                       jnp.float32(bitwidth_scale(16)))[0])
    assert abs(l_id - l_16) < 1e-3, (l_id, l_16)


def test_probe_deterministic(setup):
    m, p, mom, bn, x, y = setup
    probe = jax.jit(make_forward_step(m, quant=True, train_bn=True))
    args = (flatten_params(m, p) + flatten_bn(m, bn)
            + [x, y, jnp.float32(7.0), jnp.float32(7.0)])
    a = probe(*args)
    b = probe(*args)
    assert float(a[0]) == float(b[0]) and float(a[1]) == float(b[1])


def test_example_args_match_signature(setup):
    m, *_ = setup
    t_args = example_args(m, B, with_opt=True, with_lr=True)
    f_args = example_args(m, B, with_opt=False, with_lr=False)
    np_, nb = len(m.spec.params), len(m.spec.bn)
    assert len(t_args) == 2 * np_ + nb + 5
    assert len(f_args) == np_ + nb + 4
    # lowering must succeed with these avals
    jax.jit(make_train_step(m, quant=True)).lower(*t_args)
    jax.jit(make_forward_step(m, quant=True, train_bn=False)).lower(*f_args)


def test_infer_step_matches_eval_argmax(setup):
    """The serving graph must predict exactly what the eval graph's
    logits argmax to — same params, same BN mode, same scales."""
    m, p, mom, bn, x, y = setup
    infer = jax.jit(make_infer_step(m, quant=True))
    base = flatten_params(m, p) + flatten_bn(m, bn)
    s = jnp.float32(bitwidth_scale(4))
    preds = infer(*base, x, s, s)[0]
    assert preds.shape == (B,)
    assert preds.dtype == jnp.float32
    # recompute logits through the model directly in eval mode
    from compile import layers as L
    ctx = L.Ctx(p, bn, s, s, train=False, quant=True)
    logits = m.forward(ctx, x)
    np.testing.assert_array_equal(np.asarray(preds),
                                  np.argmax(np.asarray(logits), axis=1)
                                  .astype(np.float32))
    # and the flat signature lowers with its declared avals
    jax.jit(make_infer_step(m, quant=True)).lower(*infer_args(m, B))


def test_weight_decay_applies_only_to_weights(setup):
    """alpha/BN entries update only through their loss gradient — with a
    zero-LR step nothing should move at all (wd is folded into momentum)."""
    m, p, mom, bn, x, y = setup
    step = jax.jit(make_train_step(m, quant=True))
    out = step(*flat_train(m, p, mom, bn, x, y, 0.0, 4, 4))
    np_ = len(m.spec.params)
    for spec, new in zip(m.spec.params, out[:np_]):
        np.testing.assert_array_equal(np.asarray(new),
                                      np.asarray(p[spec.name]),
                                      err_msg=spec.name)

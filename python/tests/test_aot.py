"""AOT artifacts: manifest consistency and HLO parsability."""

import json
import os

import pytest

from compile.models import MODELS

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
MANIFEST = os.path.join(ART, "manifest.json")

pytestmark = pytest.mark.skipif(
    not os.path.exists(MANIFEST), reason="run `make artifacts` first")


@pytest.fixture(scope="module")
def manifest():
    with open(MANIFEST) as f:
        return json.load(f)


def test_manifest_covers_models(manifest):
    for name in ("smallcnn", "resnet20", "resnet18", "smallcnn_pallas"):
        assert name in manifest["models"]


def test_manifest_matches_specs(manifest):
    for name, fn in MODELS.items():
        m = fn()
        mm = manifest["models"][name]
        assert [p["name"] for p in mm["params"]] == \
            [p.name for p in m.spec.params]
        assert [tuple(p["shape"]) for p in mm["params"]] == \
            [p.shape for p in m.spec.params]
        assert [b["name"] for b in mm["bn"]] == [b.name for b in m.spec.bn]
        assert [g["name"] for g in mm["geoms"]] == \
            [g.name for g in m.spec.geoms]


def test_artifact_files_exist_and_parse(manifest):
    for name, mm in manifest["models"].items():
        for suffix, fname in mm["artifacts"].items():
            path = os.path.join(ART, fname)
            assert os.path.exists(path), path
            head = open(path).read(200)
            assert head.startswith("HloModule"), f"{fname}: {head[:40]!r}"


def test_geom_macs_totals(manifest):
    """ResNet-20 ≈ 41M MACs, ResNet-18(32px) ≈ 0.56G MACs (He et al.)."""
    r20 = sum(g["macs"] for g in manifest["models"]["resnet20"]["geoms"])
    assert 35e6 < r20 < 50e6, r20
    r18 = sum(g["macs"] for g in manifest["models"]["resnet18"]["geoms"])
    assert 0.4e9 < r18 < 0.8e9, r18

"""Layer-1 kernel correctness: every Pallas kernel vs the pure-jnp oracle.

Hypothesis sweeps shapes, bit-widths, clip levels and value ranges; the
kernels must match ``ref.py`` bit-for-bit (they compute the same fp32
expression) up to float associativity in the matmul reduction.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

# hypothesis is absent from some offline environments; skip the
# module (instead of erroring at collection) when unavailable
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    dorefa_quant,
    dorefa_quant_blocked,
    pact_quant,
    pact_quant_blocked,
    pallas_matmul,
    pallas_matmul_ad,
)
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")

BITS = st.integers(min_value=1, max_value=8)


def scale(k: int) -> float:
    return float(2.0 ** k - 1.0)


# --------------------------------------------------------------------------
# DoReFa
# --------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    st.lists(st.integers(1, 6), min_size=1, max_size=4),
    BITS,
    st.integers(0, 2**31 - 1),
)
def test_dorefa_matches_ref(dims, k, seed):
    w = jax.random.normal(jax.random.PRNGKey(seed), tuple(dims)) * 2.0
    s = scale(k)
    np.testing.assert_allclose(
        dorefa_quant(w, s), ref.dorefa_ref(w, s), rtol=1e-6, atol=1e-6)


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 4), BITS, st.integers(0, 2**31 - 1))
def test_dorefa_blocked_matches_whole(blocks, k, seed):
    w = jax.random.normal(jax.random.PRNGKey(seed), (blocks * 8, 3, 5))
    s = scale(k)
    np.testing.assert_allclose(
        dorefa_quant_blocked(w, s, block_rows=8),
        dorefa_quant(w, s), rtol=1e-6, atol=1e-6)


@settings(max_examples=15, deadline=None)
@given(BITS, st.integers(0, 2**31 - 1))
def test_dorefa_range_and_levels(k, seed):
    """Output lies in [-1, 1] and takes at most 2^k distinct values."""
    w = jax.random.normal(jax.random.PRNGKey(seed), (64,)) * 3.0
    out = np.asarray(dorefa_quant(w, scale(k)))
    assert out.min() >= -1.0 - 1e-6 and out.max() <= 1.0 + 1e-6
    assert len(np.unique(out)) <= 2 ** k


def test_dorefa_zero_tensor_no_nan():
    out = np.asarray(dorefa_quant(jnp.zeros((4, 4)), 7.0))
    assert np.isfinite(out).all()


def test_dorefa_binary_is_sign():
    """k=1 (s=1): DoReFa degenerates to ±1 * sign-ish mapping."""
    w = jnp.array([-2.0, -0.1, 0.1, 2.0])
    out = np.asarray(dorefa_quant(w, scale(1)))
    assert set(np.unique(out)).issubset({-1.0, 0.0, 1.0})


# --------------------------------------------------------------------------
# PACT
# --------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    st.lists(st.integers(1, 6), min_size=1, max_size=4),
    BITS,
    st.floats(0.5, 12.0),
    st.integers(0, 2**31 - 1),
)
def test_pact_matches_ref(dims, k, alpha, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), tuple(dims)) * 4.0
    s = scale(k)
    np.testing.assert_allclose(
        pact_quant(x, alpha, s), ref.pact_ref(x, alpha, s),
        rtol=1e-6, atol=1e-6)


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 4), BITS, st.integers(0, 2**31 - 1))
def test_pact_blocked_matches_whole(blocks, k, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (blocks * 8, 7)) * 4.0
    s = scale(k)
    np.testing.assert_allclose(
        pact_quant_blocked(x, 6.0, s, block_rows=8),
        pact_quant(x, 6.0, s), rtol=1e-6, atol=1e-6)


@settings(max_examples=15, deadline=None)
@given(BITS, st.floats(0.5, 12.0), st.integers(0, 2**31 - 1))
def test_pact_range_and_levels(k, alpha, seed):
    """Output lies in [0, alpha] with at most 2^k distinct levels."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (256,)) * 6.0
    out = np.asarray(pact_quant(x, alpha, scale(k)))
    assert out.min() >= 0.0 and out.max() <= alpha + 1e-5
    assert len(np.unique(out)) <= 2 ** k


def test_pact_negative_all_zero():
    out = np.asarray(pact_quant(-jnp.ones((8,)), 6.0, 15.0))
    np.testing.assert_array_equal(out, np.zeros(8))


def test_pact_identity_scale_is_clip():
    """Feeding s = 2^24 makes quantization the identity (DESIGN.md §6)."""
    from compile.quantizers import S_IDENTITY
    x = jnp.linspace(-1.0, 8.0, 97)
    out = np.asarray(pact_quant(x, 6.0, S_IDENTITY))
    np.testing.assert_allclose(out, np.clip(np.asarray(x), 0.0, 6.0),
                               rtol=1e-6, atol=1e-6)


# --------------------------------------------------------------------------
# Matmul
# --------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(
    st.integers(1, 200), st.integers(1, 64), st.integers(1, 150),
    st.integers(0, 2**31 - 1),
)
def test_matmul_matches_ref(m, k, n, seed):
    key = jax.random.PRNGKey(seed)
    a = jax.random.normal(key, (m, k))
    b = jax.random.normal(jax.random.fold_in(key, 1), (k, n))
    np.testing.assert_allclose(
        pallas_matmul(a, b), ref.matmul_ref(a, b), rtol=1e-4, atol=1e-4)


def test_matmul_tile_boundaries():
    """Shapes exactly on / just over the 128 tile boundary."""
    key = jax.random.PRNGKey(0)
    for m, n in [(128, 128), (129, 127), (256, 1), (1, 256)]:
        a = jax.random.normal(key, (m, 40))
        b = jax.random.normal(key, (40, n))
        np.testing.assert_allclose(
            pallas_matmul(a, b), ref.matmul_ref(a, b), rtol=1e-4, atol=1e-4)


def test_matmul_ad_gradients():
    """The custom VJP equals jnp.dot's gradients."""
    key = jax.random.PRNGKey(3)
    a = jax.random.normal(key, (17, 9))
    b = jax.random.normal(jax.random.fold_in(key, 1), (9, 5))

    ga_p, gb_p = jax.grad(lambda a, b: jnp.sum(pallas_matmul_ad(a, b) ** 2),
                          argnums=(0, 1))(a, b)
    ga_r, gb_r = jax.grad(lambda a, b: jnp.sum((a @ b) ** 2),
                          argnums=(0, 1))(a, b)
    np.testing.assert_allclose(ga_p, ga_r, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(gb_p, gb_r, rtol=1e-4, atol=1e-4)

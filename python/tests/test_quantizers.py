"""STE quantizer wrappers: forward parity + the paper's backward rules."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

# hypothesis is absent from some offline environments; skip the
# module (instead of erroring at collection) when unavailable
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.quantizers import (
    weight_quant, act_quant, bitwidth_scale, S_IDENTITY)

jax.config.update("jax_platform_name", "cpu")


def test_bitwidth_scale_values():
    assert bitwidth_scale(1) == 1.0
    assert bitwidth_scale(2) == 3.0
    assert bitwidth_scale(8) == 255.0
    # S_IDENTITY must round-trip floats exactly: round(x*s)/s == x.
    x = np.float32(0.123456)
    assert np.float32(np.round(x * S_IDENTITY) / S_IDENTITY) == pytest.approx(
        x, abs=1e-7)


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 8), st.integers(0, 2**31 - 1))
def test_weight_quant_forward(k, seed):
    w = jax.random.normal(jax.random.PRNGKey(seed), (6, 7))
    s = bitwidth_scale(k)
    np.testing.assert_allclose(
        weight_quant(w, s), ref.dorefa_ref(w, s), rtol=1e-6, atol=1e-6)


def test_weight_quant_ste_gradient():
    """dL/dw = g * (1 - tanh^2 w)/max|tanh w| (round straight-through)."""
    w = jnp.array([[-1.5, -0.2], [0.3, 1.1]])
    g = jax.grad(lambda w: jnp.sum(weight_quant(w, 3.0)))(w)
    t = np.tanh(np.asarray(w))
    m = np.abs(t).max()
    expected = (1.0 - t * t) / m * 2.0 / 2.0  # d(2q-1)/dx chain: 2 * 1/(2m)…
    # full chain: out = 2*(t/(2m)+.5 rounded)-1; STE: d out/dw = (1-t^2)/m
    expected = (1.0 - t * t) / m
    np.testing.assert_allclose(np.asarray(g), expected, rtol=1e-5)


def test_weight_quant_scale_gets_no_grad():
    """Bit-widths are optimized by the Rust finite-difference rule, not SGD."""
    w = jnp.ones((2, 2))
    fn = lambda s: jnp.sum(weight_quant(w, s))
    g = jax.grad(fn)(jnp.float32(3.0))
    assert float(g) == 0.0


def test_act_quant_ste_gradient_regions():
    """dL/dx masks to [0, alpha]; dL/dalpha collects the over-clip mass."""
    x = jnp.array([-1.0, 0.5, 2.0, 9.0])
    alpha = jnp.array([6.0])
    gx = jax.grad(lambda x: jnp.sum(act_quant(x, alpha, 15.0)))(x)
    np.testing.assert_allclose(np.asarray(gx), [0.0, 1.0, 1.0, 0.0])
    ga = jax.grad(lambda a: jnp.sum(act_quant(x, a, 15.0)))(alpha)
    # only x=9.0 exceeds alpha -> gradient 1.0
    np.testing.assert_allclose(np.asarray(ga), [1.0])


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 8), st.floats(0.5, 10.0), st.integers(0, 2**31 - 1))
def test_act_quant_forward(k, alpha, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (5, 5)) * 4.0
    s = bitwidth_scale(k)
    np.testing.assert_allclose(
        act_quant(x, jnp.float32(alpha), s), ref.pact_ref(x, alpha, s),
        rtol=1e-6, atol=1e-6)


def test_monotone_levels_in_bitwidth():
    """More bits ⇒ quantization error does not increase (on a fixed tensor)."""
    w = jax.random.normal(jax.random.PRNGKey(7), (128,))
    errs = []
    for k in range(1, 9):
        wq = weight_quant(w, bitwidth_scale(k))
        # compare against the un-rounded tanh reparameterization
        t = jnp.tanh(w)
        m = jnp.max(jnp.abs(t))
        target = t / m
        errs.append(float(jnp.mean((wq - target) ** 2)))
    assert all(errs[i] >= errs[i + 1] - 1e-9 for i in range(len(errs) - 1))

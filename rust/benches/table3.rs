//! Table III reproduction: the balancing parameter λ (paper §IV-C).
//!
//! Runs AdaQAT from scratch at λ ∈ {0.2, 0.15, 0.1} on ResNet-20 and
//! reports the learned (W, A) and top-1 — the paper's claim is monotone:
//! larger λ ⇒ more compression, lower accuracy.
//!
//! ```bash
//! cargo bench --bench table3
//! cargo bench --bench table3 -- --epochs 2 --train_size 2048
//! ```

use adaqat::config::ExperimentConfig;
use adaqat::coordinator::{default_runtime, Experiment};
use adaqat::metrics::Table;
use adaqat::util::bench::bench_args;

fn main() -> anyhow::Result<()> {
    adaqat::util::logger::init();
    if !adaqat::coordinator::artifacts_present() {
        eprintln!("bench table3: skipping — no AOT artifacts (run `make artifacts`)");
        return Ok(());
    }
    let args = bench_args();
    let model_key = args.get_str("model", "resnet20");

    let runtime = default_runtime()?;
    let model = runtime.load_model(&model_key)?;

    let mut table = Table::new(&["lambda", "W", "A", "top-1 (%)", "BitOPs (Gb)"]);
    for lambda in [0.2, 0.15, 0.1] {
        let mut cfg = ExperimentConfig::default_for(&model_key);
        cfg.epochs = 2;
        cfg.train_size = 1024;
        cfg.test_size = 512;
        cfg.eta_w = 0.08;
        cfg.eta_a = 0.04;
        cfg.apply_args(&args).map_err(|e| anyhow::anyhow!(e))?;
        cfg.lambda = lambda;
        let result = Experiment::new(&model, cfg)?.run()?;
        let (k_w, k_a) = result.final_bits;
        table.row(vec![
            format!("{lambda}"),
            k_w.to_string(),
            k_a.to_string(),
            format!("{:.1}", result.test_top1 * 100.0),
            format!("{:.2}", result.bitops_g),
        ]);
        println!("{}", table.render());
    }

    println!("\n=== Table III (ours) ===");
    print!("{}", table.render());
    println!(
        "\npaper Table III reference (ResNet-20 / CIFAR-10):
  λ=0.2 → 2/4 @ 91.7 | λ=0.15 → 3/4 @ 92.1 | λ=0.1 → 4/5 @ 92.3
expected shape: λ↑ ⇒ (W, A)↓ and top-1 (weakly) ↓."
    );
    Ok(())
}

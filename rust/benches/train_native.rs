//! Native training-backend throughput (DESIGN.md §12): steps/sec of
//! the pure-Rust fake-quant train step at k ∈ {2, 4, 8} vs the fp32
//! baseline path, written to `BENCH_train_native.json` by
//! `scripts/verify.sh` so later PRs have a training-perf trajectory
//! alongside the serving kernels' `BENCH_kernels.json`.
//!
//! Runs fully offline — no artifacts, no PJRT.
//!
//! ```bash
//! cargo bench --bench train_native
//! cargo bench --bench train_native -- --steps 40 --hidden 128 --out BENCH_train_native.json
//! ```

use std::path::PathBuf;

use adaqat::backprop::NativeBackend;
use adaqat::data::{loader::Loader, synth, DatasetKind};
use adaqat::metrics::Table;
use adaqat::runtime::StepBackend;
use adaqat::util::bench::bench_args;
use adaqat::util::json::Json;

fn main() -> anyhow::Result<()> {
    adaqat::util::logger::init();
    let args = bench_args();
    // `cargo test --benches` runs this binary unoptimized (the bench
    // smoke in scripts/verify.sh): fall back to smoke-scale defaults
    // there, full scale under `cargo bench`.
    let (def_steps, def_warmup, def_hw) =
        if cfg!(debug_assertions) { (5usize, 2usize, 16usize) } else { (30, 5, 32) };
    let steps: usize = args.get("steps", def_steps).map_err(|e| anyhow::anyhow!(e))?;
    let warmup: usize = args.get("warmup", def_warmup).map_err(|e| anyhow::anyhow!(e))?;
    let hidden: usize = args.get("hidden", 64).map_err(|e| anyhow::anyhow!(e))?;
    let batch: usize = args.get("batch", 32).map_err(|e| anyhow::anyhow!(e))?;
    let hw: usize = args.get("image_hw", def_hw).map_err(|e| anyhow::anyhow!(e))?;
    let out = args.get_str("out", "");
    let input = hw * hw * 3;

    let backend = NativeBackend::new(batch, hw, 3, 10, &[hidden])?;
    let ds = synth::generate_sized(DatasetKind::Cifar10, batch * 8, 1, 0, hw, hw).into_shared();
    let loader = Loader::new(ds, batch, true);
    let batches = loader.epoch(0);
    println!(
        "native train step: {input} -> {hidden} -> 10 MLP, batch {batch}, {steps} timed steps"
    );

    let mut table = Table::new(&["config", "ms/step", "steps/s", "final loss"]);
    let mut rows_json: Vec<Json> = vec![];
    for &(label, k, fp32) in
        &[("fp32", 32u32, true), ("w8/a8", 8, false), ("w4/a8", 4, false), ("w2/a8", 2, false)]
    {
        let mut state = backend.init_state(0)?;
        for i in 0..warmup {
            backend.train_step(&mut state, &batches[i % batches.len()], 0.01, k, 8, fp32)?;
        }
        let t0 = std::time::Instant::now();
        let mut loss = 0.0f32;
        for i in 0..steps {
            loss = backend
                .train_step(&mut state, &batches[i % batches.len()], 0.01, k, 8, fp32)?
                .loss;
        }
        let secs = t0.elapsed().as_secs_f64();
        let ms_per_step = secs * 1e3 / steps as f64;
        let steps_per_sec = steps as f64 / secs;
        anyhow::ensure!(loss.is_finite(), "{label}: diverged");
        table.row(vec![
            label.to_string(),
            format!("{ms_per_step:.2}"),
            format!("{steps_per_sec:.1}"),
            format!("{loss:.4}"),
        ]);
        rows_json.push(Json::obj(vec![
            ("config", Json::str(label)),
            ("k_w", Json::num(k as f64)),
            ("k_a", Json::num(8.0)),
            ("fp32", Json::Bool(fp32)),
            ("ms_per_step", Json::num(ms_per_step)),
            ("steps_per_sec", Json::num(steps_per_sec)),
        ]));
    }
    println!("{}", table.render());

    if !out.is_empty() {
        let doc = Json::obj(vec![
            ("bench", Json::str("train_native")),
            ("model", Json::str("native-mlp")),
            ("input", Json::num(input as f64)),
            ("hidden", Json::num(hidden as f64)),
            ("classes", Json::num(10.0)),
            ("batch", Json::num(batch as f64)),
            ("steps", Json::num(steps as f64)),
            ("results", Json::Arr(rows_json)),
        ]);
        let out = PathBuf::from(out);
        std::fs::write(&out, doc.to_string())?;
        println!("wrote {}", out.display());
    }
    Ok(())
}

//! Integer conv serving sweep (DESIGN.md §13): direct-f32 convolution
//! vs the im2col + integer-GEMM conv kernels over k_w ∈ {2,4,8} ×
//! batch ∈ {1,8,32} on the native smallcnn, written to
//! `BENCH_conv_native.json` by `scripts/verify.sh` so the conv path has
//! a perf trajectory alongside `BENCH_kernels.json` and
//! `BENCH_train_native.json` — and a ratio (`speedup_vs_direct`) the
//! bench-regression gate (`scripts/check_bench.sh`) can compare across
//! machines.
//!
//! Two forward paths per (k, batch) cell:
//! * `direct` — the math serving would do without the kernel engine:
//!   dequantized f32 kernels walked directly over the image (nested
//!   ky/kx/c loops, bounds checks), folded BN, ReLU, 2×2 pool, strided
//!   f32 fc head;
//! * `quant` — [`QuantConvNet`]: im2col patches, per-patch activation
//!   quantization at k_a = 8, i8 codes, exact i32 accumulation, BN in
//!   the f64 epilogue.
//!
//! A second sweep covers the resnet20-class residual topology
//! (DESIGN.md §18): the integer residual kernels vs the *same*
//! `QuantConvNet` served with raw f32 payloads and no activation
//! quantization (k = 32 packing), so the `speedup_vs_f32` ratio
//! isolates the integer GEMM + epilogue win with skip connections in
//! the path.
//!
//! Runs fully offline — no artifacts, no PJRT.
//!
//! ```bash
//! cargo bench --bench conv_native
//! cargo bench --bench conv_native -- --iters 5 --image_hw 32 --out BENCH_conv_native.json
//! ```

use std::path::PathBuf;

use adaqat::backprop::{ConvNativeBackend, ResNetNativeBackend};
use adaqat::data::{synth, DatasetKind};
use adaqat::kernels::conv::fold_bn;
use adaqat::kernels::QuantConvNet;
use adaqat::metrics::Table;
use adaqat::runtime::StepBackend;
use adaqat::serve::QuantizedCheckpoint;
use adaqat::util::bench::{bench_args, measure};
use adaqat::util::json::Json;

/// The pre-kernels conv math, kept as the baseline under test:
/// dequantized f32 kernels in checkpoint layout, direct convolution.
struct DirectLayer {
    h: usize,
    w: usize,
    cin: usize,
    cout: usize,
    /// `[3, 3, cin, cout]` dequantized.
    weights: Vec<f32>,
    gain: Vec<f32>,
    bias: Vec<f32>,
}

struct DirectNet {
    layers: Vec<DirectLayer>,
    fcw: Vec<f32>,
    fcb: Vec<f32>,
    flat: usize,
    classes: usize,
}

impl DirectNet {
    fn from_packed(q: &QuantizedCheckpoint, conv_names: &[String]) -> DirectNet {
        let hw = q.meta.get("input_hw").and_then(|j| j.as_arr()).expect("input_hw");
        let (mut h, mut w) = (hw[0].as_usize().unwrap(), hw[1].as_usize().unwrap());
        let mut c = q.meta.get("in_channels").and_then(|j| j.as_usize()).expect("in_channels");
        let mut layers = vec![];
        for name in conv_names {
            let wt = q.get(&format!("{name}.w")).expect("conv weight");
            let cout = wt.shape[3];
            let (gain, bias) = fold_bn(
                &q.get(&format!("{name}.bn.g")).unwrap().dequantize().data,
                &q.get(&format!("{name}.bn.b")).unwrap().dequantize().data,
                &q.get(&format!("{name}.bn.mean")).unwrap().dequantize().data,
                &q.get(&format!("{name}.bn.var")).unwrap().dequantize().data,
            );
            layers.push(DirectLayer {
                h,
                w,
                cin: c,
                cout,
                weights: wt.dequantize().data,
                gain,
                bias,
            });
            h /= 2;
            w /= 2;
            c = cout;
        }
        let fcw = q.get("fc1.w").expect("fc1.w");
        DirectNet {
            flat: fcw.shape[0],
            classes: fcw.shape[1],
            fcw: fcw.dequantize().data,
            fcb: q.get("fc1.b").expect("fc1.b").dequantize().data,
            layers,
        }
    }

    fn forward(&self, x: &[f32], rows: usize) -> Vec<f32> {
        let mut cur = x.to_vec();
        for l in &self.layers {
            let (h, w, cin, cout) = (l.h, l.w, l.cin, l.cout);
            let mut z = vec![0.0f32; rows * h * w * cout];
            for r in 0..rows {
                let img = &cur[r * h * w * cin..(r + 1) * h * w * cin];
                for oy in 0..h {
                    for ox in 0..w {
                        let o0 = ((r * h + oy) * w + ox) * cout;
                        for o in 0..cout {
                            let mut acc = 0.0f32;
                            for ky in 0..3usize {
                                let iy = (oy + ky) as isize - 1;
                                if iy < 0 || iy >= h as isize {
                                    continue;
                                }
                                for kx in 0..3usize {
                                    let ix = (ox + kx) as isize - 1;
                                    if ix < 0 || ix >= w as isize {
                                        continue;
                                    }
                                    let src = (iy as usize * w + ix as usize) * cin;
                                    let wk = ((ky * 3 + kx) * cin) * cout + o;
                                    for ci in 0..cin {
                                        acc += img[src + ci] * l.weights[wk + ci * cout];
                                    }
                                }
                            }
                            let v = acc * l.gain[o] + l.bias[o];
                            z[o0 + o] = if v < 0.0 { 0.0 } else { v };
                        }
                    }
                }
            }
            // 2x2 average pool
            let (ph, pw) = (h / 2, w / 2);
            let mut pooled = vec![0.0f32; rows * ph * pw * cout];
            for r in 0..rows {
                let img = &z[r * h * w * cout..(r + 1) * h * w * cout];
                for py in 0..ph {
                    for px in 0..pw {
                        let d0 = ((r * ph + py) * pw + px) * cout;
                        let i00 = ((2 * py) * w + 2 * px) * cout;
                        for ch in 0..cout {
                            pooled[d0 + ch] = 0.25
                                * (img[i00 + ch]
                                    + img[i00 + cout + ch]
                                    + img[i00 + w * cout + ch]
                                    + img[i00 + w * cout + cout + ch]);
                        }
                    }
                }
            }
            cur = pooled;
        }
        let mut logits = vec![0.0f32; rows * self.classes];
        for r in 0..rows {
            let xr = &cur[r * self.flat..(r + 1) * self.flat];
            let orow = &mut logits[r * self.classes..(r + 1) * self.classes];
            orow.copy_from_slice(&self.fcb);
            for (i, &xv) in xr.iter().enumerate() {
                for (o, &wv) in orow.iter_mut().zip(&self.fcw[i * self.classes..]) {
                    *o += xv * wv;
                }
            }
        }
        logits
    }
}

fn main() -> anyhow::Result<()> {
    adaqat::util::logger::init();
    let args = bench_args();
    // `cargo test --benches` runs this binary unoptimized (the bench
    // smoke in scripts/verify.sh): smoke-scale iteration counts there,
    // full scale under `cargo bench`.
    let (def_iters, def_warmup) = if cfg!(debug_assertions) { (1usize, 0usize) } else { (3, 1) };
    let iters: usize = args.get("iters", def_iters).map_err(|e| anyhow::anyhow!(e))?;
    let warmup: usize = args.get("warmup", def_warmup).map_err(|e| anyhow::anyhow!(e))?;
    let hw: usize = args.get("image_hw", 16).map_err(|e| anyhow::anyhow!(e))?;
    let out = PathBuf::from(args.get_str("out", "../BENCH_conv_native.json"));
    let channels = vec![8usize, 16];

    let ks = [2u32, 4, 8];
    let batches = [1usize, 8, 32];

    // a native conv trainer state, packed exactly as `adaqat export`
    // packs it — the same flow the serve path consumes
    let trainer = ConvNativeBackend::new(8, hw, 3, 10, &channels)?;
    let state = trainer.init_state(0)?;
    let ck = trainer.to_checkpoint(&state, 8);
    let conv_names = trainer.conv_layer_names();

    let ds = synth::generate_sized(DatasetKind::Cifar10, 32, 3, 1, hw, hw);
    let d = ds.sample_numel();
    let mut x = vec![0.0f32; 32 * d];
    for i in 0..32 {
        x[i * d..(i + 1) * d].copy_from_slice(ds.image(i));
    }

    println!(
        "=== integer conv vs direct f32 (smallcnn {hw}x{hw}x3, channels {channels:?}, k_a=8) ==="
    );
    let mut table = Table::new(&[
        "k_w", "batch", "direct ms", "quant ms", "speedup", "img/s (quant)",
    ]);
    let mut rows_json: Vec<Json> = vec![];

    for &k in &ks {
        let q = QuantizedCheckpoint::from_checkpoint(&ck, k, |n| n.ends_with(".w"));
        let quant = QuantConvNet::from_packed(&q)?;
        anyhow::ensure!(
            quant.conv.iter().all(|l| l.gemm.is_integer()),
            "k={k}: expected the integer conv path"
        );
        let direct = DirectNet::from_packed(&q, &conv_names);
        // sanity: both paths produce finite logits of the right shape
        // (bit-exact serving-vs-trainer equality is pinned by
        // tests/conv_native.rs — the two paths here deliberately differ
        // in activation quantization, so argmax can diverge on ties)
        let la = quant.forward(&x[..4 * d], 4, 1);
        let lb = direct.forward(&x[..4 * d], 4);
        anyhow::ensure!(la.len() == 40 && lb.len() == 40, "k={k}: bad logit shape");
        anyhow::ensure!(
            la.iter().chain(&lb).all(|v| v.is_finite()),
            "k={k}: non-finite logits"
        );

        for &batch in &batches {
            let xb = &x[..batch * d];
            let s_direct = measure(warmup, iters, || {
                std::hint::black_box(direct.forward(xb, batch));
            });
            let s_quant = measure(warmup, iters, || {
                std::hint::black_box(quant.forward(xb, batch, 1));
            });
            let speedup = s_direct.p50_ms / s_quant.p50_ms;
            let img_s = batch as f64 / (s_quant.p50_ms / 1e3);
            table.row(vec![
                k.to_string(),
                batch.to_string(),
                format!("{:.3}", s_direct.p50_ms),
                format!("{:.3}", s_quant.p50_ms),
                format!("{speedup:.2}x"),
                format!("{img_s:.0}"),
            ]);
            rows_json.push(Json::obj(vec![
                ("k_w", Json::num(k as f64)),
                ("k_a", Json::num(8.0)),
                ("batch", Json::num(batch as f64)),
                ("direct_ms", Json::num(s_direct.p50_ms)),
                ("quant_ms", Json::num(s_quant.p50_ms)),
                ("speedup_vs_direct", Json::num(speedup)),
                ("images_per_sec", Json::num(img_s)),
            ]));
        }
    }
    println!("{}", table.render());

    // ---- resnet20-class residual serving (DESIGN.md §18): the same
    // trainer state served twice — integer kernels at k_w × k_a = 8 vs
    // raw f32 payloads with no activation quantization (k = 32), both
    // through QuantConvNet, so the ratio is pure integer-path win
    let res_trainer = ResNetNativeBackend::new(8, hw, 3, 10, &channels, 1)?;
    let res_state = res_trainer.init_state(0)?;
    let f32_net = res_trainer.serving_resnet(&res_state, 32, 32)?;

    println!(
        "=== integer residual serving vs f32 (resnet {hw}x{hw}x3, stages {channels:?}, k_a=8) ==="
    );
    let mut res_table = Table::new(&[
        "k_w", "batch", "f32 ms", "quant ms", "speedup", "img/s (quant)",
    ]);
    for &k in &ks {
        let quant = res_trainer.serving_resnet(&res_state, k, 8)?;
        anyhow::ensure!(
            quant.res.iter().all(|b| {
                b.c1.gemm.is_integer()
                    && b.c2.gemm.is_integer()
                    && b.sc.as_ref().is_none_or(|l| l.gemm.is_integer())
            }),
            "k={k}: expected the integer residual path"
        );
        // sanity: both paths produce finite logits of the right shape
        // (bit-exact serving-vs-trainer equality is pinned by
        // tests/resnet_native.rs; the f32 side deliberately skips
        // weight and activation quantization)
        let la = quant.forward(&x[..4 * d], 4, 1);
        let lb = f32_net.forward(&x[..4 * d], 4, 1);
        anyhow::ensure!(la.len() == 40 && lb.len() == 40, "k={k}: bad resnet logit shape");
        anyhow::ensure!(
            la.iter().chain(&lb).all(|v| v.is_finite()),
            "k={k}: non-finite resnet logits"
        );

        for &batch in &batches {
            let xb = &x[..batch * d];
            let s_f32 = measure(warmup, iters, || {
                std::hint::black_box(f32_net.forward(xb, batch, 1));
            });
            let s_quant = measure(warmup, iters, || {
                std::hint::black_box(quant.forward(xb, batch, 1));
            });
            let speedup = s_f32.p50_ms / s_quant.p50_ms;
            let img_s = batch as f64 / (s_quant.p50_ms / 1e3);
            res_table.row(vec![
                k.to_string(),
                batch.to_string(),
                format!("{:.3}", s_f32.p50_ms),
                format!("{:.3}", s_quant.p50_ms),
                format!("{speedup:.2}x"),
                format!("{img_s:.0}"),
            ]);
            rows_json.push(Json::obj(vec![
                ("k_w", Json::num(k as f64)),
                ("k_a", Json::num(8.0)),
                ("batch", Json::num(batch as f64)),
                ("f32_ms", Json::num(s_f32.p50_ms)),
                ("quant_ms", Json::num(s_quant.p50_ms)),
                ("speedup_vs_f32", Json::num(speedup)),
                ("images_per_sec", Json::num(img_s)),
            ]));
        }
    }
    println!("{}", res_table.render());

    let doc = Json::obj(vec![
        ("bench", Json::str("conv_native")),
        ("model", Json::str("native-smallcnn")),
        // resnet rows (speedup_vs_f32) share the channel widths as the
        // per-stage plan, one block per stage
        ("res_model", Json::str("native-resnet20")),
        ("image_hw", Json::num(hw as f64)),
        (
            "channels",
            Json::Arr(channels.iter().map(|&c| Json::num(c as f64)).collect()),
        ),
        ("classes", Json::num(10.0)),
        ("iters", Json::num(iters as f64)),
        ("results", Json::Arr(rows_json)),
    ]);
    std::fs::write(&out, doc.to_string())?;
    println!("wrote {}", out.display());
    Ok(())
}

//! Micro benchmarks (EXPERIMENTS.md §Perf raw numbers): runtime step
//! latencies per model/graph, data-pipeline throughput, prefetch
//! overlap, controller overhead, checkpoint I/O.
//!
//! ```bash
//! cargo bench --bench micro
//! cargo bench --bench micro -- --iters 20 --models smallcnn,resnet20
//! ```

use std::sync::Arc;

use adaqat::adaqat::{AdaQatController, Controller};
use adaqat::coordinator::default_runtime;
use adaqat::data::{loader::Loader, synth, DatasetKind};
use adaqat::quant::bitwidth_scale;
use adaqat::util::bench::{bench_args, measure};

fn main() -> anyhow::Result<()> {
    adaqat::util::logger::init();
    if !adaqat::coordinator::artifacts_present() {
        eprintln!("bench micro: skipping — no AOT artifacts (run `make artifacts`)");
        return Ok(());
    }
    let args = bench_args();
    let iters: usize = args.get("iters", 5).map_err(|e| anyhow::anyhow!(e))?;
    let models = args.get_str("models", "smallcnn,resnet20");

    let runtime = default_runtime()?;

    println!("=== runtime step latency (batch baked per artifact) ===");
    for key in models.split(',') {
        let rt = runtime.load_model(key)?;
        let mut state = rt.init_state(0)?;
        let kind = if rt.mm.num_classes == 100 {
            DatasetKind::ImagenetLite
        } else {
            DatasetKind::Cifar10
        };
        let ds = synth::generate(kind, rt.mm.batch, 0, 0).into_shared();
        let batch = Loader::new(ds, rt.mm.batch, false).epoch(0).remove(0);
        let s = bitwidth_scale(4);

        let st = measure(2, iters, || {
            rt.train_step(&mut state, &batch, 0.05, s, s, false).unwrap();
        });
        println!("{}", st.row(&format!("{key} train_step (quant)")));
        let sp = measure(2, iters, || {
            rt.probe_loss(&state, &batch, s, s).unwrap();
        });
        println!("{}", sp.row(&format!("{key} probe_loss")));
        let se = measure(2, iters, || {
            rt.eval_batch(&state, &batch, s, s, false).unwrap();
        });
        println!("{}", se.row(&format!("{key} eval_batch")));
        if rt.has_fp32() {
            let sf = measure(2, iters, || {
                rt.train_step(&mut state, &batch, 0.05, s, s, true).unwrap();
            });
            println!("{}", sf.row(&format!("{key} train_step (fp32)")));
        }
        println!(
            "{:<34} probe/train ratio {:.2} (2 probes/step worst case adds {:.0}%)",
            "", sp.mean_ms / st.mean_ms, 200.0 * sp.mean_ms / st.mean_ms
        );
    }

    println!("\n=== data pipeline ===");
    let n = 2048;
    let gen = measure(1, 5, || {
        let d = synth::generate(DatasetKind::Cifar10, n, 1, 0);
        std::hint::black_box(&d.images);
    });
    println!(
        "{}  ({:.0} img/s)",
        gen.row(&format!("synth generate n={n}")),
        n as f64 / (gen.mean_ms / 1e3)
    );

    let ds = synth::generate(DatasetKind::Cifar10, n, 1, 0).into_shared();
    let loader = Loader::new(Arc::clone(&ds), 128, true);
    let asm = measure(1, 5, || {
        let batches = loader.epoch(3);
        std::hint::black_box(batches.len());
    });
    println!(
        "{}  ({:.0} img/s)",
        asm.row("epoch assemble+augment (sync)"),
        n as f64 / (asm.mean_ms / 1e3)
    );
    let pre = measure(1, 5, || {
        let rx = loader.epoch_prefetch(3);
        let mut count = 0;
        for b in rx.iter() {
            std::hint::black_box(&b.x.data);
            count += 1;
        }
        std::hint::black_box(count);
    });
    println!("{}", pre.row("epoch via prefetch thread"));

    println!("\n=== controller (pure state machine) ===");
    let ctl = measure(10, iters.max(20), || {
        let mut c = AdaQatController::with_defaults(8.0, 8.0, 0.15);
        for i in 0..1000 {
            let probes: Vec<f64> = c.probes().iter().map(|_| 1.0 + (i % 7) as f64 * 0.1).collect();
            c.update(1.0, &probes);
        }
        std::hint::black_box(c.bits());
    });
    println!("{}  (1000 updates/iter)", ctl.row("adaqat controller x1000"));

    println!("\n=== checkpoint io (resnet20-sized state) ===");
    let rt = runtime.load_model("resnet20")?;
    let state = rt.init_state(0)?;
    let path = std::env::temp_dir().join("adaqat_bench.ckpt");
    let sv = measure(1, 5, || {
        adaqat::train::save_checkpoint(&rt, &state, adaqat::util::json::Json::Null, &path)
            .unwrap();
    });
    println!("{}", sv.row("save_checkpoint (~0.3M params)"));
    let ld = measure(1, 5, || {
        let ck = adaqat::tensor::checkpoint::Checkpoint::load(&path).unwrap();
        std::hint::black_box(ck.tensors.len());
    });
    println!("{}", ld.row("load_checkpoint"));
    std::fs::remove_file(&path).ok();

    Ok(())
}

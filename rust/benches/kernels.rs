//! Quantized-kernel benchmarks (DESIGN.md §11/§14): f32-vs-integer GEMM
//! sweep over k_w ∈ {2,3,4,8} × batch ∈ {1,16,64} on the 2-layer demo
//! MLP, plus the bitserial-vs-dense-i8 sweep at k_w = k_a = k ∈ 1..=4,
//! written to `BENCH_kernels.json` so later PRs have a perf trajectory
//! to beat.
//!
//! Three forward paths per `mode: "quant"` (k, batch) cell:
//! * `legacy` — the pre-kernels serving math: dequantize the packed
//!   weights to f32 once, then the cache-hostile strided scalar dot
//!   (`w[i·n_out + o]` strides by `n_out` every element);
//! * `f32` — the kernels' f32 fallback: same dequantized weights,
//!   transposed contiguous layout (isolates the layout win);
//! * `quant` — the integer path under automatic plan selection
//!   (bitserial planes at small k_w·k_a, dense i8/i16 otherwise).
//!
//! The `mode: "bitserial"` rows race the two *forced* integer plans on
//! one layer (the demo MLP's fc1, 3072 → hidden) at k_w = k_a = k,
//! single-threaded, identical pre-quantized inputs — isolating the
//! §14 claim that popcount work scales with k_w·k_a where the dense
//! path is flat in k: `speedup_vs_i8` must improve monotonically as k
//! shrinks.
//!
//! The `mode: "dense"` rows race the vectorized dense path against the
//! same plan built under `ADAQAT_FORCE_PORTABLE=1` (the env override is
//! read fresh at plan-build time, so one process holds both): identical
//! packed weights, identical pre-quantized inputs, only the dispatched
//! dot kernel differs. k_w = 4 exercises the i8 kernel, k_w = 8 the
//! i16 kernel. The `mode: "bslice"` rows race one whole-batch bitserial
//! run against `batch` single-row runs of the same plan — the per-row
//! slicing PR 5 shipped — isolating the batch-amortized bit-plane
//! slicing win (DESIGN.md §16).
//!
//! Acceptance floors: quant ≥ 2× legacy at k_w = 4, batch 64 (ISSUE 2);
//! dense SIMD ≥ 2× portable at k_w = 4, batch 64 on AVX2 hardware and
//! bslice ≥ 1× per-row at k = 1, batch 64 (ISSUE 7); bitserial vs the
//! *vectorized* dense path is expected ≥ 1× only at the
//! `BITSERIAL_MAX_PRODUCT` crossover boundary (ISSUE 7 re-derivation).
//!
//! ```bash
//! cargo bench --bench kernels
//! cargo bench --bench kernels -- --iters 5 --hidden 512 --threads 2
//! ```

use std::path::PathBuf;

use adaqat::data::DatasetKind;
use adaqat::kernels::{quantize_row_centered, PlanChoice, QuantGemm, QuantMlp, Scratch};
use adaqat::metrics::Table;
use adaqat::serve::{demo, QuantizedCheckpoint};
use adaqat::util::bench::{bench_args, measure};
use adaqat::util::json::Json;

/// The old serving forward, generalized to the layer stack: dequantized
/// f32 weights in the checkpoint's `[d, n_out]` layout, inner loop
/// striding by `n_out` — kept verbatim as the baseline under test.
struct LegacyForward {
    layers: Vec<(usize, usize, Vec<f32>, Vec<f32>, bool)>, // (d, n_out, w, b, relu)
}

impl LegacyForward {
    fn from_packed(q: &QuantizedCheckpoint, names: &[&str]) -> LegacyForward {
        let mut layers = vec![];
        for (li, name) in names.iter().enumerate() {
            let wt = q.get(&format!("{name}.w")).expect("layer weight");
            let (d, n_out) = (wt.shape[0], wt.shape[1]);
            let w = wt.dequantize().data;
            let b = match q.get(&format!("{name}.b")) {
                Some(bt) => bt.dequantize().data,
                None => vec![0.0; n_out],
            };
            layers.push((d, n_out, w, b, li + 1 != names.len()));
        }
        LegacyForward { layers }
    }

    fn forward(&self, x: &[f32], rows: usize) -> Vec<f32> {
        let mut cur = x.to_vec();
        for (d, n_out, w, b, relu) in &self.layers {
            let (d, n_out) = (*d, *n_out);
            let mut next = vec![0.0f32; rows * n_out];
            for r in 0..rows {
                let xr = &cur[r * d..(r + 1) * d];
                for o in 0..n_out {
                    let mut acc = b[o];
                    for (i, &xv) in xr.iter().enumerate() {
                        acc += xv * w[i * n_out + o]; // strided: the old hot path
                    }
                    next[r * n_out + o] = if *relu && acc < 0.0 { 0.0 } else { acc };
                }
            }
            cur = next;
        }
        cur
    }
}

fn main() -> anyhow::Result<()> {
    adaqat::util::logger::init();
    let args = bench_args();
    let iters: usize = args.get("iters", 2).map_err(|e| anyhow::anyhow!(e))?;
    let warmup: usize = args.get("warmup", 1).map_err(|e| anyhow::anyhow!(e))?;
    let hidden: usize = args.get("hidden", 256).map_err(|e| anyhow::anyhow!(e))?;
    let samples: usize = args.get("samples", 8).map_err(|e| anyhow::anyhow!(e))?;
    let threads: usize = args.get("threads", 1).map_err(|e| anyhow::anyhow!(e))?;
    // benches always run with cwd = rust/, so the default lands at the
    // repo root where CI picks it up as an artifact
    let out = PathBuf::from(args.get_str("out", "../BENCH_kernels.json"));

    let ks = [2u32, 3, 4, 8];
    let batches = [1usize, 16, 64];

    let ck = demo::demo_mlp_checkpoint(DatasetKind::Cifar10, hidden, samples, 0, 64, 8);
    let ds = adaqat::data::synth::generate(DatasetKind::Cifar10, 64, 3, 1);
    let d = ds.sample_numel();
    let mut x = vec![0.0f32; 64 * d];
    for i in 0..64 {
        x[i * d..(i + 1) * d].copy_from_slice(ds.image(i));
    }

    println!(
        "=== quantized GEMM vs f32 (demo MLP {d}->{hidden}->10, k_a=8, {threads} thread(s)) ==="
    );
    let mut table = Table::new(&[
        "k_w", "batch", "legacy ms", "f32 ms", "quant ms", "vs legacy", "vs f32",
    ]);
    let mut rows_json: Vec<Json> = vec![];
    let mut accept: Option<f64> = None;

    for &k in &ks {
        let q = QuantizedCheckpoint::from_checkpoint(&ck, k, |n| n.ends_with(".w"));
        let quant = QuantMlp::from_packed(&q)?;
        anyhow::ensure!(
            quant.layers.iter().all(|l| l.gemm.is_integer()),
            "k={k}: expected the integer path"
        );
        // same dequantized weights, contiguous f32 fallback (k_a = 32)
        let mut q32 = q.clone();
        if let Json::Obj(m) = &mut q32.meta {
            m.insert("k_a".to_string(), Json::num(32.0));
        }
        let f32mlp = QuantMlp::from_packed(&q32)?;
        anyhow::ensure!(f32mlp.layers.iter().all(|l| !l.gemm.is_integer()));
        let legacy = LegacyForward::from_packed(&q, &["fc1", "fc2"]);

        for &batch in &batches {
            let xb = &x[..batch * d];
            let s_legacy = measure(warmup, iters, || {
                std::hint::black_box(legacy.forward(xb, batch));
            });
            let s_f32 = measure(warmup, iters, || {
                std::hint::black_box(f32mlp.forward(xb, batch, threads));
            });
            let s_quant = measure(warmup, iters, || {
                std::hint::black_box(quant.forward(xb, batch, threads));
            });
            let vs_legacy = s_legacy.p50_ms / s_quant.p50_ms;
            let vs_f32 = s_f32.p50_ms / s_quant.p50_ms;
            if k == 4 && batch == 64 {
                accept = Some(vs_legacy);
            }
            table.row(vec![
                k.to_string(),
                batch.to_string(),
                format!("{:.3}", s_legacy.p50_ms),
                format!("{:.3}", s_f32.p50_ms),
                format!("{:.3}", s_quant.p50_ms),
                format!("{vs_legacy:.1}x"),
                format!("{vs_f32:.1}x"),
            ]);
            rows_json.push(Json::obj(vec![
                ("mode", Json::str("quant")),
                ("k_w", Json::num(k as f64)),
                ("k_a", Json::num(8.0)),
                ("batch", Json::num(batch as f64)),
                ("legacy_f32_ms", Json::num(s_legacy.p50_ms)),
                ("f32_ms", Json::num(s_f32.p50_ms)),
                ("quant_ms", Json::num(s_quant.p50_ms)),
                ("speedup_vs_legacy", Json::num(vs_legacy)),
                ("speedup_vs_f32", Json::num(vs_f32)),
            ]));
        }
    }
    println!("{}", table.render());

    if let Some(sp) = accept {
        println!(
            "acceptance (k_w=4, batch=64): quant is {sp:.1}x the legacy path {}",
            if sp >= 2.0 { "(>= 2x: OK)" } else { "(< 2x — REGRESSION, investigate!)" }
        );
    }

    // --- bitserial vs dense i8 (DESIGN.md §14): k_w = k_a = k, fc1 only,
    // single thread, both plans forced so the race is path-vs-path ---
    let n_out = hidden; // fc1 is [d, hidden]
    println!(
        "=== bit-sliced popcount vs dense i8 GEMM (fc1 {d}->{n_out}, k_w=k_a=k, 1 thread) ==="
    );
    let mut btable = Table::new(&["k", "batch", "i8 ms", "bitserial ms", "vs i8"]);
    let mut baccept: Option<f64> = None;
    // per-batch p50 ms by k, for the monotone-in-k trend report
    let mut trend: Vec<(u32, usize, f64)> = vec![];
    for &k in &[1u32, 2, 3, 4] {
        let q = QuantizedCheckpoint::from_checkpoint(&ck, k, |n| n.ends_with(".w"));
        let wt = q.get("fc1.w").expect("fc1.w");
        let dense = QuantGemm::from_packed_with(wt, k, PlanChoice::DenseInt)?;
        let bits = QuantGemm::from_packed_with(wt, k, PlanChoice::Bitserial)?;
        let bias = vec![0.0f32; dense.n_out];
        for &batch in &batches {
            let mut qa = vec![0i16; batch * d];
            let mut steps = vec![0.0f32; batch];
            for r in 0..batch {
                steps[r] =
                    quantize_row_centered(&x[r * d..(r + 1) * d], k, &mut qa[r * d..(r + 1) * d]);
            }
            let mut out = vec![0.0f32; batch * dense.n_out];
            let s_dense = measure(warmup, iters, || {
                dense.forward_quant(&qa, &steps, batch, &bias, &mut out);
                std::hint::black_box(&out);
            });
            let mut scratch = Scratch::default();
            let s_bits = measure(warmup, iters, || {
                bits.forward_quant_arena(&qa, &steps, batch, &bias, &mut out, &mut scratch);
                std::hint::black_box(&out);
            });
            let vs_i8 = s_dense.p50_ms / s_bits.p50_ms;
            if k == 2 && batch == 64 {
                baccept = Some(vs_i8);
            }
            trend.push((k, batch, s_bits.p50_ms));
            btable.row(vec![
                k.to_string(),
                batch.to_string(),
                format!("{:.3}", s_dense.p50_ms),
                format!("{:.3}", s_bits.p50_ms),
                format!("{vs_i8:.1}x"),
            ]);
            rows_json.push(Json::obj(vec![
                ("mode", Json::str("bitserial")),
                ("k_w", Json::num(k as f64)),
                ("k_a", Json::num(k as f64)),
                ("batch", Json::num(batch as f64)),
                ("i8_ms", Json::num(s_dense.p50_ms)),
                ("bitserial_ms", Json::num(s_bits.p50_ms)),
                ("speedup_vs_i8", Json::num(vs_i8)),
            ]));
        }
    }
    println!("{}", btable.render());
    if let Some(sp) = baccept {
        // k_w = k_a = 2 sits exactly on the BITSERIAL_MAX_PRODUCT = 4
        // crossover: with the dense path vectorized, parity (not the
        // old 1.5x) is what keeps PlanChoice::Auto honest there.
        println!(
            "acceptance (k_w=k_a=2, batch=64): bitserial is {sp:.2}x the vectorized dense path {}",
            if sp >= 1.0 {
                "(>= 1x at the crossover boundary: OK)"
            } else {
                "(< 1x — re-derive BITSERIAL_MAX_PRODUCT, the crossover moved)"
            }
        );
    }
    // inner-loop work is ∝ k_w·k_a, so bitserial time should rise
    // monotonically in k at every batch size — report any inversion
    for &batch in &batches {
        let mut ms: Vec<(u32, f64)> = trend
            .iter()
            .filter(|(_, b, _)| *b == batch)
            .map(|&(k, _, m)| (k, m))
            .collect();
        ms.sort_by_key(|&(k, _)| k);
        let monotone = ms.windows(2).all(|w| w[0].1 <= w[1].1 * 1.05); // 5% noise slack
        println!(
            "trend (batch {batch}): bitserial ms by k {:?} {}",
            ms.iter().map(|&(k, m)| format!("k{k}={m:.3}")).collect::<Vec<_>>(),
            if monotone { "(monotone in k_w·k_a: OK)" } else { "(NOT monotone — investigate)" }
        );
    }

    // --- dense SIMD vs forced-portable scalar (DESIGN.md §16): the
    // env override is read fresh at plan-build time, so building one
    // plan natively and one under ADAQAT_FORCE_PORTABLE=1 races the
    // dispatched dot kernels in a single process on identical data.
    // k_w = 4 stores i8 weights, k_w = 8 stores i16 — both kernels.
    println!(
        "=== dense SIMD vs portable scalar (fc1 {d}->{n_out}, k_a=8, 1 thread; {}) ===",
        adaqat::kernels::isa_summary()
    );
    let mut dtable = Table::new(&["k_w", "batch", "portable ms", "native ms", "vs scalar"]);
    let mut daccept: Option<f64> = None;
    for &k in &[4u32, 8] {
        let q = QuantizedCheckpoint::from_checkpoint(&ck, k, |n| n.ends_with(".w"));
        let wt = q.get("fc1.w").expect("fc1.w");
        let native = QuantGemm::from_packed_with(wt, 8, PlanChoice::DenseInt)?;
        std::env::set_var("ADAQAT_FORCE_PORTABLE", "1");
        let portable = QuantGemm::from_packed_with(wt, 8, PlanChoice::DenseInt)?;
        std::env::remove_var("ADAQAT_FORCE_PORTABLE");
        let bias = vec![0.0f32; native.n_out];
        for &batch in &batches {
            let mut qa = vec![0i16; batch * d];
            let mut steps = vec![0.0f32; batch];
            for r in 0..batch {
                steps[r] =
                    quantize_row_centered(&x[r * d..(r + 1) * d], 8, &mut qa[r * d..(r + 1) * d]);
            }
            let mut out = vec![0.0f32; batch * native.n_out];
            let s_portable = measure(warmup, iters, || {
                portable.forward_quant(&qa, &steps, batch, &bias, &mut out);
                std::hint::black_box(&out);
            });
            let s_native = measure(warmup, iters, || {
                native.forward_quant(&qa, &steps, batch, &bias, &mut out);
                std::hint::black_box(&out);
            });
            let vs_scalar = s_portable.p50_ms / s_native.p50_ms;
            if k == 4 && batch == 64 {
                daccept = Some(vs_scalar);
            }
            dtable.row(vec![
                k.to_string(),
                batch.to_string(),
                format!("{:.3}", s_portable.p50_ms),
                format!("{:.3}", s_native.p50_ms),
                format!("{vs_scalar:.1}x"),
            ]);
            rows_json.push(Json::obj(vec![
                ("mode", Json::str("dense")),
                ("k_w", Json::num(k as f64)),
                ("k_a", Json::num(8.0)),
                ("batch", Json::num(batch as f64)),
                ("portable_ms", Json::num(s_portable.p50_ms)),
                ("native_ms", Json::num(s_native.p50_ms)),
                ("speedup_vs_scalar", Json::num(vs_scalar)),
            ]));
        }
    }
    println!("{}", dtable.render());
    if let Some(sp) = daccept {
        println!(
            "acceptance (k_w=4, batch=64): native dense is {sp:.1}x the portable scalar path {}",
            if sp >= 2.0 { "(>= 2x: OK)" } else { "(< 2x — check the isa line above)" }
        );
    }

    // --- batch-amortized bit-plane slicing vs per-row runs (§16): one
    // whole-batch bitserial forward against `batch` single-row forwards
    // of the same plan — reproducing PR 5's per-row slicing cadence —
    // so the ratio isolates what weight-stationary batch reuse buys.
    println!(
        "=== bitserial batch-amortized slicing vs per-row runs (fc1 {d}->{n_out}, k_w=k_a=k, 1 thread) ==="
    );
    let mut stable = Table::new(&["k", "batch", "per-row ms", "batched ms", "vs per-row"]);
    let mut saccept: Option<f64> = None;
    for &k in &[1u32, 2] {
        let q = QuantizedCheckpoint::from_checkpoint(&ck, k, |n| n.ends_with(".w"));
        let wt = q.get("fc1.w").expect("fc1.w");
        let bits = QuantGemm::from_packed_with(wt, k, PlanChoice::Bitserial)?;
        let bias = vec![0.0f32; bits.n_out];
        let n_out = bits.n_out;
        for &batch in &[16usize, 64] {
            let mut qa = vec![0i16; batch * d];
            let mut steps = vec![0.0f32; batch];
            for r in 0..batch {
                steps[r] =
                    quantize_row_centered(&x[r * d..(r + 1) * d], k, &mut qa[r * d..(r + 1) * d]);
            }
            let mut out = vec![0.0f32; batch * n_out];
            let mut scratch = Scratch::default();
            let s_perrow = measure(warmup, iters, || {
                for r in 0..batch {
                    bits.forward_quant_arena(
                        &qa[r * d..(r + 1) * d],
                        &steps[r..r + 1],
                        1,
                        &bias,
                        &mut out[r * n_out..(r + 1) * n_out],
                        &mut scratch,
                    );
                }
                std::hint::black_box(&out);
            });
            let s_batched = measure(warmup, iters, || {
                bits.forward_quant_arena(&qa, &steps, batch, &bias, &mut out, &mut scratch);
                std::hint::black_box(&out);
            });
            let vs_perrow = s_perrow.p50_ms / s_batched.p50_ms;
            if k == 1 && batch == 64 {
                saccept = Some(vs_perrow);
            }
            stable.row(vec![
                k.to_string(),
                batch.to_string(),
                format!("{:.3}", s_perrow.p50_ms),
                format!("{:.3}", s_batched.p50_ms),
                format!("{vs_perrow:.2}x"),
            ]);
            rows_json.push(Json::obj(vec![
                ("mode", Json::str("bslice")),
                ("k_w", Json::num(k as f64)),
                ("k_a", Json::num(k as f64)),
                ("batch", Json::num(batch as f64)),
                ("perrow_ms", Json::num(s_perrow.p50_ms)),
                ("batched_ms", Json::num(s_batched.p50_ms)),
                ("speedup_vs_perrow", Json::num(vs_perrow)),
            ]));
        }
    }
    println!("{}", stable.render());
    if let Some(sp) = saccept {
        println!(
            "acceptance (k=1, batch=64): batch-amortized slicing is {sp:.2}x the per-row cadence {}",
            if sp >= 1.0 { "(>= 1x: OK)" } else { "(< 1x — REGRESSION, investigate!)" }
        );
    }

    let doc = Json::obj(vec![
        ("bench", Json::str("kernels")),
        ("model", Json::str("demo-mlp")),
        ("input", Json::num(d as f64)),
        ("hidden", Json::num(hidden as f64)),
        ("classes", Json::num(10.0)),
        ("threads", Json::num(threads as f64)),
        ("iters", Json::num(iters as f64)),
        ("results", Json::Arr(rows_json)),
    ]);
    std::fs::write(&out, doc.to_string())?;
    println!("wrote {}", out.display());
    Ok(())
}

//! Serving benchmarks (DESIGN.md §7): packed-checkpoint size at swept
//! bit-widths, single-stream vs dynamically-batched throughput, a TCP
//! loopback end-to-end run, and a scored overload scenario (§19) —
//! 4x the measured sustained throughput against a small queue with
//! admission control armed. The overload row lands in
//! `BENCH_serve.json`, which `scripts/check_bench.sh` gates against
//! `bench_baselines/BENCH_serve.json`.
//!
//! Runs entirely offline on the pure-Rust reference backend — no AOT
//! artifacts or PJRT needed — so it doubles as the serving subsystem's
//! executable smoke test in CI (`cargo test -q --benches`).
//!
//! ```bash
//! cargo bench --bench serve
//! cargo bench --bench serve -- --n 8192 --workers 4 --max_delay_ms 1
//! ```

use std::path::PathBuf;
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use adaqat::data::DatasetKind;
use adaqat::metrics::{Histogram, Table};
use adaqat::serve::engine::SubmitError;
use adaqat::serve::{
    demo, Backend, Engine, EngineConfig, QuantizedCheckpoint, ReferenceBackend, Server,
};
use adaqat::util::bench::bench_args;
use adaqat::util::json::Json;

fn main() -> anyhow::Result<()> {
    adaqat::util::logger::init();
    let args = bench_args();
    // smoke scale under `cargo test --benches` (unoptimized), full
    // scale under `cargo bench` — same convention as the other benches
    let (def_n, def_single) = if cfg!(debug_assertions) { (512, 64) } else { (2048, 256) };
    let n: usize = args.get("n", def_n).map_err(|e| anyhow::anyhow!(e))?;
    let batch: usize = args.get("batch", 64).map_err(|e| anyhow::anyhow!(e))?;
    let workers: usize = args.get("workers", 2).map_err(|e| anyhow::anyhow!(e))?;
    let window_ms: u64 = args.get("max_delay_ms", 2).map_err(|e| anyhow::anyhow!(e))?;
    let single_n: usize = args.get("single_n", def_single).map_err(|e| anyhow::anyhow!(e))?;
    let out = PathBuf::from(args.get_str("out", "../BENCH_serve.json"));

    let tmp = std::env::temp_dir().join(format!("adaqat_serve_bench_{}", std::process::id()));
    std::fs::create_dir_all(&tmp)?;

    // ---------------------------------------------- packed size sweep
    let ck = demo::demo_checkpoint(DatasetKind::Cifar10, 64, 0, batch);
    let fp32_path = tmp.join("model.ckpt");
    ck.save(&fp32_path)?;
    let fp32_bytes = std::fs::metadata(&fp32_path)?.len();
    println!("=== packed checkpoint size (fp32 source: {fp32_bytes} bytes) ===");
    let mut table = Table::new(&["k_w", "bytes", "vs fp32", "exact round-trip"]);
    for bits in [2u32, 4, 8] {
        let q = QuantizedCheckpoint::from_checkpoint(&ck, bits, |nm| nm.ends_with(".w"));
        let path = tmp.join(format!("model_w{bits}.aqq"));
        q.save(&path)?;
        let bytes = std::fs::metadata(&path)?.len();
        let reloaded = QuantizedCheckpoint::load(&path)?;
        let exact = q
            .tensors
            .iter()
            .zip(&reloaded.tensors)
            .all(|((_, a), (_, b))| a.dequantize().data == b.dequantize().data);
        table.row(vec![
            bits.to_string(),
            bytes.to_string(),
            format!("{:.1}x smaller", fp32_bytes as f64 / bytes as f64),
            exact.to_string(),
        ]);
    }
    println!("{}", table.render());

    // ---------------------------------------------- engine throughput
    let packed = Arc::new(QuantizedCheckpoint::from_checkpoint(&ck, 4, |nm| {
        nm.ends_with(".w")
    }));
    let packed2 = Arc::clone(&packed);
    let engine = Engine::start(
        EngineConfig {
            workers,
            queue_capacity: 4096.max(n),
            max_delay: Duration::from_millis(window_ms),
            ..EngineConfig::default()
        },
        move |_| Ok(Box::new(ReferenceBackend::from_packed(&packed2)?) as Box<dyn Backend>),
    )?;

    let ds = adaqat::data::synth::generate(DatasetKind::Cifar10, n, 7, 1);

    println!("=== throughput: single-stream vs dynamic batching ===");
    println!(
        "(batch {batch}, {workers} workers, {window_ms} ms window — single-stream \
         pays the window + a full-batch forward per request)"
    );
    let t0 = Instant::now();
    for i in 0..single_n {
        let resp = engine.infer_blocking(ds.image(i % n).to_vec())?;
        anyhow::ensure!(resp.result.is_ok(), "single-stream request failed");
    }
    let rps_single = single_n as f64 / t0.elapsed().as_secs_f64();

    let t0 = Instant::now();
    let (tx, rx) = mpsc::channel();
    for i in 0..n {
        engine
            .submit(i as u64, ds.image(i).to_vec(), tx.clone())
            .map_err(|e| anyhow::anyhow!("submit {i}: {e}"))?;
    }
    let mut failures = 0usize;
    for _ in 0..n {
        let resp = rx
            .recv_timeout(Duration::from_secs(60))
            .map_err(|_| anyhow::anyhow!("engine stalled"))?;
        if resp.result.is_err() {
            failures += 1;
        }
    }
    let rps_batched = n as f64 / t0.elapsed().as_secs_f64();
    anyhow::ensure!(failures == 0, "{failures} batched requests failed");

    let speedup = rps_batched / rps_single;
    println!("single-stream: {rps_single:9.0} req/s  ({single_n} requests, window included)");
    println!("batched:       {rps_batched:9.0} req/s  ({n} requests in flight)");
    println!(
        "speedup:       {speedup:9.1}x  {}",
        if speedup >= 4.0 { "(≥4x: dynamic batching pays)" } else { "(< 4x — investigate!)" }
    );
    println!("\n=== engine metrics ===\n{}", engine.metrics.report());

    // ---------------------------------------------- TCP loopback e2e
    println!("\n=== TCP loopback end-to-end ===");
    match Server::start("127.0.0.1:0", Arc::clone(&engine)) {
        Ok(server) => {
            let images: Vec<(Vec<f32>, i32)> =
                (0..n).map(|i| (ds.image(i).to_vec(), ds.labels[i])).collect();
            let report = adaqat::serve::client::run(&server.addr.to_string(), &images, 64)?;
            println!(
                "served {}/{} over TCP at {:.0} req/s, accuracy {:.1}%, {} errors",
                report.received,
                report.sent,
                report.requests_per_second(),
                100.0 * report.correct as f64 / report.received.max(1) as f64,
                report.errors
            );
            println!("{}", report.latency.row("client rtt"));
            server.stop();
        }
        Err(e) => println!("skipping TCP section (bind failed: {e})"),
    }

    engine.shutdown();

    // ---------------------------------------------- overload behavior
    // DESIGN.md §19: offer ~4x the sustained batched throughput to a
    // fresh engine with a small queue and admission control armed.
    // Scored, not timed: every rejection must carry a finite
    // retry_after_ms hint, accounting must conserve every submit, and
    // the p99 of admitted requests must stay bounded by the max_wait
    // dial rather than grow with the backlog.
    println!("\n=== overload: 4x offered load, admission control armed ===");
    let max_wait_ms: u64 = 100;
    let packed3 = Arc::clone(&packed);
    let overload_engine = Engine::start(
        EngineConfig {
            workers,
            queue_capacity: 64,
            max_delay: Duration::from_millis(window_ms),
            max_wait: Some(Duration::from_millis(max_wait_ms)),
            ..EngineConfig::default()
        },
        move |_| Ok(Box::new(ReferenceBackend::from_packed(&packed3)?) as Box<dyn Backend>),
    )?;
    let offered_rps = 4.0 * rps_batched;
    let interval = Duration::from_secs_f64(1.0 / offered_rps);
    let admitted_ms = Histogram::new();
    let (tx, rx) = mpsc::channel();
    let (mut accepted, mut rejected, mut full) = (0u64, 0u64, 0u64);
    let mut hints_ok = true;
    let t0 = Instant::now();
    for i in 0..n {
        // paced open loop: target send times are fixed up front, so a
        // slow engine cannot slow the arrival process down
        let target = t0 + interval.mul_f64(i as f64);
        loop {
            let now = Instant::now();
            if now >= target {
                break;
            }
            std::thread::sleep((target - now).min(Duration::from_millis(1)));
        }
        match overload_engine.submit(i as u64, ds.image(i).to_vec(), tx.clone()) {
            Ok(()) => accepted += 1,
            Err(SubmitError::Overloaded { retry_after_ms }) => {
                hints_ok &= (1..=30_000).contains(&retry_after_ms);
                rejected += 1;
            }
            Err(SubmitError::Full) => full += 1, // decide/push race under load
            Err(e) => anyhow::bail!("unexpected overload submit error: {e}"),
        }
    }
    drop(tx);
    for _ in 0..accepted {
        let resp = rx
            .recv_timeout(Duration::from_secs(60))
            .map_err(|_| anyhow::anyhow!("overload engine stalled"))?;
        anyhow::ensure!(resp.result.is_ok(), "admitted overload request failed");
        admitted_ms.record_ms(resp.queue_ms + resp.compute_ms);
    }
    anyhow::ensure!(rx.try_recv().is_err(), "more responses than accepted submits");
    let (c_rejected, c_dl_adm, c_dl_batch) = overload_engine.overload_counts();
    let (c_full, _c_closed) = overload_engine.shed_counts();
    overload_engine.shutdown();

    let conserved = accepted + rejected + full == n as u64
        && c_rejected == rejected
        && c_full == full
        && c_dl_adm + c_dl_batch == 0;
    let snap = admitted_ms.snapshot();
    let p99_bound_ms = 10.0 * max_wait_ms as f64;
    let p99_bounded = snap.p99_ms <= p99_bound_ms;
    let overload_score = if rejected > 0 && hints_ok && conserved && p99_bounded {
        1.0
    } else {
        0.0
    };
    let reject_fraction = rejected as f64 / n as f64;
    println!("offered:       {offered_rps:9.0} req/s (paced, {n} requests)");
    println!(
        "admitted:      {accepted:9} requests, p99 {:.1} ms (bound {p99_bound_ms:.0} ms)",
        snap.p99_ms
    );
    println!("rejected:      {rejected:9} with retry_after_ms hints, {full} shed queue-full");
    println!(
        "overload_score:{overload_score:9.1}  (rejections seen: {}, hints finite: {hints_ok}, \
         conserved: {conserved}, p99 bounded: {p99_bounded})",
        rejected > 0
    );

    let doc = Json::obj(vec![(
        "results",
        Json::Arr(vec![
            Json::obj(vec![
                ("metric", Json::str("overload")),
                ("load", Json::str("4x")),
                ("overload_score", Json::num(overload_score)),
                ("offered_rps", Json::num(offered_rps)),
                ("admitted_p99_ms", Json::num(snap.p99_ms)),
                ("reject_fraction", Json::num(reject_fraction)),
            ]),
            Json::obj(vec![
                ("metric", Json::str("throughput")),
                ("load", Json::str("1x")),
                ("rps_single", Json::num(rps_single)),
                ("rps_batched", Json::num(rps_batched)),
                ("speedup", Json::num(speedup)),
            ]),
        ]),
    )]);
    std::fs::write(&out, doc.to_string())?;
    println!("wrote {}", out.display());

    std::fs::remove_dir_all(&tmp).ok();
    Ok(())
}

//! Serving benchmarks (DESIGN.md §7): packed-checkpoint size at swept
//! bit-widths, single-stream vs dynamically-batched throughput, and a
//! TCP loopback end-to-end run.
//!
//! Runs entirely offline on the pure-Rust reference backend — no AOT
//! artifacts or PJRT needed — so it doubles as the serving subsystem's
//! executable smoke test in CI (`cargo test -q --benches`).
//!
//! ```bash
//! cargo bench --bench serve
//! cargo bench --bench serve -- --n 8192 --workers 4 --max_delay_ms 1
//! ```

use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use adaqat::data::DatasetKind;
use adaqat::metrics::Table;
use adaqat::serve::{
    demo, Backend, Engine, EngineConfig, QuantizedCheckpoint, ReferenceBackend, Server,
};
use adaqat::util::bench::bench_args;

fn main() -> anyhow::Result<()> {
    adaqat::util::logger::init();
    let args = bench_args();
    let n: usize = args.get("n", 2048).map_err(|e| anyhow::anyhow!(e))?;
    let batch: usize = args.get("batch", 64).map_err(|e| anyhow::anyhow!(e))?;
    let workers: usize = args.get("workers", 2).map_err(|e| anyhow::anyhow!(e))?;
    let window_ms: u64 = args.get("max_delay_ms", 2).map_err(|e| anyhow::anyhow!(e))?;
    let single_n: usize = args.get("single_n", 256).map_err(|e| anyhow::anyhow!(e))?;

    let tmp = std::env::temp_dir().join(format!("adaqat_serve_bench_{}", std::process::id()));
    std::fs::create_dir_all(&tmp)?;

    // ---------------------------------------------- packed size sweep
    let ck = demo::demo_checkpoint(DatasetKind::Cifar10, 64, 0, batch);
    let fp32_path = tmp.join("model.ckpt");
    ck.save(&fp32_path)?;
    let fp32_bytes = std::fs::metadata(&fp32_path)?.len();
    println!("=== packed checkpoint size (fp32 source: {fp32_bytes} bytes) ===");
    let mut table = Table::new(&["k_w", "bytes", "vs fp32", "exact round-trip"]);
    for bits in [2u32, 4, 8] {
        let q = QuantizedCheckpoint::from_checkpoint(&ck, bits, |nm| nm.ends_with(".w"));
        let path = tmp.join(format!("model_w{bits}.aqq"));
        q.save(&path)?;
        let bytes = std::fs::metadata(&path)?.len();
        let reloaded = QuantizedCheckpoint::load(&path)?;
        let exact = q
            .tensors
            .iter()
            .zip(&reloaded.tensors)
            .all(|((_, a), (_, b))| a.dequantize().data == b.dequantize().data);
        table.row(vec![
            bits.to_string(),
            bytes.to_string(),
            format!("{:.1}x smaller", fp32_bytes as f64 / bytes as f64),
            exact.to_string(),
        ]);
    }
    println!("{}", table.render());

    // ---------------------------------------------- engine throughput
    let packed = Arc::new(QuantizedCheckpoint::from_checkpoint(&ck, 4, |nm| {
        nm.ends_with(".w")
    }));
    let packed2 = Arc::clone(&packed);
    let engine = Engine::start(
        EngineConfig {
            workers,
            queue_capacity: 4096.max(n),
            max_delay: Duration::from_millis(window_ms),
        },
        move |_| Ok(Box::new(ReferenceBackend::from_packed(&packed2)?) as Box<dyn Backend>),
    )?;

    let ds = adaqat::data::synth::generate(DatasetKind::Cifar10, n, 7, 1);

    println!("=== throughput: single-stream vs dynamic batching ===");
    println!(
        "(batch {batch}, {workers} workers, {window_ms} ms window — single-stream \
         pays the window + a full-batch forward per request)"
    );
    let t0 = Instant::now();
    for i in 0..single_n {
        let resp = engine.infer_blocking(ds.image(i % n).to_vec())?;
        anyhow::ensure!(resp.result.is_ok(), "single-stream request failed");
    }
    let rps_single = single_n as f64 / t0.elapsed().as_secs_f64();

    let t0 = Instant::now();
    let (tx, rx) = mpsc::channel();
    for i in 0..n {
        engine
            .submit(i as u64, ds.image(i).to_vec(), tx.clone())
            .map_err(|e| anyhow::anyhow!("submit {i}: {e}"))?;
    }
    let mut failures = 0usize;
    for _ in 0..n {
        let resp = rx
            .recv_timeout(Duration::from_secs(60))
            .map_err(|_| anyhow::anyhow!("engine stalled"))?;
        if resp.result.is_err() {
            failures += 1;
        }
    }
    let rps_batched = n as f64 / t0.elapsed().as_secs_f64();
    anyhow::ensure!(failures == 0, "{failures} batched requests failed");

    let speedup = rps_batched / rps_single;
    println!("single-stream: {rps_single:9.0} req/s  ({single_n} requests, window included)");
    println!("batched:       {rps_batched:9.0} req/s  ({n} requests in flight)");
    println!(
        "speedup:       {speedup:9.1}x  {}",
        if speedup >= 4.0 { "(≥4x: dynamic batching pays)" } else { "(< 4x — investigate!)" }
    );
    println!("\n=== engine metrics ===\n{}", engine.metrics.report());

    // ---------------------------------------------- TCP loopback e2e
    println!("\n=== TCP loopback end-to-end ===");
    match Server::start("127.0.0.1:0", Arc::clone(&engine)) {
        Ok(server) => {
            let images: Vec<(Vec<f32>, i32)> =
                (0..n).map(|i| (ds.image(i).to_vec(), ds.labels[i])).collect();
            let report = adaqat::serve::client::run(&server.addr.to_string(), &images, 64)?;
            println!(
                "served {}/{} over TCP at {:.0} req/s, accuracy {:.1}%, {} errors",
                report.received,
                report.sent,
                report.requests_per_second(),
                100.0 * report.correct as f64 / report.received.max(1) as f64,
                report.errors
            );
            println!("{}", report.latency.row("client rtt"));
            server.stop();
        }
        Err(e) => println!("skipping TCP section (bind failed: {e})"),
    }

    engine.shutdown();
    std::fs::remove_dir_all(&tmp).ok();
    Ok(())
}

//! Table I reproduction: mixed-precision quantization of ResNet-20 on
//! (synthetic) CIFAR-10 — every row family of the paper's comparison.
//!
//! Row mapping (paper method → our in-framework analog; the substrate is
//! a synthetic dataset + CPU-scale schedule, so *shapes*, not absolute
//! points, are the reproduction target — see DESIGN.md §5/E1):
//!   baseline 32/32      → fp32 graph, from scratch
//!   DoReFa 2/32         → fixed 2/32, from scratch
//!   PACT 2/32           → fixed 2/32, fine-tuned
//!   LQ-Net 3/3          → fixed 3/3, from scratch
//!   HAWQ-V1 3.89/4      → fixed 4/4, fine-tuned
//!   FracBits 2.00/32    → scheduled fractional 2/32, fine-tuned
//!   Ours W/32 (ft+scr)  → AdaQAT, activations pinned at 32 (η_a = 0)
//!   Ours W/8  (ft+scr)  → AdaQAT, activations pinned at 8
//!   Ours W/A  (ft+scr)  → AdaQAT λ=0.15, both learned
//!
//! ```bash
//! cargo bench --bench table1                      # quick defaults, ~8 min
//! cargo bench --bench table1 -- --epochs 2 --train_size 2048   # the EXPERIMENTS.md scale
//! ```

use std::path::Path;

use adaqat::config::{ControllerKind, ExperimentConfig, Scenario};
use adaqat::coordinator::{default_runtime, ensure_fp32_pretrain, Experiment};
use adaqat::metrics::Table;
use adaqat::util::bench::bench_args;

fn main() -> anyhow::Result<()> {
    adaqat::util::logger::init();
    if !adaqat::coordinator::artifacts_present() {
        eprintln!("bench table1: skipping — no AOT artifacts (run `make artifacts`)");
        return Ok(());
    }
    let args = bench_args();
    let model_key = args.get_str("model", "resnet20");

    let runtime = default_runtime()?;
    let model = runtime.load_model(&model_key)?;

    let mut base = ExperimentConfig::default_for(&model_key);
    base.epochs = 2;
    base.train_size = 1024;
    base.test_size = 256;
    // CPU-scale bit-width LRs (paper's 1e-3 is a 300-epoch setting)
    base.eta_w = 0.08;
    base.eta_a = 0.04;
    base.apply_args(&args).map_err(|e| anyhow::anyhow!(e))?;

    let ck = ensure_fp32_pretrain(&model, &base, base.epochs, Path::new("runs/pretrained"))?;
    let ft = || Scenario::Finetune { checkpoint: ck.clone() };

    struct Row {
        label: &'static str,
        ctl: ControllerKind,
        scenario: Scenario,
        fp32: bool,
        init_na: f64,
        eta_a: Option<f64>,
        lambda: f64,
    }
    // one row per Table-I line: kept one-per-line for side-by-side
    // readability, which is worth more than rustfmt's 8-line explosion
    #[rustfmt::skip]
    let rows = vec![
        Row { label: "baseline fp32", ctl: ControllerKind::Fixed { k_w: 32, k_a: 32 }, scenario: ft(), fp32: true, init_na: 32.0, eta_a: None, lambda: 0.15 },
        Row { label: "static 2/32 scratch  [DoReFa]", ctl: ControllerKind::Fixed { k_w: 2, k_a: 32 }, scenario: Scenario::Scratch, fp32: false, init_na: 32.0, eta_a: None, lambda: 0.15 },
        Row { label: "static 2/32 finetune [PACT]", ctl: ControllerKind::Fixed { k_w: 2, k_a: 32 }, scenario: ft(), fp32: false, init_na: 32.0, eta_a: None, lambda: 0.15 },
        Row { label: "static 3/3 scratch   [LQ-Net]", ctl: ControllerKind::Fixed { k_w: 3, k_a: 3 }, scenario: Scenario::Scratch, fp32: false, init_na: 3.0, eta_a: None, lambda: 0.15 },
        Row { label: "static 4/4 finetune  [HAWQ-V1]", ctl: ControllerKind::Fixed { k_w: 4, k_a: 4 }, scenario: ft(), fp32: false, init_na: 4.0, eta_a: None, lambda: 0.15 },
        Row { label: "sched 2/32 finetune  [FracBits]", ctl: ControllerKind::FracBits { k_w_target: 2, k_a_target: 32 }, scenario: ft(), fp32: false, init_na: 32.0, eta_a: None, lambda: 0.15 },
        Row { label: "ours W/32 finetune", ctl: ControllerKind::AdaQat, scenario: ft(), fp32: false, init_na: 32.0, eta_a: Some(0.0), lambda: 0.3 },
        Row { label: "ours W/32 scratch", ctl: ControllerKind::AdaQat, scenario: Scenario::Scratch, fp32: false, init_na: 32.0, eta_a: Some(0.0), lambda: 0.3 },
        Row { label: "ours W/8 finetune", ctl: ControllerKind::AdaQat, scenario: ft(), fp32: false, init_na: 8.0, eta_a: Some(0.0), lambda: 0.15 },
        Row { label: "ours W/8 scratch", ctl: ControllerKind::AdaQat, scenario: Scenario::Scratch, fp32: false, init_na: 8.0, eta_a: Some(0.0), lambda: 0.15 },
        Row { label: "ours W/A finetune", ctl: ControllerKind::AdaQat, scenario: ft(), fp32: false, init_na: 8.0, eta_a: None, lambda: 0.15 },
        Row { label: "ours W/A scratch", ctl: ControllerKind::AdaQat, scenario: Scenario::Scratch, fp32: false, init_na: 8.0, eta_a: None, lambda: 0.15 },
    ];

    let mut table = Table::new(&["method", "W/A", "top-1 (%)", "dAcc", "WCR", "BitOPs (Gb)"]);
    let mut baseline_top1: Option<f64> = None;
    for row in rows {
        let mut cfg = base.clone();
        cfg.controller = row.ctl;
        cfg.fp32 = row.fp32;
        cfg.init_na = row.init_na;
        if let Some(ea) = row.eta_a {
            cfg.eta_a = ea;
        }
        cfg.lambda = row.lambda;
        cfg.scenario = row.scenario;
        if matches!(cfg.scenario, Scenario::Finetune { .. }) {
            cfg.lr = 0.01; // paper §IV-A fine-tuning LR
        } else {
            // paper §IV-A: from-scratch runs get twice the epochs (300
            // vs 150); mirror the ratio so scratch rows are comparable
            cfg.epochs *= 2;
        }
        let t0 = std::time::Instant::now();
        let result = Experiment::new(&model, cfg)?.run()?;
        let (k_w, k_a) = result.final_bits;
        let top1 = result.test_top1 * 100.0;
        let dacc = baseline_top1.map(|b| format!("{:+.1}", top1 - b)).unwrap_or("-".into());
        if row.fp32 {
            baseline_top1 = Some(top1);
        }
        log::info!("{}: done in {:.0}s", row.label, t0.elapsed().as_secs_f64());
        table.row(vec![
            row.label.to_string(),
            if row.fp32 { "32/32".into() } else { format!("{k_w}/{k_a}") },
            format!("{top1:.1}"),
            dacc,
            if row.fp32 { "-".into() } else { format!("{:.1}x", result.wcr) },
            format!("{:.2}", result.bitops_g),
        ]);
        println!("{}", table.render()); // progressive output
    }

    println!("\n=== Table I (ours, synthetic CIFAR-10, CPU-scale schedule) ===");
    print!("{}", table.render());
    println!(
        "\npaper Table I reference (real CIFAR-10, 150/300 epochs):
  baseline 32/32 92.4 | DoReFa 2/32 88.2 (-4.2) | PACT 2/32 89.7 (-2.7)
  LQ-Net 3/3 91.6 (-0.5) | FracBits 2/32 89.6 | HAWQ-V1 3.89/4 92.2 (-0.2)
  ours ft 2/32 92.0, 3/8 92.1, 3/4 92.2 | ours scratch 2/32 91.8, 3/8 91.8, 3/4 92.1
expected shape: low static bits lose the most; AdaQAT rows land near
baseline; scratch ≈ finetune; BitOPs(3/4) ≈ 5x lower than 2/32."
    );
    Ok(())
}

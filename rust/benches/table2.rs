//! Table II reproduction: ResNet-18 fine-tuning on ImageNet-lite
//! (100-class synthetic substitute, DESIGN.md §4).
//!
//! Rows (paper → analog): DoReFa/PACT/LQ-Net 4/4 → fixed 4/4 fine-tune;
//! FracBits 4/4 → scheduled 4/4 fine-tune; Ours 4/4 → AdaQAT λ=0.15
//! fine-tune (init 8/8); plus the fp32 reference the paper reports as
//! "FP top-1".
//!
//! ```bash
//! cargo bench --bench table2                       # quick defaults, ~5 min
//! cargo bench --bench table2 -- --epochs 1 --train_size 1024
//! ```

use std::path::Path;

use adaqat::config::{ControllerKind, ExperimentConfig, Scenario};
use adaqat::coordinator::{default_runtime, ensure_fp32_pretrain, Experiment};
use adaqat::metrics::Table;
use adaqat::util::bench::bench_args;

fn main() -> anyhow::Result<()> {
    adaqat::util::logger::init();
    if !adaqat::coordinator::artifacts_present() {
        eprintln!("bench table2: skipping — no AOT artifacts (run `make artifacts`)");
        return Ok(());
    }
    let args = bench_args();

    let runtime = default_runtime()?;
    let model = runtime.load_model("resnet18")?;

    let mut base = ExperimentConfig::default_for("resnet18");
    base.epochs = 2;
    base.train_size = 512; // 16 steps/epoch at batch 32
    base.test_size = 256;
    base.eta_w = 0.08;
    base.eta_a = 0.04;
    base.apply_args(&args).map_err(|e| anyhow::anyhow!(e))?;

    let ck = ensure_fp32_pretrain(&model, &base, base.epochs, Path::new("runs/pretrained"))?;

    // FP reference top-1 (the paper's "FP top-1" column)
    let fp_top1 = {
        let mut cfg = base.clone();
        cfg.fp32 = true;
        cfg.controller = ControllerKind::Fixed { k_w: 32, k_a: 32 };
        cfg.scenario = Scenario::Finetune { checkpoint: ck.clone() };
        cfg.epochs = 1;
        cfg.lr = 0.01;
        Experiment::new(&model, cfg)?.run()?.test_top1 * 100.0
    };

    #[rustfmt::skip]
    let rows: Vec<(&str, ControllerKind, f64)> = vec![
        ("static 4/4 finetune [DoReFa/PACT/LQ-Net]", ControllerKind::Fixed { k_w: 4, k_a: 4 }, 0.15),
        ("sched 4/4 finetune  [FracBits]", ControllerKind::FracBits { k_w_target: 4, k_a_target: 4 }, 0.15),
        ("ours W/A finetune   [AdaQAT]", ControllerKind::AdaQat, 0.15),
    ];

    let mut table = Table::new(&["method", "W/A", "top-1 (%)", "FP top-1", "WCR", "BitOPs (Gb)"]);
    for (label, ctl, lambda) in rows {
        let mut cfg = base.clone();
        cfg.controller = ctl;
        cfg.lambda = lambda;
        cfg.scenario = Scenario::Finetune { checkpoint: ck.clone() };
        cfg.lr = 0.01;
        let result = Experiment::new(&model, cfg)?.run()?;
        let (k_w, k_a) = result.final_bits;
        table.row(vec![
            label.to_string(),
            format!("{k_w}/{k_a}"),
            format!("{:.1}", result.test_top1 * 100.0),
            format!("{fp_top1:.1}"),
            format!("{:.1}x", result.wcr),
            format!("{:.2}", result.bitops_g),
        ]);
        println!("{}", table.render());
    }

    println!("\n=== Table II (ours, ImageNet-lite substitute) ===");
    print!("{}", table.render());
    println!(
        "\npaper Table II reference (real ImageNet, ResNet-18 ft):
  DoReFa 4/4 68.1 | PACT 4/4 69.2 | LQ-Net 4/4 69.3 | FracBits 4/4 70.6
  SDQ 3.85/4 71.7 | HAWQ-V3 4.8/7.5 70.4 | ours 4/4 70.3 (FP 70.5)
expected shape: 4/4 fine-tuning lands within ~0.2-2.4 pts of FP."
    );
    Ok(())
}

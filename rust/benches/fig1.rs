//! Fig. 1 reproduction: train-accuracy trace + ⌈N_w⌉/⌈N_a⌉ staircase
//! showing the oscillation regime and the freeze (paper §III-C).
//!
//! Uses an aggressive η_w and a low oscillation threshold so the full
//! decrease → oscillate → freeze cycle is visible in a CPU-scale run.
//! Writes `runs/fig1/trace.csv` and prints an ASCII rendition.
//!
//! ```bash
//! cargo bench --bench fig1                        # smallcnn, ~2 min
//! cargo bench --bench fig1 -- --model resnet20   # the paper network (slower)
//! ```

use adaqat::config::ExperimentConfig;
use adaqat::coordinator::{default_runtime, Experiment};
use adaqat::metrics::ascii_plot;
use adaqat::util::bench::bench_args;

fn main() -> anyhow::Result<()> {
    adaqat::util::logger::init();
    if !adaqat::coordinator::artifacts_present() {
        eprintln!("bench fig1: skipping — no AOT artifacts (run `make artifacts`)");
        return Ok(());
    }
    let args = bench_args();
    let model_key = args.get_str("model", "resnet20");

    let runtime = default_runtime()?;
    let model = runtime.load_model(&model_key)?;

    let mut cfg = ExperimentConfig::default_for(&model_key);
    cfg.epochs = 6;
    cfg.train_size = 2048;
    cfg.test_size = 512;
    cfg.lambda = 0.2;
    // Aggressive bit-width dynamics so the oscillation pattern forms in
    // ~100 steps (the paper sees it over tens of epochs with η=1e-3).
    cfg.eta_w = 0.08;
    cfg.eta_a = 0.04;
    cfg.osc_threshold = 6;
    cfg.out_dir = Some("runs/fig1".into());
    cfg.apply_args(&args).map_err(|e| anyhow::anyhow!(e))?;

    let result = Experiment::new(&model, cfg)?.run()?;

    let acc: Vec<f64> = result.trace.iter().map(|t| t.train_acc * 100.0).collect();
    let kw: Vec<f64> = result.trace.iter().map(|t| t.k_w as f64).collect();
    let ka: Vec<f64> = result.trace.iter().map(|t| t.k_a as f64).collect();
    let nw: Vec<f64> = result.trace.iter().map(|t| t.n_w).collect();

    println!("\n=== Fig. 1 (ours): train accuracy vs bit-width adaptation ===");
    println!("\ntrain batch accuracy (%):");
    print!("{}", ascii_plot(&[("acc", &acc)], 76, 11));
    println!("\ndiscretized bit-widths (staircase) + fractional N_w:");
    print!("{}", ascii_plot(&[("ceil(N_w)", &kw), ("ceil(N_a)", &ka), ("N_w", &nw)], 76, 11));

    // oscillation + freeze summary
    let mut freeze_step_w = None;
    let mut last_osc = 0;
    for t in &result.trace {
        if t.osc_w > last_osc {
            last_osc = t.osc_w;
        }
        if freeze_step_w.is_none() && t.osc_w >= 6 {
            freeze_step_w = Some(t.step);
        }
    }
    let (k_w, k_a) = result.final_bits;
    println!(
        "\noscillations observed: W={} A={}",
        result.trace.last().map(|t| t.osc_w).unwrap_or(0),
        result.trace.last().map(|t| t.osc_a).unwrap_or(0)
    );
    match freeze_step_w {
        Some(s) => println!("weight bit-width froze at step {s} (threshold 6)"),
        None => println!("weight bit-width did not freeze in this budget (raise --epochs)"),
    }
    println!("final bits {k_w}/{k_a}; raw data in runs/fig1/trace.csv");
    println!(
        "\npaper Fig. 1 shape: accuracy dips at each ceil(N) decrement and
recovers; N_w oscillates between two adjacent integers near the optimum
and is frozen to the larger one after the threshold is crossed."
    );
    Ok(())
}

//! Observability overhead benchmark (DESIGN.md §15): batched serve
//! throughput with the metrics samplers enabled vs disabled.
//!
//! The obs layer's contract is "cheap enough to leave on": counters and
//! histograms are one relaxed atomic op behind pre-registered handles,
//! and instrumentation sites gate their `Instant::now()` pairs on
//! [`Registry::enabled`]. This bench measures exactly that switch —
//! same engine, same requests, samplers on vs off — and emits
//! `overhead_ratio = instrumented_rps / uninstrumented_rps` to
//! `BENCH_obs.json`. `scripts/check_bench.sh` gates the ratio against
//! the committed baseline (0.95, i.e. ≤ 5% overhead). Gauges (queue
//! depth, pool occupancy) stay live in both modes by design — paired
//! add(+1)/add(−1) updates must not be torn by a mid-flight toggle —
//! so the "off" side still pays for them, which is the honest baseline:
//! the switch only controls the samplers an operator could disable.
//!
//! Runs fully offline on the reference backend — no artifacts, no PJRT.
//!
//! ```bash
//! cargo bench --bench obs
//! cargo bench --bench obs -- --n 4096 --iters 5 --out BENCH_obs.json
//! ```

use std::path::PathBuf;
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use adaqat::data::DatasetKind;
use adaqat::metrics::Table;
use adaqat::obs;
use adaqat::serve::{
    demo, Backend, Engine, EngineConfig, QuantizedCheckpoint, ReferenceBackend,
};
use adaqat::util::bench::bench_args;
use adaqat::util::json::Json;

/// Push `n` requests through the engine and wait for every answer;
/// returns requests/second.
fn run_pass(engine: &Engine, images: &[Vec<f32>], n: usize) -> anyhow::Result<f64> {
    let t0 = Instant::now();
    let (tx, rx) = mpsc::channel();
    for i in 0..n {
        engine
            .submit(i as u64, images[i % images.len()].clone(), tx.clone())
            .map_err(|e| anyhow::anyhow!("submit {i}: {e}"))?;
    }
    for _ in 0..n {
        let resp = rx
            .recv_timeout(Duration::from_secs(60))
            .map_err(|_| anyhow::anyhow!("engine stalled"))?;
        anyhow::ensure!(resp.result.is_ok(), "request failed");
    }
    Ok(n as f64 / t0.elapsed().as_secs_f64())
}

fn main() -> anyhow::Result<()> {
    adaqat::util::logger::init();
    let args = bench_args();
    // smoke scale under `cargo test --benches` (unoptimized), full
    // scale under `cargo bench` — same convention as the other benches
    let (def_n, def_iters) = if cfg!(debug_assertions) { (256usize, 1usize) } else { (2048, 3) };
    let n: usize = args.get("n", def_n).map_err(|e| anyhow::anyhow!(e))?;
    let iters: usize = args.get("iters", def_iters).map_err(|e| anyhow::anyhow!(e))?;
    let batch: usize = args.get("batch", 64).map_err(|e| anyhow::anyhow!(e))?;
    let workers: usize = args.get("workers", 2).map_err(|e| anyhow::anyhow!(e))?;
    let window_ms: u64 = args.get("max_delay_ms", 2).map_err(|e| anyhow::anyhow!(e))?;
    let out = PathBuf::from(args.get_str("out", "../BENCH_obs.json"));

    // 2-layer demo MLP at W4/A8 on the integer kernels, so the
    // per-layer forward-time histograms are live in the enabled pass
    let ck = demo::demo_mlp_checkpoint(DatasetKind::Cifar10, 64, 8, 11, batch, 8);
    let packed = Arc::new(QuantizedCheckpoint::from_checkpoint(&ck, 4, |nm| {
        nm.ends_with(".w")
    }));
    let packed2 = Arc::clone(&packed);
    let engine = Engine::start(
        EngineConfig {
            workers,
            queue_capacity: 4096.max(n),
            max_delay: Duration::from_millis(window_ms),
            // armed but never firing at this queue depth / time scale:
            // the §19 admission + deadline checks must price inside the
            // same ≤5% instrumentation budget
            default_deadline: Some(Duration::from_secs(60)),
            max_wait: Some(Duration::from_secs(30)),
        },
        move |_| Ok(Box::new(ReferenceBackend::from_packed(&packed2)?) as Box<dyn Backend>),
    )?;

    let ds = adaqat::data::synth::generate(DatasetKind::Cifar10, 256, 7, 1);
    let images: Vec<Vec<f32>> = (0..256).map(|i| ds.image(i).to_vec()).collect();

    // warm both code paths (arena growth, first-batch registration)
    run_pass(&engine, &images, n.min(512))?;

    println!("=== obs overhead: samplers on vs off ({n} requests × {iters} iters) ===");
    // interleave modes so drift (thermal, scheduler) hits both equally;
    // best-of per mode rejects the noise floor rather than averaging it
    let (mut best_on, mut best_off) = (0.0f64, 0.0f64);
    for _ in 0..iters {
        obs::global().set_enabled(true);
        best_on = best_on.max(run_pass(&engine, &images, n)?);
        obs::global().set_enabled(false);
        best_off = best_off.max(run_pass(&engine, &images, n)?);
    }
    obs::global().set_enabled(true);

    let ratio = best_on / best_off;
    let mut table = Table::new(&["mode", "best req/s"]);
    table.row(vec!["instrumented".to_string(), format!("{best_on:.0}")]);
    table.row(vec!["uninstrumented".to_string(), format!("{best_off:.0}")]);
    table.row(vec!["ratio".to_string(), format!("{ratio:.4}")]);
    println!("{}", table.render());
    println!(
        "overhead: {:.2}% {}",
        100.0 * (1.0 - ratio),
        if ratio >= 0.95 { "(within the 5% budget)" } else { "(OVER the 5% budget!)" }
    );

    let doc = Json::obj(vec![
        ("bench", Json::str("obs")),
        ("n", Json::num(n as f64)),
        ("iters", Json::num(iters as f64)),
        ("workers", Json::num(workers as f64)),
        (
            "results",
            Json::Arr(vec![Json::obj(vec![
                ("metric", Json::str("serve_overhead")),
                ("instrumented_rps", Json::num(best_on)),
                ("uninstrumented_rps", Json::num(best_off)),
                ("overhead_ratio", Json::num(ratio)),
            ])]),
        ),
    ]);
    std::fs::write(&out, doc.to_string())?;
    println!("wrote {}", out.display());

    engine.shutdown();
    Ok(())
}

//! Quantization math + the hardware cost model (paper §III-B).
//!
//! * `bitwidth_scale` — s = 2^k − 1, the runtime scalar fed to the
//!   compiled graphs (defined here; [`crate::runtime`] re-exports it for
//!   callers that think in runtime terms).
//! * [`CostModel`] — BitOPs and weight-compression-rate computed from the
//!   per-layer geometry the AOT manifest ships (FracBits eqs. (4)–(5),
//!   as adopted by the paper): for a conv filter f,
//!   `BitOPs(f) = ⌈N_w⌉·⌈N_a⌉·|f|·w_f·h_f/s_f²` — i.e. MACs × N_w × N_a,
//!   with first/last layers pinned at 8 bits.
//! * `hard_loss` — the paper's network-level simplification
//!   `L_hard = ⌈N_w⌉·⌈N_a⌉` (one bit-width per weights/activations).

pub mod energy;

use crate::runtime::manifest::ModelManifest;

pub use energy::{EnergyCost, FpgaLutCost, HardCost, MemoryCost, ProductCost};

/// Scale fed for "this signal is not quantized" (`/32` rows of Table I):
/// round(x·2^24)/2^24 is exact in f32, so quantization is the identity.
/// Mirrors `python/compile/quantizers.py::S_IDENTITY`.
pub const S_IDENTITY: f32 = 16_777_216.0; // 2^24

/// s = 2^k − 1 for integer bit-width k (k ≥ 24 ⇒ identity scale).
pub fn bitwidth_scale(k: u32) -> f32 {
    if k >= 24 {
        S_IDENTITY
    } else {
        (1u64 << k) as f32 - 1.0
    }
}

/// Integer code levels s = 2^k − 1 for k ∈ 1..=24 — the shared grid
/// definition behind [`bitwidth_scale`], the packed-checkpoint format
/// (`serve::packed`) and the integer kernels' activation quantizer
/// (`kernels::activ`). Codes c ∈ [0, s] are 2^k values; the centered
/// form q = 2c − s ∈ [−s, s] steps by 2 and carries s's parity.
pub fn code_levels(k: u32) -> u32 {
    debug_assert!((1..=24).contains(&k), "code_levels wants k in 1..=24, got {k}");
    (1u32 << k) - 1
}

/// Bits used to report "unquantized" signals in tables (fp32 baseline).
pub const FP_BITS: u32 = 32;

/// Per-layer cost inputs, extracted from the manifest.
#[derive(Debug, Clone)]
pub struct CostModel {
    layers: Vec<(usize, usize, bool)>, // (weight_count, macs, fixed8)
}

impl CostModel {
    pub fn from_manifest(mm: &ModelManifest) -> CostModel {
        CostModel {
            layers: mm
                .geoms
                .iter()
                .map(|g| (g.weight_count, g.macs, g.fixed8))
                .collect(),
        }
    }

    /// Synthetic cost model for unit tests / simulations.
    pub fn from_layers(layers: Vec<(usize, usize, bool)>) -> CostModel {
        CostModel { layers }
    }

    /// Total BitOPs in Gbit-ops for network-wide bit-widths (k_w, k_a).
    /// Fixed-8 layers (first/last, paper §IV-A) contribute at 8×8
    /// regardless; `k >= 24` means "unquantized" and is charged 32 bits
    /// (matching how Table I reports the `/32` rows).
    pub fn bitops_g(&self, k_w: u32, k_a: u32) -> f64 {
        let eff = |k: u32| -> f64 {
            if k >= 24 {
                32.0
            } else {
                k as f64
            }
        };
        let mut total = 0.0f64;
        for &(_, macs, fixed8) in &self.layers {
            let (w, a) = if fixed8 { (8.0, 8.0) } else { (eff(k_w), eff(k_a)) };
            total += macs as f64 * w * a;
        }
        total / 1e9
    }

    /// Weight compression rate vs fp32: 32 / (weighted mean weight bits).
    /// A manifest with no weights at all (every layer's weight_count is
    /// zero) compresses nothing: WCR = 1, not 0/0 = NaN.
    pub fn wcr(&self, k_w: u32) -> f64 {
        let mut bits = 0.0f64;
        let mut count = 0.0f64;
        for &(wc, _, fixed8) in &self.layers {
            let k = if fixed8 { 8.0 } else if k_w >= 24 { 32.0 } else { k_w as f64 };
            bits += wc as f64 * k;
            count += wc as f64;
        }
        if bits <= 0.0 {
            return 1.0;
        }
        32.0 * count / bits
    }

    /// Total model MACs (sanity/report helper).
    pub fn total_macs(&self) -> usize {
        self.layers.iter().map(|l| l.1).sum()
    }

    /// Raw per-layer rows (weight_count, macs, fixed8) — consumed by the
    /// extended cost models in [`energy`].
    pub fn layers(&self) -> &[(usize, usize, bool)] {
        &self.layers
    }
}

/// The paper's network-level hardware loss: L_hard = ⌈N_w⌉·⌈N_a⌉.
pub fn hard_loss(k_w: u32, k_a: u32) -> f64 {
    k_w as f64 * k_a as f64
}

/// ∂L_hard/∂⌈N_w⌉ = ⌈N_a⌉ and symmetrically (used by eq. (3)).
pub fn hard_grad_w(k_a: u32) -> f64 {
    k_a as f64
}

pub fn hard_grad_a(k_w: u32) -> f64 {
    k_w as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop::check;

    fn toy() -> CostModel {
        // stem (fixed8), two body layers, fc (fixed8)
        CostModel::from_layers(vec![
            (432, 442_368, true),
            (2_304, 2_359_296, false),
            (9_216, 2_359_296, false),
            (640, 640, true),
        ])
    }

    #[test]
    fn bitops_scales_with_bits() {
        let cm = toy();
        let b44 = cm.bitops_g(4, 4);
        let b88 = cm.bitops_g(8, 8);
        // body layers dominate; 8×8 is 4× the bit product of 4×4
        assert!(b88 > b44 * 2.0, "{b88} vs {b44}");
        // fixed layers identical in both
        let fixed_part = (442_368.0 + 640.0) * 64.0 / 1e9;
        assert!((b44 - fixed_part - 4.0 * 4.0 * (2.0 * 2_359_296.0) / 1e9).abs() < 1e-9);
    }

    #[test]
    fn unquantized_charged_32() {
        let cm = toy();
        assert!(cm.bitops_g(2, 32) > cm.bitops_g(2, 8));
        assert_eq!(cm.bitops_g(2, 32), cm.bitops_g(2, 24));
    }

    #[test]
    fn wcr_2bit_close_to_16x() {
        let cm = toy();
        // most weights are 2-bit, the small fixed layers dilute slightly
        let wcr = cm.wcr(2);
        assert!((12.0..16.0).contains(&wcr), "{wcr}");
        let wcr32 = cm.wcr(32);
        assert!(wcr32 < 1.1, "{wcr32}");
    }

    #[test]
    fn wcr_of_weightless_manifest_is_finite() {
        // all-zero weight counts (e.g. a degenerate synthetic manifest):
        // 0/0 must not leak NaN/inf into reports and bench JSON
        let empty = CostModel::from_layers(vec![(0, 100, false), (0, 50, true)]);
        for k in [1u32, 4, 32] {
            let w = empty.wcr(k);
            assert!(w.is_finite(), "wcr({k}) = {w}");
            assert_eq!(w, 1.0);
        }
        let none = CostModel::from_layers(vec![]);
        assert_eq!(none.wcr(4), 1.0);
    }

    #[test]
    fn monotonicity_properties() {
        let cm = toy();
        check(100, 3, |rng| {
            let k1 = 1 + rng.below(8) as u32;
            let k2 = k1 + 1 + rng.below(4) as u32;
            let ka = 1 + rng.below(8) as u32;
            prop_assert!(
                cm.bitops_g(k1, ka) < cm.bitops_g(k2, ka),
                "bitops not monotone in k_w: {k1} vs {k2}"
            );
            prop_assert!(
                cm.wcr(k1) > cm.wcr(k2),
                "wcr not antitone: {k1} vs {k2}"
            );
            Ok(())
        });
    }

    #[test]
    fn hard_loss_grads() {
        assert_eq!(hard_loss(3, 4), 12.0);
        assert_eq!(hard_grad_w(4), 4.0);
        assert_eq!(hard_grad_a(3), 3.0);
    }

    #[test]
    fn code_levels_match_bitwidth_scale_below_identity() {
        for k in 1..24u32 {
            assert_eq!(code_levels(k) as f32, bitwidth_scale(k), "k={k}");
        }
        assert_eq!(code_levels(24), (1 << 24) - 1);
    }

    #[test]
    fn bitwidth_scales() {
        assert_eq!(bitwidth_scale(1), 1.0);
        assert_eq!(bitwidth_scale(2), 3.0);
        assert_eq!(bitwidth_scale(8), 255.0);
        assert_eq!(bitwidth_scale(32), S_IDENTITY);
        assert_eq!(bitwidth_scale(24), S_IDENTITY);
        // identity scale: exact for f32 in [0.5, 1] (24-bit mantissa),
        // and within 1 ulp-of-2^-24 below that — i.e. "not quantized"
        // at the precision the quantized graphs operate in.
        let x = 0.7234567f32;
        assert_eq!((x * S_IDENTITY).round() / S_IDENTITY, x);
        let y = 0.1234567f32;
        assert!(((y * S_IDENTITY).round() / S_IDENTITY - y).abs() < 2.0 / S_IDENTITY);
    }
}

//! Extended hardware-cost models (paper §V future work: "finer hardware
//! complexity and energy consumption metrics, tailored for a specific
//! target architecture (e.g. FPGAs), in the L_Hard term").
//!
//! Three interchangeable `L_hard` definitions beyond the paper's
//! `⌈N_w⌉·⌈N_a⌉` product, each expressed per-layer from the manifest
//! geometry and normalized so λ ranges stay comparable:
//!
//! * [`MemoryCost`] — weight-memory bits (FracBits' recommendation for
//!   weight-only quantization): Σ |f|·k_w.
//! * [`FpgaLutCost`] — LUT-style multiplier area: a k_w×k_a array
//!   multiplier costs ≈ k_w·k_a LUTs, but DSP-block quantization makes
//!   cost *staircase* at native widths (e.g. 9×9/18×18 DSP tiles); this
//!   model charges ceil(k/9)² DSP-equivalents per MAC site.
//! * [`EnergyCost`] — switched-capacitance proxy: MAC energy scales
//!   ≈ (k_w·k_a)^1.25 for array multipliers plus a k_a-linear SRAM-read
//!   term (activation traffic), following standard accelerator energy
//!   breakdowns.
//!
//! Each implements [`HardCost`], so the AdaQAT controller's hardware
//! gradient (eq. (3)) can swap cost models without touching the update
//! rule — the finite-difference machinery only needs
//! `∂L_hard/∂⌈N_w⌉` and `∂L_hard/∂⌈N_a⌉`, here computed as exact
//! one-bit differences.

use super::CostModel;

/// A pluggable hardware-loss term for eq. (2)/(3).
pub trait HardCost: Send {
    /// L_hard at discretized bit-widths.
    fn loss(&self, k_w: u32, k_a: u32) -> f64;

    /// Exact one-bit finite differences — the discrete analog of
    /// ∂L_hard/∂⌈N⌉, consistent with how the task-loss gradient is
    /// estimated (paper §III-C).
    fn grad_w(&self, k_w: u32, k_a: u32) -> f64 {
        self.loss(k_w, k_a) - self.loss(k_w.saturating_sub(1).max(1), k_a)
    }

    fn grad_a(&self, k_w: u32, k_a: u32) -> f64 {
        self.loss(k_w, k_a) - self.loss(k_w, k_a.saturating_sub(1).max(1))
    }

    fn name(&self) -> &'static str;
}

/// The paper's network-level product model (§III-B).
pub struct ProductCost;

impl HardCost for ProductCost {
    fn loss(&self, k_w: u32, k_a: u32) -> f64 {
        k_w as f64 * k_a as f64
    }

    fn name(&self) -> &'static str {
        "product"
    }
}

/// Weight-memory bits, normalized to [0, 32]-ish scale by mean bits.
pub struct MemoryCost {
    total_weights: f64,
    weighted: Vec<(f64, bool)>, // (weight_count, fixed8)
}

impl MemoryCost {
    pub fn new(cm: &CostModel) -> MemoryCost {
        let weighted: Vec<(f64, bool)> =
            cm.layers().iter().map(|&(wc, _, f8)| (wc as f64, f8)).collect();
        MemoryCost { total_weights: weighted.iter().map(|x| x.0).sum(), weighted }
    }
}

impl HardCost for MemoryCost {
    fn loss(&self, k_w: u32, _k_a: u32) -> f64 {
        let bits: f64 = self
            .weighted
            .iter()
            .map(|&(wc, f8)| wc * if f8 { 8.0 } else { k_w as f64 })
            .sum();
        bits / self.total_weights // mean bits per weight
    }

    fn name(&self) -> &'static str {
        "memory"
    }
}

/// DSP-tile staircase: ceil(k/9)² tiles per MAC (9-bit native width à la
/// modern FPGA DSP slices), weighted by per-layer MAC counts.
pub struct FpgaLutCost {
    macs: Vec<(f64, bool)>,
    total_macs: f64,
}

impl FpgaLutCost {
    pub fn new(cm: &CostModel) -> FpgaLutCost {
        let macs: Vec<(f64, bool)> =
            cm.layers().iter().map(|&(_, m, f8)| (m as f64, f8)).collect();
        FpgaLutCost { total_macs: macs.iter().map(|x| x.0).sum(), macs }
    }

    fn tiles(k: u32) -> f64 {
        (k as f64 / 9.0).ceil()
    }
}

impl HardCost for FpgaLutCost {
    fn loss(&self, k_w: u32, k_a: u32) -> f64 {
        let per_mac = |kw: u32, ka: u32| Self::tiles(kw) * Self::tiles(ka);
        let cost: f64 = self
            .macs
            .iter()
            .map(|&(m, f8)| m * if f8 { per_mac(8, 8) } else { per_mac(k_w, k_a) })
            .sum();
        // ×16 so λ values tuned for the product model stay in range
        16.0 * cost / self.total_macs
    }

    fn name(&self) -> &'static str {
        "fpga-dsp"
    }
}

/// Switched-capacitance proxy: (k_w·k_a)^1.25 multiplier energy +
/// 0.5·k_a SRAM traffic per MAC.
pub struct EnergyCost {
    macs: Vec<(f64, bool)>,
    total_macs: f64,
}

impl EnergyCost {
    pub fn new(cm: &CostModel) -> EnergyCost {
        let macs: Vec<(f64, bool)> =
            cm.layers().iter().map(|&(_, m, f8)| (m as f64, f8)).collect();
        EnergyCost { total_macs: macs.iter().map(|x| x.0).sum(), macs }
    }
}

impl HardCost for EnergyCost {
    fn loss(&self, k_w: u32, k_a: u32) -> f64 {
        let per_mac = |kw: u32, ka: u32| {
            ((kw * ka) as f64).powf(1.25) / 8.0 + 0.5 * ka as f64
        };
        let cost: f64 = self
            .macs
            .iter()
            .map(|&(m, f8)| m * if f8 { per_mac(8, 8) } else { per_mac(k_w, k_a) })
            .sum();
        cost / self.total_macs
    }

    fn name(&self) -> &'static str {
        "energy"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop::check;

    fn cm() -> CostModel {
        CostModel::from_layers(vec![
            (432, 442_368, true),
            (2_304, 2_359_296, false),
            (9_216, 2_359_296, false),
            (640, 640, true),
        ])
    }

    #[test]
    fn product_matches_paper_model() {
        let c = ProductCost;
        assert_eq!(c.loss(3, 4), 12.0);
        assert_eq!(c.grad_w(3, 4), 4.0); // one-bit difference = ⌈N_a⌉
        assert_eq!(c.grad_a(3, 4), 3.0);
    }

    #[test]
    fn memory_ignores_activations() {
        let cost = cm();
        let c = MemoryCost::new(&cost);
        assert_eq!(c.loss(4, 2), c.loss(4, 8));
        assert_eq!(c.grad_a(4, 4), 0.0);
        assert!(c.grad_w(4, 4) > 0.0);
        // mean bits at k_w = 8 is exactly 8 (fixed layers also 8)
        assert!((c.loss(8, 1) - 8.0).abs() < 1e-9);
    }

    #[test]
    fn fpga_cost_staircases_at_dsp_width() {
        let cost = cm();
        let c = FpgaLutCost::new(&cost);
        // within one DSP tile (k ≤ 9) cost is flat...
        assert_eq!(c.loss(4, 4), c.loss(9, 9));
        // ...and jumps when a second tile is needed
        assert!(c.loss(10, 9) > c.loss(9, 9));
        assert_eq!(c.grad_w(5, 5), 0.0); // flat inside the tile
        assert!(c.grad_w(10, 9) > 0.0); // gradient appears at the step
    }

    #[test]
    fn all_models_monotone_nondecreasing() {
        let cost = cm();
        let models: Vec<Box<dyn HardCost>> = vec![
            Box::new(ProductCost),
            Box::new(MemoryCost::new(&cost)),
            Box::new(FpgaLutCost::new(&cost)),
            Box::new(EnergyCost::new(&cost)),
        ];
        check(200, 17, |rng| {
            let kw = 1 + rng.below(16) as u32;
            let ka = 1 + rng.below(16) as u32;
            for m in &models {
                prop_assert!(
                    m.loss(kw + 1, ka) >= m.loss(kw, ka) - 1e-12,
                    "{} not monotone in k_w at ({kw},{ka})",
                    m.name()
                );
                prop_assert!(
                    m.loss(kw, ka + 1) >= m.loss(kw, ka) - 1e-12,
                    "{} not monotone in k_a at ({kw},{ka})",
                    m.name()
                );
                prop_assert!(
                    m.grad_w(kw, ka) >= -1e-12 && m.grad_a(kw, ka) >= -1e-12,
                    "{} negative gradient",
                    m.name()
                );
            }
            Ok(())
        });
    }

    #[test]
    fn energy_grows_superlinearly_in_bit_product() {
        let cost = cm();
        let c = EnergyCost::new(&cost);
        let e44 = c.loss(4, 4);
        let e88 = c.loss(8, 8);
        // (64/16)^1.25 = 5.66x on the body layers; fixed layers dilute,
        // but growth must exceed the linear 4x of the product model
        assert!(e88 / e44 > 3.0, "{e88} / {e44}");
    }
}

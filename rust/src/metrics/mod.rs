//! Metrics output: CSV writers, aligned report tables, ASCII plots
//! (used by the Fig. 1 bench to render the bit-width staircase), and
//! lock-free latency histograms with percentile reporting (used by the
//! serve subsystem's per-request queue/compute timings — DESIGN.md §7).

use std::io::Write;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};

/// Append-style CSV writer with a fixed header.
pub struct CsvWriter {
    file: std::io::BufWriter<std::fs::File>,
    columns: usize,
}

impl CsvWriter {
    pub fn create(path: &Path, header: &[&str]) -> anyhow::Result<CsvWriter> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut file = std::io::BufWriter::new(std::fs::File::create(path)?);
        writeln!(file, "{}", header.join(","))?;
        Ok(CsvWriter { file, columns: header.len() })
    }

    pub fn row(&mut self, values: &[String]) -> anyhow::Result<()> {
        anyhow::ensure!(
            values.len() == self.columns,
            "row has {} values, header has {}",
            values.len(),
            self.columns
        );
        writeln!(self.file, "{}", values.join(","))?;
        self.file.flush()?;
        Ok(())
    }
}

/// Build an aligned text table (the bench harnesses print these in the
/// papers' row order).
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Table {
        Table { header: header.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }

    pub fn row(&mut self, values: Vec<String>) {
        assert_eq!(values.len(), self.header.len());
        self.rows.push(values);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(String::len).collect();
        for row in &self.rows {
            for (i, v) in row.iter().enumerate() {
                widths[i] = widths[i].max(v.len());
            }
        }
        let fmt_row = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let mut out = fmt_row(&self.header);
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }
}

/// Render one or more named series as a compact ASCII chart (Fig. 1).
pub fn ascii_plot(series: &[(&str, &[f64])], width: usize, height: usize) -> String {
    let glyphs = ['*', '+', 'o', 'x', '#'];
    let n = series.iter().map(|(_, s)| s.len()).max().unwrap_or(0);
    if n == 0 {
        return String::new();
    }
    let lo = series
        .iter()
        .flat_map(|(_, s)| s.iter().copied())
        .fold(f64::INFINITY, f64::min);
    let hi = series
        .iter()
        .flat_map(|(_, s)| s.iter().copied())
        .fold(f64::NEG_INFINITY, f64::max);
    let span = (hi - lo).max(1e-9);
    let mut grid = vec![vec![' '; width]; height];
    for (si, (_, s)) in series.iter().enumerate() {
        for (i, &v) in s.iter().enumerate() {
            let x = i * (width - 1) / (n - 1).max(1);
            let y = ((v - lo) / span * (height - 1) as f64).round() as usize;
            let y = height - 1 - y.min(height - 1);
            grid[y][x] = glyphs[si % glyphs.len()];
        }
    }
    let mut out = String::new();
    for (i, row) in grid.iter().enumerate() {
        let label = if i == 0 {
            format!("{hi:9.3} |")
        } else if i == height - 1 {
            format!("{lo:9.3} |")
        } else {
            "          |".to_string()
        };
        out.push_str(&label);
        out.extend(row.iter());
        out.push('\n');
    }
    out.push_str("           ");
    out.push_str(&"-".repeat(width));
    out.push('\n');
    let legend: Vec<String> = series
        .iter()
        .enumerate()
        .map(|(i, (name, _))| format!("{} {}", glyphs[i % glyphs.len()], name))
        .collect();
    out.push_str(&format!("           {}\n", legend.join("   ")));
    out
}

// --------------------------------------------------------------- latency

/// Number of log-spaced histogram buckets.
const HIST_BUCKETS: usize = 96;
/// Lower edge of bucket 0 in milliseconds (1 µs).
const HIST_LO_MS: f64 = 1e-3;
/// log2 of the bucket-width ratio: buckets grow by 2^0.25 ≈ 1.19×, so
/// reported percentiles carry ≲ ±10% quantization error and the range
/// covers 1 µs … ~16.8 s.
const HIST_LOG2_RATIO: f64 = 0.25;

/// A fixed-memory, thread-safe latency histogram. `record_ms` is a
/// single relaxed atomic increment, so the serve workers can stamp every
/// request without contending on a lock.
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    /// Total in nanoseconds (u64 holds > 500 years of accumulated time).
    sum_ns: AtomicU64,
    max_ns: AtomicU64,
}

/// Point-in-time percentile summary of a [`Histogram`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LatencySnapshot {
    pub count: u64,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub max_ms: f64,
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram::new()
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram {
            buckets: (0..HIST_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_ns: AtomicU64::new(0),
            max_ns: AtomicU64::new(0),
        }
    }

    fn bucket_index(ms: f64) -> usize {
        if ms <= HIST_LO_MS {
            return 0;
        }
        let idx = ((ms / HIST_LO_MS).log2() / HIST_LOG2_RATIO) as usize;
        idx.min(HIST_BUCKETS - 1)
    }

    /// Geometric midpoint of a bucket, in ms (what percentiles report).
    fn bucket_mid(i: usize) -> f64 {
        HIST_LO_MS * 2f64.powf((i as f64 + 0.5) * HIST_LOG2_RATIO)
    }

    pub fn record_ms(&self, ms: f64) {
        let ms = if ms.is_finite() && ms >= 0.0 { ms } else { 0.0 };
        self.buckets[Self::bucket_index(ms)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        let ns = (ms * 1e6) as u64;
        self.sum_ns.fetch_add(ns, Ordering::Relaxed);
        self.max_ns.fetch_max(ns, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// p ∈ [0, 1]; returns 0 for an empty histogram.
    pub fn percentile(&self, p: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return 0.0;
        }
        let target = ((p.clamp(0.0, 1.0) * total as f64).ceil() as u64).max(1);
        let mut cum = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            cum += b.load(Ordering::Relaxed);
            if cum >= target {
                return Self::bucket_mid(i);
            }
        }
        Self::bucket_mid(HIST_BUCKETS - 1)
    }

    pub fn snapshot(&self) -> LatencySnapshot {
        let count = self.count();
        let max_ms = self.max_ns.load(Ordering::Relaxed) as f64 / 1e6;
        // percentile() reports bucket midpoints, and the top sample's
        // log-bucket midpoint can sit *above* the recorded maximum — a
        // snapshot must never claim a percentile beyond its own max
        LatencySnapshot {
            count,
            mean_ms: if count == 0 {
                0.0
            } else {
                self.sum_ns.load(Ordering::Relaxed) as f64 / 1e6 / count as f64
            },
            p50_ms: self.percentile(0.50).min(max_ms),
            p95_ms: self.percentile(0.95).min(max_ms),
            p99_ms: self.percentile(0.99).min(max_ms),
            max_ms,
        }
    }
}

impl LatencySnapshot {
    /// One aligned report line (used by `adaqat serve` stats logging and
    /// the serve bench).
    pub fn row(&self, name: &str) -> String {
        format!(
            "{name:<12} n={:<7} mean {:>8.3} ms  p50 {:>8.3}  p95 {:>8.3}  p99 {:>8.3}  max {:>8.3}",
            self.count, self.mean_ms, self.p50_ms, self.p95_ms, self.p99_ms, self.max_ms
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_roundtrip() {
        let path = std::env::temp_dir()
            .join(format!("adaqat_csv_{}.csv", std::process::id()));
        {
            let mut w = CsvWriter::create(&path, &["a", "b"]).unwrap();
            w.row(&["1".into(), "2".into()]).unwrap();
            assert!(w.row(&["only-one".into()]).is_err());
        }
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "a,b\n1,2\n");
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn table_aligns() {
        let mut t = Table::new(&["method", "top1"]);
        t.row(vec!["baseline".into(), "92.4".into()]);
        t.row(vec!["ours".into(), "92.2".into()]);
        let r = t.render();
        assert!(r.contains("method"));
        let lines: Vec<&str> = r.lines().collect();
        assert_eq!(lines.len(), 4);
        assert_eq!(lines[2].find("92.4"), lines[3].find("92.2"));
    }

    #[test]
    fn plot_contains_series_extremes() {
        let s1: Vec<f64> = (0..50).map(|i| (i as f64 / 10.0).sin()).collect();
        let s2: Vec<f64> = (0..50).map(|i| i as f64 / 50.0).collect();
        let p = ascii_plot(&[("sin", &s1), ("ramp", &s2)], 60, 12);
        assert!(p.contains('*') && p.contains('+'));
        assert!(p.contains("sin") && p.contains("ramp"));
        assert!(p.lines().count() >= 13);
    }

    #[test]
    fn plot_empty_ok() {
        assert_eq!(ascii_plot(&[], 10, 5), "");
    }

    #[test]
    fn histogram_percentiles_track_uniform_distribution() {
        let h = Histogram::new();
        for i in 1..=1000 {
            h.record_ms(i as f64);
        }
        let s = h.snapshot();
        assert_eq!(s.count, 1000);
        assert!((s.mean_ms - 500.5).abs() < 10.0, "mean {}", s.mean_ms);
        // log-bucketed: ≲ ±19% relative quantization error per bucket
        assert!((400.0..625.0).contains(&s.p50_ms), "p50 {}", s.p50_ms);
        assert!((760.0..1190.0).contains(&s.p95_ms), "p95 {}", s.p95_ms);
        assert!(s.p50_ms <= s.p95_ms && s.p95_ms <= s.p99_ms);
        assert!((s.max_ms - 1000.0).abs() < 1.0, "max {}", s.max_ms);
    }

    #[test]
    fn histogram_empty_and_edge_values() {
        let h = Histogram::new();
        assert_eq!(h.snapshot().count, 0);
        assert_eq!(h.percentile(0.5), 0.0);
        // pathological inputs land in bucket 0 instead of poisoning state
        h.record_ms(-3.0);
        h.record_ms(f64::NAN);
        h.record_ms(0.0);
        assert_eq!(h.count(), 3);
        assert!(h.percentile(1.0) < 2e-3);
        // far beyond the top bucket still counts
        h.record_ms(1e9);
        assert_eq!(h.count(), 4);
        assert!(h.snapshot().max_ms >= 1e9 - 1.0);
    }

    #[test]
    fn snapshot_percentiles_never_exceed_observed_max() {
        // regression: a single 5 ms sample lands in a log bucket whose
        // geometric midpoint is ≈ 5.31 ms, so the raw percentile sits
        // above the recorded maximum — the snapshot must clamp
        let h = Histogram::new();
        h.record_ms(5.0);
        assert!(h.percentile(0.99) > 5.0, "premise: midpoint exceeds the sample");
        let s = h.snapshot();
        assert!((s.max_ms - 5.0).abs() < 1e-6);
        assert!(s.p50_ms <= s.max_ms, "p50 {} > max {}", s.p50_ms, s.max_ms);
        assert!(s.p95_ms <= s.max_ms, "p95 {} > max {}", s.p95_ms, s.max_ms);
        assert!(s.p99_ms <= s.max_ms, "p99 {} > max {}", s.p99_ms, s.max_ms);
    }

    #[test]
    fn histogram_is_shareable_across_threads() {
        let h = std::sync::Arc::new(Histogram::new());
        let mut handles = vec![];
        for t in 0..4 {
            let h = std::sync::Arc::clone(&h);
            handles.push(std::thread::spawn(move || {
                for i in 0..250 {
                    h.record_ms((t * 250 + i) as f64 / 10.0);
                }
            }));
        }
        for j in handles {
            j.join().unwrap();
        }
        assert_eq!(h.count(), 1000);
    }
}

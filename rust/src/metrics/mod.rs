//! Metrics output: CSV writers, aligned report tables, ASCII plots
//! (used by the Fig. 1 bench to render the bit-width staircase).

use std::io::Write;
use std::path::Path;

/// Append-style CSV writer with a fixed header.
pub struct CsvWriter {
    file: std::io::BufWriter<std::fs::File>,
    columns: usize,
}

impl CsvWriter {
    pub fn create(path: &Path, header: &[&str]) -> anyhow::Result<CsvWriter> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut file = std::io::BufWriter::new(std::fs::File::create(path)?);
        writeln!(file, "{}", header.join(","))?;
        Ok(CsvWriter { file, columns: header.len() })
    }

    pub fn row(&mut self, values: &[String]) -> anyhow::Result<()> {
        anyhow::ensure!(
            values.len() == self.columns,
            "row has {} values, header has {}",
            values.len(),
            self.columns
        );
        writeln!(self.file, "{}", values.join(","))?;
        self.file.flush()?;
        Ok(())
    }
}

/// Build an aligned text table (the bench harnesses print these in the
/// papers' row order).
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Table {
        Table { header: header.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }

    pub fn row(&mut self, values: Vec<String>) {
        assert_eq!(values.len(), self.header.len());
        self.rows.push(values);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(String::len).collect();
        for row in &self.rows {
            for (i, v) in row.iter().enumerate() {
                widths[i] = widths[i].max(v.len());
            }
        }
        let fmt_row = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let mut out = fmt_row(&self.header);
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }
}

/// Render one or more named series as a compact ASCII chart (Fig. 1).
pub fn ascii_plot(series: &[(&str, &[f64])], width: usize, height: usize) -> String {
    let glyphs = ['*', '+', 'o', 'x', '#'];
    let n = series.iter().map(|(_, s)| s.len()).max().unwrap_or(0);
    if n == 0 {
        return String::new();
    }
    let lo = series
        .iter()
        .flat_map(|(_, s)| s.iter().copied())
        .fold(f64::INFINITY, f64::min);
    let hi = series
        .iter()
        .flat_map(|(_, s)| s.iter().copied())
        .fold(f64::NEG_INFINITY, f64::max);
    let span = (hi - lo).max(1e-9);
    let mut grid = vec![vec![' '; width]; height];
    for (si, (_, s)) in series.iter().enumerate() {
        for (i, &v) in s.iter().enumerate() {
            let x = i * (width - 1) / (n - 1).max(1);
            let y = ((v - lo) / span * (height - 1) as f64).round() as usize;
            let y = height - 1 - y.min(height - 1);
            grid[y][x] = glyphs[si % glyphs.len()];
        }
    }
    let mut out = String::new();
    for (i, row) in grid.iter().enumerate() {
        let label = if i == 0 {
            format!("{hi:9.3} |")
        } else if i == height - 1 {
            format!("{lo:9.3} |")
        } else {
            "          |".to_string()
        };
        out.push_str(&label);
        out.extend(row.iter());
        out.push('\n');
    }
    out.push_str("           ");
    out.push_str(&"-".repeat(width));
    out.push('\n');
    let legend: Vec<String> = series
        .iter()
        .enumerate()
        .map(|(i, (name, _))| format!("{} {}", glyphs[i % glyphs.len()], name))
        .collect();
    out.push_str(&format!("           {}\n", legend.join("   ")));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_roundtrip() {
        let path = std::env::temp_dir()
            .join(format!("adaqat_csv_{}.csv", std::process::id()));
        {
            let mut w = CsvWriter::create(&path, &["a", "b"]).unwrap();
            w.row(&["1".into(), "2".into()]).unwrap();
            assert!(w.row(&["only-one".into()]).is_err());
        }
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "a,b\n1,2\n");
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn table_aligns() {
        let mut t = Table::new(&["method", "top1"]);
        t.row(vec!["baseline".into(), "92.4".into()]);
        t.row(vec!["ours".into(), "92.2".into()]);
        let r = t.render();
        assert!(r.contains("method"));
        let lines: Vec<&str> = r.lines().collect();
        assert_eq!(lines.len(), 4);
        assert_eq!(lines[2].find("92.4"), lines[3].find("92.2"));
    }

    #[test]
    fn plot_contains_series_extremes() {
        let s1: Vec<f64> = (0..50).map(|i| (i as f64 / 10.0).sin()).collect();
        let s2: Vec<f64> = (0..50).map(|i| i as f64 / 50.0).collect();
        let p = ascii_plot(&[("sin", &s1), ("ramp", &s2)], 60, 12);
        assert!(p.contains('*') && p.contains('+'));
        assert!(p.contains("sin") && p.contains("ramp"));
        assert!(p.lines().count() >= 13);
    }

    #[test]
    fn plot_empty_ok() {
        assert_eq!(ascii_plot(&[], 10, 5), "");
    }
}

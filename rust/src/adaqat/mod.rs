//! The AdaQAT bit-width controller — the paper's contribution (§III).
//!
//! Maintains relaxed real-valued bit-widths `N_w`, `N_a`; trains the
//! network at the discretized `⌈N_w⌉`, `⌈N_a⌉`; estimates task-loss
//! gradients by finite differences between ceil/floor neighbors on the
//! same batch (eq. (3)); updates with per-axis learning rates (eq. (4));
//! detects the oscillation regime and freezes each bit-width at the
//! larger oscillation point after `osc_threshold` flips (Fig. 1).
//!
//! The controller is *pure state-machine*: it never touches the runtime.
//! The trainer asks it which probes to run (`probes()`), executes them
//! against the compiled loss graph, and feeds the results back
//! (`update()`), keeping this logic independently unit- and
//! property-testable against synthetic loss landscapes.

pub mod baselines;

pub use baselines::{FixedController, FracBitsController};

/// Which bit-width a finite-difference probe perturbs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Axis {
    Weights,
    Activations,
}

/// A probe the trainer must run: evaluate L_task at (k_w, k_a) on the
/// current batch. `up` marks a forward (k+1) difference — used only at
/// the 1-bit clamp, where the paper's ceil/floor difference degenerates
/// (see `AdaQatController::probes`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ProbeRequest {
    pub axis: Axis,
    pub k_w: u32,
    pub k_a: u32,
    pub up: bool,
}

/// Common interface for AdaQAT and the baseline bit-width policies.
pub trait Controller: Send {
    /// Discretized bit-widths to train with right now (⌈N_w⌉, ⌈N_a⌉).
    fn bits(&self) -> (u32, u32);
    /// The relaxed fractional values (for logging/Fig. 1).
    fn fractional(&self) -> (f64, f64);
    /// Neighbor evaluations needed before `update` (empty = no probe).
    fn probes(&self) -> Vec<ProbeRequest>;
    /// Feed back the train-batch loss `l_cc` (at `bits()`) and the probe
    /// losses, in the same order `probes()` returned them.
    fn update(&mut self, l_cc: f64, probe_losses: &[f64]);
    /// (weights frozen?, activations frozen?)
    fn frozen(&self) -> (bool, bool);
    fn osc_counts(&self) -> (usize, usize) {
        (0, 0)
    }
    fn name(&self) -> String;
}

/// Per-axis adaptive state.
#[derive(Debug, Clone)]
struct AxisState {
    n: f64,
    eta: f64,
    frozen: Option<u32>,
    /// last observed ⌈N⌉
    prev_ceil: u32,
    /// direction of the last ⌈N⌉ change: -1, +1 (0 = none yet)
    last_dir: i32,
    /// number of direction flips observed
    osc: usize,
    /// the two most recent distinct ⌈N⌉ values (oscillation points)
    osc_points: (u32, u32),
}

impl AxisState {
    fn new(init: f64, eta: f64) -> AxisState {
        let c = init.ceil() as u32;
        AxisState {
            n: init,
            eta,
            // η = 0 means "this axis is not learned" (e.g. the /32 rows
            // of Table I): freeze immediately at the initial ceil.
            frozen: if eta == 0.0 { Some(c) } else { None },
            prev_ceil: c,
            last_dir: 0,
            osc: 0,
            osc_points: (c, c),
        }
    }

    fn ceil(&self) -> u32 {
        match self.frozen {
            Some(k) => k,
            None => self.n.ceil() as u32,
        }
    }

    fn floor(&self) -> u32 {
        (self.n.floor() as u32).max(1)
    }

    /// Apply one gradient step; detect ceil movement + oscillation.
    fn step(&mut self, grad: f64, osc_threshold: usize) {
        if self.frozen.is_some() {
            return;
        }
        self.n = (self.n - self.eta * grad).clamp(1.0, 32.0);
        let c = self.n.ceil() as u32;
        if c != self.prev_ceil {
            let dir = if c > self.prev_ceil { 1 } else { -1 };
            if self.last_dir != 0 && dir != self.last_dir {
                self.osc += 1;
                self.osc_points = (self.prev_ceil, c);
            }
            self.last_dir = dir;
            self.prev_ceil = c;
        }
        if self.osc >= osc_threshold {
            // freeze at the larger of the two oscillation points (Fig. 1)
            let k = self.osc_points.0.max(self.osc_points.1);
            self.frozen = Some(k);
            self.n = k as f64;
        }
    }
}

/// The paper's adaptive controller.
pub struct AdaQatController {
    w: AxisState,
    a: AxisState,
    lambda: f64,
    osc_threshold: usize,
    /// Pluggable L_hard (paper §III-B product by default; the §V
    /// future-work FPGA/energy models live in crate::quant::energy).
    hard: Box<dyn crate::quant::HardCost>,
}

impl AdaQatController {
    /// `eta_* = 0` pins that axis at `ceil(init_*)` for the whole run
    /// (used for the weight-only rows of Table I, A = 32).
    pub fn new(
        init_nw: f64,
        init_na: f64,
        eta_w: f64,
        eta_a: f64,
        lambda: f64,
        osc_threshold: usize,
    ) -> AdaQatController {
        assert!((1.0..=32.0).contains(&init_nw));
        assert!((1.0..=32.0).contains(&init_na));
        AdaQatController {
            w: AxisState::new(init_nw, eta_w),
            a: AxisState::new(init_na, eta_a),
            lambda,
            osc_threshold,
            hard: Box::new(crate::quant::ProductCost),
        }
    }

    /// Swap the hardware-loss model (builder style).
    pub fn with_hard_cost(mut self, hard: Box<dyn crate::quant::HardCost>) -> AdaQatController {
        self.hard = hard;
        self
    }

    /// Paper defaults: η_w = 0.001, η_a = 0.0005, threshold 10 (§III-C).
    pub fn with_defaults(init_nw: f64, init_na: f64, lambda: f64) -> AdaQatController {
        AdaQatController::new(init_nw, init_na, 0.001, 0.0005, lambda, 10)
    }
}

impl Controller for AdaQatController {
    fn bits(&self) -> (u32, u32) {
        (self.w.ceil(), self.a.ceil())
    }

    fn fractional(&self) -> (f64, f64) {
        (self.w.n, self.a.n)
    }

    fn probes(&self) -> Vec<ProbeRequest> {
        let (kw, ka) = self.bits();
        let mut probes = vec![];
        // A floor probe is informative only when ceil != floor; on exact
        // integers the finite difference is zero and the hardware term
        // alone drives the update (paper eq. (3) degenerates cleanly) —
        // EXCEPT at the 1-bit clamp: there the hardware term would pin N
        // at 1.0 forever because no floor exists. We instead issue a
        // *forward* difference probe at k+1 (a deviation from the paper,
        // which never reaches the clamp with its 1e-3 learning rates;
        // documented in DESIGN.md §10).
        if self.w.frozen.is_none() {
            if self.w.floor() != kw {
                probes.push(ProbeRequest {
                    axis: Axis::Weights,
                    k_w: self.w.floor(),
                    k_a: ka,
                    up: false,
                });
            } else if self.w.n <= 1.0 {
                probes.push(ProbeRequest { axis: Axis::Weights, k_w: 2, k_a: ka, up: true });
            }
        }
        if self.a.frozen.is_none() {
            if self.a.floor() != ka {
                probes.push(ProbeRequest {
                    axis: Axis::Activations,
                    k_w: kw,
                    k_a: self.a.floor(),
                    up: false,
                });
            } else if self.a.n <= 1.0 {
                probes.push(ProbeRequest { axis: Axis::Activations, k_w: kw, k_a: 2, up: true });
            }
        }
        probes
    }

    fn update(&mut self, l_cc: f64, probe_losses: &[f64]) {
        let (kw, ka) = self.bits();
        let requests = self.probes();
        assert_eq!(requests.len(), probe_losses.len(), "probe arity mismatch");
        // task-loss finite differences (0 when no probe was needed)
        let mut g_task_w = 0.0;
        let mut g_task_a = 0.0;
        for (req, &l_probe) in requests.iter().zip(probe_losses) {
            // backward: ∂L/∂N ≈ L(⌈N⌉) − L(⌊N⌋); forward (clamp): L(k+1) − L(k)
            let g = if req.up { l_probe - l_cc } else { l_cc - l_probe };
            match req.axis {
                Axis::Weights => g_task_w = g,
                Axis::Activations => g_task_a = g,
            }
        }
        // eq. (3): total gradient = task finite difference + λ·∂L_hard
        // (∂L_hard as an exact one-bit difference of the active cost
        // model; for the paper's product model this is exactly ⌈N_a⌉ /
        // ⌈N_w⌉).
        let g_w = g_task_w + self.lambda * self.hard.grad_w(kw, ka);
        let g_a = g_task_a + self.lambda * self.hard.grad_a(kw, ka);
        self.w.step(g_w, self.osc_threshold);
        self.a.step(g_a, self.osc_threshold);
    }

    fn frozen(&self) -> (bool, bool) {
        (self.w.frozen.is_some(), self.a.frozen.is_some())
    }

    fn osc_counts(&self) -> (usize, usize) {
        (self.w.osc, self.a.osc)
    }

    fn name(&self) -> String {
        format!("adaqat(λ={})", self.lambda)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop::check;

    /// Synthetic task-loss landscape: flat above k*, steep below.
    /// L(k_w, k_a) = exp(kw* − k_w) + exp(ka* − k_a), roughly the shape a
    /// partially-trained network exhibits (test_steps.py measures the
    /// real thing).
    fn task_loss(k_w: u32, k_a: u32, kw_star: f64, ka_star: f64) -> f64 {
        (kw_star - k_w as f64).exp() + (ka_star - k_a as f64).exp()
    }

    /// Drive a controller against the synthetic landscape until both
    /// axes freeze (or `max_iters`).
    fn drive(
        c: &mut AdaQatController,
        kw_star: f64,
        ka_star: f64,
        max_iters: usize,
    ) -> usize {
        for it in 0..max_iters {
            let (kw, ka) = c.bits();
            let l_cc = task_loss(kw, ka, kw_star, ka_star);
            let probe_losses: Vec<f64> = c
                .probes()
                .iter()
                .map(|p| task_loss(p.k_w, p.k_a, kw_star, ka_star))
                .collect();
            c.update(l_cc, &probe_losses);
            if c.frozen() == (true, true) {
                return it;
            }
        }
        max_iters
    }

    #[test]
    fn converges_near_optimum_and_freezes() {
        // larger etas so the test converges in few iterations
        let mut c = AdaQatController::new(8.0, 8.0, 0.05, 0.05, 0.15, 10);
        let iters = drive(&mut c, 3.0, 4.0, 50_000);
        assert!(iters < 50_000, "never froze");
        let (kw, ka) = c.bits();
        assert!((3..=5).contains(&kw), "kw={kw}");
        assert!((4..=6).contains(&ka), "ka={ka}");
        assert!(c.osc_counts().0 >= 10 || c.osc_counts().1 >= 10);
    }

    #[test]
    fn bits_decrease_from_init_before_freezing() {
        let mut c = AdaQatController::new(8.0, 8.0, 0.05, 0.05, 0.15, 10);
        let (kw0, ka0) = c.bits();
        drive(&mut c, 2.0, 3.0, 50_000);
        let (kw, ka) = c.bits();
        assert!(kw < kw0 && ka < ka0, "({kw},{ka}) from ({kw0},{ka0})");
    }

    #[test]
    fn larger_lambda_more_compression() {
        // Table III property: λ↑ ⇒ frozen bit-widths ↓ (weakly)
        let mut frozen_bits = vec![];
        for lambda in [0.05, 0.3, 1.5] {
            let mut c = AdaQatController::new(8.0, 8.0, 0.05, 0.05, lambda, 10);
            drive(&mut c, 3.0, 3.0, 50_000);
            let (kw, ka) = c.bits();
            frozen_bits.push(kw + ka);
        }
        assert!(
            frozen_bits[0] >= frozen_bits[1] && frozen_bits[1] >= frozen_bits[2],
            "{frozen_bits:?}"
        );
    }

    #[test]
    fn eta_zero_pins_axis() {
        // the weight-only rows of Table I: activations stay at 32
        let mut c = AdaQatController::new(8.0, 32.0, 0.05, 0.0, 0.15, 10);
        assert_eq!(c.frozen(), (false, true));
        drive(&mut c, 2.0, 2.0, 50_000);
        let (_, ka) = c.bits();
        assert_eq!(ka, 32);
        // and no activation probes were ever requested
        assert!(c.probes().iter().all(|p| p.axis == Axis::Weights));
    }

    #[test]
    fn frozen_controller_stops_probing_and_moving() {
        let mut c = AdaQatController::new(8.0, 8.0, 0.05, 0.05, 0.15, 10);
        drive(&mut c, 3.0, 3.0, 50_000);
        let bits = c.bits();
        assert!(c.probes().is_empty());
        c.update(99.0, &[]);
        assert_eq!(c.bits(), bits);
    }

    #[test]
    fn integer_n_requests_no_task_probe() {
        let c = AdaQatController::new(8.0, 8.0, 0.05, 0.05, 0.15, 10);
        // N exactly 8.0: ceil == floor == 8 → only hardware force applies
        assert!(c.probes().is_empty());
    }

    #[test]
    fn clamps_to_valid_range() {
        let mut c = AdaQatController::new(1.0, 1.0, 10.0, 10.0, 100.0, 1_000_000);
        for _ in 0..100 {
            let probes: Vec<f64> = c.probes().iter().map(|_| 0.0).collect();
            c.update(0.0, &probes);
            let (nw, na) = c.fractional();
            assert!((1.0..=32.0).contains(&nw));
            assert!((1.0..=32.0).contains(&na));
        }
    }

    #[test]
    fn freeze_picks_larger_oscillation_point() {
        let mut c = AdaQatController::new(4.0, 8.0, 0.2, 0.0, 0.15, 3);
        // Hand-drive N_w across the 3/4 boundary repeatedly: loss favors
        // 4 bits strongly below 4, hardware pushes down above.
        for _ in 0..10_000 {
            let (kw, _) = c.bits();
            let l_cc = task_loss(kw, 32, 4.2, 0.0);
            let probes: Vec<f64> = c
                .probes()
                .iter()
                .map(|p| task_loss(p.k_w, 32, 4.2, 0.0))
                .collect();
            c.update(l_cc, &probes);
            if c.frozen().0 {
                break;
            }
        }
        assert!(c.frozen().0, "never froze");
        let (kw, _) = c.bits();
        // oscillating between 4 and 5 → freeze at the larger = 5
        assert!(kw == 5 || kw == 4, "kw={kw}");
    }

    #[test]
    fn probe_arity_mismatch_panics() {
        let result = std::panic::catch_unwind(|| {
            let mut c = AdaQatController::new(7.5, 7.5, 0.05, 0.05, 0.15, 10);
            c.update(1.0, &[]); // probes() is non-empty for fractional N
        });
        assert!(result.is_err());
    }

    #[test]
    fn property_never_exceeds_bounds_any_landscape() {
        check(100, 13, |rng| {
            let mut c = AdaQatController::new(
                1.0 + 7.0 * rng.uniform() as f64,
                1.0 + 7.0 * rng.uniform() as f64,
                0.1 * rng.uniform() as f64,
                0.1 * rng.uniform() as f64,
                rng.uniform() as f64,
                1 + rng.below(12),
            );
            for _ in 0..300 {
                let l_cc = (rng.uniform() * 5.0) as f64;
                let probes: Vec<f64> = c
                    .probes()
                    .iter()
                    .map(|_| (rng.uniform() * 5.0) as f64)
                    .collect();
                c.update(l_cc, &probes);
                let (kw, ka) = c.bits();
                prop_assert!((1..=32).contains(&kw), "kw out of range: {kw}");
                prop_assert!((1..=32).contains(&ka), "ka out of range: {ka}");
                let (fw, fa) = c.frozen();
                if fw && fa {
                    break;
                }
            }
            Ok(())
        });
    }
}

#[cfg(test)]
mod clamp_tests {
    use super::*;

    /// Landscape where 1-bit is catastrophic: the controller must escape
    /// the 1-bit clamp via the forward probe and oscillate around 2.
    #[test]
    fn clamp_release_probe_escapes_one_bit() {
        let mut c = AdaQatController::new(1.0, 8.0, 0.2, 0.0, 0.15, 1000);
        // at the clamp, an up-probe must be requested
        let probes = c.probes();
        assert_eq!(probes.len(), 1);
        assert!(probes[0].up);
        assert_eq!(probes[0].k_w, 2);
        // 1-bit loss 5.0 vs 2-bit loss 0.5 → strong upward pressure
        let l = |k: u32| if k <= 1 { 5.0 } else { 0.5 / k as f64 };
        for _ in 0..50 {
            let (kw, _) = c.bits();
            let pl: Vec<f64> = c.probes().iter().map(|p| l(p.k_w)).collect();
            c.update(l(kw), &pl);
        }
        let (nw, _) = c.fractional();
        assert!(nw > 1.0, "stuck at the clamp: N_w = {nw}");
    }

    #[test]
    fn clamp_trap_oscillates_and_freezes() {
        // steep below 2, hardware pushes down: expect oscillation around
        // the 1/2 boundary and an eventual freeze at 2 (larger point).
        let mut c = AdaQatController::new(3.0, 8.0, 0.25, 0.0, 0.3, 4);
        let l = |k: u32| if k <= 1 { 6.0 } else { 0.2 };
        for _ in 0..10_000 {
            let (kw, _) = c.bits();
            let pl: Vec<f64> = c.probes().iter().map(|p| l(p.k_w)).collect();
            c.update(l(kw), &pl);
            if c.frozen().0 {
                break;
            }
        }
        assert!(c.frozen().0, "never froze: N_w = {}", c.fractional().0);
        let (kw, _) = c.bits();
        // the larger oscillation point: 2 (1↔2 bouncing) or 3 if the
        // rebound overshoots the 2-boundary before falling back
        assert!(kw == 2 || kw == 3, "froze at {kw}");
    }
}

//! Baseline bit-width policies the paper compares against (Table I):
//!
//! * [`FixedController`] — static uniform bit-widths; reproduces the
//!   DoReFa/PACT/LQ-Net-style rows (e.g. 2/32, 4/4) when paired with the
//!   same quantized training graph.
//! * [`FracBitsController`] — a FracBits-style *scheduled* relaxation:
//!   fractional bit-widths anneal linearly from init to target over a
//!   warm-up fraction of the run, then stay fixed. FracBits proper
//!   learns the relaxation with a gradient; our comparator reproduces
//!   the schedule *shape* (gradual fractional descent, no oscillation
//!   phase) which is what the Table I comparison exercises — documented
//!   as a shape-level comparator in DESIGN.md §5.

use super::{Controller, ProbeRequest};

/// Static bit-widths (k ≥ 24 ⇒ treated as unquantized 32-bit signals).
pub struct FixedController {
    k_w: u32,
    k_a: u32,
}

impl FixedController {
    pub fn new(k_w: u32, k_a: u32) -> FixedController {
        assert!((1..=32).contains(&k_w) && (1..=32).contains(&k_a));
        FixedController { k_w, k_a }
    }
}

impl Controller for FixedController {
    fn bits(&self) -> (u32, u32) {
        (self.k_w, self.k_a)
    }

    fn fractional(&self) -> (f64, f64) {
        (self.k_w as f64, self.k_a as f64)
    }

    fn probes(&self) -> Vec<ProbeRequest> {
        vec![]
    }

    fn update(&mut self, _l_cc: f64, probe_losses: &[f64]) {
        debug_assert!(probe_losses.is_empty());
    }

    fn frozen(&self) -> (bool, bool) {
        (true, true)
    }

    fn name(&self) -> String {
        format!("fixed({}/{})", self.k_w, self.k_a)
    }
}

/// Linear annealing from `init` to `target` over `anneal_updates` calls.
pub struct FracBitsController {
    n_w: f64,
    n_a: f64,
    target_w: f64,
    target_a: f64,
    step_w: f64,
    step_a: f64,
    updates_left: usize,
}

impl FracBitsController {
    pub fn new(
        init_nw: f64,
        init_na: f64,
        target_w: u32,
        target_a: u32,
        anneal_updates: usize,
    ) -> FracBitsController {
        let n = anneal_updates.max(1) as f64;
        FracBitsController {
            n_w: init_nw,
            n_a: init_na,
            target_w: target_w as f64,
            target_a: target_a as f64,
            step_w: (init_nw - target_w as f64) / n,
            step_a: (init_na - target_a as f64) / n,
            updates_left: anneal_updates.max(1),
        }
    }
}

impl Controller for FracBitsController {
    fn bits(&self) -> (u32, u32) {
        (self.n_w.ceil() as u32, self.n_a.ceil() as u32)
    }

    fn fractional(&self) -> (f64, f64) {
        (self.n_w, self.n_a)
    }

    fn probes(&self) -> Vec<ProbeRequest> {
        vec![]
    }

    fn update(&mut self, _l_cc: f64, _probe_losses: &[f64]) {
        if self.updates_left == 0 {
            return;
        }
        self.updates_left -= 1;
        self.n_w = (self.n_w - self.step_w).max(self.target_w);
        self.n_a = (self.n_a - self.step_a).max(self.target_a);
        if self.updates_left == 0 {
            self.n_w = self.target_w;
            self.n_a = self.target_a;
        }
    }

    fn frozen(&self) -> (bool, bool) {
        (self.updates_left == 0, self.updates_left == 0)
    }

    fn name(&self) -> String {
        format!("fracbits(→{}/{})", self.target_w, self.target_a)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fixed_never_moves() {
        let mut c = FixedController::new(2, 32);
        assert_eq!(c.bits(), (2, 32));
        assert!(c.probes().is_empty());
        c.update(5.0, &[]);
        assert_eq!(c.bits(), (2, 32));
        assert_eq!(c.frozen(), (true, true));
    }

    #[test]
    #[should_panic]
    fn fixed_rejects_zero_bits() {
        FixedController::new(0, 4);
    }

    #[test]
    fn fracbits_anneals_monotonically_to_target() {
        let mut c = FracBitsController::new(8.0, 8.0, 3, 4, 20);
        let mut prev = c.fractional();
        for _ in 0..25 {
            c.update(0.0, &[]);
            let cur = c.fractional();
            assert!(cur.0 <= prev.0 + 1e-12 && cur.1 <= prev.1 + 1e-12);
            prev = cur;
        }
        assert_eq!(c.bits(), (3, 4));
        assert_eq!(c.frozen(), (true, true));
    }

    #[test]
    fn fracbits_exact_landing() {
        let mut c = FracBitsController::new(8.0, 8.0, 2, 2, 7);
        for _ in 0..7 {
            assert_eq!(c.frozen(), (false, false));
            c.update(0.0, &[]);
        }
        assert_eq!(c.fractional(), (2.0, 2.0));
    }
}

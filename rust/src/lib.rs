//! # AdaQAT — Adaptive Bit-Width Quantization-Aware Training
//!
//! Full-system reproduction of *AdaQAT* (Gernigon et al., 2024) as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! * **Layer 1/2 (build time)** — Pallas quantizer kernels + JAX model
//!   graphs, AOT-lowered to HLO text by `python/compile/aot.py`.
//! * **Layer 3 (this crate)** — the coordinator that *is* the paper's
//!   contribution: the adaptive bit-width controller ([`adaqat`]), the
//!   training orchestrator ([`train`]), the synthetic data pipeline
//!   ([`data`]), the hardware cost model ([`quant`]), the PJRT
//!   runtime ([`runtime`]) that executes the compiled artifacts, the
//!   pure-Rust training backend ([`backprop`]) that runs the same
//!   experiments offline with no artifacts at all, the
//!   quantized-inference serving subsystem ([`serve`]) that turns a
//!   finished run into a batched TCP service, and the integer-domain
//!   quantized kernel engine ([`kernels`]) that makes the learned
//!   bit-widths buy actual compute, not just disk bytes. Python never
//!   runs on the training or serving paths.
//!
//! See DESIGN.md for the system inventory and EXPERIMENTS.md for the
//! paper-vs-measured record.

pub mod adaqat;
pub mod backprop;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod kernels;
pub mod metrics;
pub mod obs;
pub mod quant;
pub mod runtime;
pub mod serve;
pub mod tensor;
pub mod train;
pub mod util;

//! Training orchestrator: epochs, LR schedule, controller probes,
//! evaluation, checkpointing, and per-step tracing.
//!
//! This is where the layers meet at runtime: batches stream in from the
//! data pipeline's prefetch thread, a [`StepBackend`] executes the train
//! step — the compiled HLO graphs on PJRT, or the pure-Rust
//! [`crate::backprop`] backend — and the AdaQAT controller steers the
//! bit-widths between steps (paper §III-C). The trainer is generic over
//! both [`Controller`] and [`StepBackend`], so AdaQAT, the Table I
//! baselines, and every backend run through the exact same loop.

pub mod schedule;

use std::path::Path;
use std::time::Instant;

use crate::adaqat::Controller;
use crate::config::ExperimentConfig;
use crate::data::loader::Loader;
use crate::quant::CostModel;
use crate::runtime::{StepBackend, StepMetrics, TrainState};
use crate::tensor::checkpoint::Checkpoint;
use crate::util::json::Json;

use schedule::CosineSchedule;

/// One row of the per-probe trace (drives Fig. 1).
#[derive(Debug, Clone)]
pub struct TraceRecord {
    pub step: usize,
    pub n_w: f64,
    pub n_a: f64,
    pub k_w: u32,
    pub k_a: u32,
    pub train_loss: f64,
    pub train_acc: f64,
    pub osc_w: usize,
    pub osc_a: usize,
}

/// One row of the per-epoch record.
#[derive(Debug, Clone)]
pub struct EpochRecord {
    pub epoch: usize,
    pub lr: f64,
    pub train_loss: f64,
    pub train_acc: f64,
    pub test_loss: f64,
    pub test_acc: f64,
    pub k_w: u32,
    pub k_a: u32,
}

/// Everything a finished run reports (consumed by the bench harnesses).
#[derive(Debug, Clone)]
pub struct RunResult {
    pub final_bits: (u32, u32),
    pub test_top1: f64,
    pub test_loss: f64,
    pub wcr: f64,
    pub bitops_g: f64,
    pub epochs: Vec<EpochRecord>,
    pub trace: Vec<TraceRecord>,
    pub wall_seconds: f64,
    pub steps: usize,
    /// Mean wall time of one train step (the §Perf headline).
    pub step_seconds: f64,
}

/// Train `state` under `cfg` with the given controller; returns the run
/// record. `train`/`test` loaders must match the backend's batch size.
pub fn train(
    backend: &dyn StepBackend,
    cfg: &ExperimentConfig,
    controller: &mut dyn Controller,
    state: &mut TrainState,
    train_loader: &Loader,
    test_loader: &Loader,
) -> anyhow::Result<RunResult> {
    let t0 = Instant::now();
    let steps_per_epoch = train_loader.batches_per_epoch();
    let sched = CosineSchedule::new(cfg.lr, cfg.epochs * steps_per_epoch);
    let cost = CostModel::from_manifest(backend.mm());
    let batch_size = backend.mm().batch;

    // Controller trajectory in the same registry the serving side uses
    // (DESIGN.md §15): live bit-width/oscillation gauges per axis plus
    // probe/freeze counters, updated at every probe. The coordinator
    // dumps the registry next to trace.csv, so a run's final exposition
    // carries the trajectory endpoint alongside the serving series.
    let reg = crate::obs::global();
    let bits_g = [
        reg.gauge("adaqat_train_bits", &[("axis", "w")]),
        reg.gauge("adaqat_train_bits", &[("axis", "a")]),
    ];
    let frac_g = [
        reg.gauge("adaqat_train_frac_bits", &[("axis", "w")]),
        reg.gauge("adaqat_train_frac_bits", &[("axis", "a")]),
    ];
    let osc_g = [
        reg.gauge("adaqat_train_osc", &[("axis", "w")]),
        reg.gauge("adaqat_train_osc", &[("axis", "a")]),
    ];
    let freezes_c = [
        reg.counter("adaqat_train_freezes_total", &[("axis", "w")]),
        reg.counter("adaqat_train_freezes_total", &[("axis", "a")]),
    ];
    let probes_c = reg.counter("adaqat_train_probes_total", &[]);
    let mut was_frozen = controller.frozen();

    let mut epochs = vec![];
    let mut trace = vec![];
    let mut step = 0usize;
    let mut step_time = 0.0f64;

    for epoch in 0..cfg.epochs {
        // the LR this epoch *starts* at — recorded in the epoch row
        // (reading the schedule after the loop would report the next
        // epoch's first-step LR, a value no step this epoch used)
        let epoch_lr = sched.lr(step);
        let mut ep_loss = 0.0f64;
        let mut ep_correct = 0.0f64;
        let mut ep_batches = 0usize;
        let rx = train_loader.epoch_prefetch(cfg.seed ^ (epoch as u64) << 32);
        for batch in rx.iter() {
            let lr = sched.lr(step) as f32;
            let (k_w, k_a) = controller.bits();
            let ts = Instant::now();
            let m = backend.train_step(state, &batch, lr, k_w, k_a, cfg.fp32)?;
            step_time += ts.elapsed().as_secs_f64();
            anyhow::ensure!(
                m.loss.is_finite(),
                "training diverged at step {step} (loss = {})",
                m.loss
            );
            ep_loss += m.loss as f64;
            ep_correct += m.correct as f64;
            ep_batches += 1;

            // ---- AdaQAT probe: finite differences on the SAME batch
            let frozen = controller.frozen();
            if !cfg.fp32 && !(frozen.0 && frozen.1) && step % cfg.probe_interval == 0 {
                let requests = controller.probes();
                let mut probe_losses = Vec::with_capacity(requests.len());
                for p in &requests {
                    let pm = backend.probe_loss(state, &batch, p.k_w, p.k_a)?;
                    probe_losses.push(pm.loss as f64);
                }
                controller.update(m.loss as f64, &probe_losses);
                let (n_w, n_a) = controller.fractional();
                let (k_w2, k_a2) = controller.bits();
                let (osc_w, osc_a) = controller.osc_counts();
                trace.push(TraceRecord {
                    step,
                    n_w,
                    n_a,
                    k_w: k_w2,
                    k_a: k_a2,
                    train_loss: m.loss as f64,
                    train_acc: m.correct as f64 / batch_size as f64,
                    osc_w,
                    osc_a,
                });
                probes_c.inc();
                bits_g[0].set(k_w2 as f64);
                bits_g[1].set(k_a2 as f64);
                frac_g[0].set(n_w);
                frac_g[1].set(n_a);
                osc_g[0].set(osc_w as f64);
                osc_g[1].set(osc_a as f64);
                let frozen_now = controller.frozen();
                if frozen_now.0 && !was_frozen.0 {
                    freezes_c[0].inc();
                }
                if frozen_now.1 && !was_frozen.1 {
                    freezes_c[1].inc();
                }
                was_frozen = frozen_now;
            }
            step += 1;
        }

        let (test_loss, test_acc) =
            evaluate(backend, state, test_loader, controller, cfg.fp32)?;
        let (k_w, k_a) = controller.bits();
        let rec = EpochRecord {
            epoch,
            lr: epoch_lr,
            train_loss: ep_loss / ep_batches.max(1) as f64,
            train_acc: ep_correct / (ep_batches.max(1) * batch_size) as f64,
            test_loss,
            test_acc,
            k_w,
            k_a,
        };
        log::info!(
            "epoch {epoch}: train loss {:.4} acc {:.3} | test loss {:.4} acc {:.3} | bits {}/{} (N={:.2}/{:.2}) osc {:?}",
            rec.train_loss, rec.train_acc, rec.test_loss, rec.test_acc,
            k_w, k_a, controller.fractional().0, controller.fractional().1,
            controller.osc_counts(),
        );
        epochs.push(rec);
    }

    let (k_w, k_a) = controller.bits();
    let last = epochs.last();
    Ok(RunResult {
        final_bits: (k_w, k_a),
        test_top1: last.map(|e| e.test_acc).unwrap_or(0.0),
        test_loss: last.map(|e| e.test_loss).unwrap_or(f64::NAN),
        wcr: if cfg.fp32 { 1.0 } else { cost.wcr(k_w) },
        bitops_g: if cfg.fp32 {
            cost.bitops_g(32, 32)
        } else {
            cost.bitops_g(k_w, k_a)
        },
        epochs,
        trace,
        wall_seconds: t0.elapsed().as_secs_f64(),
        steps: step,
        step_seconds: if step > 0 { step_time / step as f64 } else { 0.0 },
    })
}

/// Run the eval pass over the whole test loader; returns (loss, top-1).
pub fn evaluate(
    backend: &dyn StepBackend,
    state: &TrainState,
    test_loader: &Loader,
    controller: &dyn Controller,
    fp32: bool,
) -> anyhow::Result<(f64, f64)> {
    let (k_w, k_a) = controller.bits();
    let mut loss = 0.0f64;
    let mut correct = 0.0f64;
    let mut batches = 0usize;
    for batch in test_loader.epoch(0) {
        let m: StepMetrics = backend.eval_batch(state, &batch, k_w, k_a, fp32)?;
        loss += m.loss as f64;
        correct += m.correct as f64;
        batches += 1;
    }
    let n = (batches * backend.mm().batch) as f64;
    Ok((loss / batches.max(1) as f64, correct / n.max(1.0)))
}

/// Save model parameters + BN stats under their manifest names.
pub fn save_checkpoint(
    backend: &dyn StepBackend,
    state: &TrainState,
    meta: Json,
    path: &Path,
) -> anyhow::Result<()> {
    let mut ck = Checkpoint::new(meta);
    for (spec, t) in backend.mm().params.iter().zip(&state.params) {
        ck.push(spec.name.clone(), t.clone());
    }
    for (spec, t) in backend.mm().bn.iter().zip(&state.bn) {
        ck.push(spec.name.clone(), t.clone());
    }
    ck.save(path)?;
    log::info!("saved checkpoint to {path:?}");
    Ok(())
}

/// Write the probe trace as CSV (Fig. 1 raw data).
pub fn save_trace(trace: &[TraceRecord], path: &Path) -> anyhow::Result<()> {
    let mut w = crate::metrics::CsvWriter::create(
        path,
        &["step", "n_w", "n_a", "k_w", "k_a", "train_loss", "train_acc", "osc_w", "osc_a"],
    )?;
    for t in trace {
        w.row(&[
            t.step.to_string(),
            format!("{:.4}", t.n_w),
            format!("{:.4}", t.n_a),
            t.k_w.to_string(),
            t.k_a.to_string(),
            format!("{:.5}", t.train_loss),
            format!("{:.4}", t.train_acc),
            t.osc_w.to_string(),
            t.osc_a.to_string(),
        ])?;
    }
    Ok(())
}

//! Learning-rate schedules (paper §IV-A: cosine annealing).

/// Cosine annealing from `lr0` to 0 over `total` steps (paper §IV-A).
#[derive(Debug, Clone, Copy)]
pub struct CosineSchedule {
    pub lr0: f64,
    pub total: usize,
}

impl CosineSchedule {
    pub fn new(lr0: f64, total: usize) -> CosineSchedule {
        assert!(total > 0);
        CosineSchedule { lr0, total }
    }

    pub fn lr(&self, step: usize) -> f64 {
        let t = (step.min(self.total)) as f64 / self.total as f64;
        self.lr0 * 0.5 * (1.0 + (std::f64::consts::PI * t).cos())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn endpoints_and_midpoint() {
        let s = CosineSchedule::new(0.1, 100);
        assert!((s.lr(0) - 0.1).abs() < 1e-12);
        assert!((s.lr(50) - 0.05).abs() < 1e-12);
        assert!(s.lr(100) < 1e-12);
        assert!(s.lr(200) < 1e-12); // clamped past the end
    }

    #[test]
    fn monotone_decreasing() {
        let s = CosineSchedule::new(0.1, 64);
        for i in 1..=64 {
            assert!(s.lr(i) <= s.lr(i - 1) + 1e-15);
        }
    }
}

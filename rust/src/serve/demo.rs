//! Demo models over the synthetic datasets (DESIGN.md §7).
//!
//! Serving needs models whose artifact chain runs in the offline build,
//! where PJRT execution is stubbed (DESIGN.md §3). Two of them:
//!
//! * [`demo_checkpoint`] — nearest-centroid linear classifier:
//!   `argmin_c ‖x − μ_c‖² = argmax_c μ_c·x − ½‖μ_c‖²` fits the legacy
//!   single-`fc` contract exactly, and the synthetic classes carry
//!   enough linear signal (color triple, blob position) that
//!   predictions are far above chance.
//! * [`demo_mlp_checkpoint`] — a genuine 2-layer ReLU MLP for the
//!   integer kernel engine (`crate::kernels`): fc1 is a *mirrored*
//!   random projection `[R; −R]` (so `relu(Rx) − relu(−Rx) = Rx` is
//!   linearly recoverable through the nonlinearity), fc2 scores the
//!   class centroids in the projected space. The model exercises two
//!   packed GEMMs, ReLU and per-layer activation quantization while
//!   keeping the centroid classifier's above-chance accuracy (up to
//!   the random projection's distortion) — the end-to-end demo serves
//!   *meaningful* answers, not noise.
//!
//! [`ReferenceBackend`]: super::engine::ReferenceBackend

use crate::data::{synth, DatasetKind};
use crate::tensor::checkpoint::Checkpoint;
use crate::tensor::Tensor;
use crate::util::json::Json;
use crate::util::rng::Rng;

use super::engine::ReferenceBackend;

/// Build the demo checkpoint: `fc.w` ([d, classes]) holds the class
/// centroids of `per_class` training samples per class, `fc.b` the
/// −½‖μ_c‖² offsets; meta carries everything the reference backend
/// needs (`input_hw`, `in_channels`, `num_classes`, `serve_batch`).
pub fn demo_checkpoint(
    kind: DatasetKind,
    per_class: usize,
    seed: u64,
    serve_batch: usize,
) -> Checkpoint {
    assert!(per_class > 0 && serve_batch > 0);
    let nc = kind.num_classes();
    let n = per_class * nc;
    let ds = synth::generate(kind, n, seed, 0);
    let d = ds.sample_numel();

    let mut sums = vec![0.0f64; nc * d];
    for i in 0..n {
        let c = ds.labels[i] as usize;
        let row = &mut sums[c * d..(c + 1) * d];
        for (j, &p) in ds.image(i).iter().enumerate() {
            row[j] += p as f64;
        }
    }
    let mut w = vec![0.0f32; d * nc];
    let mut b = vec![0.0f32; nc];
    for c in 0..nc {
        let mut norm2 = 0.0f64;
        for j in 0..d {
            let mu = sums[c * d + j] / per_class as f64;
            w[j * nc + c] = mu as f32;
            norm2 += mu * mu;
        }
        b[c] = (-0.5 * norm2) as f32;
    }

    let dataset = match kind {
        DatasetKind::Cifar10 => "cifar10",
        DatasetKind::ImagenetLite => "imagenet-lite",
    };
    let mut ck = Checkpoint::new(Json::obj(vec![
        ("model", Json::str("demo-linear")),
        ("dataset", Json::str(dataset)),
        ("input_hw", Json::Arr(vec![Json::num(ds.h as f64), Json::num(ds.w as f64)])),
        ("in_channels", Json::num(ds.c as f64)),
        ("num_classes", Json::num(nc as f64)),
        ("serve_batch", Json::num(serve_batch as f64)),
        ("k_a", Json::num(32.0)),
        ("train_per_class", Json::num(per_class as f64)),
        ("seed", Json::num(seed as f64)),
    ]));
    ck.push("fc.w", Tensor::new(vec![d, nc], w));
    ck.push("fc.b", Tensor::new(vec![nc], b));
    ck
}

/// Gain of the random-feature block in the demo MLP's second layer —
/// real signal flowing through every hidden unit, small enough that the
/// centroid-pair block keeps the model at the linear demo's accuracy.
const MLP_DISTRACTOR_GAIN: f32 = 0.3;

/// Build the 2-layer demo MLP (`mlp_layers = ["fc1", "fc2"]`, ReLU
/// between). `fc1.w` ([d, hidden], hidden = 2m) is a *mirrored* bank
/// `[B; −B]`: the first `classes` rows of B are the class centroids
/// μ_c, the rest random features ~ N(0, 1/d). Mirroring makes every
/// pre-ReLU signal linearly recoverable — `relu(b·x) − relu(−b·x) =
/// b·x` — so `fc2` reconstructs the exact nearest-centroid score
/// `μ_c·x − ½‖μ_c‖²` from the centroid pairs while mixing in the
/// random-feature pairs' class means at [`MLP_DISTRACTOR_GAIN`]. The
/// result is a genuine ReLU MLP (two packed GEMMs, nonlinearity,
/// per-layer activation quantization at `k_a`) that still classifies at
/// the linear demo's accuracy instead of drowning it in projection
/// noise. Meta carries `mlp_layers` plus everything the reference
/// backend needs.
pub fn demo_mlp_checkpoint(
    kind: DatasetKind,
    hidden: usize,
    per_class: usize,
    seed: u64,
    serve_batch: usize,
    k_a: u32,
) -> Checkpoint {
    assert!(per_class > 0 && serve_batch > 0);
    let nc = kind.num_classes();
    assert!(
        hidden % 2 == 0 && hidden >= 2 * nc,
        "hidden must be even and >= 2*num_classes, got {hidden} for {nc} classes"
    );
    let m = hidden / 2;
    let n = per_class * nc;
    let ds = synth::generate(kind, n, seed, 0);
    let d = ds.sample_numel();

    // class centroids μ_c
    let mut sums = vec![0.0f64; nc * d];
    for i in 0..n {
        let c = ds.labels[i] as usize;
        let row = &mut sums[c * d..(c + 1) * d];
        for (j, &p) in ds.image(i).iter().enumerate() {
            row[j] += p as f64;
        }
    }
    // feature bank B (m×d): centroid rows, then random features
    let mut rng = Rng::new(seed ^ 0x5EED_F00D);
    let sd = 1.0 / (d as f32).sqrt();
    let mut bank = vec![0.0f32; m * d];
    for c in 0..nc {
        for i in 0..d {
            bank[c * d + i] = (sums[c * d + i] / per_class as f64) as f32;
        }
    }
    for v in bank[nc * d..].iter_mut() {
        *v = rng.normal() * sd;
    }

    // fc1 = [B; −B] in the checkpoint's [d, hidden] layout
    let mut w1 = vec![0.0f32; d * hidden];
    for j in 0..m {
        for i in 0..d {
            w1[i * hidden + j] = bank[j * d + i];
            w1[i * hidden + m + j] = -bank[j * d + i];
        }
    }

    // class means of the random-feature hidden units over the train set
    // (the mirrored layout means unit j fires relu(b_j·x), unit m+j
    // fires relu(−b_j·x))
    let mut hsum = vec![0.0f64; nc * hidden];
    for i in 0..n {
        let c = ds.labels[i] as usize;
        let x = ds.image(i);
        for j in nc..m {
            let mut dot = 0.0f64;
            for (xi, bi) in x.iter().zip(&bank[j * d..(j + 1) * d]) {
                dot += *xi as f64 * *bi as f64;
            }
            hsum[c * hidden + j] += dot.max(0.0);
            hsum[c * hidden + m + j] += (-dot).max(0.0);
        }
    }

    // fc2: exact centroid-score reconstruction on the first nc pairs,
    // γ-scaled hidden-space class means on the random-feature pairs
    let g = MLP_DISTRACTOR_GAIN as f64;
    let mut w2 = vec![0.0f32; hidden * nc];
    let mut b2 = vec![0.0f32; nc];
    for c in 0..nc {
        w2[c * nc + c] = 1.0;
        w2[(m + c) * nc + c] = -1.0;
        let mut norm2 = 0.0f64;
        for i in 0..d {
            let mu = sums[c * d + i] / per_class as f64;
            norm2 += mu * mu;
        }
        let mut blk2 = 0.0f64;
        for j in nc..m {
            for &jj in &[j, m + j] {
                let hc = hsum[c * hidden + jj] / per_class as f64;
                w2[jj * nc + c] = (g * hc) as f32;
                blk2 += hc * hc;
            }
        }
        b2[c] = (-0.5 * norm2 - 0.5 * g * blk2) as f32;
    }

    let dataset = match kind {
        DatasetKind::Cifar10 => "cifar10",
        DatasetKind::ImagenetLite => "imagenet-lite",
    };
    let mut ck = Checkpoint::new(Json::obj(vec![
        ("model", Json::str("demo-mlp")),
        ("dataset", Json::str(dataset)),
        (
            "mlp_layers",
            Json::Arr(vec![Json::str("fc1"), Json::str("fc2")]),
        ),
        ("input_hw", Json::Arr(vec![Json::num(ds.h as f64), Json::num(ds.w as f64)])),
        ("in_channels", Json::num(ds.c as f64)),
        ("num_classes", Json::num(nc as f64)),
        ("serve_batch", Json::num(serve_batch as f64)),
        ("hidden", Json::num(hidden as f64)),
        ("k_a", Json::num(k_a as f64)),
        ("train_per_class", Json::num(per_class as f64)),
        ("seed", Json::num(seed as f64)),
    ]));
    ck.push("fc1.w", Tensor::new(vec![d, hidden], w1));
    ck.push("fc1.b", Tensor::new(vec![hidden], vec![0.0; hidden]));
    ck.push("fc2.w", Tensor::new(vec![hidden, nc], w2));
    ck.push("fc2.b", Tensor::new(vec![nc], b2));
    ck
}

/// Top-1 accuracy of a backend on a fresh synthetic *test* split.
pub fn demo_accuracy(
    backend: &ReferenceBackend,
    kind: DatasetKind,
    n: usize,
    seed: u64,
) -> f64 {
    let ds = synth::generate(kind, n, seed, 1);
    let correct = (0..n)
        .filter(|&i| backend.classify_one(ds.image(i)) == ds.labels[i] as usize)
        .count();
    correct as f64 / n as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::engine::Backend;
    use crate::serve::packed::QuantizedCheckpoint;

    #[test]
    fn deterministic_and_well_formed() {
        let a = demo_checkpoint(DatasetKind::Cifar10, 4, 3, 8);
        let b = demo_checkpoint(DatasetKind::Cifar10, 4, 3, 8);
        assert_eq!(a.tensors, b.tensors);
        assert_eq!(a.tensors[0].1.shape, vec![32 * 32 * 3, 10]);
        assert_eq!(a.tensors[1].1.shape, vec![10]);
        assert_eq!(a.meta.get("serve_batch").unwrap().as_usize(), Some(8));
    }

    #[test]
    fn beats_chance_even_after_4bit_packing() {
        let ck = demo_checkpoint(DatasetKind::Cifar10, 16, 1, 8);
        let q = QuantizedCheckpoint::from_checkpoint(&ck, 4, |n| n.ends_with(".w"));
        let backend = ReferenceBackend::from_packed(&q).unwrap();
        let acc = demo_accuracy(&backend, DatasetKind::Cifar10, 200, 11);
        assert!(acc > 0.2, "4-bit demo accuracy only {acc}");
    }

    #[test]
    fn mlp_demo_is_deterministic_well_formed_and_beats_chance() {
        let a = demo_mlp_checkpoint(DatasetKind::Cifar10, 128, 8, 2, 8, 8);
        let b = demo_mlp_checkpoint(DatasetKind::Cifar10, 128, 8, 2, 8, 8);
        assert_eq!(a.tensors, b.tensors);
        assert_eq!(a.tensors[0].1.shape, vec![32 * 32 * 3, 128]);
        assert_eq!(a.tensors[2].1.shape, vec![128, 10]);
        // mirrored projection: column m+j is the negation of column j
        let w1 = &a.tensors[0].1;
        assert_eq!(w1.data[0 * 128 + 64], -w1.data[0 * 128 + 0]);

        // 8-bit pack + integer kernels keep the linear demo's accuracy
        // (the centroid pairs reconstruct its scores through the ReLU)
        let q = QuantizedCheckpoint::from_checkpoint(&a, 8, |n| n.ends_with(".w"));
        let backend = ReferenceBackend::from_packed(&q).unwrap();
        let acc = demo_accuracy(&backend, DatasetKind::Cifar10, 200, 12);
        assert!(acc > 0.3, "8-bit MLP demo accuracy only {acc}");
    }

    #[test]
    fn hundred_class_variant_works() {
        let ck = demo_checkpoint(DatasetKind::ImagenetLite, 2, 5, 4);
        let q = QuantizedCheckpoint::from_checkpoint(&ck, 8, |n| n.ends_with(".w"));
        let backend = ReferenceBackend::from_packed(&q).unwrap();
        assert_eq!(backend.num_classes(), 100);
        let acc = demo_accuracy(&backend, DatasetKind::ImagenetLite, 200, 2);
        assert!(acc > 0.03, "100-class accuracy only {acc}");
    }
}

//! Nearest-centroid demo model over the synthetic datasets
//! (DESIGN.md §7).
//!
//! Serving needs a model whose artifact chain runs in the offline build,
//! where PJRT execution is stubbed (DESIGN.md §3). A nearest-centroid
//! classifier is linear — `argmin_c ‖x − μ_c‖² = argmax_c μ_c·x −
//! ½‖μ_c‖²` — so it fits the [`ReferenceBackend`]'s `fc.w`/`fc.b`
//! contract exactly, and the synthetic classes carry enough linear
//! signal (color triple, blob position) that predictions are far above
//! chance: the end-to-end demo serves *meaningful* answers, not noise.
//!
//! [`ReferenceBackend`]: super::engine::ReferenceBackend

use crate::data::{synth, DatasetKind};
use crate::tensor::checkpoint::Checkpoint;
use crate::tensor::Tensor;
use crate::util::json::Json;

use super::engine::ReferenceBackend;

/// Build the demo checkpoint: `fc.w` ([d, classes]) holds the class
/// centroids of `per_class` training samples per class, `fc.b` the
/// −½‖μ_c‖² offsets; meta carries everything the reference backend
/// needs (`input_hw`, `in_channels`, `num_classes`, `serve_batch`).
pub fn demo_checkpoint(
    kind: DatasetKind,
    per_class: usize,
    seed: u64,
    serve_batch: usize,
) -> Checkpoint {
    assert!(per_class > 0 && serve_batch > 0);
    let nc = kind.num_classes();
    let n = per_class * nc;
    let ds = synth::generate(kind, n, seed, 0);
    let d = ds.sample_numel();

    let mut sums = vec![0.0f64; nc * d];
    for i in 0..n {
        let c = ds.labels[i] as usize;
        let row = &mut sums[c * d..(c + 1) * d];
        for (j, &p) in ds.image(i).iter().enumerate() {
            row[j] += p as f64;
        }
    }
    let mut w = vec![0.0f32; d * nc];
    let mut b = vec![0.0f32; nc];
    for c in 0..nc {
        let mut norm2 = 0.0f64;
        for j in 0..d {
            let mu = sums[c * d + j] / per_class as f64;
            w[j * nc + c] = mu as f32;
            norm2 += mu * mu;
        }
        b[c] = (-0.5 * norm2) as f32;
    }

    let dataset = match kind {
        DatasetKind::Cifar10 => "cifar10",
        DatasetKind::ImagenetLite => "imagenet-lite",
    };
    let mut ck = Checkpoint::new(Json::obj(vec![
        ("model", Json::str("demo-linear")),
        ("dataset", Json::str(dataset)),
        ("input_hw", Json::Arr(vec![Json::num(ds.h as f64), Json::num(ds.w as f64)])),
        ("in_channels", Json::num(ds.c as f64)),
        ("num_classes", Json::num(nc as f64)),
        ("serve_batch", Json::num(serve_batch as f64)),
        ("k_a", Json::num(32.0)),
        ("train_per_class", Json::num(per_class as f64)),
        ("seed", Json::num(seed as f64)),
    ]));
    ck.push("fc.w", Tensor::new(vec![d, nc], w));
    ck.push("fc.b", Tensor::new(vec![nc], b));
    ck
}

/// Top-1 accuracy of a backend on a fresh synthetic *test* split.
pub fn demo_accuracy(
    backend: &ReferenceBackend,
    kind: DatasetKind,
    n: usize,
    seed: u64,
) -> f64 {
    let ds = synth::generate(kind, n, seed, 1);
    let correct = (0..n)
        .filter(|&i| backend.classify_one(ds.image(i)) == ds.labels[i] as usize)
        .count();
    correct as f64 / n as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::engine::Backend;
    use crate::serve::packed::QuantizedCheckpoint;

    #[test]
    fn deterministic_and_well_formed() {
        let a = demo_checkpoint(DatasetKind::Cifar10, 4, 3, 8);
        let b = demo_checkpoint(DatasetKind::Cifar10, 4, 3, 8);
        assert_eq!(a.tensors, b.tensors);
        assert_eq!(a.tensors[0].1.shape, vec![32 * 32 * 3, 10]);
        assert_eq!(a.tensors[1].1.shape, vec![10]);
        assert_eq!(a.meta.get("serve_batch").unwrap().as_usize(), Some(8));
    }

    #[test]
    fn beats_chance_even_after_4bit_packing() {
        let ck = demo_checkpoint(DatasetKind::Cifar10, 16, 1, 8);
        let q = QuantizedCheckpoint::from_checkpoint(&ck, 4, |n| n.ends_with(".w"));
        let backend = ReferenceBackend::from_packed(&q).unwrap();
        let acc = demo_accuracy(&backend, DatasetKind::Cifar10, 200, 11);
        assert!(acc > 0.2, "4-bit demo accuracy only {acc}");
    }

    #[test]
    fn hundred_class_variant_works() {
        let ck = demo_checkpoint(DatasetKind::ImagenetLite, 2, 5, 4);
        let q = QuantizedCheckpoint::from_checkpoint(&ck, 8, |n| n.ends_with(".w"));
        let backend = ReferenceBackend::from_packed(&q).unwrap();
        assert_eq!(backend.num_classes(), 100);
        let acc = demo_accuracy(&backend, DatasetKind::ImagenetLite, 200, 2);
        assert!(acc > 0.03, "100-class accuracy only {acc}");
    }
}

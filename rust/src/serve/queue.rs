//! Bounded MPSC request queue between the front end and the batcher
//! (DESIGN.md §7).
//!
//! Connection threads `push` (non-blocking: a full queue is surfaced to
//! the client as backpressure instead of buffering unboundedly), worker
//! threads `pop` with a timeout. Built on `Mutex<VecDeque>` + `Condvar`
//! rather than `std::sync::mpsc` because the batcher needs
//! deadline-bounded waits and multiple *consumers* (one per worker),
//! which `mpsc::Receiver` cannot provide.

use std::collections::VecDeque;
use std::fmt;
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::obs::{self, Counter, Gauge, Registry};
use crate::util::failpoint;

/// One inference request traveling through the pipeline.
pub struct ServeRequest {
    /// Client-chosen id, echoed back in the response (ids are scoped to
    /// their connection: the per-request response channel does the
    /// routing, so cross-connection collisions are harmless).
    pub id: u64,
    /// Flattened NHWC pixels for exactly one image.
    pub pixels: Vec<f32>,
    /// When the request entered the queue (queue-latency clock).
    pub enqueued: Instant,
    /// Absolute point after which the answer is worthless to the
    /// client. Checked at admission and again when a batch forms; an
    /// expired request is answered with `deadline_exceeded` instead of
    /// computed (DESIGN.md §19). `None` = no deadline.
    pub deadline: Option<Instant>,
    /// Where the engine delivers the answer.
    pub resp: mpsc::Sender<ServeResponse>,
}

impl ServeRequest {
    /// Expired against its own deadline at `now`?
    pub fn expired_at(&self, now: Instant) -> bool {
        self.deadline.is_some_and(|d| d <= now)
    }
}

/// Where in the pipeline a deadline was found expired — the `stage`
/// label on `adaqat_deadline_expired_total`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DeadlineStage {
    /// Caught at `submit` before the request entered the queue.
    Admission,
    /// Caught when the batcher formed a batch (or the queue reclaimed
    /// an expired entry to make room).
    Batch,
}

impl DeadlineStage {
    pub fn label(self) -> &'static str {
        match self {
            DeadlineStage::Admission => "admission",
            DeadlineStage::Batch => "batch",
        }
    }
}

/// Structured failure for one request, serialized by the protocol layer
/// as a machine-readable `error` code plus detail fields — overload
/// clients branch on the code, not on prose.
#[derive(Debug, Clone, PartialEq)]
pub enum ServeError {
    /// The deadline passed before the answer could be produced.
    DeadlineExceeded { stage: DeadlineStage },
    /// Admission control refused the request; retry after the hint.
    Overloaded { retry_after_ms: u64 },
    /// The backend failed (or panicked) computing the batch.
    Inference(String),
}

impl ServeError {
    /// The wire-level `error` code.
    pub fn code(&self) -> &'static str {
        match self {
            ServeError::DeadlineExceeded { .. } => "deadline_exceeded",
            ServeError::Overloaded { .. } => "overloaded",
            ServeError::Inference(_) => "inference_failed",
        }
    }
}

impl fmt::Display for ServeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ServeError::DeadlineExceeded { stage } => {
                write!(f, "deadline exceeded (stage {})", stage.label())
            }
            ServeError::Overloaded { retry_after_ms } => {
                write!(f, "overloaded (retry after {retry_after_ms} ms)")
            }
            ServeError::Inference(msg) => write!(f, "inference failed: {msg}"),
        }
    }
}

/// The engine's answer to one request.
#[derive(Debug, Clone, PartialEq)]
pub struct ServeResponse {
    pub id: u64,
    /// Predicted class, or a structured failure.
    pub result: Result<usize, ServeError>,
    pub queue_ms: f64,
    pub compute_ms: f64,
}

/// Why a push was refused. The request is dropped; the caller still
/// holds the id and its response channel and reports the error itself.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PushError {
    /// Queue at capacity — shed load at the edge.
    Full,
    /// Engine shutting down.
    Closed,
}

impl fmt::Display for PushError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            PushError::Full => "queue full (backpressure)",
            PushError::Closed => "server shutting down",
        })
    }
}

/// Outcome of a timed pop.
pub enum Pop {
    Item(ServeRequest),
    TimedOut,
    /// Closed *and* drained — consumers should exit.
    Closed,
}

struct Inner {
    q: VecDeque<ServeRequest>,
    closed: bool,
}

/// The queue's registry handles (DESIGN.md §15): a live depth gauge and
/// shed counters labeled by reason. Registered once at queue
/// construction; updated under the queue lock, so the gauge never
/// disagrees with `len()` at a quiescent point.
struct QueueObs {
    depth: Arc<Gauge>,
    shed_full: Arc<Counter>,
    shed_closed: Arc<Counter>,
    /// `adaqat_deadline_expired_total{stage="batch"}` — expiries found
    /// after admission (batch formation, or push-time reclaim). The
    /// `stage="admission"` sibling lives with the admission policy.
    deadline_batch: Arc<Counter>,
}

impl QueueObs {
    fn register(reg: &Registry) -> QueueObs {
        QueueObs {
            depth: reg.gauge("adaqat_queue_depth", &[]),
            shed_full: reg.counter("adaqat_queue_shed_total", &[("reason", "full")]),
            shed_closed: reg.counter("adaqat_queue_shed_total", &[("reason", "closed")]),
            deadline_batch: reg
                .counter("adaqat_deadline_expired_total", &[("stage", "batch")]),
        }
    }
}

/// The bounded queue itself; shared via `Arc`.
pub struct RequestQueue {
    inner: Mutex<Inner>,
    cv: Condvar,
    capacity: usize,
    obs: QueueObs,
}

impl RequestQueue {
    pub fn new(capacity: usize) -> Arc<RequestQueue> {
        Self::with_obs(capacity, obs::global())
    }

    /// [`new`](RequestQueue::new) against an explicit registry. Tests
    /// use an isolated [`Registry`] so depth-gauge assertions stay
    /// deterministic while other tests serve traffic through the
    /// global one in parallel.
    pub fn with_obs(capacity: usize, reg: &Registry) -> Arc<RequestQueue> {
        assert!(capacity > 0, "queue capacity must be positive");
        Arc::new(RequestQueue {
            inner: Mutex::new(Inner { q: VecDeque::with_capacity(capacity), closed: false }),
            cv: Condvar::new(),
            capacity,
            obs: QueueObs::register(reg),
        })
    }

    pub fn push(&self, req: ServeRequest) -> Result<(), PushError> {
        failpoint::hit("queue_push");
        let mut g = self.inner.lock().unwrap();
        if g.closed {
            self.obs.shed_closed.inc();
            return Err(PushError::Closed);
        }
        if g.q.len() >= self.capacity {
            // Before shedding a live request, reclaim entries whose
            // deadline already passed: they will never be computed, so
            // an expired head must not cost an admittable request its
            // slot (ISSUE 10 satellite). Each reclaimed entry is
            // answered `deadline_exceeded` here, exactly once.
            let now = Instant::now();
            let before = g.q.len();
            g.q.retain(|r| {
                if r.expired_at(now) {
                    self.answer_expired(r, now);
                    false
                } else {
                    true
                }
            });
            let reclaimed = before - g.q.len();
            if reclaimed > 0 {
                self.obs.depth.add(-(reclaimed as f64));
            }
            if g.q.len() >= self.capacity {
                self.obs.shed_full.inc();
                return Err(PushError::Full);
            }
        }
        g.q.push_back(req);
        self.obs.depth.add(1.0);
        drop(g);
        self.cv.notify_one();
        Ok(())
    }

    /// Answer `req` with a batch-stage `deadline_exceeded` error and
    /// count it. The queue owns the `stage="batch"` counter, so both
    /// reclaim paths — push-time eviction above and batch-formation
    /// expiry in the worker loop — account through this one method.
    pub fn expire_batch(&self, req: ServeRequest) {
        self.answer_expired(&req, Instant::now());
    }

    fn answer_expired(&self, req: &ServeRequest, now: Instant) {
        self.obs.deadline_batch.inc();
        // receiver gone (client disconnected) is fine — the expiry is
        // still counted, which is what conservation checks audit
        let _ = req.resp.send(ServeResponse {
            id: req.id,
            result: Err(ServeError::DeadlineExceeded { stage: DeadlineStage::Batch }),
            queue_ms: now.duration_since(req.enqueued).as_secs_f64() * 1e3,
            compute_ms: 0.0,
        });
    }

    /// Wait up to `timeout` for one request.
    pub fn pop(&self, timeout: Duration) -> Pop {
        let deadline = Instant::now() + timeout;
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(req) = g.q.pop_front() {
                self.obs.depth.add(-1.0);
                return Pop::Item(req);
            }
            if g.closed {
                return Pop::Closed;
            }
            let now = Instant::now();
            if now >= deadline {
                return Pop::TimedOut;
            }
            let (guard, _res) = self.cv.wait_timeout(g, deadline - now).unwrap();
            g = guard;
        }
    }

    /// Close the queue: pushes fail, pops drain the backlog then report
    /// [`Pop::Closed`].
    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.cv.notify_all();
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().q.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// (full, closed) shed counts as this queue's registry series
    /// report them. Queues sharing a registry (production: the global
    /// one) share the series, so a multi-queue process reads totals.
    pub fn shed_counts(&self) -> (u64, u64) {
        (self.obs.shed_full.get(), self.obs.shed_closed.get())
    }

    /// Batch-stage deadline expiries (push-time reclaim + batch
    /// formation), as this queue's registry series reports them.
    pub fn deadline_expired_count(&self) -> u64 {
        self.obs.deadline_batch.get()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64) -> (ServeRequest, mpsc::Receiver<ServeResponse>) {
        req_with_deadline(id, None)
    }

    fn req_with_deadline(
        id: u64,
        deadline: Option<Instant>,
    ) -> (ServeRequest, mpsc::Receiver<ServeResponse>) {
        let (tx, rx) = mpsc::channel();
        (
            ServeRequest {
                id,
                pixels: vec![0.0; 4],
                enqueued: Instant::now(),
                deadline,
                resp: tx,
            },
            rx,
        )
    }

    #[test]
    fn fifo_order() {
        let q = RequestQueue::new(8);
        let mut rxs = vec![];
        for id in 0..5 {
            let (r, rx) = req(id);
            q.push(r).unwrap();
            rxs.push(rx);
        }
        for id in 0..5 {
            match q.pop(Duration::from_millis(10)) {
                Pop::Item(r) => assert_eq!(r.id, id),
                _ => panic!("expected item {id}"),
            }
        }
        assert!(matches!(q.pop(Duration::from_millis(1)), Pop::TimedOut));
    }

    #[test]
    fn capacity_backpressure() {
        let q = RequestQueue::new(2);
        let (r0, _k0) = req(0);
        let (r1, _k1) = req(1);
        let (r2, _k2) = req(2);
        q.push(r0).unwrap();
        q.push(r1).unwrap();
        assert_eq!(q.push(r2).unwrap_err(), PushError::Full);
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn close_drains_then_reports_closed() {
        let q = RequestQueue::new(4);
        let (r0, _k0) = req(0);
        q.push(r0).unwrap();
        q.close();
        let (r1, _k1) = req(1);
        assert_eq!(q.push(r1).unwrap_err(), PushError::Closed);
        assert!(matches!(q.pop(Duration::from_millis(1)), Pop::Item(_)));
        assert!(matches!(q.pop(Duration::from_millis(1)), Pop::Closed));
    }

    #[test]
    fn pop_wakes_on_cross_thread_push() {
        let q = RequestQueue::new(4);
        let q2 = Arc::clone(&q);
        let t = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            let (r, rx) = req(7);
            q2.push(r).unwrap();
            rx
        });
        let start = Instant::now();
        match q.pop(Duration::from_secs(5)) {
            Pop::Item(r) => assert_eq!(r.id, 7),
            _ => panic!("expected pushed item"),
        }
        assert!(start.elapsed() < Duration::from_secs(4), "pop did not wake early");
        t.join().unwrap();
    }

    #[test]
    fn depth_gauge_and_shed_counters_track_queue_events() {
        // isolated registry: the global one is shared with every other
        // test in this binary, so its gauge is not deterministic here
        let reg = Registry::new();
        let q = RequestQueue::with_obs(2, &reg);
        let depth = reg.gauge("adaqat_queue_depth", &[]);
        let (r0, _k0) = req(0);
        let (r1, _k1) = req(1);
        let (r2, _k2) = req(2);
        q.push(r0).unwrap();
        q.push(r1).unwrap();
        assert_eq!(depth.get(), 2.0);
        assert_eq!(q.push(r2).unwrap_err(), PushError::Full);
        assert_eq!(q.shed_counts(), (1, 0), "full shed counted, depth untouched");
        assert_eq!(depth.get(), 2.0);
        assert!(matches!(q.pop(Duration::from_millis(1)), Pop::Item(_)));
        assert!(matches!(q.pop(Duration::from_millis(1)), Pop::Item(_)));
        assert_eq!(depth.get(), 0.0, "gauge returns to 0 after drain");
        q.close();
        let (r3, _k3) = req(3);
        assert_eq!(q.push(r3).unwrap_err(), PushError::Closed);
        assert_eq!(q.shed_counts(), (1, 1));
        assert_eq!(depth.get(), 0.0);
    }

    #[test]
    fn expired_head_is_reclaimed_instead_of_shedding_a_live_push() {
        // regression (ISSUE 10): queue at capacity but holding an
        // already-expired head → the live push must be admitted, the
        // expired entry answered deadline_exceeded, and nothing shed
        let reg = Registry::new();
        let q = RequestQueue::with_obs(2, &reg);
        let past = Instant::now() - Duration::from_millis(5);
        let (r0, k0) = req_with_deadline(0, Some(past));
        let (r1, _k1) = req(1);
        q.push(r0).unwrap();
        q.push(r1).unwrap();
        let (r2, _k2) = req(2);
        q.push(r2).expect("live push must displace the expired head");
        assert_eq!(q.len(), 2);
        assert_eq!(q.shed_counts(), (0, 0), "no shed while reclaim can make room");
        assert_eq!(q.deadline_expired_count(), 1);
        let resp = k0.try_recv().expect("expired entry must be answered");
        assert_eq!(resp.id, 0);
        assert_eq!(
            resp.result,
            Err(ServeError::DeadlineExceeded { stage: DeadlineStage::Batch })
        );
        // survivors come out in order, skipping the reclaimed entry
        match q.pop(Duration::from_millis(1)) {
            Pop::Item(r) => assert_eq!(r.id, 1),
            _ => panic!("expected id 1"),
        }
        match q.pop(Duration::from_millis(1)) {
            Pop::Item(r) => assert_eq!(r.id, 2),
            _ => panic!("expected id 2"),
        }
        // depth gauge consistent after the reclaim + drain
        assert_eq!(reg.gauge("adaqat_queue_depth", &[]).get(), 0.0);
        // a full queue of *live* requests still sheds
        let (r3, _k3) = req(3);
        let (r4, _k4) = req(4);
        let (r5, _k5) = req(5);
        q.push(r3).unwrap();
        q.push(r4).unwrap();
        assert_eq!(q.push(r5).unwrap_err(), PushError::Full);
        assert_eq!(q.shed_counts(), (1, 0));
    }

    #[test]
    fn expire_batch_answers_and_counts() {
        let reg = Registry::new();
        let q = RequestQueue::with_obs(4, &reg);
        let (r, k) = req_with_deadline(9, Some(Instant::now()));
        q.expire_batch(r);
        assert_eq!(q.deadline_expired_count(), 1);
        let resp = k.try_recv().unwrap();
        assert_eq!(resp.id, 9);
        assert!(matches!(resp.result, Err(ServeError::DeadlineExceeded { .. })));
        assert_eq!(resp.compute_ms, 0.0);
    }

    #[test]
    fn concurrent_producers_all_land() {
        let q = RequestQueue::new(1024);
        let mut handles = vec![];
        for p in 0..8u64 {
            let q = Arc::clone(&q);
            handles.push(std::thread::spawn(move || {
                for i in 0..50 {
                    let (r, rx) = req(p * 100 + i);
                    q.push(r).unwrap();
                    drop(rx); // response channel unused in this test
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(q.len(), 400);
    }
}

//! Bounded MPSC request queue between the front end and the batcher
//! (DESIGN.md §7).
//!
//! Connection threads `push` (non-blocking: a full queue is surfaced to
//! the client as backpressure instead of buffering unboundedly), worker
//! threads `pop` with a timeout. Built on `Mutex<VecDeque>` + `Condvar`
//! rather than `std::sync::mpsc` because the batcher needs
//! deadline-bounded waits and multiple *consumers* (one per worker),
//! which `mpsc::Receiver` cannot provide.

use std::collections::VecDeque;
use std::fmt;
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::obs::{self, Counter, Gauge, Registry};

/// One inference request traveling through the pipeline.
pub struct ServeRequest {
    /// Client-chosen id, echoed back in the response (ids are scoped to
    /// their connection: the per-request response channel does the
    /// routing, so cross-connection collisions are harmless).
    pub id: u64,
    /// Flattened NHWC pixels for exactly one image.
    pub pixels: Vec<f32>,
    /// When the request entered the queue (queue-latency clock).
    pub enqueued: Instant,
    /// Where the engine delivers the answer.
    pub resp: mpsc::Sender<ServeResponse>,
}

/// The engine's answer to one request.
#[derive(Debug, Clone, PartialEq)]
pub struct ServeResponse {
    pub id: u64,
    /// Predicted class, or a human-readable failure.
    pub result: Result<usize, String>,
    pub queue_ms: f64,
    pub compute_ms: f64,
}

/// Why a push was refused. The request is dropped; the caller still
/// holds the id and its response channel and reports the error itself.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PushError {
    /// Queue at capacity — shed load at the edge.
    Full,
    /// Engine shutting down.
    Closed,
}

impl fmt::Display for PushError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            PushError::Full => "queue full (backpressure)",
            PushError::Closed => "server shutting down",
        })
    }
}

/// Outcome of a timed pop.
pub enum Pop {
    Item(ServeRequest),
    TimedOut,
    /// Closed *and* drained — consumers should exit.
    Closed,
}

struct Inner {
    q: VecDeque<ServeRequest>,
    closed: bool,
}

/// The queue's registry handles (DESIGN.md §15): a live depth gauge and
/// shed counters labeled by reason. Registered once at queue
/// construction; updated under the queue lock, so the gauge never
/// disagrees with `len()` at a quiescent point.
struct QueueObs {
    depth: Arc<Gauge>,
    shed_full: Arc<Counter>,
    shed_closed: Arc<Counter>,
}

impl QueueObs {
    fn register(reg: &Registry) -> QueueObs {
        QueueObs {
            depth: reg.gauge("adaqat_queue_depth", &[]),
            shed_full: reg.counter("adaqat_queue_shed_total", &[("reason", "full")]),
            shed_closed: reg.counter("adaqat_queue_shed_total", &[("reason", "closed")]),
        }
    }
}

/// The bounded queue itself; shared via `Arc`.
pub struct RequestQueue {
    inner: Mutex<Inner>,
    cv: Condvar,
    capacity: usize,
    obs: QueueObs,
}

impl RequestQueue {
    pub fn new(capacity: usize) -> Arc<RequestQueue> {
        Self::with_obs(capacity, obs::global())
    }

    /// [`new`](RequestQueue::new) against an explicit registry. Tests
    /// use an isolated [`Registry`] so depth-gauge assertions stay
    /// deterministic while other tests serve traffic through the
    /// global one in parallel.
    pub fn with_obs(capacity: usize, reg: &Registry) -> Arc<RequestQueue> {
        assert!(capacity > 0, "queue capacity must be positive");
        Arc::new(RequestQueue {
            inner: Mutex::new(Inner { q: VecDeque::with_capacity(capacity), closed: false }),
            cv: Condvar::new(),
            capacity,
            obs: QueueObs::register(reg),
        })
    }

    pub fn push(&self, req: ServeRequest) -> Result<(), PushError> {
        let mut g = self.inner.lock().unwrap();
        if g.closed {
            self.obs.shed_closed.inc();
            return Err(PushError::Closed);
        }
        if g.q.len() >= self.capacity {
            self.obs.shed_full.inc();
            return Err(PushError::Full);
        }
        g.q.push_back(req);
        self.obs.depth.add(1.0);
        drop(g);
        self.cv.notify_one();
        Ok(())
    }

    /// Wait up to `timeout` for one request.
    pub fn pop(&self, timeout: Duration) -> Pop {
        let deadline = Instant::now() + timeout;
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(req) = g.q.pop_front() {
                self.obs.depth.add(-1.0);
                return Pop::Item(req);
            }
            if g.closed {
                return Pop::Closed;
            }
            let now = Instant::now();
            if now >= deadline {
                return Pop::TimedOut;
            }
            let (guard, _res) = self.cv.wait_timeout(g, deadline - now).unwrap();
            g = guard;
        }
    }

    /// Close the queue: pushes fail, pops drain the backlog then report
    /// [`Pop::Closed`].
    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.cv.notify_all();
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().q.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// (full, closed) shed counts as this queue's registry series
    /// report them. Queues sharing a registry (production: the global
    /// one) share the series, so a multi-queue process reads totals.
    pub fn shed_counts(&self) -> (u64, u64) {
        (self.obs.shed_full.get(), self.obs.shed_closed.get())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64) -> (ServeRequest, mpsc::Receiver<ServeResponse>) {
        let (tx, rx) = mpsc::channel();
        (
            ServeRequest { id, pixels: vec![0.0; 4], enqueued: Instant::now(), resp: tx },
            rx,
        )
    }

    #[test]
    fn fifo_order() {
        let q = RequestQueue::new(8);
        let mut rxs = vec![];
        for id in 0..5 {
            let (r, rx) = req(id);
            q.push(r).unwrap();
            rxs.push(rx);
        }
        for id in 0..5 {
            match q.pop(Duration::from_millis(10)) {
                Pop::Item(r) => assert_eq!(r.id, id),
                _ => panic!("expected item {id}"),
            }
        }
        assert!(matches!(q.pop(Duration::from_millis(1)), Pop::TimedOut));
    }

    #[test]
    fn capacity_backpressure() {
        let q = RequestQueue::new(2);
        let (r0, _k0) = req(0);
        let (r1, _k1) = req(1);
        let (r2, _k2) = req(2);
        q.push(r0).unwrap();
        q.push(r1).unwrap();
        assert_eq!(q.push(r2).unwrap_err(), PushError::Full);
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn close_drains_then_reports_closed() {
        let q = RequestQueue::new(4);
        let (r0, _k0) = req(0);
        q.push(r0).unwrap();
        q.close();
        let (r1, _k1) = req(1);
        assert_eq!(q.push(r1).unwrap_err(), PushError::Closed);
        assert!(matches!(q.pop(Duration::from_millis(1)), Pop::Item(_)));
        assert!(matches!(q.pop(Duration::from_millis(1)), Pop::Closed));
    }

    #[test]
    fn pop_wakes_on_cross_thread_push() {
        let q = RequestQueue::new(4);
        let q2 = Arc::clone(&q);
        let t = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            let (r, rx) = req(7);
            q2.push(r).unwrap();
            rx
        });
        let start = Instant::now();
        match q.pop(Duration::from_secs(5)) {
            Pop::Item(r) => assert_eq!(r.id, 7),
            _ => panic!("expected pushed item"),
        }
        assert!(start.elapsed() < Duration::from_secs(4), "pop did not wake early");
        t.join().unwrap();
    }

    #[test]
    fn depth_gauge_and_shed_counters_track_queue_events() {
        // isolated registry: the global one is shared with every other
        // test in this binary, so its gauge is not deterministic here
        let reg = Registry::new();
        let q = RequestQueue::with_obs(2, &reg);
        let depth = reg.gauge("adaqat_queue_depth", &[]);
        let (r0, _k0) = req(0);
        let (r1, _k1) = req(1);
        let (r2, _k2) = req(2);
        q.push(r0).unwrap();
        q.push(r1).unwrap();
        assert_eq!(depth.get(), 2.0);
        assert_eq!(q.push(r2).unwrap_err(), PushError::Full);
        assert_eq!(q.shed_counts(), (1, 0), "full shed counted, depth untouched");
        assert_eq!(depth.get(), 2.0);
        assert!(matches!(q.pop(Duration::from_millis(1)), Pop::Item(_)));
        assert!(matches!(q.pop(Duration::from_millis(1)), Pop::Item(_)));
        assert_eq!(depth.get(), 0.0, "gauge returns to 0 after drain");
        q.close();
        let (r3, _k3) = req(3);
        assert_eq!(q.push(r3).unwrap_err(), PushError::Closed);
        assert_eq!(q.shed_counts(), (1, 1));
        assert_eq!(depth.get(), 0.0);
    }

    #[test]
    fn concurrent_producers_all_land() {
        let q = RequestQueue::new(1024);
        let mut handles = vec![];
        for p in 0..8u64 {
            let q = Arc::clone(&q);
            handles.push(std::thread::spawn(move || {
                for i in 0..50 {
                    let (r, rx) = req(p * 100 + i);
                    q.push(r).unwrap();
                    drop(rx); // response channel unused in this test
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(q.len(), 400);
    }
}

//! The quantized-inference serving subsystem (DESIGN.md §7).
//!
//! Turns a finished AdaQAT run into an inference service — the artifact
//! chain the paper's "cheaper inference" claim cashes out into:
//!
//! ```text
//!  final.ckpt ──adaqat export──▶ packed .aqq (AQQCKPT1, k_w-bit codes)
//!                                    │
//!                 adaqat serve ──────┤
//!                                    ▼
//!   TCP/NDJSON ▶ [queue] ▶ [dynamic batcher] ▶ [N workers × Backend]
//!      ▲            bounded     deadline-based      PJRT infer graph
//!      │            MPSC        coalescing          or pure-Rust ref.
//!   adaqat client                                   └▶ latency histograms
//! ```
//!
//! Module map: [`packed`] — bit-packed checkpoints; [`queue`] +
//! [`batcher`] — the request pipeline; [`admission`] — overload
//! policy in front of the queue (deadlines, retry-after, DESIGN.md
//! §19); [`engine`] — workers, backends, metrics; [`protocol`] + [`server`] + [`client`] — the NDJSON/TCP
//! front end; [`demo`] — the offline-runnable demo models (linear
//! nearest-centroid and the 2-layer ReLU MLP). The reference backend's
//! math lives in [`crate::kernels`]: integer-domain GEMMs over the
//! packed codes, so the learned bit-widths buy compute, not just bytes
//! (DESIGN.md §11).

pub mod admission;
pub mod batcher;
pub mod client;
pub mod demo;
pub mod engine;
pub mod packed;
pub mod protocol;
pub mod queue;
pub mod server;

pub use admission::{AdmissionControl, Decision};
pub use engine::{Backend, Engine, EngineConfig, ReferenceBackend, RuntimeBackend};
pub use packed::{PackedTensor, QuantizedCheckpoint};
pub use queue::{DeadlineStage, RequestQueue, ServeError, ServeRequest, ServeResponse};
pub use server::Server;

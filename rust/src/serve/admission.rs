//! Admission control in front of [`RequestQueue::push`] (DESIGN.md
//! §19): reject early, with an honest retry hint, instead of queueing
//! work the engine cannot finish in time.
//!
//! The policy consumes signals the obs registry already carries —
//! queue depth (`adaqat_queue_depth`), recent sheds
//! (`adaqat_queue_shed_total{reason="full"}`) — plus an EWMA of
//! observed batch drain rate the workers feed back after every batch.
//! From those it estimates the queue wait a new request would see and
//! answers one of:
//!
//! - **Admit** — the request enters the queue.
//! - **Overloaded** — estimated wait exceeds the configured bound (or
//!   the queue is at capacity, or sheds are actively happening near
//!   capacity). Carries `retry_after_ms` derived from the current
//!   drain rate: the time for the backlog to drain to half capacity,
//!   not a constant.
//! - **DeadlineHopeless** — the request carries a deadline budget
//!   smaller than the estimated wait; admitting it would only waste a
//!   batch slot before a guaranteed `deadline_exceeded`.
//!
//! The policy is armed only when `max_wait` is `Some` (the serve flag
//! `--max_wait_ms`, 0 = off); disarmed it admits everything and the
//! queue's own capacity backpressure is the only shed path, which
//! preserves the pre-admission-control behavior.
//!
//! [`RequestQueue::push`]: crate::serve::queue::RequestQueue::push

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::obs::{Counter, Gauge, Registry};

/// EWMA smoothing: new = (1-ALPHA)·old + ALPHA·instant.
const ALPHA: f64 = 0.2;
/// Sheds within this window count as "actively shedding".
const SHED_RECENCY_MS: u64 = 1000;
/// Bounds on the retry hint. The floor keeps it finite and nonzero;
/// the ceiling keeps a mis-estimated drain rate from parking clients.
const RETRY_AFTER_MIN_MS: u64 = 1;
const RETRY_AFTER_MAX_MS: u64 = 30_000;
/// Retry hint when the drain rate is still unknown (no batch has
/// completed yet): one batch window's worth of backoff.
const RETRY_AFTER_COLD_MS: u64 = 50;

/// Admission verdict for one request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Decision {
    Admit,
    /// Reject with a drain-rate-derived retry hint (always finite,
    /// in `[RETRY_AFTER_MIN_MS, RETRY_AFTER_MAX_MS]`).
    Overloaded { retry_after_ms: u64 },
    /// The request's own deadline budget cannot survive the estimated
    /// queue wait — answered `deadline_exceeded{stage="admission"}`.
    DeadlineHopeless,
}

/// The policy object. One per engine, shared with every connection
/// thread (decisions) and every worker (drain-rate feedback).
pub struct AdmissionControl {
    capacity: usize,
    max_wait: Option<Duration>,
    /// Queue depth series shared with the engine's `RequestQueue`.
    depth: Arc<Gauge>,
    /// Full-shed series shared with the queue — recency of sheds is an
    /// overload signal even when depth has transiently dipped.
    shed_full: Arc<Counter>,
    /// `adaqat_admission_rejected_total` — Overloaded verdicts.
    rejected: Arc<Counter>,
    /// `adaqat_deadline_expired_total{stage="admission"}` — requests
    /// dead on arrival or DeadlineHopeless.
    deadline_admission: Arc<Counter>,
    /// EWMA total drain rate, rows/ms across the worker pool, stored
    /// as f64 bits. 0 = unknown (no batch observed yet).
    drain_rate_bits: AtomicU64,
    /// Construction instant — atomics below store ms since this epoch.
    epoch: Instant,
    /// shed_full value at the last decide() that inspected it.
    seen_shed: AtomicU64,
    /// ms-since-epoch of the most recent observed shed increase.
    last_shed_ms: AtomicU64,
    workers: f64,
}

impl AdmissionControl {
    pub fn register(
        capacity: usize,
        workers: usize,
        max_wait: Option<Duration>,
        reg: &Registry,
    ) -> Arc<AdmissionControl> {
        Arc::new(AdmissionControl {
            capacity,
            max_wait,
            depth: reg.gauge("adaqat_queue_depth", &[]),
            shed_full: reg.counter("adaqat_queue_shed_total", &[("reason", "full")]),
            rejected: reg.counter("adaqat_admission_rejected_total", &[]),
            deadline_admission: reg
                .counter("adaqat_deadline_expired_total", &[("stage", "admission")]),
            drain_rate_bits: AtomicU64::new(0f64.to_bits()),
            epoch: Instant::now(),
            seen_shed: AtomicU64::new(0),
            last_shed_ms: AtomicU64::new(u64::MAX),
            workers: workers.max(1) as f64,
        })
    }

    /// Armed at all? Disarmed (no `max_wait`) the engine skips
    /// [`decide`](Self::decide) entirely.
    pub fn enabled(&self) -> bool {
        self.max_wait.is_some()
    }

    /// Total drain rate estimate in rows/ms (0 until the first batch).
    pub fn drain_rate(&self) -> f64 {
        f64::from_bits(self.drain_rate_bits.load(Ordering::SeqCst))
    }

    /// Worker feedback: `rows` finished in `compute` wall time on one
    /// worker. Folded into the pool-wide EWMA drain rate.
    pub fn observe_batch(&self, rows: usize, compute: Duration) {
        if rows == 0 {
            return;
        }
        let ms = (compute.as_secs_f64() * 1e3).max(1e-3);
        let inst = rows as f64 / ms * self.workers;
        let _ = self
            .drain_rate_bits
            .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |bits| {
                let old = f64::from_bits(bits);
                let new = if old == 0.0 { inst } else { (1.0 - ALPHA) * old + ALPHA * inst };
                Some(new.to_bits())
            });
    }

    /// Judge one request. `budget` is the request's remaining deadline
    /// budget (`deadline - now`), `None` when it has no deadline.
    /// Increments the rejection/expiry counters for non-Admit verdicts.
    pub fn decide(&self, budget: Option<Duration>) -> Decision {
        let Some(max_wait) = self.max_wait else {
            return Decision::Admit;
        };
        let depth = self.depth.get().max(0.0);
        let rate = self.drain_rate();
        let est_wait_ms = if rate > 0.0 { Some(depth / rate) } else { None };

        if let (Some(est), Some(b)) = (est_wait_ms, budget) {
            if est > b.as_secs_f64() * 1e3 {
                self.deadline_admission.inc();
                return Decision::DeadlineHopeless;
            }
        }

        let over_wait = est_wait_ms.is_some_and(|est| est > max_wait.as_secs_f64() * 1e3);
        let at_capacity = depth as usize >= self.capacity;
        let shedding = self.recent_shed() && depth as usize * 4 >= self.capacity * 3;
        if over_wait || at_capacity || shedding {
            self.rejected.inc();
            return Decision::Overloaded { retry_after_ms: self.retry_after_ms(depth, rate) };
        }
        Decision::Admit
    }

    /// Count a request that arrived with its deadline already expired
    /// (the admission-stage expiry the engine detects before push).
    pub fn note_admission_expiry(&self) {
        self.deadline_admission.inc();
    }

    /// (overloaded rejections, admission-stage deadline expiries).
    pub fn reject_counts(&self) -> (u64, u64) {
        (self.rejected.get(), self.deadline_admission.get())
    }

    /// How long until the backlog drains to half capacity at the
    /// current rate — the honest retry hint. Falls back to a cold
    /// constant only when no batch has ever completed.
    fn retry_after_ms(&self, depth: f64, rate: f64) -> u64 {
        if rate <= 0.0 {
            return RETRY_AFTER_COLD_MS;
        }
        let excess = (depth - self.capacity as f64 / 2.0).max(1.0);
        (excess / rate).ceil().clamp(RETRY_AFTER_MIN_MS as f64, RETRY_AFTER_MAX_MS as f64)
            as u64
    }

    fn recent_shed(&self) -> bool {
        let now_ms = self.epoch.elapsed().as_millis() as u64;
        let cur = self.shed_full.get();
        let seen = self.seen_shed.swap(cur, Ordering::SeqCst);
        if cur > seen {
            self.last_shed_ms.store(now_ms, Ordering::SeqCst);
            return true;
        }
        let last = self.last_shed_ms.load(Ordering::SeqCst);
        last != u64::MAX && now_ms.saturating_sub(last) < SHED_RECENCY_MS
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn policy(
        capacity: usize,
        max_wait_ms: Option<u64>,
    ) -> (Arc<AdmissionControl>, Arc<Gauge>, Registry) {
        let reg = Registry::new();
        let ac = AdmissionControl::register(
            capacity,
            2,
            max_wait_ms.map(Duration::from_millis),
            &reg,
        );
        let depth = reg.gauge("adaqat_queue_depth", &[]);
        (ac, depth, reg)
    }

    #[test]
    fn disarmed_policy_admits_everything() {
        let (ac, depth, _reg) = policy(4, None);
        assert!(!ac.enabled());
        depth.set(1e6);
        assert_eq!(ac.decide(Some(Duration::from_millis(1))), Decision::Admit);
    }

    #[test]
    fn cold_policy_admits_below_capacity_and_rejects_at_capacity() {
        let (ac, depth, _reg) = policy(8, Some(100));
        depth.set(3.0);
        assert_eq!(ac.decide(None), Decision::Admit);
        depth.set(8.0);
        match ac.decide(None) {
            Decision::Overloaded { retry_after_ms } => {
                // drain rate unknown → cold fallback, still finite
                assert_eq!(retry_after_ms, RETRY_AFTER_COLD_MS);
            }
            other => panic!("expected Overloaded, got {other:?}"),
        }
        assert_eq!(ac.reject_counts().0, 1);
    }

    #[test]
    fn estimated_wait_beyond_max_wait_rejects_with_drain_derived_hint() {
        let (ac, depth, _reg) = policy(1000, Some(10));
        // 2 workers × 16 rows / 8 ms → EWMA starts at 4 rows/ms total
        ac.observe_batch(16, Duration::from_millis(8));
        assert!((ac.drain_rate() - 4.0).abs() < 1e-9);
        // depth 400 → est wait 100 ms > max_wait 10 ms
        depth.set(400.0);
        match ac.decide(None) {
            Decision::Overloaded { retry_after_ms } => {
                // excess over half capacity: (400-500)→floor 1 row? no:
                // depth < cap/2 keeps excess at the 1-row floor → ~1ms…
                // clamp guarantees the hint is finite and ≥ 1
                assert!(retry_after_ms >= 1 && retry_after_ms <= 30_000);
            }
            other => panic!("expected Overloaded, got {other:?}"),
        }
        // depth 4 → est wait 1 ms ≤ 10 ms → admit
        depth.set(4.0);
        assert_eq!(ac.decide(None), Decision::Admit);
    }

    #[test]
    fn hopeless_deadline_budget_is_rejected_as_deadline_expiry() {
        let (ac, depth, _reg) = policy(1000, Some(500));
        ac.observe_batch(10, Duration::from_millis(10)); // 2 rows/ms
        depth.set(200.0); // est wait 100 ms
        assert_eq!(
            ac.decide(Some(Duration::from_millis(20))),
            Decision::DeadlineHopeless
        );
        assert_eq!(ac.reject_counts(), (0, 1));
        // a roomy budget sails through
        assert_eq!(ac.decide(Some(Duration::from_millis(400))), Decision::Admit);
    }

    #[test]
    fn recent_sheds_near_capacity_trip_rejection() {
        let (ac, depth, reg) = policy(8, Some(10_000));
        // deep queue but under capacity and huge max_wait: admit…
        ac.observe_batch(100, Duration::from_millis(1));
        depth.set(7.0);
        assert_eq!(ac.decide(None), Decision::Admit);
        // …until the queue reports a fresh full-shed
        reg.counter("adaqat_queue_shed_total", &[("reason", "full")]).inc();
        assert!(matches!(ac.decide(None), Decision::Overloaded { .. }));
        // below ¾ capacity the shed signal alone does not reject
        depth.set(2.0);
        assert_eq!(ac.decide(None), Decision::Admit);
    }

    #[test]
    fn ewma_converges_toward_sustained_rate() {
        let (ac, _depth, _reg) = policy(64, Some(100));
        for _ in 0..64 {
            ac.observe_batch(8, Duration::from_millis(4)); // 4 rows/ms total
        }
        assert!((ac.drain_rate() - 4.0).abs() < 1e-6);
    }
}

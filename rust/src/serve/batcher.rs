//! Dynamic batcher: coalesce single-image requests into the runtime's
//! static batch shape under a max-latency deadline (DESIGN.md §7).
//!
//! Policy: block until the first request arrives, then keep pulling
//! until either the batch is full or `max_delay` has elapsed since the
//! first pull. Under load, batches fill instantly and the deadline never
//! fires; at low rates, a lone request waits at most `max_delay` before
//! dispatch — the classic throughput/latency dial every serving stack
//! exposes (the serve bench measures both ends of it).

use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::obs::{self, HistHandle};
use crate::util::failpoint;

use super::queue::{Pop, RequestQueue, ServeRequest};

/// Poll granularity while idle-waiting for the *first* request; bounds
/// shutdown latency, not request latency (a push wakes the wait early).
const IDLE_POLL: Duration = Duration::from_millis(100);

pub struct DynamicBatcher {
    queue: Arc<RequestQueue>,
    batch: usize,
    max_delay: Duration,
    /// Coalesced-rows distribution (`adaqat_batch_rows`, DESIGN.md §15)
    /// — the occupancy dial this module's deadline policy controls.
    batch_rows: Arc<HistHandle>,
}

impl DynamicBatcher {
    pub fn new(queue: Arc<RequestQueue>, batch: usize, max_delay: Duration) -> DynamicBatcher {
        Self::with_hist(
            queue,
            batch,
            max_delay,
            obs::global().histogram("adaqat_batch_rows", &[]),
        )
    }

    /// [`new`](DynamicBatcher::new) with an explicit batch-rows series,
    /// so an engine built on an isolated [`Registry`] (chaos tests)
    /// keeps its histogram out of the global registry. Worker threads
    /// hold the `Arc<HistHandle>`, not the registry itself.
    pub fn with_hist(
        queue: Arc<RequestQueue>,
        batch: usize,
        max_delay: Duration,
        batch_rows: Arc<HistHandle>,
    ) -> DynamicBatcher {
        assert!(batch > 0, "batch must be positive");
        DynamicBatcher { queue, batch, max_delay, batch_rows }
    }

    /// Next coalesced batch (1..=batch requests), or `None` once the
    /// queue is closed and drained.
    pub fn next_batch(&self) -> Option<Vec<ServeRequest>> {
        // chaos site: stall batch formation so deadlines expire in-queue
        failpoint::hit("batcher_stall");
        let first = loop {
            match self.queue.pop(IDLE_POLL) {
                Pop::Item(r) => break r,
                Pop::TimedOut => continue,
                Pop::Closed => return None,
            }
        };
        let deadline = Instant::now() + self.max_delay;
        let mut out = Vec::with_capacity(self.batch);
        out.push(first);
        while out.len() < self.batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match self.queue.pop(deadline - now) {
                Pop::Item(r) => out.push(r),
                // Closed: ship what we have; the next call returns None.
                Pop::TimedOut | Pop::Closed => break,
            }
        }
        self.batch_rows.record(out.len() as f64);
        Some(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;

    fn req(id: u64) -> ServeRequest {
        let (tx, rx) = mpsc::channel();
        drop(rx);
        ServeRequest { id, pixels: vec![], enqueued: Instant::now(), deadline: None, resp: tx }
    }

    #[test]
    fn full_batch_dispatches_without_waiting_out_the_deadline() {
        let q = RequestQueue::new(64);
        for id in 0..8 {
            q.push(req(id)).unwrap();
        }
        let b = DynamicBatcher::new(Arc::clone(&q), 4, Duration::from_secs(30));
        let t0 = Instant::now();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 4);
        assert_eq!(batch.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1, 2, 3]);
        assert!(t0.elapsed() < Duration::from_secs(5), "deadline should not matter");
        // the rest are still queued for the next batch
        assert_eq!(b.next_batch().unwrap().len(), 4);
    }

    #[test]
    fn partial_batch_ships_at_the_deadline() {
        let q = RequestQueue::new(64);
        q.push(req(1)).unwrap();
        let b = DynamicBatcher::new(Arc::clone(&q), 16, Duration::from_millis(30));
        let t0 = Instant::now();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 1);
        let waited = t0.elapsed();
        assert!(waited >= Duration::from_millis(25), "shipped too early: {waited:?}");
        assert!(waited < Duration::from_secs(5), "deadline overshot: {waited:?}");
    }

    #[test]
    fn late_arrivals_join_within_the_window() {
        let q = RequestQueue::new(64);
        q.push(req(1)).unwrap();
        let q2 = Arc::clone(&q);
        let t = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(15));
            q2.push(req(2)).unwrap();
        });
        let b = DynamicBatcher::new(Arc::clone(&q), 2, Duration::from_secs(10));
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 2, "second request should have joined");
        t.join().unwrap();
    }

    #[test]
    fn closed_queue_terminates_the_batcher() {
        let q = RequestQueue::new(8);
        q.push(req(1)).unwrap();
        q.close();
        let b = DynamicBatcher::new(Arc::clone(&q), 4, Duration::from_millis(5));
        // drains the backlog first…
        assert_eq!(b.next_batch().unwrap().len(), 1);
        // …then signals termination
        assert!(b.next_batch().is_none());
    }
}

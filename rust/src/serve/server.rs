//! TCP front end: accept loop + per-connection reader/writer threads
//! (DESIGN.md §7).
//!
//! Each connection gets a reader thread (parses NDJSON requests, submits
//! them to the engine) and a writer thread (drains the connection's
//! response channel). Requests pipeline freely: a client may have any
//! number in flight; ids map answers back to questions. All writes to a
//! connection go through one mutex-guarded `BufWriter`, so response and
//! control lines never interleave mid-line.

use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::Duration;

use crate::util::failpoint;

use super::engine::Engine;
use super::protocol::{self, Request};
use super::queue::ServeResponse;

/// Hard cap on one NDJSON request line. Generous — a 32×32×3 image is
/// ~80 KiB of JSON — but bounded, so a newline-less client cannot grow
/// server memory without limit.
const MAX_LINE_BYTES: u64 = 16 * 1024 * 1024;

/// A running server; dropping it does NOT stop the accept loop — call
/// [`Server::stop`].
pub struct Server {
    pub addr: SocketAddr,
    stop: Arc<AtomicBool>,
    drain: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Bind (`"127.0.0.1:0"` picks a free port — see `self.addr`) and
    /// start accepting.
    pub fn start(bind: &str, engine: Arc<Engine>) -> anyhow::Result<Server> {
        let listener = TcpListener::bind(bind)?;
        listener.set_nonblocking(true)?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let drain = Arc::new(AtomicBool::new(false));
        let drain2 = Arc::clone(&drain);
        let accept_thread = std::thread::Builder::new()
            .name("serve-accept".to_string())
            .spawn(move || accept_loop(listener, engine, stop2, drain2))?;
        log::info!("serving on {addr}");
        Ok(Server { addr, stop, drain, accept_thread: Some(accept_thread) })
    }

    /// True once a client sent `{"cmd":"drain"}`. The serve loop polls
    /// this (alongside the signal latch) and performs the graceful
    /// shutdown: [`Server::stop`], engine drain, metrics flush, exit 0.
    pub fn drain_requested(&self) -> bool {
        self.drain.load(Ordering::SeqCst)
    }

    /// Stop accepting new connections (existing ones run until the
    /// client disconnects or the engine shuts down).
    pub fn stop(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

fn accept_loop(
    listener: TcpListener,
    engine: Arc<Engine>,
    stop: Arc<AtomicBool>,
    drain: Arc<AtomicBool>,
) {
    loop {
        match listener.accept() {
            Ok((stream, peer)) => {
                log::debug!("connection from {peer}");
                let engine = Arc::clone(&engine);
                let drain = Arc::clone(&drain);
                let _ = std::thread::Builder::new()
                    .name("serve-conn".to_string())
                    .spawn(move || handle_conn(stream, engine, drain));
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                if stop.load(Ordering::Relaxed) || drain.load(Ordering::SeqCst) {
                    return;
                }
                std::thread::sleep(Duration::from_millis(20));
            }
            Err(e) => {
                log::warn!("accept failed: {e}");
                std::thread::sleep(Duration::from_millis(100));
            }
        }
    }
}

type SharedWriter = Arc<Mutex<BufWriter<TcpStream>>>;

fn write_line(out: &SharedWriter, line: &str) -> bool {
    // chaos site: injected resets exercise the disconnect paths
    if failpoint::io_error("conn_write").is_some() {
        return false;
    }
    let mut g = out.lock().unwrap();
    writeln!(g, "{line}").and_then(|_| g.flush()).is_ok()
}

fn handle_conn(stream: TcpStream, engine: Arc<Engine>, drain: Arc<AtomicBool>) {
    // the listener is non-blocking; accepted sockets must not be
    let _ = stream.set_nonblocking(false);
    let _ = stream.set_nodelay(true);
    let write_half = match stream.try_clone() {
        Ok(s) => s,
        Err(e) => {
            log::warn!("connection clone failed: {e}");
            return;
        }
    };
    let out: SharedWriter = Arc::new(Mutex::new(BufWriter::new(write_half)));
    let (tx, rx) = mpsc::channel::<ServeResponse>();

    let out_resp = Arc::clone(&out);
    let writer_thread = std::thread::spawn(move || {
        for resp in rx.iter() {
            if !write_line(&out_resp, &protocol::response_line(&resp)) {
                break;
            }
        }
    });

    // Bounded line framing: a client that never sends '\n' (or sends one
    // enormous line) must hit a hard cap, not grow a String until OOM —
    // the queue's backpressure can't protect what never reaches it.
    let mut reader = BufReader::new(stream).take(MAX_LINE_BYTES);
    let mut buf: Vec<u8> = Vec::new();
    loop {
        buf.clear();
        reader.set_limit(MAX_LINE_BYTES);
        // chaos site: injected resets on the read path
        if failpoint::io_error("conn_read").is_some() {
            break;
        }
        match reader.read_until(b'\n', &mut buf) {
            Ok(0) => break, // EOF
            Ok(_) => {}
            Err(_) => break,
        }
        if buf.last() != Some(&b'\n') && reader.limit() == 0 {
            // cap hit mid-line: answer once (a structured bad_request,
            // not a silent close), then drop the connection
            write_line(
                &out,
                &protocol::error_line(
                    None,
                    "bad_request",
                    &format!("request line exceeds {MAX_LINE_BYTES} bytes"),
                ),
            );
            break;
        }
        let line = match std::str::from_utf8(&buf) {
            Ok(l) => l.trim(),
            Err(_) => {
                if !write_line(
                    &out,
                    &protocol::error_line(None, "bad_request", "request is not UTF-8"),
                ) {
                    break;
                }
                continue;
            }
        };
        if line.is_empty() {
            continue;
        }
        let keep_going = match protocol::parse_request(line) {
            Ok(Request::Ping) => write_line(&out, &protocol::pong_line()),
            Ok(Request::Stats) => write_line(
                &out,
                &protocol::stats_line(
                    &engine.metrics,
                    engine.queue_depth(),
                    engine.shed_counts(),
                    engine.overload_counts(),
                ),
            ),
            Ok(Request::Metrics) => {
                write_line(&out, &protocol::metrics_line(&engine.prometheus()))
            }
            Ok(Request::Trace) => {
                write_line(&out, &protocol::trace_line(&engine.metrics.trace.snapshot()))
            }
            Ok(Request::Drain) => {
                // graceful shutdown begins: flag the serve loop (which
                // closes the listener and drains the engine), ack the
                // admin, and keep this connection open for in-flight
                // answers
                log::info!("drain requested by admin command");
                drain.store(true, Ordering::SeqCst);
                write_line(&out, &protocol::drain_line())
            }
            Ok(Request::Infer { id, pixels, deadline_ms }) => {
                match engine.submit_with_deadline(id, pixels, deadline_ms, tx.clone()) {
                    Ok(()) => true,
                    Err(e) => write_line(&out, &protocol::submit_error_line(id, &e)),
                }
            }
            Err(msg) => write_line(&out, &protocol::error_line(None, "bad_request", &msg)),
        };
        if !keep_going {
            break;
        }
    }
    // Reader done: drop our sender so the writer drains in-flight
    // responses and exits once the engine releases its clones.
    drop(tx);
    let _ = writer_thread.join();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DatasetKind;
    use crate::serve::demo;
    use crate::serve::engine::{Backend, EngineConfig, ReferenceBackend};
    use crate::serve::packed::QuantizedCheckpoint;
    use crate::util::json::Json;

    fn start_demo_server() -> (Server, Arc<Engine>, Arc<QuantizedCheckpoint>) {
        let ck = demo::demo_checkpoint(DatasetKind::Cifar10, 8, 21, 8);
        let q = Arc::new(QuantizedCheckpoint::from_checkpoint(&ck, 4, |n| {
            n.ends_with(".w")
        }));
        let q2 = Arc::clone(&q);
        let engine = Engine::start(
            EngineConfig {
                workers: 2,
                queue_capacity: 128,
                max_delay: Duration::from_millis(2),
                ..EngineConfig::default()
            },
            move |_| Ok(Box::new(ReferenceBackend::from_packed(&q2)?) as Box<dyn Backend>),
        )
        .unwrap();
        let server = Server::start("127.0.0.1:0", Arc::clone(&engine)).unwrap();
        (server, engine, q)
    }

    #[test]
    fn tcp_smoke_ping_infer_stats_and_errors() {
        let (server, engine, q) = start_demo_server();
        let direct = ReferenceBackend::from_packed(&q).unwrap();
        let stream = TcpStream::connect(server.addr).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut w = stream.try_clone().unwrap();
        let mut line = String::new();

        // ping
        writeln!(w, r#"{{"cmd":"ping"}}"#).unwrap();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("\"ok\""), "{line}");

        // a real image round-trips with the direct prediction
        let ds = crate::data::synth::generate(DatasetKind::Cifar10, 4, 3, 1);
        let px: Vec<String> = ds.image(1).iter().map(|p| format!("{p}")).collect();
        writeln!(w, r#"{{"id": 42, "image": [{}]}}"#, px.join(",")).unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        let j = Json::parse(line.trim()).unwrap();
        assert_eq!(j.get("id").unwrap().as_f64(), Some(42.0));
        assert_eq!(
            j.get("class").unwrap().as_f64(),
            Some(direct.classify_one(ds.image(1)) as f64)
        );

        // wrong pixel count → structured bad_request with the id echoed
        writeln!(w, r#"{{"id": 43, "image": [1, 2, 3]}}"#).unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        let j = Json::parse(line.trim()).unwrap();
        assert_eq!(j.get("id").unwrap().as_f64(), Some(43.0));
        assert_eq!(j.get("error").unwrap().as_str(), Some("bad_request"));

        // garbage → structured bad_request without id
        writeln!(w, "zzz").unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        let j = Json::parse(line.trim()).unwrap();
        assert_eq!(j.get("error").unwrap().as_str(), Some("bad_request"));
        assert!(j.get("id").is_none());

        // stats reflect the one served request
        writeln!(w, r#"{{"cmd":"stats"}}"#).unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        let j = Json::parse(line.trim()).unwrap();
        assert_eq!(j.get("requests").unwrap().as_f64(), Some(1.0));

        drop(w);
        drop(reader);
        server.stop();
        engine.shutdown();
    }

    #[test]
    fn over_cap_line_gets_structured_bad_request_before_close() {
        // a newline-less line that exhausts the 16 MiB cap must be
        // answered with {"error":"bad_request"} — not a silent close
        let (server, engine, _q) = start_demo_server();
        let stream = TcpStream::connect(server.addr).unwrap();
        let mut w = stream.try_clone().unwrap();
        let chunk = vec![b'a'; 1 << 20];
        for _ in 0..16 {
            w.write_all(&chunk).unwrap();
        }
        w.flush().unwrap();
        // half-close: the server sees the cap hit (limit exhausted, no
        // newline), answers, and drops the connection
        stream.shutdown(std::net::Shutdown::Write).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let j = Json::parse(line.trim()).unwrap();
        assert_eq!(j.get("error").unwrap().as_str(), Some("bad_request"));
        assert!(
            j.get("detail").unwrap().as_str().unwrap().contains("exceeds"),
            "{line}"
        );
        // then the connection closes: next read is EOF
        line.clear();
        assert_eq!(reader.read_line(&mut line).unwrap(), 0);
        server.stop();
        engine.shutdown();
    }

    #[test]
    fn drain_command_acks_and_trips_the_server_flag() {
        let (server, engine, _q) = start_demo_server();
        assert!(!server.drain_requested());
        let stream = TcpStream::connect(server.addr).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut w = stream.try_clone().unwrap();
        writeln!(w, r#"{{"cmd":"drain"}}"#).unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let j = Json::parse(line.trim()).unwrap();
        assert_eq!(j.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(j.get("draining").unwrap().as_bool(), Some(true));
        assert!(server.drain_requested());
        drop(w);
        drop(reader);
        server.stop();
        engine.shutdown();
    }
}

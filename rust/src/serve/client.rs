//! Demo load-generating client (DESIGN.md §7): pipelines labeled images
//! over one TCP connection with a bounded in-flight window, then reports
//! client-observed latency percentiles, throughput, and accuracy.
//!
//! Used by `adaqat client`, the serve bench's TCP mode, and the
//! end-to-end test (≥1k requests through the full stack).

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::metrics::{Histogram, LatencySnapshot};
use crate::util::json::Json;

/// What one run observed, from the client's side of the socket.
pub struct ClientReport {
    pub sent: usize,
    pub received: usize,
    pub errors: usize,
    /// Predictions matching the supplied label.
    pub correct: usize,
    pub wall_seconds: f64,
    pub latency: LatencySnapshot,
    /// id → Ok(class) | Err(message), for correctness cross-checks.
    pub preds: BTreeMap<u64, Result<usize, String>>,
}

impl ClientReport {
    pub fn requests_per_second(&self) -> f64 {
        if self.wall_seconds > 0.0 {
            self.received as f64 / self.wall_seconds
        } else {
            0.0
        }
    }
}

/// Send `images` (pixels, label) as requests `id = 0..n`, keeping at
/// most `window` in flight. `window = 1` is the single-stream regime;
/// large windows exercise dynamic batching.
pub fn run(
    addr: &str,
    images: &[(Vec<f32>, i32)],
    window: usize,
) -> anyhow::Result<ClientReport> {
    anyhow::ensure!(window >= 1, "window must be >= 1");
    let stream = TcpStream::connect(addr)?;
    stream.set_nodelay(true)?;
    let read_half = stream.try_clone()?;

    let n = images.len();
    let outstanding = Arc::new(AtomicUsize::new(0));
    let sent_at: Arc<Mutex<BTreeMap<u64, Instant>>> = Arc::new(Mutex::new(BTreeMap::new()));
    let latency = Arc::new(Histogram::new());
    let preds: Arc<Mutex<BTreeMap<u64, Result<usize, String>>>> =
        Arc::new(Mutex::new(BTreeMap::new()));

    let reader_outstanding = Arc::clone(&outstanding);
    let reader_sent_at = Arc::clone(&sent_at);
    let reader_latency = Arc::clone(&latency);
    let reader_preds = Arc::clone(&preds);
    let reader = std::thread::spawn(move || -> Result<usize, String> {
        let mut r = BufReader::new(read_half);
        let mut line = String::new();
        let mut received = 0usize;
        while received < n {
            line.clear();
            match r.read_line(&mut line) {
                Ok(0) => return Err(format!("server closed after {received}/{n}")),
                Ok(_) => {}
                Err(e) => return Err(format!("read failed after {received}/{n}: {e}")),
            }
            let j = Json::parse(line.trim()).map_err(|e| format!("bad response: {e}"))?;
            let id = match j.get("id").and_then(Json::as_f64) {
                Some(v) => v as u64,
                // id-less protocol error (shouldn't happen for well-formed
                // requests) — count it so the run still terminates
                None => {
                    return Err(format!("response without id: {}", line.trim()));
                }
            };
            if let Some(t0) = reader_sent_at.lock().unwrap().remove(&id) {
                reader_latency.record_ms(t0.elapsed().as_secs_f64() * 1e3);
            }
            let outcome = match j.get("class").and_then(Json::as_f64) {
                Some(c) => Ok(c as usize),
                None => Err(j
                    .get("error")
                    .and_then(Json::as_str)
                    .unwrap_or("malformed response")
                    .to_string()),
            };
            reader_preds.lock().unwrap().insert(id, outcome);
            reader_outstanding.fetch_sub(1, Ordering::AcqRel);
            received += 1;
        }
        Ok(received)
    });

    let t0 = Instant::now();
    let mut w = std::io::BufWriter::new(stream);
    let mut sent = 0usize;
    for (id, (pixels, _)) in images.iter().enumerate() {
        if outstanding.load(Ordering::Acquire) >= window {
            // about to block on the window: everything buffered must be
            // on the wire or the responses we wait for can never come
            w.flush()?;
        }
        while outstanding.load(Ordering::Acquire) >= window {
            if reader.is_finished() {
                break; // reader bailed; stop feeding a dead run
            }
            std::thread::sleep(Duration::from_micros(50));
        }
        if reader.is_finished() {
            break;
        }
        let mut line = String::with_capacity(pixels.len() * 10 + 32);
        let _ = write!(line, "{{\"id\":{id},\"image\":[");
        for (i, p) in pixels.iter().enumerate() {
            if i > 0 {
                line.push(',');
            }
            // shortest round-trip formatting straight into the buffer
            // (no per-pixel temporary): the server parses back the
            // exact f32 we hold
            let _ = write!(line, "{p}");
        }
        line.push_str("]}\n");
        sent_at.lock().unwrap().insert(id as u64, Instant::now());
        outstanding.fetch_add(1, Ordering::AcqRel);
        w.write_all(line.as_bytes())?;
        if window == 1 {
            w.flush()?;
        }
        sent += 1;
    }
    w.flush()?;

    let received = match reader.join() {
        Ok(Ok(r)) => r,
        Ok(Err(e)) => anyhow::bail!("client reader: {e}"),
        Err(_) => anyhow::bail!("client reader panicked"),
    };
    let wall_seconds = t0.elapsed().as_secs_f64();

    let preds = Arc::try_unwrap(preds)
        .map_err(|_| anyhow::anyhow!("reader still holds preds"))?
        .into_inner()
        .unwrap();
    let mut errors = 0usize;
    let mut correct = 0usize;
    for (id, outcome) in &preds {
        match outcome {
            Ok(class) => {
                if images[*id as usize].1 as usize == *class {
                    correct += 1;
                }
            }
            Err(_) => errors += 1,
        }
    }
    Ok(ClientReport {
        sent,
        received,
        errors,
        correct,
        wall_seconds,
        latency: latency.snapshot(),
        preds,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DatasetKind;
    use crate::serve::demo;
    use crate::serve::engine::{Backend, Engine, EngineConfig, ReferenceBackend};
    use crate::serve::packed::QuantizedCheckpoint;
    use crate::serve::server::Server;

    #[test]
    fn windowed_client_round_trips_small_batch() {
        let ck = demo::demo_checkpoint(DatasetKind::Cifar10, 4, 31, 8);
        let q = Arc::new(QuantizedCheckpoint::from_checkpoint(&ck, 4, |n| {
            n.ends_with(".w")
        }));
        let q2 = Arc::clone(&q);
        let engine = Engine::start(
            EngineConfig {
                workers: 1,
                queue_capacity: 64,
                max_delay: Duration::from_millis(1),
            },
            move |_| Ok(Box::new(ReferenceBackend::from_packed(&q2)?) as Box<dyn Backend>),
        )
        .unwrap();
        let server = Server::start("127.0.0.1:0", Arc::clone(&engine)).unwrap();

        let ds = crate::data::synth::generate(DatasetKind::Cifar10, 32, 77, 1);
        let images: Vec<(Vec<f32>, i32)> =
            (0..32).map(|i| (ds.image(i).to_vec(), ds.labels[i])).collect();
        let report = run(&server.addr.to_string(), &images, 8).unwrap();
        assert_eq!(report.sent, 32);
        assert_eq!(report.received, 32);
        assert_eq!(report.errors, 0);
        assert_eq!(report.preds.len(), 32);
        assert!(report.latency.count == 32);
        // every prediction matches the model's direct forward
        let direct = ReferenceBackend::from_packed(&q).unwrap();
        for (id, outcome) in &report.preds {
            assert_eq!(
                outcome.as_ref().ok().copied(),
                Some(direct.classify_one(ds.image(*id as usize)))
            );
        }
        server.stop();
        engine.shutdown();
    }
}

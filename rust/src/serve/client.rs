//! Demo load-generating client (DESIGN.md §7, §19): pipelines labeled
//! images over one TCP connection with a bounded in-flight window, then
//! reports client-observed latency percentiles, throughput, accuracy —
//! and, under overload, the attempted/retried/shed accounting that
//! makes the overload benches interpretable.
//!
//! `overloaded` replies are retried with jittered exponential backoff
//! that honors the server's `retry_after_ms` hint (the hint is a floor,
//! never a ceiling — the server knows its drain rate, the client adds
//! jitter so synchronized retry waves don't re-overload it). Every
//! other error (`bad_request`, `deadline_exceeded`, `queue_full`,
//! `inference_failed`) is final.
//!
//! Used by `adaqat client`, the serve bench's TCP mode, and the
//! end-to-end tests.

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::net::TcpStream;
use std::sync::mpsc;
use std::time::{Duration, Instant};

use crate::metrics::{Histogram, LatencySnapshot};
use crate::util::json::Json;
use crate::util::rng::Rng;

/// Give up on a run that makes no progress for this long (server hung,
/// response lost to a dropped connection, …).
const STALL_TIMEOUT: Duration = Duration::from_secs(60);
/// Backoff floor for the first retry; doubles per attempt.
const BACKOFF_BASE_MS: u64 = 10;

/// Load-generation knobs beyond the image list.
#[derive(Debug, Clone)]
pub struct ClientConfig {
    /// Max requests in flight (1 = single-stream).
    pub window: usize,
    /// Retry budget per request for `overloaded` replies; 0 = never
    /// retry (every rejection is recorded as shed).
    pub max_retries: u32,
    /// Attach this `deadline_ms` budget to every request (`None` =
    /// no deadline field; the server default applies).
    pub deadline_ms: Option<u64>,
    /// Seed for backoff jitter (deterministic load patterns in tests).
    pub seed: u64,
}

impl Default for ClientConfig {
    fn default() -> ClientConfig {
        ClientConfig { window: 32, max_retries: 4, deadline_ms: None, seed: 0x5eed }
    }
}

/// What one run observed, from the client's side of the socket.
pub struct ClientReport {
    /// First attempts written (one per image reached).
    pub sent: usize,
    /// Final outcomes received (== `preds.len()`).
    pub received: usize,
    /// All wire sends, retries included.
    pub attempted: usize,
    /// Retry sends (`attempted - sent` when every image was reached).
    pub retried: usize,
    /// Requests abandoned after exhausting the retry budget on
    /// `overloaded` replies.
    pub shed: usize,
    /// Final outcomes that are errors (sheds included).
    pub errors: usize,
    /// Predictions matching the supplied label.
    pub correct: usize,
    pub wall_seconds: f64,
    pub latency: LatencySnapshot,
    /// id → Ok(class) | Err(error code), for correctness cross-checks.
    pub preds: BTreeMap<u64, Result<usize, String>>,
}

impl ClientReport {
    pub fn requests_per_second(&self) -> f64 {
        if self.wall_seconds > 0.0 {
            self.received as f64 / self.wall_seconds
        } else {
            0.0
        }
    }
}

/// What the reader thread decoded from one response line.
enum Outcome {
    Class(usize),
    Overloaded { retry_after_ms: u64 },
    Error(String),
}

/// Send `images` (pixels, label) as requests `id = 0..n` with the
/// default retry policy. See [`run_with`] for the full dial set.
pub fn run(
    addr: &str,
    images: &[(Vec<f32>, i32)],
    window: usize,
) -> anyhow::Result<ClientReport> {
    run_with(addr, images, &ClientConfig { window, ..ClientConfig::default() })
}

/// Send `images` as requests `id = 0..n`, keeping at most `cfg.window`
/// in flight (ids map answers back to questions, so at most one attempt
/// per id is ever outstanding). `overloaded` replies are retried up to
/// `cfg.max_retries` times with jittered exponential backoff honoring
/// the server's `retry_after_ms`; exhausted budgets count as `shed`.
pub fn run_with(
    addr: &str,
    images: &[(Vec<f32>, i32)],
    cfg: &ClientConfig,
) -> anyhow::Result<ClientReport> {
    anyhow::ensure!(cfg.window >= 1, "window must be >= 1");
    let stream = TcpStream::connect(addr)?;
    stream.set_nodelay(true)?;
    let read_half = stream.try_clone()?;

    let n = images.len();
    let (ev_tx, ev_rx) = mpsc::channel::<Result<(u64, Outcome), String>>();
    let reader = std::thread::spawn(move || {
        let mut r = BufReader::new(read_half);
        let mut line = String::new();
        loop {
            line.clear();
            match r.read_line(&mut line) {
                Ok(0) => return, // EOF: run finished or server closed
                Ok(_) => {}
                Err(e) => {
                    let _ = ev_tx.send(Err(format!("read failed: {e}")));
                    return;
                }
            }
            let event = decode_line(line.trim());
            let fatal = event.is_err();
            if ev_tx.send(event).is_err() || fatal {
                return;
            }
        }
    });

    let t0 = Instant::now();
    let mut w = BufWriter::new(stream.try_clone()?);
    let mut rng = Rng::new(cfg.seed);
    let latency = Histogram::new();
    // per-id state: send time of the outstanding attempt, attempt index
    let mut in_flight: BTreeMap<u64, (Instant, u32)> = BTreeMap::new();
    // (ready_at, id, next attempt) — small (≤ window), linear scan is fine
    let mut backlog: Vec<(Instant, u64, u32)> = Vec::new();
    let mut preds: BTreeMap<u64, Result<usize, String>> = BTreeMap::new();
    let mut next_idx = 0usize;
    let (mut sent, mut attempted, mut retried, mut shed) = (0usize, 0usize, 0usize, 0usize);
    let mut last_progress = Instant::now();

    while preds.len() < n {
        // fill the window: due retries first (they hold older ids), then
        // fresh images
        let mut wrote = false;
        while in_flight.len() < cfg.window {
            let now = Instant::now();
            if let Some(pos) = backlog.iter().position(|(ready, _, _)| *ready <= now) {
                let (_, id, attempt) = backlog.swap_remove(pos);
                write_request(&mut w, id, &images[id as usize].0, cfg.deadline_ms)?;
                in_flight.insert(id, (Instant::now(), attempt));
                attempted += 1;
                retried += 1;
                wrote = true;
            } else if next_idx < n {
                let id = next_idx as u64;
                write_request(&mut w, id, &images[next_idx].0, cfg.deadline_ms)?;
                in_flight.insert(id, (Instant::now(), 0));
                next_idx += 1;
                sent += 1;
                attempted += 1;
                wrote = true;
            } else {
                break;
            }
        }
        if wrote || cfg.window == 1 {
            // everything buffered must be on the wire before we wait,
            // or the responses we block on can never arrive
            w.flush()?;
        }

        // wait for one event (short timeout so due retries stay timely)
        let event = match ev_rx.recv_timeout(Duration::from_millis(1)) {
            Ok(ev) => ev,
            Err(mpsc::RecvTimeoutError::Timeout) => {
                if last_progress.elapsed() > STALL_TIMEOUT {
                    anyhow::bail!(
                        "client stalled: {}/{n} outcomes after {:?}",
                        preds.len(),
                        STALL_TIMEOUT
                    );
                }
                continue;
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                anyhow::bail!("server closed after {}/{n} outcomes", preds.len())
            }
        };
        let (id, outcome) = event.map_err(|e| anyhow::anyhow!("client reader: {e}"))?;
        let Some((sent_t, attempt)) = in_flight.remove(&id) else {
            anyhow::bail!("response for id {id} which is not in flight");
        };
        last_progress = Instant::now();
        match outcome {
            Outcome::Class(c) => {
                latency.record_ms(sent_t.elapsed().as_secs_f64() * 1e3);
                preds.insert(id, Ok(c));
            }
            Outcome::Overloaded { retry_after_ms } => {
                if attempt < cfg.max_retries {
                    // server hint is the floor; exponential backoff and
                    // jitter de-synchronize concurrent retriers
                    let base = retry_after_ms.max(BACKOFF_BASE_MS << attempt);
                    let jitter = rng.below((base / 2 + 1) as usize) as u64;
                    backlog.push((
                        Instant::now() + Duration::from_millis(base + jitter),
                        id,
                        attempt + 1,
                    ));
                } else {
                    shed += 1;
                    preds.insert(id, Err("overloaded (retry budget exhausted)".into()));
                }
            }
            Outcome::Error(code) => {
                preds.insert(id, Err(code));
            }
        }
    }

    let wall_seconds = t0.elapsed().as_secs_f64();
    // unblock the reader (it is parked in read_line) and reap it
    let _ = stream.shutdown(std::net::Shutdown::Both);
    let _ = reader.join();

    let mut errors = 0usize;
    let mut correct = 0usize;
    for (id, outcome) in &preds {
        match outcome {
            Ok(class) => {
                if images[*id as usize].1 as usize == *class {
                    correct += 1;
                }
            }
            Err(_) => errors += 1,
        }
    }
    Ok(ClientReport {
        sent,
        received: preds.len(),
        attempted,
        retried,
        shed,
        errors,
        correct,
        wall_seconds,
        latency: latency.snapshot(),
        preds,
    })
}

/// Decode one response line into (id, outcome). Lines without an id
/// are fatal — they cannot be correlated, so the run cannot finish.
fn decode_line(line: &str) -> Result<(u64, Outcome), String> {
    let j = Json::parse(line).map_err(|e| format!("bad response: {e}"))?;
    let id = j
        .get("id")
        .and_then(Json::as_f64)
        .ok_or_else(|| format!("response without id: {line}"))? as u64;
    if let Some(c) = j.get("class").and_then(Json::as_f64) {
        return Ok((id, Outcome::Class(c as usize)));
    }
    let code = j
        .get("error")
        .and_then(Json::as_str)
        .unwrap_or("malformed response")
        .to_string();
    if code == "overloaded" {
        let retry_after_ms =
            j.get("retry_after_ms").and_then(Json::as_f64).unwrap_or(0.0) as u64;
        return Ok((id, Outcome::Overloaded { retry_after_ms }));
    }
    Ok((id, Outcome::Error(code)))
}

/// Serialize and buffer one request line (flushing is the caller's
/// windowing decision).
fn write_request(
    w: &mut BufWriter<TcpStream>,
    id: u64,
    pixels: &[f32],
    deadline_ms: Option<u64>,
) -> anyhow::Result<()> {
    let mut line = String::with_capacity(pixels.len() * 10 + 48);
    let _ = write!(line, "{{\"id\":{id},\"image\":[");
    for (i, p) in pixels.iter().enumerate() {
        if i > 0 {
            line.push(',');
        }
        // shortest round-trip formatting straight into the buffer (no
        // per-pixel temporary): the server parses back the exact f32
        let _ = write!(line, "{p}");
    }
    line.push(']');
    if let Some(d) = deadline_ms {
        let _ = write!(line, ",\"deadline_ms\":{d}");
    }
    line.push_str("}\n");
    w.write_all(line.as_bytes())?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DatasetKind;
    use crate::serve::demo;
    use crate::serve::engine::{Backend, Engine, EngineConfig, ReferenceBackend};
    use crate::serve::packed::QuantizedCheckpoint;
    use crate::serve::server::Server;
    use std::sync::Arc;

    #[test]
    fn windowed_client_round_trips_small_batch() {
        let ck = demo::demo_checkpoint(DatasetKind::Cifar10, 4, 31, 8);
        let q = Arc::new(QuantizedCheckpoint::from_checkpoint(&ck, 4, |n| {
            n.ends_with(".w")
        }));
        let q2 = Arc::clone(&q);
        let engine = Engine::start(
            EngineConfig {
                workers: 1,
                queue_capacity: 64,
                max_delay: Duration::from_millis(1),
                ..EngineConfig::default()
            },
            move |_| Ok(Box::new(ReferenceBackend::from_packed(&q2)?) as Box<dyn Backend>),
        )
        .unwrap();
        let server = Server::start("127.0.0.1:0", Arc::clone(&engine)).unwrap();

        let ds = crate::data::synth::generate(DatasetKind::Cifar10, 32, 77, 1);
        let images: Vec<(Vec<f32>, i32)> =
            (0..32).map(|i| (ds.image(i).to_vec(), ds.labels[i])).collect();
        let report = run(&server.addr.to_string(), &images, 8).unwrap();
        assert_eq!(report.sent, 32);
        assert_eq!(report.received, 32);
        assert_eq!(report.errors, 0);
        // a clean run retries and sheds nothing
        assert_eq!(report.attempted, 32);
        assert_eq!(report.retried, 0);
        assert_eq!(report.shed, 0);
        assert_eq!(report.preds.len(), 32);
        assert!(report.latency.count == 32);
        // every prediction matches the model's direct forward
        let direct = ReferenceBackend::from_packed(&q).unwrap();
        for (id, outcome) in &report.preds {
            assert_eq!(
                outcome.as_ref().ok().copied(),
                Some(direct.classify_one(ds.image(*id as usize)))
            );
        }
        server.stop();
        engine.shutdown();
    }

    #[test]
    fn deadline_field_rides_along_and_zero_budget_is_a_final_error() {
        let ck = demo::demo_checkpoint(DatasetKind::Cifar10, 4, 13, 8);
        let q = Arc::new(QuantizedCheckpoint::from_checkpoint(&ck, 4, |n| {
            n.ends_with(".w")
        }));
        let q2 = Arc::clone(&q);
        let engine = Engine::start(
            EngineConfig {
                workers: 1,
                queue_capacity: 64,
                max_delay: Duration::from_millis(1),
                ..EngineConfig::default()
            },
            move |_| Ok(Box::new(ReferenceBackend::from_packed(&q2)?) as Box<dyn Backend>),
        )
        .unwrap();
        let server = Server::start("127.0.0.1:0", Arc::clone(&engine)).unwrap();
        let ds = crate::data::synth::generate(DatasetKind::Cifar10, 8, 5, 1);
        let images: Vec<(Vec<f32>, i32)> =
            (0..8).map(|i| (ds.image(i).to_vec(), ds.labels[i])).collect();
        // zero budget: every request expires at admission — a final,
        // structured error, never a retry, never a stale answer
        let report = run_with(
            &server.addr.to_string(),
            &images,
            &ClientConfig { window: 4, deadline_ms: Some(0), ..ClientConfig::default() },
        )
        .unwrap();
        assert_eq!(report.received, 8);
        assert_eq!(report.errors, 8);
        assert_eq!(report.retried, 0);
        assert_eq!(report.shed, 0);
        for outcome in report.preds.values() {
            assert_eq!(outcome.as_ref().unwrap_err(), "deadline_exceeded");
        }
        // a generous budget answers everything
        let report = run_with(
            &server.addr.to_string(),
            &images,
            &ClientConfig {
                window: 4,
                deadline_ms: Some(60_000),
                ..ClientConfig::default()
            },
        )
        .unwrap();
        assert_eq!(report.received, 8);
        assert_eq!(report.errors, 0);
        server.stop();
        engine.shutdown();
    }
}

//! Wire protocol: newline-delimited JSON over TCP (DESIGN.md §7).
//!
//! `serde` is unavailable offline (DESIGN.md §3), so framing is built on
//! `util::json`. One JSON object per line, each direction:
//!
//! ```text
//!   → {"id": 7, "image": [f32 × h·w·c], "deadline_ms": 250}
//!     (deadline_ms optional: budget from arrival; 0 = already dead)
//!   → {"cmd": "ping"}                        liveness probe
//!   → {"cmd": "stats"}                       latency/throughput counters
//!   → {"cmd": "metrics"}                     Prometheus text exposition
//!   → {"cmd": "trace"}                       recent request spans
//!   → {"cmd": "drain"}                       begin graceful shutdown
//!   ← {"id": 7, "class": 3, "queue_ms": 0.8, "compute_ms": 1.9}
//!   ← {"id": 7, "error": "overloaded", "retry_after_ms": 12, "detail": …}
//!   ← {"id": 7, "error": "deadline_exceeded", "stage": "batch", …}
//!   ← {"error": "bad_request", "detail": "…"}   (parse/cap violations)
//!   ← {"ok": true}                           pong
//!   ← {"ok": true, "draining": true}         drain acknowledged
//!   ← {"requests": …, "queue_p50_ms": …, …}  stats
//!   ← {"metrics": "adaqat_…{…} v\n…"}        exposition as one string
//!   ← {"traces": [{"id": …, "enqueue_us": …, …}, …]}
//! ```
//!
//! Every error frame carries a machine-readable `error` code
//! (`bad_request`, `queue_full`, `shutting_down`, `overloaded`,
//! `deadline_exceeded`, `inference_failed`) plus a human `detail` —
//! overload clients branch on the code (DESIGN.md §19).

use std::sync::atomic::Ordering;

use crate::obs::RequestTrace;
use crate::util::json::Json;

use super::engine::{EngineMetrics, SubmitError};
use super::queue::{ServeError, ServeResponse};

/// A parsed inbound line.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    Infer {
        id: u64,
        pixels: Vec<f32>,
        /// Client deadline budget in ms from arrival (`None` = server
        /// default applies).
        deadline_ms: Option<u64>,
    },
    Ping,
    Stats,
    /// Prometheus text exposition of every registered series.
    Metrics,
    /// Recent request spans from the engine's trace ring.
    Trace,
    /// Admin: begin graceful drain (close listener, finish in-flight).
    Drain,
}

/// Parse one request line. Errors are strings ready to ship back via
/// [`error_line`] under the `bad_request` code.
pub fn parse_request(line: &str) -> Result<Request, String> {
    let j = Json::parse(line).map_err(|e| e.to_string())?;
    if let Some(cmd) = j.get("cmd").and_then(Json::as_str) {
        return match cmd {
            "ping" => Ok(Request::Ping),
            "stats" => Ok(Request::Stats),
            "metrics" => Ok(Request::Metrics),
            "trace" => Ok(Request::Trace),
            "drain" => Ok(Request::Drain),
            other => Err(format!("unknown cmd {other:?}")),
        };
    }
    let image = j
        .get("image")
        .and_then(Json::as_arr)
        .ok_or_else(|| "request needs \"image\" (array) or \"cmd\"".to_string())?;
    let mut pixels = Vec::with_capacity(image.len());
    for v in image {
        pixels.push(
            v.as_f64().ok_or_else(|| "image must be all numbers".to_string())? as f32,
        );
    }
    let id = match j.get("id") {
        None => 0,
        Some(v) => {
            let f = v
                .as_f64()
                .ok_or_else(|| "id must be a number".to_string())?;
            // reject anything a u64 echo could not round-trip exactly —
            // pipelined clients correlate responses by id
            if f < 0.0 || f.fract() != 0.0 || f >= 9_007_199_254_740_992.0 {
                return Err("id must be a non-negative integer < 2^53".to_string());
            }
            f as u64
        }
    };
    let deadline_ms = match j.get("deadline_ms") {
        None => None,
        Some(v) => {
            let f = v
                .as_f64()
                .ok_or_else(|| "deadline_ms must be a number".to_string())?;
            if f < 0.0 || f.fract() != 0.0 || f >= 9_007_199_254_740_992.0 {
                return Err(
                    "deadline_ms must be a non-negative integer < 2^53".to_string()
                );
            }
            Some(f as u64)
        }
    };
    Ok(Request::Infer { id, pixels, deadline_ms })
}

/// Serialize an engine response (success or per-request failure).
pub fn response_line(resp: &ServeResponse) -> String {
    let mut pairs = vec![("id", Json::num(resp.id as f64))];
    match &resp.result {
        Ok(class) => pairs.push(("class", Json::num(*class as f64))),
        Err(e) => {
            pairs.push(("error", Json::str(e.code())));
            match e {
                ServeError::DeadlineExceeded { stage } => {
                    pairs.push(("stage", Json::str(stage.label())));
                }
                ServeError::Overloaded { retry_after_ms } => {
                    pairs.push(("retry_after_ms", Json::num(*retry_after_ms as f64)));
                }
                ServeError::Inference(msg) => {
                    pairs.push(("detail", Json::str(msg.clone())));
                }
            }
        }
    }
    pairs.push(("queue_ms", Json::num(round3(resp.queue_ms))));
    pairs.push(("compute_ms", Json::num(round3(resp.compute_ms))));
    Json::obj(pairs).to_string()
}

/// Protocol-level error frame: machine-readable `code` + human
/// `detail` (parse failures and cap violations use `bad_request`).
pub fn error_line(id: Option<u64>, code: &str, detail: &str) -> String {
    let mut pairs = vec![];
    if let Some(id) = id {
        pairs.push(("id", Json::num(id as f64)));
    }
    pairs.push(("error", Json::str(code)));
    if !detail.is_empty() {
        pairs.push(("detail", Json::str(detail)));
    }
    Json::obj(pairs).to_string()
}

/// The error frame for a refused `submit`: code per variant, plus
/// `retry_after_ms` on `overloaded` (always present and finite there —
/// the client backoff contract) and `stage` on `deadline_exceeded`.
pub fn submit_error_line(id: u64, e: &SubmitError) -> String {
    let code = match e {
        SubmitError::BadInput { .. } => "bad_request",
        SubmitError::Full => "queue_full",
        SubmitError::Closed => "shutting_down",
        SubmitError::Overloaded { .. } => "overloaded",
        SubmitError::DeadlineExceeded => "deadline_exceeded",
    };
    let mut pairs = vec![
        ("id", Json::num(id as f64)),
        ("error", Json::str(code)),
    ];
    match e {
        SubmitError::Overloaded { retry_after_ms } => {
            pairs.push(("retry_after_ms", Json::num(*retry_after_ms as f64)));
        }
        SubmitError::DeadlineExceeded => {
            pairs.push(("stage", Json::str("admission")));
        }
        _ => {}
    }
    pairs.push(("detail", Json::str(e.to_string())));
    Json::obj(pairs).to_string()
}

pub fn pong_line() -> String {
    Json::obj(vec![("ok", Json::Bool(true))]).to_string()
}

/// Acknowledge a `{"cmd":"drain"}`: the listener is closing; in-flight
/// requests finish against their deadlines.
pub fn drain_line() -> String {
    Json::obj(vec![("ok", Json::Bool(true)), ("draining", Json::Bool(true))]).to_string()
}

/// Snapshot the engine counters as one stats object. `queue_depth`,
/// the shed counts, and the overload counts come from the live queue
/// and admission policy (the engine owns them, the metrics struct does
/// not), so the server passes them alongside. `overload` is
/// (admission rejections, admission-stage expiries, batch-stage
/// expiries) as [`Engine::overload_counts`] reports them.
///
/// [`Engine::overload_counts`]: super::engine::Engine::overload_counts
pub fn stats_line(
    m: &EngineMetrics,
    queue_depth: usize,
    shed: (u64, u64),
    overload: (u64, u64, u64),
) -> String {
    let q = m.queue.snapshot();
    let c = m.compute.snapshot();
    Json::obj(vec![
        ("requests", Json::num(m.requests.load(Ordering::Relaxed) as f64)),
        ("failures", Json::num(m.failures.load(Ordering::Relaxed) as f64)),
        ("batches", Json::num(m.batches.load(Ordering::Relaxed) as f64)),
        // unfilled coalescing slots; only static-shape backends pad
        // them with real zero rows (see EngineMetrics::padded)
        ("unfilled_slots", Json::num(m.padded.load(Ordering::Relaxed) as f64)),
        ("queue_depth", Json::num(queue_depth as f64)),
        ("shed_full", Json::num(shed.0 as f64)),
        ("shed_closed", Json::num(shed.1 as f64)),
        ("overloaded", Json::num(overload.0 as f64)),
        ("deadline_admission", Json::num(overload.1 as f64)),
        ("deadline_batch", Json::num(overload.2 as f64)),
        ("queue_p50_ms", Json::num(round3(q.p50_ms))),
        ("queue_p95_ms", Json::num(round3(q.p95_ms))),
        ("queue_p99_ms", Json::num(round3(q.p99_ms))),
        ("compute_p50_ms", Json::num(round3(c.p50_ms))),
        ("compute_p95_ms", Json::num(round3(c.p95_ms))),
        ("compute_p99_ms", Json::num(round3(c.p99_ms))),
    ])
    .to_string()
}

/// Wrap the (multi-line) Prometheus exposition in a one-line JSON
/// object — `util::json` escapes the newlines, so NDJSON framing holds.
pub fn metrics_line(text: &str) -> String {
    Json::obj(vec![("metrics", Json::str(text))]).to_string()
}

/// Serialize the trace-ring snapshot, oldest span first.
pub fn trace_line(traces: &[RequestTrace]) -> String {
    let arr = traces
        .iter()
        .map(|t| {
            Json::obj(vec![
                ("id", Json::num(t.id as f64)),
                ("enqueue_us", Json::num(t.enqueue_us as f64)),
                ("batch_us", Json::num(t.batch_us as f64)),
                ("compute_done_us", Json::num(t.compute_done_us as f64)),
                ("reply_us", Json::num(t.reply_us as f64)),
                ("rows", Json::num(t.rows as f64)),
                ("ok", Json::Bool(t.ok)),
            ])
        })
        .collect();
    Json::obj(vec![("traces", Json::Arr(arr))]).to_string()
}

/// Keep emitted latencies short and round-trippable.
fn round3(ms: f64) -> f64 {
    (ms * 1000.0).round() / 1000.0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_infer_request() {
        let r = parse_request(r#"{"id": 9, "image": [0.5, -1.25, 3]}"#).unwrap();
        assert_eq!(
            r,
            Request::Infer { id: 9, pixels: vec![0.5, -1.25, 3.0], deadline_ms: None }
        );
        // id defaults to 0
        let r = parse_request(r#"{"image": []}"#).unwrap();
        assert_eq!(r, Request::Infer { id: 0, pixels: vec![], deadline_ms: None });
    }

    #[test]
    fn parses_and_validates_deadline_ms() {
        let r = parse_request(r#"{"id": 1, "image": [1], "deadline_ms": 250}"#).unwrap();
        assert_eq!(
            r,
            Request::Infer { id: 1, pixels: vec![1.0], deadline_ms: Some(250) }
        );
        // zero is legal — it means "already expired", a deterministic
        // way to exercise the admission-stage deadline path
        let r = parse_request(r#"{"image": [1], "deadline_ms": 0}"#).unwrap();
        assert_eq!(r, Request::Infer { id: 0, pixels: vec![1.0], deadline_ms: Some(0) });
        assert!(parse_request(r#"{"image": [1], "deadline_ms": -5}"#).is_err());
        assert!(parse_request(r#"{"image": [1], "deadline_ms": 1.5}"#).is_err());
        assert!(parse_request(r#"{"image": [1], "deadline_ms": "soon"}"#).is_err());
    }

    #[test]
    fn parses_commands_and_rejects_garbage() {
        assert_eq!(parse_request(r#"{"cmd": "ping"}"#).unwrap(), Request::Ping);
        assert_eq!(parse_request(r#"{"cmd": "stats"}"#).unwrap(), Request::Stats);
        assert_eq!(parse_request(r#"{"cmd": "metrics"}"#).unwrap(), Request::Metrics);
        assert_eq!(parse_request(r#"{"cmd": "trace"}"#).unwrap(), Request::Trace);
        assert_eq!(parse_request(r#"{"cmd": "drain"}"#).unwrap(), Request::Drain);
        assert!(parse_request(r#"{"cmd": "reboot"}"#).is_err());
        assert!(parse_request("not json").is_err());
        assert!(parse_request(r#"{"id": 1}"#).is_err());
        assert!(parse_request(r#"{"image": ["a"]}"#).is_err());
    }

    #[test]
    fn non_roundtrippable_ids_are_rejected() {
        // a u64 echo must return exactly the id the client sent —
        // anything else breaks pipelined correlation
        assert!(parse_request(r#"{"id": -1, "image": [1]}"#).is_err());
        assert!(parse_request(r#"{"id": 1.5, "image": [1]}"#).is_err());
        assert!(parse_request(r#"{"id": 9007199254740992, "image": [1]}"#).is_err());
        assert!(parse_request(r#"{"id": "7", "image": [1]}"#).is_err());
        assert!(parse_request(r#"{"id": 9007199254740991, "image": [1]}"#).is_ok());
    }

    #[test]
    fn response_lines_roundtrip_through_json() {
        let ok = ServeResponse {
            id: 3,
            result: Ok(7),
            queue_ms: 0.1234567,
            compute_ms: 2.5,
        };
        let j = Json::parse(&response_line(&ok)).unwrap();
        assert_eq!(j.get("id").unwrap().as_f64(), Some(3.0));
        assert_eq!(j.get("class").unwrap().as_f64(), Some(7.0));
        assert_eq!(j.get("queue_ms").unwrap().as_f64(), Some(0.123));
        assert!(j.get("error").is_none());

        let err = ServeResponse {
            id: 4,
            result: Err(ServeError::Inference("kernel exploded".to_string())),
            queue_ms: 0.0,
            compute_ms: 0.0,
        };
        let j = Json::parse(&response_line(&err)).unwrap();
        assert!(j.get("class").is_none());
        assert_eq!(j.get("error").unwrap().as_str(), Some("inference_failed"));
        assert!(j.get("detail").unwrap().as_str().unwrap().contains("exploded"));

        let dl = ServeResponse {
            id: 5,
            result: Err(ServeError::DeadlineExceeded {
                stage: crate::serve::queue::DeadlineStage::Batch,
            }),
            queue_ms: 7.0,
            compute_ms: 0.0,
        };
        let j = Json::parse(&response_line(&dl)).unwrap();
        assert_eq!(j.get("error").unwrap().as_str(), Some("deadline_exceeded"));
        assert_eq!(j.get("stage").unwrap().as_str(), Some("batch"));
        assert!(j.get("class").is_none());
    }

    #[test]
    fn submit_error_lines_carry_machine_codes() {
        let j = Json::parse(&submit_error_line(
            7,
            &SubmitError::Overloaded { retry_after_ms: 12 },
        ))
        .unwrap();
        assert_eq!(j.get("id").unwrap().as_f64(), Some(7.0));
        assert_eq!(j.get("error").unwrap().as_str(), Some("overloaded"));
        assert_eq!(j.get("retry_after_ms").unwrap().as_f64(), Some(12.0));

        let j = Json::parse(&submit_error_line(8, &SubmitError::DeadlineExceeded)).unwrap();
        assert_eq!(j.get("error").unwrap().as_str(), Some("deadline_exceeded"));
        assert_eq!(j.get("stage").unwrap().as_str(), Some("admission"));

        let j = Json::parse(&submit_error_line(9, &SubmitError::Full)).unwrap();
        assert_eq!(j.get("error").unwrap().as_str(), Some("queue_full"));
        let j = Json::parse(&submit_error_line(10, &SubmitError::Closed)).unwrap();
        assert_eq!(j.get("error").unwrap().as_str(), Some("shutting_down"));
        let j = Json::parse(&submit_error_line(
            11,
            &SubmitError::BadInput { got: 3, want: 4 },
        ))
        .unwrap();
        assert_eq!(j.get("error").unwrap().as_str(), Some("bad_request"));
        assert!(j.get("detail").unwrap().as_str().unwrap().contains("3"));
    }

    #[test]
    fn error_pong_and_drain_lines_are_valid_json() {
        let j = Json::parse(&error_line(Some(5), "bad_request", "boom")).unwrap();
        assert_eq!(j.get("id").unwrap().as_f64(), Some(5.0));
        assert_eq!(j.get("error").unwrap().as_str(), Some("bad_request"));
        assert_eq!(j.get("detail").unwrap().as_str(), Some("boom"));
        let j = Json::parse(&error_line(None, "bad_request", "bad \"quote\"")).unwrap();
        assert!(j.get("id").is_none());
        assert!(j.get("detail").unwrap().as_str().unwrap().contains('"'));
        let j = Json::parse(&pong_line()).unwrap();
        assert_eq!(j.get("ok").unwrap().as_bool(), Some(true));
        assert!(j.get("draining").is_none());
        let j = Json::parse(&drain_line()).unwrap();
        assert_eq!(j.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(j.get("draining").unwrap().as_bool(), Some(true));
    }

    #[test]
    fn stats_line_reports_counters() {
        let m = EngineMetrics::default();
        m.requests.store(12, Ordering::Relaxed);
        m.queue.record_ms(1.0);
        m.compute.record_ms(2.0);
        let j = Json::parse(&stats_line(&m, 3, (5, 1), (2, 1, 4))).unwrap();
        assert_eq!(j.get("requests").unwrap().as_f64(), Some(12.0));
        assert_eq!(j.get("queue_depth").unwrap().as_f64(), Some(3.0));
        assert_eq!(j.get("shed_full").unwrap().as_f64(), Some(5.0));
        assert_eq!(j.get("shed_closed").unwrap().as_f64(), Some(1.0));
        assert_eq!(j.get("overloaded").unwrap().as_f64(), Some(2.0));
        assert_eq!(j.get("deadline_admission").unwrap().as_f64(), Some(1.0));
        assert_eq!(j.get("deadline_batch").unwrap().as_f64(), Some(4.0));
        assert!(j.get("queue_p50_ms").unwrap().as_f64().unwrap() > 0.0);
        assert!(j.get("compute_p99_ms").unwrap().as_f64().unwrap() > 0.0);
    }

    #[test]
    fn metrics_line_survives_ndjson_framing() {
        // the exposition is multi-line by nature; the frame must not be
        let text = "adaqat_queue_depth 0\nadaqat_pool_active 1\n";
        let line = metrics_line(text);
        assert!(!line.contains('\n'), "frame must stay a single line");
        let j = Json::parse(&line).unwrap();
        assert_eq!(j.get("metrics").unwrap().as_str(), Some(text));
    }

    #[test]
    fn trace_line_serializes_spans_in_order() {
        let traces = [
            RequestTrace {
                id: 7,
                enqueue_us: 10,
                batch_us: 20,
                compute_done_us: 30,
                reply_us: 40,
                rows: 4,
                ok: true,
            },
            RequestTrace {
                id: 8,
                enqueue_us: 50,
                batch_us: 60,
                compute_done_us: 70,
                reply_us: 80,
                rows: 1,
                ok: false,
            },
        ];
        let j = Json::parse(&trace_line(&traces)).unwrap();
        let arr = j.get("traces").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 2);
        assert_eq!(arr[0].get("id").unwrap().as_f64(), Some(7.0));
        assert_eq!(arr[0].get("enqueue_us").unwrap().as_f64(), Some(10.0));
        assert_eq!(arr[0].get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(arr[1].get("rows").unwrap().as_f64(), Some(1.0));
        assert_eq!(arr[1].get("ok").unwrap().as_bool(), Some(false));
        let empty = Json::parse(&trace_line(&[])).unwrap();
        assert_eq!(empty.get("traces").unwrap().as_arr().unwrap().len(), 0);
    }
}

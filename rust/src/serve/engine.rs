//! Multi-worker inference engine (DESIGN.md §7).
//!
//! N worker threads each own one [`Backend`] instance (PJRT clients are
//! `Rc`-based and `!Send`, so backends are constructed *on* their worker
//! thread via a factory), pull coalesced batches from the shared
//! [`RequestQueue`], run the forward pass over the *real* row count
//! (static-shape backends pad internally), and answer each request
//! through its own response channel while recording queue/compute
//! latency into the engine's histograms.
//!
//! Two backends:
//! * [`RuntimeBackend`] — the compiled "infer" graph on the PJRT
//!   runtime, state loaded from a dequantized packed checkpoint.
//! * [`ReferenceBackend`] — a pure-Rust quantized model (single fc or
//!   an MLP stack) over a packed checkpoint, running the integer-domain
//!   kernels in [`crate::kernels`]. It exists so the whole serving
//!   pipeline — packing, batching, workers, wire protocol — runs and
//!   benches in the offline build, and doubles as the nearest-centroid
//!   demo model for the synthetic datasets.

use std::fmt;
use std::panic::AssertUnwindSafe;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use crate::kernels::{QuantConvNet, QuantMlp, WorkerPool};
use crate::metrics::Histogram;
use crate::obs::{self, Registry, RequestTrace, TraceRing};
use crate::quant::bitwidth_scale;
use crate::runtime::{ModelRuntime, Runtime, TrainState};
use crate::tensor::Tensor;
use crate::util::failpoint;

use super::admission::{AdmissionControl, Decision};
use super::batcher::DynamicBatcher;
use super::packed::QuantizedCheckpoint;
use super::queue::{PushError, RequestQueue, ServeError, ServeRequest, ServeResponse};

/// A model that classifies one coalesced batch at a time.
pub trait Backend {
    /// (h, w, c) of one input image.
    fn input_shape(&self) -> (usize, usize, usize);
    /// Upper bound on rows per `infer` call (the batcher's coalescing
    /// target; static-shape backends also pad up to it internally).
    fn max_batch(&self) -> usize;
    fn num_classes(&self) -> usize;
    /// `x` is (rows, h, w, c) with 1 ≤ rows ≤ `max_batch()` — the
    /// *real* request count, no padding; returns `rows` predicted
    /// classes. Backends whose compiled graph has a static batch shape
    /// (PJRT) pad internally and truncate the answer; dynamic backends
    /// do `rows` of work, so a 1-image batch costs 1 image.
    fn infer(&self, x: &Tensor) -> anyhow::Result<Vec<usize>>;
}

/// Shared counters + latency histograms.
#[derive(Default)]
pub struct EngineMetrics {
    /// Time from enqueue to batch pickup, per request.
    pub queue: Histogram,
    /// Forward-pass wall time, per request (all requests in a batch see
    /// the same compute time — that is the cost model of batching).
    pub compute: Histogram,
    pub requests: AtomicU64,
    pub failures: AtomicU64,
    pub batches: AtomicU64,
    /// Unfilled batch slots across all batches (the coalescing
    /// occupancy complement the serve bench reports). Only static-shape
    /// backends (PJRT) actually compute these as zero rows — the
    /// kernels-backed reference backend does `rows`-only work, so for
    /// it this measures batcher occupancy, not wasted compute.
    pub padded: AtomicU64,
    /// Static rows per batch (set once at engine start; denominators).
    pub batch_rows: AtomicU64,
    /// Last-N request spans, enqueue → batch → compute → reply
    /// (DESIGN.md §15); the `trace` protocol command reads this.
    pub trace: TraceRing,
}

impl EngineMetrics {
    pub fn report(&self) -> String {
        let batches = self.batches.load(Ordering::Relaxed);
        let batch_rows = self.batch_rows.load(Ordering::Relaxed);
        // before the first batch lands there is no occupancy to speak
        // of — the old max(1) denominator clamp made an idle engine
        // read a perfect "100.0%" instead of admitting it has no data
        let occupancy = if batches == 0 || batch_rows == 0 {
            "n/a".to_string()
        } else {
            let denom = (batches * batch_rows) as f64;
            format!(
                "{:.1}%",
                100.0 * (1.0 - self.padded.load(Ordering::Relaxed) as f64 / denom)
            )
        };
        format!(
            "{}\n{}\nrequests {}  failures {}  batches {}  mean occupancy {}",
            self.queue.snapshot().row("queue"),
            self.compute.snapshot().row("compute"),
            self.requests.load(Ordering::Relaxed),
            self.failures.load(Ordering::Relaxed),
            batches,
            occupancy,
        )
    }
}

/// Engine construction parameters (`ServeConfig` maps onto this).
#[derive(Debug, Clone)]
pub struct EngineConfig {
    pub workers: usize,
    pub queue_capacity: usize,
    /// Dynamic-batching window: max time a lone request waits for
    /// company before a partial batch ships.
    pub max_delay: Duration,
    /// Deadline applied to requests that carry none of their own
    /// (`--default_deadline_ms`; `None` = requests without a
    /// `deadline_ms` field never expire).
    pub default_deadline: Option<Duration>,
    /// Arms admission control (`--max_wait_ms`): reject before the
    /// queue when the estimated wait exceeds this bound. `None`
    /// disarms the policy — capacity backpressure only.
    pub max_wait: Option<Duration>,
}

impl Default for EngineConfig {
    fn default() -> EngineConfig {
        EngineConfig {
            workers: 2,
            queue_capacity: 1024,
            max_delay: Duration::from_millis(5),
            default_deadline: None,
            max_wait: None,
        }
    }
}

/// Fatal submit outcomes (distinct from per-request inference failures,
/// which come back through the response channel).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SubmitError {
    BadInput { got: usize, want: usize },
    Full,
    Closed,
    /// Admission control refused the request; the hint is finite and
    /// drain-rate-derived (DESIGN.md §19).
    Overloaded { retry_after_ms: u64 },
    /// The request's deadline was already unmeetable at admission.
    DeadlineExceeded,
}

impl fmt::Display for SubmitError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SubmitError::BadInput { got, want } => {
                write!(f, "image has {got} values, model wants {want}")
            }
            SubmitError::Full => f.write_str("queue full (backpressure)"),
            SubmitError::Closed => f.write_str("server shutting down"),
            SubmitError::Overloaded { retry_after_ms } => {
                write!(f, "overloaded (retry after {retry_after_ms} ms)")
            }
            SubmitError::DeadlineExceeded => {
                f.write_str("deadline exceeded (stage admission)")
            }
        }
    }
}

/// Everything a worker thread needs besides its backend; bundled so
/// the spawn sites stay readable as the pipeline grows dials.
struct WorkerCtx {
    queue: Arc<RequestQueue>,
    metrics: Arc<EngineMetrics>,
    admission: Arc<AdmissionControl>,
    batch_rows: Arc<obs::HistHandle>,
    max_delay: Duration,
}

/// The running engine: queue + workers + metrics.
pub struct Engine {
    queue: Arc<RequestQueue>,
    pub metrics: Arc<EngineMetrics>,
    admission: Arc<AdmissionControl>,
    input_numel: usize,
    num_classes: usize,
    batch: usize,
    default_deadline: Option<Duration>,
    workers: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

impl Engine {
    /// Spawn `cfg.workers` threads, each building its own backend via
    /// `factory(worker_id)`. Blocks until every worker reports ready (or
    /// any factory fails, which tears the engine down).
    pub fn start<F>(cfg: EngineConfig, factory: F) -> anyhow::Result<Arc<Engine>>
    where
        F: Fn(usize) -> anyhow::Result<Box<dyn Backend>> + Send + Sync + 'static,
    {
        Self::start_with_obs(cfg, factory, obs::global())
    }

    /// [`start`](Engine::start) against an explicit registry: the
    /// queue/admission/batch-rows series register there instead of the
    /// global one, so chaos tests assert exact counter conservation
    /// while unrelated tests serve traffic in parallel.
    pub fn start_with_obs<F>(
        cfg: EngineConfig,
        factory: F,
        reg: &Registry,
    ) -> anyhow::Result<Arc<Engine>>
    where
        F: Fn(usize) -> anyhow::Result<Box<dyn Backend>> + Send + Sync + 'static,
    {
        anyhow::ensure!(cfg.workers >= 1, "need at least one worker");
        let queue = RequestQueue::with_obs(cfg.queue_capacity, reg);
        let admission =
            AdmissionControl::register(cfg.queue_capacity, cfg.workers, cfg.max_wait, reg);
        let batch_rows_hist = reg.histogram("adaqat_batch_rows", &[]);
        let metrics = Arc::new(EngineMetrics::default());
        let factory = Arc::new(factory);
        let (ready_tx, ready_rx) = mpsc::channel::<Result<(usize, usize, usize), String>>();
        let mut handles = vec![];
        for wid in 0..cfg.workers {
            let ctx = WorkerCtx {
                queue: Arc::clone(&queue),
                metrics: Arc::clone(&metrics),
                admission: Arc::clone(&admission),
                batch_rows: Arc::clone(&batch_rows_hist),
                max_delay: cfg.max_delay,
            };
            let factory = Arc::clone(&factory);
            let ready = ready_tx.clone();
            handles.push(
                std::thread::Builder::new()
                    .name(format!("serve-worker-{wid}"))
                    .spawn(move || {
                        let backend = match (*factory)(wid) {
                            Ok(b) => {
                                let (h, w, c) = b.input_shape();
                                let _ = ready.send(Ok((
                                    h * w * c,
                                    b.max_batch(),
                                    b.num_classes(),
                                )));
                                b
                            }
                            Err(e) => {
                                let _ = ready.send(Err(format!("worker {wid}: {e}")));
                                return;
                            }
                        };
                        worker_loop(backend.as_ref(), &ctx);
                    })?,
            );
        }
        drop(ready_tx);
        let mut signature = None;
        for _ in 0..cfg.workers {
            match ready_rx.recv() {
                Ok(Ok(sig)) => {
                    if let Some(prev) = signature {
                        if prev != sig {
                            queue.close();
                            anyhow::bail!(
                                "workers disagree on model shape: {prev:?} vs {sig:?}"
                            );
                        }
                    }
                    signature = Some(sig);
                }
                Ok(Err(e)) => {
                    queue.close();
                    anyhow::bail!("backend construction failed: {e}");
                }
                Err(_) => {
                    queue.close();
                    anyhow::bail!("a serve worker died before reporting ready");
                }
            }
        }
        let (input_numel, batch, num_classes) =
            signature.expect("at least one worker reported");
        metrics.batch_rows.store(batch as u64, Ordering::Relaxed);
        log::info!(
            "serve engine up: {} workers, batch {batch}, window {:?}, queue cap {}, kernels: {}",
            cfg.workers,
            cfg.max_delay,
            cfg.queue_capacity,
            crate::kernels::isa_summary()
        );
        Ok(Arc::new(Engine {
            queue,
            metrics,
            admission,
            input_numel,
            num_classes,
            batch,
            default_deadline: cfg.default_deadline,
            workers: Mutex::new(handles),
        }))
    }

    pub fn input_numel(&self) -> usize {
        self.input_numel
    }

    pub fn num_classes(&self) -> usize {
        self.num_classes
    }

    pub fn batch(&self) -> usize {
        self.batch
    }

    /// Enqueue one request with no explicit deadline (the engine's
    /// `default_deadline`, if any, still applies).
    pub fn submit(
        &self,
        id: u64,
        pixels: Vec<f32>,
        resp: mpsc::Sender<ServeResponse>,
    ) -> Result<(), SubmitError> {
        self.submit_with_deadline(id, pixels, None, resp)
    }

    /// Enqueue one request; the answer arrives on `resp`. `deadline_ms`
    /// is the client's budget from *now* (the wire `deadline_ms`
    /// field); `None` falls back to the engine default. The deadline is
    /// judged here (admission) and again at batch formation — an
    /// expired request is answered, never computed.
    pub fn submit_with_deadline(
        &self,
        id: u64,
        pixels: Vec<f32>,
        deadline_ms: Option<u64>,
        resp: mpsc::Sender<ServeResponse>,
    ) -> Result<(), SubmitError> {
        if pixels.len() != self.input_numel {
            return Err(SubmitError::BadInput { got: pixels.len(), want: self.input_numel });
        }
        let now = Instant::now();
        let budget = deadline_ms
            .map(Duration::from_millis)
            .or(self.default_deadline);
        let deadline = budget.map(|b| now + b);
        // admission-stage deadline check: a zero budget is already dead
        if budget.is_some_and(|b| b.is_zero()) {
            self.admission.note_admission_expiry();
            return Err(SubmitError::DeadlineExceeded);
        }
        if self.admission.enabled() {
            match self.admission.decide(budget) {
                Decision::Admit => {}
                Decision::Overloaded { retry_after_ms } => {
                    return Err(SubmitError::Overloaded { retry_after_ms });
                }
                Decision::DeadlineHopeless => return Err(SubmitError::DeadlineExceeded),
            }
        }
        self.queue
            .push(ServeRequest { id, pixels, enqueued: now, deadline, resp })
            .map_err(|e| match e {
                PushError::Full => SubmitError::Full,
                PushError::Closed => SubmitError::Closed,
            })
    }

    /// Single-request convenience (the serve bench's single-stream mode).
    pub fn infer_blocking(&self, pixels: Vec<f32>) -> anyhow::Result<ServeResponse> {
        let (tx, rx) = mpsc::channel();
        self.submit(0, pixels, tx).map_err(|e| anyhow::anyhow!("{e}"))?;
        rx.recv_timeout(Duration::from_secs(30))
            .map_err(|_| anyhow::anyhow!("engine dropped the request"))
    }

    /// Current queue backlog (mirrors the `adaqat_queue_depth` gauge).
    pub fn queue_depth(&self) -> usize {
        self.queue.len()
    }

    /// (full, closed) shed counts from the request queue.
    pub fn shed_counts(&self) -> (u64, u64) {
        self.queue.shed_counts()
    }

    /// Overload accounting: (admission rejections, admission-stage
    /// deadline expiries, batch-stage deadline expiries). With
    /// [`shed_counts`](Engine::shed_counts) these close the
    /// conservation identity the chaos tests assert:
    /// `answered + shed + overloaded + deadline_expired == submitted`.
    pub fn overload_counts(&self) -> (u64, u64, u64) {
        let (overloaded, dl_admission) = self.admission.reject_counts();
        (overloaded, dl_admission, self.queue.deadline_expired_count())
    }

    /// Full Prometheus text exposition: every series in the global
    /// registry (per-layer kernels, queue, pool, training) plus this
    /// engine's own counters and latency summaries mirrored under the
    /// same naming scheme (DESIGN.md §15).
    pub fn prometheus(&self) -> String {
        use std::fmt::Write as _;
        let mut out = obs::global().render_prometheus();
        let m = &self.metrics;
        let _ = writeln!(out, "adaqat_requests_total {}", m.requests.load(Ordering::Relaxed));
        let _ = writeln!(out, "adaqat_failures_total {}", m.failures.load(Ordering::Relaxed));
        let _ = writeln!(out, "adaqat_batches_total {}", m.batches.load(Ordering::Relaxed));
        let _ = writeln!(
            out,
            "adaqat_unfilled_slots_total {}",
            m.padded.load(Ordering::Relaxed)
        );
        obs::render_latency_lines(&mut out, "adaqat_request_queue_ms", "", &m.queue.snapshot());
        obs::render_latency_lines(
            &mut out,
            "adaqat_request_compute_ms",
            "",
            &m.compute.snapshot(),
        );
        out
    }

    /// Stop accepting work, drain the queue, join the workers.
    pub fn shutdown(&self) {
        self.queue.close();
        for h in self.workers.lock().unwrap().drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(backend: &dyn Backend, ctx: &WorkerCtx) {
    let (h, w, c) = backend.input_shape();
    let sz = h * w * c;
    let bs = backend.max_batch();
    let metrics = ctx.metrics.as_ref();
    let batcher = DynamicBatcher::with_hist(
        Arc::clone(&ctx.queue),
        bs,
        ctx.max_delay,
        Arc::clone(&ctx.batch_rows),
    );
    while let Some(reqs) = batcher.next_batch() {
        let picked = Instant::now();
        // batch-stage deadline re-check (DESIGN.md §19): entries whose
        // deadline passed while queued are answered `deadline_exceeded`
        // and reclaimed, not computed — the queue counts them
        let (live, expired): (Vec<_>, Vec<_>) =
            reqs.into_iter().partition(|r| !r.expired_at(picked));
        for r in expired {
            ctx.queue.expire_batch(r);
        }
        if live.is_empty() {
            continue;
        }
        // ship only the real rows — static-shape backends pad for
        // themselves, dynamic ones do `rows` of work (no zero-row tax)
        let rows = live.len();
        let mut x = vec![0.0f32; rows * sz];
        for (i, r) in live.iter().enumerate() {
            x[i * sz..(i + 1) * sz].copy_from_slice(&r.pixels);
        }
        let t0 = Instant::now();
        // a panicking backend (or injected worker_infer fault) must not
        // take the worker — and its batch's requests — down with it:
        // unwinds become per-request inference errors, conservation
        // holds, and the worker lives to pull the next batch
        let outcome = std::panic::catch_unwind(AssertUnwindSafe(|| {
            failpoint::hit("worker_infer");
            backend.infer(&Tensor::new(vec![rows, h, w, c], x))
        }))
        .unwrap_or_else(|p| {
            let what = p
                .downcast_ref::<&str>()
                .map(|s| s.to_string())
                .or_else(|| p.downcast_ref::<String>().cloned())
                .unwrap_or_else(|| "panic".to_string());
            Err(anyhow::anyhow!("worker panicked: {what}"))
        });
        let done = Instant::now();
        let compute = done.duration_since(t0);
        let compute_ms = compute.as_secs_f64() * 1e3;
        ctx.admission.observe_batch(rows, compute);
        metrics.batches.fetch_add(1, Ordering::Relaxed);
        metrics.padded.fetch_add((bs - rows) as u64, Ordering::Relaxed);
        match outcome {
            Ok(classes) => {
                for (i, r) in live.into_iter().enumerate() {
                    let queue_ms =
                        picked.duration_since(r.enqueued).as_secs_f64() * 1e3;
                    metrics.queue.record_ms(queue_ms);
                    metrics.compute.record_ms(compute_ms);
                    metrics.requests.fetch_add(1, Ordering::Relaxed);
                    push_trace(metrics, &r, picked, done, rows as u32, true);
                    let _ = r.resp.send(ServeResponse {
                        id: r.id,
                        result: Ok(classes[i]),
                        queue_ms,
                        compute_ms,
                    });
                }
            }
            Err(e) => {
                let msg = e.to_string();
                log::warn!("serve worker: inference failed: {msg}");
                for r in live {
                    let queue_ms =
                        picked.duration_since(r.enqueued).as_secs_f64() * 1e3;
                    // failed traffic must show up in the latency stats
                    // too — an outage is exactly when they are read
                    metrics.queue.record_ms(queue_ms);
                    metrics.compute.record_ms(compute_ms);
                    metrics.failures.fetch_add(1, Ordering::Relaxed);
                    push_trace(metrics, &r, picked, done, rows as u32, false);
                    let _ = r.resp.send(ServeResponse {
                        id: r.id,
                        result: Err(ServeError::Inference(msg.clone())),
                        queue_ms,
                        compute_ms,
                    });
                }
            }
        }
    }
}

/// Record one request's span — enqueue → batch pickup → compute done →
/// reply — into the engine's trace ring. Called *before* the response
/// channel send so a client that issues `trace` right after receiving
/// its answer always finds its own entry; `rows` is the size of the
/// batch the request rode in. Skips entirely (no ring lock) when the
/// registry's sampler switch is off.
fn push_trace(
    metrics: &EngineMetrics,
    r: &ServeRequest,
    picked: Instant,
    done: Instant,
    rows: u32,
    ok: bool,
) {
    if !obs::global().enabled() {
        return;
    }
    let ring = &metrics.trace;
    ring.push(RequestTrace {
        id: r.id,
        enqueue_us: ring.us_since_epoch(r.enqueued),
        batch_us: ring.us_since_epoch(picked),
        compute_done_us: ring.us_since_epoch(done),
        reply_us: ring.us_since_epoch(Instant::now()),
        rows,
        ok,
    });
}

// ------------------------------------------------------------- backends

/// The quantized network a packed checkpoint serves: an fc stack
/// ([`QuantMlp`]) or, when the meta carries `conv_layers` or
/// `res_blocks`, the conv/residual blocks + fc head of a
/// [`QuantConvNet`] (DESIGN.md §13, §18).
enum ServedNet {
    Mlp(QuantMlp),
    Conv(QuantConvNet),
}

impl ServedNet {
    fn input_numel(&self) -> usize {
        match self {
            ServedNet::Mlp(m) => m.input,
            ServedNet::Conv(c) => c.input_numel(),
        }
    }

    fn classes(&self) -> usize {
        match self {
            ServedNet::Mlp(m) => m.classes,
            ServedNet::Conv(c) => c.classes,
        }
    }

    fn classify(&self, x: &[f32], rows: usize, pool: &WorkerPool) -> Vec<usize> {
        match self {
            ServedNet::Mlp(m) => m.classify_pooled(x, rows, pool),
            ServedNet::Conv(c) => c.classify_pooled(x, rows, pool),
        }
    }
}

/// Pure-Rust quantized backend: a [`QuantMlp`] (single fc layer or an
/// `mlp_layers` stack with ReLU) or a [`QuantConvNet`] (`conv_layers`
/// or `res_blocks` meta) over a packed checkpoint whose meta carries
/// `input_hw`, `in_channels`, `num_classes`, `serve_batch` (written by
/// `adaqat demo-model` / the native trainers). Packed weight tensors
/// run in the integer domain (i8/i16 codes, i32 accumulation,
/// activations quantized on the fly at the learned k_a) instead of the
/// old dequantize-to-f32 strided dot — see DESIGN.md §11/§13.
pub struct ReferenceBackend {
    net: ServedNet,
    h: usize,
    wid: usize,
    c: usize,
    batch: usize,
    /// Persistent worker pool + scratch arenas (DESIGN.md §14): thread
    /// count resolved once here at construction, workers spawned once,
    /// buffers recycled across requests — the request path spawns
    /// nothing and (once warm) allocates nothing.
    pool: WorkerPool,
}

impl ReferenceBackend {
    pub fn from_packed(q: &QuantizedCheckpoint) -> anyhow::Result<ReferenceBackend> {
        Self::with_threads(q, 1)
    }

    /// `threads` sizes the per-batch row parallelism inside the GEMMs
    /// (`--threads` in `ServeConfig`); 0 means one per available core,
    /// resolved here — backend construction — not per request. Thread
    /// count never changes results — the integer kernels are
    /// order-independent.
    pub fn with_threads(
        q: &QuantizedCheckpoint,
        threads: usize,
    ) -> anyhow::Result<ReferenceBackend> {
        let hw = q
            .meta
            .get("input_hw")
            .and_then(|j| j.as_arr())
            .ok_or_else(|| anyhow::anyhow!(
                "packed meta lacks input_hw — export a demo-model checkpoint \
                 or add serving metadata"
            ))?;
        anyhow::ensure!(hw.len() == 2, "input_hw must have 2 entries");
        let h = hw[0].as_usize().ok_or_else(|| anyhow::anyhow!("bad input_hw"))?;
        let wid = hw[1].as_usize().ok_or_else(|| anyhow::anyhow!("bad input_hw"))?;
        let c = q
            .meta
            .get("in_channels")
            .and_then(|j| j.as_usize())
            .ok_or_else(|| anyhow::anyhow!("packed meta lacks in_channels"))?;
        let classes = q
            .meta
            .get("num_classes")
            .and_then(|j| j.as_usize())
            .ok_or_else(|| anyhow::anyhow!("packed meta lacks num_classes"))?;
        let batch = q
            .meta
            .get("serve_batch")
            .and_then(|j| j.as_usize())
            .unwrap_or(16);
        let net = if q.meta.get("conv_layers").is_some() || q.meta.get("res_blocks").is_some() {
            // the conv loader derives its input shape from these same
            // meta keys and validates the tensor chain against them
            // internally, so no cross-check is possible (or needed) here
            ServedNet::Conv(QuantConvNet::from_packed(q)?)
        } else {
            let mlp = QuantMlp::from_packed(q)?;
            // mlp.input comes from the tensors; the meta must agree
            anyhow::ensure!(
                mlp.input == h * wid * c,
                "model expects {} inputs but meta says {}x{}x{}",
                mlp.input,
                h,
                wid,
                c
            );
            ServedNet::Mlp(mlp)
        };
        anyhow::ensure!(
            net.classes() == classes,
            "model has {} outputs but meta num_classes is {classes}",
            net.classes()
        );
        let pool = WorkerPool::new(threads);
        log::info!(
            "reference backend: {} gemm thread(s) (requested {threads}; 0 = per core), kernels: {}",
            pool.threads(),
            crate::kernels::isa_summary()
        );
        Ok(ReferenceBackend { net, h, wid, c, batch, pool })
    }

    /// Direct (non-batched) forward for one image — the ground truth the
    /// e2e tests compare the pipelined path against. Per-row activation
    /// scales make this bit-identical to the same image inside any
    /// batch, so the comparison is exact, not approximate.
    pub fn classify_one(&self, pixels: &[f32]) -> usize {
        debug_assert_eq!(pixels.len(), self.h * self.wid * self.c);
        self.net.classify(pixels, 1, &self.pool)[0]
    }
}

impl Backend for ReferenceBackend {
    fn input_shape(&self) -> (usize, usize, usize) {
        (self.h, self.wid, self.c)
    }

    fn max_batch(&self) -> usize {
        self.batch
    }

    fn num_classes(&self) -> usize {
        self.net.classes()
    }

    fn infer(&self, x: &Tensor) -> anyhow::Result<Vec<usize>> {
        anyhow::ensure!(
            x.shape.len() == 4
                && x.shape[1] == self.h
                && x.shape[2] == self.wid
                && x.shape[3] == self.c,
            "reference backend: bad batch shape {:?}",
            x.shape
        );
        let rows = x.shape[0];
        anyhow::ensure!(
            rows >= 1 && rows <= self.batch,
            "reference backend: {rows} rows exceeds serve batch {}",
            self.batch
        );
        Ok(self.net.classify(&x.data, rows, &self.pool))
    }
}

/// The PJRT path: compiled "infer" graph + state from a packed
/// checkpoint, quantization scales from the checkpoint's (k_w, k_a).
pub struct RuntimeBackend {
    rt: ModelRuntime,
    state: TrainState,
    s_w: f32,
    s_a: f32,
}

impl RuntimeBackend {
    pub fn new(
        artifact_dir: &Path,
        model_key: &str,
        packed: &QuantizedCheckpoint,
    ) -> anyhow::Result<RuntimeBackend> {
        let runtime = Runtime::new(artifact_dir)?;
        let rt = runtime.load_model(model_key)?;
        anyhow::ensure!(
            rt.has_infer(),
            "{model_key}: artifact set has no \"infer\" graph — re-run `make artifacts`"
        );
        let ck = packed.to_checkpoint();
        let state = rt.load_state(&ck, 0)?;
        let k_w = packed.meta.get("k_w").and_then(|j| j.as_f64()).unwrap_or(32.0) as u32;
        let k_a = packed.meta.get("k_a").and_then(|j| j.as_f64()).unwrap_or(32.0) as u32;
        log::info!("runtime backend: {model_key} at W{k_w}/A{k_a}");
        Ok(RuntimeBackend {
            rt,
            state,
            s_w: bitwidth_scale(k_w),
            s_a: bitwidth_scale(k_a),
        })
    }
}

impl Backend for RuntimeBackend {
    fn input_shape(&self) -> (usize, usize, usize) {
        (self.rt.mm.input_hw.0, self.rt.mm.input_hw.1, self.rt.mm.in_channels)
    }

    fn max_batch(&self) -> usize {
        self.rt.mm.batch
    }

    fn num_classes(&self) -> usize {
        self.rt.mm.num_classes
    }

    fn infer(&self, x: &Tensor) -> anyhow::Result<Vec<usize>> {
        // The compiled graph's batch shape is static: pad partial
        // batches with zero rows here and truncate the answer.
        let bs = self.rt.mm.batch;
        let (h, w, c) = self.input_shape();
        let sz = h * w * c;
        anyhow::ensure!(
            x.shape.len() == 4 && x.shape[1] == h && x.shape[2] == w && x.shape[3] == c,
            "runtime backend: bad batch shape {:?}",
            x.shape
        );
        let rows = x.shape[0];
        anyhow::ensure!(
            rows >= 1 && rows <= bs,
            "runtime backend: {rows} rows exceeds compiled batch {bs}"
        );
        let mut classes = if rows == bs {
            self.rt.infer_batch(&self.state, x, self.s_w, self.s_a)?
        } else {
            let mut padded = vec![0.0f32; bs * sz];
            padded[..rows * sz].copy_from_slice(&x.data);
            self.rt.infer_batch(
                &self.state,
                &Tensor::new(vec![bs, h, w, c], padded),
                self.s_w,
                self.s_a,
            )?
        };
        classes.truncate(rows);
        Ok(classes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DatasetKind;
    use crate::serve::demo;

    fn demo_engine(
        workers: usize,
        batch: usize,
        max_delay_ms: u64,
    ) -> (Arc<Engine>, Arc<QuantizedCheckpoint>) {
        let ck = demo::demo_checkpoint(DatasetKind::Cifar10, 8, 42, batch);
        let q = Arc::new(QuantizedCheckpoint::from_checkpoint(&ck, 4, |n| {
            n.ends_with(".w")
        }));
        let q2 = Arc::clone(&q);
        let engine = Engine::start(
            EngineConfig {
                workers,
                queue_capacity: 256,
                max_delay: Duration::from_millis(max_delay_ms),
                ..EngineConfig::default()
            },
            move |_| Ok(Box::new(ReferenceBackend::from_packed(&q2)?) as Box<dyn Backend>),
        )
        .unwrap();
        (engine, q)
    }

    #[test]
    fn pipeline_matches_direct_forward() {
        let (engine, q) = demo_engine(2, 8, 2);
        let direct = ReferenceBackend::from_packed(&q).unwrap();
        let ds = crate::data::synth::generate(DatasetKind::Cifar10, 64, 5, 1);
        let (tx, rx) = mpsc::channel();
        for i in 0..64 {
            engine.submit(i as u64, ds.image(i).to_vec(), tx.clone()).unwrap();
        }
        let mut got = 0;
        while got < 64 {
            let resp = rx.recv_timeout(Duration::from_secs(10)).unwrap();
            let want = direct.classify_one(ds.image(resp.id as usize));
            assert_eq!(resp.result, Ok(want), "request {}", resp.id);
            assert!(resp.queue_ms >= 0.0 && resp.compute_ms >= 0.0);
            got += 1;
        }
        assert_eq!(engine.metrics.requests.load(Ordering::Relaxed), 64);
        assert!(engine.metrics.queue.count() == 64);
        engine.shutdown();
    }

    #[test]
    fn bad_input_rejected_at_submit() {
        let (engine, _q) = demo_engine(1, 4, 1);
        let (tx, _rx) = mpsc::channel();
        let err = engine.submit(0, vec![0.0; 7], tx).unwrap_err();
        assert!(matches!(err, SubmitError::BadInput { got: 7, .. }));
        engine.shutdown();
    }

    #[test]
    fn zero_deadline_budget_expires_at_admission() {
        let (engine, _q) = demo_engine(1, 4, 1);
        let numel = engine.input_numel();
        let (tx, _rx) = mpsc::channel();
        assert_eq!(
            engine.submit_with_deadline(0, vec![0.0; numel], Some(0), tx).unwrap_err(),
            SubmitError::DeadlineExceeded
        );
        assert_eq!(engine.overload_counts(), (0, 1, 0));
        engine.shutdown();
    }

    #[test]
    fn generous_deadline_still_answers_normally() {
        let (engine, q) = demo_engine(1, 4, 1);
        let direct = ReferenceBackend::from_packed(&q).unwrap();
        let ds = crate::data::synth::generate(DatasetKind::Cifar10, 4, 11, 1);
        let (tx, rx) = mpsc::channel();
        engine
            .submit_with_deadline(5, ds.image(1).to_vec(), Some(60_000), tx)
            .unwrap();
        let resp = rx.recv_timeout(Duration::from_secs(10)).unwrap();
        assert_eq!(resp.result, Ok(direct.classify_one(ds.image(1))));
        assert_eq!(engine.overload_counts(), (0, 0, 0));
        engine.shutdown();
    }

    #[test]
    fn armed_admission_rejects_with_finite_retry_after_at_capacity() {
        // capacity-2 queue with a long batching window and admission
        // armed: the queue fills, then further submits come back
        // Overloaded (finite retry hint) instead of bare Full
        let ck = demo::demo_checkpoint(DatasetKind::Cifar10, 8, 42, 4);
        let q = Arc::new(QuantizedCheckpoint::from_checkpoint(&ck, 4, |n| {
            n.ends_with(".w")
        }));
        let q2 = Arc::clone(&q);
        let reg = crate::obs::Registry::new();
        let engine = Engine::start_with_obs(
            EngineConfig {
                workers: 1,
                queue_capacity: 2,
                max_delay: Duration::from_millis(200),
                max_wait: Some(Duration::from_millis(100)),
                ..EngineConfig::default()
            },
            move |_| Ok(Box::new(ReferenceBackend::from_packed(&q2)?) as Box<dyn Backend>),
            &reg,
        )
        .unwrap();
        let numel = engine.input_numel();
        let (tx, _rx) = mpsc::channel::<ServeResponse>();
        // overfill: worker takes up to 4/batch, so pushing hard
        // eventually catches the queue at capacity
        let mut saw_overloaded = false;
        for i in 0..512 {
            match engine.submit(i, vec![0.0; numel], tx.clone()) {
                Ok(()) => {}
                Err(SubmitError::Overloaded { retry_after_ms }) => {
                    assert!(retry_after_ms >= 1, "retry hint must be finite and nonzero");
                    assert!(retry_after_ms <= 30_000, "retry hint must be bounded");
                    saw_overloaded = true;
                    break;
                }
                Err(other) => panic!("armed admission must reject as Overloaded: {other}"),
            }
        }
        assert!(saw_overloaded, "512 submits never caught a capacity-2 queue full");
        assert!(engine.overload_counts().0 >= 1);
        engine.shutdown();
    }

    #[test]
    fn submit_after_shutdown_is_closed() {
        let (engine, _q) = demo_engine(1, 4, 1);
        let numel = engine.input_numel();
        engine.shutdown();
        let (tx, _rx) = mpsc::channel();
        assert_eq!(engine.submit(0, vec![0.0; numel], tx).unwrap_err(), SubmitError::Closed);
    }

    #[test]
    fn report_occupancy_is_na_before_first_batch() {
        let m = EngineMetrics::default();
        assert!(
            m.report().contains("mean occupancy n/a"),
            "idle engine must not claim perfect occupancy: {}",
            m.report()
        );
        m.batch_rows.store(8, Ordering::Relaxed);
        m.batches.store(1, Ordering::Relaxed);
        m.padded.store(2, Ordering::Relaxed);
        assert!(
            m.report().contains("mean occupancy 75.0%"),
            "6 of 8 slots filled: {}",
            m.report()
        );
    }

    #[test]
    fn factory_failure_propagates() {
        let result = Engine::start(EngineConfig::default(), |wid| {
            anyhow::bail!("no backend for worker {wid}")
        });
        assert!(result.is_err());
        assert!(result.err().unwrap().to_string().contains("no backend"));
    }

    #[test]
    fn infer_blocking_round_trips() {
        let (engine, q) = demo_engine(1, 4, 1);
        let direct = ReferenceBackend::from_packed(&q).unwrap();
        let ds = crate::data::synth::generate(DatasetKind::Cifar10, 4, 9, 1);
        let resp = engine.infer_blocking(ds.image(2).to_vec()).unwrap();
        assert_eq!(resp.result, Ok(direct.classify_one(ds.image(2))));
        engine.shutdown();
    }

    #[test]
    fn partial_batches_carry_their_real_row_count() {
        let ck = demo::demo_checkpoint(DatasetKind::Cifar10, 8, 17, 8);
        let q = QuantizedCheckpoint::from_checkpoint(&ck, 4, |n| n.ends_with(".w"));
        let backend = ReferenceBackend::from_packed(&q).unwrap();
        let (h, w, c) = backend.input_shape();
        let sz = h * w * c;
        let ds = crate::data::synth::generate(DatasetKind::Cifar10, 3, 23, 1);
        // a 3-row tensor against a serve batch of 8: 3 answers, each
        // matching the direct forward — no zero-padded rows computed
        let mut x = vec![0.0f32; 3 * sz];
        for i in 0..3 {
            x[i * sz..(i + 1) * sz].copy_from_slice(ds.image(i));
        }
        let preds = backend.infer(&Tensor::new(vec![3, h, w, c], x)).unwrap();
        assert_eq!(preds.len(), 3);
        for i in 0..3 {
            assert_eq!(preds[i], backend.classify_one(ds.image(i)), "row {i}");
        }
        // oversized batches are rejected, not silently truncated
        let too_big = Tensor::zeros(vec![9, h, w, c]);
        assert!(backend.infer(&too_big).is_err());
    }

    #[test]
    fn conv_checkpoint_serves_through_the_engine() {
        // a native conv trainer's state, packed with full serving meta,
        // must load as a QuantConvNet and answer through the pipelined
        // engine exactly like the trainer's own serving forward
        use crate::backprop::ConvNativeBackend;
        use crate::runtime::StepBackend;

        let trainer = ConvNativeBackend::new(8, 8, 3, 10, &[4]).unwrap();
        let state = trainer.init_state(7).unwrap();
        let ck = trainer.to_checkpoint(&state, 8);
        let q = Arc::new(QuantizedCheckpoint::from_checkpoint(&ck, 4, |n| {
            n.ends_with(".w")
        }));
        let q2 = Arc::clone(&q);
        let engine = Engine::start(
            EngineConfig {
                workers: 2,
                queue_capacity: 64,
                max_delay: Duration::from_millis(2),
                ..EngineConfig::default()
            },
            move |_| {
                Ok(Box::new(ReferenceBackend::with_threads(&q2, 2)?) as Box<dyn Backend>)
            },
        )
        .unwrap();
        let ds = crate::data::synth::generate_sized(DatasetKind::Cifar10, 16, 5, 1, 8, 8);
        let (tx, rx) = mpsc::channel();
        for i in 0..16 {
            engine.submit(i as u64, ds.image(i).to_vec(), tx.clone()).unwrap();
        }
        for _ in 0..16 {
            let resp = rx.recv_timeout(Duration::from_secs(10)).unwrap();
            let i = resp.id as usize;
            let want = trainer.predict(&state, ds.image(i), 1, 4, 8).unwrap()[0];
            assert_eq!(resp.result, Ok(want), "request {i}");
        }
        engine.shutdown();
    }

    #[test]
    fn mlp_engine_pipeline_matches_direct_forward() {
        // 2-layer demo MLP at 8-bit weights / 8-bit activations on the
        // integer kernels, 2 GEMM threads — pipeline must agree with
        // classify_one exactly (per-row activation scales)
        let ck = demo::demo_mlp_checkpoint(DatasetKind::Cifar10, 64, 8, 5, 8, 8);
        let q = Arc::new(QuantizedCheckpoint::from_checkpoint(&ck, 8, |n| {
            n.ends_with(".w")
        }));
        let q2 = Arc::clone(&q);
        let engine = Engine::start(
            EngineConfig {
                workers: 2,
                queue_capacity: 128,
                max_delay: Duration::from_millis(2),
                ..EngineConfig::default()
            },
            move |_| {
                Ok(Box::new(ReferenceBackend::with_threads(&q2, 2)?) as Box<dyn Backend>)
            },
        )
        .unwrap();
        let direct = ReferenceBackend::from_packed(&q).unwrap();
        let ds = crate::data::synth::generate(DatasetKind::Cifar10, 32, 3, 1);
        let (tx, rx) = mpsc::channel();
        for i in 0..32 {
            engine.submit(i as u64, ds.image(i).to_vec(), tx.clone()).unwrap();
        }
        for _ in 0..32 {
            let resp = rx.recv_timeout(Duration::from_secs(10)).unwrap();
            let want = direct.classify_one(ds.image(resp.id as usize));
            assert_eq!(resp.result, Ok(want), "request {}", resp.id);
        }
        engine.shutdown();
    }
}

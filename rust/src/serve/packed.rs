//! Packed quantized checkpoints (`AQQCKPT1`) — the deployment artifact
//! of an AdaQAT run (DESIGN.md §7).
//!
//! A [`QuantizedCheckpoint`] is the serving sibling of
//! [`crate::tensor::checkpoint::Checkpoint`]: weight tensors are stored
//! as bit-packed integer codes at the learned k_w plus one f32 max-abs
//! scale per tensor; everything else (BN statistics, biases, PACT α)
//! stays raw f32. Layout (integers little-endian):
//!
//! ```text
//!   magic   "AQQCKPT1"                       (8 bytes)
//!   meta    u32 len + JSON bytes             (k_w, k_a, cost summary, …)
//!   count   u32                              number of tensors
//!   entry*  u16 name_len + name bytes
//!           u8  ndim + u32 dims[ndim]
//!           u8  bits      (1..=24 packed; 32 = raw f32)
//!           f32 scale     (max-abs; 0 for raw tensors)
//!           payload       packed: ceil(numel·bits/8) bytes, codes
//!                         LSB-first; raw: numel·4 bytes f32 LE
//! ```
//!
//! The quantization grid mirrors the training quantizer: s = 2^k − 1
//! levels (`quant::code_levels`) spread symmetrically over
//! [−max|x|, +max|x|]; code c dequantizes to `(2c − s)·Δ` with the
//! per-tensor step Δ = scale/s — the same centered-code folding the
//! integer kernels use (`crate::kernels`), so a dequantized value and
//! the kernel's q·Δ reconstruction are the *same* f32. The dequantized
//! stream is the checkpoint's *canonical* content: save → load →
//! [`PackedTensor::dequantize`] is bit-exact, which is what the runtime
//! consumes and what the round-trip tests pin down. Bit-stream packing
//! goes through the u64 word-at-a-time paths in [`crate::kernels::pack`].

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

use crate::kernels::pack;
use crate::quant::code_levels;
use crate::tensor::checkpoint::{read_u16, read_u32, Checkpoint};
use crate::tensor::Tensor;
use crate::util::json::Json;

const MAGIC: &[u8; 8] = b"AQQCKPT1";

/// Marker bits value for "stored raw f32, not quantized".
pub const RAW_BITS: u32 = 32;

/// One bit-packed (or raw) tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct PackedTensor {
    pub shape: Vec<usize>,
    /// 1..=24: packed integer codes; [`RAW_BITS`]: raw f32 payload.
    pub bits: u32,
    /// Max-abs of the source tensor (packed tensors only).
    pub scale: f32,
    pub payload: Vec<u8>,
}

impl PackedTensor {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    fn packed_len(numel: usize, bits: u32) -> usize {
        (numel * bits as usize + 7) / 8
    }

    /// Shape product with overflow as a hard error — the same guard the
    /// file loader applies to untrusted dims, for in-memory tensors.
    fn checked_numel(shape: &[usize]) -> usize {
        shape
            .iter()
            .try_fold(1usize, |acc, &d| acc.checked_mul(d))
            .expect("PackedTensor shape product overflows usize")
    }

    /// s = 2^k − 1 (`quant::code_levels`) — spelled as a local helper
    /// because the runtime-facing `bitwidth_scale` switches to the
    /// identity scale at k ≥ 24, which would not fit a k-bit code field.
    fn levels(bits: u32) -> f32 {
        code_levels(bits) as f32
    }

    /// Store a tensor untouched (fp32 passthrough).
    pub fn raw(t: &Tensor) -> PackedTensor {
        let payload = t.data.iter().flat_map(|x| x.to_le_bytes()).collect();
        PackedTensor { shape: t.shape.clone(), bits: RAW_BITS, scale: 0.0, payload }
    }

    /// Quantize to `bits` ∈ 1..=24 on the symmetric s = 2^k − 1 grid.
    /// Scale handling and the reciprocal are hoisted out of the
    /// per-element loop; packing is the u64 word-at-a-time fast path.
    pub fn quantize(t: &Tensor, bits: u32) -> PackedTensor {
        assert!((1..=24).contains(&bits), "packed bits must be in 1..=24, got {bits}");
        let n = Self::checked_numel(&t.shape);
        let s = Self::levels(bits);
        let scale = t.data.iter().fold(0.0f32, |m, x| m.max(x.abs()));
        let codes: Vec<u32> = if scale > 0.0 {
            let inv = 0.5 / scale;
            t.data
                .iter()
                .map(|&x| ((x * inv + 0.5).clamp(0.0, 1.0) * s).round() as u32)
                .collect()
        } else {
            vec![(0.5 * s).round() as u32; n]
        };
        let payload = pack::pack_codes(&codes, bits);
        PackedTensor { shape: t.shape.clone(), bits, scale, payload }
    }

    /// The f32 tensor the runtime consumes. Deterministic: the same
    /// codes + scale always dequantize to bit-identical floats — value
    /// = (2c − s)·Δ with Δ = scale/s, the exact folding the integer
    /// kernels reproduce in their epilogue.
    pub fn dequantize(&self) -> Tensor {
        let n = Self::checked_numel(&self.shape);
        if self.bits == RAW_BITS {
            let data = self
                .payload
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            return Tensor::new(self.shape.clone(), data);
        }
        let s_i = code_levels(self.bits) as i32;
        let step = self.scale / s_i as f32;
        let codes = pack::unpack_codes(&self.payload, self.bits, n);
        let data = codes.iter().map(|&c| (2 * c as i32 - s_i) as f32 * step).collect();
        Tensor::new(self.shape.clone(), data)
    }

    /// Bytes this tensor occupies on disk (payload only).
    pub fn payload_bytes(&self) -> usize {
        self.payload.len()
    }
}

/// A packed model: JSON metadata + named [`PackedTensor`]s.
#[derive(Debug, Clone)]
pub struct QuantizedCheckpoint {
    pub meta: Json,
    pub tensors: Vec<(String, PackedTensor)>,
}

impl QuantizedCheckpoint {
    pub fn new(meta: Json) -> QuantizedCheckpoint {
        QuantizedCheckpoint { meta, tensors: vec![] }
    }

    pub fn push(&mut self, name: impl Into<String>, t: PackedTensor) {
        self.tensors.push((name.into(), t));
    }

    pub fn get(&self, name: &str) -> Option<&PackedTensor> {
        self.tensors.iter().find(|(n, _)| n == name).map(|(_, t)| t)
    }

    /// Pack a training checkpoint: tensors selected by `is_weight` are
    /// quantized to `bits`, the rest stay raw. The source metadata is
    /// carried over and `k_w` is set to `bits` (an existing `k_a` is
    /// kept — activations quantize at runtime, not in the file).
    pub fn from_checkpoint(
        ck: &Checkpoint,
        bits: u32,
        is_weight: impl Fn(&str) -> bool,
    ) -> QuantizedCheckpoint {
        let mut meta = match &ck.meta {
            Json::Obj(m) => m.clone(),
            _ => BTreeMap::new(),
        };
        meta.insert("format".to_string(), Json::str("aqqckpt1"));
        meta.insert("k_w".to_string(), Json::num(bits as f64));
        let mut q = QuantizedCheckpoint { meta: Json::Obj(meta), tensors: vec![] };
        for (name, t) in &ck.tensors {
            let pt = if is_weight(name) && t.numel() > 0 {
                PackedTensor::quantize(t, bits)
            } else {
                PackedTensor::raw(t)
            };
            q.push(name.clone(), pt);
        }
        q
    }

    /// Dequantize everything back into a plain [`Checkpoint`] (what
    /// `ModelRuntime::load_state` and the reference backend consume).
    pub fn to_checkpoint(&self) -> Checkpoint {
        let mut ck = Checkpoint::new(self.meta.clone());
        for (name, t) in &self.tensors {
            ck.push(name.clone(), t.dequantize());
        }
        ck
    }

    /// Total payload bytes across tensors (excludes names/meta framing).
    pub fn payload_bytes(&self) -> usize {
        self.tensors.iter().map(|(_, t)| t.payload_bytes()).sum()
    }

    /// A meta array of layer names (`mlp_layers`, `conv_layers`):
    /// `Ok(None)` when the key is absent, `Err` when it is present but
    /// malformed — an empty array or non-string entries. One parser for
    /// every layer-stack loader ([`crate::kernels::QuantMlp`],
    /// [`crate::kernels::conv::QuantConvNet`]).
    pub fn meta_layer_names(&self, key: &str) -> anyhow::Result<Option<Vec<String>>> {
        let Some(j) = self.meta.get(key) else {
            return Ok(None);
        };
        let arr = j
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("meta {key} must be an array of layer names"))?;
        anyhow::ensure!(!arr.is_empty(), "meta {key} is empty");
        let names = arr
            .iter()
            .map(|e| {
                e.as_str()
                    .map(str::to_string)
                    .ok_or_else(|| anyhow::anyhow!("{key} entries must be strings"))
            })
            .collect::<anyhow::Result<Vec<String>>>()?;
        Ok(Some(names))
    }

    // ---------------------------------------------------------------- io
    pub fn save(&self, path: &Path) -> anyhow::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut w = std::io::BufWriter::new(std::fs::File::create(path)?);
        w.write_all(MAGIC)?;
        let meta = self.meta.to_string();
        w.write_all(&(meta.len() as u32).to_le_bytes())?;
        w.write_all(meta.as_bytes())?;
        w.write_all(&(self.tensors.len() as u32).to_le_bytes())?;
        for (name, t) in &self.tensors {
            anyhow::ensure!(name.len() <= u16::MAX as usize, "name too long");
            w.write_all(&(name.len() as u16).to_le_bytes())?;
            w.write_all(name.as_bytes())?;
            anyhow::ensure!(t.shape.len() <= u8::MAX as usize, "too many dims");
            w.write_all(&[t.shape.len() as u8])?;
            for &d in &t.shape {
                w.write_all(&(d as u32).to_le_bytes())?;
            }
            anyhow::ensure!(
                t.bits == RAW_BITS || (1..=24).contains(&t.bits),
                "{name}: bad bits {}",
                t.bits
            );
            let expect = if t.bits == RAW_BITS {
                t.numel() * 4
            } else {
                PackedTensor::packed_len(t.numel(), t.bits)
            };
            anyhow::ensure!(
                t.payload.len() == expect,
                "{name}: payload {} bytes, expected {expect}",
                t.payload.len()
            );
            w.write_all(&[t.bits as u8])?;
            w.write_all(&t.scale.to_le_bytes())?;
            w.write_all(&t.payload)?;
        }
        Ok(())
    }

    pub fn load(path: &Path) -> anyhow::Result<QuantizedCheckpoint> {
        let mut r = std::io::BufReader::new(std::fs::File::open(path)?);
        let mut magic = [0u8; 8];
        r.read_exact(&mut magic)?;
        anyhow::ensure!(
            &magic == MAGIC,
            "bad packed-checkpoint magic in {path:?} (expected AQQCKPT1)"
        );
        let meta_len = read_u32(&mut r)? as usize;
        let mut meta_bytes = vec![0u8; meta_len];
        r.read_exact(&mut meta_bytes)?;
        let meta = Json::parse(std::str::from_utf8(&meta_bytes)?)
            .map_err(|e| anyhow::anyhow!("packed meta: {e}"))?;
        let count = read_u32(&mut r)? as usize;
        let mut tensors = Vec::with_capacity(count.min(4096));
        for _ in 0..count {
            let name_len = read_u16(&mut r)? as usize;
            let mut name = vec![0u8; name_len];
            r.read_exact(&mut name)?;
            let name = String::from_utf8(name)?;
            let mut ndim = [0u8; 1];
            r.read_exact(&mut ndim)?;
            let mut shape = Vec::with_capacity(ndim[0] as usize);
            for _ in 0..ndim[0] {
                shape.push(read_u32(&mut r)? as usize);
            }
            let mut bits_scale = [0u8; 5];
            r.read_exact(&mut bits_scale)?;
            let bits = bits_scale[0] as u32;
            anyhow::ensure!(
                bits == RAW_BITS || (1..=24).contains(&bits),
                "{name}: bad bits {bits}"
            );
            let scale = f32::from_le_bytes([
                bits_scale[1],
                bits_scale[2],
                bits_scale[3],
                bits_scale[4],
            ]);
            // dims come from an untrusted file: overflow must be Err,
            // not a debug panic / silent release wraparound
            let numel = shape
                .iter()
                .try_fold(1usize, |acc, &d| acc.checked_mul(d))
                .ok_or_else(|| {
                    anyhow::anyhow!("{name}: shape {shape:?} overflows usize")
                })?;
            let len = if bits == RAW_BITS {
                numel.checked_mul(4)
            } else {
                numel
                    .checked_mul(bits as usize)
                    .and_then(|b| b.checked_add(7))
                    .map(|b| b / 8)
            }
            .ok_or_else(|| {
                anyhow::anyhow!("{name}: payload size overflows usize")
            })?;
            let mut payload = vec![0u8; len];
            r.read_exact(&mut payload)?;
            tensors.push((name, PackedTensor { shape, bits, scale, payload }));
        }
        Ok(QuantizedCheckpoint { meta, tensors })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn tmpfile(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("adaqat_packed_{}_{name}", std::process::id()))
    }

    fn random_tensor(shape: Vec<usize>, seed: u64) -> Tensor {
        let mut rng = Rng::new(seed);
        let n: usize = shape.iter().product();
        Tensor::new(shape, (0..n).map(|_| rng.normal() * 0.1).collect())
    }

    #[test]
    fn bit_packing_roundtrips_all_widths() {
        // payload layout is owned by kernels::pack now; this pins the
        // same LSB-first contract at the PackedTensor level
        for bits in [1u32, 2, 3, 4, 5, 7, 8, 11, 16, 24] {
            let max = (1u64 << bits) - 1;
            let codes: Vec<u32> =
                (0..100u64).map(|i| ((i * 2654435761) % (max + 1)) as u32).collect();
            let buf = pack::pack_codes(&codes, bits);
            assert_eq!(buf.len(), (codes.len() * bits as usize + 7) / 8);
            for (i, &c) in codes.iter().enumerate() {
                assert_eq!(
                    pack::read_bits_scalar(&buf, i * bits as usize, bits),
                    c,
                    "bits={bits} i={i}"
                );
            }
            assert_eq!(pack::unpack_codes(&buf, bits, codes.len()), codes);
        }
    }

    #[test]
    fn dequantize_is_deterministic_and_bounded() {
        let t = random_tensor(vec![64, 3], 1);
        let p = PackedTensor::quantize(&t, 4);
        let a = p.dequantize();
        let b = p.dequantize();
        assert_eq!(a, b);
        let max = t.data.iter().fold(0.0f32, |m, x| m.max(x.abs()));
        // 4-bit grid: worst-case error is one half-step of 2·max/15
        let step = 2.0 * max / 15.0;
        for (x, q) in t.data.iter().zip(&a.data) {
            assert!((x - q).abs() <= 0.5 * step + 1e-6, "{x} vs {q}");
        }
    }

    #[test]
    fn raw_tensors_are_bit_exact() {
        let t = random_tensor(vec![17], 2);
        assert_eq!(PackedTensor::raw(&t).dequantize(), t);
    }

    #[test]
    fn zero_tensor_survives() {
        let t = Tensor::zeros(vec![8, 8]);
        let p = PackedTensor::quantize(&t, 3);
        assert_eq!(p.scale, 0.0);
        assert_eq!(p.dequantize(), t);
    }

    #[test]
    fn file_roundtrip_exact_dequant() {
        let mut q = QuantizedCheckpoint::new(Json::obj(vec![
            ("model", Json::str("resnet20")),
            ("k_a", Json::num(4.0)),
        ]));
        q.push("stem.w", PackedTensor::quantize(&random_tensor(vec![3, 3, 3, 16], 3), 4));
        q.push("stem.bn.mean", PackedTensor::raw(&random_tensor(vec![16], 4)));
        q.push("fc.w", PackedTensor::quantize(&random_tensor(vec![64, 10], 5), 2));
        let path = tmpfile("roundtrip.aqq");
        q.save(&path).unwrap();
        let rt = QuantizedCheckpoint::load(&path).unwrap();
        assert_eq!(rt.meta.get("model").unwrap().as_str(), Some("resnet20"));
        assert_eq!(rt.tensors.len(), 3);
        for ((n1, t1), (n2, t2)) in q.tensors.iter().zip(&rt.tensors) {
            assert_eq!(n1, n2);
            assert_eq!(t1, t2);
            // the canonical dequantized stream is bit-identical
            assert_eq!(t1.dequantize().data, t2.dequantize().data);
        }
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn from_checkpoint_selects_weights_and_carries_meta() {
        let mut ck = Checkpoint::new(Json::obj(vec![
            ("model", Json::str("toy")),
            ("k_a", Json::num(8.0)),
        ]));
        ck.push("conv1.w", random_tensor(vec![3, 3, 3, 8], 6));
        ck.push("conv1.b", random_tensor(vec![8], 7));
        ck.push("bn.var", random_tensor(vec![8], 8));
        let q = QuantizedCheckpoint::from_checkpoint(&ck, 4, |n| n.ends_with(".w"));
        assert_eq!(q.get("conv1.w").unwrap().bits, 4);
        assert_eq!(q.get("conv1.b").unwrap().bits, RAW_BITS);
        assert_eq!(q.get("bn.var").unwrap().bits, RAW_BITS);
        assert_eq!(q.meta.get("k_w").unwrap().as_f64(), Some(4.0));
        assert_eq!(q.meta.get("k_a").unwrap().as_f64(), Some(8.0));
        assert_eq!(q.meta.get("model").unwrap().as_str(), Some("toy"));
        // dequantized checkpoint exposes the same tensor names in order
        let back = q.to_checkpoint();
        let names: Vec<&str> = back.tensors.iter().map(|(n, _)| n.as_str()).collect();
        assert_eq!(names, vec!["conv1.w", "conv1.b", "bn.var"]);
        // raw tensors pass through exactly
        assert_eq!(back.tensors[1].1, ck.tensors[1].1);
    }

    #[test]
    fn four_bit_file_is_at_most_a_sixth_of_fp32() {
        // weight-dominated model, as every real manifest is
        let mut ck = Checkpoint::new(Json::Null);
        ck.push("fc.w", random_tensor(vec![3072, 10], 9));
        ck.push("fc.b", random_tensor(vec![10], 10));
        let fp32_path = tmpfile("size_fp32.ckpt");
        ck.save(&fp32_path).unwrap();
        let q = QuantizedCheckpoint::from_checkpoint(&ck, 4, |n| n.ends_with(".w"));
        let packed_path = tmpfile("size_packed.aqq");
        q.save(&packed_path).unwrap();
        let fp32 = std::fs::metadata(&fp32_path).unwrap().len();
        let packed = std::fs::metadata(&packed_path).unwrap().len();
        assert!(
            packed * 6 <= fp32,
            "packed {packed} bytes vs fp32 {fp32} — ratio {:.3}",
            packed as f64 / fp32 as f64
        );
        std::fs::remove_file(fp32_path).ok();
        std::fs::remove_file(packed_path).ok();
    }

    #[test]
    fn meta_layer_names_absent_valid_and_malformed() {
        let mut q = QuantizedCheckpoint::new(Json::obj(vec![
            (
                "mlp_layers",
                Json::Arr(vec![Json::str("fc1"), Json::str("fc2")]),
            ),
            ("conv_layers", Json::Arr(vec![])),
            ("k_a", Json::num(8.0)),
        ]));
        assert_eq!(
            q.meta_layer_names("mlp_layers").unwrap(),
            Some(vec!["fc1".to_string(), "fc2".to_string()])
        );
        assert_eq!(q.meta_layer_names("missing").unwrap(), None);
        assert!(q.meta_layer_names("conv_layers").is_err(), "empty array");
        assert!(q.meta_layer_names("k_a").is_err(), "not an array");
        if let Json::Obj(m) = &mut q.meta {
            m.insert(
                "bad".to_string(),
                Json::Arr(vec![Json::str("x"), Json::num(1.0)]),
            );
        }
        assert!(q.meta_layer_names("bad").is_err(), "non-string entry");
    }

    #[test]
    fn empty_non_ascii_and_truncated() {
        // empty tensor list + non-ASCII name in meta
        let q = QuantizedCheckpoint::new(Json::obj(vec![("λ", Json::num(0.15))]));
        let path = tmpfile("empty.aqq");
        q.save(&path).unwrap();
        let rt = QuantizedCheckpoint::load(&path).unwrap();
        assert!(rt.tensors.is_empty());
        assert_eq!(rt.meta.get("λ").unwrap().as_f64(), Some(0.15));
        // non-ASCII tensor name
        let mut q2 = QuantizedCheckpoint::new(Json::Null);
        q2.push("重み.w", PackedTensor::quantize(&random_tensor(vec![32], 11), 4));
        q2.save(&path).unwrap();
        let rt2 = QuantizedCheckpoint::load(&path).unwrap();
        assert_eq!(rt2.tensors[0].0, "重み.w");
        // truncation anywhere is an error, not a short read
        let bytes = std::fs::read(&path).unwrap();
        for cut in [bytes.len() - 3, 20, 9] {
            std::fs::write(&path, &bytes[..cut]).unwrap();
            assert!(QuantizedCheckpoint::load(&path).is_err(), "cut at {cut}");
        }
        // wrong magic
        std::fs::write(&path, b"AQCKPT01xxxxxxxxxxxx").unwrap();
        assert!(QuantizedCheckpoint::load(&path).is_err());
        std::fs::remove_file(path).ok();
    }
}

//! Experiment configuration system.
//!
//! A config is a typed struct with defaults per model, overridable from
//! (a) a `key = value` config file (TOML-subset: flat keys, `#` comments)
//! and (b) CLI flags (`--epochs 5`). Every experiment — examples, bench
//! harnesses, the `adaqat train` subcommand — goes through this struct,
//! so runs are fully describable by a small text file.

use std::path::{Path, PathBuf};

use crate::util::cli::Args;

/// Which bit-width controller drives the run (paper §III vs baselines).
#[derive(Debug, Clone, PartialEq)]
pub enum ControllerKind {
    /// The paper's method: fractional bit-widths + finite differences.
    AdaQat,
    /// Static bit-widths (DoReFa/PACT-style rows of Table I).
    Fixed { k_w: u32, k_a: u32 },
    /// FracBits-style scheduled relaxation (comparator, DESIGN.md §5).
    FracBits { k_w_target: u32, k_a_target: u32 },
}

/// Training scenario (paper §IV: fine-tuning vs from scratch).
#[derive(Debug, Clone, PartialEq)]
pub enum Scenario {
    Scratch,
    Finetune { checkpoint: PathBuf },
}

#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    /// Manifest model key: smallcnn | resnet20 | resnet18 | smallcnn_pallas
    /// (PJRT), or "native-mlp" for the native backend.
    pub model: String,
    /// Dataset: "cifar10" (10-class synthetic) | "imagenet-lite" (100-class).
    pub dataset: String,
    pub scenario: Scenario,
    pub controller: ControllerKind,
    /// Run the fp32 baseline graph instead of the quantized one.
    pub fp32: bool,
    /// Step backend: "pjrt" (compiled HLO artifacts) | "native" (the
    /// pure-Rust `backprop` MLP trainer — runs offline, DESIGN.md §12).
    pub backend: String,
    /// Hidden-layer widths of the native MLP (ignored by pjrt).
    pub hidden: Vec<usize>,
    /// Conv channel widths of the native conv models: one per
    /// conv→BN→ReLU→pool block (smallcnn) or one per residual stage
    /// (resnet20-class). Ignored by pjrt and the native MLP.
    pub channels: Vec<usize>,
    /// Residual blocks per stage of the native resnet20-class model
    /// (DESIGN.md §18; the paper's ResNet20 is channels = 16,32,64 with
    /// blocks = 3). Ignored by every other model.
    pub blocks: usize,
    /// Batch size of the native backend (pjrt batch comes from the
    /// compiled artifact's static shape).
    pub batch: usize,
    /// Synthetic image side length. The PJRT artifact models are
    /// compiled for 32; the native backend accepts any size.
    pub image_hw: usize,

    pub epochs: usize,
    pub train_size: usize,
    pub test_size: usize,
    /// Initial LR; cosine-annealed to 0 over `epochs` (paper §IV-A).
    pub lr: f64,
    /// Hardware-loss balance λ (paper eq. (2)).
    pub lambda: f64,
    /// Bit-width learning rates η_w, η_a (paper §III-C defaults).
    pub eta_w: f64,
    pub eta_a: f64,
    /// Initial fractional bit-widths.
    pub init_nw: f64,
    pub init_na: f64,
    /// Run the finite-difference probe every this many train steps.
    pub probe_interval: usize,
    /// Oscillation count that freezes a bit-width (paper: 10).
    pub osc_threshold: usize,

    pub seed: u64,
    /// Where to write metrics CSVs / checkpoints (None = no output files).
    pub out_dir: Option<PathBuf>,
    /// Hardware-loss model for AdaQAT (paper §III-B "product" by
    /// default; "memory" | "fpga-dsp" | "energy" are the §V future-work
    /// extensions implemented in quant::energy).
    pub hard_cost: String,
}

impl ExperimentConfig {
    /// Sensible CPU-scale defaults for a model key.
    pub fn default_for(model: &str) -> ExperimentConfig {
        let (dataset, train_size, test_size) = match model {
            "resnet18" => ("imagenet-lite", 4096, 512),
            _ => ("cifar10", 8192, 1024),
        };
        ExperimentConfig {
            model: model.to_string(),
            dataset: dataset.to_string(),
            scenario: Scenario::Scratch,
            controller: ControllerKind::AdaQat,
            fp32: false,
            backend: "pjrt".to_string(),
            hidden: vec![64],
            channels: vec![8, 16],
            blocks: 2,
            batch: 32,
            image_hw: 32,
            epochs: 4,
            train_size,
            test_size,
            lr: 0.1,
            lambda: 0.15,
            eta_w: 0.001,
            eta_a: 0.0005,
            init_nw: 8.0,
            init_na: 8.0,
            probe_interval: 1,
            osc_threshold: 10,
            seed: 0,
            out_dir: None,
            hard_cost: "product".to_string(),
        }
    }

    /// Apply one `key = value` setting; returns Err for unknown keys or
    /// unparsable values.
    pub fn set(&mut self, key: &str, value: &str) -> Result<(), String> {
        fn p<T: std::str::FromStr>(k: &str, v: &str) -> Result<T, String> {
            v.parse().map_err(|_| format!("{k}: cannot parse {v:?}"))
        }
        match key {
            "model" => self.model = value.to_string(),
            "dataset" => self.dataset = value.to_string(),
            "fp32" => self.fp32 = p(key, value)?,
            "backend" => {
                if !["pjrt", "native"].contains(&value) {
                    return Err(format!("backend: expected pjrt|native, got {value:?}"));
                }
                self.backend = value.to_string();
            }
            "hidden" => {
                // comma-separated widths: "64" or "128,64"
                self.hidden = value
                    .split(',')
                    .map(|v| {
                        v.trim()
                            .parse()
                            .map_err(|_| format!("hidden: cannot parse {v:?}"))
                    })
                    .collect::<Result<Vec<usize>, String>>()?;
            }
            "channels" => {
                // comma-separated conv widths: "8,16" or "16,32,64"
                self.channels = value
                    .split(',')
                    .map(|v| {
                        v.trim()
                            .parse()
                            .map_err(|_| format!("channels: cannot parse {v:?}"))
                    })
                    .collect::<Result<Vec<usize>, String>>()?;
            }
            "blocks" => self.blocks = p(key, value)?,
            "batch" => self.batch = p(key, value)?,
            "image_hw" => self.image_hw = p(key, value)?,
            "epochs" => self.epochs = p(key, value)?,
            "train_size" => self.train_size = p(key, value)?,
            "test_size" => self.test_size = p(key, value)?,
            "lr" => self.lr = p(key, value)?,
            "lambda" => self.lambda = p(key, value)?,
            "eta_w" => self.eta_w = p(key, value)?,
            "eta_a" => self.eta_a = p(key, value)?,
            "init_nw" => self.init_nw = p(key, value)?,
            "init_na" => self.init_na = p(key, value)?,
            "probe_interval" => self.probe_interval = p(key, value)?,
            "osc_threshold" => self.osc_threshold = p(key, value)?,
            "seed" => self.seed = p(key, value)?,
            "out_dir" => self.out_dir = Some(PathBuf::from(value)),
            "hard_cost" => {
                if !["product", "memory", "fpga-dsp", "energy"].contains(&value) {
                    return Err(format!(
                        "hard_cost: expected product|memory|fpga-dsp|energy, got {value:?}"
                    ));
                }
                self.hard_cost = value.to_string();
            }
            "checkpoint" => {
                self.scenario = Scenario::Finetune { checkpoint: PathBuf::from(value) }
            }
            "controller" => {
                self.controller = match value {
                    "adaqat" => ControllerKind::AdaQat,
                    other => {
                        // fixed:2:32  |  fracbits:3:4
                        let parts: Vec<&str> = other.split(':').collect();
                        match parts.as_slice() {
                            ["fixed", w, a] => ControllerKind::Fixed {
                                k_w: p("k_w", w)?,
                                k_a: p("k_a", a)?,
                            },
                            ["fracbits", w, a] => ControllerKind::FracBits {
                                k_w_target: p("k_w", w)?,
                                k_a_target: p("k_a", a)?,
                            },
                            _ => return Err(format!(
                                "controller: expected adaqat|fixed:W:A|fracbits:W:A, got {value:?}"
                            )),
                        }
                    }
                }
            }
            _ => return Err(format!("unknown config key {key:?}")),
        }
        Ok(())
    }

    /// Load `key = value` lines (TOML-subset; `#` comments, blank lines).
    pub fn apply_file(&mut self, path: &Path) -> Result<(), String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("{path:?}: {e}"))?;
        for (lineno, line) in text.lines().enumerate() {
            let line = line.split('#').next().unwrap().trim();
            if line.is_empty() {
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| format!("{path:?}:{}: expected key = value", lineno + 1))?;
            self.set(k.trim(), v.trim().trim_matches('"'))
                .map_err(|e| format!("{path:?}:{}: {e}", lineno + 1))?;
        }
        Ok(())
    }

    /// Apply CLI overrides for every key present in `args`.
    pub fn apply_args(&mut self, args: &Args) -> Result<(), String> {
        for key in [
            "model", "dataset", "fp32", "backend", "hidden", "channels", "blocks",
            "batch", "image_hw", "epochs", "train_size", "test_size",
            "lr", "lambda", "eta_w", "eta_a", "init_nw", "init_na",
            "probe_interval", "osc_threshold", "seed", "out_dir",
            "checkpoint", "controller", "hard_cost",
        ] {
            if args.has(key) {
                let v = args.get_str(key, "");
                self.set(key, &v)?;
            }
        }
        Ok(())
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.epochs == 0 {
            return Err("epochs must be >= 1".into());
        }
        if !(self.lr > 0.0) {
            return Err("lr must be positive".into());
        }
        if self.lambda < 0.0 {
            return Err("lambda must be >= 0".into());
        }
        if !(1.0..=32.0).contains(&self.init_nw) || !(1.0..=32.0).contains(&self.init_na) {
            return Err("init_nw/init_na must be in [1, 32]".into());
        }
        if self.probe_interval == 0 {
            return Err("probe_interval must be >= 1".into());
        }
        if self.batch == 0 {
            return Err("batch must be >= 1".into());
        }
        if !(4..=64).contains(&self.image_hw) {
            return Err("image_hw must be in [4, 64]".into());
        }
        if self.backend == "native" {
            if crate::backprop::is_native_conv_model(&self.model) {
                // one geometry contract, owned by the manifest builder
                crate::backprop::validate_smallcnn_geometry(self.image_hw, &self.channels)?;
            } else if crate::backprop::is_native_resnet_model(&self.model) {
                crate::backprop::validate_resnet_geometry(
                    self.image_hw,
                    &self.channels,
                    self.blocks,
                )?;
            } else if self.hidden.is_empty() || self.hidden.contains(&0) {
                return Err("native backend needs at least one non-zero hidden width".into());
            }
        }
        Ok(())
    }
}

/// Configuration for `adaqat serve` (DESIGN.md §7). Same conventions as
/// [`ExperimentConfig`]: typed struct, `key = value` settings, CLI
/// overrides via [`Args`].
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Packed checkpoint (`adaqat export` output) to serve.
    pub checkpoint: PathBuf,
    /// Bind address, e.g. "127.0.0.1:7878" (port 0 picks a free port).
    pub addr: String,
    /// Worker threads, each owning one backend instance.
    pub workers: usize,
    /// Bounded request-queue capacity (beyond it, clients see
    /// backpressure errors instead of unbounded buffering).
    pub queue_capacity: usize,
    /// Dynamic-batching window in milliseconds: the max time a lone
    /// request waits for company before a partial batch ships.
    pub max_delay_ms: u64,
    /// "reference" (pure-Rust quantized kernels, offline-runnable) or
    /// "runtime" (compiled infer graph on PJRT).
    pub backend: String,
    /// Manifest model key for the runtime backend.
    pub model: String,
    /// GEMM row-parallelism per backend instance (std::thread workers
    /// inside the kernels, DESIGN.md §11); 0 = one per available core.
    /// Total compute threads ≈ workers × threads, so the default keeps
    /// one GEMM thread per serving worker.
    pub threads: usize,
    /// When set, the serve loop rewrites this file with the Prometheus
    /// text exposition at every stats interval (DESIGN.md §15) — a
    /// file-scrape surface for setups without a TCP scraper.
    pub metrics_out: Option<PathBuf>,
    /// Deadline budget applied to requests that carry no `deadline_ms`
    /// of their own (DESIGN.md §19); 0 = requests without a deadline
    /// never expire.
    pub default_deadline_ms: u64,
    /// Admission-control trip wire: reject with `overloaded` +
    /// `retry_after_ms` once the estimated queue wait exceeds this
    /// budget (DESIGN.md §19); 0 disarms admission control (the queue
    /// sheds with `queue_full` at capacity, as before).
    pub max_wait_ms: u64,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            checkpoint: PathBuf::new(),
            addr: "127.0.0.1:7878".to_string(),
            workers: 2,
            queue_capacity: 1024,
            max_delay_ms: 5,
            backend: "reference".to_string(),
            model: "resnet20".to_string(),
            threads: 1,
            metrics_out: None,
            default_deadline_ms: 0,
            max_wait_ms: 500,
        }
    }
}

impl ServeConfig {
    pub fn set(&mut self, key: &str, value: &str) -> Result<(), String> {
        fn p<T: std::str::FromStr>(k: &str, v: &str) -> Result<T, String> {
            v.parse().map_err(|_| format!("{k}: cannot parse {v:?}"))
        }
        match key {
            "checkpoint" => self.checkpoint = PathBuf::from(value),
            "addr" => self.addr = value.to_string(),
            "workers" => self.workers = p(key, value)?,
            "queue_capacity" => self.queue_capacity = p(key, value)?,
            "max_delay_ms" => self.max_delay_ms = p(key, value)?,
            "default_deadline_ms" => self.default_deadline_ms = p(key, value)?,
            "max_wait_ms" => self.max_wait_ms = p(key, value)?,
            "threads" => self.threads = p(key, value)?,
            "metrics_out" => self.metrics_out = Some(PathBuf::from(value)),
            "model" => self.model = value.to_string(),
            "backend" => {
                if !["reference", "runtime"].contains(&value) {
                    return Err(format!(
                        "backend: expected reference|runtime, got {value:?}"
                    ));
                }
                self.backend = value.to_string();
            }
            _ => return Err(format!("unknown serve config key {key:?}")),
        }
        Ok(())
    }

    pub fn apply_args(&mut self, args: &Args) -> Result<(), String> {
        for key in [
            "checkpoint", "addr", "workers", "queue_capacity", "max_delay_ms",
            "default_deadline_ms", "max_wait_ms", "backend", "model", "threads",
            "metrics_out",
        ] {
            if args.has(key) {
                let v = args.get_str(key, "");
                self.set(key, &v)?;
            }
        }
        Ok(())
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.checkpoint.as_os_str().is_empty() {
            return Err("serve requires --checkpoint (a packed .aqq file)".into());
        }
        if self.workers == 0 {
            return Err("workers must be >= 1".into());
        }
        if self.queue_capacity == 0 {
            return Err("queue_capacity must be >= 1".into());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_differ_by_model() {
        let a = ExperimentConfig::default_for("resnet20");
        let b = ExperimentConfig::default_for("resnet18");
        assert_eq!(a.dataset, "cifar10");
        assert_eq!(b.dataset, "imagenet-lite");
        assert_eq!(a.eta_w, 0.001);
        assert_eq!(a.eta_a, 0.0005);
        assert_eq!(a.osc_threshold, 10);
    }

    #[test]
    fn set_and_validate() {
        let mut c = ExperimentConfig::default_for("resnet20");
        c.set("lambda", "0.2").unwrap();
        c.set("controller", "fixed:2:32").unwrap();
        assert_eq!(c.lambda, 0.2);
        assert_eq!(c.controller, ControllerKind::Fixed { k_w: 2, k_a: 32 });
        c.set("controller", "adaqat").unwrap();
        assert_eq!(c.controller, ControllerKind::AdaQat);
        assert!(c.validate().is_ok());
        c.set("epochs", "0").unwrap();
        assert!(c.validate().is_err());
    }

    #[test]
    fn native_backend_keys_parse_and_validate() {
        let mut c = ExperimentConfig::default_for("native-mlp");
        assert_eq!(c.backend, "pjrt");
        assert_eq!(c.image_hw, 32);
        c.set("backend", "native").unwrap();
        c.set("hidden", "128, 64").unwrap();
        c.set("batch", "16").unwrap();
        c.set("image_hw", "16").unwrap();
        assert_eq!(c.hidden, vec![128, 64]);
        assert!(c.validate().is_ok());
        assert!(c.set("backend", "cuda").is_err());
        assert!(c.set("hidden", "12,x").is_err());
        c.set("image_hw", "2").unwrap();
        assert!(c.validate().is_err());
        c.set("image_hw", "16").unwrap();
        c.set("hidden", "0").unwrap();
        assert!(c.validate().is_err());
    }

    #[test]
    fn native_conv_keys_parse_and_validate() {
        let mut c = ExperimentConfig::default_for("smallcnn");
        assert_eq!(c.channels, vec![8, 16]);
        c.set("backend", "native").unwrap();
        c.set("channels", "4, 8").unwrap();
        c.set("image_hw", "16").unwrap();
        assert_eq!(c.channels, vec![4, 8]);
        assert!(c.validate().is_ok());
        assert!(c.set("channels", "4,x").is_err());
        c.set("channels", "0").unwrap();
        assert!(c.validate().is_err(), "zero conv width");
        // one pool per block: hw must divide by 2^blocks
        c.set("channels", "4,8,16").unwrap();
        c.set("image_hw", "12").unwrap();
        assert!(c.validate().is_err(), "12 % 8 != 0");
        c.set("image_hw", "16").unwrap();
        assert!(c.validate().is_ok());
        // the MLP hidden-width rule still applies to non-conv models
        c.set("model", "native-mlp").unwrap();
        c.set("hidden", "0").unwrap();
        assert!(c.validate().is_err());
    }

    #[test]
    fn native_resnet_keys_parse_and_validate() {
        let mut c = ExperimentConfig::default_for("resnet20");
        assert_eq!(c.blocks, 2);
        // under pjrt, "resnet20" names the artifact model: no geometry rule
        assert!(c.validate().is_ok());
        c.set("backend", "native").unwrap();
        c.set("channels", "4, 8").unwrap();
        c.set("blocks", "1").unwrap();
        c.set("image_hw", "8").unwrap();
        assert!(c.validate().is_ok());
        assert!(c.set("blocks", "x").is_err());
        c.set("blocks", "0").unwrap();
        assert!(c.validate().is_err(), "zero blocks per stage");
        c.set("blocks", "1").unwrap();
        // one stride-2 downsample per stage transition: hw % 2^(stages-1)
        c.set("channels", "4,8,16").unwrap();
        c.set("image_hw", "12").unwrap();
        assert!(c.validate().is_err(), "12 % 4 != 0");
        c.set("image_hw", "16").unwrap();
        assert!(c.validate().is_ok());
    }

    #[test]
    fn rejects_unknown_and_bad() {
        let mut c = ExperimentConfig::default_for("resnet20");
        assert!(c.set("nope", "1").is_err());
        assert!(c.set("epochs", "x").is_err());
        assert!(c.set("controller", "magic").is_err());
    }

    #[test]
    fn config_file_roundtrip() {
        let mut c = ExperimentConfig::default_for("resnet20");
        let path = std::env::temp_dir()
            .join(format!("adaqat_cfg_{}.toml", std::process::id()));
        std::fs::write(
            &path,
            "# comment\nepochs = 7\nlambda = 0.1  # inline\ncontroller = \"fracbits:3:4\"\n",
        )
        .unwrap();
        c.apply_file(&path).unwrap();
        assert_eq!(c.epochs, 7);
        assert_eq!(c.lambda, 0.1);
        assert_eq!(
            c.controller,
            ControllerKind::FracBits { k_w_target: 3, k_a_target: 4 }
        );
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn cli_overrides() {
        let mut c = ExperimentConfig::default_for("resnet20");
        let args = Args::parse(
            "--epochs 3 --lambda 0.2 --checkpoint runs/fp.ckpt"
                .split_whitespace()
                .map(String::from),
        )
        .unwrap();
        c.apply_args(&args).unwrap();
        assert_eq!(c.epochs, 3);
        assert!(matches!(c.scenario, Scenario::Finetune { .. }));
    }

    #[test]
    fn serve_config_defaults_overrides_and_validation() {
        let mut s = ServeConfig::default();
        assert!(s.validate().is_err(), "checkpoint is required");
        assert_eq!(s.metrics_out, None, "no exposition dump unless asked");
        let args = Args::parse(
            "--checkpoint runs/demo/packed.aqq --workers 4 --max_delay_ms 2 --backend runtime --model smallcnn --threads 0 --metrics_out runs/demo/metrics.prom"
                .split_whitespace()
                .map(String::from),
        )
        .unwrap();
        assert_eq!(s.default_deadline_ms, 0, "no implicit deadline by default");
        assert_eq!(s.max_wait_ms, 500, "admission control armed by default");
        s.apply_args(&args).unwrap();
        assert!(s.validate().is_ok());
        assert_eq!(s.workers, 4);
        assert_eq!(s.max_delay_ms, 2);
        assert_eq!(s.backend, "runtime");
        assert_eq!(s.model, "smallcnn");
        assert_eq!(s.threads, 0, "0 = auto-size to the machine");
        assert_eq!(s.addr, "127.0.0.1:7878");
        assert_eq!(s.metrics_out, Some(PathBuf::from("runs/demo/metrics.prom")));
        s.set("default_deadline_ms", "250").unwrap();
        s.set("max_wait_ms", "0").unwrap();
        assert_eq!(s.default_deadline_ms, 250);
        assert_eq!(s.max_wait_ms, 0, "0 disarms admission control");
        assert!(s.validate().is_ok());
    }

    #[test]
    fn serve_config_rejects_bad_values() {
        let mut s = ServeConfig::default();
        assert!(s.set("backend", "gpu-magic").is_err());
        assert!(s.set("workers", "zero").is_err());
        assert!(s.set("nope", "1").is_err());
        s.set("checkpoint", "x.aqq").unwrap();
        s.set("workers", "0").unwrap();
        assert!(s.validate().is_err());
    }
}

//! Unified observability layer (DESIGN.md §15).
//!
//! One process-wide [`Registry`] of named + labeled series — counters,
//! gauges, and log-bucketed histograms (the existing
//! [`crate::metrics::Histogram`] is the storage engine) — that the
//! serve queue, batcher, worker pool, kernels, and training loop all
//! register into, plus a fixed-size [`TraceRing`] of per-request spans.
//!
//! Design split, chosen for the serving hot path:
//! * **Registration** (naming a series, first lookup) takes a `Mutex`
//!   and allocates — done once, at construction time (backend build,
//!   pool build, queue build), never per request.
//! * **Updates** go through pre-registered handles ([`Counter`],
//!   [`Gauge`], [`HistHandle`]) and are single relaxed atomic ops — no
//!   lock, no allocation, no branch beyond the enable check.
//! * **Rendering** ([`Registry::render_prometheus`]) takes the
//!   registration lock and snapshots every series into Prometheus text
//!   exposition format: every emitted line is `name{labels} value`
//!   (histograms expand to `_count`/`_sum`/quantile/`_max` lines).
//!
//! The enable switch ([`Registry::set_enabled`]) gates the *samplers* —
//! counters and histograms skip their atomic write when disabled, and
//! instrumentation sites skip their `Instant::now()` calls by checking
//! [`Registry::enabled`] first. Gauges deliberately ignore the switch:
//! they track live structural state (queue depth, pool occupancy) via
//! paired `add(+1)/add(-1)` calls, and honoring a mid-flight toggle
//! would leave them skewed forever. `benches/obs.rs` uses the switch to
//! measure the instrumented-vs-uninstrumented serve throughput ratio
//! that `scripts/check_bench.sh` gates at ≤ 5% overhead.
//!
//! Label cardinality budget: series registration is capped at
//! [`MAX_SERIES`]. Callers must only label by *bounded* dimensions
//! (layer name, plan kind, bit-width, axis, reason) — never by request
//! id or other unbounded values; those belong in the trace ring.
//! Overflowing the cap warns once and hands back detached handles that
//! update normally but never render, so a labeling bug degrades
//! exposition instead of memory.

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

use crate::metrics::{Histogram, LatencySnapshot};

/// Hard cap on registered series (the label cardinality budget,
/// DESIGN.md §15). Per-layer series are `layers × plans × widths`, all
/// small and bounded; 4096 leaves two orders of magnitude of headroom.
pub const MAX_SERIES: usize = 4096;

/// How many request traces the ring keeps (newest win).
pub const TRACE_RING_CAPACITY: usize = 256;

/// Monotonically increasing event count. Updates are one relaxed
/// `fetch_add`; disabled registries skip the write entirely.
pub struct Counter {
    v: AtomicU64,
    enabled: Arc<AtomicBool>,
}

impl Counter {
    fn new(enabled: Arc<AtomicBool>) -> Counter {
        Counter { v: AtomicU64::new(0), enabled }
    }

    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        if self.enabled.load(Ordering::Relaxed) {
            self.v.fetch_add(n, Ordering::Relaxed);
        }
    }

    pub fn get(&self) -> u64 {
        self.v.load(Ordering::Relaxed)
    }
}

/// A current-value series (queue depth, pool occupancy, controller
/// bit-width). Stored as f64 bits in one atomic; `add` is a CAS loop
/// (uncontended in practice — each gauge has a handful of writers).
/// Gauges ignore the registry's enable switch — see the module docs.
pub struct Gauge {
    bits: AtomicU64,
}

impl Gauge {
    fn new() -> Gauge {
        Gauge { bits: AtomicU64::new(0f64.to_bits()) }
    }

    pub fn set(&self, v: f64) {
        self.bits.store(v.to_bits(), Ordering::Relaxed);
    }

    pub fn add(&self, d: f64) {
        let _ = self.bits.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |b| {
            Some((f64::from_bits(b) + d).to_bits())
        });
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.bits.load(Ordering::Relaxed))
    }
}

/// A registered log-bucketed histogram: [`crate::metrics::Histogram`]
/// (the storage engine — 96 log-spaced buckets, relaxed atomics) behind
/// the registry's enable switch.
pub struct HistHandle {
    h: Histogram,
    enabled: Arc<AtomicBool>,
}

impl HistHandle {
    fn new(enabled: Arc<AtomicBool>) -> HistHandle {
        HistHandle { h: Histogram::new(), enabled }
    }

    pub fn record_ms(&self, ms: f64) {
        if self.enabled.load(Ordering::Relaxed) {
            self.h.record_ms(ms);
        }
    }

    /// Unit-agnostic alias: the log-bucket storage works for any
    /// non-negative magnitude (e.g. rows per batch), not just
    /// milliseconds — the series name carries the unit.
    pub fn record(&self, v: f64) {
        self.record_ms(v);
    }

    pub fn count(&self) -> u64 {
        self.h.count()
    }

    pub fn snapshot(&self) -> LatencySnapshot {
        self.h.snapshot()
    }
}

enum Handle {
    Counter(Arc<Counter>),
    Gauge(Arc<Gauge>),
    Hist(Arc<HistHandle>),
}

struct SeriesEntry {
    name: String,
    /// Pre-rendered label block: `{k="v",…}`, or `""` when unlabeled.
    labels: String,
    handle: Handle,
}

/// The series table. One process-wide instance lives behind
/// [`global()`]; tests build isolated instances via [`Registry::new`]
/// so gauge assertions stay deterministic under parallel test threads.
pub struct Registry {
    series: Mutex<BTreeMap<String, SeriesEntry>>,
    enabled: Arc<AtomicBool>,
    overflow_warned: AtomicBool,
}

impl Default for Registry {
    fn default() -> Registry {
        Registry::new()
    }
}

/// The process-wide registry every production call site registers into.
pub fn global() -> &'static Registry {
    static G: OnceLock<Registry> = OnceLock::new();
    G.get_or_init(Registry::new)
}

impl Registry {
    pub fn new() -> Registry {
        Registry {
            series: Mutex::new(BTreeMap::new()),
            enabled: Arc::new(AtomicBool::new(true)),
            overflow_warned: AtomicBool::new(false),
        }
    }

    /// Whether samplers record. Instrumentation sites with setup cost
    /// (an `Instant::now()` per layer) check this first and skip the
    /// whole block when off.
    pub fn enabled(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    /// Flip sampling on/off (counters + histograms; gauges keep
    /// tracking — see the module docs). The obs bench uses this to
    /// measure overhead; operators could use it as a kill switch.
    pub fn set_enabled(&self, on: bool) {
        self.enabled.store(on, Ordering::Relaxed);
    }

    /// Get-or-register a counter. Same `(name, labels)` → the same
    /// underlying series, so re-construction (a rebuilt backend, a
    /// second engine) keeps accumulating rather than resetting.
    pub fn counter(&self, name: &str, labels: &[(&str, &str)]) -> Arc<Counter> {
        let labels_s = format_labels(labels);
        let key = format!("{name}{labels_s}");
        let mut g = self.series.lock().unwrap();
        if let Some(e) = g.get(&key) {
            if let Handle::Counter(c) = &e.handle {
                return Arc::clone(c);
            }
            log::warn!("obs: {key} already registered as a different type");
            return Arc::new(Counter::new(Arc::clone(&self.enabled)));
        }
        if self.over_budget(&g) {
            return Arc::new(Counter::new(Arc::clone(&self.enabled)));
        }
        let c = Arc::new(Counter::new(Arc::clone(&self.enabled)));
        g.insert(
            key,
            SeriesEntry {
                name: name.to_string(),
                labels: labels_s,
                handle: Handle::Counter(Arc::clone(&c)),
            },
        );
        c
    }

    /// Get-or-register a gauge (see [`Registry::counter`] for the
    /// get-or-register contract).
    pub fn gauge(&self, name: &str, labels: &[(&str, &str)]) -> Arc<Gauge> {
        let labels_s = format_labels(labels);
        let key = format!("{name}{labels_s}");
        let mut g = self.series.lock().unwrap();
        if let Some(e) = g.get(&key) {
            if let Handle::Gauge(v) = &e.handle {
                return Arc::clone(v);
            }
            log::warn!("obs: {key} already registered as a different type");
            return Arc::new(Gauge::new());
        }
        if self.over_budget(&g) {
            return Arc::new(Gauge::new());
        }
        let v = Arc::new(Gauge::new());
        g.insert(
            key,
            SeriesEntry {
                name: name.to_string(),
                labels: labels_s,
                handle: Handle::Gauge(Arc::clone(&v)),
            },
        );
        v
    }

    /// Get-or-register a histogram (see [`Registry::counter`] for the
    /// get-or-register contract).
    pub fn histogram(&self, name: &str, labels: &[(&str, &str)]) -> Arc<HistHandle> {
        let labels_s = format_labels(labels);
        let key = format!("{name}{labels_s}");
        let mut g = self.series.lock().unwrap();
        if let Some(e) = g.get(&key) {
            if let Handle::Hist(h) = &e.handle {
                return Arc::clone(h);
            }
            log::warn!("obs: {key} already registered as a different type");
            return Arc::new(HistHandle::new(Arc::clone(&self.enabled)));
        }
        if self.over_budget(&g) {
            return Arc::new(HistHandle::new(Arc::clone(&self.enabled)));
        }
        let h = Arc::new(HistHandle::new(Arc::clone(&self.enabled)));
        g.insert(
            key,
            SeriesEntry {
                name: name.to_string(),
                labels: labels_s,
                handle: Handle::Hist(Arc::clone(&h)),
            },
        );
        h
    }

    fn over_budget(&self, g: &BTreeMap<String, SeriesEntry>) -> bool {
        if g.len() < MAX_SERIES {
            return false;
        }
        if !self.overflow_warned.swap(true, Ordering::Relaxed) {
            log::warn!(
                "obs: series cap {MAX_SERIES} reached — new series get detached \
                 handles and are dropped from exposition (label cardinality \
                 budget, DESIGN.md §15)"
            );
        }
        true
    }

    /// Number of registered series (tests + budget monitoring).
    pub fn series_count(&self) -> usize {
        self.series.lock().unwrap().len()
    }

    /// Render every series as Prometheus text exposition. Counters and
    /// gauges emit one `name{labels} value` line; histograms emit a
    /// summary block (`_count`, `_sum`, `quantile="…"`, `_max`) whose
    /// every line still parses as `name{labels} value`.
    pub fn render_prometheus(&self) -> String {
        let g = self.series.lock().unwrap();
        let mut out = String::new();
        for e in g.values() {
            match &e.handle {
                Handle::Counter(c) => {
                    let _ = writeln!(out, "{}{} {}", e.name, e.labels, c.get());
                }
                Handle::Gauge(v) => {
                    let _ = writeln!(out, "{}{} {}", e.name, e.labels, fmt_f64(v.get()));
                }
                Handle::Hist(h) => {
                    render_latency_lines(&mut out, &e.name, &e.labels, &h.snapshot());
                }
            }
        }
        out
    }
}

/// Append a histogram snapshot as summary-style exposition lines.
/// `labels` is a pre-rendered block from [`format_labels`] (or `""`).
/// Shared by the registry renderer and `Engine::prometheus`, which
/// mirrors its unregistered per-engine histograms through it.
pub fn render_latency_lines(out: &mut String, name: &str, labels: &str, s: &LatencySnapshot) {
    let _ = writeln!(out, "{name}_count{labels} {}", s.count);
    let _ = writeln!(out, "{name}_sum{labels} {}", fmt_f64(s.mean_ms * s.count as f64));
    for (q, v) in [("0.5", s.p50_ms), ("0.95", s.p95_ms), ("0.99", s.p99_ms)] {
        let with_q = splice_label(labels, &format!("quantile=\"{q}\""));
        let _ = writeln!(out, "{name}{with_q} {}", fmt_f64(v));
    }
    let _ = writeln!(out, "{name}_max{labels} {}", fmt_f64(s.max_ms));
}

/// Render `[("k","v"),…]` as `{k="v",…}` (empty slice → empty string).
/// Values get `\` / `"` / newline escaped per the exposition format;
/// keys are trusted (they are compile-time literals at every call site).
pub fn format_labels(labels: &[(&str, &str)]) -> String {
    if labels.is_empty() {
        return String::new();
    }
    let mut s = String::from("{");
    for (i, (k, v)) in labels.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        s.push_str(k);
        s.push_str("=\"");
        for c in v.chars() {
            match c {
                '\\' => s.push_str("\\\\"),
                '"' => s.push_str("\\\""),
                '\n' => s.push_str("\\n"),
                c => s.push(c),
            }
        }
        s.push('"');
    }
    s.push('}');
    s
}

/// Insert one extra `k="v"` pair into a pre-rendered label block.
fn splice_label(labels: &str, extra: &str) -> String {
    if labels.is_empty() {
        format!("{{{extra}}}")
    } else {
        format!("{},{extra}}}", &labels[..labels.len() - 1])
    }
}

/// Exposition-safe float: Rust's `Display` never emits scientific
/// notation, so the only parse hazards are NaN/inf — map them to 0.
fn fmt_f64(v: f64) -> String {
    format!("{}", if v.is_finite() { v } else { 0.0 })
}

// ------------------------------------------------------------- tracing

/// One request's span through the pipeline, timestamps in µs relative
/// to the owning [`TraceRing`]'s epoch (so they compare and serialize
/// without wall-clock plumbing). `enqueue ≤ batch ≤ compute_done ≤
/// reply` by construction — the e2e test pins the monotonicity down.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RequestTrace {
    pub id: u64,
    /// When the request entered the queue.
    pub enqueue_us: u64,
    /// When a worker picked the batch containing it.
    pub batch_us: u64,
    /// When that batch's forward pass finished.
    pub compute_done_us: u64,
    /// When the response was handed to the reply channel.
    pub reply_us: u64,
    /// Rows in the batch it rode in.
    pub rows: u32,
    pub ok: bool,
}

struct TraceRingInner {
    buf: Vec<RequestTrace>,
    /// Next write slot (wraps at capacity).
    next: usize,
    total: u64,
}

/// Fixed-size ring of recent [`RequestTrace`]s. Push is a short mutex
/// hold + one copy; memory is bounded by construction, so tracing can
/// stay on in production. Each engine owns one (inside
/// `EngineMetrics`), keeping traces per-engine and tests deterministic.
pub struct TraceRing {
    epoch: Instant,
    inner: Mutex<TraceRingInner>,
    capacity: usize,
}

impl Default for TraceRing {
    fn default() -> TraceRing {
        TraceRing::new(TRACE_RING_CAPACITY)
    }
}

impl TraceRing {
    pub fn new(capacity: usize) -> TraceRing {
        assert!(capacity > 0, "trace ring capacity must be positive");
        TraceRing {
            epoch: Instant::now(),
            inner: Mutex::new(TraceRingInner {
                buf: Vec::with_capacity(capacity),
                next: 0,
                total: 0,
            }),
            capacity,
        }
    }

    /// Microseconds from the ring's epoch to `t` (0 if `t` predates it).
    pub fn us_since_epoch(&self, t: Instant) -> u64 {
        t.saturating_duration_since(self.epoch).as_micros() as u64
    }

    pub fn push(&self, t: RequestTrace) {
        let mut g = self.inner.lock().unwrap();
        if g.buf.len() < self.capacity {
            g.buf.push(t);
        } else {
            let slot = g.next;
            g.buf[slot] = t;
        }
        g.next = (g.next + 1) % self.capacity;
        g.total += 1;
    }

    /// All retained traces, oldest first.
    pub fn snapshot(&self) -> Vec<RequestTrace> {
        let g = self.inner.lock().unwrap();
        if g.buf.len() < self.capacity {
            g.buf.clone()
        } else {
            let mut out = Vec::with_capacity(self.capacity);
            out.extend_from_slice(&g.buf[g.next..]);
            out.extend_from_slice(&g.buf[..g.next]);
            out
        }
    }

    /// Lifetime push count (≥ retained length).
    pub fn total(&self) -> u64 {
        self.inner.lock().unwrap().total
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse_line(line: &str) -> Option<(String, f64)> {
        // the format the e2e test also enforces: name{labels} value
        let (head, val) = line.rsplit_once(' ')?;
        let val: f64 = val.parse().ok()?;
        let name = match head.split_once('{') {
            Some((n, rest)) => {
                if !rest.ends_with('}') {
                    return None;
                }
                n
            }
            None => head,
        };
        if name.is_empty()
            || !name
                .chars()
                .all(|c| c.is_ascii_alphanumeric() || c == '_')
            || name.chars().next().unwrap().is_ascii_digit()
        {
            return None;
        }
        Some((name.to_string(), val))
    }

    #[test]
    fn get_or_register_returns_the_same_series() {
        let reg = Registry::new();
        let a = reg.counter("t_total", &[("k", "1")]);
        let b = reg.counter("t_total", &[("k", "1")]);
        let c = reg.counter("t_total", &[("k", "2")]);
        a.inc();
        b.add(2);
        c.inc();
        assert_eq!(a.get(), 3, "same (name, labels) must share storage");
        assert_eq!(c.get(), 1);
        assert_eq!(reg.series_count(), 2);
    }

    #[test]
    fn disabled_registry_skips_samplers_but_not_gauges() {
        let reg = Registry::new();
        let c = reg.counter("c_total", &[]);
        let h = reg.histogram("h_ms", &[]);
        let g = reg.gauge("g", &[]);
        reg.set_enabled(false);
        c.inc();
        h.record_ms(1.0);
        g.add(2.0);
        assert_eq!(c.get(), 0);
        assert_eq!(h.count(), 0);
        assert_eq!(g.get(), 2.0, "gauges track live state regardless");
        reg.set_enabled(true);
        c.inc();
        h.record_ms(1.0);
        assert_eq!(c.get(), 1);
        assert_eq!(h.count(), 1);
    }

    #[test]
    fn render_emits_parseable_lines_with_labels() {
        let reg = Registry::new();
        reg.counter("req_total", &[("plan", "int8"), ("k_w", "4")]).add(7);
        reg.gauge("depth", &[]).set(3.5);
        reg.histogram("lat_ms", &[("layer", "fc1")]).record_ms(2.0);
        let text = reg.render_prometheus();
        let mut names = vec![];
        for line in text.lines() {
            let (name, _) = parse_line(line)
                .unwrap_or_else(|| panic!("unparseable exposition line: {line:?}"));
            names.push(name);
        }
        assert!(text.contains("req_total{plan=\"int8\",k_w=\"4\"} 7"), "{text}");
        assert!(text.contains("depth 3.5"), "{text}");
        assert!(text.contains("lat_ms_count{layer=\"fc1\"} 1"), "{text}");
        assert!(
            text.contains("lat_ms{layer=\"fc1\",quantile=\"0.5\"}"),
            "{text}"
        );
        assert!(names.contains(&"lat_ms_max".to_string()));
    }

    #[test]
    fn label_values_are_escaped() {
        let s = format_labels(&[("k", "a\"b\\c")]);
        assert_eq!(s, "{k=\"a\\\"b\\\\c\"}");
    }

    #[test]
    fn type_mismatch_hands_back_a_detached_handle() {
        let reg = Registry::new();
        reg.counter("x", &[]).inc();
        let g = reg.gauge("x", &[]); // wrong type for an existing name
        g.set(9.0);
        assert_eq!(reg.series_count(), 1);
        assert!(
            !reg.render_prometheus().contains('9'),
            "detached handle must not render"
        );
    }

    #[test]
    fn trace_ring_wraps_keeping_newest() {
        let ring = TraceRing::new(4);
        let mk = |i: u64| RequestTrace {
            id: i,
            enqueue_us: i,
            batch_us: i + 1,
            compute_done_us: i + 2,
            reply_us: i + 3,
            rows: 1,
            ok: true,
        };
        for i in 0..6 {
            ring.push(mk(i));
        }
        let got = ring.snapshot();
        assert_eq!(got.len(), 4);
        assert_eq!(got.iter().map(|t| t.id).collect::<Vec<_>>(), vec![2, 3, 4, 5]);
        assert_eq!(ring.total(), 6);
    }

    #[test]
    fn trace_timestamps_are_relative_to_the_epoch() {
        let ring = TraceRing::new(2);
        let before = ring.us_since_epoch(Instant::now());
        std::thread::sleep(std::time::Duration::from_millis(2));
        let after = ring.us_since_epoch(Instant::now());
        assert!(after > before);
    }
}

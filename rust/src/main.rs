//! `adaqat` CLI — the leader entrypoint.
//!
//! Subcommands:
//!   train       run one experiment from flags / --config file
//!   eval        evaluate a checkpoint on the test split
//!   pretrain    produce an fp32 checkpoint for the fine-tuning scenario
//!   inspect     print manifest + cost-model facts for a model
//!   export      pack a training checkpoint into the AQQCKPT1 serving format
//!   serve       run the quantized-inference TCP service (DESIGN.md §7)
//!   client      demo load generator against a running server
//!   demo-model  build the offline nearest-centroid demo checkpoint
//!
//! Examples:
//!   adaqat train --model resnet20 --controller adaqat --lambda 0.15 \
//!                --epochs 4 --out_dir runs/demo
//!   adaqat export --checkpoint runs/demo/final.ckpt --out runs/demo/packed.aqq
//!   adaqat serve --checkpoint runs/demo/packed.aqq --addr 127.0.0.1:7878
//!   adaqat client --addr 127.0.0.1:7878 --n 1000 --window 64

use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Duration;

use adaqat::adaqat::FixedController;
use adaqat::config::{ExperimentConfig, ServeConfig};
use adaqat::coordinator::{self, Experiment};
use adaqat::data::DatasetKind;
use adaqat::quant::CostModel;
use adaqat::runtime::{ModelRuntime, StepBackend};
use adaqat::serve::{
    demo, Backend, Engine, EngineConfig, QuantizedCheckpoint, ReferenceBackend,
    RuntimeBackend, Server,
};
use adaqat::tensor::checkpoint::Checkpoint;
use adaqat::util::cli::Args;

const TRAIN_FLAGS: &[&str] = &[
    "model", "dataset", "fp32", "backend", "hidden", "channels", "blocks", "batch", "image_hw",
    "epochs", "train_size", "test_size", "lr",
    "lambda", "eta_w", "eta_a", "init_nw", "init_na", "probe_interval",
    "osc_threshold", "seed", "out_dir", "checkpoint", "controller",
    "hard_cost", "config", "help",
];

const EXPORT_FLAGS: &[&str] = &["checkpoint", "out", "bits", "help"];

const SERVE_FLAGS: &[&str] = &[
    "checkpoint", "addr", "workers", "queue_capacity", "max_delay_ms",
    "default_deadline_ms", "max_wait_ms", "backend", "model", "threads",
    "metrics_out", "help",
];

const CLIENT_FLAGS: &[&str] =
    &["addr", "n", "window", "retries", "deadline_ms", "dataset", "seed", "help"];

const DEMO_MODEL_FLAGS: &[&str] =
    &["out", "dataset", "samples", "seed", "serve_batch", "hidden", "k_a", "help"];

fn main() {
    adaqat::util::logger::init();
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> anyhow::Result<()> {
    let args = Args::from_env().map_err(|e| anyhow::anyhow!(e))?;
    let cmd = args.positional.first().map(String::as_str).unwrap_or("help");
    if args.has("help") || cmd == "help" {
        print_help();
        return Ok(());
    }
    let known = match cmd {
        "train" | "eval" | "pretrain" | "inspect" => TRAIN_FLAGS,
        "export" => EXPORT_FLAGS,
        "serve" => SERVE_FLAGS,
        "client" => CLIENT_FLAGS,
        "demo-model" => DEMO_MODEL_FLAGS,
        other => anyhow::bail!("unknown command {other:?} (try `adaqat help`)"),
    };
    args.reject_unknown(known).map_err(|e| anyhow::anyhow!(e))?;
    match cmd {
        "train" => cmd_train(&args),
        "eval" => cmd_eval(&args),
        "pretrain" => cmd_pretrain(&args),
        "inspect" => cmd_inspect(&args),
        "export" => cmd_export(&args),
        "serve" => cmd_serve(&args),
        "client" => cmd_client(&args),
        "demo-model" => cmd_demo_model(&args),
        _ => unreachable!("matched above"),
    }
}

fn config_from(args: &Args) -> anyhow::Result<ExperimentConfig> {
    let model = args.get_str("model", "resnet20");
    let mut cfg = ExperimentConfig::default_for(&model);
    if args.has("config") {
        cfg.apply_file(Path::new(&args.get_str("config", "")))
            .map_err(|e| anyhow::anyhow!(e))?;
    }
    cfg.apply_args(args).map_err(|e| anyhow::anyhow!(e))?;
    // A native run with no model chosen anywhere (no --model flag, no
    // `model =` line in the config file — i.e. cfg.model still holds
    // the flag default) must not stamp checkpoints with the default
    // PJRT key: on an artifact-bearing box, export would then resolve
    // that model's manifest roles, match none of the fc1.w/… names,
    // and silently pack every tensor raw.
    if cfg.backend == "native" && !args.has("model") && cfg.model == model {
        cfg.model = adaqat::backprop::NATIVE_MODEL_KEY.to_string();
    }
    // The native conv trainers are addressed by their familiar names
    // (`--backend native --model smallcnn` / `--model resnet20`) but
    // their checkpoints carry the native keys, for the same
    // artifact-box reason as above.
    if cfg.backend == "native" && cfg.model == "smallcnn" {
        cfg.model = adaqat::backprop::NATIVE_SMALLCNN_KEY.to_string();
    }
    if cfg.backend == "native" && cfg.model == "resnet20" {
        cfg.model = adaqat::backprop::NATIVE_RESNET_KEY.to_string();
    }
    cfg.validate().map_err(|e| anyhow::anyhow!(e))?;
    Ok(cfg)
}

/// The step backend a config asks for. The PJRT variant owns its
/// `ModelRuntime` (which holds the client handle); the native variant
/// is whichever trainer the model key selects (MLP, conv, or resnet) behind
/// `backprop::build_native`. Both expose `&dyn StepBackend` for the
/// shared train/eval code paths.
enum BackendHolder {
    Native(Box<dyn StepBackend>),
    Pjrt(ModelRuntime),
}

impl BackendHolder {
    fn build(cfg: &ExperimentConfig) -> anyhow::Result<BackendHolder> {
        if cfg.backend == "native" {
            Ok(BackendHolder::Native(adaqat::backprop::build_native(cfg)?))
        } else {
            let rt = coordinator::default_runtime()?;
            Ok(BackendHolder::Pjrt(rt.load_model(&cfg.model)?))
        }
    }

    fn step(&self) -> &dyn StepBackend {
        match self {
            BackendHolder::Native(b) => b.as_ref(),
            BackendHolder::Pjrt(rt) => rt,
        }
    }
}

fn cmd_train(args: &Args) -> anyhow::Result<()> {
    let cfg = config_from(args)?;
    let holder = BackendHolder::build(&cfg)?;
    let exp = Experiment::new(holder.step(), cfg)?;
    let result = exp.run()?;
    let (k_w, k_a) = result.final_bits;
    println!("final bits:   {k_w}/{k_a}");
    println!("test top-1:   {:.2}%", result.test_top1 * 100.0);
    println!("WCR:          {:.1}x", result.wcr);
    println!("BitOPs:       {:.2} Gb", result.bitops_g);
    println!(
        "wall:         {:.1}s ({} steps, {:.0} ms/step)",
        result.wall_seconds,
        result.steps,
        result.step_seconds * 1e3
    );
    Ok(())
}

fn cmd_eval(args: &Args) -> anyhow::Result<()> {
    let cfg = config_from(args)?;
    anyhow::ensure!(args.has("checkpoint"), "eval requires --checkpoint");
    let ck_path = PathBuf::from(args.get_str("checkpoint", ""));
    let holder = BackendHolder::build(&cfg)?;
    let ck = Checkpoint::load(&ck_path)?;
    let k_w = ck.meta.get("k_w").and_then(|j| j.as_f64()).unwrap_or(32.0) as u32;
    let k_a = ck.meta.get("k_a").and_then(|j| j.as_f64()).unwrap_or(32.0) as u32;
    let state = holder.step().load_state(&ck, cfg.seed)?;
    let exp = Experiment::new(holder.step(), cfg)?;
    let controller = FixedController::new(k_w, k_a);
    let (loss, acc) = adaqat::train::evaluate(
        holder.step(),
        &state,
        &exp.test_loader,
        &controller,
        exp.cfg.fp32,
    )?;
    println!("checkpoint:  {ck_path:?} (bits {k_w}/{k_a})");
    println!("test loss:   {loss:.4}");
    println!("test top-1:  {:.2}%", acc * 100.0);
    Ok(())
}

fn cmd_pretrain(args: &Args) -> anyhow::Result<()> {
    let cfg = config_from(args)?;
    let holder = BackendHolder::build(&cfg)?;
    let path = coordinator::ensure_fp32_pretrain(
        holder.step(),
        &cfg,
        cfg.epochs,
        Path::new("runs/pretrained"),
    )?;
    println!("fp32 checkpoint: {}", path.display());
    Ok(())
}

fn cmd_inspect(args: &Args) -> anyhow::Result<()> {
    let cfg = config_from(args)?;
    let rt = coordinator::default_runtime()?;
    let mm = rt.manifest.model(&cfg.model)?;
    let cost = CostModel::from_manifest(mm);
    println!("model:        {}", mm.key);
    println!("batch:        {}", mm.batch);
    println!(
        "input:        {}x{}x{} -> {} classes",
        mm.input_hw.0, mm.input_hw.1, mm.in_channels, mm.num_classes
    );
    println!("params:       {} tensors, {} scalars", mm.params.len(), mm.param_count());
    println!("weights:      {} scalars", mm.weight_count());
    println!("bn tensors:   {}", mm.bn.len());
    println!("layers:       {}", mm.geoms.len());
    println!("total MACs:   {:.1}M", cost.total_macs() as f64 / 1e6);
    println!("artifacts:    {:?}", mm.artifacts.keys().collect::<Vec<_>>());
    println!();
    println!("cost model (paper §III-B):");
    for (k_w, k_a) in [(32, 32), (8, 8), (4, 4), (3, 4), (3, 8), (2, 32)] {
        println!(
            "  W{k_w:>2}/A{k_a:>2}:  BitOPs {:7.2} Gb   WCR {:5.1}x",
            cost.bitops_g(k_w, k_a),
            cost.wcr(k_w)
        );
    }
    Ok(())
}

// ------------------------------------------------------------- serving

fn cmd_export(args: &Args) -> anyhow::Result<()> {
    anyhow::ensure!(args.has("checkpoint"), "export requires --checkpoint");
    let ck_path = PathBuf::from(args.get_str("checkpoint", ""));
    let ck = Checkpoint::load(&ck_path)?;
    let bits = if args.has("bits") {
        // explicit value, even an invalid one like 0, must be validated
        // downstream rather than silently replaced by the default
        args.get::<u32>("bits", 8).map_err(|e| anyhow::anyhow!(e))?
    } else {
        // meta k_w outside the packable range (e.g. a 32-bit baseline
        // run) falls back to 8-bit packing rather than hard-failing the
        // documented no-flag flow
        match ck.meta.get("k_w").and_then(|j| j.as_f64()).map(|k| k as u32) {
            Some(k) if (1..=24).contains(&k) => k,
            Some(k) => {
                log::info!(
                    "meta k_w = {k} is not packable; defaulting to 8 (pass --bits to override)"
                );
                8
            }
            None => 8,
        }
    };
    let out = PathBuf::from(args.get_str(
        "out",
        &format!("{}.aqq", ck_path.with_extension("").display()),
    ));
    let (q, report) = coordinator::export_packed(&ck, bits)?;
    q.save(&out)?;
    let fp32_file = std::fs::metadata(&ck_path)?.len();
    let packed_file = std::fs::metadata(&out)?.len();
    println!("packed:      {}", out.display());
    println!(
        "tensors:     {} quantized at {} bits, {} raw f32",
        report.quantized_tensors, report.k_w, report.raw_tensors
    );
    println!(
        "size:        {packed_file} bytes vs {fp32_file} fp32 ({:.1}% / {:.1}x smaller)",
        100.0 * packed_file as f64 / fp32_file as f64,
        fp32_file as f64 / packed_file as f64
    );
    if let Some(cost) = q.meta.get("cost") {
        println!("cost model:  {}", cost.to_string());
    }
    Ok(())
}

fn engine_from(scfg: &ServeConfig) -> anyhow::Result<Arc<Engine>> {
    let packed = Arc::new(QuantizedCheckpoint::load(&scfg.checkpoint)?);
    let nonzero_ms = |ms: u64| (ms > 0).then(|| Duration::from_millis(ms));
    let cfg = EngineConfig {
        workers: scfg.workers,
        queue_capacity: scfg.queue_capacity,
        max_delay: Duration::from_millis(scfg.max_delay_ms),
        default_deadline: nonzero_ms(scfg.default_deadline_ms),
        max_wait: nonzero_ms(scfg.max_wait_ms),
    };
    let threads = scfg.threads;
    match scfg.backend.as_str() {
        "reference" => Engine::start(cfg, move |_| {
            Ok(Box::new(ReferenceBackend::with_threads(&packed, threads)?)
                as Box<dyn Backend>)
        }),
        "runtime" => {
            let dir = coordinator::artifact_dir();
            let model = scfg.model.clone();
            Engine::start(cfg, move |_| {
                Ok(Box::new(RuntimeBackend::new(&dir, &model, &packed)?)
                    as Box<dyn Backend>)
            })
        }
        other => anyhow::bail!("unknown backend {other:?}"),
    }
}

fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    let mut scfg = ServeConfig::default();
    scfg.apply_args(args).map_err(|e| anyhow::anyhow!(e))?;
    scfg.validate().map_err(|e| anyhow::anyhow!(e))?;
    // graceful drain (DESIGN.md §19): SIGINT/SIGTERM latch a flag the
    // serve loop polls, same path as the wire-level {"cmd":"drain"}
    adaqat::util::signal::install();
    let engine = engine_from(&scfg)?;
    let server = Server::start(&scfg.addr, Arc::clone(&engine))?;
    // the GEMM pool only exists on the reference backend (the PJRT
    // backend's compute lives in the compiled graph)
    let gemm_note = if scfg.backend == "reference" {
        format!(
            ", {} gemm thread(s)/worker",
            adaqat::kernels::resolve_threads(scfg.threads)
        )
    } else {
        String::new()
    };
    println!(
        "serving {} on {} ({} workers, batch {}, window {} ms{gemm_note})",
        scfg.checkpoint.display(),
        server.addr,
        scfg.workers,
        engine.batch(),
        scfg.max_delay_ms
    );
    let dump_metrics = |engine: &Engine| {
        if let Some(path) = &scfg.metrics_out {
            if let Err(e) = std::fs::write(path, engine.prometheus()) {
                log::warn!("metrics_out: cannot write {}: {e}", path.display());
            }
        }
    };
    if let Some(path) = &scfg.metrics_out {
        println!("metrics exposition -> {}", path.display());
    }
    // write once at startup so scrapers see the file immediately
    dump_metrics(&engine);
    // Foreground service: report latency stats until a signal or a
    // wire-level {"cmd":"drain"} asks for a graceful exit. A short
    // poll tick bounds drain latency; stats/exposition refresh on a
    // coarser multiple of it.
    const TICK: Duration = Duration::from_millis(200);
    const STATS_EVERY: u32 = 50; // ≈ every 10 s
    let mut ticks = 0u32;
    loop {
        std::thread::sleep(TICK);
        if server.drain_requested() || adaqat::util::signal::requested() {
            break;
        }
        ticks += 1;
        if ticks % STATS_EVERY == 0 {
            dump_metrics(&engine);
            if engine.metrics.requests.load(std::sync::atomic::Ordering::Relaxed) > 0 {
                log::info!("\n{}", engine.metrics.report());
            }
        }
    }
    // Drain: stop accepting, finish what was admitted (in-queue work
    // still races its deadlines), flush the exposition, exit cleanly.
    println!("draining: listener closed, finishing in-flight requests…");
    server.stop();
    engine.shutdown();
    dump_metrics(&engine);
    if engine.metrics.requests.load(std::sync::atomic::Ordering::Relaxed) > 0 {
        println!("{}", engine.metrics.report());
    }
    println!("drained: bye");
    Ok(())
}

fn cmd_client(args: &Args) -> anyhow::Result<()> {
    let addr = args.get_str("addr", "127.0.0.1:7878");
    let n: usize = args.get("n", 1000).map_err(|e| anyhow::anyhow!(e))?;
    let window: usize = args.get("window", 64).map_err(|e| anyhow::anyhow!(e))?;
    let seed: u64 = args.get("seed", 0).map_err(|e| anyhow::anyhow!(e))?;
    let retries: u32 = args.get("retries", 4).map_err(|e| anyhow::anyhow!(e))?;
    let deadline_ms: u64 = args.get("deadline_ms", 0).map_err(|e| anyhow::anyhow!(e))?;
    let kind = DatasetKind::parse(&args.get_str("dataset", "cifar10"))
        .map_err(|e| anyhow::anyhow!(e))?;
    let ds = adaqat::data::synth::generate(kind, n, seed, 1);
    let images: Vec<(Vec<f32>, i32)> =
        (0..n).map(|i| (ds.image(i).to_vec(), ds.labels[i])).collect();
    println!("sending {n} requests to {addr} (window {window})…");
    let cfg = adaqat::serve::client::ClientConfig {
        window,
        max_retries: retries,
        deadline_ms: (deadline_ms > 0).then_some(deadline_ms),
        seed,
    };
    let report = adaqat::serve::client::run_with(&addr, &images, &cfg)?;
    println!("received:    {}/{} ({} errors)", report.received, report.sent, report.errors);
    println!(
        "attempted:   {} wire sends ({} retried, {} shed after {} attempts)",
        report.attempted,
        report.retried,
        report.shed,
        retries + 1
    );
    println!(
        "accuracy:    {:.1}% ({} correct)",
        100.0 * report.correct as f64 / report.received.max(1) as f64,
        report.correct
    );
    println!(
        "throughput:  {:.0} req/s over {:.2}s",
        report.requests_per_second(),
        report.wall_seconds
    );
    println!("{}", report.latency.row("latency"));
    Ok(())
}

fn cmd_demo_model(args: &Args) -> anyhow::Result<()> {
    let out = PathBuf::from(args.get_str("out", "runs/demo/model.ckpt"));
    let kind = DatasetKind::parse(&args.get_str("dataset", "cifar10"))
        .map_err(|e| anyhow::anyhow!(e))?;
    let samples: usize = args.get("samples", 64).map_err(|e| anyhow::anyhow!(e))?;
    let seed: u64 = args.get("seed", 0).map_err(|e| anyhow::anyhow!(e))?;
    let serve_batch: usize = args.get("serve_batch", 64).map_err(|e| anyhow::anyhow!(e))?;
    // --hidden N builds the 2-layer ReLU MLP (kernels demo); 0 = linear
    let hidden: usize = args.get("hidden", 0).map_err(|e| anyhow::anyhow!(e))?;
    let k_a: u32 = args.get("k_a", 8).map_err(|e| anyhow::anyhow!(e))?;
    let ck = if hidden > 0 {
        // validate here so flag mistakes are CLI errors, not panics
        anyhow::ensure!(
            hidden % 2 == 0 && hidden >= 2 * kind.num_classes(),
            "--hidden must be even and >= {} (2x num_classes), got {hidden}",
            2 * kind.num_classes()
        );
        anyhow::ensure!(
            (1..=24).contains(&k_a),
            "--k_a must be in 1..=24, got {k_a}"
        );
        demo::demo_mlp_checkpoint(kind, hidden, samples, seed, serve_batch, k_a)
    } else {
        demo::demo_checkpoint(kind, samples, seed, serve_batch)
    };
    ck.save(&out)?;
    // quick self-check on a fresh test split (fp32, pre-packing)
    let (q, _) = coordinator::export_packed(&ck, 8)?;
    let backend = ReferenceBackend::from_packed(&q)?;
    let acc = demo::demo_accuracy(&backend, kind, 512, seed ^ 1);
    println!("demo model:  {}", out.display());
    println!("classes:     {}", q.meta.get("num_classes").and_then(|j| j.as_f64()).unwrap_or(0.0));
    println!(
        "test top-1:  {:.1}% ({}, fresh split)",
        acc * 100.0,
        if hidden > 0 { "2-layer ReLU MLP" } else { "nearest-centroid" }
    );
    println!("next:        adaqat export --checkpoint {} --bits 4", out.display());
    Ok(())
}

fn print_help() {
    println!(
        "adaqat — AdaQAT: Adaptive Bit-Width Quantization-Aware Training

USAGE: adaqat <train|eval|pretrain|inspect|export|serve|client|demo-model> [--flags]

COMMANDS
  train       run one experiment (controller: adaqat | fixed:W:A | fracbits:W:A)
  eval        evaluate --checkpoint on the test split
  pretrain    produce an fp32 checkpoint (fine-tuning scenario)
  inspect     print manifest + cost model for --model
  export      pack --checkpoint into the AQQCKPT1 serving format
  serve       serve a packed checkpoint over TCP/NDJSON (DESIGN.md §7)
  client      demo load generator against a running `adaqat serve`
  demo-model  build the offline nearest-centroid demo checkpoint

TRAIN/EVAL FLAGS
  --model NAME          smallcnn | resnet20 | resnet18 | smallcnn_pallas
  --backend B           pjrt (compiled artifacts) | native (pure-Rust
                        trainers, run offline)                [pjrt]
                        native models: the MLP (default), smallcnn
                        (conv+BN blocks) and resnet20 (residual
                        blocks with integer skip joins, DESIGN.md §18)
  --hidden W[,W...]     native MLP hidden widths              [64]
  --channels C[,C...]   native conv widths: one per smallcnn
                        conv-BN-ReLU-pool block, or one per
                        resnet20 stage                        [8,16]
  --blocks N            native resnet20 residual blocks per
                        stage (paper: --channels 16,32,64
                        --blocks 3)                           [2]
  --batch N             native batch size                     [32]
  --image_hw N          synthetic image side (native; pjrt=32) [32]
  --config FILE         key = value config file (flags override it)
  --controller SPEC     adaqat | fixed:2:32 | fracbits:3:4   [adaqat]
  --lambda F            hardware-loss balance λ              [0.15]
  --epochs N            training epochs                      [4]
  --lr F                initial LR (cosine annealed)         [0.1]
  --eta_w F / --eta_a F bit-width learning rates             [0.001/0.0005]
  --init_nw F / --init_na F  initial fractional bit-widths   [8/8]
  --checkpoint FILE     fine-tune from / evaluate this checkpoint
  --fp32 BOOL           run the fp32 baseline graph          [false]
  --train_size/--test_size N  synthetic split sizes
  --probe_interval N    steps between bit-width probes       [1]
  --osc_threshold N     oscillations before freezing         [10]
  --hard_cost M         L_hard model: product | memory | fpga-dsp | energy
  --seed N / --out_dir DIR

SERVING FLAGS
  export:     --checkpoint FILE [--out FILE.aqq] [--bits N (default: meta k_w)]
  serve:      --checkpoint FILE.aqq [--addr HOST:PORT] [--workers N]
              [--queue_capacity N] [--max_delay_ms N]
              [--default_deadline_ms N (deadline for requests without
               one; 0 = no implicit deadline)]
              [--max_wait_ms N (admission control: reject `overloaded`
               + retry_after_ms past this queue-wait estimate;
               0 disarms, default 500)]
              [--backend reference|runtime] [--model NAME]
              [--threads N (GEMM threads per backend; 0 = per core)]
              [--metrics_out FILE (rewrite Prometheus exposition
               every 10s; also served via the metrics command)]
              SIGINT/SIGTERM or a {{\"cmd\":\"drain\"}} line drain
              gracefully: finish in-flight work, flush metrics, exit 0
  client:     [--addr HOST:PORT] [--n N] [--window N] [--dataset D] [--seed N]
              [--retries N (per-request budget for `overloaded`
               replies, jittered exponential backoff honoring
               retry_after_ms; default 4)]
              [--deadline_ms N (attach this budget to every request;
               0 = none)]
  demo-model: [--out FILE] [--dataset D] [--samples PER_CLASS]
              [--serve_batch N] [--seed N]
              [--hidden N (0 = linear; even N builds the 2-layer ReLU MLP)]
              [--k_a N (MLP activation bits, default 8)]

Serving quickstart (no PJRT artifacts needed):
  adaqat demo-model --hidden 256 && adaqat export --checkpoint runs/demo/model.ckpt --bits 4
  adaqat serve --checkpoint runs/demo/model.aqq &
  adaqat client --n 1000 --window 64

Offline train→export→serve (no PJRT artifacts needed):
  adaqat train --backend native --hidden 64 --epochs 4 --out_dir runs/native
  adaqat export --checkpoint runs/native/final.ckpt
  adaqat serve --checkpoint runs/native/final.aqq
Same loop on the conv model (im2col conv + BN, integer conv serving):
  adaqat train --backend native --model smallcnn --channels 8,16 \
               --epochs 4 --out_dir runs/cnn
Same loop on the paper's architecture (residual blocks, integer skip
joins — docs/HANDBOOK.md is the full operator walkthrough):
  adaqat train --backend native --model resnet20 --channels 8,16 \
               --blocks 2 --epochs 4 --out_dir runs/resnet

Artifacts are loaded from $ADAQAT_ARTIFACTS (default ./artifacts);
build them with `make artifacts`."
    );
}

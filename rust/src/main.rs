//! `adaqat` CLI — the leader entrypoint.
//!
//! Subcommands:
//!   train      run one experiment from flags / --config file
//!   eval       evaluate a checkpoint on the test split
//!   pretrain   produce an fp32 checkpoint for the fine-tuning scenario
//!   inspect    print manifest + cost-model facts for a model
//!
//! Examples:
//!   adaqat train --model resnet20 --controller adaqat --lambda 0.15 \
//!                --epochs 4 --out_dir runs/demo
//!   adaqat pretrain --model resnet20 --epochs 3
//!   adaqat eval --model resnet20 --checkpoint runs/demo/final.ckpt

use std::path::{Path, PathBuf};

use adaqat::adaqat::FixedController;
use adaqat::config::ExperimentConfig;
use adaqat::coordinator::{self, Experiment};
use adaqat::quant::CostModel;
use adaqat::tensor::checkpoint::Checkpoint;
use adaqat::util::cli::Args;

const KNOWN_FLAGS: &[&str] = &[
    "model", "dataset", "fp32", "epochs", "train_size", "test_size", "lr",
    "lambda", "eta_w", "eta_a", "init_nw", "init_na", "probe_interval",
    "osc_threshold", "seed", "out_dir", "checkpoint", "controller",
    "hard_cost", "config", "help",
];

fn main() {
    adaqat::util::logger::init();
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> anyhow::Result<()> {
    let args = Args::from_env().map_err(|e| anyhow::anyhow!(e))?;
    let cmd = args.positional.first().map(String::as_str).unwrap_or("help");
    if args.has("help") || cmd == "help" {
        print_help();
        return Ok(());
    }
    args.reject_unknown(KNOWN_FLAGS).map_err(|e| anyhow::anyhow!(e))?;
    match cmd {
        "train" => cmd_train(&args),
        "eval" => cmd_eval(&args),
        "pretrain" => cmd_pretrain(&args),
        "inspect" => cmd_inspect(&args),
        other => anyhow::bail!("unknown command {other:?} (try `adaqat help`)"),
    }
}

fn config_from(args: &Args) -> anyhow::Result<ExperimentConfig> {
    let model = args.get_str("model", "resnet20");
    let mut cfg = ExperimentConfig::default_for(&model);
    if args.has("config") {
        cfg.apply_file(Path::new(&args.get_str("config", "")))
            .map_err(|e| anyhow::anyhow!(e))?;
    }
    cfg.apply_args(args).map_err(|e| anyhow::anyhow!(e))?;
    cfg.validate().map_err(|e| anyhow::anyhow!(e))?;
    Ok(cfg)
}

fn cmd_train(args: &Args) -> anyhow::Result<()> {
    let cfg = config_from(args)?;
    let rt = coordinator::default_runtime()?;
    let model_rt = rt.load_model(&cfg.model)?;
    let exp = Experiment::new(&model_rt, cfg)?;
    let result = exp.run()?;
    let (k_w, k_a) = result.final_bits;
    println!("final bits:   {k_w}/{k_a}");
    println!("test top-1:   {:.2}%", result.test_top1 * 100.0);
    println!("WCR:          {:.1}x", result.wcr);
    println!("BitOPs:       {:.2} Gb", result.bitops_g);
    println!(
        "wall:         {:.1}s ({} steps, {:.0} ms/step)",
        result.wall_seconds,
        result.steps,
        result.step_seconds * 1e3
    );
    Ok(())
}

fn cmd_eval(args: &Args) -> anyhow::Result<()> {
    let cfg = config_from(args)?;
    anyhow::ensure!(args.has("checkpoint"), "eval requires --checkpoint");
    let ck_path = PathBuf::from(args.get_str("checkpoint", ""));
    let rt = coordinator::default_runtime()?;
    let model_rt = rt.load_model(&cfg.model)?;
    let ck = Checkpoint::load(&ck_path)?;
    let k_w = ck.meta.get("k_w").and_then(|j| j.as_f64()).unwrap_or(32.0) as u32;
    let k_a = ck.meta.get("k_a").and_then(|j| j.as_f64()).unwrap_or(32.0) as u32;
    let state = model_rt.load_state(&ck, cfg.seed)?;
    let exp = Experiment::new(&model_rt, cfg)?;
    let controller = FixedController::new(k_w, k_a);
    let (loss, acc) = adaqat::train::evaluate(
        &model_rt,
        &state,
        &exp.test_loader,
        &controller,
        exp.cfg.fp32,
    )?;
    println!("checkpoint:  {ck_path:?} (bits {k_w}/{k_a})");
    println!("test loss:   {loss:.4}");
    println!("test top-1:  {:.2}%", acc * 100.0);
    Ok(())
}

fn cmd_pretrain(args: &Args) -> anyhow::Result<()> {
    let cfg = config_from(args)?;
    let rt = coordinator::default_runtime()?;
    let model_rt = rt.load_model(&cfg.model)?;
    let path = coordinator::ensure_fp32_pretrain(
        &model_rt,
        &cfg,
        cfg.epochs,
        Path::new("runs/pretrained"),
    )?;
    println!("fp32 checkpoint: {}", path.display());
    Ok(())
}

fn cmd_inspect(args: &Args) -> anyhow::Result<()> {
    let cfg = config_from(args)?;
    let rt = coordinator::default_runtime()?;
    let mm = rt.manifest.model(&cfg.model)?;
    let cost = CostModel::from_manifest(mm);
    println!("model:        {}", mm.key);
    println!("batch:        {}", mm.batch);
    println!(
        "input:        {}x{}x{} -> {} classes",
        mm.input_hw.0, mm.input_hw.1, mm.in_channels, mm.num_classes
    );
    println!("params:       {} tensors, {} scalars", mm.params.len(), mm.param_count());
    println!("weights:      {} scalars", mm.weight_count());
    println!("bn tensors:   {}", mm.bn.len());
    println!("layers:       {}", mm.geoms.len());
    println!("total MACs:   {:.1}M", cost.total_macs() as f64 / 1e6);
    println!("artifacts:    {:?}", mm.artifacts.keys().collect::<Vec<_>>());
    println!();
    println!("cost model (paper §III-B):");
    for (k_w, k_a) in [(32, 32), (8, 8), (4, 4), (3, 4), (3, 8), (2, 32)] {
        println!(
            "  W{k_w:>2}/A{k_a:>2}:  BitOPs {:7.2} Gb   WCR {:5.1}x",
            cost.bitops_g(k_w, k_a),
            cost.wcr(k_w)
        );
    }
    Ok(())
}

fn print_help() {
    println!(
        "adaqat — AdaQAT: Adaptive Bit-Width Quantization-Aware Training

USAGE: adaqat <train|eval|pretrain|inspect> [--flags]

COMMANDS
  train     run one experiment (controller: adaqat | fixed:W:A | fracbits:W:A)
  eval      evaluate --checkpoint on the test split
  pretrain  produce an fp32 checkpoint (fine-tuning scenario)
  inspect   print manifest + cost model for --model

COMMON FLAGS
  --model NAME          smallcnn | resnet20 | resnet18 | smallcnn_pallas
  --config FILE         key = value config file (flags override it)
  --controller SPEC     adaqat | fixed:2:32 | fracbits:3:4   [adaqat]
  --lambda F            hardware-loss balance λ              [0.15]
  --epochs N            training epochs                      [4]
  --lr F                initial LR (cosine annealed)         [0.1]
  --eta_w F / --eta_a F bit-width learning rates             [0.001/0.0005]
  --init_nw F / --init_na F  initial fractional bit-widths   [8/8]
  --checkpoint FILE     fine-tune from / evaluate this checkpoint
  --fp32 BOOL           run the fp32 baseline graph          [false]
  --train_size/--test_size N  synthetic split sizes
  --probe_interval N    steps between bit-width probes       [1]
  --osc_threshold N     oscillations before freezing         [10]
  --hard_cost M         L_hard model: product | memory | fpga-dsp | energy
  --seed N / --out_dir DIR

Artifacts are loaded from $ADAQAT_ARTIFACTS (default ./artifacts);
build them with `make artifacts`."
    );
}

//! Unsafe-policy lint (DESIGN.md §17).
//!
//! Scans the Rust source tree and enforces the repo's unsafe contract:
//!
//! - every `unsafe` block carries a `// SAFETY:` justification in the
//!   contiguous comment block directly above it;
//! - every `unsafe fn` documents its caller contract (a `# Safety` doc
//!   section or a `// SAFETY:` comment);
//! - every `unsafe impl Send`/`Sync` carries an `// AUDIT:` tag naming
//!   the invariant that makes the type thread-safe, on top of the
//!   SAFETY justification;
//! - atomic `Ordering::Relaxed` only appears in the allow-listed
//!   counter/gauge modules (`relaxed` lines in the config).
//!
//! String/char-literal contents and comment text are separated before
//! matching, so `"unsafe"` inside a string can't trip the scanner and
//! `// SAFETY` prose can't hide a real violation. The scan is
//! line-based and deliberately conservative: it never needs a full
//! parser because rustfmt (the CI lint step) has already normalised
//! the shapes it matches on.
//!
//! Config: `unsafe_audit.conf` next to the manifest (`scan`, `exempt`,
//! `relaxed` directives; paths relative to the config's directory).
//! Output: a machine-readable JSON report (`--report <path>`, default
//! stdout) plus human-readable violation lines on stderr; exit 1 when
//! any violation is found.

use adaqat::util::json::Json;
use std::path::{Path, PathBuf};
use std::process::ExitCode;

const MSG_BLOCK: &str = "unsafe block without a `// SAFETY:` justification";
const MSG_FN: &str = "unsafe fn without a `# Safety` caller contract";
const MSG_IMPL: &str = "unsafe impl without a `// SAFETY:` justification";
const MSG_AUDIT: &str = "unsafe impl Send/Sync without an `// AUDIT:` invariant tag";
const MSG_RELAXED: &str = "Ordering::Relaxed outside the allow-listed counter modules";

struct Config {
    root: PathBuf,
    scan: Vec<PathBuf>,
    exempt: Vec<PathBuf>,
    relaxed: Vec<PathBuf>,
}

#[derive(Default)]
struct Stats {
    blocks: usize,
    fns: usize,
    impls: usize,
    relaxed: usize,
}

struct Violation {
    file: String,
    line: usize,
    kind: &'static str,
    message: &'static str,
}

fn violation(file: &str, line: usize, kind: &'static str, message: &'static str) -> Violation {
    Violation { file: file.to_string(), line, kind, message }
}

/// One source line split into its code text (string/char-literal
/// contents dropped) and its comment text (line, doc and block).
#[derive(Default)]
struct LineView {
    code: String,
    comment: String,
}

fn is_ident(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

/// Split source into per-line code and comment channels. Handles line
/// and nested block comments, plain/byte strings with escapes, raw
/// strings (`r"…"`, `r#"…"#`, `br"…"`), and char literals (including
/// escaped ones like `'\''` and `'"'`) vs lifetime ticks.
fn split_code_comments(src: &str) -> Vec<LineView> {
    let ch: Vec<char> = src.chars().collect();
    let n = ch.len();
    let mut out: Vec<LineView> = vec![LineView::default()];
    let mut i = 0usize;
    while i < n {
        let c = ch[i];
        if c == '\n' {
            out.push(LineView::default());
            i += 1;
            continue;
        }
        // line comment (also covers `///` and `//!` doc comments)
        if c == '/' && i + 1 < n && ch[i + 1] == '/' {
            while i < n && ch[i] != '\n' {
                out.last_mut().unwrap().comment.push(ch[i]);
                i += 1;
            }
            continue;
        }
        // block comment, nested per Rust's grammar
        if c == '/' && i + 1 < n && ch[i + 1] == '*' {
            let mut depth = 1u32;
            out.last_mut().unwrap().comment.push_str("/*");
            i += 2;
            while i < n && depth > 0 {
                if ch[i] == '\n' {
                    out.push(LineView::default());
                    i += 1;
                } else if ch[i] == '/' && i + 1 < n && ch[i + 1] == '*' {
                    depth += 1;
                    out.last_mut().unwrap().comment.push_str("/*");
                    i += 2;
                } else if ch[i] == '*' && i + 1 < n && ch[i + 1] == '/' {
                    depth -= 1;
                    out.last_mut().unwrap().comment.push_str("*/");
                    i += 2;
                } else {
                    out.last_mut().unwrap().comment.push(ch[i]);
                    i += 1;
                }
            }
            continue;
        }
        // raw string r"…" / r#"…"# (optionally byte-prefixed), only
        // when the `r` does not continue an identifier
        if (c == 'r' || (c == 'b' && i + 1 < n && ch[i + 1] == 'r'))
            && (i == 0 || !is_ident(ch[i - 1]))
        {
            let mut j = if c == 'b' { i + 2 } else { i + 1 };
            let mut hashes = 0usize;
            while j < n && ch[j] == '#' {
                hashes += 1;
                j += 1;
            }
            if j < n && ch[j] == '"' {
                j += 1;
                while j < n {
                    if ch[j] == '\n' {
                        out.push(LineView::default());
                    } else if ch[j] == '"' {
                        let mut k = 0usize;
                        while k < hashes && j + 1 + k < n && ch[j + 1 + k] == '#' {
                            k += 1;
                        }
                        if k == hashes {
                            j += 1 + hashes;
                            break;
                        }
                    }
                    j += 1;
                }
                i = j;
                continue;
            }
        }
        // plain string literal (escapes honoured)
        if c == '"' {
            i += 1;
            while i < n {
                if ch[i] == '\\' {
                    i += 2;
                } else if ch[i] == '\n' {
                    out.push(LineView::default());
                    i += 1;
                } else if ch[i] == '"' {
                    i += 1;
                    break;
                } else {
                    i += 1;
                }
            }
            continue;
        }
        // char literal vs lifetime tick
        if c == '\'' {
            if i + 1 < n && ch[i + 1] == '\\' {
                i += 2;
                while i < n && ch[i] != '\'' {
                    i += 1;
                }
                i += 1;
                continue;
            }
            if i + 2 < n && ch[i + 2] == '\'' && ch[i + 1] != '\'' {
                i += 3;
                continue;
            }
            out.last_mut().unwrap().code.push(c);
            i += 1;
            continue;
        }
        out.last_mut().unwrap().code.push(c);
        i += 1;
    }
    out
}

/// Byte offsets of whole-word occurrences of `word` in `s`.
fn word_positions(s: &str, word: &str) -> Vec<usize> {
    let mut hits = Vec::new();
    let mut from = 0usize;
    while let Some(pos) = s[from..].find(word) {
        let at = from + pos;
        let end = at + word.len();
        let before_ok = at == 0 || !is_ident(s[..at].chars().next_back().unwrap());
        let after_ok = end >= s.len() || !is_ident(s[end..].chars().next().unwrap());
        if before_ok && after_ok {
            hits.push(at);
        }
        from = end;
    }
    hits
}

/// Code text from byte `col` on line `li` joined with the next few
/// lines — enough lookahead to classify what follows `unsafe` even
/// when rustfmt wrapped the signature.
fn joined_tail(lines: &[LineView], li: usize, col: usize) -> String {
    let mut tail = String::new();
    if col < lines[li].code.len() {
        tail.push_str(&lines[li].code[col..]);
    }
    for l in lines.iter().skip(li + 1).take(3) {
        tail.push(' ');
        tail.push_str(&l.code);
    }
    tail
}

/// The first code token after `col` on line `li`: `"{"` for a bare
/// block, otherwise the identifier (`impl`, `fn`, …).
fn next_token(lines: &[LineView], li: usize, col: usize) -> String {
    let tail = joined_tail(lines, li, col);
    let t = tail.trim_start();
    if t.starts_with('{') {
        return "{".to_string();
    }
    t.chars().take_while(|&c| is_ident(c)).collect()
}

/// The comment/attribute block above line `li` (plus any comment on
/// the line itself), concatenated. Attribute lines pass through, and
/// so do statement-continuation code lines (`let x =` left on its own
/// line by rustfmt with the `unsafe { … }` beneath) — the comment
/// above the *statement* documents the block, matching clippy's
/// accept-comment-above-statement semantics. A blank line or a
/// completed statement/block edge (`;`, `{`, `}`) ends the walk.
fn audit_context(lines: &[LineView], li: usize) -> String {
    let mut ctx = lines[li].comment.clone();
    let mut i = li;
    while i > 0 {
        i -= 1;
        let code = lines[i].code.trim();
        let comment = lines[i].comment.trim();
        if code.is_empty() && comment.is_empty() {
            break;
        }
        if !code.is_empty()
            && !code.starts_with('#')
            && (code.ends_with(';') || code.ends_with('{') || code.ends_with('}'))
        {
            break;
        }
        ctx.push('\n');
        ctx.push_str(comment);
    }
    ctx
}

/// Lint one source file's text. `relaxed_ok` marks files on the
/// Relaxed-ordering allow-list.
fn audit_source(
    label: &str,
    src: &str,
    relaxed_ok: bool,
    stats: &mut Stats,
    out: &mut Vec<Violation>,
) {
    let lines = split_code_comments(src);
    for (li, line) in lines.iter().enumerate() {
        for at in word_positions(&line.code, "unsafe") {
            let tok = next_token(&lines, li, at + "unsafe".len());
            let ctx = audit_context(&lines, li);
            let documented = ctx.contains("SAFETY:") || ctx.contains("# Safety");
            match tok.as_str() {
                "impl" => {
                    stats.impls += 1;
                    let tail = joined_tail(&lines, li, at);
                    let marker = tail.contains("Send for") || tail.contains("Sync for");
                    if marker && !ctx.contains("AUDIT") {
                        out.push(violation(label, li + 1, "impl-missing-audit", MSG_AUDIT));
                    }
                    if !documented {
                        out.push(violation(label, li + 1, "impl-missing-safety", MSG_IMPL));
                    }
                }
                "fn" => {
                    stats.fns += 1;
                    if !documented {
                        out.push(violation(label, li + 1, "fn-missing-safety", MSG_FN));
                    }
                }
                _ => {
                    stats.blocks += 1;
                    if !documented {
                        out.push(violation(label, li + 1, "block-missing-safety", MSG_BLOCK));
                    }
                }
            }
        }
        let relaxed_hits = word_positions(&line.code, "Relaxed").len();
        stats.relaxed += relaxed_hits;
        if relaxed_hits > 0 && !relaxed_ok {
            out.push(violation(label, li + 1, "relaxed-not-allowlisted", MSG_RELAXED));
        }
    }
}

fn parse_config(path: &Path) -> std::io::Result<Config> {
    let text = std::fs::read_to_string(path)?;
    let root = path.parent().unwrap_or(Path::new(".")).to_path_buf();
    let mut cfg = Config { root, scan: Vec::new(), exempt: Vec::new(), relaxed: Vec::new() };
    for raw in text.lines() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let mut it = line.split_whitespace();
        let dir = it.next().unwrap_or("");
        let arg = it.next().unwrap_or("");
        match dir {
            "scan" => cfg.scan.push(normalize(arg)),
            "exempt" => cfg.exempt.push(normalize(arg)),
            "relaxed" => cfg.relaxed.push(normalize(arg)),
            other => eprintln!("unsafe_audit: ignoring unknown directive `{other}`"),
        }
    }
    Ok(cfg)
}

/// `.` means the config root itself; everything else stays relative.
fn normalize(arg: &str) -> PathBuf {
    if arg == "." {
        PathBuf::new()
    } else {
        PathBuf::from(arg)
    }
}

/// Collect `.rs` files under `root/rel`, depth-first in name order,
/// skipping exempt subtrees. Paths in `out` stay root-relative.
fn walk(
    root: &Path,
    rel: &Path,
    exempt: &[PathBuf],
    out: &mut Vec<PathBuf>,
) -> std::io::Result<()> {
    let rd = std::fs::read_dir(root.join(rel))?;
    let mut entries: Vec<std::fs::DirEntry> = rd.collect::<Result<_, _>>()?;
    entries.sort_by_key(|e| e.file_name());
    for e in entries {
        let child = rel.join(e.file_name());
        if exempt.iter().any(|x| child.starts_with(x)) {
            continue;
        }
        if e.file_type()?.is_dir() {
            walk(root, &child, exempt, out)?;
        } else if child.extension().and_then(|s| s.to_str()) == Some("rs") {
            out.push(child);
        }
    }
    Ok(())
}

fn main() -> ExitCode {
    let mut config_path = PathBuf::from("unsafe_audit.conf");
    let mut report_path: Option<PathBuf> = None;
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--config" => {
                config_path = PathBuf::from(args.next().expect("--config needs a path"));
            }
            "--report" => {
                report_path = Some(PathBuf::from(args.next().expect("--report needs a path")));
            }
            other => {
                eprintln!("unsafe_audit: unknown argument `{other}`");
                eprintln!("usage: unsafe_audit [--config <conf>] [--report <json>]");
                return ExitCode::from(2);
            }
        }
    }

    let cfg = match parse_config(&config_path) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("unsafe_audit: cannot read {}: {e}", config_path.display());
            return ExitCode::from(2);
        }
    };

    let mut files = Vec::new();
    for s in &cfg.scan {
        if let Err(e) = walk(&cfg.root, s, &cfg.exempt, &mut files) {
            eprintln!("unsafe_audit: cannot walk {}: {e}", cfg.root.join(s).display());
            return ExitCode::from(2);
        }
    }

    let mut stats = Stats::default();
    let mut violations = Vec::new();
    for rel in &files {
        let label = rel.display().to_string();
        let src = match std::fs::read_to_string(cfg.root.join(rel)) {
            Ok(s) => s,
            Err(e) => {
                eprintln!("unsafe_audit: cannot read {label}: {e}");
                return ExitCode::from(2);
            }
        };
        let relaxed_ok = cfg.relaxed.iter().any(|p| p == rel);
        audit_source(&label, &src, relaxed_ok, &mut stats, &mut violations);
    }

    for v in &violations {
        eprintln!("{}:{}: [{}] {}", v.file, v.line, v.kind, v.message);
    }
    eprintln!(
        "unsafe_audit: {} files, {} unsafe blocks, {} unsafe fns, {} unsafe impls, \
         {} Relaxed sites, {} violations",
        files.len(),
        stats.blocks,
        stats.fns,
        stats.impls,
        stats.relaxed,
        violations.len()
    );

    let mut vjson = Vec::new();
    for v in &violations {
        vjson.push(Json::obj(vec![
            ("file", Json::str(v.file.clone())),
            ("line", Json::num(v.line as f64)),
            ("kind", Json::str(v.kind)),
            ("message", Json::str(v.message)),
        ]));
    }
    let report = Json::obj(vec![
        ("files_scanned", Json::num(files.len() as f64)),
        ("unsafe_blocks", Json::num(stats.blocks as f64)),
        ("unsafe_fns", Json::num(stats.fns as f64)),
        ("unsafe_impls", Json::num(stats.impls as f64)),
        ("relaxed_sites", Json::num(stats.relaxed as f64)),
        ("violations", Json::Arr(vjson)),
    ]);
    match &report_path {
        Some(p) => {
            if let Err(e) = std::fs::write(p, report.to_string() + "\n") {
                eprintln!("unsafe_audit: cannot write {}: {e}", p.display());
                return ExitCode::from(2);
            }
        }
        None => println!("{}", report.to_string()),
    }

    if violations.is_empty() {
        ExitCode::SUCCESS
    } else {
        ExitCode::FAILURE
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run(src: &str, relaxed_ok: bool) -> (Stats, Vec<Violation>) {
        let mut stats = Stats::default();
        let mut v = Vec::new();
        audit_source("test.rs", src, relaxed_ok, &mut stats, &mut v);
        (stats, v)
    }

    #[test]
    fn strings_comments_and_lifetimes_do_not_trip_the_scanner() {
        let src = r##"
fn f<'a>(x: &'a str) -> usize {
    let s = "unsafe { Ordering::Relaxed }";
    let r = r#"unsafe impl Send for T {} Relaxed"#;
    let q = '"';
    let t = '\'';
    // prose mentioning unsafe and Relaxed is fine in comments
    /* block comment: unsafe fn nope() — also fine */
    s.len() + r.len() + (q as usize) + (t as usize) + x.len()
}
"##;
        let (stats, v) = run(src, false);
        assert_eq!(stats.blocks + stats.fns + stats.impls, 0);
        assert_eq!(stats.relaxed, 0);
        assert!(v.is_empty());
    }

    #[test]
    fn documented_unsafe_block_passes_undocumented_is_flagged() {
        let good = "
fn f(p: *mut u8) {
    // SAFETY: p is valid for writes, caller contract.
    unsafe { *p = 0 };
}
";
        let (stats, v) = run(good, false);
        assert_eq!(stats.blocks, 1);
        assert!(v.is_empty());

        let bad = "
fn f(p: *mut u8) {
    unsafe { *p = 0 };
}
";
        let (stats, v) = run(bad, false);
        assert_eq!(stats.blocks, 1);
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].kind, "block-missing-safety");
        assert_eq!(v[0].line, 3);
    }

    #[test]
    fn safety_comment_carries_across_attributes() {
        let src = "
fn f(p: *mut u8) {
    // SAFETY: p is valid; the allow silences a style lint only.
    #[allow(clippy::some_lint)]
    let x = unsafe { *p };
    let _ = x;
}
";
        let (stats, v) = run(src, false);
        assert_eq!(stats.blocks, 1);
        assert!(v.is_empty(), "attribute between comment and unsafe must not break the link");
    }

    #[test]
    fn safety_comment_documents_a_wrapped_statement() {
        let src = "
fn f(p: *mut u8) -> u8 {
    // SAFETY: p is valid for reads, caller contract.
    let value =
        unsafe { *p };
    value
}
";
        let (stats, v) = run(src, false);
        assert_eq!(stats.blocks, 1);
        assert!(v.is_empty(), "a rustfmt-wrapped let must not break the SAFETY link");

        let stale = "
fn f(p: *mut u8) -> u8 {
    // SAFETY: documents the first read only.
    let a = unsafe { *p };
    let b = unsafe { *p };
    a + b
}
";
        let (_, v) = run(stale, false);
        assert_eq!(v.len(), 1, "a completed statement must still end the walk");
        assert_eq!(v[0].line, 5);
    }

    #[test]
    fn send_sync_impls_require_audit_tags() {
        let good = "
// AUDIT(Send): the invariant is X.
// SAFETY: moving T across threads is sound because X.
unsafe impl Send for T {}
";
        let (_, v) = run(good, false);
        assert!(v.is_empty());

        let no_audit = "
// SAFETY: moving T across threads is sound because X.
unsafe impl Send for T {}
";
        let (stats, v) = run(no_audit, false);
        assert_eq!(stats.impls, 1);
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].kind, "impl-missing-audit");

        let plain_impl = "
// SAFETY: the trait's contract holds because Y.
unsafe impl Marker for T {}
";
        let (_, v) = run(plain_impl, false);
        assert!(v.is_empty(), "non-thread-marker unsafe impls need SAFETY only");
    }

    #[test]
    fn unsafe_fn_accepts_safety_doc_section() {
        let src = "
/// Does a thing.
///
/// # Safety
/// Caller must uphold Z.
unsafe fn danger() {}
";
        let (stats, v) = run(src, false);
        assert_eq!(stats.fns, 1);
        assert!(v.is_empty());

        let bare = "
unsafe fn danger() {}
";
        let (_, v) = run(bare, false);
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].kind, "fn-missing-safety");
    }

    #[test]
    fn relaxed_ordering_respects_the_allowlist() {
        let src = "
fn tick(c: &AtomicU64) {
    c.fetch_add(1, Ordering::Relaxed);
}
";
        let (stats, v) = run(src, true);
        assert_eq!(stats.relaxed, 1);
        assert!(v.is_empty());

        let (stats, v) = run(src, false);
        assert_eq!(stats.relaxed, 1);
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].kind, "relaxed-not-allowlisted");
    }
}

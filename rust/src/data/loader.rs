//! Batch assembly: shuffled epoch iteration + background prefetch.
//!
//! The prefetch thread builds (and augments) the *next* batch while the
//! PJRT executable runs the current one — the standard input-pipeline
//! overlap, measured in `benches/micro.rs` and EXPERIMENTS.md §Perf.

use std::sync::mpsc;
use std::sync::Arc;

use crate::runtime::Batch;
use crate::tensor::{IntTensor, Tensor};
use crate::util::rng::Rng;

use super::{augment, Dataset};

/// Iterates a dataset in shuffled full batches (training: drop-last).
pub struct Loader {
    pub dataset: Arc<Dataset>,
    pub batch: usize,
    pub augment: bool,
    pub pad: usize,
}

impl Loader {
    pub fn new(dataset: Arc<Dataset>, batch: usize, augment: bool) -> Loader {
        assert!(batch > 0 && dataset.n >= batch, "dataset smaller than batch");
        Loader { dataset, batch, augment, pad: 4 }
    }

    pub fn batches_per_epoch(&self) -> usize {
        self.dataset.n / self.batch
    }

    /// Build the batch for `indices` (len == self.batch).
    fn assemble(&self, indices: &[usize], rng: &mut Rng) -> Batch {
        let d = &self.dataset;
        let sz = d.sample_numel();
        let mut x = vec![0.0f32; self.batch * sz];
        let mut y = vec![0i32; self.batch];
        for (bi, &i) in indices.iter().enumerate() {
            let dst = &mut x[bi * sz..(bi + 1) * sz];
            if self.augment {
                augment::crop_flip(d.image(i), dst, d.h, d.w, d.c, rng, self.pad);
            } else {
                augment::copy(d.image(i), dst);
            }
            y[bi] = d.labels[i];
        }
        Batch {
            x: Tensor::new(vec![self.batch, d.h, d.w, d.c], x),
            y: IntTensor::new(vec![self.batch], y),
        }
    }

    /// The single home of the drop-last rule and the per-batch
    /// augmentation-RNG derivation — both epoch paths go through this,
    /// so sync and prefetch iteration can never drift apart. Returns
    /// `None` for a trailing partial chunk (dropped), otherwise the
    /// assembled batch with its RNG forked from the chunk's first index.
    fn batch_for_chunk(&self, epoch_seed: u64, chunk: &[usize]) -> Option<Batch> {
        if chunk.len() < self.batch {
            return None; // drop-last: partial batches never ship
        }
        let mut rng = Rng::new(epoch_seed ^ 0xA0_61).fork(chunk[0] as u64);
        Some(self.assemble(chunk, &mut rng))
    }

    /// One epoch of batches, synchronously.
    pub fn epoch(&self, epoch_seed: u64) -> Vec<Batch> {
        self.epoch_order(epoch_seed)
            .chunks(self.batch)
            .filter_map(|c| self.batch_for_chunk(epoch_seed, c))
            .collect()
    }

    fn epoch_order(&self, epoch_seed: u64) -> Vec<usize> {
        let mut order: Vec<usize> = (0..self.dataset.n).collect();
        if self.augment {
            // only shuffle the training stream
            Rng::new(epoch_seed).shuffle(&mut order);
        }
        order
    }

    /// One epoch of batches, produced by a background thread into a
    /// bounded channel (capacity 2: current + next).
    pub fn epoch_prefetch(&self, epoch_seed: u64) -> mpsc::Receiver<Batch> {
        let (tx, rx) = mpsc::sync_channel(2);
        let loader = Loader {
            dataset: Arc::clone(&self.dataset),
            batch: self.batch,
            augment: self.augment,
            pad: self.pad,
        };
        std::thread::spawn(move || {
            let order = loader.epoch_order(epoch_seed);
            for c in order.chunks(loader.batch) {
                match loader.batch_for_chunk(epoch_seed, c) {
                    Some(batch) => {
                        if tx.send(batch).is_err() {
                            break; // consumer dropped mid-epoch
                        }
                    }
                    None => break, // trailing partial chunk
                }
            }
        });
        rx
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{synth, DatasetKind};

    fn dataset(n: usize) -> Arc<Dataset> {
        synth::generate(DatasetKind::Cifar10, n, 1, 0).into_shared()
    }

    #[test]
    fn full_batches_only() {
        let l = Loader::new(dataset(70), 32, true);
        assert_eq!(l.batches_per_epoch(), 2);
        let batches = l.epoch(0);
        assert_eq!(batches.len(), 2);
        for b in &batches {
            assert_eq!(b.x.shape, vec![32, 32, 32, 3]);
            assert_eq!(b.y.shape, vec![32]);
        }
    }

    #[test]
    fn eval_loader_is_deterministic_and_ordered() {
        let l = Loader::new(dataset(64), 32, false);
        let a = l.epoch(0);
        let b = l.epoch(99); // seed must not matter without augmentation
        assert_eq!(a.len(), b.len());
        for (ba, bb) in a.iter().zip(&b) {
            assert_eq!(ba.x.data, bb.x.data);
            assert_eq!(ba.y.data, bb.y.data);
        }
        // unshuffled: first batch labels are dataset order
        assert_eq!(&a[0].y.data[..4], &l.dataset.labels[..4]);
    }

    #[test]
    fn train_epochs_shuffle_differently() {
        let l = Loader::new(dataset(128), 64, true);
        let a = l.epoch(0);
        let b = l.epoch(1);
        assert_ne!(a[0].y.data, b[0].y.data);
    }

    #[test]
    fn prefetch_matches_sync() {
        // multiple-of-batch and non-multiple sizes: the shared
        // batch_for_chunk helper must give identical streams either way,
        // including identical per-batch augmentation RNG draws
        for n in [96usize, 100, 127] {
            let l = Loader::new(dataset(n), 32, true);
            let sync: Vec<Batch> = l.epoch(5);
            let pre: Vec<Batch> = l.epoch_prefetch(5).iter().collect();
            assert_eq!(sync.len(), n / 32, "n={n}: drop-last count");
            assert_eq!(sync.len(), pre.len(), "n={n}");
            for (a, b) in sync.iter().zip(&pre) {
                assert_eq!(a.x.data, b.x.data);
                assert_eq!(a.y.data, b.y.data);
            }
        }
    }

    #[test]
    #[should_panic(expected = "smaller than batch")]
    fn rejects_tiny_dataset() {
        Loader::new(dataset(16), 32, false);
    }
}

//! Training-time augmentation (paper §IV-A: random resized crop +
//! horizontal flip; here: 4-px pad-and-crop — the standard CIFAR recipe —
//! plus horizontal flip).
//!
//! Operates on single NHWC images in place-free style: reads from the
//! dataset, writes into the batch buffer, so the hot loop does zero
//! allocation.

use crate::util::rng::Rng;

/// Copy `src` (h×w×c) into `dst` applying a random 4-px shift crop
/// (zero-padded) and a 50% horizontal flip.
pub fn crop_flip(
    src: &[f32],
    dst: &mut [f32],
    h: usize,
    w: usize,
    c: usize,
    rng: &mut Rng,
    pad: usize,
) {
    debug_assert_eq!(src.len(), h * w * c);
    debug_assert_eq!(dst.len(), h * w * c);
    // shift in [-pad, +pad]
    let dy = rng.below(2 * pad + 1) as isize - pad as isize;
    let dx = rng.below(2 * pad + 1) as isize - pad as isize;
    let flip = rng.bool(0.5);
    for y in 0..h as isize {
        let sy = y + dy;
        for x in 0..w as isize {
            let sx_logical = x + dx;
            let out = ((y as usize) * w + x as usize) * c;
            if sy < 0 || sy >= h as isize || sx_logical < 0 || sx_logical >= w as isize {
                dst[out..out + c].fill(0.0);
                continue;
            }
            let sx = if flip { w as isize - 1 - sx_logical } else { sx_logical };
            let inp = ((sy as usize) * w + sx as usize) * c;
            dst[out..out + c].copy_from_slice(&src[inp..inp + c]);
        }
    }
}

/// Identity "augmentation" for eval batches.
pub fn copy(src: &[f32], dst: &mut [f32]) {
    dst.copy_from_slice(src);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;
    use crate::prop_assert;

    fn image(h: usize, w: usize, c: usize) -> Vec<f32> {
        (0..h * w * c).map(|i| i as f32).collect()
    }

    #[test]
    fn zero_shift_no_flip_possible_identity() {
        // With pad=0 the only shift is 0; flip is still random, so check
        // that either identity or mirror comes out.
        let src = image(4, 4, 1);
        let mut dst = vec![0.0; 16];
        let mut rng = Rng::new(1);
        crop_flip(&src, &mut dst, 4, 4, 1, &mut rng, 0);
        let mirrored: Vec<f32> = (0..16)
            .map(|i| {
                let (y, x) = (i / 4, i % 4);
                src[y * 4 + (3 - x)]
            })
            .collect();
        assert!(dst == src || dst == mirrored);
    }

    #[test]
    fn preserves_pixel_multiset_when_unshifted() {
        // property: with pad=0 output is a permutation of input
        check(50, 9, |rng| {
            let src = image(8, 8, 3);
            let mut dst = vec![0.0; src.len()];
            crop_flip(&src, &mut dst, 8, 8, 3, rng, 0);
            let mut a = src.clone();
            let mut b = dst.clone();
            a.sort_by(f32::total_cmp);
            b.sort_by(f32::total_cmp);
            prop_assert!(a == b, "not a permutation");
            Ok(())
        });
    }

    #[test]
    fn shifted_pixels_zero_padded() {
        // property: out-of-range source pixels become exactly 0
        check(50, 11, |rng| {
            let src: Vec<f32> = vec![1.0; 8 * 8 * 2];
            let mut dst = vec![9.0; src.len()];
            crop_flip(&src, &mut dst, 8, 8, 2, rng, 4);
            prop_assert!(
                dst.iter().all(|&v| v == 0.0 || v == 1.0),
                "unexpected value"
            );
            Ok(())
        });
    }

    #[test]
    fn deterministic_given_rng() {
        let src = image(6, 6, 3);
        let mut d1 = vec![0.0; src.len()];
        let mut d2 = vec![0.0; src.len()];
        crop_flip(&src, &mut d1, 6, 6, 3, &mut Rng::new(5), 4);
        crop_flip(&src, &mut d2, 6, 6, 3, &mut Rng::new(5), 4);
        assert_eq!(d1, d2);
    }
}

//! Synthetic class-conditional image generator (the CIFAR-10 / ImageNet
//! substitution, DESIGN.md §4).
//!
//! Each class `c` owns a prototype: an oriented sinusoidal texture
//! (orientation θ_c, spatial frequency f_c), a color triple, and a
//! low-frequency blob position. Each *sample* jitters phase, position,
//! amplitude and adds pixel noise, so the task has real intra-class
//! variance: a linear probe tops out well below a CNN, and accuracy
//! falls off sharply when activations/weights are quantized to very few
//! bits — the loss-vs-bit-width trade-off AdaQAT's finite-difference
//! gradient feeds on.
//!
//! Generation is deterministic per (seed, split, index) via forked RNG
//! streams, so train/test splits never overlap and every run sees
//! identical data.

use crate::util::rng::Rng;

use super::{Dataset, DatasetKind};

/// Per-channel standardization constants (match the generator's output
/// statistics; analogous to CIFAR mean/std normalization in the paper's
/// §IV-A pipeline).
const MEAN: f32 = 0.28;
const STD: f32 = 0.25;

/// Class prototype parameters, derived deterministically from the class id.
#[derive(Debug, Clone, Copy)]
pub struct ClassProto {
    pub theta: f32,
    pub freq: f32,
    pub color: [f32; 3],
    pub blob_x: f32,
    pub blob_y: f32,
}

pub fn class_proto(kind: DatasetKind, class: usize) -> ClassProto {
    let nc = kind.num_classes();
    debug_assert!(class < nc);
    // Use a fixed RNG stream per class so prototypes are stable across
    // dataset sizes and splits.
    let mut r = Rng::new(0xC1A5_5E5u64 ^ ((class as u64) << 20) ^ nc as u64);
    let golden = 0.618_034_f32;
    ClassProto {
        // orientations tile [0, π) with a deterministic low-discrepancy
        // offset so nearby class ids get distant orientations
        theta: ((class as f32 * golden) % 1.0) * std::f32::consts::PI,
        freq: 2.0 + (class % 7) as f32 + r.uniform(),
        color: [
            0.45 + 0.35 * ((class * 3 + 1) % nc) as f32 / nc as f32,
            0.45 + 0.35 * ((class * 5 + 2) % nc) as f32 / nc as f32,
            0.45 + 0.35 * ((class * 7 + 3) % nc) as f32 / nc as f32,
        ],
        blob_x: 0.25 + 0.5 * r.uniform(),
        blob_y: 0.25 + 0.5 * r.uniform(),
    }
}

/// Render one sample into `out` (len = h*w*3, NHWC row-major).
pub fn render_sample(
    kind: DatasetKind,
    class: usize,
    rng: &mut Rng,
    h: usize,
    w: usize,
    out: &mut [f32],
) {
    let p = class_proto(kind, class);
    // per-sample jitter
    let phase = rng.range(0.0, 2.0 * std::f32::consts::PI);
    let amp = rng.range(0.5, 1.0);
    let dx = rng.range(-0.15, 0.15);
    let dy = rng.range(-0.15, 0.15);
    let blob_r = rng.range(0.12, 0.22);
    let noise_sigma = 0.28;
    // cue jitter: orientation/frequency wobble keeps classes from being
    // linearly separable on a single Gabor response
    let theta = p.theta + rng.range(-0.12, 0.12);
    let freq = p.freq + rng.range(-0.6, 0.6);
    let (st, ct) = theta.sin_cos();
    // distractor texture at a random orientation (shared across classes)
    let dtheta = rng.range(0.0, std::f32::consts::PI);
    let (dst, dct) = dtheta.sin_cos();
    let dphase = rng.range(0.0, 2.0 * std::f32::consts::PI);

    for yy in 0..h {
        for xx in 0..w {
            let u = xx as f32 / w as f32;
            let v = yy as f32 / h as f32;
            // oriented sinusoidal texture
            let t = ((u * ct + v * st) * freq * 2.0 * std::f32::consts::PI
                + phase)
                .sin();
            let d = ((u * dct + v * dst) * 4.5 * 2.0 * std::f32::consts::PI
                + dphase)
                .sin();
            let tex = 0.5 + 0.5 * amp * (0.75 * t + 0.25 * d);
            // low-frequency blob (class-positioned, sample-jittered)
            let bx = p.blob_x + dx;
            let by = p.blob_y + dy;
            let d2 = (u - bx) * (u - bx) + (v - by) * (v - by);
            let blob = (-d2 / (blob_r * blob_r)).exp();
            let base = 0.75 * tex + 0.25 * blob;
            let idx = (yy * w + xx) * 3;
            for ch in 0..3 {
                let val = (base * p.color[ch] + noise_sigma * rng.normal())
                    .clamp(0.0, 1.0);
                out[idx + ch] = (val - MEAN) / STD;
            }
        }
    }
}

/// Build a full split at the standard 32×32 size. `split` ∈ {0: train,
/// 1: test} decorrelates sample streams so splits never share pixels.
pub fn generate(kind: DatasetKind, n: usize, seed: u64, split: u64) -> Dataset {
    generate_sized(kind, n, seed, split, 32, 32)
}

/// [`generate`] at an arbitrary image size (the class prototypes are
/// resolution-independent: textures/blobs are parameterized in [0, 1]²,
/// so a 16×16 render is the 32×32 image sampled coarser). The native
/// training backend uses small sizes to keep offline CI runs fast.
pub fn generate_sized(
    kind: DatasetKind,
    n: usize,
    seed: u64,
    split: u64,
    h: usize,
    w: usize,
) -> Dataset {
    assert!(h > 0 && w > 0, "image size must be positive");
    let c = 3usize;
    let nc = kind.num_classes();
    let mut images = vec![0.0f32; n * h * w * c];
    let mut labels = vec![0i32; n];
    let base = Rng::new(seed ^ (split.wrapping_mul(0x9E37_79B9_0000_0001)));

    // Deterministic parallel generation: each worker renders a disjoint
    // index range; per-sample RNG comes from fork(index) so the result
    // is identical regardless of thread count.
    let threads = std::thread::available_parallelism().map(|x| x.get()).unwrap_or(4);
    let chunk = n.div_ceil(threads);
    let sample_sz = h * w * c;
    std::thread::scope(|scope| {
        for (ti, (img_chunk, lab_chunk)) in images
            .chunks_mut(chunk * sample_sz)
            .zip(labels.chunks_mut(chunk))
            .enumerate()
        {
            let base = base.clone();
            scope.spawn(move || {
                for (j, (img, lab)) in img_chunk
                    .chunks_mut(sample_sz)
                    .zip(lab_chunk.iter_mut())
                    .enumerate()
                {
                    let i = ti * chunk + j;
                    // class-balanced round-robin labels
                    let class = i % nc;
                    *lab = class as i32;
                    let mut rng = base.fork(i as u64);
                    render_sample(kind, class, &mut rng, h, w, img);
                }
            });
        }
    });

    Dataset { images, labels, n, h, w, c, num_classes: nc }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_calls() {
        let a = generate(DatasetKind::Cifar10, 64, 7, 0);
        let b = generate(DatasetKind::Cifar10, 64, 7, 0);
        assert_eq!(a.images, b.images);
        assert_eq!(a.labels, b.labels);
    }

    #[test]
    fn splits_differ() {
        let a = generate(DatasetKind::Cifar10, 32, 7, 0);
        let b = generate(DatasetKind::Cifar10, 32, 7, 1);
        assert_ne!(a.images, b.images);
    }

    #[test]
    fn prefix_stable_under_size() {
        // growing the dataset must not change earlier samples
        let a = generate(DatasetKind::Cifar10, 16, 3, 0);
        let b = generate(DatasetKind::Cifar10, 64, 3, 0);
        assert_eq!(a.images[..], b.images[..a.images.len()]);
    }

    #[test]
    fn labels_balanced() {
        let d = generate(DatasetKind::Cifar10, 100, 1, 0);
        for c in 0..10 {
            assert_eq!(d.labels.iter().filter(|&&l| l == c).count(), 10);
        }
    }

    #[test]
    fn pixel_stats_standardized() {
        let d = generate(DatasetKind::Cifar10, 256, 5, 0);
        let mean = d.images.iter().sum::<f32>() / d.images.len() as f32;
        let var = d.images.iter().map(|x| (x - mean).powi(2)).sum::<f32>()
            / d.images.len() as f32;
        assert!(mean.abs() < 0.3, "mean {mean}");
        assert!((0.3..3.0).contains(&var), "var {var}");
        assert!(d.images.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn classes_are_visually_distinct() {
        // mean inter-class L2 distance must dominate intra-class distance
        let d = generate(DatasetKind::Cifar10, 200, 2, 0);
        let dist = |a: &[f32], b: &[f32]| -> f32 {
            a.iter().zip(b).map(|(x, y)| (x - y).powi(2)).sum::<f32>()
        };
        // samples 0,10,20 are class 0; 1,11 class 1 (round-robin labels)
        let intra = dist(d.image(0), d.image(10)) + dist(d.image(0), d.image(20));
        let inter = dist(d.image(0), d.image(1)) + dist(d.image(0), d.image(5));
        assert!(inter > intra * 0.5, "inter {inter} intra {intra}");
    }

    #[test]
    fn sized_generation_matches_default_and_scales() {
        // the 32×32 wrapper is exactly generate_sized at 32
        let a = generate(DatasetKind::Cifar10, 16, 3, 0);
        let b = generate_sized(DatasetKind::Cifar10, 16, 3, 0, 32, 32);
        assert_eq!(a.images, b.images);
        // small renders are well-formed, standardized, deterministic
        let s1 = generate_sized(DatasetKind::Cifar10, 40, 9, 0, 16, 16);
        let s2 = generate_sized(DatasetKind::Cifar10, 40, 9, 0, 16, 16);
        assert_eq!(s1.images, s2.images);
        assert_eq!((s1.h, s1.w, s1.c), (16, 16, 3));
        assert_eq!(s1.images.len(), 40 * 16 * 16 * 3);
        assert!(s1.images.iter().all(|x| x.is_finite()));
        // classes still carry signal at 16×16 (round-robin labels)
        let dist = |a: &[f32], b: &[f32]| -> f32 {
            a.iter().zip(b).map(|(x, y)| (x - y).powi(2)).sum::<f32>()
        };
        let intra = dist(s1.image(0), s1.image(10)) + dist(s1.image(0), s1.image(20));
        let inter = dist(s1.image(0), s1.image(1)) + dist(s1.image(0), s1.image(5));
        assert!(inter > intra * 0.5, "inter {inter} intra {intra}");
    }

    #[test]
    fn imagenet_lite_has_100_classes() {
        let d = generate(DatasetKind::ImagenetLite, 200, 1, 0);
        let max = *d.labels.iter().max().unwrap();
        assert_eq!(d.num_classes, 100);
        assert_eq!(max, 99);
    }
}

//! Data pipeline substrate: synthetic datasets, augmentation, batching,
//! and background prefetch.
//!
//! The paper trains on CIFAR-10 and ImageNet; neither is available in
//! this environment (repro band 0/5), so [`synth`] generates
//! class-conditional structured images that preserve the property AdaQAT
//! actually exercises — a CNN-learnable task whose loss measurably
//! degrades as bit-widths shrink (DESIGN.md §4).

pub mod augment;
pub mod loader;
pub mod synth;

use std::sync::Arc;

/// An in-memory image-classification dataset, NHWC f32.
#[derive(Debug)]
pub struct Dataset {
    pub images: Vec<f32>,
    pub labels: Vec<i32>,
    pub n: usize,
    pub h: usize,
    pub w: usize,
    pub c: usize,
    pub num_classes: usize,
}

impl Dataset {
    pub fn sample_numel(&self) -> usize {
        self.h * self.w * self.c
    }

    /// Borrow one sample's pixels.
    pub fn image(&self, i: usize) -> &[f32] {
        let sz = self.sample_numel();
        &self.images[i * sz..(i + 1) * sz]
    }

    pub fn into_shared(self) -> Arc<Dataset> {
        Arc::new(self)
    }
}

/// Dataset family selector (paper datasets → synthetic substitutes).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DatasetKind {
    /// 10-class, 32×32×3 — the CIFAR-10 substitute.
    Cifar10,
    /// 100-class, 32×32×3 — the ImageNet-lite substitute.
    ImagenetLite,
}

impl DatasetKind {
    pub fn parse(s: &str) -> Result<DatasetKind, String> {
        match s {
            "cifar10" => Ok(DatasetKind::Cifar10),
            "imagenet-lite" => Ok(DatasetKind::ImagenetLite),
            _ => Err(format!("unknown dataset {s:?} (cifar10|imagenet-lite)")),
        }
    }

    pub fn num_classes(&self) -> usize {
        match self {
            DatasetKind::Cifar10 => 10,
            DatasetKind::ImagenetLite => 100,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_parsing() {
        assert_eq!(DatasetKind::parse("cifar10").unwrap(), DatasetKind::Cifar10);
        assert_eq!(
            DatasetKind::parse("imagenet-lite").unwrap(),
            DatasetKind::ImagenetLite
        );
        assert!(DatasetKind::parse("mnist").is_err());
        assert_eq!(DatasetKind::Cifar10.num_classes(), 10);
        assert_eq!(DatasetKind::ImagenetLite.num_classes(), 100);
    }
}

//! AOT manifest: the tensor-layout contract between `python/compile/aot.py`
//! and the Rust runtime.
//!
//! The manifest fixes, per model, the exact flat ordering of parameters /
//! momentum buffers / BN statistics in the compiled HLO's argument list,
//! each tensor's shape + init spec, and per-layer geometry for the
//! BitOPs/WCR cost model. If the Python and Rust sides ever disagree on
//! this file, nothing runs — so it is validated aggressively on load.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub init: String,
    pub role: String,
}

impl ParamSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Debug, Clone)]
pub struct BnSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub init: String,
}

/// Geometry of one conv/fc layer (paper §III-B cost model inputs).
#[derive(Debug, Clone)]
pub struct LayerGeom {
    pub name: String,
    pub kind: String,
    pub weight_count: usize,
    pub macs: usize,
    pub fixed8: bool,
}

#[derive(Debug, Clone)]
pub struct ModelManifest {
    pub key: String,
    pub batch: usize,
    pub input_hw: (usize, usize),
    pub in_channels: usize,
    pub num_classes: usize,
    pub params: Vec<ParamSpec>,
    pub bn: Vec<BnSpec>,
    pub geoms: Vec<LayerGeom>,
    /// artifact suffix ("train", "loss", …) → HLO filename.
    pub artifacts: BTreeMap<String, String>,
}

impl ModelManifest {
    pub fn input_numel(&self) -> usize {
        self.batch * self.input_hw.0 * self.input_hw.1 * self.in_channels
    }

    pub fn param_count(&self) -> usize {
        self.params.iter().map(|p| p.numel()).sum()
    }

    /// Weight parameters only (conv_w/fc_w) — the WCR numerator.
    pub fn weight_count(&self) -> usize {
        self.params
            .iter()
            .filter(|p| p.role == "conv_w" || p.role == "fc_w")
            .map(|p| p.numel())
            .sum()
    }
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub models: BTreeMap<String, ModelManifest>,
}

impl Manifest {
    pub fn load(dir: &Path) -> anyhow::Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            anyhow::anyhow!("{path:?}: {e} — run `make artifacts` first")
        })?;
        let json = Json::parse(&text).map_err(|e| anyhow::anyhow!("{path:?}: {e}"))?;
        let version = json
            .get("version")
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow::anyhow!("manifest missing version"))?;
        anyhow::ensure!(version == 1, "unsupported manifest version {version}");

        let mut models = BTreeMap::new();
        let mobj = json
            .at(&["models"])
            .map_err(|e| anyhow::anyhow!("{e}"))?
            .as_obj()
            .ok_or_else(|| anyhow::anyhow!("manifest models not an object"))?;
        for (key, m) in mobj {
            models.insert(key.clone(), parse_model(key, m)?);
        }
        anyhow::ensure!(!models.is_empty(), "manifest lists no models");
        Ok(Manifest { dir: dir.to_path_buf(), models })
    }

    pub fn model(&self, key: &str) -> anyhow::Result<&ModelManifest> {
        self.models.get(key).ok_or_else(|| {
            anyhow::anyhow!(
                "model {key:?} not in manifest (have: {:?})",
                self.models.keys().collect::<Vec<_>>()
            )
        })
    }
}

fn shape_of(j: &Json) -> anyhow::Result<Vec<usize>> {
    j.as_arr()
        .ok_or_else(|| anyhow::anyhow!("shape not an array"))?
        .iter()
        .map(|d| d.as_usize().ok_or_else(|| anyhow::anyhow!("bad dim")))
        .collect()
}

fn req_str(j: &Json, k: &str) -> anyhow::Result<String> {
    Ok(j.get(k)
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow::anyhow!("missing string field {k:?}"))?
        .to_string())
}

fn req_usize(j: &Json, k: &str) -> anyhow::Result<usize> {
    j.get(k)
        .and_then(Json::as_usize)
        .ok_or_else(|| anyhow::anyhow!("missing numeric field {k:?}"))
}

fn parse_model(key: &str, m: &Json) -> anyhow::Result<ModelManifest> {
    let hw = m
        .at(&["input_hw"])
        .map_err(|e| anyhow::anyhow!("{key}: {e}"))?
        .as_arr()
        .ok_or_else(|| anyhow::anyhow!("{key}: input_hw not an array"))?;
    anyhow::ensure!(hw.len() == 2, "{key}: input_hw must have 2 entries");

    let mut params = vec![];
    for p in m.at(&["params"]).map_err(|e| anyhow::anyhow!("{e}"))?.as_arr().unwrap_or(&[])
    {
        params.push(ParamSpec {
            name: req_str(p, "name")?,
            shape: shape_of(p.get("shape").ok_or_else(|| anyhow::anyhow!("no shape"))?)?,
            init: req_str(p, "init")?,
            role: req_str(p, "role")?,
        });
    }
    anyhow::ensure!(!params.is_empty(), "{key}: no params");

    let mut bn = vec![];
    for b in m.at(&["bn"]).map_err(|e| anyhow::anyhow!("{e}"))?.as_arr().unwrap_or(&[]) {
        bn.push(BnSpec {
            name: req_str(b, "name")?,
            shape: shape_of(b.get("shape").ok_or_else(|| anyhow::anyhow!("no shape"))?)?,
            init: req_str(b, "init")?,
        });
    }

    let mut geoms = vec![];
    for g in m.at(&["geoms"]).map_err(|e| anyhow::anyhow!("{e}"))?.as_arr().unwrap_or(&[])
    {
        geoms.push(LayerGeom {
            name: req_str(g, "name")?,
            kind: req_str(g, "kind")?,
            weight_count: req_usize(g, "weight_count")?,
            macs: req_usize(g, "macs")?,
            fixed8: g.get("fixed8").and_then(Json::as_bool).unwrap_or(false),
        });
    }
    anyhow::ensure!(!geoms.is_empty(), "{key}: no layer geometry");

    let mut artifacts = BTreeMap::new();
    if let Some(arts) = m.get("artifacts").and_then(Json::as_obj) {
        for (suffix, fname) in arts {
            artifacts.insert(
                suffix.clone(),
                fname.as_str().ok_or_else(|| anyhow::anyhow!("bad artifact"))?.to_string(),
            );
        }
    }
    for required in ["train", "loss", "eval"] {
        anyhow::ensure!(
            artifacts.contains_key(required),
            "{key}: missing artifact {required:?}"
        );
    }

    Ok(ModelManifest {
        key: key.to_string(),
        batch: req_usize(m, "batch")?,
        input_hw: (hw[0].as_usize().unwrap(), hw[1].as_usize().unwrap()),
        in_channels: req_usize(m, "in_channels")?,
        num_classes: req_usize(m, "num_classes")?,
        params,
        bn,
        geoms,
        artifacts,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A minimal synthetic manifest for unit tests that don't need the
    /// real artifacts (integration tests use the real one).
    pub(crate) fn fake_manifest_json() -> String {
        r#"{
          "version": 1,
          "models": {
            "toy": {
              "batch": 4, "input_hw": [8, 8], "in_channels": 3,
              "num_classes": 2,
              "params": [
                {"name": "stem.w", "shape": [3,3,3,4], "init": "kaiming:27", "role": "conv_w"},
                {"name": "fc.w", "shape": [4,2], "init": "kaiming:4", "role": "fc_w"},
                {"name": "fc.b", "shape": [2], "init": "zeros", "role": "fc_b"}
              ],
              "bn": [
                {"name": "stem.bn.mean", "shape": [4], "init": "zeros"},
                {"name": "stem.bn.var", "shape": [4], "init": "ones"}
              ],
              "geoms": [
                {"name": "stem", "kind": "conv", "weight_count": 108, "macs": 6912, "fixed8": true},
                {"name": "mid", "kind": "conv", "weight_count": 144, "macs": 9216, "fixed8": false},
                {"name": "fc", "kind": "fc", "weight_count": 8, "macs": 8, "fixed8": true}
              ],
              "artifacts": {"train": "toy_train.hlo.txt", "loss": "toy_loss.hlo.txt", "eval": "toy_eval.hlo.txt"}
            }
          }
        }"#.to_string()
    }

    fn load_fake() -> Manifest {
        let dir = std::env::temp_dir().join(format!("adaqat_manifest_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), fake_manifest_json()).unwrap();
        Manifest::load(&dir).unwrap()
    }

    #[test]
    fn parses_fake_manifest() {
        let m = load_fake();
        let toy = m.model("toy").unwrap();
        assert_eq!(toy.batch, 4);
        assert_eq!(toy.params.len(), 3);
        assert_eq!(toy.params[0].numel(), 108);
        assert_eq!(toy.bn.len(), 2);
        assert_eq!(toy.weight_count(), 108 + 8);
        assert_eq!(toy.input_numel(), 4 * 8 * 8 * 3);
        assert!(m.model("missing").is_err());
    }

    #[test]
    fn rejects_missing_artifacts() {
        let bad = fake_manifest_json().replace("\"eval\": \"toy_eval.hlo.txt\"", "\"x\": \"y\"");
        let dir = std::env::temp_dir().join(format!("adaqat_badman_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), bad.replace(", \"x\": \"y\"}", "}")).unwrap();
        assert!(Manifest::load(&dir).is_err());
    }

    #[test]
    fn rejects_wrong_version() {
        let dir = std::env::temp_dir().join(format!("adaqat_badver_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            fake_manifest_json().replace("\"version\": 1", "\"version\": 99"),
        )
        .unwrap();
        assert!(Manifest::load(&dir).is_err());
    }
}

//! PJRT runtime: load AOT artifacts, hold training state, execute steps.
//!
//! The flow mirrors /opt/xla-example/load_hlo: HLO **text** →
//! `HloModuleProto::from_text_file` → `XlaComputation` → `client.compile`
//! → `execute`. One compiled executable per (model, step-kind); the
//! AdaQAT bit-widths enter as runtime scalars (`s_w`, `s_a`), so a whole
//! training run — including every finite-difference probe — reuses the
//! same executables with different scalar inputs (DESIGN.md §2).

pub mod manifest;

use std::path::Path;

use crate::tensor::checkpoint::Checkpoint;
use crate::tensor::{init::init_tensor, IntTensor, Tensor};
use crate::util::json::Json;
use crate::util::rng::Rng;

pub use manifest::{Manifest, ModelManifest};

// The bit-width → runtime-scalar mapping lives with the rest of the
// quantization math; re-exported here because callers binding graph
// inputs reach for it through the runtime.
pub use crate::quant::{bitwidth_scale, S_IDENTITY};

/// One training batch, already padded to the artifact's static batch size.
#[derive(Debug, Clone)]
pub struct Batch {
    /// (batch, H, W, C) f32, NHWC.
    pub x: Tensor,
    /// (batch,) i32 labels.
    pub y: IntTensor,
}

/// Scalar metrics returned by every step kind.
#[derive(Debug, Clone, Copy)]
pub struct StepMetrics {
    pub loss: f32,
    pub correct: f32,
}

/// Host-resident model state: parameters, momentum, BN statistics,
/// ordered exactly as the manifest (the HLO argument order).
#[derive(Debug, Clone)]
pub struct TrainState {
    pub params: Vec<Tensor>,
    pub momentum: Vec<Tensor>,
    pub bn: Vec<Tensor>,
}

impl TrainState {
    /// Fraction of parameters with non-finite values (divergence check).
    pub fn is_finite(&self) -> bool {
        self.params.iter().all(Tensor::is_finite)
            && self.bn.iter().all(Tensor::is_finite)
    }
}

/// What executes training steps — the seam between the orchestration
/// layer ([`crate::train`], [`crate::coordinator`]) and the math.
///
/// Two implementations: the PJRT [`ModelRuntime`] (compiled HLO graphs,
/// needs AOT artifacts) and the pure-Rust [`crate::backprop`] backend
/// (offline MLP fake-quant training, DESIGN.md §12). Callers think in
/// integer bit-widths `(k_w, k_a)`; each backend maps them onto its own
/// quantizer representation (the PJRT graphs take `s = 2^k − 1` runtime
/// scalars via [`bitwidth_scale`], the native backend quantizes on the
/// same grid directly).
pub trait StepBackend {
    /// Shape/ordering contract for state, batches, and checkpoints.
    fn mm(&self) -> &ModelManifest;

    /// Fresh training state from the manifest init specs.
    fn init_state(&self, seed: u64) -> anyhow::Result<TrainState>;

    /// State from a checkpoint (missing tensors keep their fresh init).
    fn load_state(&self, ck: &Checkpoint, seed: u64) -> anyhow::Result<TrainState>;

    /// One SGD step at bit-widths (k_w, k_a); updates `state` in place.
    fn train_step(
        &self,
        state: &mut TrainState,
        batch: &Batch,
        lr: f32,
        k_w: u32,
        k_a: u32,
        fp32: bool,
    ) -> anyhow::Result<StepMetrics>;

    /// Forward-only task loss on the SAME batch at neighbor bit-widths —
    /// the finite-difference probe of paper §III-C.
    fn probe_loss(
        &self,
        state: &TrainState,
        batch: &Batch,
        k_w: u32,
        k_a: u32,
    ) -> anyhow::Result<StepMetrics>;

    /// Inference-mode evaluation at (k_w, k_a).
    fn eval_batch(
        &self,
        state: &TrainState,
        batch: &Batch,
        k_w: u32,
        k_a: u32,
        fp32: bool,
    ) -> anyhow::Result<StepMetrics>;

    /// Whether the fp32 baseline path exists (pretraining needs it).
    fn has_fp32(&self) -> bool;

    /// Extra serving metadata for checkpoints this backend trains
    /// (e.g. the native backend's `mlp_layers`/`input_hw` so exported
    /// `AQQCKPT1` files drive `serve::ReferenceBackend` directly).
    fn checkpoint_meta(&self) -> Vec<(String, Json)> {
        vec![]
    }
}

/// Initialize a [`TrainState`] from manifest init specs — shared by
/// every [`StepBackend`]: one RNG stream consumed in manifest order, so
/// a (manifest, seed) pair fixes the parameters regardless of backend.
pub fn init_state_from_manifest(mm: &ModelManifest, seed: u64) -> anyhow::Result<TrainState> {
    let mut rng = Rng::new(seed);
    let mut params = vec![];
    for p in &mm.params {
        params.push(
            init_tensor(&p.init, &p.shape, &mut rng)
                .map_err(|e| anyhow::anyhow!("{}: {e}", p.name))?,
        );
    }
    let momentum = mm.params.iter().map(|p| Tensor::zeros(p.shape.clone())).collect();
    let mut bn = vec![];
    for b in &mm.bn {
        bn.push(
            init_tensor(&b.init, &b.shape, &mut rng)
                .map_err(|e| anyhow::anyhow!("{}: {e}", b.name))?,
        );
    }
    Ok(TrainState { params, momentum, bn })
}

/// Load checkpoint tensors into a fresh state by name; momentum
/// restarts at zero. Unknown checkpoint entries are ignored, missing
/// ones keep their fresh init (e.g. `alpha` when fine-tuning from an
/// fp32 pretrain that never trained it).
pub fn load_state_from_manifest(
    mm: &ModelManifest,
    ck: &Checkpoint,
    seed: u64,
) -> anyhow::Result<TrainState> {
    let mut state = init_state_from_manifest(mm, seed)?;
    let map = ck.tensor_map();
    let mut loaded = 0usize;
    for (i, spec) in mm.params.iter().enumerate() {
        if let Some(t) = map.get(spec.name.as_str()) {
            anyhow::ensure!(
                t.shape == spec.shape,
                "checkpoint {}: shape {:?} != manifest {:?}",
                spec.name, t.shape, spec.shape
            );
            state.params[i] = (*t).clone();
            loaded += 1;
        }
    }
    for (i, spec) in mm.bn.iter().enumerate() {
        if let Some(t) = map.get(spec.name.as_str()) {
            state.bn[i] = (*t).clone();
            loaded += 1;
        }
    }
    log::info!("loaded {loaded} tensors from checkpoint");
    Ok(state)
}

/// The PJRT client + loaded manifest; entry point of the runtime layer.
pub struct Runtime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
}

impl Runtime {
    pub fn new(artifact_dir: &Path) -> anyhow::Result<Runtime> {
        let manifest = Manifest::load(artifact_dir)?;
        let client = xla::PjRtClient::cpu()?;
        log::info!(
            "PJRT client up: platform={} devices={}",
            client.platform_name(),
            client.device_count()
        );
        Ok(Runtime { client, manifest })
    }

    /// Open one model's artifact set. Executables compile lazily on first
    /// use (a step kind a run never touches — e.g. the fp32 graphs in a
    /// quantized run — is never compiled), then stay cached for the
    /// lifetime of the `ModelRuntime`.
    pub fn load_model(&self, key: &str) -> anyhow::Result<ModelRuntime> {
        let mm = self.manifest.model(key)?.clone();
        let lazy = |suffix: &str| -> LazyExe {
            LazyExe {
                path: mm
                    .artifacts
                    .get(suffix)
                    .map(|fname| self.manifest.dir.join(fname)),
                suffix: suffix.to_string(),
                cell: std::cell::OnceCell::new(),
            }
        };
        Ok(ModelRuntime {
            train: lazy("train"),
            loss: lazy("loss"),
            eval: lazy("eval"),
            infer: lazy("infer"),
            fp_train: lazy("fp_train"),
            fp_eval: lazy("fp_eval"),
            client: self.client.clone(),
            mm,
        })
    }
}

/// A lazily compiled executable (PJRT compilation of the larger HLO
/// graphs takes seconds; pay only for the graphs a run uses).
struct LazyExe {
    path: Option<std::path::PathBuf>,
    suffix: String,
    cell: std::cell::OnceCell<xla::PjRtLoadedExecutable>,
}

impl LazyExe {
    fn get(
        &self,
        client: &xla::PjRtClient,
        key: &str,
    ) -> anyhow::Result<&xla::PjRtLoadedExecutable> {
        let path = self
            .path
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("{key}: no artifact {:?}", self.suffix))?;
        if let Some(exe) = self.cell.get() {
            return Ok(exe);
        }
        let t0 = std::time::Instant::now();
        let proto = xla::HloModuleProto::from_text_file(path)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp)?;
        log::info!(
            "compiled {}_{} in {:.2}s",
            key,
            self.suffix,
            t0.elapsed().as_secs_f64()
        );
        Ok(self.cell.get_or_init(|| exe))
    }

    fn available(&self) -> bool {
        self.path.is_some()
    }
}

/// Compiled executables + manifest for one model.
pub struct ModelRuntime {
    pub mm: ModelManifest,
    client: xla::PjRtClient,
    train: LazyExe,
    loss: LazyExe,
    eval: LazyExe,
    infer: LazyExe,
    fp_train: LazyExe,
    fp_eval: LazyExe,
}

// Perf note (EXPERIMENTS.md §Perf, L3 iteration 1): build literals with
// a single memcpy via create_from_shape_and_untyped_data instead of
// vec1(copy) + reshape(second copy + XLA call).
fn to_literal(t: &Tensor) -> anyhow::Result<xla::Literal> {
    if t.shape.is_empty() {
        return Ok(xla::Literal::scalar(t.data[0]));
    }
    // SAFETY: reinterpreting a live `&[f32]` as its raw bytes — same
    // allocation, exact byte length (len·4), u8 has no alignment
    // requirement, and the borrow of `t` keeps the data alive for the
    // slice's lifetime.
    let bytes: &[u8] = unsafe {
        std::slice::from_raw_parts(t.data.as_ptr() as *const u8, t.data.len() * 4)
    };
    Ok(xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::F32,
        &t.shape,
        bytes,
    )?)
}

fn int_to_literal(t: &IntTensor) -> anyhow::Result<xla::Literal> {
    // SAFETY: reinterpreting a live `&[i32]` as its raw bytes — same
    // allocation, exact byte length (len·4), u8 has no alignment
    // requirement, and the borrow of `t` keeps the data alive for the
    // slice's lifetime.
    let bytes: &[u8] = unsafe {
        std::slice::from_raw_parts(t.data.as_ptr() as *const u8, t.data.len() * 4)
    };
    Ok(xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::S32,
        &t.shape,
        bytes,
    )?)
}

fn from_literal(l: &xla::Literal, shape: &[usize]) -> anyhow::Result<Tensor> {
    let data = l.to_vec::<f32>()?;
    Ok(Tensor::new(shape.to_vec(), data))
}

impl ModelRuntime {
    /// Initialize fresh training state from the manifest init specs.
    pub fn init_state(&self, seed: u64) -> anyhow::Result<TrainState> {
        init_state_from_manifest(&self.mm, seed)
    }

    /// Load parameters (and BN stats) from checkpoint tensors by name;
    /// momentum restarts at zero (see [`load_state_from_manifest`]).
    pub fn load_state(
        &self,
        ck: &crate::tensor::checkpoint::Checkpoint,
        seed: u64,
    ) -> anyhow::Result<TrainState> {
        load_state_from_manifest(&self.mm, ck, seed)
    }

    fn check_batch(&self, batch: &Batch) -> anyhow::Result<()> {
        anyhow::ensure!(
            batch.x.shape
                == vec![
                    self.mm.batch,
                    self.mm.input_hw.0,
                    self.mm.input_hw.1,
                    self.mm.in_channels
                ],
            "batch x shape {:?} does not match artifact batch {}",
            batch.x.shape,
            self.mm.batch
        );
        anyhow::ensure!(batch.y.shape == vec![self.mm.batch], "bad y shape");
        Ok(())
    }

    /// One fused SGD train step; updates `state` in place and returns the
    /// batch loss and correct-count. `fp32` selects the baseline graph.
    pub fn train_step(
        &self,
        state: &mut TrainState,
        batch: &Batch,
        lr: f32,
        s_w: f32,
        s_a: f32,
        fp32: bool,
    ) -> anyhow::Result<StepMetrics> {
        self.check_batch(batch)?;
        let exe = if fp32 {
            self.fp_train.get(&self.client, &self.mm.key)?
        } else {
            self.train.get(&self.client, &self.mm.key)?
        };
        let mut inputs: Vec<xla::Literal> =
            Vec::with_capacity(2 * state.params.len() + state.bn.len() + 5);
        for t in state.params.iter().chain(&state.momentum).chain(&state.bn) {
            inputs.push(to_literal(t)?);
        }
        inputs.push(to_literal(&batch.x)?);
        inputs.push(int_to_literal(&batch.y)?);
        inputs.push(xla::Literal::scalar(lr));
        inputs.push(xla::Literal::scalar(s_w));
        inputs.push(xla::Literal::scalar(s_a));

        let result = exe.execute::<xla::Literal>(&inputs)?[0][0].to_literal_sync()?;
        let outs = result.to_tuple()?;
        let np = self.mm.params.len();
        let nb = self.mm.bn.len();
        anyhow::ensure!(
            outs.len() == 2 * np + nb + 2,
            "train step returned {} outputs, expected {}",
            outs.len(),
            2 * np + nb + 2
        );
        for (i, spec) in self.mm.params.iter().enumerate() {
            state.params[i] = from_literal(&outs[i], &spec.shape)?;
            state.momentum[i] = from_literal(&outs[np + i], &spec.shape)?;
        }
        for (i, spec) in self.mm.bn.iter().enumerate() {
            state.bn[i] = from_literal(&outs[2 * np + i], &spec.shape)?;
        }
        Ok(StepMetrics {
            loss: outs[2 * np + nb].get_first_element::<f32>()?,
            correct: outs[2 * np + nb + 1].get_first_element::<f32>()?,
        })
    }

    fn forward(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        state: &TrainState,
        batch: &Batch,
        s_w: f32,
        s_a: f32,
    ) -> anyhow::Result<StepMetrics> {
        self.check_batch(batch)?;
        let mut inputs: Vec<xla::Literal> =
            Vec::with_capacity(state.params.len() + state.bn.len() + 4);
        for t in state.params.iter().chain(&state.bn) {
            inputs.push(to_literal(t)?);
        }
        inputs.push(to_literal(&batch.x)?);
        inputs.push(int_to_literal(&batch.y)?);
        inputs.push(xla::Literal::scalar(s_w));
        inputs.push(xla::Literal::scalar(s_a));
        let result = exe.execute::<xla::Literal>(&inputs)?[0][0].to_literal_sync()?;
        let (loss, correct) = result.to_tuple2()?;
        Ok(StepMetrics {
            loss: loss.get_first_element::<f32>()?,
            correct: correct.get_first_element::<f32>()?,
        })
    }

    /// Forward-only task loss with batch-stat BN — the finite-difference
    /// probe of paper §III-C (same batch, neighbor bit-width scales).
    pub fn probe_loss(
        &self,
        state: &TrainState,
        batch: &Batch,
        s_w: f32,
        s_a: f32,
    ) -> anyhow::Result<StepMetrics> {
        self.forward(self.loss.get(&self.client, &self.mm.key)?, state, batch, s_w, s_a)
    }

    /// Inference-mode evaluation (running-stat BN).
    pub fn eval_batch(
        &self,
        state: &TrainState,
        batch: &Batch,
        s_w: f32,
        s_a: f32,
        fp32: bool,
    ) -> anyhow::Result<StepMetrics> {
        let exe = if fp32 {
            self.fp_eval.get(&self.client, &self.mm.key)?
        } else {
            self.eval.get(&self.client, &self.mm.key)?
        };
        self.forward(exe, state, batch, s_w, s_a)
    }

    pub fn has_fp32(&self) -> bool {
        self.fp_train.available() && self.fp_eval.available()
    }

    /// Whether the "infer" artifact exists (serving needs it; artifact
    /// sets built before the serve subsystem landed predate it).
    pub fn has_infer(&self) -> bool {
        self.infer.available()
    }

    /// Serving forward pass: predicted class per sample, inference-mode
    /// BN, no labels. `x` must already be padded to the static batch
    /// shape (the serve batcher guarantees this — DESIGN.md §7).
    pub fn infer_batch(
        &self,
        state: &TrainState,
        x: &Tensor,
        s_w: f32,
        s_a: f32,
    ) -> anyhow::Result<Vec<usize>> {
        anyhow::ensure!(
            x.shape
                == vec![
                    self.mm.batch,
                    self.mm.input_hw.0,
                    self.mm.input_hw.1,
                    self.mm.in_channels
                ],
            "infer x shape {:?} does not match artifact batch {}",
            x.shape,
            self.mm.batch
        );
        let exe = self.infer.get(&self.client, &self.mm.key)?;
        let mut inputs: Vec<xla::Literal> =
            Vec::with_capacity(state.params.len() + state.bn.len() + 3);
        for t in state.params.iter().chain(&state.bn) {
            inputs.push(to_literal(t)?);
        }
        inputs.push(to_literal(x)?);
        inputs.push(xla::Literal::scalar(s_w));
        inputs.push(xla::Literal::scalar(s_a));
        let result = exe.execute::<xla::Literal>(&inputs)?[0][0].to_literal_sync()?;
        let outs = result.to_tuple()?;
        anyhow::ensure!(outs.len() == 1, "infer returned {} outputs", outs.len());
        let preds = outs[0].to_vec::<f32>()?;
        anyhow::ensure!(
            preds.len() == self.mm.batch,
            "infer returned {} predictions for batch {}",
            preds.len(),
            self.mm.batch
        );
        Ok(preds.into_iter().map(|p| p.max(0.0) as usize).collect())
    }
}

impl StepBackend for ModelRuntime {
    fn mm(&self) -> &ModelManifest {
        &self.mm
    }

    fn init_state(&self, seed: u64) -> anyhow::Result<TrainState> {
        ModelRuntime::init_state(self, seed)
    }

    fn load_state(&self, ck: &Checkpoint, seed: u64) -> anyhow::Result<TrainState> {
        ModelRuntime::load_state(self, ck, seed)
    }

    fn train_step(
        &self,
        state: &mut TrainState,
        batch: &Batch,
        lr: f32,
        k_w: u32,
        k_a: u32,
        fp32: bool,
    ) -> anyhow::Result<StepMetrics> {
        ModelRuntime::train_step(
            self,
            state,
            batch,
            lr,
            bitwidth_scale(k_w),
            bitwidth_scale(k_a),
            fp32,
        )
    }

    fn probe_loss(
        &self,
        state: &TrainState,
        batch: &Batch,
        k_w: u32,
        k_a: u32,
    ) -> anyhow::Result<StepMetrics> {
        ModelRuntime::probe_loss(self, state, batch, bitwidth_scale(k_w), bitwidth_scale(k_a))
    }

    fn eval_batch(
        &self,
        state: &TrainState,
        batch: &Batch,
        k_w: u32,
        k_a: u32,
        fp32: bool,
    ) -> anyhow::Result<StepMetrics> {
        ModelRuntime::eval_batch(
            self,
            state,
            batch,
            bitwidth_scale(k_w),
            bitwidth_scale(k_a),
            fp32,
        )
    }

    fn has_fp32(&self) -> bool {
        ModelRuntime::has_fp32(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bitwidth_scale_reexport_is_the_quant_impl() {
        // the single home is crate::quant (dedup'd in the serve PR);
        // the re-export must stay in lockstep
        assert_eq!(bitwidth_scale(4), crate::quant::bitwidth_scale(4));
        assert_eq!(S_IDENTITY, crate::quant::S_IDENTITY);
    }
}

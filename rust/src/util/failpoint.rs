//! Deterministic fault-injection harness (DESIGN.md §19).
//!
//! Named *failpoints* are compiled into the serving hot paths (queue
//! push, batcher, worker pool, socket read/write) and normally cost
//! nothing: without the `failpoints` cargo feature every entry point
//! here is an empty `#[inline(always)]` function the optimizer erases.
//! With the feature on, each site consults a process-global registry
//! configured either programmatically ([`configure`]) or from the
//! environment:
//!
//! ```text
//! ADAQAT_FAILPOINTS='batcher_stall=sleep(50);worker_infer=panic(0.01)'
//! ADAQAT_FAILPOINTS_SEED=42   # optional; defaults to 0
//! ```
//!
//! Supported actions:
//!
//! | spec          | effect at the site                               |
//! |---------------|--------------------------------------------------|
//! | `off`         | nothing (useful to disable one site of a list)   |
//! | `sleep(MS)`   | block the calling thread for `MS` milliseconds   |
//! | `panic(P)`    | panic with probability `P` (deterministic RNG)   |
//! | `reset(P)`    | I/O sites: return `ConnectionReset` with prob `P`|
//!
//! Randomized actions draw from a per-site [`crate::util::rng::Rng`]
//! seeded by `fnv1a(site_name) ^ seed`, so a given spec + seed produces
//! the same fault schedule on every run — chaos tests are replayable.
//!
//! The spec parser ([`parse_spec`]) is compiled unconditionally so the
//! grammar stays unit-tested in tier-1 even though the registry only
//! exists under the feature.

/// One parsed failpoint action. See the module docs for the grammar.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Action {
    /// Site disabled.
    Off,
    /// Sleep for this many milliseconds on every hit.
    Sleep(u64),
    /// Panic with this probability per hit.
    Panic(f64),
    /// (I/O sites only) surface a `ConnectionReset` error with this
    /// probability per hit.
    Reset(f64),
}

/// Parse an `ADAQAT_FAILPOINTS`-style spec: `;`-separated
/// `name=action(arg)` entries. Returns the entries in order (later
/// entries for the same name win when applied to the registry).
pub fn parse_spec(spec: &str) -> Result<Vec<(String, Action)>, String> {
    let mut out = Vec::new();
    for entry in spec.split(';') {
        let entry = entry.trim();
        if entry.is_empty() {
            continue;
        }
        let (name, action) = entry
            .split_once('=')
            .ok_or_else(|| format!("failpoint entry `{entry}` missing `=`"))?;
        let name = name.trim();
        if name.is_empty() {
            return Err(format!("failpoint entry `{entry}` has an empty name"));
        }
        out.push((name.to_string(), parse_action(action.trim())?));
    }
    Ok(out)
}

fn parse_action(s: &str) -> Result<Action, String> {
    if s == "off" {
        return Ok(Action::Off);
    }
    let (kind, rest) = s
        .split_once('(')
        .ok_or_else(|| format!("failpoint action `{s}` is not `off` or `kind(arg)`"))?;
    let arg = rest
        .strip_suffix(')')
        .ok_or_else(|| format!("failpoint action `{s}` missing closing `)`"))?
        .trim();
    match kind.trim() {
        "sleep" => arg
            .parse::<u64>()
            .map(Action::Sleep)
            .map_err(|_| format!("sleep({arg}): want integer milliseconds")),
        "panic" => parse_prob(arg).map(Action::Panic),
        "reset" => parse_prob(arg).map(Action::Reset),
        other => Err(format!("unknown failpoint action `{other}`")),
    }
}

fn parse_prob(arg: &str) -> Result<f64, String> {
    let p = arg
        .parse::<f64>()
        .map_err(|_| format!("`{arg}`: want a probability in [0, 1]"))?;
    if !(0.0..=1.0).contains(&p) {
        return Err(format!("probability {p} outside [0, 1]"));
    }
    Ok(p)
}

#[cfg(feature = "failpoints")]
mod real {
    use super::Action;
    use crate::util::{fnv1a_mix, rng::Rng, FNV1A_BASIS};
    use std::collections::HashMap;
    use std::sync::{Mutex, OnceLock};
    use std::time::Duration;

    struct Site {
        action: Action,
        rng: Rng,
    }

    struct RegistryState {
        sites: HashMap<String, Site>,
        seed: u64,
    }

    fn registry() -> &'static Mutex<RegistryState> {
        static REG: OnceLock<Mutex<RegistryState>> = OnceLock::new();
        REG.get_or_init(|| {
            let seed = std::env::var("ADAQAT_FAILPOINTS_SEED")
                .ok()
                .and_then(|s| s.parse().ok())
                .unwrap_or(0);
            let mut state = RegistryState {
                sites: HashMap::new(),
                seed,
            };
            if let Ok(spec) = std::env::var("ADAQAT_FAILPOINTS") {
                match super::parse_spec(&spec) {
                    Ok(entries) => {
                        for (name, action) in entries {
                            install(&mut state, &name, action);
                        }
                    }
                    Err(e) => panic!("ADAQAT_FAILPOINTS: {e}"),
                }
            }
            Mutex::new(state)
        })
    }

    fn site_seed(seed: u64, name: &str) -> u64 {
        let mut h = FNV1A_BASIS;
        for b in name.bytes() {
            h = fnv1a_mix(h, u64::from(b));
        }
        h ^ seed
    }

    fn install(state: &mut RegistryState, name: &str, action: Action) {
        let rng = Rng::new(site_seed(state.seed, name));
        state
            .sites
            .insert(name.to_string(), Site { action, rng });
    }

    /// Programmatically arm one failpoint (replacing any prior action
    /// and resetting its deterministic RNG).
    pub fn configure(name: &str, action: Action) {
        let mut g = registry().lock().unwrap();
        install(&mut g, name, action);
    }

    /// Disarm every failpoint. Chaos tests call this between scenarios
    /// (the registry is process-global).
    pub fn clear() {
        registry().lock().unwrap().sites.clear();
    }

    /// Execute the action armed at `name`, if any. `Sleep` blocks here;
    /// `Panic` may panic here; `Reset` does nothing at non-I/O sites
    /// (use [`io_error`] where an `io::Error` can be surfaced).
    pub fn hit(name: &str) {
        let action = {
            let mut g = registry().lock().unwrap();
            match g.sites.get_mut(name) {
                Some(site) => match site.action {
                    Action::Panic(p) => {
                        if site.rng.bool(p as f32) {
                            Action::Panic(1.0)
                        } else {
                            Action::Off
                        }
                    }
                    a => a,
                },
                None => Action::Off,
            }
        };
        // act outside the registry lock so a sleep never blocks other sites
        match action {
            Action::Sleep(ms) => std::thread::sleep(Duration::from_millis(ms)),
            Action::Panic(_) => panic!("failpoint `{name}` injected panic"),
            Action::Off | Action::Reset(_) => {}
        }
    }

    /// I/O-site variant: returns `Some(ConnectionReset)` when a
    /// `reset(P)` action fires (and also honors `sleep`/`panic`).
    pub fn io_error(name: &str) -> Option<std::io::Error> {
        let action = {
            let mut g = registry().lock().unwrap();
            match g.sites.get_mut(name) {
                Some(site) => match site.action {
                    Action::Panic(p) | Action::Reset(p) => {
                        let fired = site.rng.bool(p as f32);
                        match (site.action, fired) {
                            (Action::Panic(_), true) => Action::Panic(1.0),
                            (Action::Reset(_), true) => Action::Reset(1.0),
                            _ => Action::Off,
                        }
                    }
                    a => a,
                },
                None => Action::Off,
            }
        };
        match action {
            Action::Sleep(ms) => {
                std::thread::sleep(Duration::from_millis(ms));
                None
            }
            Action::Panic(_) => panic!("failpoint `{name}` injected panic"),
            Action::Reset(_) => Some(std::io::Error::new(
                std::io::ErrorKind::ConnectionReset,
                format!("failpoint `{name}` injected connection reset"),
            )),
            Action::Off => None,
        }
    }
}

#[cfg(feature = "failpoints")]
pub use real::{clear, configure, hit, io_error};

// Feature off (the default): every site is an empty inline function the
// optimizer removes — the serving hot paths carry zero overhead.
#[cfg(not(feature = "failpoints"))]
mod noop {
    use super::Action;

    /// No-op stub (enable the `failpoints` feature for the real one).
    #[inline(always)]
    pub fn configure(_name: &str, _action: Action) {}

    /// No-op stub (enable the `failpoints` feature for the real one).
    #[inline(always)]
    pub fn clear() {}

    /// No-op stub (enable the `failpoints` feature for the real one).
    #[inline(always)]
    pub fn hit(_name: &str) {}

    /// No-op stub (enable the `failpoints` feature for the real one).
    #[inline(always)]
    pub fn io_error(_name: &str) -> Option<std::io::Error> {
        None
    }
}

#[cfg(not(feature = "failpoints"))]
pub use noop::{clear, configure, hit, io_error};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_the_documented_example() {
        let spec = "batcher_stall=sleep(50);worker_panic=panic(0.01)";
        let entries = parse_spec(spec).unwrap();
        assert_eq!(
            entries,
            vec![
                ("batcher_stall".to_string(), Action::Sleep(50)),
                ("worker_panic".to_string(), Action::Panic(0.01)),
            ]
        );
    }

    #[test]
    fn parses_off_reset_and_ignores_empty_entries() {
        let entries = parse_spec(" a=off; ;b=reset(1.0);").unwrap();
        assert_eq!(
            entries,
            vec![
                ("a".to_string(), Action::Off),
                ("b".to_string(), Action::Reset(1.0)),
            ]
        );
    }

    #[test]
    fn rejects_malformed_specs() {
        for bad in [
            "noequals",
            "=sleep(1)",
            "a=sleep(x)",
            "a=sleep(5",
            "a=panic(1.5)",
            "a=reset(-0.1)",
            "a=explode(1)",
            "a=sleep",
        ] {
            assert!(parse_spec(bad).is_err(), "spec `{bad}` should fail");
        }
    }

    #[cfg(not(feature = "failpoints"))]
    #[test]
    fn noop_stubs_do_nothing() {
        configure("x", Action::Panic(1.0));
        hit("x"); // must not panic — the stub ignores configuration
        assert!(io_error("x").is_none());
        clear();
    }

    #[cfg(feature = "failpoints")]
    #[test]
    fn registry_fires_and_clears() {
        clear();
        configure("fp_test_sleep", Action::Sleep(1));
        let t0 = std::time::Instant::now();
        hit("fp_test_sleep");
        assert!(t0.elapsed() >= std::time::Duration::from_millis(1));

        configure("fp_test_reset", Action::Reset(1.0));
        let e = io_error("fp_test_reset").expect("reset(1.0) must fire");
        assert_eq!(e.kind(), std::io::ErrorKind::ConnectionReset);

        configure("fp_test_panic", Action::Panic(1.0));
        let r = std::panic::catch_unwind(|| hit("fp_test_panic"));
        assert!(r.is_err(), "panic(1.0) must panic");

        clear();
        hit("fp_test_panic"); // cleared: must be silent
        assert!(io_error("fp_test_reset").is_none());
    }

    #[cfg(feature = "failpoints")]
    #[test]
    fn probabilistic_sites_are_deterministic_per_name() {
        // same name + same probability → identical fire schedule on
        // reconfigure (the per-site RNG reseeds from the name)
        let schedule = |name: &str| -> Vec<bool> {
            configure(name, Action::Reset(0.5));
            (0..64).map(|_| io_error(name).is_some()).collect()
        };
        let a = schedule("fp_test_det");
        let b = schedule("fp_test_det");
        assert_eq!(a, b);
        assert!(a.iter().any(|&x| x) && a.iter().any(|&x| !x));
        clear();
    }
}

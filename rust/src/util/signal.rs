//! Minimal std-only shutdown-signal latch (DESIGN.md §19).
//!
//! The offline crate universe has no `signal-hook`/`ctrlc`, so `serve`
//! installs a raw `signal(2)` handler that does the only async-signal-
//! safe thing possible: store into a static atomic. The serve loop
//! polls [`requested`] and performs the actual graceful drain (close
//! listener, finish in-flight work, flush metrics) on a normal thread.
//!
//! On non-unix targets [`install`] is a no-op and only the admin
//! `{"cmd":"drain"}` path can trigger a drain.

use std::sync::atomic::{AtomicBool, Ordering};

static SHUTDOWN: AtomicBool = AtomicBool::new(false);

/// True once SIGINT/SIGTERM arrived (or [`raise`] was called).
pub fn requested() -> bool {
    SHUTDOWN.load(Ordering::SeqCst)
}

/// Trip the latch programmatically (tests, and the drain admin path in
/// callers that want one code path for both triggers).
pub fn raise() {
    SHUTDOWN.store(true, Ordering::SeqCst);
}

#[cfg(unix)]
mod unix {
    use super::SHUTDOWN;
    use std::sync::atomic::Ordering;

    const SIGINT: i32 = 2;
    const SIGTERM: i32 = 15;

    extern "C" {
        // POSIX signal(2) from libc, which every unix Rust binary
        // already links. Used instead of sigaction to stay free of
        // libc struct layouts.
        fn signal(signum: i32, handler: usize) -> usize;
    }

    extern "C" fn on_signal(_signum: i32) {
        // Only async-signal-safe operation here: a relaxed-or-stronger
        // atomic store. No allocation, no locks, no I/O.
        SHUTDOWN.store(true, Ordering::SeqCst);
    }

    pub fn install() {
        // SAFETY: `signal` is the POSIX C function; passing a valid
        // signal number and a function pointer with the required
        // `extern "C" fn(i32)` ABI (cast to the handler word) is its
        // documented contract. The handler itself only performs an
        // atomic store, which is async-signal-safe.
        unsafe {
            signal(SIGINT, on_signal as usize);
            signal(SIGTERM, on_signal as usize);
        }
    }
}

/// Install SIGINT/SIGTERM handlers that trip the latch. Idempotent.
#[cfg(unix)]
pub fn install() {
    unix::install();
}

/// Non-unix: no signal handling; drain is admin-command only.
#[cfg(not(unix))]
pub fn install() {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn raise_trips_the_latch() {
        install();
        raise();
        assert!(requested());
    }
}

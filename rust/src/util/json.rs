//! Minimal JSON parser/emitter.
//!
//! `serde`/`serde_json` are unavailable in this offline environment
//! (DESIGN.md §3), so the manifest and run-metadata plumbing is built on
//! this hand-rolled implementation. It supports the full JSON grammar
//! minus exotic number forms (`1e999`), which the AOT manifest never
//! emits. Round-tripping is covered by unit + property tests.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Objects preserve no insertion order (BTreeMap) — the
/// manifest relies on explicit arrays wherever ordering matters.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ------------------------------------------------------------ access
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `obj["a"]["b"]` style access that errors with the full path.
    pub fn at(&self, path: &[&str]) -> Result<&Json, JsonError> {
        let mut cur = self;
        for (i, key) in path.iter().enumerate() {
            cur = cur.get(key).ok_or_else(|| {
                JsonError::Access(format!("missing key {:?}", &path[..=i]))
            })?;
        }
        Ok(cur)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    // ------------------------------------------------------------- build
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    // ------------------------------------------------------------- parse
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0, depth: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // -------------------------------------------------------------- emit
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, e) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    e.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

#[derive(Debug)]
pub enum JsonError {
    /// Parse failure at a byte offset.
    Parse(usize, String),
    /// Path lookup failure (see [`Json::at`]).
    Access(String),
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            JsonError::Parse(pos, msg) => {
                write!(f, "json parse error at byte {pos}: {msg}")
            }
            JsonError::Access(msg) => write!(f, "json access error: {msg}"),
        }
    }
}

impl std::error::Error for JsonError {}

/// Recursion cap for arrays/objects. The parser is fed untrusted TCP
/// input by the serve front end (DESIGN.md §7), so unbounded nesting
/// must be a parse error, not a thread-stack overflow (which aborts the
/// whole process).
const MAX_DEPTH: usize = 128;

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
    depth: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError::Parse(self.pos, msg.to_string())
    }

    fn enter(&mut self) -> Result<(), JsonError> {
        self.depth += 1;
        if self.depth > MAX_DEPTH {
            return Err(self.err(&format!("nesting deeper than {MAX_DEPTH}")));
        }
        Ok(())
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", b as char)))
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected `{word}`")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek().ok_or_else(|| self.err("eof"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.literal("true", Json::Bool(true)),
            b'f' => self.literal("false", Json::Bool(false)),
            b'n' => self.literal("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => Err(self.err(&format!("unexpected byte {:?}", c as char))),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.enter()?;
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            self.depth -= 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    self.depth -= 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected `,` or `}`")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.enter()?;
        self.expect(b'[')?;
        let mut v = vec![];
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            self.depth -= 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    self.depth -= 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected `,` or `]`")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek().ok_or_else(|| self.err("eof in string"))? {
                b'"' => {
                    self.pos += 1;
                    return Ok(s);
                }
                b'\\' => {
                    self.pos += 1;
                    let esc = self.peek().ok_or_else(|| self.err("eof"))?;
                    self.pos += 1;
                    match esc {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            let hex = self
                                .bytes
                                .get(self.pos..self.pos + 4)
                                .ok_or_else(|| self.err("bad \\u"))?;
                            let hex = std::str::from_utf8(hex)
                                .map_err(|_| self.err("bad \\u"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u"))?;
                            self.pos += 4;
                            // Surrogate pairs are not needed by the manifest;
                            // map unpaired surrogates to U+FFFD.
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                }
                _ => {
                    // copy a run of plain bytes (UTF-8 passes through)
                    let start = self.pos;
                    while let Some(b) = self.peek() {
                        if b == b'"' || b == b'\\' {
                            break;
                        }
                        self.pos += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.bytes[start..self.pos])
                            .map_err(|_| self.err("invalid utf8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        match text.parse::<f64>() {
            // Overflowing forms like `1e999` parse to ±inf in Rust; the
            // module contract excludes them (they cannot round-trip), so
            // reject anything non-finite explicitly.
            Ok(v) if v.is_finite() => Ok(Json::Num(v)),
            _ => Err(self.err(&format!("bad number `{text}`"))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::str("a\nb"));
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, {"b": false}], "c": "x"}"#).unwrap();
        assert_eq!(j.at(&["a"]).unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(
            j.at(&["a"]).unwrap().as_arr().unwrap()[1]
                .get("b")
                .unwrap()
                .as_bool(),
            Some(false)
        );
        assert_eq!(j.get("c").unwrap().as_str(), Some("x"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"m":{"x":[1,2.5,-3],"s":"q\"z","n":null,"t":true}}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn emits_integers_without_fraction() {
        assert_eq!(Json::num(3.0).to_string(), "3");
        assert_eq!(Json::num(3.5).to_string(), "3.5");
    }

    #[test]
    fn access_error_paths() {
        let j = Json::parse(r#"{"a":{"b":1}}"#).unwrap();
        assert!(j.at(&["a", "b"]).is_ok());
        let e = j.at(&["a", "z"]).unwrap_err();
        assert!(format!("{e}").contains("z"));
    }

    // ------------------------------------------------- wire-protocol edges
    // (the serve front end speaks NDJSON built on this module, so the
    // grammar corners below are load-bearing — DESIGN.md §7)

    #[test]
    fn escape_sequences_decode_and_reencode() {
        assert_eq!(Json::parse(r#""Aé""#).unwrap(), Json::str("Aé"));
        assert_eq!(Json::parse(r#""\b\f\/""#).unwrap(), Json::str("\u{8}\u{c}/"));
        // unpaired surrogate maps to U+FFFD rather than corrupting the string
        assert_eq!(Json::parse(r#""\ud800""#).unwrap(), Json::str("\u{fffd}"));
        // control characters are re-emitted as \u escapes
        assert_eq!(Json::str("a\u{1}b").to_string(), "\"a\\u0001b\"");
        let s = Json::str("tab\t nl\n q\" bs\\ bell\u{7}");
        assert_eq!(Json::parse(&s.to_string()).unwrap(), s);
        // malformed escapes are errors, not panics
        assert!(Json::parse(r#""\x""#).is_err());
        assert!(Json::parse(r#""\u12""#).is_err());
        assert!(Json::parse(r#""\u12zz""#).is_err());
    }

    #[test]
    fn non_ascii_passthrough() {
        let s = Json::str("λ=0.15 · 重み 4bit ✓");
        assert_eq!(Json::parse(&s.to_string()).unwrap(), s);
    }

    #[test]
    fn deeply_nested_arrays_roundtrip() {
        let depth = 100;
        let src = format!("{}1{}", "[".repeat(depth), "]".repeat(depth));
        let j = Json::parse(&src).unwrap();
        assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
        let mut cur = &j;
        for _ in 0..depth {
            cur = &cur.as_arr().unwrap()[0];
        }
        assert_eq!(cur.as_f64(), Some(1.0));
        // unbalanced nesting is an error at every depth
        assert!(Json::parse(&format!("{}1{}", "[".repeat(4), "]".repeat(3))).is_err());
    }

    #[test]
    fn nesting_past_the_cap_is_an_error_not_a_stack_overflow() {
        // the serve front end feeds this parser raw TCP lines; a
        // 200k-bracket bomb must fail cleanly (DESIGN.md §7)
        for depth in [129usize, 10_000, 200_000] {
            let src = format!("{}1{}", "[".repeat(depth), "]".repeat(depth));
            let e = Json::parse(&src).unwrap_err();
            assert!(format!("{e}").contains("nesting"), "depth {depth}: {e}");
        }
        // exactly at the cap still parses
        let src = format!("{}1{}", "[".repeat(128), "]".repeat(128));
        assert!(Json::parse(&src).is_ok());
    }

    #[test]
    fn number_boundary_forms() {
        assert_eq!(Json::parse("1e308").unwrap().as_f64(), Some(1e308));
        assert_eq!(Json::parse("-1.5E-7").unwrap().as_f64(), Some(-1.5e-7));
        assert_eq!(Json::parse("2.5e+2").unwrap().as_f64(), Some(250.0));
        assert_eq!(Json::parse("-0").unwrap().as_f64(), Some(0.0));
        // overflow / non-finite forms are rejected, per the module contract
        assert!(Json::parse("1e999").is_err());
        assert!(Json::parse("-1e999").is_err());
        // malformed digit soup is rejected (the scanner is permissive,
        // the f64 parse is not)
        assert!(Json::parse("1.2.3").is_err());
        assert!(Json::parse("--1").is_err());
        assert!(Json::parse("1e").is_err());
        // large-magnitude integers fall back to float emission
        let j = Json::num(1e16);
        assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
    }
}

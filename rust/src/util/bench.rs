//! Bench-harness support (offline substitute for `criterion`).
//!
//! The `rust/benches/*` targets are `harness = false` binaries; this
//! module gives them shared timing statistics and argument handling
//! (cargo appends `--bench` to bench binaries — it is filtered here).

use std::time::Instant;

use super::cli::Args;

/// Parse bench CLI args, dropping the flags cargo's test harness adds.
pub fn bench_args() -> Args {
    let argv: Vec<String> = std::env::args()
        .skip(1)
        .filter(|a| a != "--bench" && a != "--test" && a != "--nocapture")
        .collect();
    Args::parse(argv).expect("bench args")
}

/// Simple latency statistics over repeated runs.
#[derive(Debug, Clone, Copy)]
pub struct Stats {
    pub iters: usize,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub min_ms: f64,
    pub max_ms: f64,
}

impl Stats {
    pub fn row(&self, name: &str) -> String {
        format!(
            "{name:<34} n={:<4} mean {:>9.2} ms  p50 {:>9.2}  p95 {:>9.2}  min {:>9.2}  max {:>9.2}",
            self.iters, self.mean_ms, self.p50_ms, self.p95_ms, self.min_ms, self.max_ms
        )
    }
}

/// Run `f` `warmup` times untimed, then `iters` timed; return stats.
pub fn measure<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Stats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    samples.sort_by(f64::total_cmp);
    let n = samples.len();
    Stats {
        iters: n,
        mean_ms: samples.iter().sum::<f64>() / n as f64,
        p50_ms: samples[n / 2],
        p95_ms: samples[(n as f64 * 0.95) as usize % n],
        min_ms: samples[0],
        max_ms: samples[n - 1],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_orders_percentiles() {
        let mut i = 0u64;
        let s = measure(2, 20, || {
            i += 1;
            std::thread::sleep(std::time::Duration::from_micros(100 + (i % 3) * 50));
        });
        assert_eq!(s.iters, 20);
        assert!(s.min_ms <= s.p50_ms && s.p50_ms <= s.p95_ms && s.p95_ms <= s.max_ms);
        assert!(s.mean_ms > 0.05);
    }
}

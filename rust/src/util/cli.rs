//! Minimal CLI argument parser (offline substitute for `clap`).
//!
//! Supports `--key value`, `--key=value`, bare `--flag`, and positional
//! arguments, with typed getters and an auto-generated usage string.

use std::collections::BTreeMap;

#[derive(Debug, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
    /// (name, help, default) — registered by the caller for `usage()`.
    specs: Vec<(String, String, String)>,
}

impl Args {
    /// Parse from an iterator of argument strings (after argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Args, String> {
        let mut positional = vec![];
        let mut flags = BTreeMap::new();
        let mut it = argv.into_iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(body) = arg.strip_prefix("--") {
                if body.is_empty() {
                    // `--` terminator: everything after is positional
                    positional.extend(it.by_ref());
                    break;
                }
                if let Some((k, v)) = body.split_once('=') {
                    flags.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    flags.insert(body.to_string(), it.next().unwrap());
                } else {
                    flags.insert(body.to_string(), "true".to_string());
                }
            } else {
                positional.push(arg);
            }
        }
        Ok(Args { positional, flags, specs: vec![] })
    }

    pub fn from_env() -> Result<Args, String> {
        Args::parse(std::env::args().skip(1))
    }

    /// Register a flag for the usage string (purely documentary).
    pub fn describe(&mut self, name: &str, help: &str, default: &str) -> &mut Self {
        self.specs.push((name.into(), help.into(), default.into()));
        self
    }

    pub fn usage(&self, program: &str, about: &str) -> String {
        let mut s = format!("{program} — {about}\n\nOptions:\n");
        for (name, help, default) in &self.specs {
            s.push_str(&format!("  --{name:<22} {help} [default: {default}]\n"));
        }
        s
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    pub fn get_str(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    pub fn get<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T, String> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("--{key}: cannot parse {v:?}")),
        }
    }

    /// Unknown-flag check: every provided flag must be in `known`.
    pub fn reject_unknown(&self, known: &[&str]) -> Result<(), String> {
        for k in self.flags.keys() {
            if !known.contains(&k.as_str()) {
                return Err(format!(
                    "unknown flag --{k} (known: {})",
                    known.join(", ")
                ));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn parses_forms() {
        let a = parse("train --epochs 5 --lr=0.1 --verbose --out dir");
        assert_eq!(a.positional, vec!["train"]);
        assert_eq!(a.get::<usize>("epochs", 0).unwrap(), 5);
        assert_eq!(a.get::<f64>("lr", 0.0).unwrap(), 0.1);
        assert!(a.has("verbose"));
        assert_eq!(a.get_str("out", ""), "dir");
    }

    #[test]
    fn defaults_apply() {
        let a = parse("x");
        assert_eq!(a.get::<usize>("missing", 7).unwrap(), 7);
        assert_eq!(a.get_str("missing", "d"), "d");
    }

    #[test]
    fn type_errors_reported() {
        let a = parse("--epochs abc");
        assert!(a.get::<usize>("epochs", 0).is_err());
    }

    #[test]
    fn double_dash_terminator() {
        let a = parse("--a 1 -- --b 2");
        assert_eq!(a.positional, vec!["--b", "2"]);
        assert!(!a.has("b"));
    }

    #[test]
    fn unknown_flags_rejected() {
        let a = parse("--lr 0.1 --typo 3");
        assert!(a.reject_unknown(&["lr"]).is_err());
        assert!(a.reject_unknown(&["lr", "typo"]).is_ok());
    }
}

//! Substrate utilities built in-repo because the offline crate universe
//! lacks `serde`/`clap`/`proptest` (DESIGN.md §3): JSON, CLI parsing,
//! deterministic RNG, property testing, and a `log` backend.

pub mod bench;
pub mod cli;
pub mod failpoint;
pub mod json;
pub mod logger;
pub mod prop;
pub mod rng;
pub mod signal;

/// FNV-1a offset basis — pair with [`fnv1a_mix`].
pub const FNV1A_BASIS: u64 = 0xcbf2_9ce4_8422_2325;

/// One FNV-1a accumulation step. The single home of the constants for
/// every in-repo content fingerprint (eval memo keys, pretrain cache
/// geometry tags) — not a cryptographic hash.
pub fn fnv1a_mix(h: u64, x: u64) -> u64 {
    (h ^ x).wrapping_mul(0x100_0000_01b3)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv1a_reference_vector() {
        // FNV-1a over the bytes "a", "b", "c" fed as u64s must be
        // order-sensitive and nonzero (guards constant typos)
        let h1 = fnv1a_mix(fnv1a_mix(FNV1A_BASIS, 97), 98);
        let h2 = fnv1a_mix(fnv1a_mix(FNV1A_BASIS, 98), 97);
        assert_ne!(h1, h2);
        // byte-at-a-time FNV-1a of "a" is the published test vector
        assert_eq!(fnv1a_mix(FNV1A_BASIS, 97), 0xaf63dc4c8601ec8c);
    }
}

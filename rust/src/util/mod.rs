//! Substrate utilities built in-repo because the offline crate universe
//! lacks `serde`/`clap`/`proptest` (DESIGN.md §3): JSON, CLI parsing,
//! deterministic RNG, property testing, and a `log` backend.

pub mod bench;
pub mod cli;
pub mod json;
pub mod logger;
pub mod prop;
pub mod rng;

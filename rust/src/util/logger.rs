//! Tiny `log` backend: stderr with elapsed-time stamps.
//!
//! Level comes from `ADAQAT_LOG` (error|warn|info|debug|trace), default
//! `info`. Installed once by `init()`; safe to call repeatedly.

use std::sync::OnceLock;
use std::time::Instant;

struct StderrLogger {
    start: Instant,
    level: log::LevelFilter,
}

impl log::Log for StderrLogger {
    fn enabled(&self, metadata: &log::Metadata) -> bool {
        metadata.level() <= self.level
    }

    fn log(&self, record: &log::Record) {
        if self.enabled(record.metadata()) {
            let t = self.start.elapsed().as_secs_f64();
            eprintln!("[{t:9.3}s {:5}] {}", record.level(), record.args());
        }
    }

    fn flush(&self) {}
}

static LOGGER: OnceLock<StderrLogger> = OnceLock::new();

pub fn init() {
    let level = match std::env::var("ADAQAT_LOG").as_deref() {
        Ok("error") => log::LevelFilter::Error,
        Ok("warn") => log::LevelFilter::Warn,
        Ok("debug") => log::LevelFilter::Debug,
        Ok("trace") => log::LevelFilter::Trace,
        _ => log::LevelFilter::Info,
    };
    let logger = LOGGER.get_or_init(|| StderrLogger { start: Instant::now(), level });
    // Err means a logger is already set (e.g. repeated init in tests) — fine.
    let _ = log::set_logger(logger);
    log::set_max_level(level);
}

//! Tiny `log` backend: stderr with elapsed-time stamps.
//!
//! Level comes from `ADAQAT_LOG` (error|warn|info|debug|trace, any
//! case), default `info`. An unrecognized value falls back to `info`
//! *and says so* — once, on the first `init()` — instead of silently
//! swallowing a typo like `ADAQAT_LOG=verbose`. Installed once by
//! `init()`; safe to call repeatedly.

use std::sync::OnceLock;
use std::time::Instant;

struct StderrLogger {
    start: Instant,
    level: log::LevelFilter,
}

impl log::Log for StderrLogger {
    fn enabled(&self, metadata: &log::Metadata) -> bool {
        metadata.level() <= self.level
    }

    fn log(&self, record: &log::Record) {
        if self.enabled(record.metadata()) {
            let t = self.start.elapsed().as_secs_f64();
            eprintln!("[{t:9.3}s {:5}] {}", record.level(), record.args());
        }
    }

    fn flush(&self) {}
}

static LOGGER: OnceLock<StderrLogger> = OnceLock::new();

/// Parse one `ADAQAT_LOG` value, case-insensitively. `None` means the
/// value is unrecognized (the caller decides the fallback and warns).
pub fn parse_level(s: &str) -> Option<log::LevelFilter> {
    match s.to_ascii_lowercase().as_str() {
        "error" => Some(log::LevelFilter::Error),
        "warn" => Some(log::LevelFilter::Warn),
        "info" => Some(log::LevelFilter::Info),
        "debug" => Some(log::LevelFilter::Debug),
        "trace" => Some(log::LevelFilter::Trace),
        _ => None,
    }
}

pub fn init() {
    let raw = std::env::var("ADAQAT_LOG").ok();
    let (level, unrecognized) = match raw.as_deref() {
        None => (log::LevelFilter::Info, None),
        Some(v) => match parse_level(v) {
            Some(l) => (l, None),
            None => (log::LevelFilter::Info, Some(v.to_string())),
        },
    };
    let first = LOGGER.get().is_none();
    let logger = LOGGER.get_or_init(|| StderrLogger { start: Instant::now(), level });
    // Err means a logger is already set (e.g. repeated init in tests) — fine.
    let _ = log::set_logger(logger);
    // the *installed* logger's level, not this call's: a repeated init
    // must not silently re-raise the max level past the filter the
    // first install decided on
    log::set_max_level(logger.level);
    if first {
        if let Some(bad) = unrecognized {
            log::warn!(
                "ADAQAT_LOG: unrecognized level {bad:?} \
                 (expected error|warn|info|debug|trace) — using info"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_parse_case_insensitively() {
        assert_eq!(parse_level("error"), Some(log::LevelFilter::Error));
        assert_eq!(parse_level("WARN"), Some(log::LevelFilter::Warn));
        assert_eq!(parse_level("Info"), Some(log::LevelFilter::Info));
        assert_eq!(parse_level("DeBuG"), Some(log::LevelFilter::Debug));
        assert_eq!(parse_level("trace"), Some(log::LevelFilter::Trace));
    }

    #[test]
    fn unknown_levels_are_reported_not_absorbed() {
        // the old match had no "info" arm and a catch-all `_ => Info`,
        // so "info", "verbose", and "wran" were indistinguishable —
        // parse_level makes the unknowns visible to init()'s warning
        assert_eq!(parse_level("verbose"), None);
        assert_eq!(parse_level("wran"), None);
        assert_eq!(parse_level(""), None);
        assert_eq!(parse_level("info "), None, "no trimming — exact tokens only");
    }
}

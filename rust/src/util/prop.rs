//! Property-testing mini-framework (offline substitute for `proptest`).
//!
//! `check(cases, seed, f)` runs `f` against `cases` forked RNG streams
//! and reports the failing case index + seed so failures reproduce
//! exactly. Coordinator invariants (oscillation counting, freeze rules,
//! batching, cost-model monotonicity) are verified with this.

use super::rng::Rng;

/// Run `f` on `cases` independent random streams; panic with a
/// reproducible diagnostic on the first failure.
pub fn check<F>(cases: usize, seed: u64, f: F)
where
    F: Fn(&mut Rng) -> Result<(), String>,
{
    let base = Rng::new(seed);
    for case in 0..cases {
        let mut rng = base.fork(case as u64);
        if let Err(msg) = f(&mut rng) {
            panic!(
                "property failed at case {case}/{cases} (seed {seed}): {msg}"
            );
        }
    }
}

/// Convenience: assert with a formatted message inside property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        check(50, 1, |rng| {
            let x = rng.uniform();
            prop_assert!((0.0..1.0).contains(&x), "x out of range: {x}");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn reports_failure() {
        check(50, 2, |rng| {
            let x = rng.uniform();
            prop_assert!(x < 0.5, "x too big: {x}");
            Ok(())
        });
    }
}

//! Deterministic RNG substrate: SplitMix64 core + Box-Muller normals.
//!
//! Used everywhere randomness is needed at runtime — parameter init
//! (Kaiming), the synthetic dataset generators, shuffling, augmentation —
//! so every run is exactly reproducible from its seed. Python is *not*
//! involved in initialization (DESIGN.md §6); this RNG is the single
//! source of randomness in the system.

/// SplitMix64: tiny, fast, passes BigCrush for our purposes, and —
/// crucially — trivially seedable/forkable for per-sample determinism.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng { state: seed.wrapping_add(0x9E37_79B9_7F4A_7C15) }
    }

    /// Derive an independent stream (e.g. one per dataset sample index).
    pub fn fork(&self, stream: u64) -> Rng {
        let mut r = Rng::new(self.state ^ stream.wrapping_mul(0xBF58_476D_1CE4_E5B9));
        r.next_u64(); // decorrelate
        r
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }

    /// Uniform in [lo, hi).
    pub fn range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n) — exactly uniform for every `n`, via
    /// Lemire's multiply-shift rejection (the plain `next_u64() % n`
    /// this replaces over-weights small residues; negligible for tiny
    /// `n`, but a shuffle/augmentation substrate should be unbiased by
    /// construction, and near large power-of-two boundaries the modulo
    /// bias is gross — see `below_unbiased_near_power_of_two_boundary`).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        loop {
            let x = self.next_u64();
            let m = (x as u128) * (n as u128);
            let lo = m as u64;
            if lo < n {
                // threshold = 2^64 mod n; draws with lo below it sit in
                // the truncated final stripe and must be rejected
                let threshold = n.wrapping_neg() % n;
                if lo < threshold {
                    continue;
                }
            }
            return (m >> 64) as usize;
        }
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f32 {
        // Guard u1 away from 0 so ln() stays finite.
        let u1 = self.uniform().max(1e-7);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos()
    }

    pub fn bool(&mut self, p: f32) -> bool {
        self.uniform() < p
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn fork_streams_are_independent() {
        let base = Rng::new(7);
        let mut a = base.fork(0);
        let mut b = base.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn uniform_in_range_and_roughly_uniform() {
        let mut r = Rng::new(3);
        let mut sum = 0.0f64;
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u as f64;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let xs: Vec<f32> = (0..20_000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / xs.len() as f32;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f32>()
            / xs.len() as f32;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
        assert!(xs.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(9);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
        }
        // n = 1 must not loop or panic
        assert_eq!(r.below(1), 0);
    }

    #[test]
    #[cfg(target_pointer_width = "64")]
    fn below_unbiased_near_power_of_two_boundary() {
        // n = 3·2^62: modulo reduction would map two full u64 stripes
        // onto [0, n/3) and only one onto the rest, so P(x < n/3) would
        // be 1/2. Unbiased sampling gives 1/3 — a ~20σ separation at
        // this sample count, so the test cannot pass by luck.
        let n: usize = 3usize << 62;
        let mut r = Rng::new(123);
        let draws = 5000usize;
        let lo_third = (0..draws).filter(|_| r.below(n) < n / 3).count();
        let frac = lo_third as f64 / draws as f64;
        assert!(
            (frac - 1.0 / 3.0).abs() < 0.035,
            "P(x < n/3) = {frac}, want 1/3 (modulo bias gives 1/2)"
        );
    }

    #[test]
    fn below_small_n_roughly_uniform() {
        // chi-square sanity at a small n (this also held pre-Lemire;
        // it pins the new path's uniformity, not just its bounds)
        let mut r = Rng::new(77);
        let mut counts = [0usize; 7];
        let draws = 70_000;
        for _ in 0..draws {
            counts[r.below(7)] += 1;
        }
        let expect = draws as f64 / 7.0;
        let chi2: f64 = counts
            .iter()
            .map(|&c| (c as f64 - expect).powi(2) / expect)
            .sum();
        // df = 6; P(chi2 > 22.5) < 0.001
        assert!(chi2 < 22.5, "chi2 {chi2}, counts {counts:?}");
    }
}

//! Synthetic in-Rust manifest for the native MLP backend.
//!
//! The PJRT path gets its [`ModelManifest`] from `python/compile/aot.py`
//! via `manifest.json`; the native backend builds the same structure
//! directly from a (batch, image size, hidden widths) description, so
//! the rest of the system — trainer, cost model, checkpointing, export —
//! consumes one contract regardless of backend and no Python is
//! involved anywhere on the native path.

use std::collections::BTreeMap;

use crate::runtime::manifest::{LayerGeom, ModelManifest, ParamSpec};

/// Manifest key every native MLP reports (there is no artifact set to
/// look it up in, so the key only has to be stable and recognizable).
pub const NATIVE_MODEL_KEY: &str = "native-mlp";

/// Build the manifest for a fully-connected ReLU stack over flattened
/// `hw × hw × in_channels` images: layer i maps `dims[i] → dims[i+1]`
/// with `dims = [hw²·c, hidden..., classes]`. Layers are named
/// `fc1..fcN` — the `mlp_layers` convention the serving subsystem's
/// [`crate::kernels::QuantMlp`] loads — with `.w`/`.b` tensors in
/// `[d_in, d_out]` / `[d_out]` layout, Kaiming/zeros init, fc roles.
///
/// No layer is pinned at 8 bits (`fixed8 = false` everywhere): the MLP
/// has no conv stem, and keeping every layer on the learned k_w makes
/// WCR/BitOPs exact functions of the controller's output.
pub fn native_manifest(
    batch: usize,
    hw: usize,
    in_channels: usize,
    classes: usize,
    hidden: &[usize],
) -> Result<ModelManifest, String> {
    if batch == 0 {
        return Err("native manifest: batch must be >= 1".into());
    }
    if hw == 0 || in_channels == 0 || classes < 2 {
        return Err("native manifest: need hw >= 1, channels >= 1, classes >= 2".into());
    }
    let mut dims = vec![hw * hw * in_channels];
    dims.extend_from_slice(hidden);
    dims.push(classes);
    if dims.iter().any(|&d| d == 0) {
        return Err("native manifest: zero-width layer".into());
    }

    let mut params = vec![];
    let mut geoms = vec![];
    for (i, pair) in dims.windows(2).enumerate() {
        let (d_in, d_out) = (pair[0], pair[1]);
        let name = format!("fc{}", i + 1);
        params.push(ParamSpec {
            name: format!("{name}.w"),
            shape: vec![d_in, d_out],
            init: format!("kaiming:{d_in}"),
            role: "fc_w".to_string(),
        });
        params.push(ParamSpec {
            name: format!("{name}.b"),
            shape: vec![d_out],
            init: "zeros".to_string(),
            role: "fc_b".to_string(),
        });
        geoms.push(LayerGeom {
            name,
            kind: "fc".to_string(),
            weight_count: d_in * d_out,
            macs: d_in * d_out,
            fixed8: false,
        });
    }

    Ok(ModelManifest {
        key: NATIVE_MODEL_KEY.to_string(),
        batch,
        input_hw: (hw, hw),
        in_channels,
        num_classes: classes,
        params,
        bn: vec![],
        geoms,
        // no AOT artifacts: every graph this model needs is native Rust
        artifacts: BTreeMap::new(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_names_and_geometry_line_up() {
        let mm = native_manifest(16, 16, 3, 10, &[32]).unwrap();
        assert_eq!(mm.key, NATIVE_MODEL_KEY);
        assert_eq!(mm.batch, 16);
        assert_eq!(mm.input_numel(), 16 * 16 * 16 * 3);
        let names: Vec<&str> = mm.params.iter().map(|p| p.name.as_str()).collect();
        assert_eq!(names, vec!["fc1.w", "fc1.b", "fc2.w", "fc2.b"]);
        assert_eq!(mm.params[0].shape, vec![768, 32]);
        assert_eq!(mm.params[2].shape, vec![32, 10]);
        assert_eq!(mm.weight_count(), 768 * 32 + 32 * 10);
        assert_eq!(mm.geoms.len(), 2);
        assert!(mm.bn.is_empty() && mm.artifacts.is_empty());
    }

    #[test]
    fn no_hidden_layer_is_a_single_fc(){
        let mm = native_manifest(4, 8, 3, 10, &[]).unwrap();
        assert_eq!(mm.params.len(), 2);
        assert_eq!(mm.params[0].shape, vec![8 * 8 * 3, 10]);
    }

    #[test]
    fn rejects_degenerate_shapes() {
        assert!(native_manifest(0, 16, 3, 10, &[32]).is_err());
        assert!(native_manifest(4, 0, 3, 10, &[32]).is_err());
        assert!(native_manifest(4, 16, 3, 1, &[32]).is_err());
        assert!(native_manifest(4, 16, 3, 10, &[0]).is_err());
    }
}

//! Synthetic in-Rust manifests for the native backends (MLP, smallcnn,
//! resnet20-class).
//!
//! The PJRT path gets its [`ModelManifest`] from `python/compile/aot.py`
//! via `manifest.json`; the native backends build the same structure
//! directly from a (batch, image size, layer widths) description, so
//! the rest of the system — trainer, cost model, checkpointing, export —
//! consumes one contract regardless of backend and no Python is
//! involved anywhere on the native path.

use std::collections::BTreeMap;

use crate::runtime::manifest::{BnSpec, LayerGeom, ModelManifest, ParamSpec};

/// Manifest key every native MLP reports (there is no artifact set to
/// look it up in, so the key only has to be stable and recognizable).
pub const NATIVE_MODEL_KEY: &str = "native-mlp";

/// Manifest key of the native conv model. Deliberately NOT "smallcnn":
/// that key names the PJRT artifact model, and an exported checkpoint
/// carrying it would resolve the *compiled* manifest's parameter roles
/// on an artifact-bearing box — matching none of the conv1.w/… names
/// and silently packing every tensor raw. `config_from` maps the
/// user-facing `--model smallcnn --backend native` onto this key.
pub const NATIVE_SMALLCNN_KEY: &str = "native-smallcnn";

/// Whether a model key selects the native conv backend (vs the MLP).
pub fn is_native_conv_model(model: &str) -> bool {
    model == "smallcnn" || model == NATIVE_SMALLCNN_KEY
}

/// Manifest key of the native residual model — distinct from the PJRT
/// "resnet20" key for the same reason [`NATIVE_SMALLCNN_KEY`] is
/// distinct from "smallcnn": an exported checkpoint carrying the PJRT
/// key would resolve the compiled manifest's parameter roles on an
/// artifact-bearing box, match none of the stem/res…/fc1 names, and
/// silently pack every tensor raw. `config_from` maps the user-facing
/// `--model resnet20 --backend native` onto this key.
pub const NATIVE_RESNET_KEY: &str = "native-resnet20";

/// Whether a model key selects the native residual backend. Only
/// consulted when the backend is already "native" — the bare
/// "resnet20" spelling still names the PJRT artifact model elsewhere.
pub fn is_native_resnet_model(model: &str) -> bool {
    model == "resnet20" || model == NATIVE_RESNET_KEY
}

/// The smallcnn architecture's geometric contract, shared by the
/// manifest builder and `ExperimentConfig::validate` so the CLI and
/// the backend can never drift apart: at least one non-zero conv
/// width, and an image side divisible by 2^blocks (each block ends in
/// a 2×2 pool).
pub fn validate_smallcnn_geometry(hw: usize, channels: &[usize]) -> Result<(), String> {
    if channels.is_empty() || channels.contains(&0) {
        return Err("native smallcnn: need at least one non-zero conv width".into());
    }
    if channels.len() >= usize::BITS as usize || hw % (1usize << channels.len()) != 0 {
        return Err(format!(
            "native smallcnn: image_hw {hw} must be divisible by 2^{} (one 2x2 pool per block)",
            channels.len()
        ));
    }
    Ok(())
}

/// Build the manifest for a fully-connected ReLU stack over flattened
/// `hw × hw × in_channels` images: layer i maps `dims[i] → dims[i+1]`
/// with `dims = [hw²·c, hidden..., classes]`. Layers are named
/// `fc1..fcN` — the `mlp_layers` convention the serving subsystem's
/// [`crate::kernels::QuantMlp`] loads — with `.w`/`.b` tensors in
/// `[d_in, d_out]` / `[d_out]` layout, Kaiming/zeros init, fc roles.
///
/// No layer is pinned at 8 bits (`fixed8 = false` everywhere): the MLP
/// has no conv stem, and keeping every layer on the learned k_w makes
/// WCR/BitOPs exact functions of the controller's output.
pub fn native_manifest(
    batch: usize,
    hw: usize,
    in_channels: usize,
    classes: usize,
    hidden: &[usize],
) -> Result<ModelManifest, String> {
    if batch == 0 {
        return Err("native manifest: batch must be >= 1".into());
    }
    if hw == 0 || in_channels == 0 || classes < 2 {
        return Err("native manifest: need hw >= 1, channels >= 1, classes >= 2".into());
    }
    let mut dims = vec![hw * hw * in_channels];
    dims.extend_from_slice(hidden);
    dims.push(classes);
    if dims.iter().any(|&d| d == 0) {
        return Err("native manifest: zero-width layer".into());
    }

    let mut params = vec![];
    let mut geoms = vec![];
    for (i, pair) in dims.windows(2).enumerate() {
        let (d_in, d_out) = (pair[0], pair[1]);
        let name = format!("fc{}", i + 1);
        params.push(ParamSpec {
            name: format!("{name}.w"),
            shape: vec![d_in, d_out],
            init: format!("kaiming:{d_in}"),
            role: "fc_w".to_string(),
        });
        params.push(ParamSpec {
            name: format!("{name}.b"),
            shape: vec![d_out],
            init: "zeros".to_string(),
            role: "fc_b".to_string(),
        });
        geoms.push(LayerGeom {
            name,
            kind: "fc".to_string(),
            weight_count: d_in * d_out,
            macs: d_in * d_out,
            fixed8: false,
        });
    }

    Ok(ModelManifest {
        key: NATIVE_MODEL_KEY.to_string(),
        batch,
        input_hw: (hw, hw),
        in_channels,
        num_classes: classes,
        params,
        bn: vec![],
        geoms,
        // no AOT artifacts: every graph this model needs is native Rust
        artifacts: BTreeMap::new(),
    })
}

/// Build the manifest for the native smallcnn: `channels.len()` blocks
/// of [3×3 conv (stride 1, "same" pad, no bias) → BN → ReLU → 2×2 avg
/// pool] over `hw × hw × in_channels` NHWC images, flattened into a
/// single fc head. Per block i the parameters are `conv{i}.w`
/// (`[3, 3, c_in, c_out]`, Kaiming over the 9·c_in fan-in), `conv{i}.bn.g`
/// (ones) and `conv{i}.bn.b` (zeros); the running statistics
/// `conv{i}.bn.mean`/`conv{i}.bn.var` live in the manifest's `bn` list —
/// exactly the tensor set [`crate::kernels::conv::QuantConvNet`] loads.
/// The head is `fc1.w`/`fc1.b` over the `hw/2^n`-pooled features.
///
/// Like the MLP manifest, no layer is pinned at 8 bits: WCR/BitOPs stay
/// exact functions of the controller's output. MACs count each conv at
/// its (pre-pool) output resolution.
pub fn native_smallcnn_manifest(
    batch: usize,
    hw: usize,
    in_channels: usize,
    classes: usize,
    channels: &[usize],
) -> Result<ModelManifest, String> {
    if batch == 0 {
        return Err("native smallcnn: batch must be >= 1".into());
    }
    if hw == 0 || in_channels == 0 || classes < 2 {
        return Err("native smallcnn: need hw >= 1, channels >= 1, classes >= 2".into());
    }
    validate_smallcnn_geometry(hw, channels)?;

    let mut params = vec![];
    let mut bn = vec![];
    let mut geoms = vec![];
    let mut side = hw;
    let mut c_in = in_channels;
    for (i, &c_out) in channels.iter().enumerate() {
        let name = format!("conv{}", i + 1);
        params.push(ParamSpec {
            name: format!("{name}.w"),
            shape: vec![3, 3, c_in, c_out],
            init: format!("kaiming:{}", 9 * c_in),
            role: "conv_w".to_string(),
        });
        params.push(ParamSpec {
            name: format!("{name}.bn.g"),
            shape: vec![c_out],
            init: "ones".to_string(),
            role: "bn_g".to_string(),
        });
        params.push(ParamSpec {
            name: format!("{name}.bn.b"),
            shape: vec![c_out],
            init: "zeros".to_string(),
            role: "bn_b".to_string(),
        });
        bn.push(BnSpec {
            name: format!("{name}.bn.mean"),
            shape: vec![c_out],
            init: "zeros".to_string(),
        });
        bn.push(BnSpec {
            name: format!("{name}.bn.var"),
            shape: vec![c_out],
            init: "ones".to_string(),
        });
        geoms.push(LayerGeom {
            name,
            kind: "conv".to_string(),
            weight_count: 9 * c_in * c_out,
            macs: 9 * c_in * c_out * side * side,
            fixed8: false,
        });
        side /= 2;
        c_in = c_out;
    }
    let flat = side * side * c_in;
    params.push(ParamSpec {
        name: "fc1.w".to_string(),
        shape: vec![flat, classes],
        init: format!("kaiming:{flat}"),
        role: "fc_w".to_string(),
    });
    params.push(ParamSpec {
        name: "fc1.b".to_string(),
        shape: vec![classes],
        init: "zeros".to_string(),
        role: "fc_b".to_string(),
    });
    geoms.push(LayerGeom {
        name: "fc1".to_string(),
        kind: "fc".to_string(),
        weight_count: flat * classes,
        macs: flat * classes,
        fixed8: false,
    });

    Ok(ModelManifest {
        key: NATIVE_SMALLCNN_KEY.to_string(),
        batch,
        input_hw: (hw, hw),
        in_channels,
        num_classes: classes,
        params,
        bn,
        geoms,
        artifacts: BTreeMap::new(),
    })
}

/// The resnet20-class architecture's geometric contract, shared by the
/// manifest builder and `ExperimentConfig::validate` (same pattern as
/// [`validate_smallcnn_geometry`]): at least one non-zero stage width,
/// at least one block per stage, and an image side divisible by
/// 2^(stages−1) — the first block of every stage after the first
/// downsamples by stride 2, and global average pooling needs at least
/// a 1×1 map at the end.
pub fn validate_resnet_geometry(
    hw: usize,
    channels: &[usize],
    blocks: usize,
) -> Result<(), String> {
    if channels.is_empty() || channels.contains(&0) {
        return Err("native resnet: need at least one non-zero stage width".into());
    }
    if blocks == 0 {
        return Err("native resnet: need at least one residual block per stage".into());
    }
    let downs = channels.len() - 1;
    if downs >= usize::BITS as usize || hw % (1usize << downs) != 0 || hw >> downs == 0 {
        return Err(format!(
            "native resnet: image_hw {hw} must be divisible by 2^{downs} \
             (one stride-2 downsample per stage transition)"
        ));
    }
    Ok(())
}

/// Push one conv→BN unit (weight + γ/β parameters, running mean/var
/// stats, and a conv [`LayerGeom`] at the unit's output resolution)
/// onto a resnet manifest under construction. `k` is the square kernel
/// side (3 for trunk convs, 1 for projection shortcuts).
fn push_conv_unit(
    params: &mut Vec<ParamSpec>,
    bn: &mut Vec<BnSpec>,
    geoms: &mut Vec<LayerGeom>,
    name: &str,
    k: usize,
    c_in: usize,
    c_out: usize,
    out_side: usize,
) {
    params.push(ParamSpec {
        name: format!("{name}.w"),
        shape: vec![k, k, c_in, c_out],
        init: format!("kaiming:{}", k * k * c_in),
        role: "conv_w".to_string(),
    });
    params.push(ParamSpec {
        name: format!("{name}.bn.g"),
        shape: vec![c_out],
        init: "ones".to_string(),
        role: "bn_g".to_string(),
    });
    params.push(ParamSpec {
        name: format!("{name}.bn.b"),
        shape: vec![c_out],
        init: "zeros".to_string(),
        role: "bn_b".to_string(),
    });
    bn.push(BnSpec {
        name: format!("{name}.bn.mean"),
        shape: vec![c_out],
        init: "zeros".to_string(),
    });
    bn.push(BnSpec {
        name: format!("{name}.bn.var"),
        shape: vec![c_out],
        init: "ones".to_string(),
    });
    geoms.push(LayerGeom {
        name: name.to_string(),
        kind: "conv".to_string(),
        weight_count: k * k * c_in * c_out,
        macs: k * k * c_in * c_out * out_side * out_side,
        fixed8: false,
    });
}

/// Build the manifest for the native resnet20-class model (DESIGN.md
/// §18): a 3×3 stride-1 stem conv→BN→ReLU into `channels[0]`, then
/// `channels.len()` stages of `blocks` residual blocks each, global
/// average pooling, and an `fc1` head over the final stage width.
///
/// Block `res{s}_{b}` is conv→BN→ReLU→conv→BN with a join-then-ReLU:
/// the first block of every stage after the first runs its `c1` conv
/// (and its 1×1 projection shortcut `sc`) at stride 2; every other
/// block keeps stride 1 and an identity shortcut. A projection is
/// emitted exactly when the shortcut must change shape (stride ≠ 1 or
/// c_in ≠ c_out) — the classic ResNet "option B" rule. All weight
/// tensors end in `.w` so `export_packed`'s artifact-free heuristic
/// packs every conv and the head while BN tensors stay raw.
///
/// The paper's ResNet20/CIFAR-10 is `channels = [16, 32, 64]`,
/// `blocks = 3`, `hw = 32` (1 stem + 18 trunk convs + fc = 20 weight
/// layers); the defaults stay smaller so the offline loop is quick.
pub fn native_resnet_manifest(
    batch: usize,
    hw: usize,
    in_channels: usize,
    classes: usize,
    channels: &[usize],
    blocks: usize,
) -> Result<ModelManifest, String> {
    if batch == 0 {
        return Err("native resnet: batch must be >= 1".into());
    }
    if hw == 0 || in_channels == 0 || classes < 2 {
        return Err("native resnet: need hw >= 1, channels >= 1, classes >= 2".into());
    }
    validate_resnet_geometry(hw, channels, blocks)?;

    let mut params = vec![];
    let mut bn = vec![];
    let mut geoms = vec![];
    let mut side = hw;
    let mut c_in = channels[0];
    push_conv_unit(
        &mut params,
        &mut bn,
        &mut geoms,
        "stem",
        3,
        in_channels,
        channels[0],
        side,
    );
    for (s, &c_out) in channels.iter().enumerate() {
        for b in 0..blocks {
            let name = format!("res{}_{}", s + 1, b + 1);
            let stride = if s > 0 && b == 0 { 2 } else { 1 };
            if stride == 2 {
                side /= 2;
            }
            push_conv_unit(
                &mut params,
                &mut bn,
                &mut geoms,
                &format!("{name}.c1"),
                3,
                c_in,
                c_out,
                side,
            );
            push_conv_unit(
                &mut params,
                &mut bn,
                &mut geoms,
                &format!("{name}.c2"),
                3,
                c_out,
                c_out,
                side,
            );
            if stride != 1 || c_in != c_out {
                push_conv_unit(
                    &mut params,
                    &mut bn,
                    &mut geoms,
                    &format!("{name}.sc"),
                    1,
                    c_in,
                    c_out,
                    side,
                );
            }
            c_in = c_out;
        }
    }
    params.push(ParamSpec {
        name: "fc1.w".to_string(),
        shape: vec![c_in, classes],
        init: format!("kaiming:{c_in}"),
        role: "fc_w".to_string(),
    });
    params.push(ParamSpec {
        name: "fc1.b".to_string(),
        shape: vec![classes],
        init: "zeros".to_string(),
        role: "fc_b".to_string(),
    });
    geoms.push(LayerGeom {
        name: "fc1".to_string(),
        kind: "fc".to_string(),
        weight_count: c_in * classes,
        macs: c_in * classes,
        fixed8: false,
    });

    Ok(ModelManifest {
        key: NATIVE_RESNET_KEY.to_string(),
        batch,
        input_hw: (hw, hw),
        in_channels,
        num_classes: classes,
        params,
        bn,
        geoms,
        artifacts: BTreeMap::new(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_names_and_geometry_line_up() {
        let mm = native_manifest(16, 16, 3, 10, &[32]).unwrap();
        assert_eq!(mm.key, NATIVE_MODEL_KEY);
        assert_eq!(mm.batch, 16);
        assert_eq!(mm.input_numel(), 16 * 16 * 16 * 3);
        let names: Vec<&str> = mm.params.iter().map(|p| p.name.as_str()).collect();
        assert_eq!(names, vec!["fc1.w", "fc1.b", "fc2.w", "fc2.b"]);
        assert_eq!(mm.params[0].shape, vec![768, 32]);
        assert_eq!(mm.params[2].shape, vec![32, 10]);
        assert_eq!(mm.weight_count(), 768 * 32 + 32 * 10);
        assert_eq!(mm.geoms.len(), 2);
        assert!(mm.bn.is_empty() && mm.artifacts.is_empty());
    }

    #[test]
    fn no_hidden_layer_is_a_single_fc() {
        let mm = native_manifest(4, 8, 3, 10, &[]).unwrap();
        assert_eq!(mm.params.len(), 2);
        assert_eq!(mm.params[0].shape, vec![8 * 8 * 3, 10]);
    }

    #[test]
    fn rejects_degenerate_shapes() {
        assert!(native_manifest(0, 16, 3, 10, &[32]).is_err());
        assert!(native_manifest(4, 0, 3, 10, &[32]).is_err());
        assert!(native_manifest(4, 16, 3, 1, &[32]).is_err());
        assert!(native_manifest(4, 16, 3, 10, &[0]).is_err());
    }

    #[test]
    fn smallcnn_manifest_shapes_names_and_geometry_line_up() {
        let mm = native_smallcnn_manifest(16, 16, 3, 10, &[8, 12]).unwrap();
        assert_eq!(mm.key, NATIVE_SMALLCNN_KEY);
        let names: Vec<&str> = mm.params.iter().map(|p| p.name.as_str()).collect();
        assert_eq!(
            names,
            vec![
                "conv1.w", "conv1.bn.g", "conv1.bn.b", "conv2.w", "conv2.bn.g", "conv2.bn.b",
                "fc1.w", "fc1.b",
            ]
        );
        assert_eq!(mm.params[0].shape, vec![3, 3, 3, 8]);
        assert_eq!(mm.params[0].init, "kaiming:27");
        assert_eq!(mm.params[3].shape, vec![3, 3, 8, 12]);
        // 16 -> pool 8 -> pool 4: fc over 4*4*12
        assert_eq!(mm.params[6].shape, vec![4 * 4 * 12, 10]);
        let bn_names: Vec<&str> = mm.bn.iter().map(|b| b.name.as_str()).collect();
        assert_eq!(
            bn_names,
            vec!["conv1.bn.mean", "conv1.bn.var", "conv2.bn.mean", "conv2.bn.var"]
        );
        assert_eq!(mm.geoms.len(), 3);
        assert_eq!(mm.geoms[0].macs, 9 * 3 * 8 * 16 * 16);
        assert_eq!(mm.geoms[1].macs, 9 * 8 * 12 * 8 * 8);
        assert_eq!(mm.weight_count(), 9 * 3 * 8 + 9 * 8 * 12 + 4 * 4 * 12 * 10);
        assert!(mm.artifacts.is_empty());
    }

    #[test]
    fn resnet_manifest_names_shapes_and_projection_rule_line_up() {
        let mm = native_resnet_manifest(16, 8, 3, 10, &[4, 8], 2).unwrap();
        assert_eq!(mm.key, NATIVE_RESNET_KEY);
        let names: Vec<&str> = mm.params.iter().map(|p| p.name.as_str()).collect();
        // stage 1 keeps identity shortcuts; the stage-2 entry block
        // downsamples and widens, so only res2_1 carries a projection
        assert_eq!(
            names,
            vec![
                "stem.w",
                "stem.bn.g",
                "stem.bn.b",
                "res1_1.c1.w",
                "res1_1.c1.bn.g",
                "res1_1.c1.bn.b",
                "res1_1.c2.w",
                "res1_1.c2.bn.g",
                "res1_1.c2.bn.b",
                "res1_2.c1.w",
                "res1_2.c1.bn.g",
                "res1_2.c1.bn.b",
                "res1_2.c2.w",
                "res1_2.c2.bn.g",
                "res1_2.c2.bn.b",
                "res2_1.c1.w",
                "res2_1.c1.bn.g",
                "res2_1.c1.bn.b",
                "res2_1.c2.w",
                "res2_1.c2.bn.g",
                "res2_1.c2.bn.b",
                "res2_1.sc.w",
                "res2_1.sc.bn.g",
                "res2_1.sc.bn.b",
                "res2_2.c1.w",
                "res2_2.c1.bn.g",
                "res2_2.c1.bn.b",
                "res2_2.c2.w",
                "res2_2.c2.bn.g",
                "res2_2.c2.bn.b",
                "fc1.w",
                "fc1.b",
            ]
        );
        assert_eq!(mm.params[0].shape, vec![3, 3, 3, 4]); // stem.w
        assert_eq!(mm.params[15].shape, vec![3, 3, 4, 8]); // res2_1.c1.w
        assert_eq!(mm.params[21].shape, vec![1, 1, 4, 8]); // res2_1.sc.w
        // GAP head: fc over the final stage width, not a flattened map
        assert_eq!(mm.params[30].shape, vec![8, 10]);
        // every weight tensor ends in .w — the export heuristic's contract
        assert!(mm
            .params
            .iter()
            .filter(|p| p.shape.len() > 1)
            .all(|p| p.name.ends_with(".w")));
        // stride-2 MACs: res2_1.c1 runs at the downsampled 4×4 side
        let g = mm.geoms.iter().find(|g| g.name == "res2_1.c1").unwrap();
        assert_eq!(g.macs, 9 * 4 * 8 * 4 * 4);
        let sc = mm.geoms.iter().find(|g| g.name == "res2_1.sc").unwrap();
        assert_eq!(sc.macs, 4 * 8 * 4 * 4);
    }

    #[test]
    fn resnet20_manifest_has_twenty_weight_layers() {
        // the paper's CIFAR-10 architecture: stem + 18 trunk convs + fc
        let mm = native_resnet_manifest(32, 32, 3, 10, &[16, 32, 64], 3).unwrap();
        let trunk = mm
            .geoms
            .iter()
            .filter(|g| g.kind == "fc" || !g.name.ends_with(".sc"))
            .count();
        assert_eq!(trunk, 20);
        // plus the two stage-transition projections
        assert_eq!(mm.geoms.len(), 22);
    }

    #[test]
    fn resnet_manifest_rejects_bad_geometry() {
        // hw not divisible by 2^(stages-1)
        assert!(native_resnet_manifest(4, 10, 3, 10, &[8, 16, 32], 1).is_err());
        assert!(native_resnet_manifest(4, 16, 3, 10, &[], 1).is_err());
        assert!(native_resnet_manifest(4, 16, 3, 10, &[8, 0], 1).is_err());
        assert!(native_resnet_manifest(4, 16, 3, 10, &[8, 16], 0).is_err());
        assert!(native_resnet_manifest(0, 16, 3, 10, &[8], 1).is_err());
        assert!(is_native_resnet_model("resnet20"));
        assert!(is_native_resnet_model(NATIVE_RESNET_KEY));
        assert!(!is_native_resnet_model(NATIVE_SMALLCNN_KEY));
    }

    #[test]
    fn smallcnn_manifest_rejects_bad_geometry() {
        // hw not divisible by 2^blocks
        assert!(native_smallcnn_manifest(4, 12, 3, 10, &[8, 16, 32]).is_err());
        assert!(native_smallcnn_manifest(4, 16, 3, 10, &[]).is_err());
        assert!(native_smallcnn_manifest(4, 16, 3, 10, &[8, 0]).is_err());
        assert!(native_smallcnn_manifest(0, 16, 3, 10, &[8]).is_err());
        assert!(native_smallcnn_manifest(4, 16, 3, 1, &[8]).is_err());
        // and the conv-model predicate names both spellings
        assert!(is_native_conv_model("smallcnn"));
        assert!(is_native_conv_model(NATIVE_SMALLCNN_KEY));
        assert!(!is_native_conv_model(NATIVE_MODEL_KEY));
    }
}

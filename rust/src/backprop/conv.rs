//! Pure-Rust quantized conv training — the conv backends of the native
//! stack (DESIGN.md §13, §18).
//!
//! Until this module, the native backend trained fc stacks only: the
//! paper's headline models are CNNs, so the conv architectures still
//! hard-required PJRT artifacts and the full AdaQAT controller had never
//! driven a conv net in CI. [`ConvNativeBackend`] closes that gap — the
//! third [`StepBackend`]: conv→BN→ReLU→pool blocks plus an fc head,
//! trained entirely in-process with the same offline closure the MLP
//! backend established (train → export → serve, zero artifacts).
//! [`ResNetNativeBackend`] extends it to the paper's resnet20-class
//! topology (DESIGN.md §18): a stem unit, residual blocks whose trunk
//! (conv→BN→ReLU→conv→BN) joins an identity or 1×1-projection shortcut
//! under a shared ReLU, global average pooling, and an fc head. The
//! backward pass differentiates through the join exactly — the gradient
//! at a block output passes the join ReLU gate, then flows down the
//! trunk chain *and* through the shortcut adjoint (projection conv
//! transpose, or a straight copy for identity), and the two input
//! gradients sum.
//!
//! Mechanics, mirroring the MLP backend wherever the two overlap:
//! * **conv forward** — im2col ([`crate::kernels::conv::im2col`], shared
//!   with the serving kernels) turns each conv into a GEMM over patch
//!   rows; weights fake-quantize per tensor on the packed grid
//!   ([`fake_quantize_tensor`]), activations per *patch row* at k_a
//!   ([`crate::kernels::activ::fake_quantize_row`]) — the identical
//!   quantizer placement the integer serving kernels evaluate, so the
//!   training forward and a served checkpoint agree to accumulation
//!   rounding.
//! * **batch-norm** — training mode normalizes with batch statistics
//!   over (batch × positions) per channel (ε = [`BN_EPS`], shared with
//!   the serving fold) and updates the running mean/var held in
//!   `TrainState::bn`; the backward pass is the full batch-stat BN
//!   gradient (not a straight-through shortcut).
//! * **backward** — straight-through through both quantizers, ReLU
//!   gated by its forward output, 2×2 avg-pool distributing δ/4,
//!   weight gradients from the quantized patches (`x̂ᵀδ`), input
//!   gradients through the quantized kernels scattered back by
//!   `col2im`. SGD + momentum 0.9, weight decay 1e-4 on `.w` only —
//!   the same optimizer contract as every other backend.
//! * **evaluation** — packs the live weights exactly as `adaqat export`
//!   would and runs the integer conv kernels
//!   ([`crate::kernels::conv::QuantConvNet`]), so trainer eval and the
//!   served model are the *same numbers*; `tests/conv_native.rs`
//!   asserts every served prediction matches.

use std::cell::{Cell, RefCell};

use crate::config::ExperimentConfig;
use crate::data::DatasetKind;
use crate::kernels::activ;
use crate::kernels::conv::{avgpool2x2, global_avgpool, im2col, ConvGeom, QuantConvNet, BN_EPS};
use crate::runtime::{
    init_state_from_manifest, load_state_from_manifest, Batch, ModelManifest, StepBackend,
    StepMetrics, TrainState,
};
use crate::serve::packed::{PackedTensor, QuantizedCheckpoint};
use crate::tensor::checkpoint::Checkpoint;
use crate::util::json::Json;

use super::manifest::{native_resnet_manifest, native_smallcnn_manifest};
use super::{fake_quantize_tensor, softmax_metrics, MOMENTUM, WEIGHT_DECAY};

/// Running-statistics update rate: `r ← (1 − m)·r + m·batch`, the
/// conventional BN momentum.
pub const BN_MOMENTUM: f32 = 0.1;

/// Everything one conv forward pass leaves behind for the backward
/// pass. Per block, buffers are laid out over `prows = batch·h·w` patch
/// rows of that block's (pre-pool) resolution.
struct ConvForwardPass {
    /// Per block: the im2col rows the GEMM consumed (fake-quantized at
    /// k_a when quantizing, raw otherwise), `[prows × patch_len]`.
    patches: Vec<Vec<f32>>,
    /// Per block: fake-quantized conv kernel (`[patch_len · c_out]`
    /// flat), `None` = the raw weights in `TrainState` were used.
    wq: Vec<Option<Vec<f32>>>,
    /// Per block: batch statistics the BN normalized with.
    bn_mean: Vec<Vec<f32>>,
    bn_var: Vec<Vec<f32>>,
    /// Per block: 1/√(σ² + ε) per channel.
    inv_std: Vec<Vec<f32>>,
    /// Per block: normalized pre-scale activations, `[prows × c_out]`.
    xhat: Vec<Vec<f32>>,
    /// Per block: post-ReLU (pre-pool) activations, `[prows × c_out]`.
    relu: Vec<Vec<f32>>,
    /// Per block: pooled block output, `[rows × h/2 × w/2 × c_out]`.
    out: Vec<Vec<f32>>,
    /// Fake-quantized fc input rows (`None` = last pooled output used).
    flat_q: Option<Vec<f32>>,
    /// Fake-quantized fc weights (`None` = raw).
    fc_wq: Option<Vec<f32>>,
    probs: Vec<f32>,
    loss: f64,
    correct: usize,
}

/// A memoized serving model, keyed on (weights + BN stats, bit-widths):
/// the conv analogue of the MLP backend's eval memo. BN statistics are
/// part of the key because the folded inference epilogue bakes them in.
struct ConvEvalCache {
    fingerprint: u64,
    k_w: u32,
    k_a: u32,
    net: QuantConvNet,
}

/// The native smallcnn trainer. Geometry lives here; all training state
/// lives in the caller's [`TrainState`], like every other backend.
pub struct ConvNativeBackend {
    mm: ModelManifest,
    /// Per conv block: the input-side geometry (3×3, stride 1, same
    /// pad; output spatial == input spatial, then a 2×2 pool).
    blocks: Vec<ConvGeom>,
    /// fc head (flat_in, classes).
    fc: (usize, usize),
    eval_cache: RefCell<Option<ConvEvalCache>>,
    /// How many times the eval memo was (re)built — pinned by tests.
    eval_builds: Cell<usize>,
}

/// FNV-1a over parameters *and* BN statistics — the eval-memo key. The
/// MLP backend hashes parameters only; here the running stats feed the
/// folded serving epilogue, so they must invalidate the memo too.
fn state_fingerprint(state: &TrainState) -> u64 {
    let mut h = crate::util::FNV1A_BASIS;
    for t in state.params.iter().chain(&state.bn) {
        for &v in &t.data {
            h = crate::util::fnv1a_mix(h, v.to_bits() as u64);
        }
    }
    h
}

/// Scatter-add im2col-row gradients back onto the input grid — the
/// exact adjoint of [`im2col`] (training-only, so it lives here rather
/// than with the serving kernels).
fn col2im(dp: &[f32], rows: usize, g: &ConvGeom, out: &mut [f32]) {
    let (oh, ow) = g.out_hw();
    let k = g.patch_len();
    assert_eq!(dp.len(), rows * oh * ow * k);
    assert_eq!(out.len(), rows * g.h * g.w * g.c_in);
    out.fill(0.0);
    let c = g.c_in;
    for r in 0..rows {
        let img = &mut out[r * g.h * g.w * c..(r + 1) * g.h * g.w * c];
        for oy in 0..oh {
            for ox in 0..ow {
                let row0 = ((r * oh + oy) * ow + ox) * k;
                for ky in 0..g.kh {
                    let iy = (oy * g.stride + ky) as isize - g.pad as isize;
                    if iy < 0 || iy >= g.h as isize {
                        continue;
                    }
                    for kx in 0..g.kw {
                        let ix = (ox * g.stride + kx) as isize - g.pad as isize;
                        if ix < 0 || ix >= g.w as isize {
                            continue;
                        }
                        let dst = (iy as usize * g.w + ix as usize) * c;
                        let src = row0 + (ky * g.kw + kx) * c;
                        for ch in 0..c {
                            img[dst + ch] += dp[src + ch];
                        }
                    }
                }
            }
        }
    }
}

impl ConvNativeBackend {
    pub fn new(
        batch: usize,
        hw: usize,
        in_channels: usize,
        classes: usize,
        channels: &[usize],
    ) -> anyhow::Result<ConvNativeBackend> {
        let mm = native_smallcnn_manifest(batch, hw, in_channels, classes, channels)
            .map_err(|e| anyhow::anyhow!(e))?;
        let mut blocks = Vec::with_capacity(channels.len());
        let mut side = hw;
        let mut c_in = in_channels;
        for &c_out in channels {
            blocks.push(ConvGeom {
                h: side,
                w: side,
                c_in,
                c_out,
                kh: 3,
                kw: 3,
                stride: 1,
                pad: 1,
            });
            side /= 2;
            c_in = c_out;
        }
        let fc = (side * side * c_in, classes);
        Ok(ConvNativeBackend {
            mm,
            blocks,
            fc,
            eval_cache: RefCell::new(None),
            eval_builds: Cell::new(0),
        })
    }

    /// Build from an [`ExperimentConfig`] (`backend = "native"`, a conv
    /// model key): the synthetic dataset fixes channels/classes,
    /// `image_hw`/`channels`/`batch` fix the geometry.
    pub fn from_config(cfg: &ExperimentConfig) -> anyhow::Result<ConvNativeBackend> {
        let kind = DatasetKind::parse(&cfg.dataset).map_err(|e| anyhow::anyhow!(e))?;
        ConvNativeBackend::new(cfg.batch, cfg.image_hw, 3, kind.num_classes(), &cfg.channels)
    }

    /// Conv block names in `conv_layers` order (`conv1`, `conv2`, …).
    pub fn conv_layer_names(&self) -> Vec<String> {
        (1..=self.blocks.len()).map(|i| format!("conv{i}")).collect()
    }

    fn check_batch(&self, batch: &Batch) -> anyhow::Result<()> {
        anyhow::ensure!(
            batch.x.shape
                == vec![
                    self.mm.batch,
                    self.mm.input_hw.0,
                    self.mm.input_hw.1,
                    self.mm.in_channels
                ],
            "native conv backend: batch x shape {:?} does not match manifest batch {}",
            batch.x.shape,
            self.mm.batch
        );
        anyhow::ensure!(
            batch.y.shape == vec![self.mm.batch],
            "native conv backend: bad y shape"
        );
        Ok(())
    }

    /// The training/probe forward: batch-stat BN, fake-quant at
    /// (k_w, k_a) when `quant` (same width thresholds as the MLP
    /// backend: weights 1..=24, activations < 24), plain f32 otherwise.
    fn forward(
        &self,
        state: &TrainState,
        batch: &Batch,
        k_w: u32,
        k_a: u32,
        quant: bool,
    ) -> ConvForwardPass {
        let rows = self.mm.batch;
        let nb = self.blocks.len();
        let mut patches = Vec::with_capacity(nb);
        let mut wqs = Vec::with_capacity(nb);
        let mut bn_mean = Vec::with_capacity(nb);
        let mut bn_var = Vec::with_capacity(nb);
        let mut inv_stds = Vec::with_capacity(nb);
        let mut xhats = Vec::with_capacity(nb);
        let mut relus = Vec::with_capacity(nb);
        let mut outs: Vec<Vec<f32>> = Vec::with_capacity(nb);

        for (l, g) in self.blocks.iter().enumerate() {
            let src: &[f32] = if l == 0 { &batch.x.data } else { &outs[l - 1] };
            let (oh, ow) = g.out_hw();
            let k = g.patch_len();
            let cout = g.c_out;
            let prows = rows * oh * ow;
            let mut p = vec![0.0f32; prows * k];
            im2col(src, rows, g, &mut p);
            if quant && k_a < 24 {
                for r in 0..prows {
                    activ::fake_quantize_row(&mut p[r * k..(r + 1) * k], k_a);
                }
            }
            let w = &state.params[3 * l].data;
            let wql = if quant && (1..=24).contains(&k_w) {
                let mut q = vec![0.0f32; w.len()];
                fake_quantize_tensor(w, k_w, &mut q);
                Some(q)
            } else {
                None
            };
            let win: &[f32] = wql.as_deref().unwrap_or(w);
            // z = patches × W  (no conv bias; BN supplies the shift)
            let mut z = vec![0.0f32; prows * cout];
            for r in 0..prows {
                let xrow = &p[r * k..(r + 1) * k];
                let orow = &mut z[r * cout..(r + 1) * cout];
                for (i, &xv) in xrow.iter().enumerate() {
                    if xv == 0.0 {
                        continue;
                    }
                    for (o, &wv) in orow.iter_mut().zip(&win[i * cout..(i + 1) * cout]) {
                        *o += xv * wv;
                    }
                }
            }
            // batch-stat BN (two-pass, f64 accumulation per channel)
            let n = prows as f64;
            let mut mean = vec![0.0f32; cout];
            let mut var = vec![0.0f32; cout];
            let mut acc = vec![0.0f64; cout];
            for r in 0..prows {
                for (a, &v) in acc.iter_mut().zip(&z[r * cout..(r + 1) * cout]) {
                    *a += v as f64;
                }
            }
            for (m, &a) in mean.iter_mut().zip(&acc) {
                *m = (a / n) as f32;
            }
            acc.fill(0.0);
            for r in 0..prows {
                for (o, (a, &v)) in acc.iter_mut().zip(&z[r * cout..(r + 1) * cout]).enumerate()
                {
                    let d = (v - mean[o]) as f64;
                    *a += d * d;
                }
            }
            for (v, &a) in var.iter_mut().zip(&acc) {
                *v = (a / n) as f32;
            }
            let mut inv_std = vec![0.0f32; cout];
            for (s, &v) in inv_std.iter_mut().zip(&var) {
                *s = 1.0 / (v + BN_EPS).sqrt();
            }
            let gamma = &state.params[3 * l + 1].data;
            let beta = &state.params[3 * l + 2].data;
            let mut xhat = vec![0.0f32; prows * cout];
            let mut y = vec![0.0f32; prows * cout];
            for r in 0..prows {
                for o in 0..cout {
                    let xh = (z[r * cout + o] - mean[o]) * inv_std[o];
                    xhat[r * cout + o] = xh;
                    let v = gamma[o] * xh + beta[o];
                    y[r * cout + o] = if v < 0.0 { 0.0 } else { v };
                }
            }
            let pooled = avgpool2x2(&y, rows, oh, ow, cout);
            patches.push(p);
            wqs.push(wql);
            bn_mean.push(mean);
            bn_var.push(var);
            inv_stds.push(inv_std);
            xhats.push(xhat);
            relus.push(y);
            outs.push(pooled);
        }

        // fc head over the flattened (NHWC) pooled features
        let (flat, classes) = self.fc;
        let flat_q = if quant && k_a < 24 {
            let mut q = outs[nb - 1].clone();
            for r in 0..rows {
                activ::fake_quantize_row(&mut q[r * flat..(r + 1) * flat], k_a);
            }
            Some(q)
        } else {
            None
        };
        let fcw = &state.params[3 * nb].data;
        let fc_wq = if quant && (1..=24).contains(&k_w) {
            let mut q = vec![0.0f32; fcw.len()];
            fake_quantize_tensor(fcw, k_w, &mut q);
            Some(q)
        } else {
            None
        };
        let fcb = &state.params[3 * nb + 1].data;
        let xin: &[f32] = flat_q.as_deref().unwrap_or(&outs[nb - 1]);
        let win: &[f32] = fc_wq.as_deref().unwrap_or(fcw);
        let mut logits = vec![0.0f32; rows * classes];
        for r in 0..rows {
            let xrow = &xin[r * flat..(r + 1) * flat];
            let orow = &mut logits[r * classes..(r + 1) * classes];
            orow.copy_from_slice(fcb);
            for (i, &xv) in xrow.iter().enumerate() {
                if xv == 0.0 {
                    continue;
                }
                for (o, &wv) in orow.iter_mut().zip(&win[i * classes..(i + 1) * classes]) {
                    *o += xv * wv;
                }
            }
        }
        let (loss, correct, probs) = softmax_metrics(&logits, &batch.y.data, rows, classes);
        ConvForwardPass {
            patches,
            wq: wqs,
            bn_mean,
            bn_var,
            inv_std: inv_stds,
            xhat: xhats,
            relu: relus,
            out: outs,
            flat_q,
            fc_wq,
            probs,
            loss,
            correct,
        }
    }

    /// STE backward + SGD-with-momentum update. Quantizers are
    /// straight-through; BN backward is the full batch-statistics
    /// gradient; pooling distributes δ/4; weight decay on `.w` only.
    fn backward_update(
        &self,
        state: &mut TrainState,
        fwd: &ConvForwardPass,
        batch: &Batch,
        lr: f32,
    ) {
        let rows = self.mm.batch;
        let nb = self.blocks.len();
        let (flat, classes) = self.fc;

        // δ at the logits: (softmax − one-hot) / rows
        let mut delta: Vec<f32> = fwd.probs.clone();
        for r in 0..rows {
            delta[r * classes + batch.y.data[r] as usize] -= 1.0;
        }
        let inv_rows = 1.0 / rows as f32;
        for v in delta.iter_mut() {
            *v *= inv_rows;
        }

        // ---- fc head
        let xh: &[f32] = fwd.flat_q.as_deref().unwrap_or(&fwd.out[nb - 1]);
        let mut gw = vec![0.0f32; flat * classes];
        for r in 0..rows {
            let xrow = &xh[r * flat..(r + 1) * flat];
            let drow = &delta[r * classes..(r + 1) * classes];
            for (i, &xv) in xrow.iter().enumerate() {
                if xv == 0.0 {
                    continue;
                }
                for (g, &dv) in gw[i * classes..(i + 1) * classes].iter_mut().zip(drow) {
                    *g += xv * dv;
                }
            }
        }
        for (g, &wv) in gw.iter_mut().zip(&state.params[3 * nb].data) {
            *g += WEIGHT_DECAY * wv;
        }
        let mut gb = vec![0.0f32; classes];
        for r in 0..rows {
            for (g, &dv) in gb.iter_mut().zip(&delta[r * classes..(r + 1) * classes]) {
                *g += dv;
            }
        }
        // δ onto the flattened features, through ŵ (no ReLU here: the
        // pool output feeds the head directly)
        let fcw: &[f32] = fwd.fc_wq.as_deref().unwrap_or(&state.params[3 * nb].data);
        let mut dcur = vec![0.0f32; rows * flat];
        for r in 0..rows {
            let drow = &delta[r * classes..(r + 1) * classes];
            let ndrow = &mut dcur[r * flat..(r + 1) * flat];
            for (i, nd) in ndrow.iter_mut().enumerate() {
                let mut s = 0.0f32;
                for (&wv, &dv) in fcw[i * classes..(i + 1) * classes].iter().zip(drow) {
                    s += wv * dv;
                }
                *nd = s;
            }
        }
        sgd_update(&mut state.params[3 * nb].data, &mut state.momentum[3 * nb].data, &gw, lr);
        sgd_update(
            &mut state.params[3 * nb + 1].data,
            &mut state.momentum[3 * nb + 1].data,
            &gb,
            lr,
        );

        // ---- conv blocks, last to first
        for l in (0..nb).rev() {
            let g = self.blocks[l];
            let (oh, ow) = g.out_hw();
            let cout = g.c_out;
            let prows = rows * oh * ow;
            let (ph, pw) = (oh / 2, ow / 2);

            // unpool: each pooled δ spreads as δ/4 over its 2×2 window
            let mut dy = vec![0.0f32; prows * cout];
            for r in 0..rows {
                for py in 0..ph {
                    for px in 0..pw {
                        let d0 = ((r * ph + py) * pw + px) * cout;
                        for ch in 0..cout {
                            let v = 0.25 * dcur[d0 + ch];
                            let i00 = ((r * oh + 2 * py) * ow + 2 * px) * cout + ch;
                            dy[i00] = v;
                            dy[i00 + cout] = v;
                            dy[i00 + ow * cout] = v;
                            dy[i00 + ow * cout + cout] = v;
                        }
                    }
                }
            }
            // ReLU gate by the forward output
            for (dv, &rv) in dy.iter_mut().zip(&fwd.relu[l]) {
                if rv <= 0.0 {
                    *dv = 0.0;
                }
            }
            // batch-norm backward (batch statistics)
            let gamma = &state.params[3 * l + 1].data;
            let inv_std = &fwd.inv_std[l];
            let xhat = &fwd.xhat[l];
            let n = prows as f64;
            let mut sum_dy = vec![0.0f64; cout];
            let mut sum_dy_xh = vec![0.0f64; cout];
            for r in 0..prows {
                for o in 0..cout {
                    let d = dy[r * cout + o] as f64;
                    sum_dy[o] += d;
                    sum_dy_xh[o] += d * xhat[r * cout + o] as f64;
                }
            }
            let ggamma: Vec<f32> = sum_dy_xh.iter().map(|&v| v as f32).collect();
            let gbeta: Vec<f32> = sum_dy.iter().map(|&v| v as f32).collect();
            let mut dz = vec![0.0f32; prows * cout];
            for o in 0..cout {
                let m1 = (sum_dy[o] / n) as f32;
                let m2 = (sum_dy_xh[o] / n) as f32;
                let f = gamma[o] * inv_std[o];
                for r in 0..prows {
                    dz[r * cout + o] =
                        f * (dy[r * cout + o] - m1 - xhat[r * cout + o] * m2);
                }
            }
            // weight gradient x̂ᵀδ over patch rows, then decay on raw w
            let k = g.patch_len();
            let mut gwc = vec![0.0f32; k * cout];
            for r in 0..prows {
                let xrow = &fwd.patches[l][r * k..(r + 1) * k];
                let drow = &dz[r * cout..(r + 1) * cout];
                for (i, &xv) in xrow.iter().enumerate() {
                    if xv == 0.0 {
                        continue;
                    }
                    for (gv, &dv) in gwc[i * cout..(i + 1) * cout].iter_mut().zip(drow) {
                        *gv += xv * dv;
                    }
                }
            }
            for (gv, &wv) in gwc.iter_mut().zip(&state.params[3 * l].data) {
                *gv += WEIGHT_DECAY * wv;
            }
            // input gradient through ŵ, scattered back through im2col
            if l > 0 {
                let win: &[f32] = fwd.wq[l].as_deref().unwrap_or(&state.params[3 * l].data);
                let mut dp = vec![0.0f32; prows * k];
                for r in 0..prows {
                    let drow = &dz[r * cout..(r + 1) * cout];
                    let prow = &mut dp[r * k..(r + 1) * k];
                    for (i, pv) in prow.iter_mut().enumerate() {
                        let mut s = 0.0f32;
                        for (&wv, &dv) in win[i * cout..(i + 1) * cout].iter().zip(drow) {
                            s += wv * dv;
                        }
                        *pv = s;
                    }
                }
                let mut din = vec![0.0f32; rows * g.h * g.w * g.c_in];
                col2im(&dp, rows, &g, &mut din);
                dcur = din;
            }
            sgd_update(&mut state.params[3 * l].data, &mut state.momentum[3 * l].data, &gwc, lr);
            sgd_update(
                &mut state.params[3 * l + 1].data,
                &mut state.momentum[3 * l + 1].data,
                &ggamma,
                lr,
            );
            sgd_update(
                &mut state.params[3 * l + 2].data,
                &mut state.momentum[3 * l + 2].data,
                &gbeta,
                lr,
            );
        }
    }

    /// Assemble a full serving checkpoint for the current state — the
    /// same tensor set `train::save_checkpoint` writes, with this
    /// backend's serving meta plus `k_a`. The engine tests and the conv
    /// bench use this to pack a trainer state exactly like a finished
    /// `adaqat train` run would.
    pub fn to_checkpoint(&self, state: &TrainState, k_a: u32) -> Checkpoint {
        let mut meta = Json::obj(vec![("k_a", Json::num(k_a as f64))]);
        if let Json::Obj(m) = &mut meta {
            for (k, v) in self.checkpoint_meta() {
                m.insert(k, v);
            }
        }
        let mut ck = Checkpoint::new(meta);
        for (spec, t) in self.mm.params.iter().zip(&state.params) {
            ck.push(spec.name.clone(), t.clone());
        }
        for (spec, t) in self.mm.bn.iter().zip(&state.bn) {
            ck.push(spec.name.clone(), t.clone());
        }
        ck
    }

    /// Pack the current weights + BN statistics exactly as
    /// `adaqat export` packs a saved checkpoint and build the integer
    /// conv kernels — the serving-identical forward.
    pub fn serving_convnet(
        &self,
        state: &TrainState,
        k_w: u32,
        k_a: u32,
    ) -> anyhow::Result<QuantConvNet> {
        let conv_names = self.conv_layer_names();
        let mut q = QuantizedCheckpoint::new(Json::obj(vec![
            ("k_a", Json::num(k_a as f64)),
            (
                "conv_layers",
                Json::Arr(conv_names.iter().map(|n| Json::str(n.clone())).collect()),
            ),
            ("mlp_layers", Json::Arr(vec![Json::str("fc1")])),
            (
                "input_hw",
                Json::Arr(vec![
                    Json::num(self.mm.input_hw.0 as f64),
                    Json::num(self.mm.input_hw.1 as f64),
                ]),
            ),
            ("in_channels", Json::num(self.mm.in_channels as f64)),
        ]));
        let pack = |t: &crate::tensor::Tensor| -> PackedTensor {
            if (1..=24).contains(&k_w) {
                PackedTensor::quantize(t, k_w)
            } else {
                PackedTensor::raw(t)
            }
        };
        for (l, name) in conv_names.iter().enumerate() {
            q.push(format!("{name}.w"), pack(&state.params[3 * l]));
            q.push(format!("{name}.bn.g"), PackedTensor::raw(&state.params[3 * l + 1]));
            q.push(format!("{name}.bn.b"), PackedTensor::raw(&state.params[3 * l + 2]));
            q.push(format!("{name}.bn.mean"), PackedTensor::raw(&state.bn[2 * l]));
            q.push(format!("{name}.bn.var"), PackedTensor::raw(&state.bn[2 * l + 1]));
        }
        let nb = self.blocks.len();
        q.push("fc1.w", pack(&state.params[3 * nb]));
        q.push("fc1.b", PackedTensor::raw(&state.params[3 * nb + 1]));
        QuantConvNet::from_packed(&q)
    }

    /// [`ConvNativeBackend::serving_convnet`] behind the
    /// fingerprint-keyed memo (weights, BN stats, bit-widths).
    fn cached_serving_convnet(
        &self,
        state: &TrainState,
        k_w: u32,
        k_a: u32,
    ) -> anyhow::Result<std::cell::RefMut<'_, QuantConvNet>> {
        let fp = state_fingerprint(state);
        let mut cache = self.eval_cache.borrow_mut();
        let hit = matches!(
            &*cache,
            Some(c) if c.fingerprint == fp && c.k_w == k_w && c.k_a == k_a
        );
        if !hit {
            *cache = Some(ConvEvalCache {
                fingerprint: fp,
                k_w,
                k_a,
                net: self.serving_convnet(state, k_w, k_a)?,
            });
            self.eval_builds.set(self.eval_builds.get() + 1);
        }
        Ok(std::cell::RefMut::map(cache, |c| {
            &mut c.as_mut().expect("just populated").net
        }))
    }

    /// Serving-identical predictions for `rows` flattened NHWC images —
    /// what the conv e2e test cross-checks the served model against.
    pub fn predict(
        &self,
        state: &TrainState,
        x: &[f32],
        rows: usize,
        k_w: u32,
        k_a: u32,
    ) -> anyhow::Result<Vec<usize>> {
        Ok(self.cached_serving_convnet(state, k_w, k_a)?.classify(x, rows, 1))
    }
}

/// SGD + momentum: `m ← 0.9·m + g; p ← p − lr·m` (the shared optimizer
/// contract, `backprop::MOMENTUM`).
fn sgd_update(p: &mut [f32], m: &mut [f32], grad: &[f32], lr: f32) {
    for ((w, mv), &gv) in p.iter_mut().zip(m.iter_mut()).zip(grad) {
        *mv = MOMENTUM * *mv + gv;
        *w -= lr * *mv;
    }
}

impl StepBackend for ConvNativeBackend {
    fn mm(&self) -> &ModelManifest {
        &self.mm
    }

    fn init_state(&self, seed: u64) -> anyhow::Result<TrainState> {
        init_state_from_manifest(&self.mm, seed)
    }

    fn load_state(&self, ck: &Checkpoint, seed: u64) -> anyhow::Result<TrainState> {
        load_state_from_manifest(&self.mm, ck, seed)
    }

    fn train_step(
        &self,
        state: &mut TrainState,
        batch: &Batch,
        lr: f32,
        k_w: u32,
        k_a: u32,
        fp32: bool,
    ) -> anyhow::Result<StepMetrics> {
        self.check_batch(batch)?;
        let fwd = self.forward(state, batch, k_w, k_a, !fp32);
        self.backward_update(state, &fwd, batch, lr);
        // running statistics move only on real train steps (probes and
        // evals are forward-only, like the PJRT graphs)
        for l in 0..self.blocks.len() {
            for (r, &b) in state.bn[2 * l].data.iter_mut().zip(&fwd.bn_mean[l]) {
                *r = (1.0 - BN_MOMENTUM) * *r + BN_MOMENTUM * b;
            }
            for (r, &b) in state.bn[2 * l + 1].data.iter_mut().zip(&fwd.bn_var[l]) {
                *r = (1.0 - BN_MOMENTUM) * *r + BN_MOMENTUM * b;
            }
        }
        Ok(StepMetrics { loss: fwd.loss as f32, correct: fwd.correct as f32 })
    }

    fn probe_loss(
        &self,
        state: &TrainState,
        batch: &Batch,
        k_w: u32,
        k_a: u32,
    ) -> anyhow::Result<StepMetrics> {
        self.check_batch(batch)?;
        let fwd = self.forward(state, batch, k_w, k_a, true);
        Ok(StepMetrics { loss: fwd.loss as f32, correct: fwd.correct as f32 })
    }

    fn eval_batch(
        &self,
        state: &TrainState,
        batch: &Batch,
        k_w: u32,
        k_a: u32,
        fp32: bool,
    ) -> anyhow::Result<StepMetrics> {
        self.check_batch(batch)?;
        let rows = self.mm.batch;
        let classes = self.mm.num_classes;
        // eval = the serving forward (memoized), so eval metrics and an
        // exported checkpoint can never drift apart. The fp32 path is
        // the same net at the identity widths: k = 32 keeps weights raw
        // and skips activation quantization, and the folded
        // running-stat BN is width-independent.
        let (k_w, k_a) = if fp32 { (32, 32) } else { (k_w, k_a) };
        let net = self.cached_serving_convnet(state, k_w, k_a)?;
        let logits = net.forward(&batch.x.data, rows, 1);
        let (loss, correct, _) = softmax_metrics(&logits, &batch.y.data, rows, classes);
        Ok(StepMetrics { loss: loss as f32, correct: correct as f32 })
    }

    fn has_fp32(&self) -> bool {
        true
    }

    fn checkpoint_meta(&self) -> Vec<(String, Json)> {
        vec![
            ("backend".to_string(), Json::str("native")),
            (
                "conv_layers".to_string(),
                Json::Arr(self.conv_layer_names().into_iter().map(Json::str).collect()),
            ),
            (
                "mlp_layers".to_string(),
                Json::Arr(vec![Json::str("fc1")]),
            ),
            (
                "input_hw".to_string(),
                Json::Arr(vec![
                    Json::num(self.mm.input_hw.0 as f64),
                    Json::num(self.mm.input_hw.1 as f64),
                ]),
            ),
            ("in_channels".to_string(), Json::num(self.mm.in_channels as f64)),
            ("num_classes".to_string(), Json::num(self.mm.num_classes as f64)),
            ("serve_batch".to_string(), Json::num(self.mm.batch as f64)),
        ]
    }
}

/// One conv→BN unit's position in the flat [`TrainState`] layout plus
/// its geometry: unit `u` owns params `[3u, 3u+3)` (w, γ, β) and BN
/// stats `[2u, 2u+2)` (mean, var) — the order
/// [`native_resnet_manifest`] emits.
#[derive(Clone, Copy)]
struct UnitIdx {
    u: usize,
    geom: ConvGeom,
}

/// One residual block's units in layout order (c1, c2, optional sc).
struct ResBlockIdx {
    name: String,
    stride: usize,
    c1: UnitIdx,
    c2: UnitIdx,
    sc: Option<UnitIdx>,
}

/// Everything one resnet forward pass leaves behind for the backward
/// pass. The per-unit vectors are indexed by [`UnitIdx::u`]; `y` holds
/// each unit's output *after* its own activation (post-ReLU for the
/// stem and c1, the raw BN output for c2 and projections — their
/// nonlinearity belongs to the join).
struct ResForwardPass {
    patches: Vec<Vec<f32>>,
    wq: Vec<Option<Vec<f32>>>,
    bn_mean: Vec<Vec<f32>>,
    bn_var: Vec<Vec<f32>>,
    inv_std: Vec<Vec<f32>>,
    xhat: Vec<Vec<f32>>,
    y: Vec<Vec<f32>>,
    /// Per block: post-join, post-ReLU output.
    join: Vec<Vec<f32>>,
    /// Global-average-pooled features, `[rows × c_last]`.
    gap: Vec<f32>,
    /// Fake-quantized fc input rows (`None` = `gap` used raw).
    flat_q: Option<Vec<f32>>,
    /// Fake-quantized fc weights (`None` = raw).
    fc_wq: Option<Vec<f32>>,
    probs: Vec<f32>,
    loss: f64,
    correct: usize,
}

/// The native resnet20-class trainer (DESIGN.md §18) — the fourth
/// [`StepBackend`]. Geometry lives here; all training state lives in
/// the caller's [`TrainState`], like every other backend.
pub struct ResNetNativeBackend {
    mm: ModelManifest,
    stem: UnitIdx,
    blocks: Vec<ResBlockIdx>,
    /// Total conv→BN units (stem + 2 or 3 per block).
    units: usize,
    /// Feature-map shape (h, w, c) entering the global average pool.
    feat: (usize, usize, usize),
    /// fc head (c_last, classes).
    fc: (usize, usize),
    eval_cache: RefCell<Option<ConvEvalCache>>,
    /// How many times the eval memo was (re)built — pinned by tests.
    eval_builds: Cell<usize>,
}

impl ResNetNativeBackend {
    pub fn new(
        batch: usize,
        hw: usize,
        in_channels: usize,
        classes: usize,
        channels: &[usize],
        blocks: usize,
    ) -> anyhow::Result<ResNetNativeBackend> {
        let mm = native_resnet_manifest(batch, hw, in_channels, classes, channels, blocks)
            .map_err(|e| anyhow::anyhow!(e))?;
        let mut u = 0usize;
        let stem = UnitIdx {
            u,
            geom: ConvGeom {
                h: hw,
                w: hw,
                c_in: in_channels,
                c_out: channels[0],
                kh: 3,
                kw: 3,
                stride: 1,
                pad: 1,
            },
        };
        u += 1;
        let mut side = hw;
        let mut c = channels[0];
        let mut blks = Vec::with_capacity(channels.len() * blocks);
        for (s, &c_out) in channels.iter().enumerate() {
            for b in 0..blocks {
                let stride = if s > 0 && b == 0 { 2 } else { 1 };
                let name = format!("res{}_{}", s + 1, b + 1);
                let c1 = UnitIdx {
                    u,
                    geom: ConvGeom {
                        h: side,
                        w: side,
                        c_in: c,
                        c_out,
                        kh: 3,
                        kw: 3,
                        stride,
                        pad: 1,
                    },
                };
                u += 1;
                let mid = side / stride;
                let c2 = UnitIdx {
                    u,
                    geom: ConvGeom {
                        h: mid,
                        w: mid,
                        c_in: c_out,
                        c_out,
                        kh: 3,
                        kw: 3,
                        stride: 1,
                        pad: 1,
                    },
                };
                u += 1;
                let sc = if stride != 1 || c != c_out {
                    let su = UnitIdx {
                        u,
                        geom: ConvGeom {
                            h: side,
                            w: side,
                            c_in: c,
                            c_out,
                            kh: 1,
                            kw: 1,
                            stride,
                            pad: 0,
                        },
                    };
                    u += 1;
                    Some(su)
                } else {
                    None
                };
                blks.push(ResBlockIdx { name, stride, c1, c2, sc });
                side = mid;
                c = c_out;
            }
        }
        Ok(ResNetNativeBackend {
            mm,
            stem,
            blocks: blks,
            units: u,
            feat: (side, side, c),
            fc: (c, classes),
            eval_cache: RefCell::new(None),
            eval_builds: Cell::new(0),
        })
    }

    /// Build from an [`ExperimentConfig`] (`backend = "native"`, a
    /// resnet model key): `image_hw`/`channels`/`blocks`/`batch` fix
    /// the geometry, the synthetic dataset fixes classes.
    pub fn from_config(cfg: &ExperimentConfig) -> anyhow::Result<ResNetNativeBackend> {
        let kind = DatasetKind::parse(&cfg.dataset).map_err(|e| anyhow::anyhow!(e))?;
        ResNetNativeBackend::new(
            cfg.batch,
            cfg.image_hw,
            3,
            kind.num_classes(),
            &cfg.channels,
            cfg.blocks,
        )
    }

    /// (name, unit) pairs in manifest/[`TrainState`] order: the stem,
    /// then `c1`/`c2`/(`sc`) per block.
    fn unit_list(&self) -> Vec<(String, UnitIdx)> {
        let mut v = vec![("stem".to_string(), self.stem)];
        for blk in &self.blocks {
            v.push((format!("{}.c1", blk.name), blk.c1));
            v.push((format!("{}.c2", blk.name), blk.c2));
            if let Some(su) = blk.sc {
                v.push((format!("{}.sc", blk.name), su));
            }
        }
        v
    }

    /// The `res_blocks` serving-meta array: one `{name, stride, proj}`
    /// object per block, the format `QuantConvNet::from_packed` reads.
    fn res_blocks_meta(&self) -> Json {
        Json::Arr(
            self.blocks
                .iter()
                .map(|b| {
                    Json::obj(vec![
                        ("name", Json::str(b.name.clone())),
                        ("stride", Json::num(b.stride as f64)),
                        ("proj", Json::Bool(b.sc.is_some())),
                    ])
                })
                .collect(),
        )
    }

    fn check_batch(&self, batch: &Batch) -> anyhow::Result<()> {
        anyhow::ensure!(
            batch.x.shape
                == vec![
                    self.mm.batch,
                    self.mm.input_hw.0,
                    self.mm.input_hw.1,
                    self.mm.in_channels
                ],
            "native resnet backend: batch x shape {:?} does not match manifest batch {}",
            batch.x.shape,
            self.mm.batch
        );
        anyhow::ensure!(
            batch.y.shape == vec![self.mm.batch],
            "native resnet backend: bad y shape"
        );
        Ok(())
    }

    /// Forward one conv→BN(→ReLU) unit and append its caches to `fwd`
    /// (units must be visited in layout order). Identical math to the
    /// smallcnn block forward minus pooling: im2col, per-patch-row
    /// activation fake-quant at k_a, per-tensor weight fake-quant at
    /// k_w, GEMM, batch-stat BN.
    fn unit_forward(
        &self,
        state: &TrainState,
        u: UnitIdx,
        src: &[f32],
        rows: usize,
        k_w: u32,
        k_a: u32,
        quant: bool,
        relu: bool,
        fwd: &mut ResForwardPass,
    ) {
        debug_assert_eq!(fwd.patches.len(), u.u, "units must be visited in layout order");
        let g = &u.geom;
        let (oh, ow) = g.out_hw();
        let k = g.patch_len();
        let cout = g.c_out;
        let prows = rows * oh * ow;
        let mut p = vec![0.0f32; prows * k];
        im2col(src, rows, g, &mut p);
        if quant && k_a < 24 {
            for r in 0..prows {
                activ::fake_quantize_row(&mut p[r * k..(r + 1) * k], k_a);
            }
        }
        let w = &state.params[3 * u.u].data;
        let wql = if quant && (1..=24).contains(&k_w) {
            let mut q = vec![0.0f32; w.len()];
            fake_quantize_tensor(w, k_w, &mut q);
            Some(q)
        } else {
            None
        };
        let win: &[f32] = wql.as_deref().unwrap_or(w);
        // z = patches × W  (no conv bias; BN supplies the shift)
        let mut z = vec![0.0f32; prows * cout];
        for r in 0..prows {
            let xrow = &p[r * k..(r + 1) * k];
            let orow = &mut z[r * cout..(r + 1) * cout];
            for (i, &xv) in xrow.iter().enumerate() {
                if xv == 0.0 {
                    continue;
                }
                for (o, &wv) in orow.iter_mut().zip(&win[i * cout..(i + 1) * cout]) {
                    *o += xv * wv;
                }
            }
        }
        // batch-stat BN (two-pass, f64 accumulation per channel)
        let n = prows as f64;
        let mut mean = vec![0.0f32; cout];
        let mut var = vec![0.0f32; cout];
        let mut acc = vec![0.0f64; cout];
        for r in 0..prows {
            for (a, &v) in acc.iter_mut().zip(&z[r * cout..(r + 1) * cout]) {
                *a += v as f64;
            }
        }
        for (m, &a) in mean.iter_mut().zip(&acc) {
            *m = (a / n) as f32;
        }
        acc.fill(0.0);
        for r in 0..prows {
            for (o, (a, &v)) in acc.iter_mut().zip(&z[r * cout..(r + 1) * cout]).enumerate() {
                let d = (v - mean[o]) as f64;
                *a += d * d;
            }
        }
        for (v, &a) in var.iter_mut().zip(&acc) {
            *v = (a / n) as f32;
        }
        let mut inv_std = vec![0.0f32; cout];
        for (s, &v) in inv_std.iter_mut().zip(&var) {
            *s = 1.0 / (v + BN_EPS).sqrt();
        }
        let gamma = &state.params[3 * u.u + 1].data;
        let beta = &state.params[3 * u.u + 2].data;
        let mut xhat = vec![0.0f32; prows * cout];
        let mut y = vec![0.0f32; prows * cout];
        for r in 0..prows {
            for o in 0..cout {
                let xh = (z[r * cout + o] - mean[o]) * inv_std[o];
                xhat[r * cout + o] = xh;
                let v = gamma[o] * xh + beta[o];
                y[r * cout + o] = if relu && v < 0.0 { 0.0 } else { v };
            }
        }
        fwd.patches.push(p);
        fwd.wq.push(wql);
        fwd.bn_mean.push(mean);
        fwd.bn_var.push(var);
        fwd.inv_std.push(inv_std);
        fwd.xhat.push(xhat);
        fwd.y.push(y);
    }

    /// The training/probe forward: batch-stat BN, fake-quant at
    /// (k_w, k_a) when `quant`, residual joins in f32, global average
    /// pooling through the serving [`global_avgpool`].
    fn forward(
        &self,
        state: &TrainState,
        batch: &Batch,
        k_w: u32,
        k_a: u32,
        quant: bool,
    ) -> ResForwardPass {
        let rows = self.mm.batch;
        let mut fwd = ResForwardPass {
            patches: Vec::with_capacity(self.units),
            wq: Vec::with_capacity(self.units),
            bn_mean: Vec::with_capacity(self.units),
            bn_var: Vec::with_capacity(self.units),
            inv_std: Vec::with_capacity(self.units),
            xhat: Vec::with_capacity(self.units),
            y: Vec::with_capacity(self.units),
            join: Vec::with_capacity(self.blocks.len()),
            gap: Vec::new(),
            flat_q: None,
            fc_wq: None,
            probs: Vec::new(),
            loss: 0.0,
            correct: 0,
        };
        self.unit_forward(state, self.stem, &batch.x.data, rows, k_w, k_a, quant, true, &mut fwd);
        let mut cur = fwd.y[0].clone();
        for blk in &self.blocks {
            self.unit_forward(state, blk.c1, &cur, rows, k_w, k_a, quant, true, &mut fwd);
            let mid = fwd.y[blk.c1.u].clone();
            self.unit_forward(state, blk.c2, &mid, rows, k_w, k_a, quant, false, &mut fwd);
            if let Some(su) = blk.sc {
                self.unit_forward(state, su, &cur, rows, k_w, k_a, quant, false, &mut fwd);
            }
            let trunk = &fwd.y[blk.c2.u];
            let shortcut: &[f32] = match blk.sc {
                Some(su) => &fwd.y[su.u],
                None => &cur,
            };
            let mut joined = vec![0.0f32; trunk.len()];
            for ((j, &t), &s) in joined.iter_mut().zip(trunk).zip(shortcut) {
                let u = t + s;
                *j = if u < 0.0 { 0.0 } else { u };
            }
            cur = joined.clone();
            fwd.join.push(joined);
        }

        // global average pool, then the fc head over [rows × c_last]
        let (flat, classes) = self.fc;
        let (fh, fw, fc) = self.feat;
        let mut gap = vec![0.0f32; rows * flat];
        global_avgpool(&cur, rows, fh, fw, fc, &mut gap);
        let flat_q = if quant && k_a < 24 {
            let mut q = gap.clone();
            for r in 0..rows {
                activ::fake_quantize_row(&mut q[r * flat..(r + 1) * flat], k_a);
            }
            Some(q)
        } else {
            None
        };
        let fcw = &state.params[3 * self.units].data;
        let fc_wq = if quant && (1..=24).contains(&k_w) {
            let mut q = vec![0.0f32; fcw.len()];
            fake_quantize_tensor(fcw, k_w, &mut q);
            Some(q)
        } else {
            None
        };
        let fcb = &state.params[3 * self.units + 1].data;
        let xin: &[f32] = flat_q.as_deref().unwrap_or(&gap);
        let win: &[f32] = fc_wq.as_deref().unwrap_or(fcw);
        let mut logits = vec![0.0f32; rows * classes];
        for r in 0..rows {
            let xrow = &xin[r * flat..(r + 1) * flat];
            let orow = &mut logits[r * classes..(r + 1) * classes];
            orow.copy_from_slice(fcb);
            for (i, &xv) in xrow.iter().enumerate() {
                if xv == 0.0 {
                    continue;
                }
                for (o, &wv) in orow.iter_mut().zip(&win[i * classes..(i + 1) * classes]) {
                    *o += xv * wv;
                }
            }
        }
        let (loss, correct, probs) = softmax_metrics(&logits, &batch.y.data, rows, classes);
        fwd.gap = gap;
        fwd.flat_q = flat_q;
        fwd.fc_wq = fc_wq;
        fwd.probs = probs;
        fwd.loss = loss;
        fwd.correct = correct;
        fwd
    }

    /// BN backward + weight gradient + SGD update for one unit; `dy` is
    /// the gradient at the unit's own output (the caller applies any
    /// ReLU gating first). Returns the gradient w.r.t. the unit input
    /// when `need_din` (the stem has no upstream, so it skips the
    /// col2im adjoint).
    fn unit_backward(
        &self,
        state: &mut TrainState,
        fwd: &ResForwardPass,
        u: UnitIdx,
        dy: &[f32],
        rows: usize,
        lr: f32,
        need_din: bool,
    ) -> Option<Vec<f32>> {
        let g = u.geom;
        let (oh, ow) = g.out_hw();
        let cout = g.c_out;
        let prows = rows * oh * ow;
        debug_assert_eq!(dy.len(), prows * cout);
        let pi = 3 * u.u;
        // batch-norm backward (batch statistics)
        let inv_std = &fwd.inv_std[u.u];
        let xhat = &fwd.xhat[u.u];
        let n = prows as f64;
        let mut sum_dy = vec![0.0f64; cout];
        let mut sum_dy_xh = vec![0.0f64; cout];
        for r in 0..prows {
            for o in 0..cout {
                let d = dy[r * cout + o] as f64;
                sum_dy[o] += d;
                sum_dy_xh[o] += d * xhat[r * cout + o] as f64;
            }
        }
        let ggamma: Vec<f32> = sum_dy_xh.iter().map(|&v| v as f32).collect();
        let gbeta: Vec<f32> = sum_dy.iter().map(|&v| v as f32).collect();
        let gamma = &state.params[pi + 1].data;
        let mut dz = vec![0.0f32; prows * cout];
        for o in 0..cout {
            let m1 = (sum_dy[o] / n) as f32;
            let m2 = (sum_dy_xh[o] / n) as f32;
            let f = gamma[o] * inv_std[o];
            for r in 0..prows {
                dz[r * cout + o] = f * (dy[r * cout + o] - m1 - xhat[r * cout + o] * m2);
            }
        }
        // weight gradient x̂ᵀδ over patch rows, then decay on raw w
        let k = g.patch_len();
        let mut gwc = vec![0.0f32; k * cout];
        for r in 0..prows {
            let xrow = &fwd.patches[u.u][r * k..(r + 1) * k];
            let drow = &dz[r * cout..(r + 1) * cout];
            for (i, &xv) in xrow.iter().enumerate() {
                if xv == 0.0 {
                    continue;
                }
                for (gv, &dv) in gwc[i * cout..(i + 1) * cout].iter_mut().zip(drow) {
                    *gv += xv * dv;
                }
            }
        }
        for (gv, &wv) in gwc.iter_mut().zip(&state.params[pi].data) {
            *gv += WEIGHT_DECAY * wv;
        }
        // input gradient through ŵ, scattered back through im2col
        let din = if need_din {
            let win: &[f32] = fwd.wq[u.u].as_deref().unwrap_or(&state.params[pi].data);
            let mut dp = vec![0.0f32; prows * k];
            for r in 0..prows {
                let drow = &dz[r * cout..(r + 1) * cout];
                let prow = &mut dp[r * k..(r + 1) * k];
                for (i, pv) in prow.iter_mut().enumerate() {
                    let mut s = 0.0f32;
                    for (&wv, &dv) in win[i * cout..(i + 1) * cout].iter().zip(drow) {
                        s += wv * dv;
                    }
                    *pv = s;
                }
            }
            let mut din = vec![0.0f32; rows * g.h * g.w * g.c_in];
            col2im(&dp, rows, &g, &mut din);
            Some(din)
        } else {
            None
        };
        sgd_update(&mut state.params[pi].data, &mut state.momentum[pi].data, &gwc, lr);
        sgd_update(&mut state.params[pi + 1].data, &mut state.momentum[pi + 1].data, &ggamma, lr);
        sgd_update(&mut state.params[pi + 2].data, &mut state.momentum[pi + 2].data, &gbeta, lr);
        din
    }

    /// STE backward + SGD update through the whole net. The residual
    /// join backward: gate by the join ReLU, send the gated gradient
    /// down the trunk (c2 → ReLU gate at c1's output → c1) *and*
    /// through the shortcut adjoint (projection unit backward, or a
    /// straight copy for identity), then sum the two input gradients.
    fn backward_update(
        &self,
        state: &mut TrainState,
        fwd: &ResForwardPass,
        batch: &Batch,
        lr: f32,
    ) {
        let rows = self.mm.batch;
        let (flat, classes) = self.fc;
        let nu = self.units;

        // δ at the logits: (softmax − one-hot) / rows
        let mut delta: Vec<f32> = fwd.probs.clone();
        for r in 0..rows {
            delta[r * classes + batch.y.data[r] as usize] -= 1.0;
        }
        let inv_rows = 1.0 / rows as f32;
        for v in delta.iter_mut() {
            *v *= inv_rows;
        }

        // ---- fc head over the pooled features
        let xh: &[f32] = fwd.flat_q.as_deref().unwrap_or(&fwd.gap);
        let mut gw = vec![0.0f32; flat * classes];
        for r in 0..rows {
            let xrow = &xh[r * flat..(r + 1) * flat];
            let drow = &delta[r * classes..(r + 1) * classes];
            for (i, &xv) in xrow.iter().enumerate() {
                if xv == 0.0 {
                    continue;
                }
                for (g, &dv) in gw[i * classes..(i + 1) * classes].iter_mut().zip(drow) {
                    *g += xv * dv;
                }
            }
        }
        for (g, &wv) in gw.iter_mut().zip(&state.params[3 * nu].data) {
            *g += WEIGHT_DECAY * wv;
        }
        let mut gb = vec![0.0f32; classes];
        for r in 0..rows {
            for (g, &dv) in gb.iter_mut().zip(&delta[r * classes..(r + 1) * classes]) {
                *g += dv;
            }
        }
        let fcw: &[f32] = fwd.fc_wq.as_deref().unwrap_or(&state.params[3 * nu].data);
        let mut dflat = vec![0.0f32; rows * flat];
        for r in 0..rows {
            let drow = &delta[r * classes..(r + 1) * classes];
            let ndrow = &mut dflat[r * flat..(r + 1) * flat];
            for (i, nd) in ndrow.iter_mut().enumerate() {
                let mut s = 0.0f32;
                for (&wv, &dv) in fcw[i * classes..(i + 1) * classes].iter().zip(drow) {
                    s += wv * dv;
                }
                *nd = s;
            }
        }
        sgd_update(&mut state.params[3 * nu].data, &mut state.momentum[3 * nu].data, &gw, lr);
        sgd_update(
            &mut state.params[3 * nu + 1].data,
            &mut state.momentum[3 * nu + 1].data,
            &gb,
            lr,
        );

        // ---- global-average-pool backward: δ spreads as δ/(h·w)
        let (fh, fww, fcc) = self.feat;
        let hw = fh * fww;
        let inv = 1.0 / hw as f32;
        let mut dcur = vec![0.0f32; rows * hw * fcc];
        for r in 0..rows {
            for p in 0..hw {
                for ch in 0..fcc {
                    dcur[(r * hw + p) * fcc + ch] = dflat[r * fcc + ch] * inv;
                }
            }
        }

        // ---- residual blocks, last to first
        for (bi, blk) in self.blocks.iter().enumerate().rev() {
            // ReLU gate at the join output
            let mut dj = dcur;
            for (d, &jv) in dj.iter_mut().zip(&fwd.join[bi]) {
                if jv <= 0.0 {
                    *d = 0.0;
                }
            }
            // trunk chain: c2, then the ReLU gate at c1's output, then c1
            let mut dmid = self
                .unit_backward(state, fwd, blk.c2, &dj, rows, lr, true)
                .expect("trunk c2 always needs din");
            for (d, &yv) in dmid.iter_mut().zip(&fwd.y[blk.c1.u]) {
                if yv <= 0.0 {
                    *d = 0.0;
                }
            }
            let din_trunk = self
                .unit_backward(state, fwd, blk.c1, &dmid, rows, lr, true)
                .expect("trunk c1 always needs din");
            // shortcut adjoint, summed with the trunk's input gradient
            dcur = match blk.sc {
                Some(su) => {
                    let mut d = self
                        .unit_backward(state, fwd, su, &dj, rows, lr, true)
                        .expect("projection always needs din");
                    for (a, &b) in d.iter_mut().zip(&din_trunk) {
                        *a += b;
                    }
                    d
                }
                None => {
                    let mut d = din_trunk;
                    for (a, &b) in d.iter_mut().zip(&dj) {
                        *a += b;
                    }
                    d
                }
            };
        }

        // ---- stem: ReLU gate, no upstream gradient needed
        let mut dstem = dcur;
        for (d, &yv) in dstem.iter_mut().zip(&fwd.y[0]) {
            if yv <= 0.0 {
                *d = 0.0;
            }
        }
        self.unit_backward(state, fwd, self.stem, &dstem, rows, lr, false);
    }

    /// Assemble a full serving checkpoint for the current state — the
    /// same tensor set `train::save_checkpoint` writes, with this
    /// backend's serving meta plus `k_a`.
    pub fn to_checkpoint(&self, state: &TrainState, k_a: u32) -> Checkpoint {
        let mut meta = Json::obj(vec![("k_a", Json::num(k_a as f64))]);
        if let Json::Obj(m) = &mut meta {
            for (k, v) in self.checkpoint_meta() {
                m.insert(k, v);
            }
        }
        let mut ck = Checkpoint::new(meta);
        for (spec, t) in self.mm.params.iter().zip(&state.params) {
            ck.push(spec.name.clone(), t.clone());
        }
        for (spec, t) in self.mm.bn.iter().zip(&state.bn) {
            ck.push(spec.name.clone(), t.clone());
        }
        ck
    }

    /// Pack the current weights + BN statistics exactly as
    /// `adaqat export` packs a saved checkpoint and build the integer
    /// residual kernels — the serving-identical forward.
    pub fn serving_resnet(
        &self,
        state: &TrainState,
        k_w: u32,
        k_a: u32,
    ) -> anyhow::Result<QuantConvNet> {
        let mut q = QuantizedCheckpoint::new(Json::obj(vec![
            ("k_a", Json::num(k_a as f64)),
            ("res_stem", Json::str("stem")),
            ("res_blocks", self.res_blocks_meta()),
            ("mlp_layers", Json::Arr(vec![Json::str("fc1")])),
            (
                "input_hw",
                Json::Arr(vec![
                    Json::num(self.mm.input_hw.0 as f64),
                    Json::num(self.mm.input_hw.1 as f64),
                ]),
            ),
            ("in_channels", Json::num(self.mm.in_channels as f64)),
        ]));
        let pack = |t: &crate::tensor::Tensor| -> PackedTensor {
            if (1..=24).contains(&k_w) {
                PackedTensor::quantize(t, k_w)
            } else {
                PackedTensor::raw(t)
            }
        };
        for (name, u) in self.unit_list() {
            q.push(format!("{name}.w"), pack(&state.params[3 * u.u]));
            q.push(format!("{name}.bn.g"), PackedTensor::raw(&state.params[3 * u.u + 1]));
            q.push(format!("{name}.bn.b"), PackedTensor::raw(&state.params[3 * u.u + 2]));
            q.push(format!("{name}.bn.mean"), PackedTensor::raw(&state.bn[2 * u.u]));
            q.push(format!("{name}.bn.var"), PackedTensor::raw(&state.bn[2 * u.u + 1]));
        }
        q.push("fc1.w", pack(&state.params[3 * self.units]));
        q.push("fc1.b", PackedTensor::raw(&state.params[3 * self.units + 1]));
        QuantConvNet::from_packed(&q)
    }

    /// [`ResNetNativeBackend::serving_resnet`] behind the
    /// fingerprint-keyed memo (weights, BN stats, bit-widths).
    fn cached_serving_resnet(
        &self,
        state: &TrainState,
        k_w: u32,
        k_a: u32,
    ) -> anyhow::Result<std::cell::RefMut<'_, QuantConvNet>> {
        let fp = state_fingerprint(state);
        let mut cache = self.eval_cache.borrow_mut();
        let hit = matches!(
            &*cache,
            Some(c) if c.fingerprint == fp && c.k_w == k_w && c.k_a == k_a
        );
        if !hit {
            *cache = Some(ConvEvalCache {
                fingerprint: fp,
                k_w,
                k_a,
                net: self.serving_resnet(state, k_w, k_a)?,
            });
            self.eval_builds.set(self.eval_builds.get() + 1);
        }
        Ok(std::cell::RefMut::map(cache, |c| {
            &mut c.as_mut().expect("just populated").net
        }))
    }

    /// Serving-identical predictions for `rows` flattened NHWC images —
    /// what the resnet e2e test cross-checks the served model against.
    pub fn predict(
        &self,
        state: &TrainState,
        x: &[f32],
        rows: usize,
        k_w: u32,
        k_a: u32,
    ) -> anyhow::Result<Vec<usize>> {
        Ok(self.cached_serving_resnet(state, k_w, k_a)?.classify(x, rows, 1))
    }
}

impl StepBackend for ResNetNativeBackend {
    fn mm(&self) -> &ModelManifest {
        &self.mm
    }

    fn init_state(&self, seed: u64) -> anyhow::Result<TrainState> {
        init_state_from_manifest(&self.mm, seed)
    }

    fn load_state(&self, ck: &Checkpoint, seed: u64) -> anyhow::Result<TrainState> {
        load_state_from_manifest(&self.mm, ck, seed)
    }

    fn train_step(
        &self,
        state: &mut TrainState,
        batch: &Batch,
        lr: f32,
        k_w: u32,
        k_a: u32,
        fp32: bool,
    ) -> anyhow::Result<StepMetrics> {
        self.check_batch(batch)?;
        let fwd = self.forward(state, batch, k_w, k_a, !fp32);
        self.backward_update(state, &fwd, batch, lr);
        // running statistics move only on real train steps (probes and
        // evals are forward-only, like the PJRT graphs)
        for u in 0..self.units {
            for (r, &b) in state.bn[2 * u].data.iter_mut().zip(&fwd.bn_mean[u]) {
                *r = (1.0 - BN_MOMENTUM) * *r + BN_MOMENTUM * b;
            }
            for (r, &b) in state.bn[2 * u + 1].data.iter_mut().zip(&fwd.bn_var[u]) {
                *r = (1.0 - BN_MOMENTUM) * *r + BN_MOMENTUM * b;
            }
        }
        Ok(StepMetrics { loss: fwd.loss as f32, correct: fwd.correct as f32 })
    }

    fn probe_loss(
        &self,
        state: &TrainState,
        batch: &Batch,
        k_w: u32,
        k_a: u32,
    ) -> anyhow::Result<StepMetrics> {
        self.check_batch(batch)?;
        let fwd = self.forward(state, batch, k_w, k_a, true);
        Ok(StepMetrics { loss: fwd.loss as f32, correct: fwd.correct as f32 })
    }

    fn eval_batch(
        &self,
        state: &TrainState,
        batch: &Batch,
        k_w: u32,
        k_a: u32,
        fp32: bool,
    ) -> anyhow::Result<StepMetrics> {
        self.check_batch(batch)?;
        let rows = self.mm.batch;
        let classes = self.mm.num_classes;
        // eval = the serving forward (memoized), so eval metrics and an
        // exported checkpoint can never drift apart (see the smallcnn
        // backend for the fp32-as-identity-widths rationale)
        let (k_w, k_a) = if fp32 { (32, 32) } else { (k_w, k_a) };
        let net = self.cached_serving_resnet(state, k_w, k_a)?;
        let logits = net.forward(&batch.x.data, rows, 1);
        let (loss, correct, _) = softmax_metrics(&logits, &batch.y.data, rows, classes);
        Ok(StepMetrics { loss: loss as f32, correct: correct as f32 })
    }

    fn has_fp32(&self) -> bool {
        true
    }

    fn checkpoint_meta(&self) -> Vec<(String, Json)> {
        vec![
            ("backend".to_string(), Json::str("native")),
            ("res_stem".to_string(), Json::str("stem")),
            ("res_blocks".to_string(), self.res_blocks_meta()),
            (
                "mlp_layers".to_string(),
                Json::Arr(vec![Json::str("fc1")]),
            ),
            (
                "input_hw".to_string(),
                Json::Arr(vec![
                    Json::num(self.mm.input_hw.0 as f64),
                    Json::num(self.mm.input_hw.1 as f64),
                ]),
            ),
            ("in_channels".to_string(), Json::num(self.mm.in_channels as f64)),
            ("num_classes".to_string(), Json::num(self.mm.num_classes as f64)),
            ("serve_batch".to_string(), Json::num(self.mm.batch as f64)),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backprop::manifest::{NATIVE_RESNET_KEY, NATIVE_SMALLCNN_KEY};
    use crate::data::{loader::Loader, synth, DatasetKind};
    use crate::tensor::{IntTensor, Tensor};

    /// A tiny conv backend + one real data batch for unit tests:
    /// 8×8×3 images, one 4-channel block, fc over 4·4·4 = 64 features.
    fn tiny(channels: &[usize]) -> (ConvNativeBackend, Batch) {
        let backend = ConvNativeBackend::new(8, 8, 3, 10, channels).unwrap();
        let ds = synth::generate_sized(DatasetKind::Cifar10, 8, 3, 0, 8, 8).into_shared();
        let batch = Loader::new(ds, 8, false).epoch(0).remove(0);
        (backend, batch)
    }

    #[test]
    fn geometry_and_param_layout_line_up() {
        let (backend, _) = tiny(&[4, 6]);
        assert_eq!(backend.blocks.len(), 2);
        assert_eq!(backend.blocks[0].h, 8);
        assert_eq!(backend.blocks[1].h, 4);
        assert_eq!(backend.blocks[1].c_in, 4);
        assert_eq!(backend.fc, (2 * 2 * 6, 10));
        assert_eq!(backend.mm.params.len(), 3 * 2 + 2);
        assert_eq!(backend.mm.bn.len(), 4);
        assert_eq!(backend.conv_layer_names(), vec!["conv1", "conv2"]);
    }

    #[test]
    fn col2im_is_the_adjoint_of_im2col() {
        // <im2col(x), y> == <x, col2im(y)> for random x, y — the
        // defining property of the transpose, which is exactly what the
        // backward pass needs col2im to be.
        let mut rng = crate::util::rng::Rng::new(17);
        for (stride, pad) in [(1usize, 1usize), (2, 0)] {
            let g = ConvGeom { h: 6, w: 5, c_in: 2, c_out: 1, kh: 3, kw: 3, stride, pad };
            let rows = 2usize;
            let (oh, ow) = g.out_hw();
            let k = g.patch_len();
            let x: Vec<f32> = (0..rows * g.h * g.w * 2).map(|_| rng.normal()).collect();
            let y: Vec<f32> = (0..rows * oh * ow * k).map(|_| rng.normal()).collect();
            let mut px = vec![0.0f32; rows * oh * ow * k];
            im2col(&x, rows, &g, &mut px);
            let mut cy = vec![0.0f32; rows * g.h * g.w * 2];
            col2im(&y, rows, &g, &mut cy);
            let lhs: f64 = px.iter().zip(&y).map(|(&a, &b)| a as f64 * b as f64).sum();
            let rhs: f64 = x.iter().zip(&cy).map(|(&a, &b)| a as f64 * b as f64).sum();
            assert!(
                (lhs - rhs).abs() <= 1e-3 * lhs.abs().max(1.0),
                "s={stride} p={pad}: <Ax,y>={lhs} vs <x,Aty>={rhs}"
            );
        }
    }

    #[test]
    fn fp32_gradients_match_finite_differences() {
        // infer the analytic gradient from one momentum-free update
        // (m0 = 0 ⇒ Δp = −lr·g) and check it against central
        // differences of the fp32 forward loss — this exercises the
        // conv, BN (batch-stat), pooling, and fc backward paths.
        let (backend, batch) = tiny(&[4]);
        let state0 = backend.init_state(1).unwrap();
        let lr = 1e-3f32;
        let mut stepped = state0.clone();
        backend.train_step(&mut stepped, &batch, lr, 32, 32, true).unwrap();
        let eps = 1e-2f32;
        // (param index, coordinate, weight-decayed?): conv w, BN γ/β,
        // fc w, fc b
        for (pi, xi, wd) in [
            (0usize, 0usize, true),
            (0, 61, true),
            (1, 2, false),
            (2, 3, false),
            (3, 123, true),
            (4, 5, false),
        ] {
            let analytic = (state0.params[pi].data[xi] - stepped.params[pi].data[xi]) / lr
                - if wd { WEIGHT_DECAY * state0.params[pi].data[xi] } else { 0.0 };
            let mut plus = state0.clone();
            plus.params[pi].data[xi] += eps;
            let lp = backend.probe_loss(&plus, &batch, 32, 32).unwrap().loss;
            let mut minus = state0.clone();
            minus.params[pi].data[xi] -= eps;
            let lm = backend.probe_loss(&minus, &batch, 32, 32).unwrap().loss;
            let fd = (lp - lm) / (2.0 * eps);
            assert!(
                (analytic - fd).abs() <= 3e-2 * analytic.abs().max(fd.abs()).max(0.05),
                "param {pi}[{xi}]: analytic {analytic} vs finite-diff {fd}"
            );
        }
    }

    #[test]
    fn training_reduces_loss_and_moves_running_stats() {
        let (backend, batch) = tiny(&[4]);
        let mut state = backend.init_state(0).unwrap();
        let init_bn = state.bn[0].data.clone();
        let first = backend.train_step(&mut state, &batch, 0.05, 8, 8, false).unwrap();
        let mut last = first;
        for _ in 0..80 {
            last = backend.train_step(&mut state, &batch, 0.05, 8, 8, false).unwrap();
        }
        assert!(last.loss.is_finite());
        assert!(
            last.loss < first.loss * 0.8,
            "loss did not decrease: {} -> {}",
            first.loss,
            last.loss
        );
        assert!(state.is_finite());
        assert_ne!(state.bn[0].data, init_bn, "running mean never updated");
    }

    #[test]
    fn probes_do_not_move_running_stats() {
        let (backend, batch) = tiny(&[4]);
        let state = backend.init_state(3).unwrap();
        let before: Vec<Vec<f32>> = state.bn.iter().map(|t| t.data.clone()).collect();
        backend.probe_loss(&state, &batch, 4, 8).unwrap();
        backend.eval_batch(&state, &batch, 4, 8, false).unwrap();
        for (t, b) in state.bn.iter().zip(&before) {
            assert_eq!(&t.data, b);
        }
    }

    #[test]
    fn quantized_training_works_and_low_bits_hurt() {
        // Train over FOUR batches, not one: with batch-stat BN
        // renormalizing after quantization, a single memorized 8-sample
        // batch can stay separable even at 1-bit weights (simulation:
        // L(1) < L(8) on some seeds) — 32 samples restore the wall on
        // every seed tried.
        let (backend, _) = tiny(&[4]);
        let ds = synth::generate_sized(DatasetKind::Cifar10, 32, 3, 0, 8, 8).into_shared();
        let batches = Loader::new(ds, 8, false).epoch(0);
        let mut state = backend.init_state(2).unwrap();
        for i in 0..80 {
            backend
                .train_step(&mut state, &batches[i % 4], 0.05, 8, 8, false)
                .unwrap();
        }
        let l8 = backend.probe_loss(&state, &batches[0], 8, 8).unwrap().loss;
        let l1 = backend.probe_loss(&state, &batches[0], 1, 8).unwrap().loss;
        assert!(l8.is_finite() && l1.is_finite());
        assert!(
            l1 > l8 + 0.05,
            "1-bit weights should hurt a trained conv net: L(1)={l1} vs L(8)={l8}"
        );
    }

    #[test]
    fn eval_batch_equals_serving_math_and_fp32_path_runs() {
        let (backend, batch) = tiny(&[4]);
        let mut state = backend.init_state(9).unwrap();
        for _ in 0..5 {
            backend.train_step(&mut state, &batch, 0.05, 8, 8, false).unwrap();
        }
        let ev = backend.eval_batch(&state, &batch, 4, 8, false).unwrap();
        // recompute through a fresh serving net: must agree exactly
        let net = backend.serving_convnet(&state, 4, 8).unwrap();
        let logits = net.forward(&batch.x.data, 8, 1);
        let (loss, correct, _) = softmax_metrics(&logits, &batch.y.data, 8, 10);
        assert_eq!(ev.loss.to_bits(), (loss as f32).to_bits());
        assert_eq!(ev.correct, correct as f32);
        let fp = backend.eval_batch(&state, &batch, 32, 32, true).unwrap();
        assert!(fp.loss.is_finite());
    }

    #[test]
    fn eval_cache_tracks_weights_bits_and_bn_stats() {
        let (backend, batch) = tiny(&[4]);
        let mut state = backend.init_state(8).unwrap();
        let a = backend.eval_batch(&state, &batch, 4, 8, false).unwrap();
        let b = backend.eval_batch(&state, &batch, 4, 8, false).unwrap();
        assert_eq!(backend.eval_builds.get(), 1, "second eval must hit the memo");
        assert_eq!(a.loss.to_bits(), b.loss.to_bits());
        backend.eval_batch(&state, &batch, 2, 8, false).unwrap();
        assert_eq!(backend.eval_builds.get(), 2, "bit-width change rebuilds");
        // a train step moves weights AND running stats — either alone
        // must invalidate; mutate only the BN stats to isolate them
        state.bn[0].data[0] += 0.25;
        backend.eval_batch(&state, &batch, 2, 8, false).unwrap();
        assert_eq!(backend.eval_builds.get(), 3, "BN-stat change rebuilds");
    }

    #[test]
    fn state_roundtrips_through_checkpoint() {
        let (backend, batch) = tiny(&[4]);
        let mut state = backend.init_state(5).unwrap();
        for _ in 0..3 {
            backend.train_step(&mut state, &batch, 0.05, 8, 8, false).unwrap();
        }
        let mut ck = Checkpoint::new(Json::Null);
        for (spec, t) in backend.mm().params.iter().zip(&state.params) {
            ck.push(spec.name.clone(), t.clone());
        }
        for (spec, t) in backend.mm().bn.iter().zip(&state.bn) {
            ck.push(spec.name.clone(), t.clone());
        }
        let restored = backend.load_state(&ck, 0).unwrap();
        let a = backend.probe_loss(&state, &batch, 4, 4).unwrap();
        let b = backend.probe_loss(&restored, &batch, 4, 4).unwrap();
        assert_eq!(a.loss.to_bits(), b.loss.to_bits());
        // and predictions go through the serving kernels identically
        let pa = backend.predict(&state, &batch.x.data, 8, 4, 8).unwrap();
        let pb = backend.predict(&restored, &batch.x.data, 8, 4, 8).unwrap();
        assert_eq!(pa, pb);
    }

    #[test]
    fn bad_batch_shape_is_rejected() {
        let (backend, _) = tiny(&[4]);
        let state = backend.init_state(0).unwrap();
        let bad = Batch {
            x: Tensor::zeros(vec![8, 4, 4, 3]),
            y: IntTensor::new(vec![8], vec![0; 8]),
        };
        assert!(backend.probe_loss(&state, &bad, 8, 8).is_err());
        assert!(ConvNativeBackend::new(8, 10, 3, 10, &[4, 8]).is_err(), "10 % 4 != 0");
    }

    #[test]
    fn from_config_uses_channels_and_model_key() {
        let mut cfg = ExperimentConfig::default_for(NATIVE_SMALLCNN_KEY);
        cfg.backend = "native".to_string();
        cfg.image_hw = 8;
        cfg.batch = 4;
        cfg.channels = vec![4];
        let backend = ConvNativeBackend::from_config(&cfg).unwrap();
        assert_eq!(backend.mm().key, NATIVE_SMALLCNN_KEY);
        assert_eq!(backend.mm().batch, 4);
        assert_eq!(backend.blocks.len(), 1);
    }

    /// A tiny resnet backend + one real data batch: 8×8×3 images, two
    /// stages ([4, 8]) of one block each — one identity block, one
    /// stride-2 projection block — GAP over 4×4×8, fc to 10 classes.
    fn tiny_res() -> (ResNetNativeBackend, Batch) {
        let backend = ResNetNativeBackend::new(8, 8, 3, 10, &[4, 8], 1).unwrap();
        let ds = synth::generate_sized(DatasetKind::Cifar10, 8, 3, 0, 8, 8).into_shared();
        let batch = Loader::new(ds, 8, false).epoch(0).remove(0);
        (backend, batch)
    }

    #[test]
    fn resnet_geometry_and_param_layout_line_up() {
        let (backend, _) = tiny_res();
        // stem + (c1, c2) + (c1, c2, sc) = 6 units
        assert_eq!(backend.units, 6);
        assert_eq!(backend.blocks.len(), 2);
        assert!(backend.blocks[0].sc.is_none(), "same-width stride-1 block is identity");
        assert!(backend.blocks[1].sc.is_some(), "stage transition needs a projection");
        assert_eq!(backend.blocks[1].stride, 2);
        assert_eq!(backend.blocks[1].c1.geom.h, 8);
        assert_eq!(backend.blocks[1].c2.geom.h, 4);
        assert_eq!(backend.blocks[1].sc.unwrap().geom.kh, 1);
        assert_eq!(backend.feat, (4, 4, 8));
        assert_eq!(backend.fc, (8, 10));
        assert_eq!(backend.mm.params.len(), 3 * 6 + 2);
        assert_eq!(backend.mm.bn.len(), 2 * 6);
        let names: Vec<String> = backend.unit_list().into_iter().map(|(n, _)| n).collect();
        assert_eq!(
            names,
            vec!["stem", "res1_1.c1", "res1_1.c2", "res2_1.c1", "res2_1.c2", "res2_1.sc"]
        );
        assert!(
            ResNetNativeBackend::new(8, 9, 3, 10, &[4, 8], 1).is_err(),
            "9 is not divisible by the stage-transition downsample"
        );
    }

    #[test]
    fn resnet_fp32_gradients_match_finite_differences() {
        // same recipe as the smallcnn test: infer the analytic gradient
        // from one momentum-free update and compare against central
        // differences. The coordinates cover the stem, the identity
        // block's trunk, the projection block's trunk AND its 1×1
        // shortcut (both join adjoints), BN γ/β, and the fc head.
        let (backend, batch) = tiny_res();
        let state0 = backend.init_state(1).unwrap();
        let lr = 1e-3f32;
        let mut stepped = state0.clone();
        backend.train_step(&mut stepped, &batch, lr, 32, 32, true).unwrap();
        let eps = 1e-2f32;
        for (pi, xi, wd) in [
            (0usize, 61usize, true), // stem.w
            (1, 2, false),           // stem.bn.g
            (3, 40, true),           // res1_1.c1.w (identity trunk)
            (8, 3, false),           // res1_1.c2.bn.b
            (9, 100, true),          // res2_1.c1.w (projection trunk)
            (15, 7, true),           // res2_1.sc.w (shortcut adjoint)
            (16, 5, false),          // res2_1.sc.bn.g
            (18, 33, true),          // fc1.w
            (19, 5, false),          // fc1.b
        ] {
            let analytic = (state0.params[pi].data[xi] - stepped.params[pi].data[xi]) / lr
                - if wd { WEIGHT_DECAY * state0.params[pi].data[xi] } else { 0.0 };
            let mut plus = state0.clone();
            plus.params[pi].data[xi] += eps;
            let lp = backend.probe_loss(&plus, &batch, 32, 32).unwrap().loss;
            let mut minus = state0.clone();
            minus.params[pi].data[xi] -= eps;
            let lm = backend.probe_loss(&minus, &batch, 32, 32).unwrap().loss;
            let fd = (lp - lm) / (2.0 * eps);
            assert!(
                (analytic - fd).abs() <= 3e-2 * analytic.abs().max(fd.abs()).max(0.05),
                "param {pi}[{xi}]: analytic {analytic} vs finite-diff {fd}"
            );
        }
    }

    #[test]
    fn resnet_training_reduces_loss_and_moves_running_stats() {
        let (backend, batch) = tiny_res();
        let mut state = backend.init_state(0).unwrap();
        let init_stem = state.bn[0].data.clone();
        let init_sc = state.bn[2 * 5].data.clone();
        let first = backend.train_step(&mut state, &batch, 0.05, 8, 8, false).unwrap();
        let mut last = first;
        for _ in 0..80 {
            last = backend.train_step(&mut state, &batch, 0.05, 8, 8, false).unwrap();
        }
        assert!(last.loss.is_finite());
        assert!(
            last.loss < first.loss * 0.8,
            "loss did not decrease: {} -> {}",
            first.loss,
            last.loss
        );
        assert!(state.is_finite());
        assert_ne!(state.bn[0].data, init_stem, "stem running mean never updated");
        assert_ne!(state.bn[2 * 5].data, init_sc, "projection running mean never updated");
    }

    #[test]
    fn resnet_probes_do_not_move_running_stats() {
        let (backend, batch) = tiny_res();
        let state = backend.init_state(3).unwrap();
        let before: Vec<Vec<f32>> = state.bn.iter().map(|t| t.data.clone()).collect();
        backend.probe_loss(&state, &batch, 4, 8).unwrap();
        backend.eval_batch(&state, &batch, 4, 8, false).unwrap();
        for (t, b) in state.bn.iter().zip(&before) {
            assert_eq!(&t.data, b);
        }
    }

    #[test]
    fn resnet_eval_batch_equals_serving_math_and_memo_tracks_state() {
        let (backend, batch) = tiny_res();
        let mut state = backend.init_state(9).unwrap();
        for _ in 0..5 {
            backend.train_step(&mut state, &batch, 0.05, 8, 8, false).unwrap();
        }
        let ev = backend.eval_batch(&state, &batch, 4, 8, false).unwrap();
        // recompute through a fresh serving net: must agree exactly
        let net = backend.serving_resnet(&state, 4, 8).unwrap();
        let logits = net.forward(&batch.x.data, 8, 1);
        let (loss, correct, _) = softmax_metrics(&logits, &batch.y.data, 8, 10);
        assert_eq!(ev.loss.to_bits(), (loss as f32).to_bits());
        assert_eq!(ev.correct, correct as f32);
        let fp = backend.eval_batch(&state, &batch, 32, 32, true).unwrap();
        assert!(fp.loss.is_finite());
        // the memo keys on (weights + BN stats, widths), like smallcnn
        let builds = backend.eval_builds.get();
        backend.eval_batch(&state, &batch, 32, 32, true).unwrap();
        assert_eq!(backend.eval_builds.get(), builds, "repeat eval must hit the memo");
        state.bn[0].data[0] += 0.25;
        backend.eval_batch(&state, &batch, 32, 32, true).unwrap();
        assert_eq!(backend.eval_builds.get(), builds + 1, "BN-stat change rebuilds");
    }

    #[test]
    fn resnet_state_roundtrips_through_checkpoint() {
        let (backend, batch) = tiny_res();
        let mut state = backend.init_state(5).unwrap();
        for _ in 0..3 {
            backend.train_step(&mut state, &batch, 0.05, 8, 8, false).unwrap();
        }
        let ck = backend.to_checkpoint(&state, 8);
        assert!(ck.meta.get("res_blocks").is_some(), "serving meta must ride along");
        let restored = backend.load_state(&ck, 0).unwrap();
        let a = backend.probe_loss(&state, &batch, 4, 4).unwrap();
        let b = backend.probe_loss(&restored, &batch, 4, 4).unwrap();
        assert_eq!(a.loss.to_bits(), b.loss.to_bits());
        // and predictions go through the serving kernels identically
        let pa = backend.predict(&state, &batch.x.data, 8, 4, 8).unwrap();
        let pb = backend.predict(&restored, &batch.x.data, 8, 4, 8).unwrap();
        assert_eq!(pa, pb);
    }

    #[test]
    fn resnet_from_config_uses_channels_and_blocks() {
        let mut cfg = ExperimentConfig::default_for(NATIVE_RESNET_KEY);
        cfg.backend = "native".to_string();
        cfg.image_hw = 8;
        cfg.batch = 4;
        cfg.channels = vec![4, 8];
        cfg.blocks = 1;
        let backend = ResNetNativeBackend::from_config(&cfg).unwrap();
        assert_eq!(backend.mm().key, NATIVE_RESNET_KEY);
        assert_eq!(backend.mm().batch, 4);
        assert_eq!(backend.blocks.len(), 2);
        assert_eq!(backend.units, 6);
    }
}

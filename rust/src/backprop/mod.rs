//! Pure-Rust quantized training backend (DESIGN.md §12; conv in §13).
//!
//! Three native [`StepBackend`]s live here: this module's MLP trainer,
//! the smallcnn conv trainer in [`conv`] ([`ConvNativeBackend`]), and
//! the resnet20-class residual trainer ([`ResNetNativeBackend`],
//! DESIGN.md §18), all selected through [`build_native`].
//!
//! The MLP backend: a fc stack trained entirely in-process —
//! fake-quant forward on the shared s = 2^k − 1 grid, softmax
//! cross-entropy, straight-through-estimator backward, SGD with
//! momentum — so `Experiment::run` executes offline end-to-end with no
//! PJRT artifacts and no Python anywhere. This is what lets the AdaQAT
//! controller be driven by *measured* gradient-descent losses in CI,
//! and what produces real checkpoints for the serve/kernels subsystems
//! to consume (train → export → serve closes on any box).
//!
//! Quantizer semantics:
//! * **Weights** — per-tensor symmetric max-abs grid, exactly
//!   `PackedTensor::quantize ∘ dequantize` (`fake_quantize_tensor`), so
//!   the weights the training forward sees are bit-identical to what an
//!   exported `AQQCKPT1` checkpoint reconstructs. (The PJRT graphs use
//!   DoReFa's tanh reparameterization instead — a deliberate
//!   per-backend difference, documented in DESIGN.md §12.)
//! * **Activations** — per-row max-abs grid via
//!   [`crate::kernels::activ::fake_quantize_row`], the same function
//!   the integer serving kernels evaluate.
//! * **Backward** — straight-through: both quantizers differentiate as
//!   identity (paper §III-A), ReLU masks by its forward output.
//!
//! Evaluation goes through [`NativeBackend::serving_mlp`]: the current
//! weights are packed exactly as `adaqat export` packs them and run on
//! the integer kernels ([`crate::kernels::QuantMlp`]), so the trainer's
//! eval forward and the served model are the *same numbers* — the e2e
//! test asserts every prediction matches.

pub mod conv;
pub mod manifest;

pub use conv::{ConvNativeBackend, ResNetNativeBackend};
pub use manifest::{
    is_native_conv_model, is_native_resnet_model, native_manifest, native_resnet_manifest,
    native_smallcnn_manifest, validate_resnet_geometry, validate_smallcnn_geometry,
    NATIVE_MODEL_KEY, NATIVE_RESNET_KEY, NATIVE_SMALLCNN_KEY,
};

use std::cell::{Cell, RefCell};

use crate::config::ExperimentConfig;
use crate::data::DatasetKind;
use crate::kernels::{activ, QuantMlp};
use crate::quant::code_levels;
use crate::runtime::{
    init_state_from_manifest, load_state_from_manifest, Batch, ModelManifest, StepBackend,
    StepMetrics, TrainState,
};
use crate::serve::packed::{PackedTensor, QuantizedCheckpoint};
use crate::tensor::checkpoint::Checkpoint;
use crate::util::json::Json;

/// SGD momentum, mirroring `python/compile/steps.py::MOMENTUM`.
pub const MOMENTUM: f32 = 0.9;
/// Weight decay on `.w` tensors, mirroring `steps.py::WEIGHT_DECAY`.
pub const WEIGHT_DECAY: f32 = 1e-4;

/// Fake-quantize a weight tensor on the packed-checkpoint grid —
/// bit-for-bit `PackedTensor::quantize(t, bits).dequantize()`:
/// s = 2^k − 1 symmetric levels over [−max|w|, +max|w|], value
/// (2c − s)·Δ with Δ = max|w|/s. An all-zero tensor stays all-zero.
pub fn fake_quantize_tensor(w: &[f32], bits: u32, out: &mut [f32]) {
    debug_assert!((1..=24).contains(&bits), "fake_quantize_tensor wants bits in 1..=24");
    debug_assert_eq!(w.len(), out.len());
    let s = code_levels(bits) as f32;
    let s_i = code_levels(bits) as i32;
    let scale = w.iter().fold(0.0f32, |m, x| m.max(x.abs()));
    if !(scale > 0.0) {
        out.fill(0.0);
        return;
    }
    let inv = 0.5 / scale;
    let step = scale / s_i as f32;
    for (o, &x) in out.iter_mut().zip(w) {
        let c = ((x * inv + 0.5).clamp(0.0, 1.0) * s).round() as i32;
        *o = (2 * c - s_i) as f32 * step;
    }
}

/// Everything one forward pass leaves behind for the backward pass.
/// The quantized copies are `None` when a signal was not quantized —
/// the backward pass then reads the raw buffer (the batch, `act`, or
/// the unmodified weights in `TrainState`) instead of a clone, so the
/// fp32 path allocates nothing per layer beyond its outputs.
struct ForwardPass {
    /// Per layer: the fake-quantized input rows, `[rows × d_in]`
    /// (`None` = input used as-is: `act[l−1]`, or the batch at l = 0).
    xhat: Vec<Option<Vec<f32>>>,
    /// Per layer: post-activation output (`[rows × d_out]`; the last
    /// entry is the logits).
    act: Vec<Vec<f32>>,
    /// Per layer: the fake-quantized weights the forward used
    /// (`None` = raw weights straight from the state).
    wq: Vec<Option<Vec<f32>>>,
    /// Softmax probabilities, `[rows × classes]`.
    probs: Vec<f32>,
    loss: f64,
    correct: usize,
}

/// A memoized serving model: `evaluate` calls `eval_batch` once per
/// test batch with identical weights, so the packed [`QuantMlp`] is
/// rebuilt only when the weights or the bit-widths actually change.
struct EvalCache {
    fingerprint: u64,
    k_w: u32,
    k_a: u32,
    mlp: QuantMlp,
}

/// The native MLP trainer. Holds the manifest-derived geometry plus an
/// eval-only memo; all training state lives in the caller's
/// [`TrainState`], exactly like the PJRT backend.
pub struct NativeBackend {
    mm: ModelManifest,
    /// Per layer (d_in, d_out).
    dims: Vec<(usize, usize)>,
    eval_cache: RefCell<Option<EvalCache>>,
    /// How many times the eval memo was (re)built — pinned by tests.
    eval_builds: Cell<usize>,
}

/// FNV-1a over the bit patterns of every parameter — the cheap "did
/// the weights change" key for the eval memo (one read pass, vs the
/// quantize + bit-pack + unpack + transpose a rebuild costs).
fn weight_fingerprint(state: &TrainState) -> u64 {
    let mut h = crate::util::FNV1A_BASIS;
    for t in &state.params {
        for &v in &t.data {
            h = crate::util::fnv1a_mix(h, v.to_bits() as u64);
        }
    }
    h
}

impl NativeBackend {
    pub fn new(
        batch: usize,
        hw: usize,
        in_channels: usize,
        classes: usize,
        hidden: &[usize],
    ) -> anyhow::Result<NativeBackend> {
        let mm = native_manifest(batch, hw, in_channels, classes, hidden)
            .map_err(|e| anyhow::anyhow!(e))?;
        let dims = mm
            .params
            .iter()
            .filter(|p| p.role == "fc_w")
            .map(|p| (p.shape[0], p.shape[1]))
            .collect();
        Ok(NativeBackend {
            mm,
            dims,
            eval_cache: RefCell::new(None),
            eval_builds: Cell::new(0),
        })
    }

    /// Build from an [`ExperimentConfig`] (`backend = "native"`): the
    /// synthetic dataset fixes channels/classes, `image_hw`/`hidden`/
    /// `batch` fix the geometry.
    pub fn from_config(cfg: &ExperimentConfig) -> anyhow::Result<NativeBackend> {
        let kind = DatasetKind::parse(&cfg.dataset).map_err(|e| anyhow::anyhow!(e))?;
        NativeBackend::new(cfg.batch, cfg.image_hw, 3, kind.num_classes(), &cfg.hidden)
    }

    /// Layer names in `mlp_layers` order (`fc1`, `fc2`, …).
    pub fn layer_names(&self) -> Vec<String> {
        (1..=self.dims.len()).map(|i| format!("fc{i}")).collect()
    }

    fn check_batch(&self, batch: &Batch) -> anyhow::Result<()> {
        anyhow::ensure!(
            batch.x.shape
                == vec![
                    self.mm.batch,
                    self.mm.input_hw.0,
                    self.mm.input_hw.1,
                    self.mm.in_channels
                ],
            "native backend: batch x shape {:?} does not match manifest batch {}",
            batch.x.shape,
            self.mm.batch
        );
        anyhow::ensure!(batch.y.shape == vec![self.mm.batch], "native backend: bad y shape");
        Ok(())
    }

    /// The training/probe forward: fake-quant at (k_w, k_a) when
    /// `quant`, plain f32 otherwise. Loss/softmax accumulate in f64.
    ///
    /// Width thresholds mirror the packed/serving side exactly, so the
    /// training forward and an exported checkpoint can never disagree:
    /// weights quantize for k_w ∈ 1..=24 (the packable range — 24 is a
    /// *real* grid here, unlike `bitwidth_scale`'s f32-identity scale)
    /// and stay raw above; activations quantize for k_a < 24 (the
    /// kernels' own fake-quant threshold in [`QuantMlp::forward`]).
    fn forward(
        &self,
        state: &TrainState,
        batch: &Batch,
        k_w: u32,
        k_a: u32,
        quant: bool,
    ) -> ForwardPass {
        let rows = self.mm.batch;
        let last = self.dims.len() - 1;
        let mut xhat: Vec<Option<Vec<f32>>> = Vec::with_capacity(self.dims.len());
        let mut act: Vec<Vec<f32>> = Vec::with_capacity(self.dims.len());
        let mut wq: Vec<Option<Vec<f32>>> = Vec::with_capacity(self.dims.len());
        for (l, &(d_in, d_out)) in self.dims.iter().enumerate() {
            let w = &state.params[2 * l].data;
            let bias = &state.params[2 * l + 1].data;
            let src: &[f32] = if l == 0 { &batch.x.data } else { &act[l - 1] };
            let xh = if quant && k_a < 24 {
                let mut q = src.to_vec();
                for r in 0..rows {
                    activ::fake_quantize_row(&mut q[r * d_in..(r + 1) * d_in], k_a);
                }
                Some(q)
            } else {
                None
            };
            let wql = if quant && (1..=24).contains(&k_w) {
                let mut q = vec![0.0f32; w.len()];
                fake_quantize_tensor(w, k_w, &mut q);
                Some(q)
            } else {
                None
            };
            let xin: &[f32] = xh.as_deref().unwrap_or(src);
            let win: &[f32] = wql.as_deref().unwrap_or(w);
            let mut out = vec![0.0f32; rows * d_out];
            for r in 0..rows {
                let xrow = &xin[r * d_in..(r + 1) * d_in];
                let orow = &mut out[r * d_out..(r + 1) * d_out];
                orow.copy_from_slice(bias);
                for (i, &xv) in xrow.iter().enumerate() {
                    if xv == 0.0 {
                        continue;
                    }
                    for (o, &wv) in orow.iter_mut().zip(&win[i * d_out..(i + 1) * d_out]) {
                        *o += xv * wv;
                    }
                }
            }
            if l != last {
                for v in out.iter_mut() {
                    if *v < 0.0 {
                        *v = 0.0;
                    }
                }
            }
            xhat.push(xh);
            wq.push(wql);
            act.push(out);
        }

        let classes = self.dims[last].1;
        let logits = &act[last];
        let (loss, correct, probs) = softmax_metrics(logits, &batch.y.data, rows, classes);
        ForwardPass { xhat, act, wq, probs, loss, correct }
    }

    /// STE backward + SGD-with-momentum update (mirrors the fused PJRT
    /// train graph: momentum 0.9, weight decay 1e-4 on `.w` only, both
    /// quantizers and the batch-mean CE differentiate straight-through
    /// onto the fake-quantized forward values).
    fn backward_update(
        &self,
        state: &mut TrainState,
        fwd: &ForwardPass,
        batch: &Batch,
        lr: f32,
    ) {
        let rows = self.mm.batch;
        let last = self.dims.len() - 1;
        let classes = self.dims[last].1;
        // δ at the logits: (softmax − one-hot) / rows
        let mut delta: Vec<f32> = fwd.probs.clone();
        for r in 0..rows {
            delta[r * classes + batch.y.data[r] as usize] -= 1.0;
        }
        let inv_rows = 1.0 / rows as f32;
        for v in delta.iter_mut() {
            *v *= inv_rows;
        }

        for l in (0..=last).rev() {
            let (d_in, d_out) = self.dims[l];
            // the forward's input rows: the quantized copy, or (when the
            // forward quantized nothing) the raw source it read directly
            let xh: &[f32] = match &fwd.xhat[l] {
                Some(x) => x,
                None if l == 0 => &batch.x.data,
                None => &fwd.act[l - 1],
            };
            // weight gradient x̂ᵀδ, then decay on the *raw* weights
            let mut gw = vec![0.0f32; d_in * d_out];
            for r in 0..rows {
                let xrow = &xh[r * d_in..(r + 1) * d_in];
                let drow = &delta[r * d_out..(r + 1) * d_out];
                for (i, &xv) in xrow.iter().enumerate() {
                    if xv == 0.0 {
                        continue;
                    }
                    for (g, &dv) in gw[i * d_out..(i + 1) * d_out].iter_mut().zip(drow) {
                        *g += xv * dv;
                    }
                }
            }
            for (g, &wv) in gw.iter_mut().zip(&state.params[2 * l].data) {
                *g += WEIGHT_DECAY * wv;
            }
            let mut gb = vec![0.0f32; d_out];
            for r in 0..rows {
                for (g, &dv) in gb.iter_mut().zip(&delta[r * d_out..(r + 1) * d_out]) {
                    *g += dv;
                }
            }
            // propagate δ through ŵ and the previous ReLU before the
            // parameters move: layer l's weights are untouched until the
            // update below, so the raw-weight fallback still reads the
            // forward's values
            if l > 0 {
                let wql: &[f32] = match &fwd.wq[l] {
                    Some(q) => q,
                    None => &state.params[2 * l].data,
                };
                let prev = &fwd.act[l - 1];
                let mut nd = vec![0.0f32; rows * d_in];
                for r in 0..rows {
                    let drow = &delta[r * d_out..(r + 1) * d_out];
                    let ndrow = &mut nd[r * d_in..(r + 1) * d_in];
                    for i in 0..d_in {
                        if prev[r * d_in + i] <= 0.0 {
                            continue; // ReLU gate (quantizer is straight-through)
                        }
                        let mut s = 0.0f32;
                        for (&wv, &dv) in wql[i * d_out..(i + 1) * d_out].iter().zip(drow) {
                            s += wv * dv;
                        }
                        ndrow[i] = s;
                    }
                }
                delta = nd;
            }
            // SGD + momentum: m ← 0.9m + g;  p ← p − lr·m
            for ((w, m), &g) in state.params[2 * l]
                .data
                .iter_mut()
                .zip(state.momentum[2 * l].data.iter_mut())
                .zip(&gw)
            {
                *m = MOMENTUM * *m + g;
                *w -= lr * *m;
            }
            for ((b, m), &g) in state.params[2 * l + 1]
                .data
                .iter_mut()
                .zip(state.momentum[2 * l + 1].data.iter_mut())
                .zip(&gb)
            {
                *m = MOMENTUM * *m + g;
                *b -= lr * *m;
            }
        }
    }

    /// Pack the current weights exactly as `adaqat export` packs a
    /// saved checkpoint and build the integer-kernel [`QuantMlp`] —
    /// the serving-identical forward. k_w ≥ 25 keeps weights raw f32
    /// (the "not quantized" rows); k_a flows through the meta so the
    /// kernels quantize activations at the learned width.
    pub fn serving_mlp(
        &self,
        state: &TrainState,
        k_w: u32,
        k_a: u32,
    ) -> anyhow::Result<QuantMlp> {
        let names = self.layer_names();
        let mut q = QuantizedCheckpoint::new(Json::obj(vec![
            ("k_a", Json::num(k_a as f64)),
            (
                "mlp_layers",
                Json::Arr(names.iter().map(|n| Json::str(n.clone())).collect()),
            ),
        ]));
        for (l, name) in names.iter().enumerate() {
            let w = &state.params[2 * l];
            let b = &state.params[2 * l + 1];
            let pw = if (1..=24).contains(&k_w) {
                PackedTensor::quantize(w, k_w)
            } else {
                PackedTensor::raw(w)
            };
            q.push(format!("{name}.w"), pw);
            q.push(format!("{name}.b"), PackedTensor::raw(b));
        }
        QuantMlp::from_packed(&q)
    }

    /// [`NativeBackend::serving_mlp`] behind the fingerprint-keyed memo:
    /// rebuilt only when the weights or the bit-widths changed since the
    /// last call (evaluation sweeps and per-sample prediction loops pass
    /// identical weights every time).
    fn cached_serving_mlp(
        &self,
        state: &TrainState,
        k_w: u32,
        k_a: u32,
    ) -> anyhow::Result<std::cell::RefMut<'_, QuantMlp>> {
        let fp = weight_fingerprint(state);
        let mut cache = self.eval_cache.borrow_mut();
        let hit = matches!(
            &*cache,
            Some(c) if c.fingerprint == fp && c.k_w == k_w && c.k_a == k_a
        );
        if !hit {
            *cache = Some(EvalCache {
                fingerprint: fp,
                k_w,
                k_a,
                mlp: self.serving_mlp(state, k_w, k_a)?,
            });
            self.eval_builds.set(self.eval_builds.get() + 1);
        }
        Ok(std::cell::RefMut::map(cache, |c| {
            &mut c.as_mut().expect("just populated").mlp
        }))
    }

    /// Serving-identical predictions for `rows` flattened images — what
    /// the e2e test cross-checks the exported/served model against.
    /// Memoized like `eval_batch`: classifying a stream sample-by-sample
    /// packs the model once, not once per sample.
    pub fn predict(
        &self,
        state: &TrainState,
        x: &[f32],
        rows: usize,
        k_w: u32,
        k_a: u32,
    ) -> anyhow::Result<Vec<usize>> {
        Ok(self.cached_serving_mlp(state, k_w, k_a)?.classify(x, rows, 1))
    }
}

/// The native step backend a config's model key selects: a conv model
/// key (`smallcnn`/[`NATIVE_SMALLCNN_KEY`]) builds the
/// [`ConvNativeBackend`], a residual key
/// (`resnet20`/[`NATIVE_RESNET_KEY`]) the [`ResNetNativeBackend`],
/// anything else the MLP [`NativeBackend`] — the one dispatch point
/// the CLI and tools share.
pub fn build_native(cfg: &ExperimentConfig) -> anyhow::Result<Box<dyn StepBackend>> {
    if is_native_conv_model(&cfg.model) {
        Ok(Box::new(ConvNativeBackend::from_config(cfg)?))
    } else if is_native_resnet_model(&cfg.model) {
        Ok(Box::new(ResNetNativeBackend::from_config(cfg)?))
    } else {
        Ok(Box::new(NativeBackend::from_config(cfg)?))
    }
}

/// Mean CE loss (f64 log-sum-exp), correct count (argmax, lowest index
/// on ties — the kernels' rule), and softmax probabilities.
pub(crate) fn softmax_metrics(
    logits: &[f32],
    labels: &[i32],
    rows: usize,
    classes: usize,
) -> (f64, usize, Vec<f32>) {
    let mut probs = vec![0.0f32; rows * classes];
    let mut loss = 0.0f64;
    let mut correct = 0usize;
    for r in 0..rows {
        let row = &logits[r * classes..(r + 1) * classes];
        let y = labels[r] as usize;
        let max = row.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
        let mut sum = 0.0f64;
        for &v in row {
            sum += ((v - max) as f64).exp();
        }
        loss += (max as f64 + sum.ln()) - row[y] as f64;
        let mut best = 0usize;
        let mut best_score = f32::NEG_INFINITY;
        for (i, &v) in row.iter().enumerate() {
            if v > best_score {
                best_score = v;
                best = i;
            }
        }
        if best == y {
            correct += 1;
        }
        for (p, &v) in probs[r * classes..(r + 1) * classes].iter_mut().zip(row) {
            *p = (((v - max) as f64).exp() / sum) as f32;
        }
    }
    (loss / rows.max(1) as f64, correct, probs)
}

impl StepBackend for NativeBackend {
    fn mm(&self) -> &ModelManifest {
        &self.mm
    }

    fn init_state(&self, seed: u64) -> anyhow::Result<TrainState> {
        init_state_from_manifest(&self.mm, seed)
    }

    fn load_state(&self, ck: &Checkpoint, seed: u64) -> anyhow::Result<TrainState> {
        load_state_from_manifest(&self.mm, ck, seed)
    }

    fn train_step(
        &self,
        state: &mut TrainState,
        batch: &Batch,
        lr: f32,
        k_w: u32,
        k_a: u32,
        fp32: bool,
    ) -> anyhow::Result<StepMetrics> {
        self.check_batch(batch)?;
        let fwd = self.forward(state, batch, k_w, k_a, !fp32);
        self.backward_update(state, &fwd, batch, lr);
        Ok(StepMetrics { loss: fwd.loss as f32, correct: fwd.correct as f32 })
    }

    fn probe_loss(
        &self,
        state: &TrainState,
        batch: &Batch,
        k_w: u32,
        k_a: u32,
    ) -> anyhow::Result<StepMetrics> {
        self.check_batch(batch)?;
        let fwd = self.forward(state, batch, k_w, k_a, true);
        Ok(StepMetrics { loss: fwd.loss as f32, correct: fwd.correct as f32 })
    }

    fn eval_batch(
        &self,
        state: &TrainState,
        batch: &Batch,
        k_w: u32,
        k_a: u32,
        fp32: bool,
    ) -> anyhow::Result<StepMetrics> {
        self.check_batch(batch)?;
        if fp32 {
            let fwd = self.forward(state, batch, 32, 32, false);
            return Ok(StepMetrics { loss: fwd.loss as f32, correct: fwd.correct as f32 });
        }
        // quantized eval = the serving forward, so eval metrics and an
        // exported checkpoint's served behavior can never drift apart;
        // memoized because evaluate() sweeps many batches per rebuild
        let rows = self.mm.batch;
        let classes = self.mm.num_classes;
        let mlp = self.cached_serving_mlp(state, k_w, k_a)?;
        let logits = mlp.forward(&batch.x.data, rows, 1);
        let (loss, correct, _) = softmax_metrics(&logits, &batch.y.data, rows, classes);
        Ok(StepMetrics { loss: loss as f32, correct: correct as f32 })
    }

    fn has_fp32(&self) -> bool {
        true
    }

    fn checkpoint_meta(&self) -> Vec<(String, Json)> {
        vec![
            ("backend".to_string(), Json::str("native")),
            (
                "mlp_layers".to_string(),
                Json::Arr(self.layer_names().into_iter().map(Json::str).collect()),
            ),
            (
                "input_hw".to_string(),
                Json::Arr(vec![
                    Json::num(self.mm.input_hw.0 as f64),
                    Json::num(self.mm.input_hw.1 as f64),
                ]),
            ),
            ("in_channels".to_string(), Json::num(self.mm.in_channels as f64)),
            ("num_classes".to_string(), Json::num(self.mm.num_classes as f64)),
            ("serve_batch".to_string(), Json::num(self.mm.batch as f64)),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{loader::Loader, synth, DatasetKind};
    use crate::tensor::Tensor;

    /// A tiny backend + one real data batch for unit tests.
    fn tiny(hidden: &[usize]) -> (NativeBackend, Batch) {
        let backend = NativeBackend::new(8, 8, 3, 10, hidden).unwrap();
        let ds = synth::generate_sized(DatasetKind::Cifar10, 8, 3, 0, 8, 8).into_shared();
        let batch = Loader::new(ds, 8, false).epoch(0).remove(0);
        (backend, batch)
    }

    #[test]
    fn fake_quant_matches_packed_roundtrip_bitwise() {
        let mut rng = crate::util::rng::Rng::new(7);
        for bits in [1u32, 2, 3, 4, 8, 15, 24] {
            let t = Tensor::new(vec![37, 5], (0..185).map(|_| rng.normal() * 0.3).collect());
            let mut fq = vec![0.0f32; t.numel()];
            fake_quantize_tensor(&t.data, bits, &mut fq);
            let rt = PackedTensor::quantize(&t, bits).dequantize();
            for (a, b) in fq.iter().zip(&rt.data) {
                assert_eq!(a.to_bits(), b.to_bits(), "bits={bits}");
            }
        }
        // zero tensor stays zero
        let mut z = vec![1.0f32; 4];
        fake_quantize_tensor(&[0.0; 4], 4, &mut z);
        assert!(z.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn forward_weight_grid_matches_packed_range_at_the_24_bit_edge() {
        // export packs k_w ∈ 1..=24; the training forward must agree at
        // the edge: 24 is a real grid, 25+ is raw — on both paths
        let (backend, batch) = tiny(&[6]);
        let state = backend.init_state(11).unwrap();
        for (k, quantized) in [(24u32, true), (25, false), (32, false)] {
            let fwd = backend.forward(&state, &batch, k, 8, true);
            if quantized {
                let expect = PackedTensor::quantize(&state.params[0], k).dequantize().data;
                assert_eq!(fwd.wq[0].as_deref(), Some(&expect[..]), "k={k}");
            } else {
                assert!(fwd.wq[0].is_none(), "k={k}: raw weights must not be copied");
            }
        }
    }

    #[test]
    fn fp32_gradients_match_finite_differences() {
        // infer the analytic gradient from one momentum-free update
        // (m0 = 0 ⇒ Δp = −lr·g) and check it against central
        // differences of the fp32 forward loss.
        let (backend, batch) = tiny(&[6]);
        let state0 = backend.init_state(1).unwrap();
        let lr = 1e-3f32;
        let mut stepped = state0.clone();
        backend
            .train_step(&mut stepped, &batch, lr, 32, 32, true)
            .unwrap();
        let eps = 1e-2f32;
        // a spread of weight/bias coordinates across both layers
        for (pi, xi) in [(0usize, 0usize), (0, 777), (1, 3), (2, 11), (3, 5)] {
            let analytic = (state0.params[pi].data[xi] - stepped.params[pi].data[xi]) / lr
                - WEIGHT_DECAY
                    * if pi % 2 == 0 { state0.params[pi].data[xi] } else { 0.0 };
            let mut plus = state0.clone();
            plus.params[pi].data[xi] += eps;
            let lp = backend.probe_loss(&plus, &batch, 32, 32).unwrap().loss;
            let mut minus = state0.clone();
            minus.params[pi].data[xi] -= eps;
            let lm = backend.probe_loss(&minus, &batch, 32, 32).unwrap().loss;
            let fd = (lp - lm) / (2.0 * eps);
            assert!(
                (analytic - fd).abs() <= 2e-2 * analytic.abs().max(fd.abs()).max(0.05),
                "param {pi}[{xi}]: analytic {analytic} vs finite-diff {fd}"
            );
        }
    }

    #[test]
    fn training_reduces_loss_on_a_fixed_batch() {
        let (backend, batch) = tiny(&[16]);
        let mut state = backend.init_state(0).unwrap();
        let first = backend.train_step(&mut state, &batch, 0.02, 8, 8, false).unwrap();
        let mut last = first;
        for _ in 0..80 {
            last = backend.train_step(&mut state, &batch, 0.02, 8, 8, false).unwrap();
        }
        assert!(last.loss.is_finite());
        assert!(
            last.loss < first.loss * 0.7,
            "loss did not decrease: {} -> {}",
            first.loss,
            last.loss
        );
        assert!(state.is_finite());
    }

    #[test]
    fn quantized_training_works_and_low_bits_hurt() {
        // after training at 8/8, the measured probe-loss surface must
        // show the wall the controller feeds on: 1-bit ≫ 8-bit loss
        let (backend, batch) = tiny(&[16]);
        let mut state = backend.init_state(2).unwrap();
        for _ in 0..80 {
            backend.train_step(&mut state, &batch, 0.02, 8, 8, false).unwrap();
        }
        let l8 = backend.probe_loss(&state, &batch, 8, 8).unwrap().loss;
        let l1 = backend.probe_loss(&state, &batch, 1, 8).unwrap().loss;
        assert!(l8.is_finite() && l1.is_finite());
        assert!(
            l1 > l8 + 0.05,
            "1-bit weights should hurt a trained net: L(1)={l1} vs L(8)={l8}"
        );
    }

    #[test]
    fn single_layer_training_forward_tracks_the_serving_kernels() {
        // no hidden layer ⇒ both paths quantize the *same* input rows,
        // so the fake-quant f32 forward and the integer kernels differ
        // only by accumulation rounding.
        let (backend, batch) = tiny(&[]);
        let mut state = backend.init_state(4).unwrap();
        for _ in 0..10 {
            backend.train_step(&mut state, &batch, 0.02, 4, 8, false).unwrap();
        }
        let fwd = backend.forward(&state, &batch, 4, 8, true);
        let mlp = backend.serving_mlp(&state, 4, 8).unwrap();
        let served = mlp.forward(&batch.x.data, 8, 1);
        let logits = &fwd.act[fwd.act.len() - 1];
        for (i, (a, b)) in logits.iter().zip(&served).enumerate() {
            assert!((a - b).abs() < 5e-3, "logit {i}: train {a} vs serve {b}");
        }
    }

    #[test]
    fn eval_batch_equals_serving_math_and_fp32_path_runs() {
        let (backend, batch) = tiny(&[12]);
        let state = backend.init_state(9).unwrap();
        let ev = backend.eval_batch(&state, &batch, 4, 8, false).unwrap();
        // recompute through the same serving mlp: must agree exactly
        let mlp = backend.serving_mlp(&state, 4, 8).unwrap();
        let logits = mlp.forward(&batch.x.data, 8, 1);
        let (loss, correct, _) = softmax_metrics(&logits, &batch.y.data, 8, 10);
        assert_eq!(ev.loss.to_bits(), (loss as f32).to_bits());
        assert_eq!(ev.correct, correct as f32);
        let fp = backend.eval_batch(&state, &batch, 32, 32, true).unwrap();
        assert!(fp.loss.is_finite());
    }

    #[test]
    fn eval_cache_reuses_the_packed_model_until_inputs_change() {
        let (backend, batch) = tiny(&[6]);
        let mut state = backend.init_state(8).unwrap();
        let a = backend.eval_batch(&state, &batch, 4, 8, false).unwrap();
        let b = backend.eval_batch(&state, &batch, 4, 8, false).unwrap();
        assert_eq!(backend.eval_builds.get(), 1, "second eval must hit the memo");
        assert_eq!(a.loss.to_bits(), b.loss.to_bits());
        backend.eval_batch(&state, &batch, 2, 8, false).unwrap();
        assert_eq!(backend.eval_builds.get(), 2, "bit-width change rebuilds");
        backend.train_step(&mut state, &batch, 0.02, 8, 8, false).unwrap();
        backend.eval_batch(&state, &batch, 2, 8, false).unwrap();
        assert_eq!(backend.eval_builds.get(), 3, "weight change rebuilds");
    }

    #[test]
    fn state_roundtrips_through_checkpoint() {
        let (backend, batch) = tiny(&[6]);
        let mut state = backend.init_state(5).unwrap();
        for _ in 0..3 {
            backend.train_step(&mut state, &batch, 0.02, 8, 8, false).unwrap();
        }
        let mut ck = Checkpoint::new(Json::Null);
        for (spec, t) in backend.mm().params.iter().zip(&state.params) {
            ck.push(spec.name.clone(), t.clone());
        }
        let restored = backend.load_state(&ck, 0).unwrap();
        let a = backend.probe_loss(&state, &batch, 4, 4).unwrap();
        let b = backend.probe_loss(&restored, &batch, 4, 4).unwrap();
        assert_eq!(a.loss.to_bits(), b.loss.to_bits());
    }

    #[test]
    fn bad_batch_shape_is_rejected() {
        let (backend, _) = tiny(&[6]);
        let state = backend.init_state(0).unwrap();
        let bad = Batch {
            x: Tensor::zeros(vec![8, 4, 4, 3]),
            y: crate::tensor::IntTensor::new(vec![8], vec![0; 8]),
        };
        assert!(backend.probe_loss(&state, &bad, 8, 8).is_err());
    }
}

//! Experiment coordinator: turns an [`ExperimentConfig`] into a full run
//! — dataset generation, loader setup, controller construction, optional
//! fp32 pretraining for the fine-tuning scenario, training, and output
//! files — so examples, the CLI, and the bench harnesses all share one
//! entry point.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use crate::adaqat::{AdaQatController, Controller, FixedController, FracBitsController};
use crate::config::{ControllerKind, ExperimentConfig, Scenario};
use crate::data::{loader::Loader, synth, Dataset, DatasetKind};
use crate::quant::{CostModel, EnergyCost, FpgaLutCost, HardCost, MemoryCost, ProductCost};
use crate::runtime::{ModelManifest, Runtime, StepBackend};
use crate::tensor::checkpoint::Checkpoint;
use crate::train::{self, RunResult};
use crate::util::json::Json;

/// A fully assembled experiment, ready to run. Generic over the step
/// backend: the PJRT `ModelRuntime` and the native `backprop` trainer
/// both plug in here, so examples, the CLI, and the bench harnesses
/// share one entry point regardless of how steps execute.
pub struct Experiment<'rt> {
    pub backend: &'rt dyn StepBackend,
    pub cfg: ExperimentConfig,
    pub train_loader: Loader,
    pub test_loader: Loader,
}

/// Build the L_hard model a config names (None = no cost model needed).
pub fn make_hard_cost(cfg: &ExperimentConfig, cost: Option<&CostModel>) -> Box<dyn HardCost> {
    match (cfg.hard_cost.as_str(), cost) {
        ("memory", Some(c)) => Box::new(MemoryCost::new(c)),
        ("fpga-dsp", Some(c)) => Box::new(FpgaLutCost::new(c)),
        ("energy", Some(c)) => Box::new(EnergyCost::new(c)),
        _ => Box::new(ProductCost),
    }
}

/// Build the controller an [`ExperimentConfig`] asks for. `cost` feeds
/// the layer-aware L_hard variants (paper §V extensions).
pub fn make_controller_with_cost(
    cfg: &ExperimentConfig,
    steps_per_epoch: usize,
    cost: Option<&CostModel>,
) -> Box<dyn Controller> {
    match &cfg.controller {
        ControllerKind::AdaQat => {
            // η_a = 0 pins activations (the weight-only Table I rows are
            // configured as init_na = 32, eta_a = 0).
            Box::new(
                AdaQatController::new(
                    cfg.init_nw,
                    cfg.init_na,
                    cfg.eta_w,
                    cfg.eta_a,
                    cfg.lambda,
                    cfg.osc_threshold,
                )
                .with_hard_cost(make_hard_cost(cfg, cost)),
            )
        }
        ControllerKind::Fixed { k_w, k_a } => Box::new(FixedController::new(*k_w, *k_a)),
        ControllerKind::FracBits { k_w_target, k_a_target } => {
            // anneal over the first half of training, FracBits-style
            let updates = (cfg.epochs * steps_per_epoch / cfg.probe_interval.max(1)) / 2;
            Box::new(FracBitsController::new(
                cfg.init_nw,
                cfg.init_na,
                *k_w_target,
                *k_a_target,
                updates.max(1),
            ))
        }
    }
}

/// Controller with the default (paper §III-B product) hardware loss.
pub fn make_controller(cfg: &ExperimentConfig, steps_per_epoch: usize) -> Box<dyn Controller> {
    make_controller_with_cost(cfg, steps_per_epoch, None)
}

/// Generate the train/test splits for a config (sizes rounded down to
/// whole batches so every execution sees a full static batch). The
/// image side length comes from `cfg.image_hw` (32 for the PJRT
/// artifact models; the native backend takes any size).
pub fn make_datasets(cfg: &ExperimentConfig, batch: usize) -> (Arc<Dataset>, Arc<Dataset>) {
    let kind = DatasetKind::parse(&cfg.dataset).expect("validated earlier");
    let round = |n: usize| (n / batch).max(1) * batch;
    let hw = cfg.image_hw;
    let train =
        synth::generate_sized(kind, round(cfg.train_size), cfg.seed, 0, hw, hw).into_shared();
    let test =
        synth::generate_sized(kind, round(cfg.test_size), cfg.seed, 1, hw, hw).into_shared();
    (train, test)
}

impl<'rt> Experiment<'rt> {
    pub fn new(
        backend: &'rt dyn StepBackend,
        cfg: ExperimentConfig,
    ) -> anyhow::Result<Experiment<'rt>> {
        cfg.validate().map_err(|e| anyhow::anyhow!("config: {e}"))?;
        DatasetKind::parse(&cfg.dataset).map_err(|e| anyhow::anyhow!("config: {e}"))?;
        let mm = backend.mm();
        anyhow::ensure!(
            (mm.input_hw.0, mm.input_hw.1) == (cfg.image_hw, cfg.image_hw),
            "config image_hw {} does not match the backend's input {}x{}",
            cfg.image_hw,
            mm.input_hw.0,
            mm.input_hw.1
        );
        let (train_ds, test_ds) = make_datasets(&cfg, mm.batch);
        let train_loader = Loader::new(train_ds, mm.batch, true);
        let test_loader = Loader::new(test_ds, mm.batch, false);
        Ok(Experiment { backend, cfg, train_loader, test_loader })
    }

    /// Run to completion: resolves the scenario (scratch vs fine-tune),
    /// trains, writes metrics/checkpoints into `cfg.out_dir` if set.
    pub fn run(&self) -> anyhow::Result<RunResult> {
        let mut state = match &self.cfg.scenario {
            Scenario::Scratch => self.backend.init_state(self.cfg.seed),
            Scenario::Finetune { checkpoint } => {
                let ck = Checkpoint::load(checkpoint)?;
                self.backend.load_state(&ck, self.cfg.seed)
            }
        }?;
        let cost = CostModel::from_manifest(self.backend.mm());
        let mut controller = make_controller_with_cost(
            &self.cfg,
            self.train_loader.batches_per_epoch(),
            Some(&cost),
        );
        log::info!(
            "experiment: model={} dataset={} controller={} scenario={:?} epochs={}",
            self.cfg.model,
            self.cfg.dataset,
            controller.name(),
            match &self.cfg.scenario {
                Scenario::Scratch => "scratch".to_string(),
                Scenario::Finetune { checkpoint } => format!("finetune({checkpoint:?})"),
            },
            self.cfg.epochs,
        );
        let result = train::train(
            self.backend,
            &self.cfg,
            controller.as_mut(),
            &mut state,
            &self.train_loader,
            &self.test_loader,
        )?;
        if let Some(dir) = &self.cfg.out_dir {
            self.write_outputs(dir, &result, &state, controller.as_ref())?;
        }
        Ok(result)
    }

    fn write_outputs(
        &self,
        dir: &Path,
        result: &RunResult,
        state: &crate::runtime::TrainState,
        controller: &dyn Controller,
    ) -> anyhow::Result<()> {
        std::fs::create_dir_all(dir)?;
        train::save_trace(&result.trace, &dir.join("trace.csv"))?;
        // Prometheus exposition of the global registry — the training
        // trajectory gauges (adaqat_train_bits/frac/osc, freeze and
        // probe counters) land next to trace.csv (DESIGN.md §15)
        std::fs::write(dir.join("metrics.prom"), crate::obs::global().render_prometheus())?;
        let mut epochs = crate::metrics::CsvWriter::create(
            &dir.join("epochs.csv"),
            &["epoch", "lr", "train_loss", "train_acc", "test_loss", "test_acc", "k_w", "k_a"],
        )?;
        for e in &result.epochs {
            epochs.row(&[
                e.epoch.to_string(),
                format!("{:.6}", e.lr),
                format!("{:.5}", e.train_loss),
                format!("{:.4}", e.train_acc),
                format!("{:.5}", e.test_loss),
                format!("{:.4}", e.test_acc),
                e.k_w.to_string(),
                e.k_a.to_string(),
            ])?;
        }
        let (k_w, k_a) = result.final_bits;
        let mut meta = Json::obj(vec![
            ("model", Json::str(self.cfg.model.clone())),
            ("controller", Json::str(controller.name())),
            ("k_w", Json::num(k_w as f64)),
            ("k_a", Json::num(k_a as f64)),
            ("test_top1", Json::num(result.test_top1)),
        ]);
        // backend-specific serving metadata (e.g. the native backend's
        // mlp_layers/input_hw) so `adaqat export` output serves directly
        if let Json::Obj(m) = &mut meta {
            for (k, v) in self.backend.checkpoint_meta() {
                m.insert(k, v);
            }
        }
        train::save_checkpoint(self.backend, state, meta, &dir.join("final.ckpt"))?;
        Ok(())
    }
}

/// FNV-1a tag of a manifest's tensor geometry (batch, input size,
/// parameter shapes). The pretrain cache key needs it because one model
/// key can describe many shapes on the native backend (`hidden`,
/// `image_hw`, `batch` are config knobs, not part of the key) — without
/// it a stale cache hit would fail checkpoint loading with a confusing
/// shape-mismatch error instead of regenerating.
fn geometry_tag(mm: &ModelManifest) -> u64 {
    use crate::util::{fnv1a_mix, FNV1A_BASIS};
    let mut h = FNV1A_BASIS;
    h = fnv1a_mix(h, mm.batch as u64);
    h = fnv1a_mix(h, mm.input_hw.0 as u64);
    h = fnv1a_mix(h, mm.input_hw.1 as u64);
    h = fnv1a_mix(h, mm.in_channels as u64);
    for p in &mm.params {
        for &d in &p.shape {
            h = fnv1a_mix(h, d as u64);
        }
        h = fnv1a_mix(h, u64::MAX); // shape separator
    }
    h
}

/// Train (or reuse a cached) fp32 model for the fine-tuning scenario:
/// the Table I/II "pretrained full-precision model". Cached under
/// `cache_dir/{model}_fp32_e{epochs}_s{seed}_g{geometry}.ckpt`.
pub fn ensure_fp32_pretrain(
    backend: &dyn StepBackend,
    base_cfg: &ExperimentConfig,
    epochs: usize,
    cache_dir: &Path,
) -> anyhow::Result<PathBuf> {
    let path = cache_dir.join(format!(
        "{}_fp32_e{}_s{}_g{:016x}.ckpt",
        base_cfg.model,
        epochs,
        base_cfg.seed,
        geometry_tag(backend.mm())
    ));
    if path.exists() {
        log::info!("reusing fp32 pretrain {path:?}");
        return Ok(path);
    }
    anyhow::ensure!(backend.has_fp32(), "{}: no fp32 artifacts", base_cfg.model);
    let mut cfg = base_cfg.clone();
    cfg.fp32 = true;
    cfg.epochs = epochs;
    cfg.scenario = Scenario::Scratch;
    cfg.out_dir = None;
    let exp = Experiment::new(backend, cfg)?;
    let mut state = exp.backend.init_state(exp.cfg.seed)?;
    let mut controller = FixedController::new(32, 32);
    let result = train::train(
        exp.backend,
        &exp.cfg,
        &mut controller,
        &mut state,
        &exp.train_loader,
        &exp.test_loader,
    )?;
    log::info!(
        "fp32 pretrain done: test top-1 {:.3} ({} epochs)",
        result.test_top1,
        epochs
    );
    std::fs::create_dir_all(cache_dir)?;
    train::save_checkpoint(
        exp.backend,
        &state,
        Json::obj(vec![
            ("model", Json::str(exp.cfg.model.clone())),
            ("fp32", Json::Bool(true)),
            ("test_top1", Json::num(result.test_top1)),
        ]),
        &path,
    )?;
    Ok(path)
}

/// The artifact directory: `$ADAQAT_ARTIFACTS` or `./artifacts`.
pub fn artifact_dir() -> PathBuf {
    PathBuf::from(
        std::env::var("ADAQAT_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string()),
    )
}

/// Whether AOT artifacts exist. Benches and integration tests call this
/// to skip gracefully (instead of failing) on checkouts that have not
/// run `make artifacts`.
pub fn artifacts_present() -> bool {
    artifact_dir().join("manifest.json").exists()
}

/// Convenience used by examples/benches: open the default artifact dir.
pub fn default_runtime() -> anyhow::Result<Runtime> {
    Runtime::new(&artifact_dir())
}

/// What `export_packed` did, for reporting.
#[derive(Debug, Clone)]
pub struct ExportReport {
    pub k_w: u32,
    pub quantized_tensors: usize,
    pub raw_tensors: usize,
    /// fp32 bytes the packed weights replace (numel × 4 of all tensors).
    pub fp32_bytes: usize,
    pub packed_payload_bytes: usize,
}

/// Convert a training checkpoint into the packed serving format
/// (DESIGN.md §7): weight tensors → `bits`-bit codes, everything else
/// raw. Weight selection uses manifest roles when artifacts are present
/// and the checkpoint names its model; otherwise it falls back to the
/// `.w` naming convention every model spec follows. The packed meta is
/// enriched with the cost-model summary (BitOPs, WCR) when the manifest
/// geometry is available.
pub fn export_packed(
    ck: &Checkpoint,
    bits: u32,
) -> anyhow::Result<(crate::serve::QuantizedCheckpoint, ExportReport)> {
    anyhow::ensure!((1..=24).contains(&bits), "export bits must be in 1..=24, got {bits}");
    let model_key = ck.meta.get("model").and_then(Json::as_str).map(str::to_string);
    let mut cost_summary: Option<(f64, f64)> = None;
    let weight_names: Option<std::collections::BTreeSet<String>> = if artifacts_present() {
        match (crate::runtime::Manifest::load(&artifact_dir()), &model_key) {
            (Ok(man), Some(key)) => match man.model(key) {
                Ok(mm) => {
                    let k_a = ck.meta.get("k_a").and_then(Json::as_f64).unwrap_or(32.0) as u32;
                    let cost = CostModel::from_manifest(mm);
                    cost_summary = Some((cost.bitops_g(bits, k_a), cost.wcr(bits)));
                    Some(
                        mm.params
                            .iter()
                            .filter(|p| p.role == "conv_w" || p.role == "fc_w")
                            .map(|p| p.name.clone())
                            .collect(),
                    )
                }
                Err(_) => None,
            },
            _ => None,
        }
    } else {
        None
    };
    if weight_names.is_none() {
        log::info!(
            "export: no manifest roles for this checkpoint; using the `.w` naming convention"
        );
    }
    let is_weight = |name: &str| match &weight_names {
        Some(set) => set.contains(name),
        None => name.ends_with(".w"),
    };
    let mut q = crate::serve::QuantizedCheckpoint::from_checkpoint(ck, bits, is_weight);
    if let (Some((bitops_g, wcr)), Json::Obj(meta)) = (cost_summary, &mut q.meta) {
        meta.insert(
            "cost".to_string(),
            Json::obj(vec![
                ("bitops_g", Json::num(bitops_g)),
                ("wcr", Json::num(wcr)),
            ]),
        );
    }
    let mut report = ExportReport {
        k_w: bits,
        quantized_tensors: 0,
        raw_tensors: 0,
        fp32_bytes: 0,
        packed_payload_bytes: q.payload_bytes(),
    };
    for ((_, src), (_, packed)) in ck.tensors.iter().zip(&q.tensors) {
        report.fp32_bytes += src.numel() * 4;
        if packed.bits == crate::serve::packed::RAW_BITS {
            report.raw_tensors += 1;
        } else {
            report.quantized_tensors += 1;
        }
    }
    Ok((q, report))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn controller_mapping_matches_config() {
        let mut cfg = ExperimentConfig::default_for("resnet20");
        cfg.controller = ControllerKind::AdaQat;
        let c = make_controller(&cfg, 100);
        assert!(c.name().starts_with("adaqat"));
        assert_eq!(c.bits(), (8, 8)); // ceil of default init 8/8

        cfg.controller = ControllerKind::Fixed { k_w: 2, k_a: 32 };
        let c = make_controller(&cfg, 100);
        assert_eq!(c.bits(), (2, 32));
        assert_eq!(c.frozen(), (true, true));

        cfg.controller = ControllerKind::FracBits { k_w_target: 3, k_a_target: 4 };
        let mut c = make_controller(&cfg, 100);
        assert_eq!(c.bits(), (8, 8));
        // anneal to target over half the updates
        for _ in 0..cfg.epochs * 100 {
            c.update(0.0, &[]);
        }
        assert_eq!(c.bits(), (3, 4));
    }

    #[test]
    fn adaqat_controller_honors_eta_zero_pin() {
        let mut cfg = ExperimentConfig::default_for("resnet20");
        cfg.init_na = 32.0;
        cfg.eta_a = 0.0;
        let c = make_controller(&cfg, 10);
        assert_eq!(c.bits().1, 32);
        assert!(c.frozen().1);
        assert!(!c.frozen().0);
    }

    #[test]
    fn datasets_round_to_whole_batches() {
        let mut cfg = ExperimentConfig::default_for("resnet20");
        cfg.train_size = 300; // not a multiple of 128
        cfg.test_size = 100;
        let (train, test) = make_datasets(&cfg, 128);
        assert_eq!(train.n, 256);
        assert_eq!(test.n, 128); // rounded down but at least one batch
        // splits are disjoint streams
        assert_ne!(train.images[..3072], test.images[..3072]);
    }

    #[test]
    fn datasets_follow_config_kind() {
        let mut cfg = ExperimentConfig::default_for("resnet18");
        cfg.train_size = 64;
        cfg.test_size = 64;
        let (train, _) = make_datasets(&cfg, 32);
        assert_eq!(train.num_classes, 100);
    }

    #[test]
    fn export_packed_heuristic_path() {
        // no model in the manifest matches "demo-linear", so the `.w`
        // naming fallback must select exactly the weight matrix
        let ck = crate::serve::demo::demo_checkpoint(
            crate::data::DatasetKind::Cifar10,
            2,
            1,
            8,
        );
        let (q, report) = export_packed(&ck, 4).unwrap();
        assert_eq!(report.k_w, 4);
        assert_eq!(report.quantized_tensors, 1);
        assert_eq!(report.raw_tensors, 1);
        assert_eq!(q.get("fc.w").unwrap().bits, 4);
        assert!(report.packed_payload_bytes * 6 < report.fp32_bytes);
        assert_eq!(q.meta.get("k_w").unwrap().as_f64(), Some(4.0));
        // and the result still drives the reference backend
        assert!(crate::serve::ReferenceBackend::from_packed(&q).is_ok());
        assert!(export_packed(&ck, 32).is_err());
    }
}

//! Checkpoint format: a self-describing binary container for named
//! tensors plus a small JSON metadata blob.
//!
//! Layout (all integers little-endian):
//! ```text
//!   magic   "AQCKPT01"                      (8 bytes)
//!   meta    u32 len + JSON bytes            (run metadata, bit-widths, …)
//!   count   u32                             number of tensors
//!   entry*  u16 name_len + name bytes
//!           u8  ndim + u32 dims[ndim]
//!           f32 data[numel]
//! ```
//! Used for fp32 pretrains (the fine-tuning scenario of Table I/II) and
//! for resuming AdaQAT runs.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

use crate::util::json::Json;

use super::Tensor;

const MAGIC: &[u8; 8] = b"AQCKPT01";

#[derive(Debug, Clone)]
pub struct Checkpoint {
    pub meta: Json,
    pub tensors: Vec<(String, Tensor)>,
}

impl Checkpoint {
    pub fn new(meta: Json) -> Checkpoint {
        Checkpoint { meta, tensors: vec![] }
    }

    pub fn push(&mut self, name: impl Into<String>, t: Tensor) {
        self.tensors.push((name.into(), t));
    }

    pub fn tensor_map(&self) -> BTreeMap<&str, &Tensor> {
        self.tensors.iter().map(|(n, t)| (n.as_str(), t)).collect()
    }

    // ---------------------------------------------------------------- io
    pub fn save(&self, path: &Path) -> anyhow::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut w = std::io::BufWriter::new(std::fs::File::create(path)?);
        w.write_all(MAGIC)?;
        let meta = self.meta.to_string();
        w.write_all(&(meta.len() as u32).to_le_bytes())?;
        w.write_all(meta.as_bytes())?;
        w.write_all(&(self.tensors.len() as u32).to_le_bytes())?;
        for (name, t) in &self.tensors {
            anyhow::ensure!(name.len() <= u16::MAX as usize, "name too long");
            w.write_all(&(name.len() as u16).to_le_bytes())?;
            w.write_all(name.as_bytes())?;
            anyhow::ensure!(t.shape.len() <= u8::MAX as usize, "too many dims");
            w.write_all(&[t.shape.len() as u8])?;
            for &d in &t.shape {
                w.write_all(&(d as u32).to_le_bytes())?;
            }
            for &x in &t.data {
                w.write_all(&x.to_le_bytes())?;
            }
        }
        Ok(())
    }

    pub fn load(path: &Path) -> anyhow::Result<Checkpoint> {
        let mut r = std::io::BufReader::new(std::fs::File::open(path)?);
        let mut magic = [0u8; 8];
        r.read_exact(&mut magic)?;
        anyhow::ensure!(&magic == MAGIC, "bad checkpoint magic in {path:?}");
        let meta_len = read_u32(&mut r)? as usize;
        let mut meta_bytes = vec![0u8; meta_len];
        r.read_exact(&mut meta_bytes)?;
        let meta = Json::parse(std::str::from_utf8(&meta_bytes)?)
            .map_err(|e| anyhow::anyhow!("checkpoint meta: {e}"))?;
        let count = read_u32(&mut r)? as usize;
        let mut tensors = Vec::with_capacity(count);
        for _ in 0..count {
            let name_len = read_u16(&mut r)? as usize;
            let mut name = vec![0u8; name_len];
            r.read_exact(&mut name)?;
            let name = String::from_utf8(name)?;
            let mut ndim = [0u8; 1];
            r.read_exact(&mut ndim)?;
            let mut shape = Vec::with_capacity(ndim[0] as usize);
            for _ in 0..ndim[0] {
                shape.push(read_u32(&mut r)? as usize);
            }
            // dims come from an untrusted file: overflow must be Err,
            // not a debug panic / silent release wraparound
            let bytes = shape
                .iter()
                .try_fold(4usize, |acc, &d| acc.checked_mul(d))
                .ok_or_else(|| {
                    anyhow::anyhow!("{name}: shape {shape:?} overflows usize")
                })?;
            let mut buf = vec![0u8; bytes];
            r.read_exact(&mut buf)?;
            let data = buf
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            tensors.push((name, Tensor::new(shape, data)));
        }
        Ok(Checkpoint { meta, tensors })
    }
}

// Shared little-endian framing primitives (also used by the packed
// serving checkpoint, serve::packed — one copy, two container formats).
pub(crate) fn read_u32<R: Read>(r: &mut R) -> std::io::Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

pub(crate) fn read_u16<R: Read>(r: &mut R) -> std::io::Result<u16> {
    let mut b = [0u8; 2];
    r.read_exact(&mut b)?;
    Ok(u16::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn tmpfile(name: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("adaqat_test_{}_{name}", std::process::id()));
        p
    }

    #[test]
    fn roundtrip() {
        let mut rng = Rng::new(1);
        let mut ck = Checkpoint::new(Json::obj(vec![
            ("model", Json::str("resnet20")),
            ("epoch", Json::num(3.0)),
        ]));
        ck.push("a.w", Tensor::new(vec![2, 3], (0..6).map(|i| i as f32).collect()));
        ck.push("b", Tensor::new(vec![4], (0..4).map(|_| rng.normal()).collect()));
        ck.push("scalar", Tensor::scalar(7.5));
        let path = tmpfile("roundtrip.ckpt");
        ck.save(&path).unwrap();
        let rt = Checkpoint::load(&path).unwrap();
        assert_eq!(rt.meta.get("model").unwrap().as_str(), Some("resnet20"));
        assert_eq!(rt.tensors.len(), 3);
        for ((n1, t1), (n2, t2)) in ck.tensors.iter().zip(&rt.tensors) {
            assert_eq!(n1, n2);
            assert_eq!(t1, t2);
        }
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_bad_magic() {
        let path = tmpfile("badmagic.ckpt");
        std::fs::write(&path, b"NOTACKPTxxxxxxx").unwrap();
        assert!(Checkpoint::load(&path).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_truncated() {
        let mut ck = Checkpoint::new(Json::Null);
        ck.push("t", Tensor::zeros(vec![128]));
        let path = tmpfile("trunc.ckpt");
        ck.save(&path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 10]).unwrap();
        assert!(Checkpoint::load(&path).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn empty_tensor_list_roundtrips() {
        let ck = Checkpoint::new(Json::obj(vec![("only", Json::str("meta"))]));
        let path = tmpfile("empty.ckpt");
        ck.save(&path).unwrap();
        let rt = Checkpoint::load(&path).unwrap();
        assert!(rt.tensors.is_empty());
        assert_eq!(rt.meta.get("only").unwrap().as_str(), Some("meta"));
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn non_ascii_names_roundtrip() {
        let mut ck = Checkpoint::new(Json::obj(vec![("λ", Json::num(0.15))]));
        ck.push("重み.conv1.w", Tensor::new(vec![3], vec![1.0, -2.0, 3.0]));
        ck.push("ß-gemein", Tensor::scalar(9.0));
        let path = tmpfile("nonascii.ckpt");
        ck.save(&path).unwrap();
        let rt = Checkpoint::load(&path).unwrap();
        assert_eq!(rt.tensors[0].0, "重み.conv1.w");
        assert_eq!(rt.tensors[1].0, "ß-gemein");
        assert_eq!(rt.meta.get("λ").unwrap().as_f64(), Some(0.15));
        assert_eq!(rt.tensors[0].1, ck.tensors[0].1);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn truncation_at_every_section_errors() {
        let mut ck = Checkpoint::new(Json::obj(vec![("m", Json::str("x"))]));
        ck.push("w", Tensor::zeros(vec![16]));
        let path = tmpfile("trunc_sections.ckpt");
        ck.save(&path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        // cut inside: magic, meta length, meta body, count, name, shape,
        // and payload — every prefix must fail loudly
        for cut in [4usize, 10, 14, 20, 24, 28, bytes.len() - 1] {
            let cut = cut.min(bytes.len() - 1);
            std::fs::write(&path, &bytes[..cut]).unwrap();
            assert!(Checkpoint::load(&path).is_err(), "cut at {cut} must error");
        }
        std::fs::remove_file(path).ok();
    }
}

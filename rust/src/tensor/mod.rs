//! Host tensor substrate: the coordinator-side representation of every
//! array that crosses the PJRT boundary.
//!
//! Deliberately minimal — the heavy math lives in the compiled HLO; the
//! host only initializes, shuttles, checkpoints, and inspects tensors.

pub mod checkpoint;
pub mod init;

/// A dense row-major f32 tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Tensor {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {:?} does not match data length {}",
            shape,
            data.len()
        );
        Tensor { shape, data }
    }

    pub fn zeros(shape: Vec<usize>) -> Tensor {
        let n = shape.iter().product();
        Tensor { shape, data: vec![0.0; n] }
    }

    pub fn full(shape: Vec<usize>, v: f32) -> Tensor {
        let n = shape.iter().product();
        Tensor { shape, data: vec![v; n] }
    }

    pub fn scalar(v: f32) -> Tensor {
        Tensor { shape: vec![], data: vec![v] }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    /// Mean of all elements (metrics convenience).
    pub fn mean(&self) -> f32 {
        if self.data.is_empty() {
            return 0.0;
        }
        self.data.iter().sum::<f32>() / self.data.len() as f32
    }

    /// L2 norm (used by divergence checks in the trainer).
    pub fn l2(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum::<f32>().sqrt()
    }

    pub fn is_finite(&self) -> bool {
        self.data.iter().all(|x| x.is_finite())
    }
}

/// A dense row-major i32 tensor (labels).
#[derive(Debug, Clone, PartialEq)]
pub struct IntTensor {
    pub shape: Vec<usize>,
    pub data: Vec<i32>,
}

impl IntTensor {
    pub fn new(shape: Vec<usize>, data: Vec<i32>) -> IntTensor {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        IntTensor { shape, data }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_stats() {
        let t = Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.numel(), 6);
        assert!((t.mean() - 3.5).abs() < 1e-6);
        assert!((t.l2() - (91.0f32).sqrt()).abs() < 1e-5);
        assert!(t.is_finite());
    }

    #[test]
    #[should_panic(expected = "does not match")]
    fn shape_mismatch_panics() {
        Tensor::new(vec![2, 2], vec![0.0; 3]);
    }

    #[test]
    fn nan_detection() {
        let mut t = Tensor::zeros(vec![4]);
        t.data[2] = f32::NAN;
        assert!(!t.is_finite());
    }

    #[test]
    fn scalar_shape() {
        let s = Tensor::scalar(2.5);
        assert_eq!(s.shape, Vec::<usize>::new());
        assert_eq!(s.numel(), 1);
    }
}

//! Parameter initialization from manifest init specs.
//!
//! The AOT manifest carries an init spec string per parameter
//! (`kaiming:<fan_in>`, `zeros`, `ones`, `const:<v>`); this module turns
//! them into tensors using the deterministic [`Rng`] so runs reproduce
//! bit-for-bit from a seed. Mirrors `python/compile/init.py`, which is
//! only used by the pytest suite.

use crate::util::rng::Rng;

use super::Tensor;

/// Initialize one tensor from its manifest spec.
pub fn init_tensor(spec: &str, shape: &[usize], rng: &mut Rng) -> Result<Tensor, String> {
    let n: usize = shape.iter().product();
    let data = if let Some(fan) = spec.strip_prefix("kaiming:") {
        let fan_in: f64 = fan.parse().map_err(|_| format!("bad kaiming spec {spec:?}"))?;
        if fan_in <= 0.0 {
            return Err(format!("kaiming fan_in must be positive, got {fan_in}"));
        }
        let std = (2.0 / fan_in).sqrt() as f32;
        (0..n).map(|_| std * rng.normal()).collect()
    } else if spec == "zeros" {
        vec![0.0; n]
    } else if spec == "ones" {
        vec![1.0; n]
    } else if let Some(v) = spec.strip_prefix("const:") {
        let v: f32 = v.parse().map_err(|_| format!("bad const spec {spec:?}"))?;
        vec![v; n]
    } else {
        return Err(format!("unknown init spec {spec:?}"));
    };
    Ok(Tensor::new(shape.to_vec(), data))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kaiming_moments() {
        let mut rng = Rng::new(0);
        let t = init_tensor("kaiming:72", &[3, 3, 8, 100], &mut rng).unwrap();
        let std_expected = (2.0f32 / 72.0).sqrt();
        let mean = t.mean();
        let var = t.data.iter().map(|x| (x - mean).powi(2)).sum::<f32>()
            / t.numel() as f32;
        assert!(mean.abs() < 0.01 * std_expected * 10.0);
        assert!((var.sqrt() - std_expected).abs() / std_expected < 0.05);
    }

    #[test]
    fn const_and_fixed() {
        let mut rng = Rng::new(0);
        assert!(init_tensor("zeros", &[4], &mut rng).unwrap().data.iter().all(|&x| x == 0.0));
        assert!(init_tensor("ones", &[4], &mut rng).unwrap().data.iter().all(|&x| x == 1.0));
        assert!(init_tensor("const:10.0", &[2], &mut rng).unwrap().data.iter().all(|&x| x == 10.0));
    }

    #[test]
    fn deterministic_given_seed() {
        let a = init_tensor("kaiming:9", &[16], &mut Rng::new(5)).unwrap();
        let b = init_tensor("kaiming:9", &[16], &mut Rng::new(5)).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn bad_specs_error() {
        let mut rng = Rng::new(0);
        assert!(init_tensor("kaiming:x", &[2], &mut rng).is_err());
        assert!(init_tensor("kaiming:0", &[2], &mut rng).is_err());
        assert!(init_tensor("mystery", &[2], &mut rng).is_err());
        assert!(init_tensor("const:zz", &[2], &mut rng).is_err());
    }
}

//! Integer-domain quantized GEMM plans (DESIGN.md §11).
//!
//! A [`QuantGemm`] is built *once* per layer at checkpoint load from a
//! [`PackedTensor`]: codes are unpacked with the u64 fast path
//! ([`super::pack`]), centered (q = 2c − s), and transposed into a
//! row-major `[n_out][d]` layout so the inner reduction is contiguous —
//! the checkpoint stores weights `[d, n_out]`, which made the old
//! serving loop stride by `n_out` floats per element. The per-tensor
//! scale collapses into a single step Δ_w = scale/s folded with the
//! activation row's Δ_a into one f64 epilogue multiply per output.
//!
//! Accumulation is i32 and *exact*: |Σ q_a·q_w| ≤ d·s_a·s_w, and plans
//! only take the integer path when that bound fits i32 (checked at
//! construction — see [`QuantGemm::integer_bound_ok`]). Exactness makes
//! the kernel order-independent, so cache blocking and row threading
//! cannot change results: the blocked/threaded output is bit-identical
//! to a naive scalar dot, which is what the property tests pin down.
//!
//! The dense inner dot is vectorized (DESIGN.md §16): runtime CPU
//! detection — the same pattern as the popcount dispatch in
//! [`super::bitserial`] — picks an AVX2 kernel built on
//! `_mm256_madd_epi16` (i8 weights sign-extended to i16 first), with
//! the portable scalar loop as fallback and `ADAQAT_FORCE_PORTABLE=1`
//! pinning every plan to it. The SIMD lanes are exact too: each lane's
//! partial sum is bounded by Σ|q_a·q_w| ≤ d·s_a·s_w, the very bound the
//! plan admitted, so no lane can wrap and lane order is invisible —
//! AVX2 output is bit-identical to portable by the same argument that
//! makes tiling invisible.
//!
//! Codes wider than i16 (k > 15), raw-f32 tensors, identity-scale
//! activations (k_a ≥ 24) and bound violations fall back to an f32 plan
//! over the canonical dequantized weights, same transposed layout.
//!
//! Small width products take a third form: when k_w·k_a ≤
//! [`BITSERIAL_MAX_PRODUCT`](super::bitserial::BITSERIAL_MAX_PRODUCT)
//! the plan stores bit-sliced weight planes instead of dense codes and
//! the dot runs on AND+popcount (§14, [`super::bitserial`]) — same
//! exact integer accumulator, so the three integer forms are
//! interchangeable bit for bit and callers never see which one ran.

use crate::quant::code_levels;
use crate::serve::packed::{PackedTensor, RAW_BITS};

use super::activ::MAX_INT_ACT_BITS;
use super::bitserial::BitserialGemm;
use super::pack;
use super::{force_portable, grab, KernelIsa, Scratch, SplitMut};

/// Weight storage: centered integer codes when the integer path is
/// usable, canonical dequantized f32 otherwise. All row-major
/// `[n_out][d]` (transposed from the checkpoint's `[d, n_out]`).
enum Weights {
    /// k_w ≤ 7: |q| ≤ 127 fits i8 (half the cache traffic of i16).
    I8(Vec<i8>),
    /// 8 ≤ k_w ≤ 15: |q| ≤ 32767 fits i16.
    I16(Vec<i16>),
    /// Bit-sliced planes: inner-loop work ∝ k_w·k_a (DESIGN.md §14).
    Bits(BitserialGemm),
    /// Fallback: canonical `PackedTensor::dequantize` values.
    F32(Vec<f32>),
}

/// Which representation a plan executes (selection is observable so the
/// dispatch-boundary tests and the bench sweep can pin it down).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlanKind {
    Bitserial,
    Int8,
    Int16,
    F32,
}

impl PlanKind {
    /// Stable lowercase token for metric labels (`plan="bitserial"` in
    /// the per-layer series, DESIGN.md §15) — decoupled from the Debug
    /// spelling so renames cannot silently churn series names.
    pub fn label(self) -> &'static str {
        match self {
            PlanKind::Bitserial => "bitserial",
            PlanKind::Int8 => "int8",
            PlanKind::Int16 => "int16",
            PlanKind::F32 => "f32",
        }
    }

    /// [`label`] refined with the ISA the plan dispatches to, so the
    /// per-layer obs series distinguish SIMD/tiled plans from scalar
    /// ones (`int8_avx2` vs `int8`). The base token is always a prefix,
    /// so existing dashboards can still group by plan family. f32 plans
    /// have no ISA variants; `popcnt` only exists for bitserial.
    ///
    /// [`label`]: PlanKind::label
    pub fn label_with(self, isa: KernelIsa) -> &'static str {
        match (self, isa) {
            (PlanKind::Bitserial, KernelIsa::Avx2) => "bitserial_avx2",
            (PlanKind::Bitserial, KernelIsa::Popcnt) => "bitserial_popcnt",
            (PlanKind::Bitserial, KernelIsa::Portable) => "bitserial",
            (PlanKind::Int8, KernelIsa::Avx2) => "int8_avx2",
            (PlanKind::Int8, _) => "int8",
            (PlanKind::Int16, KernelIsa::Avx2) => "int16_avx2",
            (PlanKind::Int16, _) => "int16",
            (PlanKind::F32, _) => "f32",
        }
    }
}

/// Plan-selection override for [`QuantGemm::from_packed_with`]. `Auto`
/// (what [`QuantGemm::from_packed`] uses) picks bitserial for small
/// k_w·k_a, the dense i8/i16 path otherwise, f32 when the integer path
/// is inadmissible; the forced variants exist for the bench sweep and
/// the cross-path property tests and error out when the requested path
/// is unavailable (rather than silently falling back).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlanChoice {
    Auto,
    DenseInt,
    Bitserial,
    F32,
}

/// Output-neuron tile: one tile of weight rows (tile × d codes) is
/// streamed while every batch row's activations stay resident, so the
/// weight matrix is read once per tile instead of once per batch row.
pub(crate) const OUT_TILE: usize = 16;

/// Reduction-dimension block (§16): one activation span this long plus
/// an OUT_TILE of weight-row spans fits L1/L2 comfortably (at i16 that
/// is 2 KiB of activations + 32 KiB of weights), so huge-d layers
/// (im2col patch rows run to tens of thousands of features) sweep the
/// whole output tile per block instead of thrashing the activation row
/// out of cache once per output. Blocking cannot change results: the
/// i32 accumulator is exact, so the split is invisible in the bits.
pub(crate) const D_TILE: usize = 1024;

/// Runtime ISA pick for the dense i8/i16 dot, the same
/// `is_x86_feature_detected!` pattern as the popcount dispatch in
/// [`super::bitserial`]. Detection runs at plan build (never on the
/// request path) and reads `ADAQAT_FORCE_PORTABLE` fresh each time so
/// one process can build portable and native plans back to back (the
/// bench A/B and the CI matrix both rely on that).
fn detect_dense() -> KernelIsa {
    // Under Miri there are no SIMD intrinsics: pin to the portable
    // kernels so the aliasing model checks the code the portable CI leg
    // actually runs (scripts/analyze.sh, DESIGN.md §17).
    if cfg!(miri) || force_portable() {
        return KernelIsa::Portable;
    }
    #[cfg(target_arch = "x86_64")]
    {
        if is_x86_feature_detected!("avx2") {
            return KernelIsa::Avx2;
        }
    }
    KernelIsa::Portable
}

/// The ISA a dense plan built right now would execute — the serve
/// startup banner ([`super::isa_summary`]) reports it so A/B runs and
/// CI logs show which kernels are actually live.
pub fn detected_dense_isa() -> KernelIsa {
    detect_dense()
}

pub struct QuantGemm {
    /// Input features (contiguous inner/reduction dimension).
    pub d: usize,
    /// Output features.
    pub n_out: usize,
    /// Weight bit-width (RAW_BITS for raw-f32 tensors).
    pub bits: u32,
    /// Δ_w = scale / (2^k_w − 1); 0 for f32 plans.
    pub step_w: f32,
    /// ISA the dense inner dot dispatches to (fixed at plan build;
    /// bitserial plans carry their own popcount backend).
    isa: KernelIsa,
    weights: Weights,
}

impl QuantGemm {
    /// Whether the i32 accumulator is exact for reduction length `d` at
    /// weight width `k_w` and activation width `k_a`:
    /// d·(2^k_a − 1)·(2^k_w − 1) ≤ i32::MAX. (At W8/A8 this allows
    /// d ≤ 33 025 — far above any fc layer served here; see §11.)
    pub fn integer_bound_ok(d: usize, k_w: u32, k_a: u32) -> bool {
        if k_w == 0 || k_a == 0 || k_w > MAX_INT_ACT_BITS || k_a > MAX_INT_ACT_BITS {
            return false;
        }
        let sw = code_levels(k_w) as u128;
        let sa = code_levels(k_a) as u128;
        (d as u128) * sw * sa <= i32::MAX as u128
    }

    /// Build a plan from a packed weight tensor of shape `[d, n_out]`
    /// with automatic representation selection. `k_a` is the activation
    /// width the plan will be driven at; it decides the representation
    /// up front.
    pub fn from_packed(t: &PackedTensor, k_a: u32) -> anyhow::Result<QuantGemm> {
        Self::from_packed_with(t, k_a, PlanChoice::Auto)
    }

    /// [`from_packed`] with an explicit [`PlanChoice`]. Forced integer
    /// choices error when the integer path is inadmissible (raw
    /// weights, identity k_a, i32 bound) instead of falling back.
    ///
    /// [`from_packed`]: QuantGemm::from_packed
    pub fn from_packed_with(
        t: &PackedTensor,
        k_a: u32,
        choice: PlanChoice,
    ) -> anyhow::Result<QuantGemm> {
        anyhow::ensure!(
            t.shape.len() == 2,
            "QuantGemm wants a 2-d weight tensor, got shape {:?}",
            t.shape
        );
        let d = t.shape[0];
        let n_out = t.shape[1];
        anyhow::ensure!(d > 0 && n_out > 0, "degenerate weight shape {:?}", t.shape);
        let integer_ok = t.bits != RAW_BITS
            && k_a < 24
            && Self::integer_bound_ok(d, t.bits, k_a);
        let integer = match choice {
            PlanChoice::F32 => false,
            PlanChoice::Auto => integer_ok,
            PlanChoice::DenseInt | PlanChoice::Bitserial => {
                anyhow::ensure!(
                    integer_ok,
                    "forced {choice:?} plan but the integer path is inadmissible \
                     (bits {}, k_a {k_a}, d {d})",
                    t.bits
                );
                true
            }
        };
        if !integer {
            let deq = t.dequantize().data;
            let mut w = vec![0.0f32; d * n_out];
            for i in 0..d {
                for o in 0..n_out {
                    w[o * d + i] = deq[i * n_out + o];
                }
            }
            return Ok(QuantGemm {
                d,
                n_out,
                bits: t.bits,
                step_w: 0.0,
                isa: detect_dense(),
                weights: Weights::F32(w),
            });
        }
        let s_i = code_levels(t.bits) as i32;
        let s = s_i as f32;
        let step_w = if t.scale > 0.0 { t.scale / s } else { 0.0 };
        let codes = pack::unpack_codes(&t.payload, t.bits, d * n_out);
        let bitserial = match choice {
            PlanChoice::Bitserial => true,
            PlanChoice::Auto => BitserialGemm::preferred(t.bits, k_a),
            _ => false,
        };
        let weights = if bitserial {
            Weights::Bits(BitserialGemm::from_codes(&codes, d, n_out, t.bits, k_a))
        } else if t.bits <= 7 {
            let mut w = vec![0i8; d * n_out];
            for i in 0..d {
                for o in 0..n_out {
                    w[o * d + i] = (2 * codes[i * n_out + o] as i32 - s_i) as i8;
                }
            }
            Weights::I8(w)
        } else {
            let mut w = vec![0i16; d * n_out];
            for i in 0..d {
                for o in 0..n_out {
                    w[o * d + i] = (2 * codes[i * n_out + o] as i32 - s_i) as i16;
                }
            }
            Weights::I16(w)
        };
        Ok(QuantGemm { d, n_out, bits: t.bits, step_w, isa: detect_dense(), weights })
    }

    /// Which representation this plan executes.
    pub fn plan_kind(&self) -> PlanKind {
        match &self.weights {
            Weights::Bits(_) => PlanKind::Bitserial,
            Weights::I8(_) => PlanKind::Int8,
            Weights::I16(_) => PlanKind::Int16,
            Weights::F32(_) => PlanKind::F32,
        }
    }

    /// The ISA this plan's inner loop dispatches to — the dense dot's
    /// pick, or the popcount backend for bitserial plans.
    pub fn isa(&self) -> KernelIsa {
        match &self.weights {
            Weights::Bits(b) => b.isa(),
            _ => self.isa,
        }
    }

    /// Full metric label: representation refined with the dispatched
    /// ISA (`int8_avx2`, `bitserial_popcnt`, … — DESIGN.md §15/§16).
    pub fn plan_label(&self) -> &'static str {
        self.plan_kind().label_with(self.isa())
    }

    /// The bitserial engine when this plan is bit-sliced — the pooled
    /// forward drives batch-amortized slicing through it directly
    /// ([`BitserialGemm::slice_rows`] / [`BitserialGemm::sweep_cols`]).
    pub(crate) fn bitserial(&self) -> Option<&BitserialGemm> {
        match &self.weights {
            Weights::Bits(b) => Some(b),
            _ => None,
        }
    }

    /// Pin the dense dispatch for cross-ISA equivalence tests.
    #[cfg(test)]
    pub(crate) fn set_isa(&mut self, isa: KernelIsa) {
        self.isa = isa;
    }

    /// One (row-range × output-range) tile of the dense integer
    /// forward — the unit the worker pool distributes — writing through
    /// a shared [`SplitMut`] view of the full `[rows × n_out]` output.
    /// `dscale[r]` is the hoisted per-row epilogue constant Δ_a[r]·Δ_w
    /// as f64 (computed once per row, not per cell). Tiles cover
    /// disjoint cells, so concurrent calls on disjoint ranges are
    /// race-free, and exact i32 accumulation keeps any grid bit-
    /// identical to the full-range call.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn forward_tile(
        &self,
        qa: &[i16],
        dscale: &[f64],
        r0: usize,
        r1: usize,
        o0: usize,
        o1: usize,
        gain: Option<&[f32]>,
        bias: &[f32],
        out: &SplitMut<f32>,
    ) {
        match &self.weights {
            Weights::I8(w) => tile_rows(
                w, self.d, self.n_out, self.isa, qa, dscale, r0, r1, o0, o1, gain, bias, out,
            ),
            Weights::I16(w) => tile_rows(
                w, self.d, self.n_out, self.isa, qa, dscale, r0, r1, o0, o1, gain, bias, out,
            ),
            _ => panic!("forward_tile wants a dense integer plan"),
        }
    }

    /// Whether this plan runs the integer path (drive it with
    /// [`forward_quant`]; otherwise use [`forward_f32`]).
    ///
    /// [`forward_quant`]: QuantGemm::forward_quant
    /// [`forward_f32`]: QuantGemm::forward_f32
    pub fn is_integer(&self) -> bool {
        !matches!(self.weights, Weights::F32(_))
    }

    /// Integer-domain forward over `rows` quantized activation rows:
    /// `out[r·n_out + o] = (Σ_i qa[r·d+i]·qw[o·d+i]) · Δ_a[r]·Δ_w + bias[o]`.
    /// The accumulator is exact i32; the epilogue folds both steps in
    /// f64 and rounds once to f32. Convenience form with a throwaway
    /// workspace — serving hot paths use [`forward_quant_arena`] so a
    /// bitserial plan slices into a reused per-worker arena instead.
    ///
    /// [`forward_quant_arena`]: QuantGemm::forward_quant_arena
    pub fn forward_quant(
        &self,
        qa: &[i16],
        step_a: &[f32],
        rows: usize,
        bias: &[f32],
        out: &mut [f32],
    ) {
        self.run_quant(qa, step_a, rows, None, bias, out, &mut Scratch::default());
    }

    /// [`forward_quant`] against a caller-owned [`Scratch`] arena (the
    /// allocation-free hot path; dense plans never touch the arena).
    ///
    /// [`forward_quant`]: QuantGemm::forward_quant
    pub fn forward_quant_arena(
        &self,
        qa: &[i16],
        step_a: &[f32],
        rows: usize,
        bias: &[f32],
        out: &mut [f32],
        scratch: &mut Scratch,
    ) {
        self.run_quant(qa, step_a, rows, None, bias, out, scratch);
    }

    /// [`forward_quant`] with a per-output-channel epilogue gain — the
    /// folded batch-norm path of the conv kernels (DESIGN.md §13):
    /// `out[r,o] = (Σ_i qa·qw) · Δ_a[r]·Δ_w·gain[o] + bias[o]`, all
    /// scale factors folded in f64 and rounded once to f32.
    ///
    /// [`forward_quant`]: QuantGemm::forward_quant
    pub fn forward_quant_scaled(
        &self,
        qa: &[i16],
        step_a: &[f32],
        rows: usize,
        gain: &[f32],
        bias: &[f32],
        out: &mut [f32],
    ) {
        assert_eq!(gain.len(), self.n_out);
        self.run_quant(qa, step_a, rows, Some(gain), bias, out, &mut Scratch::default());
    }

    /// [`forward_quant_scaled`] against a caller-owned [`Scratch`]
    /// arena (the conv serving hot path).
    ///
    /// [`forward_quant_scaled`]: QuantGemm::forward_quant_scaled
    #[allow(clippy::too_many_arguments)]
    pub fn forward_quant_scaled_arena(
        &self,
        qa: &[i16],
        step_a: &[f32],
        rows: usize,
        gain: &[f32],
        bias: &[f32],
        out: &mut [f32],
        scratch: &mut Scratch,
    ) {
        assert_eq!(gain.len(), self.n_out);
        self.run_quant(qa, step_a, rows, Some(gain), bias, out, scratch);
    }

    #[allow(clippy::too_many_arguments)]
    fn run_quant(
        &self,
        qa: &[i16],
        step_a: &[f32],
        rows: usize,
        gain: Option<&[f32]>,
        bias: &[f32],
        out: &mut [f32],
        scratch: &mut Scratch,
    ) {
        assert!(self.is_integer(), "f32 plan driven through forward_quant");
        assert_eq!(qa.len(), rows * self.d);
        assert_eq!(step_a.len(), rows);
        assert_eq!(bias.len(), self.n_out);
        assert_eq!(out.len(), rows * self.n_out);
        let sw = self.step_w as f64;
        if let Weights::Bits(b) = &self.weights {
            b.run(qa, step_a, rows, sw, gain, bias, out, scratch);
            return;
        }
        // hoist the per-row epilogue constant Δ_a[r]·Δ_w once per row
        // (it used to be recomputed per output tile)
        let Scratch { dscale, grow_events, .. } = scratch;
        grab(dscale, rows, grow_events);
        for r in 0..rows {
            dscale[r] = step_a[r] as f64 * sw;
        }
        let split = SplitMut::new(out);
        match &self.weights {
            Weights::I8(w) => tile_rows(
                w, self.d, self.n_out, self.isa, qa, dscale, 0, rows, 0, self.n_out, gain, bias,
                &split,
            ),
            Weights::I16(w) => tile_rows(
                w, self.d, self.n_out, self.isa, qa, dscale, 0, rows, 0, self.n_out, gain, bias,
                &split,
            ),
            _ => unreachable!("guarded by is_integer"),
        }
    }

    /// f32 fallback forward over raw activation rows, same transposed
    /// contiguous layout — and the *same operation sequence* as the
    /// pre-kernels scalar path (accumulator seeded with the bias, then
    /// products added in ascending index order), so it is bit-identical
    /// to the old strided loop by construction, not approximately.
    pub fn forward_f32(&self, x: &[f32], rows: usize, bias: &[f32], out: &mut [f32]) {
        assert_eq!(x.len(), rows * self.d);
        assert_eq!(bias.len(), self.n_out);
        assert_eq!(out.len(), rows * self.n_out);
        let w = match &self.weights {
            Weights::F32(w) => w,
            _ => panic!("integer plan driven through forward_f32"),
        };
        for o0 in (0..self.n_out).step_by(OUT_TILE) {
            let o1 = (o0 + OUT_TILE).min(self.n_out);
            for r in 0..rows {
                let a = &x[r * self.d..(r + 1) * self.d];
                for o in o0..o1 {
                    let wr = &w[o * self.d..(o + 1) * self.d];
                    let mut acc = bias[o];
                    for (&xv, &yv) in a.iter().zip(wr) {
                        acc += xv * yv;
                    }
                    out[r * self.n_out + o] = acc;
                }
            }
        }
    }

    /// [`forward_f32`] with a per-output-channel epilogue gain (the f32
    /// fallback of the folded-BN conv path). Unlike the unscaled
    /// variant there is no legacy bit-pattern to reproduce, so the
    /// accumulator starts at zero and the epilogue mirrors the integer
    /// kernel's: `out[r,o] = (Σ_i x·w) · gain[o] + bias[o]` with the
    /// gain applied in f64 and one rounding to f32.
    ///
    /// [`forward_f32`]: QuantGemm::forward_f32
    pub fn forward_f32_scaled(
        &self,
        x: &[f32],
        rows: usize,
        gain: &[f32],
        bias: &[f32],
        out: &mut [f32],
    ) {
        assert_eq!(x.len(), rows * self.d);
        assert_eq!(gain.len(), self.n_out);
        assert_eq!(bias.len(), self.n_out);
        assert_eq!(out.len(), rows * self.n_out);
        let w = match &self.weights {
            Weights::F32(w) => w,
            _ => panic!("integer plan driven through forward_f32_scaled"),
        };
        for o0 in (0..self.n_out).step_by(OUT_TILE) {
            let o1 = (o0 + OUT_TILE).min(self.n_out);
            for r in 0..rows {
                let a = &x[r * self.d..(r + 1) * self.d];
                for o in o0..o1 {
                    let wr = &w[o * self.d..(o + 1) * self.d];
                    let mut acc = 0.0f32;
                    for (&xv, &yv) in a.iter().zip(wr) {
                        acc += xv * yv;
                    }
                    out[r * self.n_out + o] = (acc as f64 * gain[o] as f64) as f32 + bias[o];
                }
            }
        }
    }
}

/// Dense weight element (i8 or i16): the ISA-dispatched inner dot
/// against the centered i16 activation span. Every backend is exact —
/// any partial sum of products is bounded by Σ|q_a·q_w| ≤ d·s_a·s_w ≤
/// i32::MAX (the plan admission bound), so neither the scalar
/// accumulator nor any SIMD lane can wrap and every summation order
/// yields the same bits (pinned by `dense_dot_backends_agree`).
pub(crate) trait DenseWeight: Copy + Send + Sync + 'static {
    fn dot(a: &[i16], w: &[Self], isa: KernelIsa) -> i32;
}

impl DenseWeight for i8 {
    #[inline]
    fn dot(a: &[i16], w: &[i8], isa: KernelIsa) -> i32 {
        match isa {
            #[cfg(target_arch = "x86_64")]
            KernelIsa::Avx2 => {
                // SAFETY: plans only carry Avx2 when detection
                // confirmed it at plan build.
                unsafe { dot_i8_avx2(a, w) }
            }
            _ => dot_scalar(a, w),
        }
    }
}

impl DenseWeight for i16 {
    #[inline]
    fn dot(a: &[i16], w: &[i16], isa: KernelIsa) -> i32 {
        match isa {
            #[cfg(target_arch = "x86_64")]
            KernelIsa::Avx2 => {
                // SAFETY: plans only carry Avx2 when detection
                // confirmed it at plan build.
                unsafe { dot_i16_avx2(a, w) }
            }
            _ => dot_scalar(a, w),
        }
    }
}

/// Portable scalar dot — the fallback leg of every dispatch and the
/// reference the SIMD kernels are pinned against.
#[inline]
fn dot_scalar<T: Copy>(a: &[i16], w: &[T]) -> i32
where
    i32: From<T>,
{
    let mut acc = 0i32;
    for (&x, &y) in a.iter().zip(w) {
        acc += x as i32 * i32::from(y);
    }
    acc
}

/// AVX2 i8-weight dot, 16 elements per step: weights sign-extended
/// i8→i16 (`_mm256_cvtepi8_epi16`), then `_mm256_madd_epi16` multiplies
/// adjacent pairs into i32 lanes. The madd itself cannot saturate — its
/// only overflow case is two −32768·−32768 products, and centered codes
/// q = 2c − s never reach −32768 — and the lane accumulators are exact
/// per the admission bound (see [`DenseWeight`]). Scalar tail for
/// `d mod 16` elements.
///
/// # Safety
/// Caller must have verified AVX2 support (detection at plan build).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn dot_i8_avx2(a: &[i16], w: &[i8]) -> i32 {
    use std::arch::x86_64::{
        __m128i, __m256i, _mm256_add_epi32, _mm256_cvtepi8_epi16, _mm256_loadu_si256,
        _mm256_madd_epi16, _mm256_setzero_si256, _mm256_storeu_si256, _mm_loadu_si128,
    };
    debug_assert_eq!(a.len(), w.len());
    let d = a.len();
    let chunks = d / 16;
    let mut lanes = [0i32; 8];
    // SAFETY: every load covers 16 in-bounds elements (c < d/16), the
    // `loadu`/`storeu` forms have no alignment requirement, and the
    // `lanes` store writes exactly the 32 bytes it owns; AVX2 itself is
    // guaranteed by this function's contract.
    unsafe {
        let mut acc = _mm256_setzero_si256();
        for c in 0..chunks {
            let va = _mm256_loadu_si256(a.as_ptr().add(16 * c) as *const __m256i);
            let vw =
                _mm256_cvtepi8_epi16(_mm_loadu_si128(w.as_ptr().add(16 * c) as *const __m128i));
            acc = _mm256_add_epi32(acc, _mm256_madd_epi16(va, vw));
        }
        _mm256_storeu_si256(lanes.as_mut_ptr() as *mut __m256i, acc);
    }
    let mut sum: i32 = lanes.iter().sum();
    for i in 16 * chunks..d {
        sum += a[i] as i32 * w[i] as i32;
    }
    sum
}

/// AVX2 i16-weight dot, 16 elements per step: two full 256-bit loads
/// into `_mm256_madd_epi16`. Centered codes never reach −32768 (|q| ≤
/// 2^15 − 1 at the widest admissible k), so the pairwise i32 result is
/// exact, and lane accumulators are exact per the admission bound.
///
/// # Safety
/// Caller must have verified AVX2 support (detection at plan build).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn dot_i16_avx2(a: &[i16], w: &[i16]) -> i32 {
    use std::arch::x86_64::{
        __m256i, _mm256_add_epi32, _mm256_loadu_si256, _mm256_madd_epi16, _mm256_setzero_si256,
        _mm256_storeu_si256,
    };
    debug_assert_eq!(a.len(), w.len());
    let d = a.len();
    let chunks = d / 16;
    let mut lanes = [0i32; 8];
    // SAFETY: both 256-bit loads cover 16 in-bounds i16 elements
    // (c < d/16) with no alignment requirement (`loadu`), and the
    // `lanes` store writes exactly the 32 bytes it owns; AVX2 itself is
    // guaranteed by this function's contract.
    unsafe {
        let mut acc = _mm256_setzero_si256();
        for c in 0..chunks {
            let va = _mm256_loadu_si256(a.as_ptr().add(16 * c) as *const __m256i);
            let vw = _mm256_loadu_si256(w.as_ptr().add(16 * c) as *const __m256i);
            acc = _mm256_add_epi32(acc, _mm256_madd_epi16(va, vw));
        }
        _mm256_storeu_si256(lanes.as_mut_ptr() as *mut __m256i, acc);
    }
    let mut sum: i32 = lanes.iter().sum();
    for i in 16 * chunks..d {
        sum += a[i] as i32 * w[i] as i32;
    }
    sum
}

/// The cache-blocked dense integer tile kernel shared by i8 and i16
/// storage (§16): within one (row, output) tile, the reduction runs in
/// D_TILE blocks with the OUT_TILE accumulator array carried across
/// blocks — one activation block is swept against the whole weight tile
/// before moving on, so the block stays L1-resident and the weight tile
/// stays L2-resident across all batch rows (weight-stationary batch
/// reuse). Epilogue: hoisted per-row `dscale[r]` (= Δ_a[r]·Δ_w), folded
/// with the optional per-channel gain in f64, one rounding to f32 —
/// `gain = None` reproduces [`QuantGemm::forward_quant`]'s arithmetic
/// exactly (the per-channel factor is never multiplied in).
#[allow(clippy::too_many_arguments)]
fn tile_rows<T: DenseWeight>(
    w: &[T],
    d: usize,
    n_out: usize,
    isa: KernelIsa,
    qa: &[i16],
    dscale: &[f64],
    r0: usize,
    r1: usize,
    o0: usize,
    o1: usize,
    gain: Option<&[f32]>,
    bias: &[f32],
    out: &SplitMut<f32>,
) {
    let mut acc = [0i32; OUT_TILE];
    for ot0 in (o0..o1).step_by(OUT_TILE) {
        let ot1 = (ot0 + OUT_TILE).min(o1);
        for r in r0..r1 {
            let a = &qa[r * d..(r + 1) * d];
            acc[..ot1 - ot0].fill(0);
            for i0 in (0..d).step_by(D_TILE) {
                let i1 = (i0 + D_TILE).min(d);
                let ab = &a[i0..i1];
                for o in ot0..ot1 {
                    acc[o - ot0] += T::dot(ab, &w[o * d + i0..o * d + i1], isa);
                }
            }
            let da = dscale[r];
            for o in ot0..ot1 {
                let scale = match gain {
                    Some(g) => da * g[o] as f64,
                    None => da,
                };
                // SAFETY: tiles cover disjoint (r, o) cells.
                unsafe { out.write(r * n_out + o, (acc[o - ot0] as f64 * scale) as f32 + bias[o]) };
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::activ::quantize_row_centered;
    use crate::quant::code_levels;
    use crate::tensor::Tensor;
    use crate::util::rng::Rng;

    /// Hand-build a PackedTensor from explicit codes (bypasses the
    /// max-abs scale heuristic so tests control the grid exactly).
    fn packed_from_codes(codes: &[u32], shape: Vec<usize>, bits: u32, scale: f32) -> PackedTensor {
        PackedTensor {
            shape,
            bits,
            scale,
            payload: pack::pack_codes(codes, bits),
        }
    }

    fn random_codes(n: usize, bits: u32, rng: &mut Rng) -> Vec<u32> {
        let max = code_levels(bits) as u64;
        (0..n).map(|_| (rng.next_u64() % (max + 1)) as u32).collect()
    }

    /// Bit-exactness against a genuine dequantize-then-f32-matmul.
    ///
    /// With power-of-two steps every dequantized grid point is exact in
    /// f32, every product q_a·q_w·2^-(ma+mw) has a ≤16-bit integer
    /// mantissa, and every partial sum stays an integer multiple of
    /// 2^-(ma+mw) below 2^24 for d ≤ 128 — so the f32 matmul is exact
    /// arithmetic and must equal the integer kernel *bitwise*, for
    /// every k ∈ 2..=8. (Arbitrary scales are covered by the i64-oracle
    /// test below; there f32 matmul rounding makes bitwise equality
    /// impossible for any kernel.)
    #[test]
    fn bitexact_vs_f32_matmul_on_pow2_steps_all_widths() {
        let mut rng = Rng::new(42);
        for k in 2..=8u32 {
            let d = 96usize; // ≤ 128 keeps f32 partial sums exact at k=8
            let n_out = 7usize;
            let rows = 3usize;
            let s_i = code_levels(k) as i32;
            // scale = s·2^-9 ⇒ step_w = scale/s = 2^-9 exactly
            let wscale = s_i as f32 * 0.001953125; // 2^-9
            let wcodes = random_codes(d * n_out, k, &mut rng);
            let wt = packed_from_codes(&wcodes, vec![d, n_out], k, wscale);
            let gemm = QuantGemm::from_packed(&wt, k).unwrap();
            assert!(gemm.is_integer());

            // activations on the same grid with step 2^-7; force
            // max-abs = s·2^-7 so the recovered step is exactly 2^-7
            let acodes = random_codes(rows * d, k, &mut rng);
            let astep = 0.0078125f32; // 2^-7
            let mut x = vec![0.0f32; rows * d];
            for (xi, &c) in x.iter_mut().zip(&acodes) {
                *xi = (2 * c as i32 - s_i) as f32 * astep;
            }
            for r in 0..rows {
                x[r * d] = s_i as f32 * astep; // pin the row max
            }
            let bias: Vec<f32> = (0..n_out).map(|_| rng.normal() * 0.1).collect();

            // kernel path: quantize on the fly + integer GEMM
            let mut qa = vec![0i16; rows * d];
            let mut steps = vec![0.0f32; rows];
            for r in 0..rows {
                steps[r] =
                    quantize_row_centered(&x[r * d..(r + 1) * d], k, &mut qa[r * d..(r + 1) * d]);
                assert_eq!(steps[r], astep, "k={k} row {r}: step not recovered");
            }
            let mut got = vec![0.0f32; rows * n_out];
            gemm.forward_quant(&qa, &steps, rows, &bias, &mut got);

            // oracle: canonical dequantized weights, plain f32 matmul
            let wdeq: Tensor = wt.dequantize();
            for r in 0..rows {
                for o in 0..n_out {
                    let mut acc = 0.0f32;
                    for i in 0..d {
                        acc += x[r * d + i] * wdeq.data[i * n_out + o];
                    }
                    let want = acc + bias[o];
                    assert_eq!(
                        got[r * n_out + o].to_bits(),
                        want.to_bits(),
                        "k={k} r={r} o={o}: {} vs {want}",
                        got[r * n_out + o]
                    );
                }
            }
        }
    }

    /// At arbitrary scales the integer accumulator must still equal a
    /// naive i64 dot over independently-unpacked (scalar path) codes —
    /// blocked loops, i8/i16 storage, transposition and the u64 unpack
    /// fast path all cancel out exactly, for every width 2..=8.
    #[test]
    fn integer_acc_matches_scalar_i64_oracle_any_scale() {
        let mut rng = Rng::new(7);
        for k in 2..=8u32 {
            let d = 131usize; // odd: exercises partial-byte payload tails
            let n_out = 10usize;
            let rows = 4usize;
            let wdata: Vec<f32> = (0..d * n_out).map(|_| rng.normal() * 0.2).collect();
            let wt = PackedTensor::quantize(&Tensor::new(vec![d, n_out], wdata), k);
            let gemm = QuantGemm::from_packed(&wt, k).unwrap();
            assert!(gemm.is_integer(), "k={k}");

            let x: Vec<f32> = (0..rows * d).map(|_| rng.normal()).collect();
            let mut qa = vec![0i16; rows * d];
            let mut steps = vec![0.0f32; rows];
            for r in 0..rows {
                steps[r] =
                    quantize_row_centered(&x[r * d..(r + 1) * d], k, &mut qa[r * d..(r + 1) * d]);
            }
            let bias = vec![0.25f32; n_out];
            let mut got = vec![0.0f32; rows * n_out];
            gemm.forward_quant(&qa, &steps, rows, &bias, &mut got);

            // oracle: scalar per-element unpack + i64 accumulation +
            // the same epilogue arithmetic
            let s_i = code_levels(k) as i64;
            let sw = if wt.scale > 0.0 { wt.scale / s_i as f32 } else { 0.0 };
            for r in 0..rows {
                for o in 0..n_out {
                    let mut acc = 0i64;
                    for i in 0..d {
                        let c = pack::read_bits_scalar(
                            &wt.payload,
                            (i * n_out + o) * k as usize,
                            k,
                        ) as i64;
                        acc += qa[r * d + i] as i64 * (2 * c - s_i);
                    }
                    assert!(acc.abs() <= i32::MAX as i64, "k={k}: bound violated");
                    let want = (acc as f64 * (steps[r] as f64 * sw as f64)) as f32 + bias[o];
                    assert_eq!(
                        got[r * n_out + o].to_bits(),
                        want.to_bits(),
                        "k={k} r={r} o={o}"
                    );
                }
            }
        }
    }

    #[test]
    fn f32_fallback_matches_legacy_strided_scalar_path() {
        // raw-f32 weights: the plan must reproduce the pre-kernels
        // strided loop bit-for-bit (same values, same summation order)
        let mut rng = Rng::new(13);
        let (d, n_out, rows) = (57usize, 9usize, 2usize);
        let wdata: Vec<f32> = (0..d * n_out).map(|_| rng.normal()).collect();
        let wt = PackedTensor::raw(&Tensor::new(vec![d, n_out], wdata.clone()));
        let gemm = QuantGemm::from_packed(&wt, 32).unwrap();
        assert!(!gemm.is_integer());
        let x: Vec<f32> = (0..rows * d).map(|_| rng.normal()).collect();
        let bias: Vec<f32> = (0..n_out).map(|_| rng.normal()).collect();
        let mut got = vec![0.0f32; rows * n_out];
        gemm.forward_f32(&x, rows, &bias, &mut got);
        for r in 0..rows {
            for o in 0..n_out {
                // the old serving loop: bias-seeded accumulator, then
                // w[i*n_out + o] with i ascending
                let mut acc = bias[o];
                for i in 0..d {
                    acc += x[r * d + i] * wdata[i * n_out + o];
                }
                assert_eq!(got[r * n_out + o].to_bits(), acc.to_bits());
            }
        }
    }

    #[test]
    fn overflow_guard_falls_back_to_f32() {
        assert!(QuantGemm::integer_bound_ok(3072, 8, 8));
        assert!(QuantGemm::integer_bound_ok(33_025, 8, 8)); // 33025·255² ≤ i32::MAX
        assert!(!QuantGemm::integer_bound_ok(33_026, 8, 8));
        assert!(!QuantGemm::integer_bound_ok(2_100, 15, 15));
        let mut rng = Rng::new(3);
        let wdata: Vec<f32> = (0..8 * 4).map(|_| rng.normal()).collect();
        let wt = PackedTensor::quantize(&Tensor::new(vec![8, 4], wdata), 8);
        // k_a = 32 (identity) forces the f32 plan even for packed weights
        let gemm = QuantGemm::from_packed(&wt, 32).unwrap();
        assert!(!gemm.is_integer());
    }

    #[test]
    fn scaled_epilogue_matches_unscaled_at_unit_gain_and_oracle_otherwise() {
        let mut rng = Rng::new(29);
        for k in [2u32, 4, 8] {
            let (d, n_out, rows) = (45usize, 9usize, 3usize);
            let wdata: Vec<f32> = (0..d * n_out).map(|_| rng.normal() * 0.2).collect();
            let wt = PackedTensor::quantize(&Tensor::new(vec![d, n_out], wdata), k);
            let gemm = QuantGemm::from_packed(&wt, k).unwrap();
            assert!(gemm.is_integer(), "k={k}");
            let x: Vec<f32> = (0..rows * d).map(|_| rng.normal()).collect();
            let mut qa = vec![0i16; rows * d];
            let mut steps = vec![0.0f32; rows];
            for r in 0..rows {
                steps[r] =
                    quantize_row_centered(&x[r * d..(r + 1) * d], k, &mut qa[r * d..(r + 1) * d]);
            }
            let bias: Vec<f32> = (0..n_out).map(|_| rng.normal() * 0.1).collect();

            // unit gain: f64 ·1.0 is exact, so scaled == unscaled bitwise
            let mut plain = vec![0.0f32; rows * n_out];
            gemm.forward_quant(&qa, &steps, rows, &bias, &mut plain);
            let mut unit = vec![0.0f32; rows * n_out];
            gemm.forward_quant_scaled(&qa, &steps, rows, &vec![1.0; n_out], &bias, &mut unit);
            for (a, b) in plain.iter().zip(&unit) {
                assert_eq!(a.to_bits(), b.to_bits(), "k={k}");
            }

            // random per-channel gain vs the scalar i64 oracle with the
            // same f64 epilogue folding
            let gain: Vec<f32> = (0..n_out).map(|_| 0.5 + rng.uniform()).collect();
            let mut got = vec![0.0f32; rows * n_out];
            gemm.forward_quant_scaled(&qa, &steps, rows, &gain, &bias, &mut got);
            let s_i = code_levels(k) as i64;
            let sw = if wt.scale > 0.0 { wt.scale / s_i as f32 } else { 0.0 };
            for r in 0..rows {
                for o in 0..n_out {
                    let mut acc = 0i64;
                    for i in 0..d {
                        let c = pack::read_bits_scalar(&wt.payload, (i * n_out + o) * k as usize, k)
                            as i64;
                        acc += qa[r * d + i] as i64 * (2 * c - s_i);
                    }
                    let scale = steps[r] as f64 * sw as f64 * gain[o] as f64;
                    let want = (acc as f64 * scale) as f32 + bias[o];
                    assert_eq!(got[r * n_out + o].to_bits(), want.to_bits(), "k={k} r={r} o={o}");
                }
            }
        }
    }

    #[test]
    fn f32_scaled_epilogue_matches_direct_dot() {
        let mut rng = Rng::new(31);
        let (d, n_out, rows) = (23usize, 6usize, 2usize);
        let wdata: Vec<f32> = (0..d * n_out).map(|_| rng.normal()).collect();
        let wt = PackedTensor::raw(&Tensor::new(vec![d, n_out], wdata.clone()));
        let gemm = QuantGemm::from_packed(&wt, 32).unwrap();
        assert!(!gemm.is_integer());
        let x: Vec<f32> = (0..rows * d).map(|_| rng.normal()).collect();
        let gain: Vec<f32> = (0..n_out).map(|_| 0.5 + rng.uniform()).collect();
        let bias: Vec<f32> = (0..n_out).map(|_| rng.normal()).collect();
        let mut got = vec![0.0f32; rows * n_out];
        gemm.forward_f32_scaled(&x, rows, &gain, &bias, &mut got);
        for r in 0..rows {
            for o in 0..n_out {
                let mut acc = 0.0f32;
                for i in 0..d {
                    acc += x[r * d + i] * wdata[i * n_out + o];
                }
                let want = (acc as f64 * gain[o] as f64) as f32 + bias[o];
                assert_eq!(got[r * n_out + o].to_bits(), want.to_bits(), "r={r} o={o}");
            }
        }
    }

    #[test]
    fn plan_selection_dispatch_boundaries() {
        let mut rng = Rng::new(41);
        let t = Tensor::new(vec![40, 5], (0..40 * 5).map(|_| rng.normal()).collect());
        let plan = |k_w: u32, k_a: u32| {
            QuantGemm::from_packed(&PackedTensor::quantize(&t, k_w), k_a)
                .unwrap()
                .plan_kind()
        };
        // k_w·k_a ≤ BITSERIAL_MAX_PRODUCT rides the popcount planes
        assert_eq!(plan(1, 1), PlanKind::Bitserial);
        assert_eq!(plan(2, 2), PlanKind::Bitserial);
        assert_eq!(plan(1, 4), PlanKind::Bitserial);
        assert_eq!(plan(4, 1), PlanKind::Bitserial);
        // past the product threshold: dense centered codes (the SIMD
        // dense path moved the crossover down from 9 — see §16)
        assert_eq!(plan(3, 3), PlanKind::Int8);
        assert_eq!(plan(2, 4), PlanKind::Int8);
        assert_eq!(plan(1, 8), PlanKind::Int8);
        assert_eq!(plan(2, 5), PlanKind::Int8);
        assert_eq!(plan(4, 4), PlanKind::Int8);
        assert_eq!(plan(8, 8), PlanKind::Int8);
        assert_eq!(plan(12, 2), PlanKind::Int16);
        // inadmissible integer path: f32 fallback
        assert_eq!(plan(4, 32), PlanKind::F32);
        assert_eq!(
            QuantGemm::from_packed(&PackedTensor::raw(&t), 8).unwrap().plan_kind(),
            PlanKind::F32
        );
        // forced choices override the heuristic but never admissibility
        let wt = PackedTensor::quantize(&t, 2);
        let forced = QuantGemm::from_packed_with(&wt, 2, PlanChoice::DenseInt).unwrap();
        assert_eq!(forced.plan_kind(), PlanKind::Int8);
        let forced = QuantGemm::from_packed_with(&wt, 8, PlanChoice::Bitserial).unwrap();
        assert_eq!(forced.plan_kind(), PlanKind::Bitserial);
        let forced = QuantGemm::from_packed_with(&wt, 2, PlanChoice::F32).unwrap();
        assert_eq!(forced.plan_kind(), PlanKind::F32);
        assert!(QuantGemm::from_packed_with(&PackedTensor::raw(&t), 2, PlanChoice::Bitserial)
            .is_err());
        assert!(QuantGemm::from_packed_with(&wt, 32, PlanChoice::DenseInt).is_err());
    }

    /// The SIMD dense dots must return exactly the scalar integer at
    /// every length class: below one vector (1, 7, 15), exact multiples
    /// (16, 32, 1024), one-past (17, 33, 1033) and odd in-between —
    /// the partial-lane tails are where a wrong bound silently truncates.
    #[test]
    fn dense_dot_backends_agree() {
        let mut rng = Rng::new(97);
        let isa = detect_dense(); // Portable on non-AVX2 (test is then trivially green)
        for &len in &[1usize, 7, 15, 16, 17, 31, 32, 33, 100, 131, 1024, 1033] {
            let a: Vec<i16> =
                (0..len).map(|_| (rng.next_u64() % 511) as i16 - 255).collect();
            let w8: Vec<i8> =
                (0..len).map(|_| ((rng.next_u64() % 255) as i32 - 127) as i8).collect();
            let w16: Vec<i16> =
                (0..len).map(|_| (rng.next_u64() % 2047) as i16 - 1023).collect();
            // oracle in i64 + bound check (keeps the i32 contract honest)
            let mut o8 = 0i64;
            let mut o16 = 0i64;
            for i in 0..len {
                o8 += a[i] as i64 * w8[i] as i64;
                o16 += a[i] as i64 * w16[i] as i64;
            }
            assert!(o8.abs() <= i32::MAX as i64 && o16.abs() <= i32::MAX as i64);
            assert_eq!(dot_scalar(&a, &w8) as i64, o8, "scalar i8 len={len}");
            assert_eq!(dot_scalar(&a, &w16) as i64, o16, "scalar i16 len={len}");
            assert_eq!(<i8 as DenseWeight>::dot(&a, &w8, isa) as i64, o8, "i8 len={len}");
            assert_eq!(<i16 as DenseWeight>::dot(&a, &w16, isa) as i64, o16, "i16 len={len}");
        }
    }

    /// The tiled/SIMD forward vs the scalar i64 oracle at shapes that
    /// straddle every tile boundary: d and n_out not multiples of
    /// OUT_TILE/16-lane/D_TILE, exact multiples, and one-past-D_TILE.
    /// k = 3 drives the i8 storage, k = 8 the i16 storage.
    #[test]
    fn tiled_path_matches_i64_oracle_at_tile_boundaries() {
        let mut rng = Rng::new(101);
        for &(d, n_out) in &[(17usize, 3usize), (33, 17), (64, 16), (131, 10), (1025, 5)] {
            for k in [3u32, 8] {
                let rows = 3usize;
                let wdata: Vec<f32> = (0..d * n_out).map(|_| rng.normal() * 0.2).collect();
                let wt = PackedTensor::quantize(&Tensor::new(vec![d, n_out], wdata), k);
                let gemm =
                    QuantGemm::from_packed_with(&wt, k, PlanChoice::DenseInt).unwrap();
                assert_eq!(
                    gemm.plan_kind(),
                    if k <= 7 { PlanKind::Int8 } else { PlanKind::Int16 },
                    "d={d} k={k}"
                );
                let x: Vec<f32> = (0..rows * d).map(|_| rng.normal()).collect();
                let mut qa = vec![0i16; rows * d];
                let mut steps = vec![0.0f32; rows];
                for r in 0..rows {
                    steps[r] = quantize_row_centered(
                        &x[r * d..(r + 1) * d],
                        k,
                        &mut qa[r * d..(r + 1) * d],
                    );
                }
                let bias = vec![0.125f32; n_out];
                let mut got = vec![0.0f32; rows * n_out];
                gemm.forward_quant(&qa, &steps, rows, &bias, &mut got);
                let s_i = code_levels(k) as i64;
                let sw = if wt.scale > 0.0 { wt.scale / s_i as f32 } else { 0.0 };
                for r in 0..rows {
                    for o in 0..n_out {
                        let mut acc = 0i64;
                        for i in 0..d {
                            let c = pack::read_bits_scalar(
                                &wt.payload,
                                (i * n_out + o) * k as usize,
                                k,
                            ) as i64;
                            acc += qa[r * d + i] as i64 * (2 * c - s_i);
                        }
                        let want =
                            (acc as f64 * (steps[r] as f64 * sw as f64)) as f32 + bias[o];
                        assert_eq!(
                            got[r * n_out + o].to_bits(),
                            want.to_bits(),
                            "d={d} n_out={n_out} k={k} r={r} o={o}"
                        );
                    }
                }
            }
        }
    }

    /// Drive the accumulator to ±(i32::MAX − 3022) — the exact edge the
    /// admission bound allows at W8/A8, d = 33 025 — on both the SIMD
    /// and portable paths. Any lane that wraps or saturates is off by
    /// billions here, not by one ulp.
    #[test]
    fn i32_bound_edge_is_exact_on_every_isa() {
        let d = 33_025usize;
        let n_out = 2usize;
        // column 0 all code 0 (q_w = −255), column 1 all 255 (q_w = +255)
        let mut codes = vec![0u32; d * n_out];
        for i in 0..d {
            codes[i * n_out + 1] = 255;
        }
        // scale = 255 ⇒ Δ_w = 255/255 = 1.0 exactly
        let wt = packed_from_codes(&codes, vec![d, n_out], 8, 255.0);
        let mut gemm = QuantGemm::from_packed_with(&wt, 8, PlanChoice::DenseInt).unwrap();
        let qa = vec![-255i16; d]; // extreme centered activation row
        let steps = vec![1.0f32];
        let bias = [0.5f32, -0.5];
        let edge = 33_025i64 * 255 * 255; // 2_147_480_625 = i32::MAX − 3022
        assert!(edge <= i32::MAX as i64);
        let want0 = (edge as f64) as f32 + bias[0]; // col 0: (−255)·(−255)·d
        let want1 = (-edge as f64) as f32 + bias[1];
        for isa in [detect_dense(), KernelIsa::Portable] {
            gemm.set_isa(isa);
            let mut out = vec![0.0f32; n_out];
            gemm.forward_quant(&qa, &steps, 1, &bias, &mut out);
            assert_eq!(out[0].to_bits(), want0.to_bits(), "{isa:?} col 0");
            assert_eq!(out[1].to_bits(), want1.to_bits(), "{isa:?} col 1");
        }
    }

    /// Pinning the dispatch itself: the same plan forced onto every
    /// available ISA returns the same bits for i8 and i16 storage,
    /// scaled and unscaled epilogues.
    #[test]
    fn isa_override_never_changes_bits() {
        let mut rng = Rng::new(103);
        for k in [4u32, 8, 12] {
            let (d, n_out, rows) = (131usize, 10usize, 3usize);
            let k_a = 6u32;
            let wdata: Vec<f32> = (0..d * n_out).map(|_| rng.normal() * 0.2).collect();
            let wt = PackedTensor::quantize(&Tensor::new(vec![d, n_out], wdata), k);
            let mut gemm = QuantGemm::from_packed_with(&wt, k_a, PlanChoice::DenseInt).unwrap();
            let x: Vec<f32> = (0..rows * d).map(|_| rng.normal()).collect();
            let mut qa = vec![0i16; rows * d];
            let mut steps = vec![0.0f32; rows];
            for r in 0..rows {
                steps[r] = quantize_row_centered(
                    &x[r * d..(r + 1) * d],
                    k_a,
                    &mut qa[r * d..(r + 1) * d],
                );
            }
            let gain: Vec<f32> = (0..n_out).map(|_| 0.5 + rng.uniform()).collect();
            let bias: Vec<f32> = (0..n_out).map(|_| rng.normal() * 0.1).collect();
            gemm.set_isa(KernelIsa::Portable);
            let mut base = vec![0.0f32; rows * n_out];
            gemm.forward_quant(&qa, &steps, rows, &bias, &mut base);
            let mut base_scaled = vec![0.0f32; rows * n_out];
            gemm.forward_quant_scaled(&qa, &steps, rows, &gain, &bias, &mut base_scaled);
            for isa in [detect_dense()] {
                gemm.set_isa(isa);
                let mut got = vec![0.0f32; rows * n_out];
                gemm.forward_quant(&qa, &steps, rows, &bias, &mut got);
                for (a, b) in base.iter().zip(&got) {
                    assert_eq!(a.to_bits(), b.to_bits(), "k={k} {isa:?}");
                }
                gemm.forward_quant_scaled(&qa, &steps, rows, &gain, &bias, &mut got);
                for (a, b) in base_scaled.iter().zip(&got) {
                    assert_eq!(a.to_bits(), b.to_bits(), "scaled k={k} {isa:?}");
                }
            }
        }
    }

    #[test]
    fn plan_labels_expose_isa() {
        // the full table — obs series names are API
        use crate::kernels::KernelIsa::*;
        assert_eq!(PlanKind::Int8.label_with(Avx2), "int8_avx2");
        assert_eq!(PlanKind::Int8.label_with(Portable), "int8");
        assert_eq!(PlanKind::Int16.label_with(Avx2), "int16_avx2");
        assert_eq!(PlanKind::Int16.label_with(Portable), "int16");
        assert_eq!(PlanKind::Bitserial.label_with(Avx2), "bitserial_avx2");
        assert_eq!(PlanKind::Bitserial.label_with(Popcnt), "bitserial_popcnt");
        assert_eq!(PlanKind::Bitserial.label_with(Portable), "bitserial");
        assert_eq!(PlanKind::F32.label_with(Avx2), "f32");
        // plan_label goes through the plan's own dispatch
        let mut rng = Rng::new(111);
        let t = Tensor::new(vec![20, 4], (0..80).map(|_| rng.normal()).collect());
        let wt = PackedTensor::quantize(&t, 4); // k_w = 4 ⇒ i8 storage
        let mut gemm = QuantGemm::from_packed_with(&wt, 8, PlanChoice::DenseInt).unwrap();
        gemm.set_isa(Portable);
        assert_eq!(gemm.plan_label(), "int8");
        gemm.set_isa(Avx2);
        assert_eq!(gemm.plan_label(), "int8_avx2");
        let bits = QuantGemm::from_packed_with(&wt, 2, PlanChoice::Bitserial).unwrap();
        assert!(bits.plan_label().starts_with("bitserial"));
        let f = QuantGemm::from_packed_with(&wt, 2, PlanChoice::F32).unwrap();
        assert_eq!(f.plan_label(), "f32");
    }

    #[test]
    fn zero_scale_weights_produce_zero_logits_plus_bias() {
        let wt = PackedTensor::quantize(&Tensor::zeros(vec![6, 3]), 4);
        assert_eq!(wt.scale, 0.0);
        let gemm = QuantGemm::from_packed(&wt, 4).unwrap();
        let x = vec![1.0f32; 6];
        let mut qa = vec![0i16; 6];
        let step = quantize_row_centered(&x, 4, &mut qa);
        let mut out = vec![0.0f32; 3];
        gemm.forward_quant(&qa, &[step], 1, &[1.0, 2.0, 3.0], &mut out);
        assert_eq!(out, vec![1.0, 2.0, 3.0]);
    }
}

//! Integer-domain 2-d convolution over im2col patches (DESIGN.md §13).
//!
//! A convolution is a GEMM in disguise: expanding each output position's
//! receptive field into a row (im2col) turns `conv2d(x, w)` into
//! `patches · W` with `W` the checkpoint's `[kh, kw, c_in, c_out]`
//! kernel flattened to `[kh·kw·c_in, c_out]` — exactly the shape
//! [`QuantGemm`] plans already execute. This module adds the pieces that
//! make the learned conv bit-widths buy integer compute on the serving
//! path, the same way [`super::QuantMlp`] does for fc stacks:
//!
//! * [`im2col`] — patch expansion, zero-filled outside the image, patch
//!   element order `(ky, kx, c)` matching the kernel layout;
//! * activation quantization *per patch row* via [`super::activ`], the
//!   same `2^k − 1` grid as training — a patch's codes depend only on
//!   its own values, so batch composition never changes a sample;
//! * batch-norm folded into the GEMM epilogue ([`fold_bn`]): inference
//!   BN is an affine map per channel, so `γ·(z − μ)/√(σ² + ε) + β`
//!   collapses into the kernels' one-f64-multiply epilogue
//!   ([`QuantGemm::forward_quant_scaled`]);
//! * [`avgpool2x2`] — the 2×2/stride-2 average pool between blocks;
//! * [`QuantConvNet`] — conv→BN→ReLU→pool blocks plus a [`QuantMlp`]
//!   fc head, loaded from one packed checkpoint whose meta carries
//!   `conv_layers` next to the existing `mlp_layers`;
//! * [`QuantResBlock`] + [`global_avgpool`] — residual blocks with
//!   integer skip joins for the resnet20-class topology (meta
//!   `res_blocks`, DESIGN.md §18): each branch finishes its own exact
//!   integer accumulation and per-channel f64 epilogue (BN folded per
//!   branch), the f32 join adds the two rounded branch outputs, and
//!   the next layer's per-patch quantization re-quantizes the joined
//!   activations onto its own 2^k − 1 grid.
//!
//! The native conv trainers ([`crate::backprop::conv`]) evaluate
//! through this exact code, so trainer eval and the served model are
//! the same numbers — the guarantee the MLP path already gives.

use std::time::Instant;

use crate::obs;
use crate::serve::packed::QuantizedCheckpoint;
use crate::util::json::Json;

use super::activ;
use super::gemm::QuantGemm;
use super::{chunk_range, grab, LayerObs, QuantMlp, Scratch, SplitMut, WorkerPool};

/// Batch-norm epsilon — one constant shared by the native trainer's
/// batch-stat normalization and the folded inference epilogue, so the
/// two sides can never disagree on the stabilizer.
pub const BN_EPS: f32 = 1e-5;

/// Geometry of one 2-d convolution over NHWC input.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ConvGeom {
    /// Input spatial size.
    pub h: usize,
    pub w: usize,
    pub c_in: usize,
    pub c_out: usize,
    /// Kernel spatial size.
    pub kh: usize,
    pub kw: usize,
    /// Stride, both dimensions.
    pub stride: usize,
    /// Zero padding, both dimensions.
    pub pad: usize,
}

impl ConvGeom {
    /// Output spatial size: `(dim + 2·pad − k)/stride + 1` per axis.
    pub fn out_hw(&self) -> (usize, usize) {
        (
            (self.h + 2 * self.pad - self.kh) / self.stride + 1,
            (self.w + 2 * self.pad - self.kw) / self.stride + 1,
        )
    }

    /// im2col row length: `kh·kw·c_in`.
    pub fn patch_len(&self) -> usize {
        self.kh * self.kw * self.c_in
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.h == 0 || self.w == 0 || self.c_in == 0 || self.c_out == 0 {
            return Err(format!("conv geometry has a zero dimension: {self:?}"));
        }
        if self.kh == 0 || self.kw == 0 || self.stride == 0 {
            return Err(format!("conv kernel/stride must be >= 1: {self:?}"));
        }
        if self.h + 2 * self.pad < self.kh || self.w + 2 * self.pad < self.kw {
            return Err(format!("kernel larger than padded input: {self:?}"));
        }
        Ok(())
    }
}

/// Expand `rows` NHWC images (`x.len() == rows·h·w·c_in`) into im2col
/// patch rows: `out` row `r·oh·ow + oy·ow + ox` holds the `(ky, kx, c)`
/// window anchored at `stride·(oy, ox) − pad`, zero where the window
/// hangs off the image. The element order matches the checkpoint's
/// `[kh, kw, c_in, c_out]` kernel flattened to `[kh·kw·c_in, c_out]`.
pub fn im2col(x: &[f32], rows: usize, g: &ConvGeom, out: &mut [f32]) {
    let (oh, ow) = g.out_hw();
    let k = g.patch_len();
    assert_eq!(x.len(), rows * g.h * g.w * g.c_in, "im2col: bad input length");
    assert_eq!(out.len(), rows * oh * ow * k, "im2col: bad output length");
    out.fill(0.0);
    let c = g.c_in;
    for r in 0..rows {
        let img = &x[r * g.h * g.w * c..(r + 1) * g.h * g.w * c];
        for oy in 0..oh {
            for ox in 0..ow {
                let row0 = ((r * oh + oy) * ow + ox) * k;
                for ky in 0..g.kh {
                    let iy = (oy * g.stride + ky) as isize - g.pad as isize;
                    if iy < 0 || iy >= g.h as isize {
                        continue;
                    }
                    for kx in 0..g.kw {
                        let ix = (ox * g.stride + kx) as isize - g.pad as isize;
                        if ix < 0 || ix >= g.w as isize {
                            continue;
                        }
                        let src = (iy as usize * g.w + ix as usize) * c;
                        let dst = row0 + (ky * g.kw + kx) * c;
                        out[dst..dst + c].copy_from_slice(&img[src..src + c]);
                    }
                }
            }
        }
    }
}

/// 2×2 average pool with stride 2 over NHWC input; spatial dims must be
/// even. Each output is `0.25·(a + b + c + d)` — a power-of-two factor,
/// so pooling is exact whenever the four inputs sum exactly.
/// Allocating convenience over [`avgpool2x2_into`] (the training
/// backward and tests; serving pools into an arena buffer).
pub fn avgpool2x2(x: &[f32], rows: usize, h: usize, w: usize, c: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; rows * (h / 2) * (w / 2) * c];
    avgpool2x2_into(x, rows, h, w, c, &mut out);
    out
}

/// [`avgpool2x2`] into a caller-owned buffer of exactly
/// `rows·(h/2)·(w/2)·c` elements.
pub fn avgpool2x2_into(x: &[f32], rows: usize, h: usize, w: usize, c: usize, out: &mut [f32]) {
    assert!(h % 2 == 0 && w % 2 == 0, "avgpool2x2 wants even spatial dims, got {h}x{w}");
    assert_eq!(x.len(), rows * h * w * c, "avgpool2x2: bad input length");
    let (ph, pw) = (h / 2, w / 2);
    assert_eq!(out.len(), rows * ph * pw * c, "avgpool2x2: bad output length");
    for r in 0..rows {
        let img = &x[r * h * w * c..(r + 1) * h * w * c];
        for py in 0..ph {
            for px in 0..pw {
                let o0 = ((r * ph + py) * pw + px) * c;
                let i00 = ((2 * py) * w + 2 * px) * c;
                let i01 = i00 + c;
                let i10 = i00 + w * c;
                let i11 = i10 + c;
                for ch in 0..c {
                    out[o0 + ch] = 0.25
                        * (img[i00 + ch] + img[i01 + ch] + img[i10 + ch] + img[i11 + ch]);
                }
            }
        }
    }
}

/// Fold inference batch-norm into a per-channel affine epilogue:
/// `γ·(z − μ)/√(σ² + ε) + β  =  z·gain + bias` with
/// `gain = γ/√(σ² + ε)` and `bias = β − μ·gain`. Both the packed-model
/// loader and the native trainer's eval path go through this one
/// function, so the fold is bitwise-identical on both sides.
pub fn fold_bn(gamma: &[f32], beta: &[f32], mean: &[f32], var: &[f32]) -> (Vec<f32>, Vec<f32>) {
    assert!(
        gamma.len() == beta.len() && gamma.len() == mean.len() && gamma.len() == var.len(),
        "fold_bn: mismatched channel counts"
    );
    let mut gain = vec![0.0f32; gamma.len()];
    let mut bias = vec![0.0f32; gamma.len()];
    for o in 0..gamma.len() {
        gain[o] = gamma[o] / (var[o] + BN_EPS).sqrt();
        bias[o] = beta[o] - mean[o] * gain[o];
    }
    (gain, bias)
}

/// One conv→BN(folded)→ReLU→(pool) block: a [`QuantGemm`] plan over the
/// flattened kernel, driven across im2col patch rows with per-patch
/// activation quantization at `k_a`.
pub struct QuantConvLayer {
    pub name: String,
    pub geom: ConvGeom,
    pub gemm: QuantGemm,
    /// Folded-BN per-channel multiplier (γ/√(σ² + ε)).
    pub gain: Vec<f32>,
    /// Folded-BN per-channel shift (β − μ·gain).
    pub bias: Vec<f32>,
    pub k_a: u32,
    /// Whether a ReLU follows the folded BN. False for the second conv
    /// and the projection shortcut of a residual block — there the
    /// nonlinearity belongs to the join ([`QuantResBlock`]).
    pub relu: bool,
    /// Whether a 2×2 average pool follows the ReLU.
    pub pool: bool,
}

impl QuantConvLayer {
    /// Forward `rows` NHWC images through conv→BN→ReLU(→pool). Output is
    /// NHWC `[rows, oh(/2), ow(/2), c_out]`. Allocating convenience
    /// over [`forward_scratch`] (tests and one-off callers).
    ///
    /// [`forward_scratch`]: QuantConvLayer::forward_scratch
    pub fn forward(&self, x: &[f32], rows: usize) -> Vec<f32> {
        let mut out = Vec::new();
        self.forward_scratch(x, rows, &mut Scratch::default(), &mut out);
        out
    }

    /// [`forward`](QuantConvLayer::forward) with every transient buffer
    /// — im2col patches, quantized patch rows, activation bit planes,
    /// the pre-pool feature map — drawn from (and recycled through) the
    /// arena, so repeat requests allocate nothing: the arena-reuse test
    /// pins the pool's grow counter flat across requests. `out` is
    /// resized in place and counts against the same arena budget.
    pub fn forward_scratch(&self, x: &[f32], rows: usize, s: &mut Scratch, out: &mut Vec<f32>) {
        let g = &self.geom;
        let (oh, ow) = g.out_hw();
        let k = g.patch_len();
        let prows = rows * oh * ow;
        let mut patches = std::mem::take(&mut s.patches);
        grab(&mut patches, prows * k, &s.grow_events);
        im2col(x, rows, g, &mut patches);
        let mut pre = std::mem::take(&mut s.conv_out);
        grab(&mut pre, prows * g.c_out, &s.grow_events);
        if self.gemm.is_integer() {
            let mut qa = std::mem::take(&mut s.qa);
            let mut steps = std::mem::take(&mut s.steps);
            grab(&mut qa, prows * k, &s.grow_events);
            grab(&mut steps, prows, &s.grow_events);
            for p in 0..prows {
                steps[p] = activ::quantize_row_centered(
                    &patches[p * k..(p + 1) * k],
                    self.k_a,
                    &mut qa[p * k..(p + 1) * k],
                );
            }
            self.gemm.forward_quant_scaled_arena(
                &qa,
                &steps,
                prows,
                &self.gain,
                &self.bias,
                &mut pre,
                s,
            );
            s.qa = qa;
            s.steps = steps;
        } else {
            if self.k_a < 24 {
                for p in 0..prows {
                    activ::fake_quantize_row(&mut patches[p * k..(p + 1) * k], self.k_a);
                }
            }
            self.gemm
                .forward_f32_scaled(&patches, prows, &self.gain, &self.bias, &mut pre);
        }
        s.patches = patches;
        if self.relu {
            for v in pre.iter_mut() {
                if *v < 0.0 {
                    *v = 0.0;
                }
            }
        }
        if self.pool {
            grab(out, rows * (oh / 2) * (ow / 2) * g.c_out, &s.grow_events);
            avgpool2x2_into(&pre, rows, oh, ow, g.c_out, out);
            s.conv_out = pre;
        } else {
            // the computed map becomes the output; the caller's old
            // buffer cycles back into the arena for the next block
            std::mem::swap(out, &mut pre);
            s.conv_out = pre;
        }
    }
}

/// Global average pool over NHWC input: one mean per (row, channel),
/// accumulated in f64 over the spatial positions in order and rounded
/// to f32 once. The resnet head reduction (DESIGN.md §18) — shared by
/// serving and the native trainer's eval path so the two sides agree
/// bitwise.
pub fn global_avgpool(x: &[f32], rows: usize, h: usize, w: usize, c: usize, out: &mut [f32]) {
    assert_eq!(x.len(), rows * h * w * c, "global_avgpool: bad input length");
    assert_eq!(out.len(), rows * c, "global_avgpool: bad output length");
    let inv = 1.0f64 / (h * w) as f64;
    for r in 0..rows {
        let img = &x[r * h * w * c..(r + 1) * h * w * c];
        for ch in 0..c {
            let mut acc = 0.0f64;
            for p in 0..h * w {
                acc += img[p * c + ch] as f64;
            }
            out[r * c + ch] = (acc * inv) as f32;
        }
    }
}

/// One residual block (DESIGN.md §18): a two-conv trunk
/// (conv→BN→ReLU→conv→BN) joined with an identity or 1×1-projection
/// shortcut, ReLU after the join. Each branch finishes its own exact
/// integer accumulation and per-channel f64 epilogue (BN folded per
/// branch) and rounds to f32 once; the join then adds the two rounded
/// maps elementwise — f32 addition of already-determined values, no
/// rounding freedom left — and the next consumer's per-patch-row
/// activation quantization puts the joined map back on its own
/// `2^k − 1` grid. No requantization step lives in the join itself.
pub struct QuantResBlock {
    pub name: String,
    /// Trunk conv 1: 3×3 at the block stride, ReLU.
    pub c1: QuantConvLayer,
    /// Trunk conv 2: 3×3 stride 1, no ReLU (the join supplies it).
    pub c2: QuantConvLayer,
    /// 1×1 projection at the block stride when the shape changes;
    /// `None` = identity shortcut.
    pub sc: Option<QuantConvLayer>,
    /// Per-unit registry handles (see [`LayerObs`]).
    obs_c1: LayerObs,
    obs_c2: LayerObs,
    obs_sc: Option<LayerObs>,
}

impl QuantResBlock {
    /// Wire up a block from already-loaded units, registering each unit
    /// with the observability layer under its checkpoint name.
    pub fn new(
        name: &str,
        c1: QuantConvLayer,
        c2: QuantConvLayer,
        sc: Option<QuantConvLayer>,
    ) -> QuantResBlock {
        let reg = |l: &QuantConvLayer| {
            LayerObs::register(&l.name, l.gemm.plan_label(), l.gemm.bits, l.k_a)
        };
        QuantResBlock {
            name: name.to_string(),
            obs_c1: reg(&c1),
            obs_c2: reg(&c2),
            obs_sc: sc.as_ref().map(&reg),
            c1,
            c2,
            sc,
        }
    }

    /// Forward `rows` NHWC maps through trunk + shortcut + join.
    /// Allocating convenience over [`forward_scratch`] (tests and
    /// one-off callers).
    ///
    /// [`forward_scratch`]: QuantResBlock::forward_scratch
    pub fn forward(&self, x: &[f32], rows: usize) -> Vec<f32> {
        let mut out = Vec::new();
        self.forward_scratch(x, rows, &mut Scratch::default(), &mut out, false);
        out
    }

    /// [`forward`](QuantResBlock::forward) out of the arena: the
    /// trunk's mid-map stages through `Scratch::res_mid` and the
    /// projection branch through `Scratch::res_sc` — slots separate
    /// from the unit forwards' `conv_out`, which cycles underneath
    /// both. `obs_on` gates per-unit telemetry (the caller reads the
    /// global switch once per batch).
    pub fn forward_scratch(
        &self,
        x: &[f32],
        rows: usize,
        s: &mut Scratch,
        out: &mut Vec<f32>,
        obs_on: bool,
    ) {
        let mut mid = std::mem::take(&mut s.res_mid);
        let t0 = if obs_on { Some(Instant::now()) } else { None };
        self.c1.forward_scratch(x, rows, s, &mut mid);
        if let Some(t) = t0 {
            self.obs_c1.record(rows, t);
        }
        let t0 = if obs_on { Some(Instant::now()) } else { None };
        self.c2.forward_scratch(&mid, rows, s, out);
        if let Some(t) = t0 {
            self.obs_c2.record(rows, t);
        }
        s.res_mid = mid;
        if let Some(sc) = &self.sc {
            let mut short = std::mem::take(&mut s.res_sc);
            let t0 = if obs_on { Some(Instant::now()) } else { None };
            sc.forward_scratch(x, rows, s, &mut short);
            if let Some(t) = t0 {
                self.obs_sc.as_ref().expect("projection obs handle").record(rows, t);
            }
            debug_assert_eq!(out.len(), short.len());
            for (o, v) in out.iter_mut().zip(short.iter()) {
                let u = *o + *v;
                *o = if u < 0.0 { 0.0 } else { u };
            }
            s.res_sc = short;
        } else {
            // identity shortcut: the loader guarantees stride 1 and
            // matching channels, so input and trunk output line up
            debug_assert_eq!(out.len(), x.len());
            for (o, v) in out.iter_mut().zip(x.iter()) {
                let u = *o + *v;
                *o = if u < 0.0 { 0.0 } else { u };
            }
        }
    }
}

/// A conv stack plus fc head loaded from one packed checkpoint — the
/// conv sibling of [`QuantMlp`]. Two architecture contracts, selected
/// by the meta (see [`QuantConvNet::from_packed`]): the smallcnn shape
/// (`conv_layers`: conv→BN→ReLU→pool per entry, pooled features
/// flattened NHWC into the `mlp_layers` head) and the resnet20-class
/// shape (`res_blocks`: a stem unit, residual blocks with integer skip
/// joins, then [`global_avgpool`] into the head).
pub struct QuantConvNet {
    /// The plain prefix: every smallcnn block, or the resnet stem.
    pub conv: Vec<QuantConvLayer>,
    /// Residual blocks after the prefix (empty for smallcnn).
    pub res: Vec<QuantResBlock>,
    pub head: QuantMlp,
    /// Input image shape (h, w, c).
    pub h: usize,
    pub w: usize,
    pub c: usize,
    pub classes: usize,
    /// Feature-map shape (h, w, c) entering the head reduction.
    feat: (usize, usize, usize),
    /// Features reduce by [`global_avgpool`] (resnet) instead of
    /// flattening (smallcnn).
    gap: bool,
    /// Registry handles parallel to `conv` (see [`LayerObs`]); the
    /// blocks in `res` and the fc head carry their own.
    obs: Vec<LayerObs>,
}

impl QuantConvNet {
    /// Build from a packed checkpoint. Two topologies share one loader
    /// (the meta says which; both also need `input_hw`/`in_channels`):
    ///
    /// * `conv_layers` (names) — the smallcnn shape: each entry is a
    ///   square odd-kernel stride-1 "same"-pad conv with folded BN,
    ///   ReLU and a 2×2 average pool; pooled features flatten into the
    ///   `mlp_layers` head.
    /// * `res_blocks` (DESIGN.md §18) — the resnet20-class shape: a
    ///   stem unit (meta `res_stem`, default `"stem"`), then one object
    ///   per block `{name, stride, proj}` loading `name.c1`/`name.c2`
    ///   (plus `name.sc` when `proj`); features reduce by
    ///   [`global_avgpool`] instead of flattening.
    ///
    /// Every unit carries tensors `L.w` (`[kh, kw, c_in, c_out]`) and
    /// raw BN statistics `L.bn.g`, `L.bn.b`, `L.bn.mean`, `L.bn.var`
    /// (`[c_out]` each). Activation widths resolve like the MLP: meta
    /// `k_a` globally, `layer_k_a` per-unit overrides; k_w is
    /// per-tensor (each packed width).
    pub fn from_packed(q: &QuantizedCheckpoint) -> anyhow::Result<QuantConvNet> {
        let conv_names = q.meta_layer_names("conv_layers")?;
        let res_meta = q.meta.get("res_blocks").and_then(Json::as_arr);
        anyhow::ensure!(
            conv_names.is_some() || res_meta.is_some(),
            "packed meta lacks conv_layers/res_blocks — not a conv checkpoint"
        );
        anyhow::ensure!(
            conv_names.is_none() || res_meta.is_none(),
            "conv_layers and res_blocks are mutually exclusive"
        );
        let hw = q
            .meta
            .get("input_hw")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("conv checkpoint meta lacks input_hw"))?;
        anyhow::ensure!(hw.len() == 2, "input_hw must have 2 entries");
        let h0 = hw[0].as_usize().ok_or_else(|| anyhow::anyhow!("bad input_hw"))?;
        let w0 = hw[1].as_usize().ok_or_else(|| anyhow::anyhow!("bad input_hw"))?;
        let c0 = q
            .meta
            .get("in_channels")
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow::anyhow!("conv checkpoint meta lacks in_channels"))?;
        let global_k_a = q.meta.get("k_a").and_then(Json::as_f64).unwrap_or(32.0) as u32;
        let per_layer = q.meta.get("layer_k_a");

        let raw_vec = |name: String, len: usize| -> anyhow::Result<Vec<f32>> {
            let t = q
                .get(&name)
                .ok_or_else(|| anyhow::anyhow!("packed checkpoint lacks {name}"))?;
            anyhow::ensure!(
                t.shape == vec![len],
                "{name}: shape {:?} != [{len}]",
                t.shape
            );
            Ok(t.dequantize().data)
        };

        // load one conv→foldedBN unit named `name` at an explicit
        // geometry — shared verbatim by the smallcnn loop, the resnet
        // stem, and every residual-block branch
        let load_unit = |name: &str,
                         h: usize,
                         w: usize,
                         c_in: usize,
                         stride: usize,
                         relu: bool,
                         pool: bool|
         -> anyhow::Result<QuantConvLayer> {
            let wt = q
                .get(&format!("{name}.w"))
                .ok_or_else(|| anyhow::anyhow!("packed checkpoint lacks {name}.w"))?;
            anyhow::ensure!(
                wt.shape.len() == 4,
                "{name}.w: conv kernels are [kh, kw, c_in, c_out], got {:?}",
                wt.shape
            );
            let (kh, kw, ci, co) = (wt.shape[0], wt.shape[1], wt.shape[2], wt.shape[3]);
            anyhow::ensure!(
                kh == kw && kh % 2 == 1,
                "{name}.w: kernel must be square with odd size, got {kh}x{kw}"
            );
            anyhow::ensure!(
                ci == c_in,
                "{name}.w expects {ci} input channels but the chain carries {c_in}"
            );
            let geom = ConvGeom { h, w, c_in, c_out: co, kh, kw, stride, pad: (kh - 1) / 2 };
            geom.validate().map_err(|e| anyhow::anyhow!("{name}: {e}"))?;
            let k_a = per_layer
                .and_then(|m| m.get(name))
                .and_then(Json::as_f64)
                .map(|v| v as u32)
                .unwrap_or(global_k_a);
            anyhow::ensure!(k_a >= 1, "{name}: k_a must be >= 1");
            // the 4-d kernel flattens row-major to the [kh·kw·c_in, c_out]
            // matrix the GEMM plans consume — reshape is payload-free
            let mut w2 = wt.clone();
            w2.shape = vec![geom.patch_len(), co];
            let gemm = QuantGemm::from_packed(&w2, k_a)
                .map_err(|e| anyhow::anyhow!("{name}.w: {e}"))?;
            let gamma = raw_vec(format!("{name}.bn.g"), co)?;
            let beta = raw_vec(format!("{name}.bn.b"), co)?;
            let mean = raw_vec(format!("{name}.bn.mean"), co)?;
            let var = raw_vec(format!("{name}.bn.var"), co)?;
            let (gain, bias) = fold_bn(&gamma, &beta, &mean, &var);
            Ok(QuantConvLayer { name: name.to_string(), geom, gemm, gain, bias, k_a, relu, pool })
        };

        let (mut h, mut w, mut c) = (h0, w0, c0);
        let mut conv = Vec::new();
        let mut res = Vec::new();
        if let Some(names) = &conv_names {
            for name in names {
                let layer = load_unit(name, h, w, c, 1, true, true)?;
                let (oh, ow) = layer.geom.out_hw();
                anyhow::ensure!(
                    oh % 2 == 0 && ow % 2 == 0,
                    "{name}: {oh}x{ow} feature map cannot 2x2-pool"
                );
                h = oh / 2;
                w = ow / 2;
                c = layer.geom.c_out;
                conv.push(layer);
            }
        } else if let Some(entries) = res_meta {
            let stem = q.meta.get("res_stem").and_then(Json::as_str).unwrap_or("stem");
            let layer = load_unit(stem, h, w, c, 1, true, false)?;
            let (oh, ow) = layer.geom.out_hw();
            h = oh;
            w = ow;
            c = layer.geom.c_out;
            conv.push(layer);
            for e in entries {
                let name = e
                    .get("name")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow::anyhow!("res_blocks entry lacks a name"))?;
                let stride = e
                    .get("stride")
                    .and_then(Json::as_usize)
                    .ok_or_else(|| anyhow::anyhow!("{name}: res_blocks entry lacks stride"))?;
                let proj = e.get("proj").and_then(Json::as_bool).unwrap_or(false);
                anyhow::ensure!(
                    stride == 1 || stride == 2,
                    "{name}: residual stride must be 1 or 2, got {stride}"
                );
                let c1 = load_unit(&format!("{name}.c1"), h, w, c, stride, true, false)?;
                let (mh, mw) = c1.geom.out_hw();
                let c2 = load_unit(&format!("{name}.c2"), mh, mw, c1.geom.c_out, 1, false, false)?;
                let (oh, ow) = c2.geom.out_hw();
                let co = c2.geom.c_out;
                let sc = if proj {
                    let p = load_unit(&format!("{name}.sc"), h, w, c, stride, false, false)?;
                    anyhow::ensure!(
                        p.geom.kh == 1,
                        "{name}.sc: projection shortcuts are 1x1, got {}x{}",
                        p.geom.kh,
                        p.geom.kw
                    );
                    anyhow::ensure!(
                        p.geom.c_out == co && p.geom.out_hw() == (oh, ow),
                        "{name}.sc: shortcut must match the trunk output shape"
                    );
                    Some(p)
                } else {
                    anyhow::ensure!(
                        stride == 1 && co == c,
                        "{name}: identity shortcut needs stride 1 and {c} == {co} channels \
                         (set proj for a 1x1 projection)"
                    );
                    None
                };
                res.push(QuantResBlock::new(name, c1, c2, sc));
                h = oh;
                w = ow;
                c = co;
            }
        }
        let gap = res_meta.is_some();
        let head = QuantMlp::from_packed(q)?;
        let flat = if gap { c } else { h * w * c };
        anyhow::ensure!(
            head.input == flat,
            "fc head expects {} inputs but the feature stage produces {flat}",
            head.input
        );
        let classes = head.classes;
        let obs = conv
            .iter()
            .map(|l| LayerObs::register(&l.name, l.gemm.plan_label(), l.gemm.bits, l.k_a))
            .collect();
        Ok(QuantConvNet {
            conv,
            res,
            head,
            h: h0,
            w: w0,
            c: c0,
            classes,
            feat: (h, w, c),
            gap,
            obs,
        })
    }

    /// Per-sample input feature count (`h·w·c`).
    pub fn input_numel(&self) -> usize {
        self.h * self.w * self.c
    }

    /// The feature stage only: `rows` NHWC images through the plain
    /// prefix, then every residual block, then the head reduction
    /// (flatten or [`global_avgpool`]) into `out` (`rows·head.input`
    /// elements), every intermediate drawn from the arena.
    fn features_scratch(&self, x: &[f32], rows: usize, s: &mut Scratch, out: &mut [f32]) {
        debug_assert_eq!(out.len(), rows * self.head.input);
        let mut cur = std::mem::take(&mut s.buf_a);
        grab(&mut cur, x.len(), &s.grow_events);
        cur.copy_from_slice(x);
        let mut nxt = std::mem::take(&mut s.buf_b);
        // per-layer telemetry: this runs once per pool lane over that
        // lane's sample chunk, so the rows counters sum to the batch
        // total across lanes while the histogram sees per-lane spans
        let obs_on = obs::global().enabled();
        for (li, layer) in self.conv.iter().enumerate() {
            let t_layer = if obs_on { Some(Instant::now()) } else { None };
            layer.forward_scratch(&cur, rows, s, &mut nxt);
            if let Some(t0) = t_layer {
                self.obs[li].record(rows, t0);
            }
            std::mem::swap(&mut cur, &mut nxt);
        }
        for blk in &self.res {
            blk.forward_scratch(&cur, rows, s, &mut nxt, obs_on);
            std::mem::swap(&mut cur, &mut nxt);
        }
        if self.gap {
            let (fh, fw, fc) = self.feat;
            global_avgpool(&cur, rows, fh, fw, fc, out);
        } else {
            out.copy_from_slice(&cur[..out.len()]);
        }
        // undo ping-pong parity (see QuantMlp::forward_pooled): each
        // buffer returns to the arena slot it came from so capacities
        // stay stable across requests
        if (self.conv.len() + self.res.len()) % 2 == 1 {
            std::mem::swap(&mut cur, &mut nxt);
        }
        s.buf_a = cur;
        s.buf_b = nxt;
    }

    /// Logits for `rows` stacked NHWC images on a transient pool of
    /// `threads` lanes (≤ 1 inline; 0 clamps to 1 like the old inline
    /// path — per-core auto-sizing belongs to the persistent pool) —
    /// the convenience form; serving holds a persistent [`WorkerPool`]
    /// and calls [`forward_pooled`].
    ///
    /// [`forward_pooled`]: QuantConvNet::forward_pooled
    pub fn forward(&self, x: &[f32], rows: usize, threads: usize) -> Vec<f32> {
        self.forward_pooled(x, rows, &WorkerPool::new(threads.max(1)))
    }

    /// Logits for `rows` stacked NHWC images: the batch splits into
    /// contiguous sample chunks, one per pool lane, each lane running
    /// the whole conv stack out of its own arena; the fc head then runs
    /// [`QuantMlp::forward_pooled`] over the gathered features.
    /// Per-patch activation scales make every sample independent of its
    /// neighbours, so lane count and batch composition never change a
    /// result.
    pub fn forward_pooled(&self, x: &[f32], rows: usize, pool: &WorkerPool) -> Vec<f32> {
        let sz = self.input_numel();
        assert_eq!(x.len(), rows * sz, "bad input length");
        let flat = self.head.input;
        let (mut feats, grew) = {
            let mut st = pool.stage_scratch();
            (std::mem::take(&mut st.patches), st.grow_events.clone())
        };
        grab(&mut feats, rows * flat, &grew);
        let parts = pool.threads().min(rows.max(1));
        {
            let split = SplitMut::new(&mut feats);
            pool.run_active(parts, |wid, ws| {
                let (r0, r1) = chunk_range(rows, parts, wid);
                if r0 >= r1 {
                    return;
                }
                // SAFETY: chunk_range partitions — ranges disjoint.
                let out = unsafe { split.range(r0 * flat, (r1 - r0) * flat) };
                self.features_scratch(&x[r0 * sz..r1 * sz], r1 - r0, ws, out);
            });
        }
        let logits = self.head.forward_pooled(&feats, rows, pool);
        pool.stage_scratch().patches = feats;
        logits
    }

    /// Argmax class per row (lowest index on ties — the shared rule).
    pub fn classify(&self, x: &[f32], rows: usize, threads: usize) -> Vec<usize> {
        self.classify_pooled(x, rows, &WorkerPool::new(threads.max(1)))
    }

    /// [`classify`](QuantConvNet::classify) on a persistent pool.
    pub fn classify_pooled(&self, x: &[f32], rows: usize, pool: &WorkerPool) -> Vec<usize> {
        let logits = self.forward_pooled(x, rows, pool);
        (0..rows)
            .map(|r| super::argmax(&logits[r * self.classes..(r + 1) * self.classes]))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::pack;
    use crate::quant::code_levels;
    use crate::serve::packed::PackedTensor;
    use crate::tensor::Tensor;
    use crate::util::rng::Rng;

    fn random_tensor(shape: Vec<usize>, seed: u64) -> Tensor {
        let mut rng = Rng::new(seed);
        let n: usize = shape.iter().product();
        Tensor::new(shape, (0..n).map(|_| rng.normal() * 0.3).collect())
    }

    /// Gather one patch directly from the image (independent of im2col).
    fn naive_patch(x: &[f32], r: usize, g: &ConvGeom, oy: usize, ox: usize) -> Vec<f32> {
        let mut p = vec![0.0f32; g.patch_len()];
        let img = &x[r * g.h * g.w * g.c_in..(r + 1) * g.h * g.w * g.c_in];
        for ky in 0..g.kh {
            for kx in 0..g.kw {
                let iy = (oy * g.stride + ky) as isize - g.pad as isize;
                let ix = (ox * g.stride + kx) as isize - g.pad as isize;
                if iy < 0 || iy >= g.h as isize || ix < 0 || ix >= g.w as isize {
                    continue;
                }
                for ch in 0..g.c_in {
                    p[(ky * g.kw + kx) * g.c_in + ch] =
                        img[(iy as usize * g.w + ix as usize) * g.c_in + ch];
                }
            }
        }
        p
    }

    #[test]
    fn im2col_matches_naive_gather_across_geometries() {
        let mut rng = Rng::new(3);
        for (h, w) in [(5usize, 7usize), (4, 4), (7, 5)] {
            for k in [1usize, 3] {
                for stride in [1usize, 2] {
                    for pad in [0usize, 1] {
                        let g = ConvGeom { h, w, c_in: 3, c_out: 1, kh: k, kw: k, stride, pad };
                        if g.validate().is_err() {
                            continue;
                        }
                        let rows = 2usize;
                        let x: Vec<f32> =
                            (0..rows * h * w * 3).map(|_| rng.normal()).collect();
                        let (oh, ow) = g.out_hw();
                        let kl = g.patch_len();
                        let mut out = vec![f32::NAN; rows * oh * ow * kl];
                        im2col(&x, rows, &g, &mut out);
                        for r in 0..rows {
                            for oy in 0..oh {
                                for ox in 0..ow {
                                    let row = ((r * oh + oy) * ow + ox) * kl;
                                    assert_eq!(
                                        &out[row..row + kl],
                                        &naive_patch(&x, r, &g, oy, ox)[..],
                                        "h={h} w={w} k={k} s={stride} p={pad} r={r} ({oy},{ox})"
                                    );
                                }
                            }
                        }
                    }
                }
            }
        }
    }

    /// The integer conv layer must equal a from-scratch direct
    /// convolution — naive patch gather, scalar per-element weight
    /// unpack, i64 accumulation, same f64 epilogue — bitwise, for every
    /// width 2..=8 across odd spatial sizes and stride/padding edges.
    #[test]
    fn integer_conv_matches_direct_scalar_oracle_all_widths() {
        let mut rng = Rng::new(11);
        let (cin, cout) = (3usize, 5usize);
        for k in 2..=8u32 {
            for (stride, pad) in [(1usize, 1usize), (1, 0), (2, 1), (2, 0)] {
                let g = ConvGeom { h: 5, w: 7, c_in: cin, c_out: cout, kh: 3, kw: 3, stride, pad };
                g.validate().unwrap();
                let src = random_tensor(vec![3, 3, cin, cout], 40 + k as u64);
                let wt = PackedTensor::quantize(&src, k);
                let mut w2 = wt.clone();
                w2.shape = vec![g.patch_len(), cout];
                let gemm = QuantGemm::from_packed(&w2, k).unwrap();
                assert!(gemm.is_integer(), "k={k}");
                let gain: Vec<f32> = (0..cout).map(|_| 0.5 + rng.uniform()).collect();
                let bias: Vec<f32> = (0..cout).map(|_| rng.normal() * 0.1).collect();
                let layer = QuantConvLayer {
                    name: "t".to_string(),
                    geom: g,
                    gemm,
                    gain: gain.clone(),
                    bias: bias.clone(),
                    k_a: k,
                    relu: true,
                    pool: false,
                };
                let rows = 2usize;
                let x: Vec<f32> = (0..rows * g.h * g.w * cin).map(|_| rng.normal()).collect();
                let got = layer.forward(&x, rows);

                let (oh, ow) = g.out_hw();
                let s_i = code_levels(k) as i64;
                let sw = (if wt.scale > 0.0 { wt.scale / s_i as f32 } else { 0.0 }) as f64;
                let kl = g.patch_len();
                for r in 0..rows {
                    for oy in 0..oh {
                        for ox in 0..ow {
                            let patch = naive_patch(&x, r, &g, oy, ox);
                            let mut qa = vec![0i16; kl];
                            let step = activ::quantize_row_centered(&patch, k, &mut qa);
                            for o in 0..cout {
                                let mut acc = 0i64;
                                for i in 0..kl {
                                    let c = pack::read_bits_scalar(
                                        &wt.payload,
                                        (i * cout + o) * k as usize,
                                        k,
                                    ) as i64;
                                    acc += qa[i] as i64 * (2 * c - s_i);
                                }
                                assert!(acc.abs() <= i32::MAX as i64, "k={k}: bound violated");
                                let scale = step as f64 * sw * gain[o] as f64;
                                let pre = (acc as f64 * scale) as f32 + bias[o];
                                let want = if pre < 0.0 { 0.0 } else { pre };
                                let got_v = got[(((r * oh + oy) * ow + ox) * cout) + o];
                                assert_eq!(
                                    got_v.to_bits(),
                                    want.to_bits(),
                                    "k={k} s={stride} p={pad} r={r} ({oy},{ox}) o={o}: {got_v} vs {want}"
                                );
                            }
                        }
                    }
                }
            }
        }
    }

    /// The f32 fallback path (raw weights, identity k_a) must equal a
    /// direct f32 convolution that walks the kernel window over the
    /// original image — no im2col buffer, weights read in checkpoint
    /// layout — bitwise (padded positions contribute literal 0.0·w, the
    /// same operation the im2col zeros feed the GEMM).
    #[test]
    fn f32_conv_path_matches_direct_convolution_bitwise() {
        let mut rng = Rng::new(13);
        let (cin, cout) = (2usize, 4usize);
        for (stride, pad) in [(1usize, 1usize), (2, 0)] {
            let g = ConvGeom { h: 7, w: 5, c_in: cin, c_out: cout, kh: 3, kw: 3, stride, pad };
            g.validate().unwrap();
            let wsrc = random_tensor(vec![3, 3, cin, cout], 77);
            let wt = PackedTensor::raw(&wsrc);
            let mut w2 = wt.clone();
            w2.shape = vec![g.patch_len(), cout];
            let gemm = QuantGemm::from_packed(&w2, 32).unwrap();
            assert!(!gemm.is_integer());
            let gain: Vec<f32> = (0..cout).map(|_| 0.5 + rng.uniform()).collect();
            let bias: Vec<f32> = (0..cout).map(|_| rng.normal() * 0.1).collect();
            let layer = QuantConvLayer {
                name: "t".to_string(),
                geom: g,
                gemm,
                gain: gain.clone(),
                bias: bias.clone(),
                k_a: 32,
                relu: true,
                pool: false,
            };
            let rows = 2usize;
            let x: Vec<f32> = (0..rows * g.h * g.w * cin).map(|_| rng.normal()).collect();
            let got = layer.forward(&x, rows);

            let (oh, ow) = g.out_hw();
            for r in 0..rows {
                let img = &x[r * g.h * g.w * cin..(r + 1) * g.h * g.w * cin];
                for oy in 0..oh {
                    for ox in 0..ow {
                        for o in 0..cout {
                            let mut acc = 0.0f32;
                            for ky in 0..3 {
                                for kx in 0..3 {
                                    let iy = (oy * stride + ky) as isize - pad as isize;
                                    let ix = (ox * stride + kx) as isize - pad as isize;
                                    for ch in 0..cin {
                                        let xv = if iy < 0
                                            || iy >= g.h as isize
                                            || ix < 0
                                            || ix >= g.w as isize
                                        {
                                            0.0
                                        } else {
                                            img[(iy as usize * g.w + ix as usize) * cin + ch]
                                        };
                                        acc += xv
                                            * wsrc.data[((ky * 3 + kx) * cin + ch) * cout + o];
                                    }
                                }
                            }
                            let pre = (acc as f64 * gain[o] as f64) as f32 + bias[o];
                            let want = if pre < 0.0 { 0.0 } else { pre };
                            let got_v = got[(((r * oh + oy) * ow + ox) * cout) + o];
                            assert_eq!(
                                got_v.to_bits(),
                                want.to_bits(),
                                "s={stride} p={pad} r={r} ({oy},{ox}) o={o}"
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn avgpool_halves_and_averages() {
        // one channel, 4x4: pooled (0,0) = mean of the top-left 2x2
        let mut x = vec![0.0f32; 16];
        for (i, v) in x.iter_mut().enumerate() {
            *v = i as f32;
        }
        let p = avgpool2x2(&x, 1, 4, 4, 1);
        assert_eq!(p.len(), 4);
        assert_eq!(p[0], (0.0 + 1.0 + 4.0 + 5.0) * 0.25);
        assert_eq!(p[1], (2.0 + 3.0 + 6.0 + 7.0) * 0.25);
        assert_eq!(p[2], (8.0 + 9.0 + 12.0 + 13.0) * 0.25);
        assert_eq!(p[3], (10.0 + 11.0 + 14.0 + 15.0) * 0.25);
        // channels stay interleaved
        let two = avgpool2x2(&random_tensor(vec![1, 4, 4, 2], 5).data, 1, 4, 4, 2);
        assert_eq!(two.len(), 2 * 2 * 2);
    }

    #[test]
    fn fold_bn_matches_direct_normalization() {
        let mut rng = Rng::new(21);
        let n = 6usize;
        let gamma: Vec<f32> = (0..n).map(|_| 0.5 + rng.uniform()).collect();
        let beta: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        let mean: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        let var: Vec<f32> = (0..n).map(|_| rng.uniform() * 2.0).collect();
        let (gain, bias) = fold_bn(&gamma, &beta, &mean, &var);
        for o in 0..n {
            let z = rng.normal() * 3.0;
            let direct = gamma[o] * (z - mean[o]) / (var[o] + BN_EPS).sqrt() + beta[o];
            let folded = z * gain[o] + bias[o];
            assert!(
                (direct - folded).abs() <= 1e-4 * direct.abs().max(1.0),
                "o={o}: {direct} vs {folded}"
            );
        }
    }

    /// A full synthetic conv checkpoint: conv1 (3→4) + conv2 (4→6) over
    /// 8×8 inputs, fc head 6·2·2 → classes.
    fn conv_checkpoint(k_w: u32, k_a: f64, seed: u64) -> QuantizedCheckpoint {
        let classes = 3usize;
        let mut q = QuantizedCheckpoint::new(Json::obj(vec![
            ("k_a", Json::num(k_a)),
            (
                "conv_layers",
                Json::Arr(vec![Json::str("conv1"), Json::str("conv2")]),
            ),
            ("mlp_layers", Json::Arr(vec![Json::str("fc1")])),
            (
                "input_hw",
                Json::Arr(vec![Json::num(8.0), Json::num(8.0)]),
            ),
            ("in_channels", Json::num(3.0)),
            ("num_classes", Json::num(classes as f64)),
            ("serve_batch", Json::num(8.0)),
        ]));
        let quant = |t: &Tensor| -> PackedTensor {
            if (1..=24).contains(&k_w) {
                PackedTensor::quantize(t, k_w)
            } else {
                PackedTensor::raw(t)
            }
        };
        for (i, &(ci, co)) in [(3usize, 4usize), (4, 6)].iter().enumerate() {
            let name = format!("conv{}", i + 1);
            let s = seed + i as u64;
            q.push(
                format!("{name}.w"),
                quant(&random_tensor(vec![3, 3, ci, co], s)),
            );
            for (suffix, off) in [("g", 10u64), ("b", 20), ("mean", 30)] {
                q.push(
                    format!("{name}.bn.{suffix}"),
                    PackedTensor::raw(&random_tensor(vec![co], s + off)),
                );
            }
            q.push(
                format!("{name}.bn.var"),
                PackedTensor::raw(&Tensor::new(
                    vec![co],
                    (0..co).map(|j| 0.5 + 0.1 * j as f32).collect(),
                )),
            );
        }
        q.push("fc1.w", quant(&random_tensor(vec![6 * 2 * 2, classes], seed + 40)));
        q.push("fc1.b", PackedTensor::raw(&random_tensor(vec![classes], seed + 41)));
        q
    }

    #[test]
    fn conv_net_loads_and_batch_and_threads_are_invariant() {
        let q = conv_checkpoint(4, 8.0, 100);
        let net = QuantConvNet::from_packed(&q).unwrap();
        assert_eq!(net.conv.len(), 2);
        assert_eq!((net.h, net.w, net.c), (8, 8, 3));
        assert_eq!(net.classes, 3);
        assert!(net.conv.iter().all(|l| l.gemm.is_integer()));
        let mut rng = Rng::new(1);
        let rows = 6usize;
        let x: Vec<f32> = (0..rows * net.input_numel()).map(|_| rng.normal()).collect();
        let base = net.forward(&x, rows, 1);
        assert_eq!(base.len(), rows * net.classes);
        assert!(base.iter().all(|v| v.is_finite()));
        // thread invariance
        for threads in [2usize, 3, 8] {
            let got = net.forward(&x, rows, threads);
            for (a, b) in base.iter().zip(&got) {
                assert_eq!(a.to_bits(), b.to_bits(), "threads={threads}");
            }
        }
        // batch invariance: row 4 alone == row 4 in the batch
        let sz = net.input_numel();
        let solo = net.forward(&x[4 * sz..5 * sz], 1, 1);
        for (a, b) in base[4 * net.classes..5 * net.classes].iter().zip(&solo) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        let preds = net.classify(&x, rows, 2);
        assert!(preds.iter().all(|&p| p < net.classes));
    }

    #[test]
    fn conv_net_rejects_malformed_checkpoints() {
        // missing a BN tensor
        let mut q = conv_checkpoint(4, 8.0, 200);
        q.tensors.retain(|(n, _)| n != "conv2.bn.var");
        assert!(QuantConvNet::from_packed(&q).is_err());
        // fc head that does not match the conv output size
        let mut q2 = conv_checkpoint(4, 8.0, 201);
        q2.tensors.retain(|(n, _)| n != "fc1.w");
        q2.push("fc1.w", PackedTensor::quantize(&random_tensor(vec![99, 3], 9), 4));
        assert!(QuantConvNet::from_packed(&q2).is_err());
        // odd feature map cannot pool
        let mut q3 = conv_checkpoint(4, 8.0, 202);
        if let Json::Obj(m) = &mut q3.meta {
            m.insert(
                "input_hw".to_string(),
                Json::Arr(vec![Json::num(5.0), Json::num(5.0)]),
            );
        }
        assert!(QuantConvNet::from_packed(&q3).is_err());
        // wrong channel chain
        let mut q4 = conv_checkpoint(4, 8.0, 203);
        if let Json::Obj(m) = &mut q4.meta {
            m.insert("in_channels".to_string(), Json::num(5.0));
        }
        assert!(QuantConvNet::from_packed(&q4).is_err());
        // not a conv checkpoint at all
        let q5 = QuantizedCheckpoint::new(Json::obj(vec![("k_a", Json::num(8.0))]));
        assert!(QuantConvNet::from_packed(&q5).is_err());
    }

    #[test]
    fn conv_arena_stops_allocating_after_warmup() {
        // the satellite contract: im2col patches, quantized patch rows
        // and feature maps are recycled through the pool's arenas — the
        // first request populates them, every later request allocates
        // nothing (the debug grow counter freezes), and answers stay
        // bit-identical throughout.
        let q = conv_checkpoint(2, 2.0, 400);
        let net = QuantConvNet::from_packed(&q).unwrap();
        // W2·A2: the conv blocks ride the bitserial popcount planes
        assert!(net
            .conv
            .iter()
            .all(|l| l.gemm.plan_kind() == crate::kernels::PlanKind::Bitserial));
        let pool = WorkerPool::new(2);
        let mut rng = Rng::new(3);
        let rows = 6usize;
        let x: Vec<f32> = (0..rows * net.input_numel()).map(|_| rng.normal()).collect();
        let first = net.forward_pooled(&x, rows, &pool);
        let warm = pool.grow_events();
        assert!(warm > 0, "warm-up should have populated the arenas");
        for _ in 0..4 {
            let again = net.forward_pooled(&x, rows, &pool);
            for (a, b) in first.iter().zip(&again) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
        assert_eq!(pool.grow_events(), warm, "conv hot path allocated after warm-up");
        // and the pooled path agrees with the transient-inline one
        let inline = net.forward(&x, rows, 1);
        for (a, b) in first.iter().zip(&inline) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn raw_weights_fall_back_to_f32_plans() {
        let q = conv_checkpoint(32, 8.0, 300);
        let net = QuantConvNet::from_packed(&q).unwrap();
        assert!(net.conv.iter().all(|l| !l.gemm.is_integer()));
        let mut rng = Rng::new(2);
        let x: Vec<f32> = (0..2 * net.input_numel()).map(|_| rng.normal()).collect();
        let logits = net.forward(&x, 2, 1);
        assert!(logits.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn global_avgpool_means_per_channel_stay_interleaved() {
        // 1 row, 2x2 spatial, 2 channels interleaved NHWC
        let x = vec![1.0f32, 10.0, 2.0, 20.0, 3.0, 30.0, 4.0, 40.0];
        let mut out = vec![f32::NAN; 2];
        global_avgpool(&x, 1, 2, 2, 2, &mut out);
        assert_eq!(out[0], 2.5);
        assert_eq!(out[1], 25.0);
        // rows are independent
        let mut x2 = x.clone();
        x2.extend(x.iter().map(|v| v * 2.0));
        let mut out2 = vec![f32::NAN; 4];
        global_avgpool(&x2, 2, 2, 2, 2, &mut out2);
        assert_eq!(&out2[..2], &out[..]);
        assert_eq!(out2[2], 5.0);
        assert_eq!(out2[3], 50.0);
    }

    /// From-scratch scalar oracle for one integer conv unit: naive
    /// patch gather, per-element weight unpack, i64 accumulation, the
    /// same f64 epilogue — the reference both residual branches compose
    /// over.
    fn scalar_conv_unit(
        x: &[f32],
        rows: usize,
        g: &ConvGeom,
        wt: &PackedTensor,
        k: u32,
        gain: &[f32],
        bias: &[f32],
        relu: bool,
    ) -> Vec<f32> {
        let (oh, ow) = g.out_hw();
        let kl = g.patch_len();
        let cout = g.c_out;
        let s_i = code_levels(k) as i64;
        let sw = (if wt.scale > 0.0 { wt.scale / s_i as f32 } else { 0.0 }) as f64;
        let mut out = vec![0.0f32; rows * oh * ow * cout];
        for r in 0..rows {
            for oy in 0..oh {
                for ox in 0..ow {
                    let patch = naive_patch(x, r, g, oy, ox);
                    let mut qa = vec![0i16; kl];
                    let step = activ::quantize_row_centered(&patch, k, &mut qa);
                    for o in 0..cout {
                        let mut acc = 0i64;
                        for i in 0..kl {
                            let c = pack::read_bits_scalar(
                                &wt.payload,
                                (i * cout + o) * k as usize,
                                k,
                            ) as i64;
                            acc += qa[i] as i64 * (2 * c - s_i);
                        }
                        let scale = step as f64 * sw * gain[o] as f64;
                        let mut pre = (acc as f64 * scale) as f32 + bias[o];
                        if relu && pre < 0.0 {
                            pre = 0.0;
                        }
                        out[((r * oh + oy) * ow + ox) * cout + o] = pre;
                    }
                }
            }
        }
        out
    }

    /// Build one integer conv unit plus the raw pieces its oracle needs.
    fn make_unit(
        name: &str,
        g: ConvGeom,
        k: u32,
        seed: u64,
        relu: bool,
    ) -> (QuantConvLayer, PackedTensor, Vec<f32>, Vec<f32>) {
        let src = random_tensor(vec![g.kh, g.kw, g.c_in, g.c_out], seed);
        let wt = PackedTensor::quantize(&src, k);
        let mut w2 = wt.clone();
        w2.shape = vec![g.patch_len(), g.c_out];
        let gemm = QuantGemm::from_packed(&w2, k).unwrap();
        assert!(gemm.is_integer(), "{name} k={k}");
        let mut rng = Rng::new(seed ^ 0x9e37);
        let gain: Vec<f32> = (0..g.c_out).map(|_| 0.5 + rng.uniform()).collect();
        let bias: Vec<f32> = (0..g.c_out).map(|_| rng.normal() * 0.1).collect();
        let layer = QuantConvLayer {
            name: name.to_string(),
            geom: g,
            gemm,
            gain: gain.clone(),
            bias: bias.clone(),
            k_a: k,
            relu,
            pool: false,
        };
        (layer, wt, gain, bias)
    }

    /// The integer residual join must equal composing the per-unit
    /// scalar oracles with a plain f32 add + ReLU — bitwise, for every
    /// width 2..=8, across identity and projection shortcuts (stride 1
    /// and 2, odd channel counts included). Each branch's oracle
    /// recomputes its accumulator from scalar-unpacked codes, so this
    /// pins the whole branch-epilogue-join chain, not just the add.
    #[test]
    fn integer_residual_join_matches_scalar_oracle_all_widths() {
        // (c_in, c_mid, c_out, stride, proj, h, w)
        let cases = [
            (5usize, 3usize, 5usize, 1usize, false, 5usize, 4usize),
            (3, 4, 6, 2, true, 6, 6),
            (3, 5, 7, 1, true, 5, 5),
        ];
        for k in 2..=8u32 {
            for (ci, cm, co, stride, proj, h, w) in cases {
                let g1 = ConvGeom { h, w, c_in: ci, c_out: cm, kh: 3, kw: 3, stride, pad: 1 };
                let (mh, mw) = g1.out_hw();
                let g2 =
                    ConvGeom { h: mh, w: mw, c_in: cm, c_out: co, kh: 3, kw: 3, stride: 1, pad: 1 };
                let seed = 900 + k as u64 * 10 + stride as u64;
                let (l1, wt1, gain1, bias1) = make_unit("b.c1", g1, k, seed, true);
                let (l2, wt2, gain2, bias2) = make_unit("b.c2", g2, k, seed + 1, false);
                let (sc, sc_oracle) = if proj {
                    let gs =
                        ConvGeom { h, w, c_in: ci, c_out: co, kh: 1, kw: 1, stride, pad: 0 };
                    let (ls, wts, gains, biass) = make_unit("b.sc", gs, k, seed + 2, false);
                    (Some(ls), Some((gs, wts, gains, biass)))
                } else {
                    (None, None)
                };
                let blk = QuantResBlock::new("b", l1, l2, sc);
                let rows = 2usize;
                let mut rng = Rng::new(seed + 5);
                let x: Vec<f32> = (0..rows * h * w * ci).map(|_| rng.normal()).collect();
                let got = blk.forward(&x, rows);

                let mid = scalar_conv_unit(&x, rows, &g1, &wt1, k, &gain1, &bias1, true);
                let trunk = scalar_conv_unit(&mid, rows, &g2, &wt2, k, &gain2, &bias2, false);
                let shortcut = match &sc_oracle {
                    Some((gs, wts, gains, biass)) => {
                        scalar_conv_unit(&x, rows, gs, wts, k, gains, biass, false)
                    }
                    None => x.clone(),
                };
                assert_eq!(got.len(), trunk.len());
                assert_eq!(trunk.len(), shortcut.len());
                for (i, ((t, s), g)) in trunk.iter().zip(&shortcut).zip(&got).enumerate() {
                    let u = t + s;
                    let want = if u < 0.0 { 0.0 } else { u };
                    assert_eq!(
                        g.to_bits(),
                        want.to_bits(),
                        "k={k} ci={ci} cm={cm} co={co} stride={stride} proj={proj} i={i}"
                    );
                }
            }
        }
    }

    /// A full synthetic resnet checkpoint: stem (3→4) over 8×8 inputs,
    /// res1_1 identity (4→4), res2_1 projection at stride 2 (4→8),
    /// global average pool, fc head 8 → classes.
    fn res_checkpoint(k_w: u32, k_a: f64, seed: u64) -> QuantizedCheckpoint {
        let classes = 3usize;
        let mut q = QuantizedCheckpoint::new(Json::obj(vec![
            ("k_a", Json::num(k_a)),
            ("res_stem", Json::str("stem")),
            (
                "res_blocks",
                Json::Arr(vec![
                    Json::obj(vec![
                        ("name", Json::str("res1_1")),
                        ("stride", Json::num(1.0)),
                        ("proj", Json::Bool(false)),
                    ]),
                    Json::obj(vec![
                        ("name", Json::str("res2_1")),
                        ("stride", Json::num(2.0)),
                        ("proj", Json::Bool(true)),
                    ]),
                ]),
            ),
            ("mlp_layers", Json::Arr(vec![Json::str("fc1")])),
            (
                "input_hw",
                Json::Arr(vec![Json::num(8.0), Json::num(8.0)]),
            ),
            ("in_channels", Json::num(3.0)),
            ("num_classes", Json::num(classes as f64)),
            ("serve_batch", Json::num(8.0)),
        ]));
        let quant = |t: &Tensor| -> PackedTensor {
            if (1..=24).contains(&k_w) {
                PackedTensor::quantize(t, k_w)
            } else {
                PackedTensor::raw(t)
            }
        };
        let units = [
            ("stem", 3usize, 3usize, 4usize),
            ("res1_1.c1", 3, 4, 4),
            ("res1_1.c2", 3, 4, 4),
            ("res2_1.c1", 3, 4, 8),
            ("res2_1.c2", 3, 8, 8),
            ("res2_1.sc", 1, 4, 8),
        ];
        for (i, &(name, kh, ci, co)) in units.iter().enumerate() {
            let s = seed + i as u64;
            q.push(
                format!("{name}.w"),
                quant(&random_tensor(vec![kh, kh, ci, co], s)),
            );
            for (suffix, off) in [("g", 10u64), ("b", 20), ("mean", 30)] {
                q.push(
                    format!("{name}.bn.{suffix}"),
                    PackedTensor::raw(&random_tensor(vec![co], s + off)),
                );
            }
            q.push(
                format!("{name}.bn.var"),
                PackedTensor::raw(&Tensor::new(
                    vec![co],
                    (0..co).map(|j| 0.5 + 0.1 * j as f32).collect(),
                )),
            );
        }
        q.push("fc1.w", quant(&random_tensor(vec![8, classes], seed + 40)));
        q.push("fc1.b", PackedTensor::raw(&random_tensor(vec![classes], seed + 41)));
        q
    }

    #[test]
    fn res_net_loads_and_batch_and_threads_are_invariant() {
        let q = res_checkpoint(4, 8.0, 500);
        let net = QuantConvNet::from_packed(&q).unwrap();
        assert_eq!(net.conv.len(), 1, "stem only in the plain prefix");
        assert_eq!(net.res.len(), 2);
        assert!(net.res[0].sc.is_none());
        assert!(net.res[1].sc.is_some());
        assert_eq!(net.head.input, 8, "GAP feeds channels, not h*w*c");
        assert_eq!((net.h, net.w, net.c), (8, 8, 3));
        assert!(net.conv[0].gemm.is_integer());
        let mut rng = Rng::new(7);
        let rows = 6usize;
        let x: Vec<f32> = (0..rows * net.input_numel()).map(|_| rng.normal()).collect();
        let base = net.forward(&x, rows, 1);
        assert_eq!(base.len(), rows * net.classes);
        assert!(base.iter().all(|v| v.is_finite()));
        for threads in [2usize, 3, 8] {
            let got = net.forward(&x, rows, threads);
            for (a, b) in base.iter().zip(&got) {
                assert_eq!(a.to_bits(), b.to_bits(), "threads={threads}");
            }
        }
        let sz = net.input_numel();
        let solo = net.forward(&x[4 * sz..5 * sz], 1, 1);
        for (a, b) in base[4 * net.classes..5 * net.classes].iter().zip(&solo) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        let preds = net.classify(&x, rows, 2);
        assert!(preds.iter().all(|&p| p < net.classes));
    }

    #[test]
    fn res_net_rejects_malformed_checkpoints() {
        // projection declared but tensor missing
        let mut q = res_checkpoint(4, 8.0, 600);
        q.tensors.retain(|(n, _)| n != "res2_1.sc.w");
        assert!(QuantConvNet::from_packed(&q).is_err());
        // projection kernel must be 1x1
        let mut q2 = res_checkpoint(4, 8.0, 601);
        q2.tensors.retain(|(n, _)| n != "res2_1.sc.w");
        q2.push(
            "res2_1.sc.w",
            PackedTensor::quantize(&random_tensor(vec![3, 3, 4, 8], 9), 4),
        );
        assert!(QuantConvNet::from_packed(&q2).is_err());
        // identity shortcut cannot change shape: flip res2_1 to proj=false
        let mut q3 = res_checkpoint(4, 8.0, 602);
        if let Json::Obj(m) = &mut q3.meta {
            if let Some(Json::Arr(arr)) = m.get_mut("res_blocks") {
                if let Json::Obj(e) = &mut arr[1] {
                    e.insert("proj".to_string(), Json::Bool(false));
                }
            }
        }
        assert!(QuantConvNet::from_packed(&q3).is_err());
        // the two topology keys are mutually exclusive
        let mut q4 = res_checkpoint(4, 8.0, 603);
        if let Json::Obj(m) = &mut q4.meta {
            m.insert(
                "conv_layers".to_string(),
                Json::Arr(vec![Json::str("stem")]),
            );
        }
        assert!(QuantConvNet::from_packed(&q4).is_err());
        // head must match the channel count, not the flattened map
        let mut q5 = res_checkpoint(4, 8.0, 604);
        q5.tensors.retain(|(n, _)| n != "fc1.w");
        q5.push(
            "fc1.w",
            PackedTensor::quantize(&random_tensor(vec![8 * 4 * 4, 3], 11), 4),
        );
        assert!(QuantConvNet::from_packed(&q5).is_err());
    }

    #[test]
    fn res_arena_stops_allocating_after_warmup() {
        // residual staging buffers (res_mid/res_sc) join the recycling
        // contract: buffers permute between arena slots across a
        // request, so capacities can take a few requests to reach their
        // fixed point — warm generously, then pin the grow counter flat
        let q = res_checkpoint(2, 2.0, 700);
        let net = QuantConvNet::from_packed(&q).unwrap();
        let pool = WorkerPool::new(2);
        let mut rng = Rng::new(5);
        let rows = 6usize;
        let x: Vec<f32> = (0..rows * net.input_numel()).map(|_| rng.normal()).collect();
        let first = net.forward_pooled(&x, rows, &pool);
        for _ in 0..5 {
            net.forward_pooled(&x, rows, &pool);
        }
        let warm = pool.grow_events();
        assert!(warm > 0, "warm-up should have populated the arenas");
        for _ in 0..4 {
            let again = net.forward_pooled(&x, rows, &pool);
            for (a, b) in first.iter().zip(&again) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
        assert_eq!(pool.grow_events(), warm, "residual hot path allocated after warm-up");
        // and the pooled path agrees with the transient-inline one
        let inline = net.forward(&x, rows, 1);
        for (a, b) in first.iter().zip(&inline) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }
}

//! On-the-fly activation quantization at the checkpoint's learned k_a.
//!
//! Serving quantizes activations per *row* (one request's feature
//! vector) on the same symmetric s = 2^k − 1 grid the training
//! quantizer and the packed weight format use (`quant::code_levels`):
//! code c = round((x/scale·½ + ½)·s) with scale = max|x| over the row.
//! The kernels consume the *centered* integer q = 2c − s ∈ [−s, s]
//! (q has the parity of s, giving the grid's 2^k points), so a row
//! dequantizes as x ≈ q·Δ with a single per-row step Δ = scale/s and no
//! zero-point cross terms survive into the GEMM — the whole
//! dequantization collapses into one f32 epilogue multiply per output.
//!
//! Per-row (not per-batch) scales matter twice: accuracy (one hot
//! sample cannot crush everyone else's resolution) and exactness (a
//! row's codes are independent of its batch neighbours, so a 1-image
//! batch is bit-identical to the same image inside a 64-batch — the
//! property the serving e2e test pins down).

use crate::quant::code_levels;

/// Largest k_a the centered-i16 integer path accepts: |2c − s| ≤ s must
/// fit i16, so s = 2^k − 1 ≤ 32767 ⇒ k ≤ 15. Beyond that (and at the
/// k ≥ 24 "identity" widths) layers fall back to the f32 path.
pub const MAX_INT_ACT_BITS: u32 = 15;

/// Quantize one activation row to centered codes at `bits` ∈ 1..=15.
/// Returns the row's dequantization step Δ = max|x| / s; the row
/// reconstructs as x̂_i = q_i·Δ. An all-zero row returns Δ = 0 with
/// all-zero codes.
pub fn quantize_row_centered(x: &[f32], bits: u32, out: &mut [i16]) -> f32 {
    assert!(
        (1..=MAX_INT_ACT_BITS).contains(&bits),
        "integer activation path needs bits in 1..=15, got {bits}"
    );
    assert_eq!(x.len(), out.len());
    let s = code_levels(bits) as f32;
    let s_i = code_levels(bits) as i32;
    let scale = x.iter().fold(0.0f32, |m, v| m.max(v.abs()));
    if !(scale > 0.0) {
        out.fill(0);
        return 0.0;
    }
    let inv = 0.5 / scale;
    for (o, &v) in out.iter_mut().zip(x) {
        let unit = (v * inv + 0.5).clamp(0.0, 1.0);
        let c = (unit * s).round() as i32;
        *o = (2 * c - s_i) as i16;
    }
    scale / s
}

/// Undo the centering: the raw grid code c = (q + s)/2 of a centered
/// code q = 2c − s (exact — q always carries the parity of s, so the
/// shift never truncates). The bit-sliced kernels ([`super::bitserial`])
/// decompose these raw codes into planes; keeping the inverse next to
/// the quantizer pins the two conventions together.
#[inline]
pub fn raw_code(q: i16, s: i32) -> u32 {
    debug_assert!((q as i32).abs() <= s && ((q as i32) & 1) == (s & 1));
    ((q as i32 + s) >> 1) as u32
}

/// Fake-quantize a row in place (quantize + dequantize to the grid's
/// f32 points, x̂ = q·Δ). The f32 fallback layers use this so a model's
/// learned k_a is honoured even when the integer path is unavailable
/// (raw-f32 weights, k_a > 15, or an i32-overflow guard trip).
pub fn fake_quantize_row(x: &mut [f32], bits: u32) {
    let s = code_levels(bits) as f32;
    let s_i = code_levels(bits) as i32;
    let scale = x.iter().fold(0.0f32, |m, v| m.max(v.abs()));
    if !(scale > 0.0) {
        return;
    }
    let step = scale / s;
    let inv = 0.5 / scale;
    for v in x.iter_mut() {
        let unit = (*v * inv + 0.5).clamp(0.0, 1.0);
        let c = (unit * s).round() as i32;
        *v = (2 * c - s_i) as f32 * step;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn grid_points_requantize_to_themselves() {
        // a value already on the grid must come back with the same code
        for bits in [2u32, 3, 4, 8, 15] {
            let s = code_levels(bits) as i32;
            let step = 0.003f32;
            // q ranges over the grid: q = 2c − s for c = 0..=s
            let xs: Vec<f32> =
                (0..=s).map(|c| (2 * c - s) as f32 * step).collect();
            let mut q = vec![0i16; xs.len()];
            let got_step = quantize_row_centered(&xs, bits, &mut q);
            for (c, &qi) in q.iter().enumerate() {
                assert_eq!(qi as i32, 2 * c as i32 - s, "bits={bits} c={c}");
            }
            // max|x| = s·step, so the recovered step is scale/s = step
            assert!((got_step - step).abs() <= step * 1e-5);
        }
    }

    #[test]
    fn zero_row_is_zero() {
        let mut q = vec![7i16; 16];
        let step = quantize_row_centered(&[0.0; 16], 4, &mut q);
        assert_eq!(step, 0.0);
        assert!(q.iter().all(|&v| v == 0));
    }

    #[test]
    fn codes_are_bounded_and_reconstruction_is_within_half_step() {
        let mut rng = Rng::new(9);
        for bits in 2..=8u32 {
            let s = code_levels(bits) as i32;
            let xs: Vec<f32> = (0..256).map(|_| rng.normal()).collect();
            let mut q = vec![0i16; xs.len()];
            let step = quantize_row_centered(&xs, bits, &mut q);
            for (&x, &qi) in xs.iter().zip(&q) {
                assert!((qi as i32).abs() <= s, "bits={bits}");
                // centered codes share the parity of s
                assert_eq!((qi as i32 & 1), (s & 1), "bits={bits}");
                let err = (x - qi as f32 * step).abs();
                assert!(err <= step + 1e-6, "bits={bits}: {x} vs {}", qi as f32 * step);
            }
        }
    }

    #[test]
    fn raw_code_inverts_centering_on_the_whole_grid() {
        for bits in [1u32, 2, 4, 15] {
            let s = code_levels(bits) as i32;
            for c in 0..=s {
                let q = (2 * c - s) as i16;
                assert_eq!(raw_code(q, s), c as u32, "bits={bits} c={c}");
            }
        }
    }

    #[test]
    fn fake_quantize_matches_integer_reconstruction() {
        let mut rng = Rng::new(11);
        let xs: Vec<f32> = (0..128).map(|_| rng.normal() * 0.3).collect();
        let mut q = vec![0i16; xs.len()];
        let step = quantize_row_centered(&xs, 4, &mut q);
        let mut fq = xs.clone();
        fake_quantize_row(&mut fq, 4);
        for (&qi, &f) in q.iter().zip(&fq) {
            assert_eq!(qi as f32 * step, f);
        }
    }
}

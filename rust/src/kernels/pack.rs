//! Bit-stream packing: word-at-a-time (u64) fast paths plus the
//! per-element scalar reference they are verified against.
//!
//! Codes are written LSB-first at widths 1..=24, the same layout
//! `serve::packed` has always used on disk — the fast paths exist
//! because the serving hot path unpacks every weight tensor once at
//! load and the per-element `read_bits` loop (byte/shift bookkeeping
//! per code) dominated that step. The streaming versions keep a u64
//! accumulator and touch each payload byte exactly once.
//!
//! The scalar `write_bits_scalar`/`read_bits_scalar` pair stays `pub`
//! as the property-test oracle: both directions are cross-checked
//! against it on odd lengths at every width (see tests).

/// Write `bits` low bits of `code` at bit offset `off`, LSB-first.
/// Per-element reference implementation (the pre-kernels code path).
pub fn write_bits_scalar(buf: &mut [u8], off: usize, bits: u32, code: u32) {
    let mut v = code as u64;
    let mut off = off;
    let mut rem = bits as usize;
    while rem > 0 {
        let byte = off / 8;
        let shift = off % 8;
        let take = (8 - shift).min(rem);
        buf[byte] |= ((v & ((1u64 << take) - 1)) as u8) << shift;
        v >>= take;
        off += take;
        rem -= take;
    }
}

/// Read `bits` bits at bit offset `off`, LSB-first. Per-element
/// reference implementation (the pre-kernels code path).
pub fn read_bits_scalar(buf: &[u8], off: usize, bits: u32) -> u32 {
    let mut v = 0u64;
    let mut got = 0usize;
    let mut off = off;
    let mut rem = bits as usize;
    while rem > 0 {
        let byte = off / 8;
        let shift = off % 8;
        let take = (8 - shift).min(rem);
        let part = (buf[byte] as u64 >> shift) & ((1u64 << take) - 1);
        v |= part << got;
        got += take;
        off += take;
        rem -= take;
    }
    v as u32
}

/// Exact payload length for `n` codes at `bits` each.
pub fn packed_len(n: usize, bits: u32) -> usize {
    (n * bits as usize + 7) / 8
}

/// Pack `codes` at `bits` each into a fresh LSB-first byte stream.
/// Streams through a u64 accumulator: the accumulator never holds more
/// than 7 + 24 bits, so `filled + bits` cannot overflow 64.
pub fn pack_codes(codes: &[u32], bits: u32) -> Vec<u8> {
    assert!((1..=24).contains(&bits), "pack width must be in 1..=24, got {bits}");
    let mut out = Vec::with_capacity(packed_len(codes.len(), bits));
    let mask = (1u64 << bits) - 1;
    let mut acc = 0u64;
    let mut filled = 0u32;
    for &c in codes {
        acc |= ((c as u64) & mask) << filled;
        filled += bits;
        while filled >= 8 {
            out.push(acc as u8);
            acc >>= 8;
            filled -= 8;
        }
    }
    if filled > 0 {
        out.push(acc as u8);
    }
    out
}

/// Scatter `n` codes into `bits` LSB-first u64 bit planes (the
/// bit-sliced weight layout of [`super::bitserial`]): plane `j` holds
/// the 2^j digit of every code, element `i` at word `i/64`, bit `i%64`.
/// Codes are read from `codes[offset + i·stride]` so a `[d, n_out]`
/// weight matrix transposes into per-output planes without an
/// intermediate buffer. Bits past `n` in the tail word stay zero (a
/// zero bit contributes nothing to a popcount, which is exactly what
/// the centering identity needs). Returns Σc over the scattered codes —
/// the per-row code sum the bitserial dot folds back out.
pub fn codes_to_bitplanes(
    codes: &[u32],
    offset: usize,
    stride: usize,
    n: usize,
    bits: u32,
    planes: &mut [u64],
) -> u64 {
    assert!((1..=24).contains(&bits), "plane width must be in 1..=24, got {bits}");
    let words = (n + 63) / 64;
    assert_eq!(
        planes.len(),
        bits as usize * words,
        "plane buffer wants {} words for {n} codes at {bits} bits",
        bits as usize * words
    );
    planes.fill(0);
    let mut sum = 0u64;
    for i in 0..n {
        let c = codes[offset + i * stride];
        sum += c as u64;
        let word = i / 64;
        let bit = i % 64;
        for j in 0..bits as usize {
            planes[j * words + word] |= (((c >> j) & 1) as u64) << bit;
        }
    }
    sum
}

/// Unpack `n` codes at `bits` each from an LSB-first byte stream.
/// Mirror image of [`pack_codes`]; panics if the payload is shorter
/// than [`packed_len`]`(n, bits)` (callers validate sizes at load).
pub fn unpack_codes(payload: &[u8], bits: u32, n: usize) -> Vec<u32> {
    assert!((1..=24).contains(&bits), "unpack width must be in 1..=24, got {bits}");
    assert!(
        payload.len() >= packed_len(n, bits),
        "payload {} bytes, need {} for {n} codes at {bits} bits",
        payload.len(),
        packed_len(n, bits)
    );
    let mut out = Vec::with_capacity(n);
    let mask = (1u64 << bits) - 1;
    let mut acc = 0u64;
    let mut have = 0u32;
    let mut next = 0usize;
    for _ in 0..n {
        while have < bits {
            acc |= (payload[next] as u64) << have;
            next += 1;
            have += 8;
        }
        out.push((acc & mask) as u32);
        acc >>= bits;
        have -= bits;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_codes(n: usize, bits: u32, seed: u64) -> Vec<u32> {
        let mut rng = Rng::new(seed);
        let max = (1u64 << bits) - 1;
        (0..n).map(|_| (rng.next_u64() % (max + 1)) as u32).collect()
    }

    #[test]
    fn fast_pack_matches_scalar_on_odd_lengths_and_all_widths() {
        for bits in 1..=24u32 {
            // odd / prime / tiny lengths hit every partial-byte tail
            for n in [0usize, 1, 2, 3, 7, 13, 64, 101] {
                let codes = random_codes(n, bits, (bits as u64) << 8 | n as u64);
                let fast = pack_codes(&codes, bits);
                let mut scalar = vec![0u8; packed_len(n, bits)];
                for (i, &c) in codes.iter().enumerate() {
                    write_bits_scalar(&mut scalar, i * bits as usize, bits, c);
                }
                assert_eq!(fast, scalar, "bits={bits} n={n}");
            }
        }
    }

    #[test]
    fn fast_unpack_matches_scalar_and_roundtrips() {
        for bits in 1..=24u32 {
            for n in [1usize, 5, 17, 100] {
                let codes = random_codes(n, bits, 0xF00D ^ (bits as u64 * 31 + n as u64));
                let payload = pack_codes(&codes, bits);
                let fast = unpack_codes(&payload, bits, n);
                let scalar: Vec<u32> = (0..n)
                    .map(|i| read_bits_scalar(&payload, i * bits as usize, bits))
                    .collect();
                assert_eq!(fast, scalar, "bits={bits} n={n}");
                assert_eq!(fast, codes, "roundtrip bits={bits} n={n}");
            }
        }
    }

    #[test]
    fn packed_len_is_exact() {
        assert_eq!(packed_len(0, 3), 0);
        assert_eq!(packed_len(8, 1), 1);
        assert_eq!(packed_len(9, 1), 2);
        assert_eq!(packed_len(100, 3), 38); // 300 bits -> 37.5 -> 38
        assert_eq!(pack_codes(&[1; 100], 3).len(), 38);
    }

    #[test]
    #[should_panic(expected = "payload")]
    fn short_payload_panics_not_reads_garbage() {
        unpack_codes(&[0u8; 2], 8, 3);
    }

    #[test]
    fn bitplanes_match_per_bit_reads_and_sum_codes() {
        // odd n exercises the partial tail word; stride 3 exercises the
        // transposing read the weight planes use
        for bits in [1u32, 2, 3, 4, 7] {
            for n in [1usize, 63, 64, 65, 131] {
                let stride = 3usize;
                let codes = random_codes(n * stride, bits, 0xBEEF ^ (bits as u64 * 131 + n as u64));
                let words = (n + 63) / 64;
                let mut planes = vec![u64::MAX; bits as usize * words];
                let sum = codes_to_bitplanes(&codes, 1, stride, n, bits, &mut planes);
                let mut want_sum = 0u64;
                for i in 0..n {
                    let c = codes[1 + i * stride];
                    want_sum += c as u64;
                    for j in 0..bits as usize {
                        let got = (planes[j * words + i / 64] >> (i % 64)) & 1;
                        assert_eq!(got, ((c >> j) & 1) as u64, "bits={bits} n={n} i={i} j={j}");
                    }
                }
                assert_eq!(sum, want_sum, "bits={bits} n={n}");
                // tail bits past n must be zero in every plane
                for j in 0..bits as usize {
                    for i in n..words * 64 {
                        assert_eq!(
                            (planes[j * words + i / 64] >> (i % 64)) & 1,
                            0,
                            "bits={bits} n={n}: tail bit {i} set in plane {j}"
                        );
                    }
                }
            }
        }
    }
}

//! Pure-Rust quantized compute subsystem (DESIGN.md §11/§14).
//!
//! The cost model (`quant::CostModel`) charges compute proportional to
//! k_w·k_a — but until this module existed the serving path dequantized
//! every packed tensor back to f32 and ran a strided scalar dot, so the
//! learned bit-widths saved disk bytes and zero compute. `kernels`
//! operates directly on the low-bit codes instead:
//!
//! * [`pack`] — u64 word-at-a-time bit-stream pack/unpack plus the
//!   bit-plane scatter (the per-element loops survive only as
//!   property-test oracles);
//! * [`gemm`] — [`QuantGemm`] plans: codes unpacked once at load and
//!   stored as one of three interchangeable-by-the-bit forms — dense
//!   centered i8/i16 codes (transposed contiguous `[n_out][d]`, exact
//!   i32 accumulation), bit-sliced popcount planes for small k_w·k_a
//!   ([`bitserial`], §14 — inner-loop work genuinely ∝ k_w·k_a, 64
//!   elements per AND+popcount word), or a dequantized f32 fallback;
//! * [`activ`] — per-row on-the-fly activation quantization at the
//!   checkpoint's learned k_a, same s = 2^k − 1 grid as training;
//! * [`QuantMlp`] (here) — the multi-layer forward: fc stacks with
//!   ReLU, per-layer mixed k_w (each tensor's packed width) and k_a
//!   (checkpoint meta), tile-parallel (rows × output columns) across a
//!   [`WorkerPool`] so small-batch/large-layer shapes use every lane.
//!
//! **Pool & arena lifecycle (§14).** A [`WorkerPool`] is built once per
//! backend (`ReferenceBackend` construction resolves `--threads`,
//! 0 = per core, at that point — never per request) and owns N−1
//! persistent worker threads plus three [`Scratch`] arenas: one per
//! worker, one for the calling thread, and one batch-staging arena for
//! the layer ping-pong/quantization buffers. Every per-request buffer —
//! im2col patches, quantized rows, activation bit planes, layer
//! activations — lives in an arena and is recycled across requests, so
//! after the first batch the forward path performs no heap allocation
//! (`Scratch` counts capacity growths on a shared debug counter;
//! the arena-reuse tests pin the counter flat across requests).
//! `QuantMlp::forward(x, rows, threads)` remains as a convenience that
//! runs a transient pool (inline for `threads ≤ 1`).
//!
//! `serve::ReferenceBackend` is a thin adapter over [`QuantMlp`] /
//! [`QuantConvNet`] plus its persistent pool.

pub mod activ;
pub mod bitserial;
pub mod conv;
pub mod gemm;
pub mod pack;

pub use activ::{fake_quantize_row, quantize_row_centered, raw_code, MAX_INT_ACT_BITS};
pub use bitserial::{BitserialGemm, BITSERIAL_MAX_PRODUCT};
pub use conv::{QuantConvNet, QuantResBlock};
pub use gemm::{PlanChoice, PlanKind, QuantGemm};

/// Instruction set a kernel dispatches to, detected once at plan build
/// (`is_x86_feature_detected!`, same pattern for the dense and popcount
/// paths). `ADAQAT_FORCE_PORTABLE` pins every detection to `Portable`
/// for A/B runs and the portable leg of the CI matrix.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelIsa {
    Portable,
    Popcnt,
    Avx2,
}

impl KernelIsa {
    /// Stable lowercase token for logs and metric labels.
    pub fn label(self) -> &'static str {
        match self {
            KernelIsa::Portable => "portable",
            KernelIsa::Popcnt => "popcnt",
            KernelIsa::Avx2 => "avx2",
        }
    }
}

/// Whether `ADAQAT_FORCE_PORTABLE` pins ISA detection to the portable
/// kernels (set to anything but "" or "0"). Read fresh on every
/// detection — detection runs only at plan build — so one process can
/// build portable and native plans back to back (the bench A/B does).
pub(crate) fn force_portable() -> bool {
    match std::env::var("ADAQAT_FORCE_PORTABLE") {
        Ok(v) => !v.is_empty() && v != "0",
        Err(_) => false,
    }
}

/// One-line ISA banner for the serve startup log: which backend the
/// dense and popcount kernels would dispatch to right now, plus a
/// marker when `ADAQAT_FORCE_PORTABLE` is overriding detection.
pub fn isa_summary() -> String {
    format!(
        "dense={} popcount={}{}",
        gemm::detected_dense_isa().label(),
        bitserial::detected_popcount_isa().label(),
        if force_portable() { " (ADAQAT_FORCE_PORTABLE)" } else { "" }
    )
}

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::time::Instant;

use crate::obs;
use crate::serve::packed::QuantizedCheckpoint;
use crate::util::json::Json;

/// Resolve a requested GEMM thread count (0 = one per available core —
/// looked up here, at construction time, never on the request path).
pub fn resolve_threads(requested: usize) -> usize {
    if requested == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    } else {
        requested
    }
}

/// Reusable per-worker buffers: every transient buffer the forward
/// paths need (quantized rows, activation bit planes, im2col patches,
/// layer ping-pong) lives here so the hot path allocates nothing once
/// warm. Capacity growths tick the pool's shared debug counter — the
/// arena-reuse tests assert it stays flat across requests.
#[derive(Default)]
pub struct Scratch {
    /// Activation bit planes for one bitserial GEMM chunk.
    pub(crate) planes: Vec<u64>,
    /// Per-row raw-code sums matching `planes`.
    pub(crate) asum: Vec<i64>,
    /// Quantized activation rows (centered i16 codes).
    pub(crate) qa: Vec<i16>,
    /// Per-row activation steps Δ_a.
    pub(crate) steps: Vec<f32>,
    /// Per-row hoisted epilogue constants Δ_a[r]·Δ_w as f64 — computed
    /// once per row per GEMM instead of once per output tile.
    pub(crate) dscale: Vec<f64>,
    /// Layer ping-pong buffers (MLP stages, conv feature maps).
    pub(crate) buf_a: Vec<f32>,
    pub(crate) buf_b: Vec<f32>,
    /// im2col patch rows (conv); doubles as the conv feature staging
    /// buffer at the net level (the two uses never overlap).
    pub(crate) patches: Vec<f32>,
    /// Pre-pool conv block output.
    pub(crate) conv_out: Vec<f32>,
    /// Residual-block staging (DESIGN.md §18): the trunk's mid-map
    /// (conv1 output) and the projection-shortcut branch. Separate from
    /// `conv_out` because both live across the nested unit forwards
    /// that cycle `conv_out` underneath them.
    pub(crate) res_mid: Vec<f32>,
    pub(crate) res_sc: Vec<f32>,
    /// Pool-shared allocation counter (None outside a pool).
    pub(crate) grow_events: Option<Arc<AtomicU64>>,
}

impl Scratch {
    fn with_counter(counter: Arc<AtomicU64>) -> Scratch {
        Scratch { grow_events: Some(counter), ..Scratch::default() }
    }
}

/// Resize `v` to `n` elements for reuse, ticking the pool's debug
/// counter when the capacity had to grow (i.e. a real allocation).
/// A same-length re-grab is free — no clear, no refill: every consumer
/// fully writes its buffer (im2col zero-fills its own output,
/// quantize/slice/GEMM loops cover every element, and the bitserial
/// zero-Δ rows never read their planes), so stale contents are never
/// observable and the per-request memset the arenas exist to avoid is
/// actually avoided.
pub(crate) fn grab<T: Clone + Default>(v: &mut Vec<T>, n: usize, grew: &Option<Arc<AtomicU64>>) {
    if v.len() == n {
        return;
    }
    if v.capacity() < n {
        if let Some(c) = grew {
            c.fetch_add(1, Ordering::Relaxed);
        }
    }
    v.clear();
    v.resize(n, T::default());
}

/// Lock an arena, shrugging off poisoning: a panicked job may have
/// poisoned the mutex while unwinding, but `Scratch` holds only plain
/// reusable buffers that every consumer resizes/overwrites before
/// reading, so a poisoned arena is still perfectly usable — without
/// this, one panicked job would wedge the pool forever even though its
/// workers are healthy (`run` already reports the panic itself).
fn lock_scratch(m: &Mutex<Scratch>) -> MutexGuard<'_, Scratch> {
    m.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// The job pointer handed to pool workers: a borrowed closure with its
/// lifetime erased. Sound because [`WorkerPool::run_dyn`] blocks until
/// every worker has finished the generation the pointer was published
/// for, and `run_lock` serializes generations.
#[derive(Clone, Copy)]
struct Job(*const (dyn Fn(usize, &mut Scratch) + Sync));

// AUDIT(Send): the invariant is pointee liveness — `Job` is one erased
// closure pointer published per pool generation, and `run_dyn` does not
// return until `remaining == 0`, so the pointee outlives every worker's
// dereference (the generation-monotonicity debug asserts pin the
// drain-before-republish protocol).
// SAFETY: the pointer is only dereferenced by workers inside the
// generation it was published for; the pointee outlives that window
// (see AUDIT above), so moving the pointer across threads is sound.
unsafe impl Send for Job {}
// AUDIT(Sync): the invariant is shared-call safety — the pointee is
// `dyn Fn(..) + Sync`, so concurrent `&`-calls from every lane are the
// exact contract the closure's type already promises.
// SAFETY: `&Job` only allows reading the pointer and calling the Sync
// pointee; both are safe from any number of threads at once.
unsafe impl Sync for Job {}

struct PoolState {
    job: Option<Job>,
    generation: u64,
    /// Workers still running the current generation.
    remaining: usize,
    panicked: bool,
    shutdown: bool,
}

struct PoolShared {
    state: Mutex<PoolState>,
    /// Workers wait here for a new generation.
    work: Condvar,
    /// The caller waits here for `remaining == 0`.
    done: Condvar,
}

/// The pool's registry handles (DESIGN.md §15): a live occupancy gauge
/// (lanes currently executing a job, summed across pools sharing the
/// registry) and a lifetime job counter. Registered once per pool at
/// construction.
struct PoolObs {
    active: Arc<obs::Gauge>,
    jobs_total: Arc<obs::Counter>,
}

impl PoolObs {
    fn register() -> PoolObs {
        let reg = obs::global();
        PoolObs {
            active: reg.gauge("adaqat_pool_active", &[]),
            jobs_total: reg.counter("adaqat_pool_jobs_total", &[]),
        }
    }
}

/// Persistent scoped worker pool (DESIGN.md §14): N−1 worker threads
/// spawned once at backend construction replace the per-batch
/// `std::thread::scope` spawns the forward paths used to pay. Each
/// `run` publishes one borrowed job closure; every worker (the calling
/// thread participates as worker 0) invokes it once with its worker id
/// and its own persistent [`Scratch`] arena, and `run` returns when all
/// have finished — the same barrier semantics as a scoped spawn,
/// without the thread setup/teardown per batch. Rayon-free: the
/// offline crate universe has no dependencies (DESIGN.md §3).
pub struct WorkerPool {
    threads: usize,
    shared: Arc<PoolShared>,
    handles: Vec<std::thread::JoinHandle<()>>,
    /// Serializes concurrent `run` calls (one generation in flight).
    run_lock: Mutex<()>,
    /// Worker 0's (the calling thread's) arena.
    main_scratch: Mutex<Scratch>,
    /// Batch-staging arena: layer ping-pong + quantization buffers the
    /// calling thread fills before fanning row chunks out.
    stage: Mutex<Scratch>,
    grow_events: Arc<AtomicU64>,
    obs: PoolObs,
}

impl WorkerPool {
    /// Build a pool with `threads` total lanes (0 = one per core via
    /// [`resolve_threads`]); `threads ≤ 1` spawns nothing and `run`
    /// executes inline.
    pub fn new(threads: usize) -> WorkerPool {
        let threads = resolve_threads(threads);
        let shared = Arc::new(PoolShared {
            state: Mutex::new(PoolState {
                job: None,
                generation: 0,
                remaining: 0,
                panicked: false,
                shutdown: false,
            }),
            work: Condvar::new(),
            done: Condvar::new(),
        });
        let grow_events = Arc::new(AtomicU64::new(0));
        let mut handles = Vec::new();
        for wid in 1..threads {
            let shared = Arc::clone(&shared);
            let counter = Arc::clone(&grow_events);
            handles.push(
                std::thread::Builder::new()
                    .name(format!("gemm-worker-{wid}"))
                    .spawn(move || {
                        let mut scratch = Scratch::with_counter(counter);
                        pool_worker_loop(&shared, wid, &mut scratch);
                    })
                    .expect("spawn gemm worker"),
            );
        }
        WorkerPool {
            threads,
            main_scratch: Mutex::new(Scratch::with_counter(Arc::clone(&grow_events))),
            stage: Mutex::new(Scratch::with_counter(Arc::clone(&grow_events))),
            grow_events,
            shared,
            handles,
            run_lock: Mutex::new(()),
            obs: PoolObs::register(),
        }
    }

    /// Resolved lane count (worker threads + the calling thread).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Total arena capacity growths since pool construction — the debug
    /// counter the allocation-free-hot-path tests pin down: it must
    /// stop moving once the pool has served a warm-up request.
    pub fn grow_events(&self) -> u64 {
        self.grow_events.load(Ordering::Relaxed)
    }

    /// Run `f(worker_id, scratch)` once on every lane (ids
    /// `0..threads()`) and return when all lanes have finished.
    /// Panics if any lane's job panicked.
    pub fn run<F>(&self, f: F)
    where
        F: Fn(usize, &mut Scratch) + Sync,
    {
        self.run_dyn(&f);
    }

    /// [`run`](WorkerPool::run), skipping the worker broadcast when at
    /// most one lane would do work: a batch-1 request (or any
    /// `parts == 1` split) executes inline on the caller with zero
    /// synchronization — the same fast path the pre-pool scoped-spawn
    /// code had — instead of waking N−1 workers to return immediately.
    /// Results are identical either way (lane 0 covers the whole
    /// range; the kernels are order-independent).
    pub fn run_active<F>(&self, active: usize, f: F)
    where
        F: Fn(usize, &mut Scratch) + Sync,
    {
        if active <= 1 {
            self.obs.jobs_total.inc();
            self.obs.active.add(1.0);
            let mut scratch = lock_scratch(&self.main_scratch);
            f(0, &mut scratch);
            drop(scratch);
            self.obs.active.add(-1.0);
            return;
        }
        self.run_dyn(&f);
    }

    fn run_dyn<'a>(&'a self, f: &'a (dyn Fn(usize, &mut Scratch) + Sync + 'a)) {
        if self.handles.is_empty() {
            self.obs.jobs_total.inc();
            self.obs.active.add(1.0);
            let mut scratch = lock_scratch(&self.main_scratch);
            f(0, &mut scratch);
            drop(scratch);
            self.obs.active.add(-1.0);
            return;
        }
        self.obs.jobs_total.inc();
        // occupancy gauge: all lanes (workers + caller) count as busy
        // for the span of the generation — a coarse but truthful view
        // of pool saturation, paired +/- so the gauge is drift-free on
        // every non-panicking path (a panicking job tears the worker
        // down anyway)
        self.obs.active.add(self.threads as f64);
        let serial = self.run_lock.lock().unwrap();
        let ptr: *const (dyn Fn(usize, &mut Scratch) + Sync + 'a) = f;
        // SAFETY: lifetime erasure — this function does not return
        // until every worker reports done, so `f` outlives all uses.
        #[allow(clippy::useless_transmute)]
        let job = Job(unsafe {
            std::mem::transmute::<
                *const (dyn Fn(usize, &mut Scratch) + Sync + 'a),
                *const (dyn Fn(usize, &mut Scratch) + Sync + 'static),
            >(ptr)
        });
        {
            let mut st = self.shared.state.lock().unwrap();
            // generation protocol invariant: a new generation may only
            // be published once the previous one fully drained — the
            // erased Job pointer's liveness argument depends on it
            debug_assert!(
                st.remaining == 0 && st.job.is_none(),
                "worker pool generation published before the previous one drained"
            );
            st.job = Some(job);
            st.generation += 1;
            st.remaining = self.handles.len();
            self.shared.work.notify_all();
        }
        let caller = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut scratch = lock_scratch(&self.main_scratch);
            f(0, &mut scratch);
        }));
        let mut st = self.shared.state.lock().unwrap();
        while st.remaining > 0 {
            st = self.shared.done.wait(st).unwrap();
        }
        st.job = None;
        let worker_panicked = st.panicked;
        st.panicked = false;
        drop(st);
        drop(serial);
        self.obs.active.add(-(self.threads as f64));
        if caller.is_err() || worker_panicked {
            panic!("worker pool job panicked");
        }
    }

    /// The batch-staging arena (callers must release the guard before
    /// invoking `run` — workers never touch this arena, but holding it
    /// across a nested `*_pooled` call would self-deadlock).
    pub(crate) fn stage_scratch(&self) -> MutexGuard<'_, Scratch> {
        lock_scratch(&self.stage)
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        match self.shared.state.lock() {
            Ok(mut st) => st.shutdown = true,
            Err(poisoned) => poisoned.into_inner().shutdown = true,
        }
        self.shared.work.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn pool_worker_loop(shared: &PoolShared, wid: usize, scratch: &mut Scratch) {
    let mut my_gen = 0u64;
    let mut st = shared.state.lock().unwrap();
    loop {
        if st.shutdown {
            return;
        }
        if st.generation != my_gen {
            // generation monotonicity: `run_dyn` waits for the previous
            // generation to drain before publishing the next, so a
            // worker can never skip one — each wake sees exactly +1
            debug_assert_eq!(
                st.generation,
                my_gen + 1,
                "worker pool generation not monotone (worker skipped a generation)"
            );
            my_gen = st.generation;
            let job = st.job.expect("pool generation published without a job");
            drop(st);
            let ok = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                // SAFETY: the pointer stays valid until `remaining`
                // reaches zero, which cannot happen before this call
                // returns (we decrement below).
                let f = unsafe { &*job.0 };
                f(wid, &mut *scratch);
            }))
            .is_ok();
            let mut after = shared.state.lock().unwrap();
            if !ok {
                after.panicked = true;
            }
            after.remaining -= 1;
            if after.remaining == 0 {
                shared.done.notify_all();
            }
            st = after;
        } else {
            st = shared.work.wait(st).unwrap();
        }
    }
}

/// Contiguous chunk `i` of `n` items split across `parts` lanes (empty
/// for trailing lanes when `n < parts`). With the kernels'
/// order-independent exact accumulation, the split can never change
/// results — only wall-clock.
pub(crate) fn chunk_range(n: usize, parts: usize, i: usize) -> (usize, usize) {
    let chunk = n.div_ceil(parts.max(1));
    let r0 = (i * chunk).min(n);
    let r1 = (r0 + chunk).min(n);
    (r0, r1)
}

/// Debug-build claim map for [`SplitMut`] (DESIGN.md §17): one bit per
/// output cell, set by `fetch_or` when a [`range`] or [`write`] claims
/// it. The RMW is atomic, so when two lanes race for the same cell
/// exactly one observes the bit already set and panics — turning the
/// "unsafe-but-audited" disjointness contract into a runtime-verified
/// invariant on every test/CI run. Compiled out entirely in release
/// builds (`debug_assertions` off), so the serving hot path pays zero.
///
/// [`range`]: SplitMut::range
/// [`write`]: SplitMut::write
#[cfg(debug_assertions)]
struct ClaimMap {
    words: Box<[AtomicU64]>,
}

#[cfg(debug_assertions)]
impl ClaimMap {
    fn new(len: usize) -> ClaimMap {
        let n = len.div_ceil(64);
        let mut words = Vec::with_capacity(n);
        words.resize_with(n, || AtomicU64::new(0));
        ClaimMap { words: words.into_boxed_slice() }
    }

    /// Claim cells `[start, start + len)`, panicking if any of them was
    /// already claimed by this or any other lane. Callers bounds-check
    /// first, so the word indexing here cannot go out of range.
    fn claim(&self, start: usize, len: usize) {
        if len == 0 {
            return;
        }
        let end = start + len;
        let (w0, w1) = (start / 64, (end - 1) / 64);
        for w in w0..=w1 {
            let lo = if w == w0 { start % 64 } else { 0 };
            let hi = if w == w1 { (end - 1) % 64 + 1 } else { 64 };
            let mask = if hi - lo == 64 { u64::MAX } else { ((1u64 << (hi - lo)) - 1) << lo };
            // Relaxed is enough: the RMW's atomicity alone guarantees a
            // unique winner per bit; no other memory is published here.
            let prev = self.words[w].fetch_or(mask, Ordering::Relaxed);
            assert!(
                prev & mask == 0,
                "SplitMut overlapping claim: cells [{start}, {end}) collide with an \
                 earlier range()/write() claim on the same buffer"
            );
        }
    }
}

/// Mutable view of one output buffer that pool jobs carve into disjoint
/// pieces by worker id — the borrow checker cannot see the disjointness
/// through the shared job closure, so the carve is unsafe-but-audited.
/// Row-granular jobs take contiguous [`range`]s; tile-granular jobs
/// (column splits interleave their cells in memory) use per-cell
/// [`write`]s instead.
///
/// Under `debug_assertions` every claim is additionally checked against
/// a [`ClaimMap`]: any overlapping carve — from concurrent lanes or
/// from a buggy sequential double-visit — panics instead of silently
/// racing. A `SplitMut` is therefore single-use by contract: each cell
/// may be claimed at most once over the view's lifetime (every forward
/// path builds a fresh view per parallel section, so this is the
/// contract they already obeyed).
///
/// [`range`]: SplitMut::range
/// [`write`]: SplitMut::write
pub(crate) struct SplitMut<'a, T> {
    ptr: *mut T,
    len: usize,
    #[cfg(debug_assertions)]
    claims: ClaimMap,
    _life: std::marker::PhantomData<&'a mut [T]>,
}

// AUDIT(Send): the invariant is exclusive origin — the view is built
// from one `&mut [T]`, whose borrow it holds for its lifetime, so the
// pointer's target is owned for the duration and may move threads with
// the view whenever the element type itself may (`T: Send`).
// SAFETY: sending the view only relocates which thread may claim
// pieces; the underlying buffer stays exclusively borrowed.
unsafe impl<T: Send> Send for SplitMut<'_, T> {}
// AUDIT(Sync): the invariant is claim disjointness — concurrent
// `&`-access hands out non-overlapping `&mut` pieces only (caller
// contract on `range`/`write`, runtime-verified by the debug
// [`ClaimMap`]), so no two threads ever alias a cell.
// SAFETY: shared access cannot create overlapping mutable aliasing as
// long as the claim contract holds; the dynamic checker enforces it on
// every debug run.
unsafe impl<T: Send> Sync for SplitMut<'_, T> {}

impl<'a, T> SplitMut<'a, T> {
    pub(crate) fn new(buf: &'a mut [T]) -> SplitMut<'a, T> {
        SplitMut {
            ptr: buf.as_mut_ptr(),
            len: buf.len(),
            #[cfg(debug_assertions)]
            claims: ClaimMap::new(buf.len()),
            _life: std::marker::PhantomData,
        }
    }

    /// # Safety
    /// Concurrent callers must take non-overlapping `(start, len)`
    /// ranges (the forward paths derive them from [`chunk_range`],
    /// which partitions), and no concurrent [`write`](SplitMut::write)
    /// may land inside a handed-out range. Debug builds verify this
    /// dynamically via the claim map.
    #[allow(clippy::mut_from_ref)]
    pub(crate) unsafe fn range(&self, start: usize, len: usize) -> &mut [T] {
        // checked add: a pathological `start` near usize::MAX must not
        // wrap past the bounds test below
        let end = start.checked_add(len).expect("SplitMut range overflow: start + len wraps");
        assert!(end <= self.len, "SplitMut range out of bounds");
        #[cfg(debug_assertions)]
        self.claims.claim(start, len);
        // SAFETY: `[start, end)` is in bounds (asserted above) and the
        // caller guarantees no concurrent claim overlaps it.
        unsafe { std::slice::from_raw_parts_mut(self.ptr.add(start), len) }
    }

    /// Write one cell. The bounds check is a hard `assert!` — it guards
    /// a raw-pointer store, so release builds must not skip it (one
    /// predictable compare per output cell, same cost class as the
    /// slice indexing the epilogue already does).
    ///
    /// # Safety
    /// Concurrent callers must write disjoint indices (the tiled
    /// forward paths derive them from [`chunk_range`] grids, which
    /// partition the `[rows × n_out]` cell space). Debug builds verify
    /// this dynamically via the claim map.
    pub(crate) unsafe fn write(&self, idx: usize, v: T) {
        assert!(idx < self.len, "SplitMut write out of bounds");
        #[cfg(debug_assertions)]
        self.claims.claim(idx, 1);
        // SAFETY: `idx` is in bounds (asserted above) and the caller
        // guarantees no concurrent claim covers it.
        unsafe { *self.ptr.add(idx) = v };
    }
}

/// Per-layer telemetry handles (DESIGN.md §15): one forward-wall-time
/// histogram and one rows counter per `(layer, plan, k_w, k_a)` series
/// in the global registry. Registered once when a net is built from a
/// packed checkpoint — the labels are exactly the serving cost profile
/// AdaQAT's learned bit-widths are supposed to change, so the series
/// answer "which plan does layer X actually run, and what does it
/// cost" per scrape. Nets hold these in a `Vec` parallel to their
/// layer list (rather than on the layer structs) so layer literals in
/// tests stay registry-free.
pub struct LayerObs {
    forward_ms: Arc<obs::HistHandle>,
    rows_total: Arc<obs::Counter>,
}

impl LayerObs {
    /// `plan` is the full plan label ([`QuantGemm::plan_label`]), which
    /// carries the dispatched ISA (`int8_avx2` vs `int8`) so the
    /// per-layer series distinguish tiled/SIMD plans from scalar ones.
    pub fn register(layer: &str, plan: &str, k_w: u32, k_a: u32) -> LayerObs {
        let (k_w, k_a) = (k_w.to_string(), k_a.to_string());
        let labels = [
            ("layer", layer),
            ("plan", plan),
            ("k_w", k_w.as_str()),
            ("k_a", k_a.as_str()),
        ];
        let reg = obs::global();
        LayerObs {
            forward_ms: reg.histogram("adaqat_layer_forward_ms", &labels),
            rows_total: reg.counter("adaqat_layer_rows_total", &labels),
        }
    }

    /// Record one timed span over `rows` rows. Callers gate the
    /// `Instant::now()` pair on [`obs::Registry::enabled`], so a
    /// disabled registry pays nothing here.
    pub fn record(&self, rows: usize, t0: Instant) {
        self.forward_ms.record_ms(t0.elapsed().as_secs_f64() * 1e3);
        self.rows_total.add(rows as u64);
    }
}

/// One fc layer: a weight plan, bias, the activation width its *input*
/// is quantized at, and whether a ReLU follows it.
pub struct QuantLayer {
    pub name: String,
    pub gemm: QuantGemm,
    pub bias: Vec<f32>,
    pub k_a: u32,
    pub relu: bool,
}

/// A stack of [`QuantLayer`]s loaded from a packed checkpoint.
pub struct QuantMlp {
    pub layers: Vec<QuantLayer>,
    /// Input feature count of the first layer.
    pub input: usize,
    /// Output count of the last layer.
    pub classes: usize,
    /// Registry handles parallel to `layers` (see [`LayerObs`]).
    obs: Vec<LayerObs>,
}

impl QuantMlp {
    /// Build from a packed checkpoint. Layer names come from the meta
    /// `mlp_layers` array (`["fc1", "fc2", …]`, ReLU between layers);
    /// a checkpoint without it serves the legacy single `fc` layer.
    /// Each layer `L` needs `L.w` (`[d_in, d_out]`) and optionally
    /// `L.b` (`[d_out]`). Activation widths: meta `k_a` globally,
    /// overridable per layer via a `layer_k_a` object (`{"fc1": 8}`);
    /// k_w is per-tensor by construction (each `PackedTensor` carries
    /// its own bit-width), so mixed-precision stacks need no extra meta.
    pub fn from_packed(q: &QuantizedCheckpoint) -> anyhow::Result<QuantMlp> {
        let names: Vec<String> = q
            .meta_layer_names("mlp_layers")?
            .unwrap_or_else(|| vec!["fc".to_string()]);
        let global_k_a =
            q.meta.get("k_a").and_then(Json::as_f64).unwrap_or(32.0) as u32;
        let per_layer = q.meta.get("layer_k_a");
        let last = names.len() - 1;
        let mut layers = Vec::with_capacity(names.len());
        for (li, name) in names.iter().enumerate() {
            let wt = q
                .get(&format!("{name}.w"))
                .ok_or_else(|| anyhow::anyhow!("packed checkpoint lacks {name}.w"))?;
            let k_a = per_layer
                .and_then(|m| m.get(name))
                .and_then(Json::as_f64)
                .map(|v| v as u32)
                .unwrap_or(global_k_a);
            anyhow::ensure!(k_a >= 1, "{name}: k_a must be >= 1");
            let gemm = QuantGemm::from_packed(wt, k_a)
                .map_err(|e| anyhow::anyhow!("{name}.w: {e}"))?;
            let bias = match q.get(&format!("{name}.b")) {
                Some(bt) => {
                    anyhow::ensure!(
                        bt.shape == vec![gemm.n_out],
                        "{name}.b shape {:?} != [{}]",
                        bt.shape,
                        gemm.n_out
                    );
                    bt.dequantize().data
                }
                None => vec![0.0; gemm.n_out],
            };
            layers.push(QuantLayer {
                name: name.clone(),
                gemm,
                bias,
                k_a,
                relu: li != last,
            });
        }
        for pair in layers.windows(2) {
            anyhow::ensure!(
                pair[0].gemm.n_out == pair[1].gemm.d,
                "layer chain mismatch: {}.w outputs {} but {}.w expects {}",
                pair[0].name,
                pair[0].gemm.n_out,
                pair[1].name,
                pair[1].gemm.d
            );
        }
        let input = layers[0].gemm.d;
        let classes = layers[layers.len() - 1].gemm.n_out;
        let obs = layers
            .iter()
            .map(|l| LayerObs::register(&l.name, l.gemm.plan_label(), l.gemm.bits, l.k_a))
            .collect();
        Ok(QuantMlp { layers, input, classes, obs })
    }

    /// Logits for `rows` stacked input rows (`x.len() == rows·input`)
    /// on a transient pool of `threads` lanes (≤ 1 runs inline with no
    /// thread spawn; 0 clamps to 1, matching the old inline behavior —
    /// per-core auto-sizing is the *pool's* convention, resolved once
    /// at backend construction) — the convenience form; serving holds a
    /// persistent [`WorkerPool`] and calls [`forward_pooled`] instead.
    /// Identical bits either way: the kernels are order-independent.
    ///
    /// [`forward_pooled`]: QuantMlp::forward_pooled
    pub fn forward(&self, x: &[f32], rows: usize, threads: usize) -> Vec<f32> {
        self.forward_pooled(x, rows, &WorkerPool::new(threads.max(1)))
    }

    /// Logits for `rows` stacked input rows, row-parallel across the
    /// pool's lanes, every transient buffer drawn from the pool's
    /// arenas (allocation-free once warm). Integer layers quantize
    /// their input rows on the fly; f32-fallback layers fake-quantize
    /// when k_a < 24 so the learned activation width is honoured either
    /// way. Per-row activation scales make results independent of batch
    /// composition: a row computes bit-identically at batch 1 and
    /// inside a full batch.
    pub fn forward_pooled(&self, x: &[f32], rows: usize, pool: &WorkerPool) -> Vec<f32> {
        assert_eq!(x.len(), rows * self.input, "bad input length");
        // Take the staging buffers out of the arena (releasing the
        // guard — holding it across pool.run would block nothing, but
        // holding it across a nested *_pooled call would deadlock).
        let (mut cur, mut nxt, mut qa, mut steps, mut dscale, grew) = {
            let mut st = pool.stage_scratch();
            (
                std::mem::take(&mut st.buf_a),
                std::mem::take(&mut st.buf_b),
                std::mem::take(&mut st.qa),
                std::mem::take(&mut st.steps),
                std::mem::take(&mut st.dscale),
                st.grow_events.clone(),
            )
        };
        grab(&mut cur, x.len(), &grew);
        cur.copy_from_slice(x);
        // per-layer telemetry: one enabled check per forward, one
        // Instant pair per layer when on, nothing when off
        let obs_on = obs::global().enabled();
        for (li, layer) in self.layers.iter().enumerate() {
            let t_layer = if obs_on { Some(Instant::now()) } else { None };
            let d = layer.gemm.d;
            let n_out = layer.gemm.n_out;
            grab(&mut nxt, rows * n_out, &grew);
            if layer.gemm.is_integer() {
                grab(&mut qa, rows * d, &grew);
                grab(&mut steps, rows, &grew);
                for r in 0..rows {
                    steps[r] = activ::quantize_row_centered(
                        &cur[r * d..(r + 1) * d],
                        layer.k_a,
                        &mut qa[r * d..(r + 1) * d],
                    );
                }
                // hoisted per-row epilogue constants, shared by every
                // tile that touches the row
                grab(&mut dscale, rows, &grew);
                let sw = layer.gemm.step_w as f64;
                for r in 0..rows {
                    dscale[r] = steps[r] as f64 * sw;
                }
                // Tile-granular distribution over [rows × n_out]: rows
                // split first (cheapest — contiguous output), then
                // leftover lanes split the output columns, so a
                // small-batch/large-layer request (the serving hot
                // case) still occupies every lane. Any grid gives the
                // same bits: the kernels are order-independent.
                let lanes = pool.threads();
                let row_tiles = rows.min(lanes).max(1);
                let col_tiles = (lanes / row_tiles).min(n_out.div_ceil(gemm::OUT_TILE)).max(1);
                let tiles = row_tiles * col_tiles;
                let parts = tiles.min(lanes);
                let qa_ref = &qa;
                let steps_ref = &steps;
                let dscale_ref = &dscale;
                let split = SplitMut::new(&mut nxt);
                if let Some(bits) = layer.gemm.bitserial() {
                    // Batch-amortized slicing: the whole batch's
                    // activation bit-planes go into the staging arena
                    // once (row-parallel), then every weight-plane tile
                    // sweeps against them — column tiles share the
                    // slices instead of re-slicing their rows.
                    let per_row = bits.plane_words_per_row();
                    let (mut planes, mut asum) = {
                        let mut st = pool.stage_scratch();
                        (std::mem::take(&mut st.planes), std::mem::take(&mut st.asum))
                    };
                    grab(&mut planes, rows * per_row, &grew);
                    grab(&mut asum, rows, &grew);
                    let sparts = rows.min(lanes);
                    {
                        let psplit = SplitMut::new(&mut planes);
                        let ssplit = SplitMut::new(&mut asum);
                        pool.run_active(sparts, |wid, _ws| {
                            let (r0, r1) = chunk_range(rows, sparts, wid);
                            if r0 >= r1 {
                                return;
                            }
                            // SAFETY: chunk_range partitions — disjoint.
                            let pchunk =
                                unsafe { psplit.range(r0 * per_row, (r1 - r0) * per_row) };
                            // SAFETY: same partition, row-granular.
                            let schunk = unsafe { ssplit.range(r0, r1 - r0) };
                            bits.slice_rows(qa_ref, steps_ref, r0, r1, pchunk, schunk);
                        });
                    }
                    let planes_ref = &planes;
                    let asum_ref = &asum;
                    pool.run_active(parts, |wid, _ws| {
                        let mut t = wid;
                        while t < tiles {
                            let (r0, r1) = chunk_range(rows, row_tiles, t % row_tiles);
                            let (o0, o1) = chunk_range(n_out, col_tiles, t / row_tiles);
                            if r0 < r1 && o0 < o1 {
                                bits.sweep_cols(
                                    planes_ref, asum_ref, steps_ref, dscale_ref, r0, r1, o0,
                                    o1, None, &layer.bias, &split,
                                );
                            }
                            t += parts;
                        }
                    });
                    let mut st = pool.stage_scratch();
                    st.planes = planes;
                    st.asum = asum;
                } else {
                    pool.run_active(parts, |wid, _ws| {
                        let mut t = wid;
                        while t < tiles {
                            let (r0, r1) = chunk_range(rows, row_tiles, t % row_tiles);
                            let (o0, o1) = chunk_range(n_out, col_tiles, t / row_tiles);
                            if r0 < r1 && o0 < o1 {
                                layer.gemm.forward_tile(
                                    qa_ref, dscale_ref, r0, r1, o0, o1, None, &layer.bias,
                                    &split,
                                );
                            }
                            t += parts;
                        }
                    });
                }
            } else {
                let parts = pool.threads().min(rows.max(1));
                if layer.k_a < 24 {
                    for r in 0..rows {
                        activ::fake_quantize_row(&mut cur[r * d..(r + 1) * d], layer.k_a);
                    }
                }
                let xin = &cur;
                let split = SplitMut::new(&mut nxt);
                pool.run_active(parts, |wid, _ws| {
                    let (r0, r1) = chunk_range(rows, parts, wid);
                    if r0 >= r1 {
                        return;
                    }
                    // SAFETY: chunk_range partitions — ranges disjoint.
                    let out = unsafe { split.range(r0 * n_out, (r1 - r0) * n_out) };
                    layer.gemm.forward_f32(&xin[r0 * d..r1 * d], r1 - r0, &layer.bias, out);
                });
            }
            if layer.relu {
                for v in nxt.iter_mut() {
                    if *v < 0.0 {
                        *v = 0.0;
                    }
                }
            }
            if let Some(t0) = t_layer {
                self.obs[li].record(rows, t0);
            }
            std::mem::swap(&mut cur, &mut nxt);
        }
        let logits = cur[..rows * self.classes].to_vec();
        // undo ping-pong parity so each buffer returns to the arena
        // slot it came from — keeps capacities stable across requests
        // (an odd layer count would otherwise re-grow on request 2)
        if self.layers.len() % 2 == 1 {
            std::mem::swap(&mut cur, &mut nxt);
        }
        let mut st = pool.stage_scratch();
        st.buf_a = cur;
        st.buf_b = nxt;
        st.qa = qa;
        st.steps = steps;
        st.dscale = dscale;
        logits
    }

    /// Argmax class per row (ties break to the lowest class id, the
    /// same rule the pre-kernels serving loop used).
    pub fn classify(&self, x: &[f32], rows: usize, threads: usize) -> Vec<usize> {
        self.classify_pooled(x, rows, &WorkerPool::new(threads.max(1)))
    }

    /// [`classify`](QuantMlp::classify) on a persistent pool.
    pub fn classify_pooled(&self, x: &[f32], rows: usize, pool: &WorkerPool) -> Vec<usize> {
        let logits = self.forward_pooled(x, rows, pool);
        (0..rows)
            .map(|r| argmax(&logits[r * self.classes..(r + 1) * self.classes]))
            .collect()
    }
}

pub(crate) fn argmax(scores: &[f32]) -> usize {
    let mut best = 0usize;
    let mut best_score = f32::NEG_INFINITY;
    for (i, &s) in scores.iter().enumerate() {
        if s > best_score {
            best_score = s;
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::packed::PackedTensor;
    use crate::tensor::checkpoint::Checkpoint;
    use crate::tensor::Tensor;
    use crate::util::rng::Rng;

    fn random_tensor(shape: Vec<usize>, seed: u64) -> Tensor {
        let mut rng = Rng::new(seed);
        let n: usize = shape.iter().product();
        Tensor::new(shape, (0..n).map(|_| rng.normal() * 0.2).collect())
    }

    /// A legacy-style single-layer packed checkpoint (`fc.w`/`fc.b`).
    fn single_layer_packed(d: usize, classes: usize, bits: u32, k_a: f64) -> QuantizedCheckpoint {
        let mut ck = Checkpoint::new(Json::obj(vec![("k_a", Json::num(k_a))]));
        ck.push("fc.w", random_tensor(vec![d, classes], 21));
        ck.push("fc.b", random_tensor(vec![classes], 22));
        QuantizedCheckpoint::from_checkpoint(&ck, bits, |n| n.ends_with(".w"))
    }

    #[test]
    fn legacy_single_layer_f32_path_matches_old_strided_oracle() {
        // k_a = 32 (identity): the f32 plan must reproduce the
        // pre-kernels serving math — dequantized weights, strided
        // layout, ascending-index accumulation — bit for bit.
        let (d, classes) = (48usize, 10usize);
        let q = single_layer_packed(d, classes, 4, 32.0);
        let mlp = QuantMlp::from_packed(&q).unwrap();
        assert_eq!(mlp.layers.len(), 1);
        assert!(!mlp.layers[0].gemm.is_integer());
        assert!(!mlp.layers[0].relu);
        let w = q.get("fc.w").unwrap().dequantize().data;
        let b = q.get("fc.b").unwrap().dequantize().data;
        let mut rng = Rng::new(5);
        let x: Vec<f32> = (0..3 * d).map(|_| rng.normal()).collect();
        let logits = mlp.forward(&x, 3, 1);
        for r in 0..3 {
            for cls in 0..classes {
                // the old ReferenceBackend::classify_one inner loop
                let mut score = b[cls];
                for i in 0..d {
                    score += x[r * d + i] * w[i * classes + cls];
                }
                assert_eq!(logits[r * classes + cls].to_bits(), score.to_bits());
            }
        }
    }

    #[test]
    fn two_layer_mixed_precision_chain() {
        // fc1 at 3 bits, fc2 at 8 bits, per-layer k_a override — the
        // per-tensor `bits` field carries mixed k_w with no extra meta.
        let (d, h, classes) = (24usize, 12usize, 5usize);
        let mut q = QuantizedCheckpoint::new(Json::obj(vec![
            ("k_a", Json::num(8.0)),
            (
                "mlp_layers",
                Json::Arr(vec![Json::str("fc1"), Json::str("fc2")]),
            ),
            (
                "layer_k_a",
                Json::obj(vec![("fc2", Json::num(6.0))]),
            ),
        ]));
        q.push("fc1.w", PackedTensor::quantize(&random_tensor(vec![d, h], 1), 3));
        q.push("fc1.b", PackedTensor::raw(&random_tensor(vec![h], 2)));
        q.push("fc2.w", PackedTensor::quantize(&random_tensor(vec![h, classes], 3), 8));
        q.push("fc2.b", PackedTensor::raw(&random_tensor(vec![classes], 4)));
        let mlp = QuantMlp::from_packed(&q).unwrap();
        assert_eq!(mlp.input, d);
        assert_eq!(mlp.classes, classes);
        assert_eq!(mlp.layers[0].gemm.bits, 3);
        assert_eq!(mlp.layers[1].gemm.bits, 8);
        assert_eq!(mlp.layers[0].k_a, 8);
        assert_eq!(mlp.layers[1].k_a, 6);
        assert!(mlp.layers[0].relu && !mlp.layers[1].relu);
        let mut rng = Rng::new(6);
        let x: Vec<f32> = (0..4 * d).map(|_| rng.normal()).collect();
        let preds = mlp.classify(&x, 4, 1);
        assert_eq!(preds.len(), 4);
        assert!(preds.iter().all(|&p| p < classes));
    }

    #[test]
    fn thread_count_never_changes_results() {
        let (d, h, classes) = (64usize, 32usize, 10usize);
        let mut q = QuantizedCheckpoint::new(Json::obj(vec![
            ("k_a", Json::num(8.0)),
            (
                "mlp_layers",
                Json::Arr(vec![Json::str("fc1"), Json::str("fc2")]),
            ),
        ]));
        q.push("fc1.w", PackedTensor::quantize(&random_tensor(vec![d, h], 31), 4));
        q.push("fc2.w", PackedTensor::quantize(&random_tensor(vec![h, classes], 32), 4));
        let mlp = QuantMlp::from_packed(&q).unwrap();
        let mut rng = Rng::new(33);
        let rows = 13usize; // deliberately not divisible by thread counts
        let x: Vec<f32> = (0..rows * d).map(|_| rng.normal()).collect();
        let base = mlp.forward(&x, rows, 1);
        for threads in [2usize, 3, 4, 8, 64] {
            let got = mlp.forward(&x, rows, threads);
            assert_eq!(base.len(), got.len());
            for (a, b) in base.iter().zip(&got) {
                assert_eq!(a.to_bits(), b.to_bits(), "threads={threads}");
            }
        }
    }

    #[test]
    fn tile_split_never_changes_results_small_batch_wide_layer() {
        // batch-1/2 requests on a wide layer split across column tiles
        // now — every lane count must stay bit-identical to inline,
        // for a dense layer and a bitserial (pre-sliced) layer alike
        let (d, h, classes) = (96usize, 200usize, 40usize);
        let mut q = QuantizedCheckpoint::new(Json::obj(vec![
            ("k_a", Json::num(8.0)),
            (
                "mlp_layers",
                Json::Arr(vec![Json::str("fc1"), Json::str("fc2")]),
            ),
            // fc2 at k_w=1, k_a=4: product 4 rides the popcount planes
            ("layer_k_a", Json::obj(vec![("fc2", Json::num(4.0))])),
        ]));
        q.push("fc1.w", PackedTensor::quantize(&random_tensor(vec![d, h], 71), 4));
        q.push("fc2.w", PackedTensor::quantize(&random_tensor(vec![h, classes], 72), 1));
        let mlp = QuantMlp::from_packed(&q).unwrap();
        assert_eq!(mlp.layers[0].gemm.plan_kind(), gemm::PlanKind::Int8);
        assert_eq!(mlp.layers[1].gemm.plan_kind(), gemm::PlanKind::Bitserial);
        let mut rng = Rng::new(73);
        for rows in [1usize, 2, 5] {
            let x: Vec<f32> = (0..rows * d).map(|_| rng.normal()).collect();
            let base = mlp.forward(&x, rows, 1);
            for threads in [2usize, 3, 8, 64] {
                let got = mlp.forward(&x, rows, threads);
                for (a, b) in base.iter().zip(&got) {
                    assert_eq!(a.to_bits(), b.to_bits(), "rows={rows} threads={threads}");
                }
            }
        }
    }

    #[test]
    fn batch_composition_does_not_change_a_row() {
        // per-row activation scales: row 3 of a 8-batch == the same
        // image at batch 1, bitwise
        let q = single_layer_packed(32, 7, 4, 6.0);
        let mlp = QuantMlp::from_packed(&q).unwrap();
        assert!(mlp.layers[0].gemm.is_integer());
        let mut rng = Rng::new(44);
        let x: Vec<f32> = (0..8 * 32).map(|_| rng.normal()).collect();
        let batch = mlp.forward(&x, 8, 2);
        let solo = mlp.forward(&x[3 * 32..4 * 32], 1, 1);
        for (a, b) in batch[3 * 7..4 * 7].iter().zip(&solo) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn pool_runs_every_lane_once_per_generation() {
        use std::sync::atomic::AtomicUsize;
        let pool = WorkerPool::new(4);
        assert_eq!(pool.threads(), 4);
        let hits = AtomicUsize::new(0);
        let mask = AtomicUsize::new(0);
        for _ in 0..50 {
            pool.run(|wid, _s| {
                hits.fetch_add(1, Ordering::SeqCst);
                mask.fetch_or(1 << wid, Ordering::SeqCst);
            });
        }
        // 50 generations × 4 lanes, every lane id seen
        assert_eq!(hits.load(Ordering::SeqCst), 200);
        assert_eq!(mask.load(Ordering::SeqCst), 0b1111);
    }

    #[test]
    fn pool_single_lane_runs_inline() {
        let pool = WorkerPool::new(1);
        assert_eq!(pool.threads(), 1);
        let tid = std::thread::current().id();
        pool.run(|wid, _s| {
            assert_eq!(wid, 0);
            assert_eq!(std::thread::current().id(), tid, "inline lane left the caller");
        });
    }

    #[test]
    #[should_panic(expected = "worker pool job panicked")]
    fn pool_propagates_worker_panics() {
        let pool = WorkerPool::new(3);
        pool.run(|wid, _s| {
            if wid == 2 {
                panic!("boom on worker 2");
            }
        });
    }

    #[test]
    fn persistent_pool_matches_transient_forward_bitwise() {
        let (d, h, classes) = (64usize, 32usize, 10usize);
        let mut q = QuantizedCheckpoint::new(Json::obj(vec![
            ("k_a", Json::num(4.0)), // k_w·k_a = 16/4: dense + bitserial mix
            (
                "mlp_layers",
                Json::Arr(vec![Json::str("fc1"), Json::str("fc2")]),
            ),
        ]));
        q.push("fc1.w", PackedTensor::quantize(&random_tensor(vec![d, h], 61), 4));
        q.push("fc2.w", PackedTensor::quantize(&random_tensor(vec![h, classes], 62), 1));
        let mlp = QuantMlp::from_packed(&q).unwrap();
        assert_eq!(mlp.layers[0].gemm.plan_kind(), gemm::PlanKind::Int8);
        assert_eq!(mlp.layers[1].gemm.plan_kind(), gemm::PlanKind::Bitserial);
        let mut rng = Rng::new(63);
        let rows = 11usize;
        let x: Vec<f32> = (0..rows * d).map(|_| rng.normal()).collect();
        let base = mlp.forward(&x, rows, 1);
        let pool = WorkerPool::new(3);
        for _ in 0..3 {
            let got = mlp.forward_pooled(&x, rows, &pool);
            for (a, b) in base.iter().zip(&got) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
    }

    #[test]
    fn mlp_arena_stops_allocating_after_warmup() {
        // bitserial layers slice activation planes per request — after
        // the first (warm-up) batch every buffer must come from the
        // arenas: the pool's grow counter freezes.
        let q = single_layer_packed(96, 10, 2, 2.0);
        let mlp = QuantMlp::from_packed(&q).unwrap();
        assert_eq!(mlp.layers[0].gemm.plan_kind(), gemm::PlanKind::Bitserial);
        let pool = WorkerPool::new(2);
        let mut rng = Rng::new(77);
        let x: Vec<f32> = (0..8 * 96).map(|_| rng.normal()).collect();
        let first = mlp.forward_pooled(&x, 8, &pool);
        let warm = pool.grow_events();
        assert!(warm > 0, "warm-up should have populated the arenas");
        for _ in 0..5 {
            let again = mlp.forward_pooled(&x, 8, &pool);
            for (a, b) in first.iter().zip(&again) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
        assert_eq!(pool.grow_events(), warm, "hot path allocated after warm-up");
    }

    #[test]
    fn pool_scratch_arenas_recover_from_poisoned_jobs() {
        use std::sync::atomic::AtomicUsize;
        // a panicking job poisons the caller-lane arena mutex while
        // unwinding; lock_scratch must shrug the poison off and the
        // pool must keep serving jobs afterwards
        let pool = WorkerPool::new(2);
        let poisoned = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run(|_wid, _s| panic!("poison the arenas"));
        }));
        assert!(poisoned.is_err(), "panicking job must propagate");
        let hits = AtomicUsize::new(0);
        for _ in 0..3 {
            pool.run(|_wid, _s| {
                hits.fetch_add(1, Ordering::SeqCst);
            });
        }
        assert_eq!(hits.load(Ordering::SeqCst), 6, "pool wedged after a poisoned job");
    }

    /// Small, thread-light SplitMut carve — the Miri target for the
    /// raw-pointer aliasing model (scripts/analyze.sh filters on
    /// `splitmut`): disjoint ranges from scoped threads must cover the
    /// buffer exactly once.
    #[test]
    fn splitmut_disjoint_range_carve_covers_exactly() {
        let n = 130usize;
        let mut buf = vec![0u32; n];
        {
            let split = SplitMut::new(&mut buf);
            let parts = 4;
            std::thread::scope(|s| {
                for i in 0..parts {
                    let split = &split;
                    s.spawn(move || {
                        let (r0, r1) = chunk_range(n, parts, i);
                        // SAFETY: chunk_range partitions — disjoint.
                        let chunk = unsafe { split.range(r0, r1 - r0) };
                        for (j, c) in chunk.iter_mut().enumerate() {
                            *c = (r0 + j) as u32;
                        }
                    });
                }
            });
        }
        for (i, &v) in buf.iter().enumerate() {
            assert_eq!(v as usize, i);
        }
    }

    /// Interleaved per-cell writes (the tiled-epilogue shape) from two
    /// scoped threads — disjoint cells, every cell covered once.
    #[test]
    fn splitmut_disjoint_cell_writes_cover_exactly() {
        let n = 65usize; // odd length: exercises the claim-map tail word
        let mut buf = vec![0u32; n];
        {
            let split = SplitMut::new(&mut buf);
            std::thread::scope(|s| {
                for lane in 0..2usize {
                    let split = &split;
                    s.spawn(move || {
                        let mut i = lane;
                        while i < n {
                            // SAFETY: lanes write disjoint interleaved cells.
                            unsafe { split.write(i, (i + 1) as u32) };
                            i += 2;
                        }
                    });
                }
            });
        }
        for (i, &v) in buf.iter().enumerate() {
            assert_eq!(v as usize, i + 1);
        }
    }

    #[test]
    #[should_panic(expected = "SplitMut write out of bounds")]
    fn splitmut_write_out_of_bounds_panics() {
        let mut buf = vec![0.0f32; 4];
        let split = SplitMut::new(&mut buf);
        // SAFETY: never reached — the hard bounds assert fires first
        // (this is the release-mode guarantee the test pins down).
        unsafe { split.write(4, 1.0) };
    }

    #[test]
    #[should_panic(expected = "SplitMut range overflow")]
    fn splitmut_range_overflow_is_caught() {
        let mut buf = vec![0.0f32; 4];
        let split = SplitMut::new(&mut buf);
        // SAFETY: never reached — the checked add panics before any
        // pointer arithmetic can wrap.
        let _ = unsafe { split.range(usize::MAX, 2) };
    }

    /// The dynamic disjointness checker's negative test (debug builds
    /// only — release compiles the claim map out): a seeded overlapping
    /// carve must panic instead of silently aliasing.
    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "SplitMut overlapping claim")]
    fn splitmut_overlapping_carve_is_caught() {
        let mut buf = vec![0.0f32; 64];
        let split = SplitMut::new(&mut buf);
        // SAFETY: the first claim is exclusive; the second overlaps and
        // panics inside the claim map before a second alias exists.
        let _a = unsafe { split.range(0, 40) };
        // SAFETY: see above — this call panics, no alias is created.
        let _b = unsafe { split.range(32, 8) };
    }

    /// Same checker through the worker pool: two lanes claim ranges
    /// seeded to overlap; exactly one wins the atomic claim, the other
    /// panics, and the pool surfaces it as a job panic.
    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "worker pool job panicked")]
    fn splitmut_concurrent_overlap_is_caught_via_pool() {
        let pool = WorkerPool::new(2);
        let mut buf = vec![0.0f32; 128];
        let split = SplitMut::new(&mut buf);
        pool.run(|wid, _s| {
            let (start, len) = if wid == 0 { (0, 96) } else { (64, 64) };
            // SAFETY: the overlap is caught by the claim map before a
            // second mutable alias over [64, 96) can exist.
            let chunk = unsafe { split.range(start, len) };
            chunk[0] = 1.0;
        });
    }

    #[test]
    fn missing_and_mismatched_tensors_error() {
        let q = QuantizedCheckpoint::new(Json::obj(vec![(
            "mlp_layers",
            Json::Arr(vec![Json::str("fc1")]),
        )]));
        assert!(QuantMlp::from_packed(&q).is_err());
        // chain mismatch: fc1 outputs 12, fc2 expects 13
        let mut q2 = QuantizedCheckpoint::new(Json::obj(vec![
            ("k_a", Json::num(8.0)),
            (
                "mlp_layers",
                Json::Arr(vec![Json::str("fc1"), Json::str("fc2")]),
            ),
        ]));
        q2.push("fc1.w", PackedTensor::quantize(&random_tensor(vec![6, 12], 1), 4));
        q2.push("fc2.w", PackedTensor::quantize(&random_tensor(vec![13, 3], 2), 4));
        assert!(QuantMlp::from_packed(&q2).is_err());
    }
}
